"""Quick interactive validation of all kernels vs oracles (interpret mode)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

rng = np.random.default_rng(0)

# minmax_hash
fp = rng.random((37, 300)) < 0.1
mp = rng.integers(0, 2**31 - 1, size=(300, 130), dtype=np.int32)
mins_k, maxs_k = ops.minmax_hash(jnp.asarray(fp), jnp.asarray(mp))
mins_r, maxs_r = ref.minmax_hash(jnp.asarray(fp), jnp.asarray(mp))
np.testing.assert_array_equal(np.asarray(mins_k), np.asarray(mins_r))
np.testing.assert_array_equal(np.asarray(maxs_k), np.asarray(maxs_r))
print("minmax_hash OK")

# haar2d
imgs = rng.standard_normal((9, 32, 64)).astype(np.float32)
out_k = ops.haar2d(jnp.asarray(imgs))
out_r = ref.haar2d(jnp.asarray(imgs))
np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-4)
print("haar2d OK")

# stft_mag
frames = rng.standard_normal((50, 200)).astype(np.float32)
win = np.hanning(200).astype(np.float32)
dr, di = ref.dft_matrices(200, 101)
out_k = ops.stft_mag(jnp.asarray(frames), jnp.asarray(win), jnp.asarray(dr),
                     jnp.asarray(di))
out_r = ref.stft_mag(jnp.asarray(frames), jnp.asarray(win), jnp.asarray(dr),
                     jnp.asarray(di))
np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=2e-4, atol=1e-3)
print("stft_mag OK")

# jaccard
a = rng.integers(0, 2**32, size=(77, 8), dtype=np.uint32)
b = rng.integers(0, 2**32, size=(77, 8), dtype=np.uint32)
out_k = ops.jaccard_popcount(jnp.asarray(a), jnp.asarray(b))
out_r = ref.jaccard_popcount(jnp.asarray(a), jnp.asarray(b))
np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-6)
print("jaccard OK")

# flash attention
q = rng.standard_normal((2, 4, 128, 64)).astype(np.float32)
k = rng.standard_normal((2, 2, 128, 64)).astype(np.float32)
v = rng.standard_normal((2, 2, 128, 64)).astype(np.float32)
out_k = ops.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=True, bq=64, bk=64)
out_r = ref.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=True)
np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=2e-5)
print("flash_attention causal OK")

# decode shape: sq=8 with cache sk=128
q2 = rng.standard_normal((1, 4, 8, 64)).astype(np.float32)
out_k = ops.flash_attention(jnp.asarray(q2), jnp.asarray(k[:1]),
                            jnp.asarray(v[:1]), causal=True, bq=8, bk=64)
out_r = ref.flash_attention(jnp.asarray(q2), jnp.asarray(k[:1]),
                            jnp.asarray(v[:1]), causal=True)
np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=2e-5)
print("flash_attention decode OK")
print("ALL KERNELS OK")
