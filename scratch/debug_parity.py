import numpy as np
import jax
import jax.numpy as jnp

from repro.models import ModelConfig, decode_step, forward, init_cache, init_params
from repro.models import layers as L

cfg = ModelConfig(name="d", n_layers=1, d_model=32, n_heads=2, n_kv_heads=1,
                  d_ff=64, vocab_size=64, attn_q_block=8, attn_kv_block=8,
                  loss_seq_chunk=8, param_dtype="float32",
                  compute_dtype="float32")
B, S = 1, 16
rng = np.random.default_rng(0)
params = init_params(jax.random.PRNGKey(0), cfg)
tokens = jnp.asarray(rng.integers(0, 64, (B, S)), jnp.int32)

hidden, _ = forward(params, {"tokens": tokens}, cfg)

cache = init_cache(cfg, B, S)
outs = []
for t in range(S):
    lg, cache = decode_step(params, cache, tokens[:, t:t+1], cfg)
    outs.append(lg)

w = params["lm_head"].astype(jnp.float32)
fwd_logits = jnp.einsum("bsd,dv->bsv", hidden.astype(jnp.float32), w)
dec_logits = jnp.stack(outs, axis=1)
err = jnp.abs(dec_logits - fwd_logits).max(axis=(0, 2))
print("per-position err:", np.asarray(err))

# isolate attention: compare blocked_attention vs decode_attention directly
q = jnp.asarray(rng.standard_normal((B, S, 2, 16)), jnp.float32)
k = jnp.asarray(rng.standard_normal((B, S, 1, 16)), jnp.float32)
v = jnp.asarray(rng.standard_normal((B, S, 1, 16)), jnp.float32)
blocked = L.blocked_attention(q, k, v, cfg)   # (B, S, Hq, hd)
for t in [0, 5, 15]:
    o = L.decode_attention(q[:, t:t+1], k, v, jnp.array([t]), cfg)
    e = float(jnp.abs(o[:, 0] - blocked[:, t]).max())
    print(f"attn parity t={t}: {e:.2e}")
