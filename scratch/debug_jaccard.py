"""Check Jaccard similarity between fingerprints of reoccurring events."""
import numpy as np
import jax.numpy as jnp

from repro.core import FingerprintConfig, SynthConfig, make_dataset
from repro.core.fingerprint import fingerprints_from_waveform
from repro.kernels import ref

scfg = SynthConfig(duration_s=600.0, n_stations=3, n_sources=3,
                   events_per_source=4, repeating_noise_stations=(0,),
                   seed=3, event_snr=2.5)
ds = make_dataset(scfg)

for img_time, top_k, snr_scale in ((64, 200, 1.0), (32, 200, 1.0), (32, 400, 1.0)):
    fcfg = FingerprintConfig(img_time=img_time, img_hop=4, top_k=top_k,
                             mad_sample_rate=1.0)
    st = 1
    bits, packed = fingerprints_from_waveform(jnp.asarray(ds.waveforms[st]), fcfg)
    bits = np.asarray(bits)
    lag_s = fcfg.lag_samples / fcfg.fs
    win_s = fcfg.window_samples / fcfg.fs

    # fingerprint index whose window starts just before arrival
    def fp_idx(t_arr):
        return int(max(0, (t_arr - 1.0) / lag_s))

    sims = []
    for s in range(scfg.n_sources):
        evs = [i for i in range(len(ds.event_times)) if ds.event_sources[i] == s]
        for a in range(len(evs)):
            for b in range(a + 1, len(evs)):
                ia = fp_idx(ds.arrival_time(evs[a], st))
                ib = fp_idx(ds.arrival_time(evs[b], st))
                # best over small alignment jitter
                best = 0.0
                for da in range(-2, 3):
                    for db in range(-2, 3):
                        va = bits[np.clip(ia + da, 0, bits.shape[0] - 1)]
                        vb = bits[np.clip(ib + db, 0, bits.shape[0] - 1)]
                        inter = np.logical_and(va, vb).sum()
                        union = np.logical_or(va, vb).sum()
                        best = max(best, inter / max(union, 1))
                sims.append(best)
    # background pair similarity
    bg = []
    rng = np.random.default_rng(0)
    for _ in range(200):
        i, j = rng.integers(0, bits.shape[0], 2)
        if abs(int(i) - int(j)) < 16:
            continue
        inter = np.logical_and(bits[i], bits[j]).sum()
        union = np.logical_or(bits[i], bits[j]).sum()
        bg.append(inter / max(union, 1))
    print(f"img_time={img_time} top_k={top_k}: event-pair jaccard "
          f"p50={np.median(sims):.3f} p90={np.quantile(sims,0.9):.3f} "
          f"min={min(sims):.3f} | background p50={np.median(bg):.3f} "
          f"p99={np.quantile(bg,0.99):.3f}")
