import numpy as np, jax, jax.numpy as jnp, time
from repro.core import lsh as L
from repro.core import fingerprint as F
from repro.core.detect import DetectConfig
from repro.core.synth import SynthConfig, make_dataset
from repro.configs.fast_seismic import smoke_config
from repro.stream import StreamingDetector, StreamConfig, stream_step, block_coeffs
from repro.stream import index as _; from repro.stream.index import init_index, insert, query, StreamIndexConfig, index_stats

cfg = smoke_config()
fcfg, lcfg = cfg.fingerprint, cfg.lsh
print("fp window", fcfg.window_samples, "lag", fcfg.lag_samples, "halo", fcfg.halo_samples)

ds = make_dataset(SynthConfig(duration_s=600.0, n_stations=1, n_sources=2,
                              events_per_source=5, event_snr=3.0, seed=3))
wf = ds.waveforms[0]
print("samples", wf.size, "offline n_fp", fcfg.n_fingerprints(wf.size))

# offline reference
bits, packed = F.fingerprints_from_waveform(jnp.asarray(wf), fcfg,
                                            key=jax.random.PRNGKey(0))
pairs_off, stats_off = L.search(bits, lcfg)
v = np.asarray(pairs_off.valid)
off = set(zip(np.asarray(pairs_off.idx1)[v].tolist(),
              np.asarray(pairs_off.idx2)[v].tolist()))
print("offline pairs", len(off), {k: (float(v) if hasattr(v,'item') else v) for k,v in list(stats_off.items())[:2]})

# streaming with offline stats handed in (pure-machinery parity first)
coeffs_all = F.coeffs_from_waveform(jnp.asarray(wf), fcfg)
med_mad = F.mad_stats(coeffs_all, 1.0, jax.random.PRNGKey(0))
scfg = StreamConfig(block_fingerprints=64,
                    index=StreamIndexConfig(n_buckets=2048, bucket_cap=8),
                    stats_warmup_blocks=2)
det = StreamingDetector(cfg, scfg, n_stations=1,
                        med_mad=(np.asarray(med_mad[0]), np.asarray(med_mad[1])))
n_chunks = 10
for c in np.array_split(wf, n_chunks):
    det.push(c)
st = det.stations[0]
events, pairs_s, fstats = st.finalize()
vs = np.asarray(pairs_s.valid)
stream = set(zip(np.asarray(pairs_s.idx1)[vs].tolist(),
                 np.asarray(pairs_s.idx2)[vs].tolist()))
print("stream pairs", len(stream), "fstats", fstats)
print("stream n_fp", st.ring.next_fp)
common = off & stream
print("recovered %.3f" % (len(common) / max(len(off), 1)),
      "spurious", len(stream - off))
print(index_stats(st.state))
print("ingest", st.stats.summary())
