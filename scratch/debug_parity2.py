import numpy as np
import jax
import jax.numpy as jnp

from repro.models import ModelConfig, init_params
from repro.models import layers as L
from repro.models.decoder import _embed_inputs

cfg = ModelConfig(name="d", n_layers=1, d_model=32, n_heads=2, n_kv_heads=1,
                  d_ff=64, vocab_size=64, attn_q_block=8, attn_kv_block=8,
                  param_dtype="float32", compute_dtype="float32")
B, S = 1, 16
rng = np.random.default_rng(0)
params = init_params(jax.random.PRNGKey(0), cfg)
tokens = jnp.asarray(rng.integers(0, 64, (B, S)), jnp.int32)

lp = jax.tree.map(lambda a: a[0], params["layers"])  # unstack layer 0

# forward path manual
x = _embed_inputs(params, {"tokens": tokens}, cfg)
pos = jnp.arange(S)
x_f = L.attention_block(lp["attn"], x, cfg, pos)
x_f = L.mlp_block(lp["mlp"], x_f, cfg)

# decode path manual, position 0
x0 = x[:, :1]
cache = {"k": jnp.zeros((B, S, 1, 16), jnp.bfloat16),
         "v": jnp.zeros((B, S, 1, 16), jnp.bfloat16)}
x_d, _ = L.attention_block_decode(lp["attn"], x0, cache,
                                  jnp.zeros((B,), jnp.int32), cfg)
x_d = L.mlp_block(lp["mlp"], x_d, cfg)
print("post-block err t=0:", float(jnp.abs(x_d[:, 0] - x_f[:, 0]).max()))

# attention block only
a_f = L.attention_block(lp["attn"], x, cfg, pos)
a_d, _ = L.attention_block_decode(lp["attn"], x0, cache,
                                  jnp.zeros((B,), jnp.int32), cfg)
print("post-attn err t=0:", float(jnp.abs(a_d[:, 0] - a_f[:, 0]).max()))

# qkv parity
h = L.rms_norm(x, lp["attn"]["ln"], cfg.rms_eps)
q1, k1, v1 = L.qkv_project(lp["attn"], h, cfg, pos)
h0 = L.rms_norm(x0, lp["attn"]["ln"], cfg.rms_eps)
q2, k2, v2 = L.qkv_project(lp["attn"], h0, cfg,
                           jnp.zeros((B,), jnp.int32)[:, None])
print("q err:", float(jnp.abs(q1[:, :1] - q2).max()),
      "k err:", float(jnp.abs(k1[:, :1] - k2).max()))
