"""End-to-end smoke of the FAST pipeline on synthetic data."""
import time

import numpy as np

from repro.core import (AlignConfig, DetectConfig, FingerprintConfig,
                        LSHConfig, SynthConfig, make_dataset)
from repro.core.detect import detect_events, recall_against_truth

t0 = time.perf_counter()
scfg = SynthConfig(duration_s=600.0, n_stations=3, n_sources=3,
                   events_per_source=4, repeating_noise_stations=(0,),
                   seed=3, event_snr=3.0)
ds = make_dataset(scfg)
print(f"synth: {ds.waveforms.shape}, {len(ds.event_times)} events, "
      f"{time.perf_counter()-t0:.1f}s")

fcfg = FingerprintConfig(img_time=32, img_hop=4, top_k=200,
                         mad_sample_rate=1.0)
lcfg = LSHConfig(n_tables=100, n_funcs=4, n_matches=2, bucket_cap=8,
                 min_dt=fcfg.overlap_fingerprints,
                 occurrence_frac=0.05)
acfg = AlignConfig(channel_threshold=3, min_cluster_sim=4,
                   min_cluster_size=1, min_stations=2,
                   onset_tol=int(10 * fcfg.fs / fcfg.lag_samples))
cfg = DetectConfig(fingerprint=fcfg, lsh=lcfg, align=acfg)

t0 = time.perf_counter()
det, station_events, times, stats = detect_events(ds.waveforms, cfg)
print(f"detect: {time.perf_counter()-t0:.1f}s wall")
print("stage times:", times)
print("stats:", {k: v for k, v in stats.items()})
rec = recall_against_truth(det, station_events, ds, fcfg)
print("recall:", rec)
assert rec["recall"] >= 0.7, rec
print("OK")
