"""Generate tests/golden/batch_detect.json (legacy detect_events pin).

The unified batch driver (PR 5: ``detect_events`` as a replay over the
streaming core) must reproduce the *legacy* host-orchestrated per-station
loop bit-exactly on the seed synthetic dataset. The legacy chain itself
was deleted in that PR, so this generator carries a verbatim copy of it:
fingerprint → signatures → sort-based candidate search → §6.5 occurrence
filter → channel merge → diagonal clustering, per station, then network
association. Regenerating the golden therefore never needs the old code
back — run this script and commit the JSON.
"""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AlignConfig, DetectConfig, FingerprintConfig,
                        LSHConfig, SynthConfig, make_dataset)
from repro.core import align as align_mod
from repro.core import fingerprint as fp_mod
from repro.core import lsh as lsh_mod
from repro.core.detect import recall_against_truth

SYNTH = dict(duration_s=420.0, n_stations=3, n_sources=2,
             events_per_source=4, repeating_noise_stations=(0,),
             event_snr=3.0, seed=3)


def golden_cfg() -> DetectConfig:
    """The tests/test_detect_e2e.py configuration (pin target)."""
    fcfg = FingerprintConfig(img_time=32, img_hop=4, top_k=200,
                             mad_sample_rate=1.0)
    lcfg = LSHConfig(n_tables=100, n_funcs=4, n_matches=2, bucket_cap=8,
                     min_dt=fcfg.overlap_fingerprints, occurrence_frac=0.05)
    acfg = AlignConfig(channel_threshold=3, min_cluster_sim=4,
                       min_cluster_size=1, min_stations=2,
                       onset_tol=int(10 * fcfg.fs / fcfg.lag_samples))
    return DetectConfig(fingerprint=fcfg, lsh=lcfg, align=acfg)


def legacy_detect_events(waveforms, cfg):
    """Verbatim copy of the pre-PR-5 ``detect_events`` station loop."""
    n_stations = waveforms.shape[0]
    stats, station_events, station_pairs = {}, [], []
    fcfg, lcfg, acfg = cfg.fingerprint, cfg.lsh, cfg.align
    for st in range(n_stations):
        x = jnp.asarray(waveforms[st])
        bits, _ = fp_mod.fingerprints_from_waveform(
            x, fcfg, key=jax.random.PRNGKey(fcfg.stft_len + st))
        mp = lsh_mod.hash_mappings(fcfg.fp_dim, lcfg)
        sigs = lsh_mod.signatures(bits, mp, lcfg)
        pairs = lsh_mod.candidate_pairs(sigs, lcfg)
        if lcfg.occurrence_frac > 0:
            pairs, excluded = lsh_mod.occurrence_filter(
                pairs, bits.shape[0], lcfg.occurrence_frac)
            stats[f"station{st}_excluded"] = int(excluded.sum())
        stats[f"station{st}_pairs"] = int(pairs.count())
        stats[f"station{st}_fingerprints"] = int(bits.shape[0])
        merged = align_mod.merge_channels(
            [(pairs.dt, pairs.idx1, pairs.sim, pairs.valid)],
            acfg.channel_threshold)
        events = align_mod.cluster_station(merged, acfg)
        stats[f"station{st}_events"] = int(events.count())
        station_events.append(events)
        station_pairs.append(pairs)
    detections = align_mod.associate_network(station_events, acfg, n_stations)
    stats["detections"] = int(detections["valid"].sum())
    return detections, station_events, station_pairs, stats


def main():
    cfg = golden_cfg()
    ds = make_dataset(SynthConfig(**SYNTH))
    _, events, pairs, stats = legacy_detect_events(ds.waveforms, cfg)
    rec = recall_against_truth({}, events, ds, cfg.fingerprint)
    per_station = []
    for p in pairs:
        v = np.asarray(p.valid)
        tri = sorted(zip(np.asarray(p.idx1)[v].tolist(),
                         np.asarray(p.idx2)[v].tolist(),
                         np.asarray(p.sim)[v].tolist()))
        per_station.append([list(t) for t in tri])
    # ISSUE 8 guard: the replay with the emission epilogue on (compaction
    # sized above the true pair rate + exact-Jaccard verify) must still
    # reproduce the legacy pair set this golden pins
    import dataclasses
    from repro.core.detect import detect_events, replay_config
    scfg = replay_config(cfg.lsh)
    scfg = dataclasses.replace(
        scfg, max_pairs_per_block=4096, verify_jaccard=True,
        index=dataclasses.replace(scfg.index, pk_slots=8192))
    _, _, _, cstats = detect_events(ds.waveforms, cfg, scfg=scfg,
                                    keep_pairs=True)
    for st, p in enumerate(cstats.pop("_station_pairs")):
        v = np.asarray(p.valid)
        tri = sorted(zip(np.asarray(p.idx1)[v].tolist(),
                         np.asarray(p.idx2)[v].tolist(),
                         np.asarray(p.sim)[v].tolist()))
        assert [list(t) for t in tri] == per_station[st], \
            f"compacted replay diverged from legacy at station {st} — " \
            "do not regenerate goldens"
    out = {
        "synth": SYNTH,
        "station_pairs": per_station,
        "stats": stats,
        "recall": rec,
    }
    print({k: v for k, v in stats.items()})
    print("recall", rec)
    p = pathlib.Path("tests/golden/batch_detect.json")
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(out, indent=1))
    print("wrote", p)


if __name__ == "__main__":
    main()
