"""Tiny-config smoke of every model family: loss, grads, decode parity."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.models import (ModelConfig, decode_step, forward, init_cache,
                          init_params, lm_loss, prefill)

CONFIGS = {
    "dense": ModelConfig(name="dense", n_layers=2, d_model=64, n_heads=4,
                         n_kv_heads=2, d_ff=128, vocab_size=256,
                         attn_q_block=16, attn_kv_block=16, loss_seq_chunk=16,
                         param_dtype="float32", compute_dtype="float32",
                         cache_dtype="float32"),
    "qkvbias": ModelConfig(name="qkvbias", n_layers=2, d_model=64, n_heads=4,
                           n_kv_heads=4, d_ff=128, vocab_size=256,
                           qkv_bias=True, attn_q_block=16, attn_kv_block=16,
                           loss_seq_chunk=16, param_dtype="float32",
                           compute_dtype="float32",
                           cache_dtype="float32"),
    "moe": ModelConfig(name="moe", n_layers=2, d_model=64, n_heads=4,
                       n_kv_heads=4, d_ff=0, vocab_size=256, n_experts=8,
                       n_shared_experts=2, moe_top_k=2, expert_ff=32,
                       capacity_factor=8.0,
                       attn_q_block=16, attn_kv_block=16, loss_seq_chunk=16,
                       param_dtype="float32", compute_dtype="float32",
                         cache_dtype="float32"),
    "mamba1": ModelConfig(name="mamba1", n_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=4, d_ff=0, vocab_size=256,
                          block_kind="mamba1", ssm_state=8, ssm_chunk=16,
                          loss_seq_chunk=16, param_dtype="float32",
                          compute_dtype="float32", cache_dtype="float32", subquadratic=True),
    "hybrid": ModelConfig(name="hybrid", n_layers=5, d_model=64, n_heads=4,
                          n_kv_heads=4, d_ff=0, vocab_size=256,
                          block_kind="mamba2", ssm_state=16,
                          ssm_head_dim=16, ssm_chunk=16,
                          shared_attn_every=2, attn_q_block=16,
                          attn_kv_block=16, loss_seq_chunk=16,
                          param_dtype="float32", compute_dtype="float32",
                         cache_dtype="float32",
                          subquadratic=True),
}

B, S = 2, 32
rng = np.random.default_rng(0)

for name, cfg in CONFIGS.items():
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    loss, metrics = lm_loss(params, batch, cfg)
    assert np.isfinite(float(loss)), (name, loss)
    grads = jax.grad(lambda p: lm_loss(p, batch, cfg)[0])(params)
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads)) ** 0.5
    assert np.isfinite(gnorm) and gnorm > 0, (name, gnorm)

    # decode parity: prefill S tokens, decode next == forward on S+1
    hidden, _ = forward(params, batch, cfg)
    logits_last, cache = prefill(params, batch, cfg)
    logits_step, cache2 = decode_step(params, cache, tokens[:, -1:], cfg)
    # compare: run decode from an EMPTY cache token by token vs forward
    cache0 = init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, cache0 = decode_step(params, cache0, tokens[:, t:t + 1], cfg)
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)  # (B, S, V)
    w = params["lm_head"].astype(jnp.float32)
    fwd_logits = jnp.einsum("bsd,dv->bsv", hidden.astype(jnp.float32), w)
    err = float(jnp.max(jnp.abs(dec_logits - fwd_logits)))
    scale = float(jnp.max(jnp.abs(fwd_logits))) + 1e-9
    print(f"{name}: loss={float(loss):.3f} gnorm={gnorm:.2e} "
          f"decode_max_err={err:.2e} (rel {err/scale:.2e})")
    assert err / scale < 2e-3, (name, err, scale)

print("ALL MODEL FAMILIES OK")
