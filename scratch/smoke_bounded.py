import numpy as np
import jax.numpy as jnp

from repro.configs.fast_seismic import (smoke_config,
                                        stream_bounded_smoke_config)
from repro.core.synth import SynthConfig, make_dataset
from repro.stream import StreamingDetector

cfg, scfg = smoke_config(), stream_bounded_smoke_config()
ds = make_dataset(SynthConfig(duration_s=600.0, n_stations=3, n_sources=2,
                              events_per_source=5, event_snr=3.0, seed=11))
wf = ds.waveforms

det = StreamingDetector(cfg, scfg, n_stations=3)
for start in range(0, wf.shape[1], 6000):
    det.push(wf[:, start:start + 6000])
print("alerts during stream:", sum(a.shape[0] for a in det.alerts))
print("peak buffered rows:",
      [st.peak_tri_rows for st in det.stations])
detections, events, stats = det.finalize()
print("detections:", stats.get("detections"), "alerts:", stats.get("alerts"))
print({k: v for k, v in stats.items() if not k.startswith("ingest")})

# snapshot/restore round trip: run half, snapshot, restore, run rest
import tempfile
d = tempfile.mkdtemp()
det1 = StreamingDetector(cfg, scfg, n_stations=3)
starts = list(range(0, wf.shape[1], 6000))
half = len(starts) // 2
for s in starts[:half]:
    det1.push(wf[:, s:s + 6000])
det1.snapshot(d, step=half)
det2, step = StreamingDetector.restore(d, cfg, scfg)
for s in starts[half:]:
    det1.push(wf[:, s:s + 6000])
    det2.push(wf[:, s:s + 6000])
d1, e1, s1 = det1.finalize()
d2, e2, s2 = det2.finalize()
uninterrupted = StreamingDetector(cfg, scfg, n_stations=3)
for s in starts:
    uninterrupted.push(wf[:, s:s + 6000])
d0, e0, s0 = uninterrupted.finalize()
for name in ("dt", "onset", "n_stations", "score", "valid"):
    a0, a1, a2 = (np.asarray(d0[name]), np.asarray(d1[name]),
                  np.asarray(d2[name]))
    assert (a0 == a2).all(), (name, a0, a2)
    assert (a0 == a1).all(), (name, "continuation mismatch")
print("round-trip detections identical:", True,
      "n =", int(np.asarray(d0["valid"]).sum()))
