"""Generate tests/golden/stream_pairs.json (fixed-seed parity pin)."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.fast_seismic import smoke_config
from repro.core import fingerprint as F
from repro.core import lsh as L
from repro.core.synth import SynthConfig, make_dataset
from repro.stream import StreamingDetector, StreamConfig, StreamIndexConfig

SYNTH = dict(duration_s=600.0, n_stations=1, n_sources=2,
             events_per_source=5, event_snr=3.0, seed=3)
N_CHUNKS = 10

cfg = smoke_config()
ds = make_dataset(SynthConfig(**SYNTH))
wf = ds.waveforms[0]
fcfg = cfg.fingerprint
bits, _ = F.fingerprints_from_waveform(jnp.asarray(wf), fcfg,
                                       key=jax.random.PRNGKey(0))
pairs_off, _ = L.search(bits, cfg.lsh)
v = np.asarray(pairs_off.valid)
off = sorted(zip(np.asarray(pairs_off.idx1)[v].tolist(),
                 np.asarray(pairs_off.idx2)[v].tolist()))
med_mad = F.mad_stats(F.coeffs_from_waveform(jnp.asarray(wf), fcfg), 1.0,
                      jax.random.PRNGKey(0))
med_mad = (np.asarray(med_mad[0]), np.asarray(med_mad[1]))


def stream_pairs(mm, compact=False):
    scfg = StreamConfig(block_fingerprints=64,
                        index=StreamIndexConfig(n_buckets=2048, bucket_cap=8,
                                                pk_slots=4096)
                        if compact else
                        StreamIndexConfig(n_buckets=2048, bucket_cap=8),
                        stats_warmup_blocks=2,
                        max_pairs_per_block=512 if compact else 0,
                        verify_jaccard=compact)
    det = StreamingDetector(cfg, scfg, n_stations=1, med_mad=mm)
    for chunk in np.array_split(wf, N_CHUNKS):
        det.push(chunk)
    _, pairs, _ = det.stations[0].finalize()
    pv = np.asarray(pairs.valid)
    return sorted(zip(np.asarray(pairs.idx1)[pv].tolist(),
                      np.asarray(pairs.idx2)[pv].tolist()))


two = stream_pairs(med_mad)
self_ = stream_pairs(None)
# ISSUE 8 guard: the golden pair set must be compaction-invariant — the
# emission epilogue (compact + verify at the smoke knobs) may not change
# the pairs this file pins
assert stream_pairs(med_mad, compact=True) == two, \
    "compacted emission diverged from dense — do not regenerate goldens"
offs, twos, selfs = set(off), set(two), set(self_)
r2 = len(offs & twos) / len(offs)
rs = len(offs & selfs) / len(offs)
print(f"offline={len(offs)} two_pass={len(twos)} (recall {r2:.3f}) "
      f"self={len(selfs)} (recall {rs:.3f})")

out = {
    "synth": SYNTH,
    "n_chunks": N_CHUNKS,
    "offline_pairs": [list(p) for p in off],
    "stream_two_pass_pairs": [list(p) for p in two],
    "two_pass_recall": round(r2, 4),
    "self_stats_recall": round(rs, 4),
}
p = pathlib.Path("tests/golden/stream_pairs.json")
p.parent.mkdir(parents=True, exist_ok=True)
p.write_text(json.dumps(out, indent=1))
print("wrote", p)
