"""Observability primitives (ISSUE 6).

Generic, dependency-light building blocks shared by the streaming
detector, the batch replay driver, and the serving loop:

``metrics``
    Host-side :class:`MetricsRegistry` — counters, gauges, and
    log-bucketed histograms with labels (``station="3"``), O(1) memory
    per metric, a JSON-able ``snapshot()``/``restore()`` pair (so they
    ride inside detector checkpoints), and a Prometheus text exposition
    (``render_prometheus``).

``spans``
    :class:`SpanTracer` — lightweight nested wall-clock spans
    (ingest → fused step → host tail → merge/cluster → associate)
    that always accumulate per-name totals and optionally emit a
    structured JSONL event log; plus an optional ``jax.profiler``
    trace-dump hook for when a heartbeat anomaly needs an XLA-level
    view.

What is *counted* where for the detection path (which counters come
from inside the fused dispatch vs. from the host) is documented in
``repro.stream`` ("observability path") — this package only provides
the containers.
"""
from repro.obsv.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                                merge_counts, render_prometheus)
from repro.obsv.spans import SpanTracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "merge_counts", "render_prometheus", "SpanTracer",
]
