"""Lightweight nested wall-clock spans with optional JSONL emission.

A span is one stage of the detection path (``ingest`` → ``fused_step`` →
``host_tail`` → ``merge`` → ``associate``). Entering/leaving is two
clock reads and a dict update, so the tracer stays on in production;
the JSONL event log is opt-in (pass ``jsonl_path``) and each record is
one line::

    {"ts": 1754660000.1, "name": "fused_step", "path": "chunk/fused_step",
     "depth": 1, "dur_s": 0.0021, "station": 0}

Per-name totals accumulate regardless of the sink, which is how the
span layer *derives* stage attribution (``StageTimes`` in
``core.detect`` reads them back instead of keeping its own stopwatch).

``profile()`` is the optional ``jax.profiler`` hook: when the tracer was
built with ``profile_dir`` it brackets the wrapped region with an XLA
trace dump (viewable in TensorBoard/Perfetto); otherwise it is a no-op
context.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Callable, IO


class SpanTracer:
    def __init__(self, jsonl_path: str | None = None,
                 clock: Callable[[], float] = time.perf_counter,
                 profile_dir: str | None = None):
        self.clock = clock
        self.jsonl_path = jsonl_path
        self.profile_dir = profile_dir
        self._fh: IO | None = None
        self._stack: list[str] = []
        # name -> [count, total_s]; insertion-ordered = first-entered order
        self.totals: dict[str, list] = {}

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        self._stack.append(name)
        t0 = self.clock()
        try:
            yield self
        finally:
            dt = self.clock() - t0
            path = "/".join(self._stack)
            self._stack.pop()
            tot = self.totals.get(name)
            if tot is None:
                tot = self.totals[name] = [0, 0.0]
            tot[0] += 1
            tot[1] += dt
            if self.jsonl_path is not None:
                rec = {"ts": time.time(), "name": name, "path": path,
                       "depth": len(self._stack), "dur_s": dt}
                rec.update(attrs)
                if self._fh is None:
                    self._fh = open(self.jsonl_path, "a")
                self._fh.write(json.dumps(rec) + "\n")

    def total_s(self, name: str) -> float:
        return self.totals.get(name, (0, 0.0))[1]

    def summary(self) -> dict:
        return {name: {"count": c, "total_s": t}
                for name, (c, t) in self.totals.items()}

    @contextlib.contextmanager
    def profile(self):
        """Bracket a region with a ``jax.profiler`` trace dump (no-op
        unless the tracer was given a ``profile_dir``)."""
        if self.profile_dir is None:
            yield
            return
        import jax
        with jax.profiler.trace(self.profile_dir):
            yield

    def flush(self):
        if self._fh is not None:
            self._fh.flush()

    def close(self):
        if self._fh is not None:
            self._fh.close()
            self._fh = None
