"""Host metrics registry: counters, gauges, log-bucketed histograms.

Every metric is O(1) memory — histograms are a fixed array of
power-of-two buckets, not a sample list — so a detector can run for
months without its telemetry growing (the unbounded
``StreamStats.chunk_wall_s`` list this replaces was O(stream)).

Metrics carry a label mapping (``station="3"``); the registry indexes by
``(name, sorted labels)`` so the same metric name fans out per station
while aggregate views (``total``) sum across labels. ``snapshot()``
returns a plain JSON-able dict and ``restore()`` rebuilds from it, which
is what lets the registry ride inside detector checkpoints
(``StreamingDetector.snapshot``) and benchmark artifacts
(``BENCH_stream.json``'s ``metrics`` section).

``render_prometheus`` emits the text exposition format
(https://prometheus.io/docs/instrumenting/exposition_formats/) consumed
by ``serve_detect --metrics-file``; the format-guard test parses it back.
"""
from __future__ import annotations

import math


class Counter:
    """Monotonic counter. ``set_total`` exists only to mirror counts that
    are authoritatively kept elsewhere (e.g. ring quality dicts) into the
    exposition — it never goes backwards."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int | float = 1):
        self.value += n

    def set_total(self, v: int | float):
        self.value = max(self.value, v)


class Gauge:
    """Point-in-time value (host_state_rows, real-time factor, ...)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)


class Histogram:
    """Log-bucketed wall-time histogram with fixed memory.

    Buckets are powers of two spanning ``[lo, lo * 2**(n_buckets-1))``
    seconds (defaults cover ~8 µs .. ~2 min); values outside clamp to the
    edge buckets. Tracks count/sum/min/max exactly, percentiles to
    bucket resolution (each estimate returns the upper edge of the
    bucket holding that rank — a ≤ 2x overestimate, fine for p50/p95
    monitoring).
    """

    __slots__ = ("lo", "counts", "total", "count", "vmin", "vmax")

    N_BUCKETS = 25

    def __init__(self, lo: float = 2.0 ** -17):
        self.lo = float(lo)
        self.counts = [0] * self.N_BUCKETS
        self.total = 0.0
        self.count = 0
        self.vmin = math.inf
        self.vmax = -math.inf

    def _bucket(self, v: float) -> int:
        if v <= self.lo:
            return 0
        i = int(math.ceil(math.log2(v / self.lo)))
        return min(i, self.N_BUCKETS - 1)

    def record(self, v: float):
        v = float(v)
        self.counts[self._bucket(v)] += 1
        self.total += v
        self.count += 1
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    def edges(self) -> list[float]:
        """Upper edge of each bucket (the Prometheus ``le`` labels)."""
        return [self.lo * 2.0 ** i for i in range(self.N_BUCKETS)]

    def percentile(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        rank = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= rank:
                return min(self.lo * 2.0 ** i, self.vmax)
        return self.vmax

    def summary(self) -> dict:
        return {"count": self.count, "sum": self.total,
                "min": 0.0 if self.count == 0 else self.vmin,
                "max": 0.0 if self.count == 0 else self.vmax,
                "p50": self.percentile(0.50), "p95": self.percentile(0.95)}


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _label_str(key: tuple) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class MetricsRegistry:
    """Name + labels → metric instance; one registry per detector."""

    def __init__(self):
        # name -> kind ("counter"|"gauge"|"histogram"), insertion-ordered
        self._kinds: dict[str, str] = {}
        # (name, label_key) -> metric
        self._metrics: dict[tuple, object] = {}

    def _get(self, kind: str, cls, name: str, labels: dict):
        have = self._kinds.setdefault(name, kind)
        assert have == kind, f"{name} already registered as {have}"
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = cls()
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", Histogram, name, labels)

    def total(self, name: str) -> float:
        """Sum of a counter/gauge across all label sets (0 if absent)."""
        return sum(m.value for (n, _), m in self._metrics.items()
                   if n == name)

    def histogram_merged(self, name: str) -> Histogram:
        """All label sets of a histogram folded into one (for summaries)."""
        out = Histogram()
        for (n, _), m in self._metrics.items():
            if n == name:
                out.lo = m.lo
                out.counts = [a + b for a, b in zip(out.counts, m.counts)]
                out.total += m.total
                out.count += m.count
                out.vmin = min(out.vmin, m.vmin)
                out.vmax = max(out.vmax, m.vmax)
        return out

    # -- persistence --------------------------------------------------------

    def snapshot(self) -> dict:
        counters, gauges, histograms = [], [], []
        for (name, key), m in self._metrics.items():
            labels = dict(key)
            kind = self._kinds[name]
            if kind == "counter":
                counters.append({"name": name, "labels": labels,
                                 "value": m.value})
            elif kind == "gauge":
                gauges.append({"name": name, "labels": labels,
                               "value": m.value})
            else:
                histograms.append({
                    "name": name, "labels": labels, "lo": m.lo,
                    "counts": list(m.counts), "sum": m.total,
                    "count": m.count,
                    "min": None if m.count == 0 else m.vmin,
                    "max": None if m.count == 0 else m.vmax})
        return {"schema": "metrics/v1", "counters": counters,
                "gauges": gauges, "histograms": histograms}

    def restore(self, snap: dict):
        self._kinds.clear()
        self._metrics.clear()
        for c in snap.get("counters", []):
            self.counter(c["name"], **c["labels"]).value = c["value"]
        for g in snap.get("gauges", []):
            self.gauge(g["name"], **g["labels"]).value = g["value"]
        for h in snap.get("histograms", []):
            m = self.histogram(h["name"], **h["labels"])
            m.lo = h["lo"]
            m.counts = list(h["counts"])
            m.total = h["sum"]
            m.count = h["count"]
            m.vmin = math.inf if h["min"] is None else h["min"]
            m.vmax = -math.inf if h["max"] is None else h["max"]

    def render(self, namespace: str = "repro") -> str:
        return render_prometheus(self, namespace=namespace)


def render_prometheus(reg: MetricsRegistry, namespace: str = "repro") -> str:
    """Prometheus text exposition (version 0.0.4) of a registry."""
    lines: list[str] = []
    for name, kind in reg._kinds.items():
        full = f"{namespace}_{name}"
        lines.append(f"# TYPE {full} {kind}")
        for (n, key), m in reg._metrics.items():
            if n != name:
                continue
            ls = _label_str(key)
            if kind in ("counter", "gauge"):
                lines.append(f"{full}{ls} {_fmt(m.value)}")
            else:
                acc = 0
                for edge, c in zip(m.edges(), m.counts):
                    acc += c
                    el = _label_str(key + (("le", _fmt(edge)),))
                    lines.append(f"{full}_bucket{el} {acc}")
                el = _label_str(key + (("le", "+Inf"),))
                lines.append(f"{full}_bucket{el} {m.count}")
                lines.append(f"{full}_sum{ls} {_fmt(m.total)}")
                lines.append(f"{full}_count{ls} {m.count}")
    return "\n".join(lines) + "\n"


def _fmt(v) -> str:
    if isinstance(v, int):
        return str(v)
    return repr(float(v))


def merge_counts(dicts) -> dict:
    """Key-wise integer sum of count dicts, first-seen key order.

    The single aggregation path behind every quality/drop summary
    (``StationStream.quality_summary``, the pooled
    ``StreamingDetector.quality_summary``, ``metrics_snapshot`` drop
    breakdowns) — one implementation, identical keys everywhere.
    """
    out: dict = {}
    for d in dicts:
        for k, v in d.items():
            out[k] = out.get(k, 0) + int(v)
    return out
