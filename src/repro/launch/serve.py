"""Batched serving engine: static decode slots + continuous refill.

A production-shaped (if compact) serving loop: requests queue up, prefill
fills empty slots, a jitted decode step advances all slots each tick, and
finished sequences (EOS / max tokens) are evicted and replaced. Per-slot
position bookkeeping lives in the decode cache's ``pos`` vector.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smoke --requests 12
"""
from __future__ import annotations

import argparse
import json
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import LM_ARCHS, get_smoke_config
from repro.models import (ModelConfig, decode_step, init_cache, init_params,
                          prefill)
from repro.launch.train import default_smoke_model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Static-batch continuous serving over ``n_slots`` decode lanes."""

    def __init__(self, cfg: ModelConfig, n_slots: int = 4,
                 max_len: int = 256, seed: int = 0):
        import dataclasses
        cfg = dataclasses.replace(cfg, uniform_decode_pos=False)
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.params = init_params(jax.random.PRNGKey(seed), cfg)
        self.cache = init_cache(cfg, n_slots, max_len)
        self.slot_req: list[Request | None] = [None] * n_slots
        self.slot_limit = np.zeros(n_slots, np.int64)
        self.cur_tokens = np.zeros((n_slots, 1), np.int32)
        self._decode = jax.jit(
            lambda p, c, t: decode_step(p, c, t, self.cfg))
        self.ticks = 0
        self.generated = 0

    def _prefill_slot(self, slot: int, req: Request):
        """Single-sequence prefill → copy KV/state into the slot."""
        toks = jnp.asarray(req.prompt[None, :], jnp.int32)
        logits, cache1 = jax.jit(
            lambda p, b: prefill(p, b, self.cfg))(self.params,
                                                  {"tokens": toks})
        s = req.prompt.shape[0]

        def place(dst, src):
            if dst.ndim >= 3 and dst.shape[1] == self.n_slots \
                    and src.shape[1] == 1:
                # (L, B, S, ...) caches: pad src seq dim up to max_len
                pad = [(0, 0)] * src.ndim
                pad[2] = (0, dst.shape[2] - src.shape[2])
                src_p = jnp.pad(src, pad) if src.shape[2] != dst.shape[2] \
                    else src
                return dst.at[:, slot].set(src_p[:, 0])
            return dst

        new_cache = {}
        for k, v in self.cache.items():
            if k == "pos":
                new_cache[k] = v.at[slot].set(s)
            elif k in cache1 and hasattr(cache1[k], "shape"):
                new_cache[k] = place(v, cache1[k])
            else:
                new_cache[k] = v
        self.cache = new_cache
        nxt = int(jnp.argmax(logits[0]))
        self.cur_tokens[slot, 0] = nxt
        req.out.append(nxt)
        self.slot_req[slot] = req
        self.slot_limit[slot] = s + req.max_new

    def run(self, requests: list[Request]) -> dict:
        queue = list(requests)
        active = lambda: any(r is not None for r in self.slot_req)
        t0 = time.perf_counter()
        while queue or active():
            # refill empty slots
            for slot in range(self.n_slots):
                if self.slot_req[slot] is None and queue:
                    self._prefill_slot(slot, queue.pop(0))
            # one decode tick for all slots
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(self.cur_tokens))
            self.ticks += 1
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            pos = np.asarray(self.cache["pos"])
            for slot in range(self.n_slots):
                req = self.slot_req[slot]
                if req is None:
                    continue
                tok = int(nxt[slot])
                req.out.append(tok)
                self.generated += 1
                if pos[slot] >= min(self.slot_limit[slot],
                                    self.max_len - 1):
                    req.done = True
                    self.slot_req[slot] = None
                else:
                    self.cur_tokens[slot, 0] = tok
        dt = time.perf_counter() - t0
        return {"requests": len(requests), "ticks": self.ticks,
                "generated": self.generated, "wall_s": round(dt, 3),
                "tokens_per_s": round(self.generated / max(dt, 1e-9), 1)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = default_smoke_model() if args.arch == "smoke" \
        else get_smoke_config(args.arch)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(
                        1, cfg.vocab_size,
                        size=rng.integers(4, args.prompt_len)).astype(
                            np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    eng = ServeEngine(cfg, n_slots=args.slots, max_len=args.max_len)
    stats = eng.run(reqs)
    assert all(r.done for r in reqs)
    print("RESULT " + json.dumps(stats))
    return stats


if __name__ == "__main__":
    main()
