"""Training driver: data pipeline → jitted step → checkpoint/restart.

Fault-tolerance contract (tested in tests/test_checkpoint.py):
  * checkpoints are atomic and include the data-iterator state;
  * ``--resume`` continues bit-exact from the latest checkpoint;
  * ``--inject-failure-at N`` hard-kills the process mid-run (os._exit) to
    simulate a node failure — a subsequent ``--resume`` run must finish;
  * the step watchdog flags stragglers/hangs (policy hook logs here; a
    real cluster controller would checkpoint-and-reschedule).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smoke --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt --resume
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import LM_ARCHS, get_config, get_smoke_config
from repro.data.pipeline import DataConfig, IteratorState, TokenPipeline
from repro.models.config import ModelConfig
from repro.train import checkpoint as ckpt
from repro.train.loop import TrainState, init_train_state, make_train_step
from repro.train.optimizer import OptimizerConfig
from repro.train.watchdog import StepWatchdog


def default_smoke_model() -> ModelConfig:
    return ModelConfig(name="smoke", n_layers=2, d_model=128, n_heads=4,
                       n_kv_heads=2, d_ff=256, vocab_size=512,
                       attn_q_block=64, attn_kv_block=64, loss_seq_chunk=64,
                       param_dtype="float32", compute_dtype="float32",
                       remat="none")


def build_model_config(args) -> ModelConfig:
    if args.arch == "smoke":
        return default_smoke_model()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    return cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smoke",
                    help=f"'smoke' or one of {LM_ARCHS}")
    ap.add_argument("--smoke", action="store_true",
                    help="use the arch's reduced smoke config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    ap.add_argument("--no-dedup", action="store_true")
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    cfg = build_model_config(args)
    opt_cfg = OptimizerConfig(lr=args.lr, warmup_steps=max(2, args.steps
                                                           // 10),
                              total_steps=args.steps,
                              accum_dtype="float32")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed,
                      dedup=not args.no_dedup)

    start_step = 0
    extra = {}
    state = None
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) \
            is not None:
        target = jax.eval_shape(
            lambda: init_train_state(jax.random.PRNGKey(args.seed), cfg))
        state, extra = ckpt.restore_checkpoint(args.ckpt_dir, target)
        start_step = int(extra.get("step", 0))
        print(f"resumed from step {start_step}")
    if state is None:
        state = init_train_state(jax.random.PRNGKey(args.seed), cfg)

    it_state = IteratorState.from_dict(extra["iterator"]) \
        if "iterator" in extra else None
    pipe = TokenPipeline(dcfg, state=it_state)
    batches = pipe.batches()

    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      n_microbatches=args.microbatches),
                      donate_argnums=(0,))
    wd = StepWatchdog(on_straggler=lambda info: print(
        f"[watchdog] {json.dumps(info)}"))

    losses = []
    for step in range(start_step, args.steps):
        raw = next(batches)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        wd.step_start()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        wd.step_end()
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"dedup_dropped {pipe.dedup_stats['dropped']}")
        if args.inject_failure_at == step:
            # die BEFORE this step's checkpoint — restart loses it
            print(f"[failure-injection] dying at step {step}", flush=True)
            os._exit(42)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save_checkpoint(
                args.ckpt_dir, step + 1, state,
                extra={"step": step + 1,
                       "iterator": pipe.state.to_dict()})

    if args.ckpt_dir:
        ckpt.save_checkpoint(args.ckpt_dir, args.steps, state,
                             extra={"step": args.steps,
                                    "iterator": pipe.state.to_dict()})
    result = {"final_loss": losses[-1] if losses else None,
              "first_loss": losses[0] if losses else None,
              "steps_run": len(losses),
              "dedup": pipe.dedup_stats,
              "straggler_events": len(wd.events)}
    print("RESULT " + json.dumps(result))
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(result, f)
    return result


if __name__ == "__main__":
    main()
