"""Batched similarity-serving over a streaming LSH index pool.

The detection-side sibling of ``launch/serve.py``: a ``ServeEngine``-shaped
slot/refill loop where requests are *query windows* of raw waveform
("when did something like this happen?") answered against the per-station
``StreamingIndex`` pool built by continuous ingestion. Each request's
window is split into fingerprint blocks; every tick runs **one** jitted
batched step that fingerprints each active slot once and queries it
against *every* station's index (read-only — serving never mutates the
pool), so concurrent requests share device dispatches exactly like decode
slots share a decode step, and S stations cost one vmapped dispatch
rather than S sequential queries (the ISSUE-3 index pool closing the
ROADMAP "serving shares one station's index" gap). Matches come back as
(station, corpus fingerprint id, collision count) triples.

Restartable service flags:

  ``--stations N``        stations ingested and served (the pool's S axis).
  ``--snapshot-every N``  checkpoint the ingesting detector (index pool,
                          waveform rings, MAD reservoirs) every N chunks
                          via ``train/checkpoint.py`` into
                          ``--snapshot-dir``.
  ``--restore``           instead of re-streaming the corpus from scratch,
                          restore the latest snapshot from
                          ``--snapshot-dir`` and ingest only the samples
                          that arrived after it — a killed service resumes
                          where it left off and serves the same pool.
  ``--window-fp N``       sliding detection window: the jitted step expires
                          index entries more than N fingerprints behind the
                          newest id, bounding what queries can match.
  ``--filter-window-fp N``  rolling occurrence-filter window: candidate
                          pairs are retired per closed window, bounding
                          host pair state for unbounded ingestion.
  ``--occ-limit N``       in-dispatch §6.5 occurrence limiter: cap raw
                          partner collisions per fingerprint inside the
                          traced ingest step (suppresses additive glitch
                          trains; the host rolling filter remains the
                          exact reference). Sizes its ring to the
                          sliding window (or the corpus when unwindowed).

Live health surface (ISSUE 6 — the telemetry subsystem's serving tier):

  ``--metrics-every N``   every N ingested chunks, print a ``HEARTBEAT``
                          JSON line (uptime, real-time factor, per-station
                          fingerprint throughput, per-guard drop rates,
                          data-quality counters, straggler steps) built
                          from the detector's :class:`StreamTelemetry`.
  ``--metrics-file P``    at the same cadence (and once after ingest),
                          atomically rewrite ``P`` with the Prometheus
                          text exposition of the metrics registry — point
                          a scraper or ``watch cat`` at it.
  ``--trace-jsonl P``     span tracing: append structured JSONL spans of
                          the ingest path (ingest → fused_step →
                          host_tail, nested) to ``P``.
  ``--dirty``             ingest the fault-injected scenario stream (gaps
                          + duplicated blocks + a repeating glitch train)
                          through the quality-hardened config instead of
                          the clean synth trace — the demo where drop
                          rates and quality counters are non-zero.

Usage:
  PYTHONPATH=src python -m repro.launch.serve_detect --requests 12
  PYTHONPATH=src python -m repro.launch.serve_detect \
      --snapshot-every 4 --snapshot-dir /tmp/fast_snap     # then kill …
  PYTHONPATH=src python -m repro.launch.serve_detect \
      --restore --snapshot-dir /tmp/fast_snap              # … and resume
  PYTHONPATH=src python -m repro.launch.serve_detect \
      --dirty --metrics-every 4 --metrics-file /tmp/fast.prom
"""
from __future__ import annotations

import argparse
import functools
import json
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.fast_seismic import smoke_config, stream_smoke_config
from repro.core import fingerprint as fp_mod
from repro.core import lsh as lsh_mod
from repro.core.detect import DetectConfig
from repro.core.fingerprint import FingerprintConfig
from repro.core.lsh import INVALID, LSHConfig
from repro.core.synth import SynthConfig, make_dataset
from repro.stream import index as index_mod
from repro.stream.engine import StreamingDetector, ingest_chunks
from repro.stream.index import IndexState
from repro.stream.ingest import StreamConfig


@dataclass
class QueryRequest:
    rid: int
    window: np.ndarray            # raw waveform samples
    matches: list = field(default_factory=list)  # (station, fp_id, sim)
    ticks: int = 0
    done: bool = False
    t_submit: float = 0.0
    t_done: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit


@functools.partial(jax.jit, static_argnames=("fcfg", "lcfg", "top_k"))
def _serve_step(state: IndexState, blocks: jax.Array, med: jax.Array,
                mad: jax.Array, mappings: jax.Array, slot_valid: jax.Array,
                fcfg: FingerprintConfig, lcfg: LSHConfig, top_k: int = 32):
    """(n_slots, block_samples) slot blocks × (S,)-pooled index state →
    per-(station, slot) (ids, sims) match tables, each (S, n_slots, top_k).

    The raw-coefficient half of the fingerprint chain runs once per slot
    and is shared across stations; only binarization (per-station §5.2
    statistics), signatures, and the index gather run under the station
    vmap. Query fingerprints get ids above any corpus id, so the index's
    id-ordered emission returns every stored partner; invalid slots get
    filler signatures and match nothing.
    """
    coeffs = jax.vmap(lambda b: fp_mod.coeffs_from_waveform(b, fcfg))(blocks)

    def per_station(st_state, st_med, st_mad):
        def one_slot(c, valid):
            bits, _ = fp_mod.binarize_coeffs(c, fcfg, (st_med, st_mad))
            n = bits.shape[0]
            sigs = lsh_mod.signatures(bits, mappings, lcfg, valid=valid)
            # distinct ids above every corpus id → each window fingerprint
            # pairs with all of its stored partners
            qids = jnp.int32(INVALID - 1 - n) + jnp.arange(n, dtype=jnp.int32)
            pairs = index_mod.query(st_state, sigs, qids, lcfg)
            sims = jnp.where(pairs.valid, pairs.sim, 0)
            top = jax.lax.top_k(sims, k=min(top_k, sims.shape[0]))[1]
            return pairs.idx1[top], sims[top]

        return jax.vmap(one_slot)(coeffs, slot_valid)

    return jax.vmap(per_station)(state, med, mad)


class ServeDetectEngine:
    """Static-slot continuous serving against a shared streaming index
    pool: ``state``/``med``/``mad`` carry a leading station axis
    (``StreamingDetector.pool_serving_state``)."""

    def __init__(self, cfg: DetectConfig, scfg: StreamConfig,
                 state: IndexState, med_mad, n_slots: int = 4,
                 top_k: int = 32):
        self.cfg = cfg
        self.scfg = scfg
        self.state = state
        self.med = jnp.asarray(med_mad[0])
        self.mad = jnp.asarray(med_mad[1])
        assert self.med.ndim == 2 and state.sig.ndim == 4, \
            "serving state must be pooled (leading station axis)"
        self.n_stations = self.med.shape[0]
        self.mappings = lsh_mod.hash_mappings(cfg.fingerprint.fp_dim,
                                              cfg.lsh)
        self.n_slots = n_slots
        self.top_k = top_k
        self.block_samples = cfg.fingerprint.block_samples(
            scfg.block_fingerprints)
        self.slot_req: list[QueryRequest | None] = [None] * n_slots
        self.slot_blocks: list[list[np.ndarray]] = [[] for _ in
                                                    range(n_slots)]
        self.ticks = 0

    def _split_blocks(self, window: np.ndarray
                      ) -> list[tuple[np.ndarray, np.ndarray]]:
        """Fixed-size (block, fp_valid_mask) covering the window.

        Tails are zero-padded; the mask marks fingerprints whose analysis
        window lies fully inside real samples, so padding never queries.
        """
        fcfg = self.cfg.fingerprint
        n_fp = self.scfg.block_fingerprints
        bs, adv = self.block_samples, n_fp * fcfg.lag_samples
        blocks, start = [], 0
        while start == 0 or start + fcfg.window_samples <= window.size:
            blk = np.zeros(bs, np.float32)
            seg = window[start: start + bs]
            blk[: seg.size] = seg
            avail = window.size - start
            n_valid = max(0, min(
                n_fp, (avail - fcfg.window_samples) // fcfg.lag_samples + 1))
            blocks.append((blk, np.arange(n_fp) < n_valid))
            start += adv
        return blocks

    def run(self, requests: list[QueryRequest]) -> dict:
        queue = list(requests)
        for r in queue:
            r.t_submit = time.perf_counter()
        active = lambda: any(r is not None for r in self.slot_req)
        t0 = time.perf_counter()
        while queue or active():
            for slot in range(self.n_slots):      # refill empty slots
                if self.slot_req[slot] is None and queue:
                    req = queue.pop(0)
                    self.slot_req[slot] = req
                    self.slot_blocks[slot] = self._split_blocks(req.window)
            n_fp = self.scfg.block_fingerprints
            batch = np.stack([
                self.slot_blocks[s][0][0] if self.slot_req[s] is not None
                else np.zeros(self.block_samples, np.float32)
                for s in range(self.n_slots)])
            slot_valid = jnp.asarray(np.stack([
                self.slot_blocks[s][0][1] if self.slot_req[s] is not None
                else np.zeros(n_fp, bool)
                for s in range(self.n_slots)]))
            ids, sims = _serve_step(
                self.state, jnp.asarray(batch), self.med, self.mad,
                self.mappings, slot_valid, self.cfg.fingerprint,
                self.cfg.lsh, self.top_k)
            self.ticks += 1
            ids_h, sims_h = np.asarray(ids), np.asarray(sims)  # (S, slots, k)
            for slot in range(self.n_slots):
                req = self.slot_req[slot]
                if req is None:
                    continue
                for station in range(self.n_stations):
                    keep = sims_h[station, slot] > 0
                    req.matches.extend(
                        (station, int(i), int(s))
                        for i, s in zip(ids_h[station, slot][keep],
                                        sims_h[station, slot][keep]))
                req.ticks += 1
                self.slot_blocks[slot].pop(0)
                if not self.slot_blocks[slot]:
                    req.done = True
                    req.t_done = time.perf_counter()
                    self.slot_req[slot] = None
        wall = time.perf_counter() - t0
        lats = [r.latency_s for r in requests]
        return {
            "requests": len(requests),
            "stations": self.n_stations,
            "ticks": self.ticks,
            "wall_s": round(wall, 3),
            "requests_per_s": round(len(requests) / max(wall, 1e-9), 1),
            "latency_ms_p50": round(float(np.percentile(lats, 50)) * 1e3, 1),
            "latency_ms_p95": round(float(np.percentile(lats, 95)) * 1e3, 1),
            "hit_requests": sum(1 for r in requests if r.matches),
        }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--stations", type=int, default=2,
                    help="stations ingested + served (index pool S axis)")
    ap.add_argument("--duration-s", type=float, default=600.0)
    ap.add_argument("--window-s", type=float, default=20.0)
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="checkpoint the ingesting detector every N chunks")
    ap.add_argument("--snapshot-dir", default="/tmp/fast_serve_snapshots")
    ap.add_argument("--restore", action="store_true",
                    help="resume ingestion from the latest snapshot")
    ap.add_argument("--window-fp", type=int, default=0,
                    help="sliding detection window (fingerprints; 0 = off)")
    ap.add_argument("--filter-window-fp", type=int, default=0,
                    help="rolling occurrence-filter window (0 = finalize)")
    ap.add_argument("--occ-limit", type=int, default=0,
                    help="in-dispatch §6.5 partner-collision cap (0 = off)")
    ap.add_argument("--metrics-every", type=int, default=0,
                    help="heartbeat + exposition cadence in chunks (0=off)")
    ap.add_argument("--metrics-file", default=None,
                    help="Prometheus text exposition path (atomic rewrite)")
    ap.add_argument("--trace-jsonl", default=None,
                    help="append structured span records (JSONL) here")
    ap.add_argument("--dirty", action="store_true",
                    help="ingest the fault-injected scenario stream "
                         "through the quality-hardened config")
    args = ap.parse_args(argv)

    cfg = smoke_config()
    if args.dirty:
        from repro.configs.fast_seismic import stream_dirty_smoke_config
        scfg = stream_dirty_smoke_config()
    else:
        scfg = stream_smoke_config()
    if args.window_fp or args.filter_window_fp or args.occ_limit:
        import dataclasses
        icfg = scfg.index
        if args.occ_limit:
            # ring spans everything a pair can reach back over: the
            # sliding window when set, else the whole ingested corpus
            n_fp = int(args.duration_s * cfg.fingerprint.fs
                       / cfg.fingerprint.lag_samples) + 1
            icfg = dataclasses.replace(
                icfg, occ_slots=args.window_fp or n_fp)
        scfg = dataclasses.replace(
            scfg, window_fingerprints=args.window_fp,
            filter_window_fingerprints=args.filter_window_fp,
            occ_limit=args.occ_limit, index=icfg)
    base = SynthConfig(duration_s=args.duration_s,
                       n_stations=args.stations,
                       n_sources=2, events_per_source=5,
                       event_snr=3.0, seed=3)
    if args.dirty:
        # the pinned pathology mix of the scenario benchmark: telemetry
        # gaps, a duplicated block, one long repeating glitch train
        from repro.core.synth import ScenarioConfig, make_scenario_dataset
        scen = make_scenario_dataset(ScenarioConfig(
            base=base, n_gaps=2, gap_dur_s=(2.0, 5.0),
            n_dup_blocks=1, dup_block_dur_s=20.0, dup_spacing_s=60.0,
            glitch_stations=(0,), glitch_trains=1,
            glitch_train_dur_s=args.duration_s / 4.0, seed=1))
        ds, ingest_wf = scen.clean, scen.waveforms
    else:
        ds = make_dataset(base)
        ingest_wf = ds.waveforms

    # build the corpus index pool by streaming the stations in (resuming
    # from the latest snapshot when asked — only post-snapshot samples
    # re-ingest); the ingest loop is shared with the benchmarks
    skip = 0
    if args.restore:
        det, step = StreamingDetector.restore(args.snapshot_dir, cfg, scfg)
        skip = det.stations[0].ring.samples_in
        print(f"# restored step {step}: {skip} samples already ingested")
    else:
        det = StreamingDetector(cfg, scfg, n_stations=args.stations)
    if args.trace_jsonl:
        from repro.obsv.spans import SpanTracer
        det.telemetry.tracer = SpanTracer(jsonl_path=args.trace_jsonl)
    ingest_chunks(det, ingest_wf, n_chunks=16, skip=skip,
                  snapshot_every=args.snapshot_every,
                  snapshot_dir=args.snapshot_dir,
                  metrics_every=args.metrics_every,
                  metrics_file=args.metrics_file)
    det.flush()
    assert all(st.stats_frozen for st in det.stations), \
        "ingest too short to freeze MAD statistics"
    # data-quality reconciliation + guard counters (gaps spliced/dropped,
    # duplicates suppressed, saturated buckets hit) — the operational view
    # of how dirty the ingested telemetry was
    quality = det.quality_summary()
    print("# ingest quality " + json.dumps(quality))
    if args.metrics_every:
        # final post-flush heartbeat + a last exposition rewrite so the
        # scrape file reflects the completed ingest
        print(det.telemetry.heartbeat_line(det))
        if args.metrics_file:
            det.telemetry.write_prometheus(args.metrics_file, det)
    det.telemetry.tracer.flush()
    state, med, mad = det.pool_serving_state()

    # query windows centered on known event arrivals (+ random controls)
    wf = ds.waveforms[0]
    rng = np.random.default_rng(0)
    win = int(args.window_s * cfg.fingerprint.fs)
    reqs = []
    for i in range(args.requests):
        if i < len(ds.event_times):
            t0 = int(ds.arrival_time(i, 0) * cfg.fingerprint.fs)
        else:
            t0 = int(rng.integers(0, wf.size - win))
        lo = max(0, min(t0, wf.size - win))
        reqs.append(QueryRequest(rid=i, window=wf[lo: lo + win]))

    eng = ServeDetectEngine(cfg, scfg, state, (med, mad),
                            n_slots=args.slots)
    stats = eng.run(reqs)
    assert all(r.done for r in reqs)
    stats["ingest_quality"] = quality
    if args.metrics_every:
        stats["metrics"] = det.metrics_snapshot()
    print("RESULT " + json.dumps(stats))
    return stats


if __name__ == "__main__":
    main()
