"""Batched similarity-serving over a shared streaming LSH index.

The detection-side sibling of ``launch/serve.py``: a ``ServeEngine``-shaped
slot/refill loop where requests are *query windows* of raw waveform
("when did something like this happen?") answered against a shared
``StreamingIndex`` built by continuous ingestion. Each request's window is
split into fingerprint blocks; every tick runs one jitted batched step
that fingerprints + queries one block per active slot (read-only — serving
never mutates the index), so concurrent requests share device dispatches
exactly like decode slots share a decode step.

Restartable service flags:

  ``--snapshot-every N``  checkpoint the ingesting detector (index pytree,
                          waveform ring, MAD reservoir) every N chunks via
                          ``train/checkpoint.py`` into ``--snapshot-dir``.
  ``--restore``           instead of re-streaming the corpus from scratch,
                          restore the latest snapshot from
                          ``--snapshot-dir`` and ingest only the samples
                          that arrived after it — a killed service resumes
                          where it left off and serves the same index.
  ``--window-fp N``       sliding detection window: the jitted step expires
                          index entries more than N fingerprints behind the
                          newest id, bounding what queries can match.
  ``--filter-window-fp N``  rolling occurrence-filter window: candidate
                          pairs are retired per closed window, bounding
                          host pair state for unbounded ingestion.

Usage:
  PYTHONPATH=src python -m repro.launch.serve_detect --requests 12
  PYTHONPATH=src python -m repro.launch.serve_detect \
      --snapshot-every 4 --snapshot-dir /tmp/fast_snap     # then kill …
  PYTHONPATH=src python -m repro.launch.serve_detect \
      --restore --snapshot-dir /tmp/fast_snap              # … and resume
"""
from __future__ import annotations

import argparse
import functools
import json
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.fast_seismic import smoke_config, stream_smoke_config
from repro.core import fingerprint as fp_mod
from repro.core import lsh as lsh_mod
from repro.core.detect import DetectConfig
from repro.core.fingerprint import FingerprintConfig
from repro.core.lsh import INVALID, LSHConfig
from repro.core.synth import SynthConfig, make_dataset
from repro.stream import index as index_mod
from repro.stream.engine import StreamingDetector, block_coeffs
from repro.stream.index import IndexState
from repro.stream.ingest import StreamConfig


@dataclass
class QueryRequest:
    rid: int
    window: np.ndarray            # raw waveform samples
    matches: list = field(default_factory=list)  # (corpus_fp_id, sim)
    ticks: int = 0
    done: bool = False
    t_submit: float = 0.0
    t_done: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit


@functools.partial(jax.jit, static_argnames=("fcfg", "lcfg", "top_k"))
def _serve_step(state: IndexState, blocks: jax.Array, med: jax.Array,
                mad: jax.Array, mappings: jax.Array, slot_valid: jax.Array,
                fcfg: FingerprintConfig, lcfg: LSHConfig, top_k: int = 32):
    """(S, block_samples) slot blocks → per-slot (ids, sims) match tables.

    Query fingerprints get ids beyond any corpus id, so the index's
    id-ordered emission returns every stored partner; invalid slots get
    filler signatures and match nothing. Each slot returns at most
    ``top_k`` matches per tick (highest collision counts first).
    """
    def one_slot(block, valid):
        coeffs = fp_mod.coeffs_from_waveform(block, fcfg)
        bits, _ = fp_mod.binarize_coeffs(coeffs, fcfg, (med, mad))
        n = bits.shape[0]
        sigs = lsh_mod.signatures(bits, mappings, lcfg, valid=valid)
        # distinct ids above every corpus id → each window fingerprint
        # pairs with all of its stored partners
        qids = jnp.int32(INVALID - 1 - n) + jnp.arange(n, dtype=jnp.int32)
        pairs = index_mod.query(state, sigs, qids, lcfg)
        # partner ids + collision counts, densified to a fixed top-k
        sims = jnp.where(pairs.valid, pairs.sim, 0)
        top = jax.lax.top_k(sims, k=min(top_k, sims.shape[0]))[1]
        return pairs.idx1[top], sims[top]

    return jax.vmap(one_slot)(blocks, slot_valid)


class ServeDetectEngine:
    """Static-slot continuous serving against a shared streaming index."""

    def __init__(self, cfg: DetectConfig, scfg: StreamConfig,
                 state: IndexState, med_mad, n_slots: int = 4,
                 top_k: int = 32):
        self.cfg = cfg
        self.scfg = scfg
        self.state = state
        self.med = jnp.asarray(med_mad[0])
        self.mad = jnp.asarray(med_mad[1])
        self.mappings = lsh_mod.hash_mappings(cfg.fingerprint.fp_dim,
                                              cfg.lsh)
        self.n_slots = n_slots
        self.top_k = top_k
        self.block_samples = cfg.fingerprint.block_samples(
            scfg.block_fingerprints)
        self.slot_req: list[QueryRequest | None] = [None] * n_slots
        self.slot_blocks: list[list[np.ndarray]] = [[] for _ in
                                                    range(n_slots)]
        self.ticks = 0

    def _split_blocks(self, window: np.ndarray
                      ) -> list[tuple[np.ndarray, np.ndarray]]:
        """Fixed-size (block, fp_valid_mask) covering the window.

        Tails are zero-padded; the mask marks fingerprints whose analysis
        window lies fully inside real samples, so padding never queries.
        """
        fcfg = self.cfg.fingerprint
        n_fp = self.scfg.block_fingerprints
        bs, adv = self.block_samples, n_fp * fcfg.lag_samples
        blocks, start = [], 0
        while start == 0 or start + fcfg.window_samples <= window.size:
            blk = np.zeros(bs, np.float32)
            seg = window[start: start + bs]
            blk[: seg.size] = seg
            avail = window.size - start
            n_valid = max(0, min(
                n_fp, (avail - fcfg.window_samples) // fcfg.lag_samples + 1))
            blocks.append((blk, np.arange(n_fp) < n_valid))
            start += adv
        return blocks

    def run(self, requests: list[QueryRequest]) -> dict:
        queue = list(requests)
        for r in queue:
            r.t_submit = time.perf_counter()
        active = lambda: any(r is not None for r in self.slot_req)
        t0 = time.perf_counter()
        while queue or active():
            for slot in range(self.n_slots):      # refill empty slots
                if self.slot_req[slot] is None and queue:
                    req = queue.pop(0)
                    self.slot_req[slot] = req
                    self.slot_blocks[slot] = self._split_blocks(req.window)
            n_fp = self.scfg.block_fingerprints
            batch = np.stack([
                self.slot_blocks[s][0][0] if self.slot_req[s] is not None
                else np.zeros(self.block_samples, np.float32)
                for s in range(self.n_slots)])
            slot_valid = jnp.asarray(np.stack([
                self.slot_blocks[s][0][1] if self.slot_req[s] is not None
                else np.zeros(n_fp, bool)
                for s in range(self.n_slots)]))
            ids, sims = _serve_step(
                self.state, jnp.asarray(batch), self.med, self.mad,
                self.mappings, slot_valid, self.cfg.fingerprint,
                self.cfg.lsh, self.top_k)
            self.ticks += 1
            ids_h, sims_h = np.asarray(ids), np.asarray(sims)
            for slot in range(self.n_slots):
                req = self.slot_req[slot]
                if req is None:
                    continue
                keep = sims_h[slot] > 0
                req.matches.extend(zip(ids_h[slot][keep].tolist(),
                                       sims_h[slot][keep].tolist()))
                req.ticks += 1
                self.slot_blocks[slot].pop(0)
                if not self.slot_blocks[slot]:
                    req.done = True
                    req.t_done = time.perf_counter()
                    self.slot_req[slot] = None
        wall = time.perf_counter() - t0
        lats = [r.latency_s for r in requests]
        return {
            "requests": len(requests),
            "ticks": self.ticks,
            "wall_s": round(wall, 3),
            "requests_per_s": round(len(requests) / max(wall, 1e-9), 1),
            "latency_ms_p50": round(float(np.percentile(lats, 50)) * 1e3, 1),
            "latency_ms_p95": round(float(np.percentile(lats, 95)) * 1e3, 1),
            "hit_requests": sum(1 for r in requests if r.matches),
        }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--duration-s", type=float, default=600.0)
    ap.add_argument("--window-s", type=float, default=20.0)
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="checkpoint the ingesting detector every N chunks")
    ap.add_argument("--snapshot-dir", default="/tmp/fast_serve_snapshots")
    ap.add_argument("--restore", action="store_true",
                    help="resume ingestion from the latest snapshot")
    ap.add_argument("--window-fp", type=int, default=0,
                    help="sliding detection window (fingerprints; 0 = off)")
    ap.add_argument("--filter-window-fp", type=int, default=0,
                    help="rolling occurrence-filter window (0 = finalize)")
    args = ap.parse_args(argv)

    cfg, scfg = smoke_config(), stream_smoke_config()
    if args.window_fp or args.filter_window_fp:
        import dataclasses
        scfg = dataclasses.replace(
            scfg, window_fingerprints=args.window_fp,
            filter_window_fingerprints=args.filter_window_fp)
    ds = make_dataset(SynthConfig(duration_s=args.duration_s, n_stations=1,
                                  n_sources=2, events_per_source=5,
                                  event_snr=3.0, seed=3))
    wf = ds.waveforms[0]

    # build the corpus index by streaming the station in (resuming from the
    # latest snapshot when asked — only post-snapshot samples re-ingest)
    skip = 0
    if args.restore:
        det, step = StreamingDetector.restore(args.snapshot_dir, cfg, scfg)
        skip = det.stations[0].ring.samples_in
        print(f"# restored step {step}: {skip} samples already ingested")
    else:
        det = StreamingDetector(cfg, scfg, n_stations=1)
    chunks = np.array_split(wf, 16)
    seen = 0
    for ci, chunk in enumerate(chunks):
        seen += chunk.size
        if seen <= skip:
            continue
        det.push(chunk if seen - chunk.size >= skip
                 else chunk[chunk.size - (seen - skip):])
        if args.snapshot_every and (ci + 1) % args.snapshot_every == 0:
            det.snapshot(args.snapshot_dir, step=ci + 1)
    st = det.stations[0]
    st.flush()
    assert st.stats_frozen, "ingest too short to freeze MAD statistics"
    med_mad = (np.asarray(st.med_mad[0]), np.asarray(st.med_mad[1]))

    # query windows centered on known event arrivals (+ random controls)
    rng = np.random.default_rng(0)
    win = int(args.window_s * cfg.fingerprint.fs)
    reqs = []
    for i in range(args.requests):
        if i < len(ds.event_times):
            t0 = int(ds.arrival_time(i, 0) * cfg.fingerprint.fs)
        else:
            t0 = int(rng.integers(0, wf.size - win))
        lo = max(0, min(t0, wf.size - win))
        reqs.append(QueryRequest(rid=i, window=wf[lo: lo + win]))

    eng = ServeDetectEngine(cfg, scfg, st.state, med_mad,
                            n_slots=args.slots)
    stats = eng.run(reqs)
    assert all(r.done for r in reqs)
    print("RESULT " + json.dumps(stats))
    return stats


if __name__ == "__main__":
    main()
