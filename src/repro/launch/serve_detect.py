"""Concurrent, backpressured query serving over a streaming LSH index pool.

The detection-side sibling of ``launch/serve.py``, grown into a service
tier (ISSUE 7): requests are *query windows* of raw waveform ("when did
something like this happen?") answered against the per-station
``StreamingIndex`` pool built by continuous ingestion. The tier has three
layers:

* **admission queue** (``ServeDetectEngine.submit``): a bounded FIFO in
  front of the slots. Depth past ``max_queue`` load-sheds the request —
  it completes immediately with ``outcome="rejected"`` instead of growing
  host state without bound under overload. Every request carries
  arrival-time accounting: queue wait (submit → slot admission) and
  service time (admission → completion) are split so the latency
  histograms say *where* time went.
* **batched ticks** (``ServeDetectEngine.tick``): each tick admits queued
  requests into free slots and runs **one** jitted ``_serve_step``
  dispatch that fingerprints every active slot once and queries it
  against *every* station's index (read-only — serving never mutates the
  pool). Concurrent requests share device dispatches exactly like decode
  slots share a decode step; S stations cost one vmapped dispatch, not S.
  Idle ticks (no active slots) return without assembling a batch or
  dispatching at all.
* **interleaved ingestion** (``ServeSession``): the cooperative
  single-process service loop — ingest chunks keep growing the corpus
  while query ticks run between them, against a read-only
  ``pool_serving_state()`` snapshot refreshed at a configurable cadence
  (``refresh_every_chunks``; version-gated, so an unchanged detector
  costs nothing). The shape is qseek's asyncio search loop without the
  event loop: two duties, one thread, explicit yield points.

Telemetry publishes through the PR-6 substrate, never ad-hoc counters:
``serve_requests_total{outcome=accepted|shed|served}``, per-tick
``serve_queue_depth``/``serve_active_slots`` gauges,
``serve_{latency,queue_wait,service}_seconds`` histograms and
``serve_state_refreshes_total`` all land in the detector's
``repro.obsv`` registry, so the heartbeat, the Prometheus exposition and
``metrics_snapshot()["serve"]`` carry the serving tier for free.

Restartable service flags:

  ``--stations N``        stations ingested and served (the pool's S
                          axis). With ``--restore`` it must match the
                          snapshot's pool width — a mismatched width is
                          rejected up front instead of silently serving
                          the wrong pool.
  ``--snapshot-every N``  checkpoint the ingesting detector every N
                          chunks via ``train/checkpoint.py`` into
                          ``--snapshot-dir``.
  ``--restore``           resume ingestion from the latest snapshot in
                          ``--snapshot-dir`` (only post-snapshot samples
                          re-ingest).
  ``--window-fp N``       sliding detection window (index expiry).
  ``--filter-window-fp N``  rolling occurrence-filter window.
  ``--occ-limit N``       in-dispatch §6.5 partner-collision cap.

Service-tier flags (ISSUE 7):

  ``--slots N``           concurrent request slots per batched dispatch.
  ``--max-queue N``       admission-queue bound; requests beyond it shed
                          with ``outcome="rejected"``.
  ``--interleave``        serve queries *while* ingesting (requests
                          arrive spread over the stream) instead of the
                          two-phase ingest-then-serve default.
  ``--refresh-every N``   chunks between serving-state refreshes in
                          interleaved mode.

Live health surface (ISSUE 6):

  ``--metrics-every N``   every N ingested chunks, print a ``HEARTBEAT``
                          JSON line built from ``StreamTelemetry``.
  ``--metrics-file P``    atomically rewrite ``P`` with the Prometheus
                          text exposition — at the heartbeat cadence when
                          ``--metrics-every`` is set, and always once
                          after ingest (a bare ``--metrics-file`` does a
                          final write instead of silently nothing).
  ``--trace-jsonl P``     append structured JSONL spans of the ingest
                          path to ``P``.
  ``--dirty``             ingest the fault-injected scenario stream
                          through the quality-hardened config.
  ``--locate``            located alert rows (ISSUE 9): the synthetic
                          network gets physical station geometry, the
                          ingesting detector runs the location /
                          magnitude tier, and every live alert prints as
                          an ``ALERT`` JSON line carrying origin (km),
                          relative magnitude and the upgrade flag, with
                          an aggregate ``located`` block in the RESULT.

Usage:
  PYTHONPATH=src python -m repro.launch.serve_detect --requests 12
  PYTHONPATH=src python -m repro.launch.serve_detect \
      --interleave --requests 16 --max-queue 8      # backpressured live
  PYTHONPATH=src python -m repro.launch.serve_detect \
      --snapshot-every 4 --snapshot-dir /tmp/fast_snap     # then kill …
  PYTHONPATH=src python -m repro.launch.serve_detect \
      --restore --snapshot-dir /tmp/fast_snap              # … and resume
"""
from __future__ import annotations

import argparse
import collections
import functools
import json
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.fast_seismic import smoke_config, stream_smoke_config
from repro.core import fingerprint as fp_mod
from repro.core import lsh as lsh_mod
from repro.core.detect import DetectConfig
from repro.core.fingerprint import FingerprintConfig
from repro.core.lsh import INVALID, LSHConfig
from repro.core.synth import SynthConfig, make_dataset
from repro.stream import index as index_mod
from repro.stream.engine import StreamingDetector, ingest_chunks
from repro.stream.index import IndexState
from repro.stream.ingest import StreamConfig
from repro.stream.telemetry import StreamTelemetry

# completed-request latency samples retained for exact percentiles; the
# registry histograms keep the full-lifetime (bucketed) view, so the
# engine's own memory stays O(1) on an unbounded request stream
LATENCY_WINDOW = 65536


@dataclass
class ServeConfig:
    """Serving-tier knobs (see ``configs.fast_seismic.serve_smoke_config``
    / ``serve_config`` for the smoke and paper-scale instantiations)."""
    n_slots: int = 4            # concurrent slots per batched dispatch
    max_queue: int = 64         # admission bound; beyond it requests shed
    top_k: int = 32             # matches returned per (station, block)
    refresh_every_chunks: int = 4   # interleaved serving-state cadence


@dataclass
class QueryRequest:
    rid: int
    window: np.ndarray            # raw waveform samples
    matches: list = field(default_factory=list)  # (station, fp_id, sim)
    ticks: int = 0
    done: bool = False
    outcome: str = "pending"      # pending | active | served | rejected
    t_submit: float = 0.0
    t_admit: float = 0.0          # dequeued into a slot
    t_done: float = 0.0

    @property
    def queue_wait_s(self) -> float:
        """Submit → slot admission (0.0 while still queued or shed)."""
        if self.t_admit <= 0.0:
            return 0.0
        return self.t_admit - self.t_submit

    @property
    def service_s(self) -> float:
        """Slot admission → completion (0.0 while in flight)."""
        if self.t_done <= 0.0 or self.t_admit <= 0.0:
            return 0.0
        return self.t_done - self.t_admit

    @property
    def latency_s(self) -> float:
        """Submit → completion; 0.0 for unfinished requests (an unset
        ``t_done`` used to yield a negative wall-clock delta)."""
        if self.t_done <= 0.0:
            return 0.0
        return self.t_done - self.t_submit


@functools.partial(jax.jit,
                   static_argnames=("fcfg", "lcfg", "top_k", "max_pairs"))
def _serve_step(state: IndexState, blocks: jax.Array, med: jax.Array,
                mad: jax.Array, mappings: jax.Array, slot_valid: jax.Array,
                fcfg: FingerprintConfig, lcfg: LSHConfig, top_k: int = 32,
                max_pairs: int = 0):
    """(n_slots, block_samples) slot blocks × (S,)-pooled index state →
    per-(station, slot) (ids, sims) match tables, each (S, n_slots, top_k).

    The raw-coefficient half of the fingerprint chain runs once per slot
    and is shared across stations; only binarization (per-station §5.2
    statistics), signatures, and the index gather run under the station
    vmap. Query fingerprints get ids above any corpus id, so the index's
    id-ordered emission returns every stored partner; invalid slots get
    filler signatures and match nothing.

    ``max_pairs`` > 0 (ISSUE 8) compacts each slot's emission in-dispatch
    before ranking: the ``top_k`` reduction then runs over ``max_pairs``
    candidate rows instead of the dense t * N * C slot tensor. Sized
    comfortably above the expected per-query match count (config default:
    several × top_k × n_tables) the match tables are identical — overflow
    past the bound drops lexicographically-largest candidates first.
    """
    coeffs = jax.vmap(lambda b: fp_mod.coeffs_from_waveform(b, fcfg))(blocks)

    def per_station(st_state, st_med, st_mad):
        def one_slot(c, valid):
            bits, _ = fp_mod.binarize_coeffs(c, fcfg, (st_med, st_mad))
            n = bits.shape[0]
            sigs = lsh_mod.signatures(bits, mappings, lcfg, valid=valid)
            # distinct ids above every corpus id → each window fingerprint
            # pairs with all of its stored partners
            qids = jnp.int32(INVALID - 1 - n) + jnp.arange(n, dtype=jnp.int32)
            pairs = index_mod.query(st_state, sigs, qids, lcfg,
                                    max_pairs=max_pairs)
            sims = jnp.where(pairs.valid, pairs.sim, 0)
            top = jax.lax.top_k(sims, k=min(top_k, sims.shape[0]))[1]
            return pairs.idx1[top], sims[top]

        return jax.vmap(one_slot)(coeffs, slot_valid)

    return jax.vmap(per_station)(state, med, mad)


class ServeDetectEngine:
    """Admission queue + static slots + one batched dispatch per tick.

    ``state``/``med``/``mad`` carry a leading station axis
    (``StreamingDetector.pool_serving_state``). The state may start
    ``None`` (interleaved serving before the detector's statistics
    freeze): requests queue, and ticks are idle until the first
    ``refresh``/``refresh_from`` installs a pool.
    """

    def __init__(self, cfg: DetectConfig, scfg: StreamConfig,
                 state: IndexState | None = None, med_mad=None,
                 n_slots: int = 4, top_k: int = 32, max_queue: int = 64,
                 telemetry: StreamTelemetry | None = None,
                 clock=time.perf_counter):
        self.cfg = cfg
        self.scfg = scfg
        self.telemetry = telemetry or StreamTelemetry(0)
        self.clock = clock
        self.state: IndexState | None = None
        self.med = self.mad = None
        self.n_stations = 0
        self.serving_version = -1   # detector version the pool mirrors
        self.mappings = lsh_mod.hash_mappings(cfg.fingerprint.fp_dim,
                                              cfg.lsh)
        self.n_slots = n_slots
        self.top_k = top_k
        # compacted slot queries (0 = dense): never below top_k, or the
        # (S, slots, top_k) match-table shape itself would shrink
        self.max_pairs = (0 if scfg.max_pairs_per_block == 0
                          else max(scfg.max_pairs_per_block, top_k))
        self.max_queue = max_queue
        self.block_samples = cfg.fingerprint.block_samples(
            scfg.block_fingerprints)
        # cached filler rows: idle slots never allocate per tick
        self._zero_block = np.zeros(self.block_samples, np.float32)
        self._zero_mask = np.zeros(scfg.block_fingerprints, bool)
        self.slot_req: list[QueryRequest | None] = [None] * n_slots
        self.slot_blocks: list[list] = [[] for _ in range(n_slots)]
        self.queue: collections.deque[QueryRequest] = collections.deque()
        self.ticks = 0
        self.dispatches = 0
        self.slot_ticks = 0         # Σ active slots over dispatches
        self.submitted = self.served = self.shed = 0
        self.lat = {k: collections.deque(maxlen=LATENCY_WINDOW)
                    for k in ("queue_wait_s", "service_s", "latency_s")}
        if state is not None:
            self._install(state, med_mad)

    @classmethod
    def from_detector(cls, det: StreamingDetector, **kw
                      ) -> "ServeDetectEngine":
        """Engine over a detector's current pool, sharing its telemetry
        registry (one health surface for ingest + serving)."""
        eng = cls(det.cfg, det.scfg, telemetry=det.telemetry, **kw)
        eng.refresh_from(det)
        return eng

    # -- serving state -------------------------------------------------------

    def _install(self, state: IndexState, med_mad) -> None:
        med = jnp.asarray(med_mad[0])
        assert med.ndim == 2 and state.sig.ndim == 4, \
            "serving state must be pooled (leading station axis)"
        if self.n_stations and med.shape[0] != self.n_stations:
            raise ValueError(
                f"refresh changed the pool width: serving {self.n_stations}"
                f" stations, refresh has {med.shape[0]}")
        self.state = state
        self.med = med
        self.mad = jnp.asarray(med_mad[1])
        self.n_stations = med.shape[0]

    def refresh(self, state: IndexState, med_mad, version: int = -1) -> None:
        """Install a new read-only pool snapshot (queries from the next
        tick on see the grown corpus)."""
        self._install(state, med_mad)
        self.serving_version = version
        self.telemetry.record_serve_refresh()

    def refresh_from(self, det: StreamingDetector) -> bool:
        """Version-gated refresh from an ingesting detector: a no-op
        until its statistics freeze, and when no chunk arrived since the
        pool snapshot this engine already serves."""
        if not all(st.stats_frozen for st in det.stations):
            return False
        if det.serving_version == self.serving_version:
            return False
        state, med, mad = det.pool_serving_state()
        self.refresh(state, (med, mad), version=det.serving_version)
        return True

    # -- admission -----------------------------------------------------------

    def submit(self, req: QueryRequest) -> bool:
        """Admission control: enqueue, or load-shed past ``max_queue``.

        A shed request completes immediately with ``outcome="rejected"``
        — bounded queue depth is the overload contract (the service
        answers *something* fast rather than queueing without bound).
        """
        now = self.clock()
        req.t_submit = now
        self.submitted += 1
        if len(self.queue) >= self.max_queue:
            req.done = True
            req.outcome = "rejected"
            req.t_done = now
            self.shed += 1
            self.telemetry.record_serve_admission(False)
            return False
        self.queue.append(req)
        self.telemetry.record_serve_admission(True)
        return True

    def active(self) -> bool:
        return any(r is not None for r in self.slot_req)

    def pending(self) -> int:
        """Requests not yet completed (queued + in slots)."""
        return len(self.queue) + sum(r is not None for r in self.slot_req)

    def _admit(self) -> None:
        for slot in range(self.n_slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.popleft()
                req.t_admit = self.clock()
                req.outcome = "active"
                self.slot_req[slot] = req
                self.slot_blocks[slot] = self._split_blocks(req.window)

    def _split_blocks(self, window: np.ndarray
                      ) -> list[tuple[np.ndarray, np.ndarray]]:
        """Fixed-size (block, fp_valid_mask) covering the window.

        Tails are zero-padded; the mask marks fingerprints whose analysis
        window lies fully inside real samples, so padding never queries.
        """
        fcfg = self.cfg.fingerprint
        n_fp = self.scfg.block_fingerprints
        bs, adv = self.block_samples, n_fp * fcfg.lag_samples
        blocks, start = [], 0
        while start == 0 or start + fcfg.window_samples <= window.size:
            blk = np.zeros(bs, np.float32)
            seg = window[start: start + bs]
            blk[: seg.size] = seg
            avail = window.size - start
            n_valid = max(0, min(
                n_fp, (avail - fcfg.window_samples) // fcfg.lag_samples + 1))
            blocks.append((blk, np.arange(n_fp) < n_valid))
            start += adv
        return blocks

    # -- the batched tick ----------------------------------------------------

    def tick(self) -> int:
        """One service tick: admit queued requests into free slots, run at
        most ONE batched ``_serve_step`` dispatch over every active slot,
        and complete requests whose last block was answered. Returns the
        number of slots served; an idle tick (nothing active) returns 0
        without assembling a batch or dispatching.
        """
        if self.state is not None:
            self._admit()
        active = [s for s in range(self.n_slots)
                  if self.slot_req[s] is not None]
        self.ticks += 1
        self.telemetry.record_serve_tick(len(active), len(self.queue))
        if not active:
            return 0
        batch = np.stack([
            self.slot_blocks[s][0][0] if self.slot_req[s] is not None
            else self._zero_block for s in range(self.n_slots)])
        slot_valid = jnp.asarray(np.stack([
            self.slot_blocks[s][0][1] if self.slot_req[s] is not None
            else self._zero_mask for s in range(self.n_slots)]))
        ids, sims = _serve_step(
            self.state, jnp.asarray(batch), self.med, self.mad,
            self.mappings, slot_valid, self.cfg.fingerprint,
            self.cfg.lsh, self.top_k, self.max_pairs)
        self.dispatches += 1
        self.slot_ticks += len(active)
        ids_h, sims_h = np.asarray(ids), np.asarray(sims)  # (S, slots, k)
        for slot in active:
            req = self.slot_req[slot]
            for station in range(self.n_stations):
                keep = sims_h[station, slot] > 0
                req.matches.extend(
                    (station, int(i), int(s))
                    for i, s in zip(ids_h[station, slot][keep],
                                    sims_h[station, slot][keep]))
            req.ticks += 1
            self.slot_blocks[slot].pop(0)
            if not self.slot_blocks[slot]:
                self._complete(slot)
        return len(active)

    def _complete(self, slot: int) -> None:
        req = self.slot_req[slot]
        req.done = True
        req.outcome = "served"
        req.t_done = self.clock()
        self.slot_req[slot] = None
        self.served += 1
        self.lat["queue_wait_s"].append(req.queue_wait_s)
        self.lat["service_s"].append(req.service_s)
        self.lat["latency_s"].append(req.latency_s)
        self.telemetry.record_serve_done(req.queue_wait_s, req.service_s,
                                         req.latency_s)

    def drain(self) -> None:
        """Tick until every admitted request completes."""
        assert self.state is not None or not self.pending(), \
            "cannot drain before a serving state is installed"
        while self.pending():
            self.tick()

    # -- summaries -----------------------------------------------------------

    def run(self, requests: list[QueryRequest]) -> dict:
        """Two-phase convenience path: submit everything at once (the
        all-requests-arrive-together burst), drain, summarize."""
        t0 = self.clock()
        for r in requests:
            self.submit(r)
        self.drain()
        return self.summary(requests, self.clock() - t0)

    def summary(self, requests: list[QueryRequest], wall_s: float) -> dict:
        served = [r for r in requests if r.outcome == "served"]

        def pct(vals, q):
            if not vals:        # empty request list / everything shed
                return 0.0
            return round(float(np.percentile(vals, q)) * 1e3, 2)

        lats = [r.latency_s for r in served]
        waits = [r.queue_wait_s for r in served]
        svc = [r.service_s for r in served]
        return {
            "requests": len(requests),
            "served": len(served),
            "shed": sum(1 for r in requests if r.outcome == "rejected"),
            "stations": self.n_stations,
            "ticks": self.ticks,
            "dispatches": self.dispatches,
            "wall_s": round(wall_s, 3),
            "requests_per_s": round(len(served) / max(wall_s, 1e-9), 1),
            "latency_ms_p50": pct(lats, 50),
            "latency_ms_p95": pct(lats, 95),
            "latency_ms_p99": pct(lats, 99),
            "queue_wait_ms_p50": pct(waits, 50),
            "queue_wait_ms_p99": pct(waits, 99),
            "service_ms_p50": pct(svc, 50),
            "service_ms_p99": pct(svc, 99),
            "hit_requests": sum(1 for r in served if r.matches),
        }


class ServeSession:
    """Cooperative ingest + serve loop (qseek's asyncio search-loop shape
    on one thread): chunks keep growing the corpus while query ticks run
    between them against a refreshed read-only pool snapshot.

    ``after_push()`` is the per-chunk duty cycle — refresh the engine's
    serving state at the configured cadence (version-gated; a no-op until
    the detector's statistics freeze) and pump up to ``ticks_per_chunk``
    query ticks. ``finish()`` flushes the detector, takes the final
    refresh, and drains the queue.
    """

    def __init__(self, det: StreamingDetector, engine: ServeDetectEngine,
                 refresh_every_chunks: int = 4, ticks_per_chunk: int = 2):
        self.det = det
        self.engine = engine
        self.refresh_every_chunks = max(1, refresh_every_chunks)
        self.ticks_per_chunk = ticks_per_chunk
        self.chunks = 0
        self.refreshes = 0

    def submit(self, req: QueryRequest) -> bool:
        return self.engine.submit(req)

    def ingest(self, chunk: np.ndarray, offset: int | None = None) -> None:
        self.det.push(chunk, offset)
        self.after_push()

    def after_push(self) -> None:
        self.chunks += 1
        if self.chunks % self.refresh_every_chunks == 0:
            self.refreshes += int(self.engine.refresh_from(self.det))
        self.pump(self.ticks_per_chunk)

    def pump(self, max_ticks: int) -> int:
        """Run up to ``max_ticks`` query ticks; stops early when nothing
        is pending or no serving state exists yet."""
        n = 0
        while (n < max_ticks and self.engine.state is not None
               and self.engine.pending()):
            self.engine.tick()
            n += 1
        return n

    def finish(self) -> None:
        self.det.flush()
        self.refreshes += int(self.engine.refresh_from(self.det))
        self.engine.drain()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-queue", type=int, default=64,
                    help="admission-queue bound (beyond it requests shed)")
    ap.add_argument("--interleave", action="store_true",
                    help="serve queries while ingesting (requests arrive "
                         "spread over the stream) instead of after it")
    ap.add_argument("--refresh-every", type=int, default=4,
                    help="chunks between serving-state refreshes "
                         "(interleaved mode)")
    ap.add_argument("--stations", type=int, default=2,
                    help="stations ingested + served (index pool S axis)")
    ap.add_argument("--duration-s", type=float, default=600.0)
    ap.add_argument("--window-s", type=float, default=20.0)
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="checkpoint the ingesting detector every N chunks")
    ap.add_argument("--snapshot-dir", default="/tmp/fast_serve_snapshots")
    ap.add_argument("--restore", action="store_true",
                    help="resume ingestion from the latest snapshot")
    ap.add_argument("--window-fp", type=int, default=0,
                    help="sliding detection window (fingerprints; 0 = off)")
    ap.add_argument("--filter-window-fp", type=int, default=0,
                    help="rolling occurrence-filter window (0 = finalize)")
    ap.add_argument("--occ-limit", type=int, default=0,
                    help="in-dispatch §6.5 partner-collision cap (0 = off)")
    ap.add_argument("--metrics-every", type=int, default=0,
                    help="heartbeat + exposition cadence in chunks (0=off)")
    ap.add_argument("--metrics-file", default=None,
                    help="Prometheus text exposition path (atomic rewrite)")
    ap.add_argument("--trace-jsonl", default=None,
                    help="append structured span records (JSONL) here")
    ap.add_argument("--dirty", action="store_true",
                    help="ingest the fault-injected scenario stream "
                         "through the quality-hardened config")
    ap.add_argument("--locate", action="store_true",
                    help="station geometry + location/magnitude tier: "
                         "alerts carry a migration-stacked origin and a "
                         "relative magnitude (defaults "
                         "--filter-window-fp 64 so alerts emit live)")
    args = ap.parse_args(argv)

    if args.locate:
        from repro.configs.fast_seismic import located_smoke_config
        cfg = located_smoke_config()
        # live alerts need the bounded regime: a sliding index window
        # plus the rolling occurrence filter (same shape as
        # stream_bounded_smoke_config)
        if not args.window_fp:
            args.window_fp = 128
        if not args.filter_window_fp:
            args.filter_window_fp = 64
    else:
        cfg = smoke_config()
    if args.dirty:
        from repro.configs.fast_seismic import stream_dirty_smoke_config
        scfg = stream_dirty_smoke_config()
    else:
        scfg = stream_smoke_config()
    if args.window_fp or args.filter_window_fp or args.occ_limit:
        import dataclasses
        icfg = scfg.index
        if args.occ_limit:
            # ring spans everything a pair can reach back over: the
            # sliding window when set, else the whole ingested corpus
            n_fp = int(args.duration_s * cfg.fingerprint.fs
                       / cfg.fingerprint.lag_samples) + 1
            icfg = dataclasses.replace(
                icfg, occ_slots=args.window_fp or n_fp)
        scfg = dataclasses.replace(
            scfg, window_fingerprints=args.window_fp,
            filter_window_fingerprints=args.filter_window_fp,
            occ_limit=args.occ_limit, index=icfg)
    base = SynthConfig(duration_s=args.duration_s,
                       n_stations=args.stations,
                       n_sources=2, events_per_source=5,
                       event_snr=3.0, seed=3,
                       physical_geometry=args.locate)
    if args.dirty:
        # the pinned pathology mix of the scenario benchmark: telemetry
        # gaps, a duplicated block, one long repeating glitch train
        from repro.core.synth import ScenarioConfig, make_scenario_dataset
        scen = make_scenario_dataset(ScenarioConfig(
            base=base, n_gaps=2, gap_dur_s=(2.0, 5.0),
            n_dup_blocks=1, dup_block_dur_s=20.0, dup_spacing_s=60.0,
            glitch_stations=(0,), glitch_trains=1,
            glitch_train_dur_s=args.duration_s / 4.0, seed=1))
        ds, ingest_wf = scen.clean, scen.waveforms
    else:
        ds = make_dataset(base)
        ingest_wf = ds.waveforms

    # build the corpus index pool by streaming the stations in (resuming
    # from the latest snapshot when asked — only post-snapshot samples
    # re-ingest); the ingest loop is shared with the benchmarks
    station_xy = ds.station_xy if args.locate else None
    skip = 0
    if args.restore:
        det, step = StreamingDetector.restore(args.snapshot_dir, cfg, scfg,
                                              station_xy=station_xy)
        if args.stations > len(det.stations) and det.pooled \
                and all(st.stats_frozen for st in det.stations):
            # width growth is no longer a conflict: the pool is elastic
            # (ISSUE 10) — pad the restored snapshot with fresh stations
            # joining at the frontier, re-sharded over the current mesh
            grown = args.stations - len(det.stations)
            for _ in range(grown):
                det.add_station()
            print(f"# restored pool grown {len(det.stations) - grown}"
                  f" -> {len(det.stations)} stations (elastic re-shard)")
        elif len(det.stations) != args.stations:
            raise SystemExit(
                f"--restore: the snapshot holds a {len(det.stations)}-"
                f"station index pool but --stations {args.stations} was "
                f"requested; shrinking would discard station identities "
                f"irrecoverably — rerun with --stations "
                f"{len(det.stations)} (or take a fresh snapshot at the "
                f"new width)")
        skip = det.stations[0].ring.samples_in
        print(f"# restored step {step}: {skip} samples already ingested")
    else:
        det = StreamingDetector(cfg, scfg, n_stations=args.stations,
                                station_xy=station_xy)
    if args.trace_jsonl:
        from repro.obsv.spans import SpanTracer
        det.telemetry.tracer = SpanTracer(jsonl_path=args.trace_jsonl)

    # query windows centered on known event arrivals (+ random controls)
    wf = ds.waveforms[0]
    rng = np.random.default_rng(0)
    win = int(args.window_s * cfg.fingerprint.fs)
    reqs = []
    for i in range(args.requests):
        if i < len(ds.event_times):
            t0 = int(ds.arrival_time(i, 0) * cfg.fingerprint.fs)
        else:
            t0 = int(rng.integers(0, wf.size - win))
        lo = max(0, min(t0, wf.size - win))
        reqs.append(QueryRequest(rid=i, window=wf[lo: lo + win]))

    eng = ServeDetectEngine(cfg, scfg, n_slots=args.slots,
                            max_queue=args.max_queue,
                            telemetry=det.telemetry)
    n_chunks = 16
    t_serve = time.perf_counter()
    if args.interleave:
        # the service loop: requests arrive spread over ingestion and are
        # answered against the refreshed pool while the corpus grows
        session = ServeSession(det, eng,
                               refresh_every_chunks=args.refresh_every)
        arrival_chunk = [min(n_chunks - 1, i * n_chunks // max(
            len(reqs), 1)) for i in range(len(reqs))]
        next_req = [0]

        def on_chunk(ci: int) -> None:
            while (next_req[0] < len(reqs)
                   and arrival_chunk[next_req[0]] <= ci):
                session.submit(reqs[next_req[0]])
                next_req[0] += 1
            session.after_push()

        ingest_chunks(det, ingest_wf, n_chunks=n_chunks, skip=skip,
                      snapshot_every=args.snapshot_every,
                      snapshot_dir=args.snapshot_dir,
                      metrics_every=args.metrics_every,
                      metrics_file=args.metrics_file,
                      on_chunk=on_chunk)
        for r in reqs[next_req[0]:]:
            session.submit(r)
        session.finish()
    else:
        ingest_chunks(det, ingest_wf, n_chunks=n_chunks, skip=skip,
                      snapshot_every=args.snapshot_every,
                      snapshot_dir=args.snapshot_dir,
                      metrics_every=args.metrics_every,
                      metrics_file=args.metrics_file)
        det.flush()
    assert all(st.stats_frozen for st in det.stations), \
        "ingest too short to freeze MAD statistics"
    # data-quality reconciliation + guard counters (gaps spliced/dropped,
    # duplicates suppressed, saturated buckets hit) — the operational view
    # of how dirty the ingested telemetry was
    quality = det.quality_summary()
    print("# ingest quality " + json.dumps(quality))
    located_summary = None
    if args.locate:
        # the widened ISSUE-9 alert rows: location (milli-km sentinels
        # decoded to km), relative magnitude and the upgrade flag, one
        # JSON line per alert + an aggregate block in the RESULT stats
        from repro.core.locate import LOC_NONE, MAG_NONE
        lag_s = cfg.fingerprint.lag_samples / cfg.fingerprint.fs
        alert_rows = []
        for rows in det.alerts:
            for dt, onset, n_st, score, upg, x_mkm, y_mkm, mag_m in rows:
                alert_rows.append({
                    "t_s": round(float(onset) * lag_s, 1),
                    "dt_s": round(float(dt) * lag_s, 1),
                    "stations": int(n_st), "score": int(score),
                    "upgrade": bool(upg),
                    "x_km": None if x_mkm == LOC_NONE else x_mkm / 1e3,
                    "y_km": None if y_mkm == LOC_NONE else y_mkm / 1e3,
                    "dmag": None if mag_m == MAG_NONE else mag_m / 1e3,
                })
        for row in alert_rows:
            print("ALERT " + json.dumps(row))
        loc = [r for r in alert_rows if r["x_km"] is not None]
        errs = [float(np.min(np.linalg.norm(
                    ds.source_xy - np.array([r["x_km"], r["y_km"]]),
                    axis=1))) for r in loc]
        lv = det.telemetry.locate_view()
        located_summary = {
            "alerts": len(alert_rows),
            "located": len(loc),
            "upgrades": int(sum(r["upgrade"] for r in alert_rows)),
            "moveout_rejected": lv["moveout_rejected"],
            "locate_passes": lv["passes"],
            "median_origin_err_km": (round(float(np.median(errs)), 2)
                                     if errs else None),
        }
    if args.metrics_every:
        # final post-flush heartbeat so the log reflects the completed
        # ingest
        print(det.telemetry.heartbeat_line(det))
    if args.metrics_file:
        # the final exposition rewrite runs whenever a scrape file was
        # asked for — a bare --metrics-file used to write nothing
        det.telemetry.write_prometheus(args.metrics_file, det)
    det.telemetry.tracer.flush()

    if args.interleave:
        stats = eng.summary(reqs, time.perf_counter() - t_serve)
        stats["refreshes"] = int(eng.telemetry.registry.total(
            "serve_state_refreshes_total"))
    else:
        eng.refresh_from(det)
        stats = eng.run(reqs)
    assert all(r.done for r in reqs)
    stats["ingest_quality"] = quality
    if located_summary is not None:
        stats["located"] = located_summary
    if args.metrics_every:
        stats["metrics"] = det.metrics_snapshot()
    print("RESULT " + json.dumps(stats))
    return stats


if __name__ == "__main__":
    main()
