# The 512 virtual devices MUST be requested before jax initializes —
# before any other import, including `from repro...` (spec requirement).
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver jits the real step function (train_step /
prefill / decode_step / detect_step) with production in/out shardings,
lowers it against ShapeDtypeStruct inputs (no allocation), compiles for the
512-virtual-device CPU platform, and records memory_analysis(),
cost_analysis() and the HLO collective schedule into a JSON artifact that
EXPERIMENTS.md §Dry-run/§Roofline reads.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch command-r-35b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import functools
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import dist
from repro.configs import ALL_ARCHS, LM_ARCHS, get_config, get_module
from repro.configs.shapes import LM_SHAPES, input_specs, shapes_for
from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.models import (ModelConfig, cache_sharding_rules, decode_step,
                          init_cache, param_sharding_rules, prefill)
from repro.models.config import ModelConfig as MC
from repro.train.loop import TrainState, init_train_state, make_train_step
from repro.train.optimizer import (OptimizerConfig, init_opt_state,
                                   opt_state_sharding_rules)


# ---------------------------------------------------------------------------
# sharding-tree construction
# ---------------------------------------------------------------------------


def _rules_to_shardings(rules, shapes_tree, mesh):
    """Nested dict of rule-tuples + matching ShapeDtypeStruct tree →
    NamedSharding tree (divisibility-sanitized).

    jit argument shardings MUST be evenly divisible (unlike constraints),
    so uneven-sharding mode is suspended here.
    """
    from repro.dist import _UNEVEN

    def walk(rule, shp):
        if isinstance(rule, tuple):
            tok = _UNEVEN.set(False)
            try:
                with mesh:
                    spec = dist.sanitize_spec(shp.shape, rule)
            finally:
                _UNEVEN.reset(tok)
            return NamedSharding(mesh, spec if spec is not None else P())
        return {k: walk(rule[k], shp[k]) for k in rule}

    return walk(rules, shapes_tree)


def _batch_shardings(batch_specs, mesh):
    names = (("pod", "data", "model")
             if dist.current_layout() == "fsdp" else ("pod", "data"))
    ba = tuple(a for a in names if a in mesh.shape)

    def one(sds):
        spec = (ba,) + (None,) * (len(sds.shape) - 1)
        with mesh:
            s = dist.sanitize_spec(sds.shape, spec)
        return NamedSharding(mesh, s if s is not None else P())

    return jax.tree.map(one, batch_specs)


def pick_microbatches(cfg: ModelConfig, global_batch: int, dp: int) -> int:
    """1 sequence per device per microbatch for ≥4B-param models."""
    local = global_batch // dp
    if cfg.param_count() >= 4e9:
        return local
    if cfg.param_count() >= 1e9:
        return max(1, local // 4)
    return max(1, local // 8)


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------


def lower_lm_cell(arch: str, shape_name: str, mesh, attn_impl: str,
                  microbatches: int | None = None,
                  accum_mode: str = "scan_grads",
                  shard_grads: bool = False,
                  cfg_overrides: dict | None = None):
    cfg = get_config(arch)
    if cfg_overrides:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **cfg_overrides)
    spec = LM_SHAPES[shape_name]
    dp_names = (("pod", "data", "model")
                if dist.current_layout() == "fsdp" else ("pod", "data"))
    dp = 1
    for a in dp_names:
        if a in mesh.shape:
            dp *= mesh.shape[a]

    p_rules = param_sharding_rules(cfg)
    with mesh:
        if spec.kind == "train":
            n_mb = microbatches or pick_microbatches(cfg, spec.global_batch,
                                                     dp)
            opt_cfg = OptimizerConfig()
            state_shape = jax.eval_shape(
                functools.partial(init_train_state, jax.random.PRNGKey(0),
                                  cfg))
            o_rules = opt_state_sharding_rules(
                p_rules, jax.tree.map(lambda s: s.shape, state_shape.params,
                                      is_leaf=lambda x: hasattr(x, "shape")))
            state_sh = TrainState(
                params=_rules_to_shardings(p_rules, state_shape.params, mesh),
                opt={
                    "master": _rules_to_shardings(
                        o_rules["master"], state_shape.opt["master"], mesh),
                    "m": _rules_to_shardings(o_rules["m"],
                                             state_shape.opt["m"], mesh),
                    "v": _rules_to_shardings(o_rules["v"],
                                             state_shape.opt["v"], mesh),
                    "step": NamedSharding(mesh, P()),
                },
                step=NamedSharding(mesh, P()))
            batch = input_specs(cfg, shape_name)
            batch_sh = _batch_shardings(batch, mesh)
            step = make_train_step(cfg, opt_cfg, n_microbatches=n_mb,
                                   attn_impl=attn_impl,
                                   accum_mode=accum_mode,
                                   shard_grads_like_opt=shard_grads)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_shape, batch)
            extra = {"microbatches": n_mb}
        elif spec.kind == "prefill":
            params_shape = _param_struct(cfg)
            params_sh = _rules_to_shardings(p_rules, params_shape, mesh)
            batch = input_specs(cfg, shape_name)
            batch_sh = _batch_shardings(batch, mesh)
            fn = functools.partial(prefill, cfg=cfg, impl=attn_impl)
            jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params_shape, batch)
            extra = {}
        else:  # decode
            params_shape = _param_struct(cfg)
            params_sh = _rules_to_shardings(p_rules, params_shape, mesh)
            specs = input_specs(cfg, shape_name)
            cache_shape = specs["cache"]
            c_rules = cache_sharding_rules(cfg)
            cache_sh = _rules_to_shardings(c_rules, cache_shape, mesh)
            tok_sh = _batch_shardings({"tokens": specs["tokens"]},
                                      mesh)["tokens"]
            fn = functools.partial(decode_step, cfg=cfg)
            jitted = jax.jit(lambda p, c, t: fn(p, c, t),
                             in_shardings=(params_sh, cache_sh, tok_sh),
                             out_shardings=(None, cache_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_shape, cache_shape,
                                   specs["tokens"])
            extra = {}
    return lowered, cfg, spec, extra


def _param_struct(cfg: ModelConfig):
    from repro.models import init_params
    return jax.eval_shape(
        functools.partial(init_params, jax.random.PRNGKey(0), cfg))


def lower_detect_cell(shape_name: str, mesh, use_shard_map: bool = True,
                      occ_limit: int = 0):
    """Lower the fixed-shape detection cell (now a wrapper over the shared
    streaming core) with production shardings. The per-chunk in-trace
    index is sized like the paper-scale streaming config; ``occ_limit``
    > 0 lowers the cell with the in-dispatch §6.5 occurrence limiter on,
    so its cost shows up in the dry-run HLO/memory stats before anyone
    pays for a TPU."""
    from repro.configs import fast_seismic as fs
    from repro.core.detect import detect_step, detect_step_sharded
    from repro.stream.index import StreamIndexConfig
    dcfg = fs.config()
    specs = fs.input_specs(shape_name)
    n_chunk_fp = dcfg.fingerprint.n_fingerprints(
        specs["waveforms"].shape[1])
    icfg = StreamIndexConfig(
        n_buckets=16384, bucket_cap=dcfg.lsh.bucket_cap,
        occ_slots=n_chunk_fp if occ_limit > 0 else 0)
    knobs = dict(icfg=icfg, occ_limit=occ_limit)
    all_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    wf_sh = NamedSharding(mesh, P(all_axes, None))
    stat_sh = NamedSharding(mesh, P())
    if use_shard_map:
        step = functools.partial(detect_step_sharded, cfg=dcfg, mesh=mesh,
                                 **knobs)
    else:  # SPMD-partitioner baseline (kept for §Perf comparison)
        step = jax.vmap(functools.partial(detect_step, cfg=dcfg, **knobs),
                        in_axes=(0, None, None))
    with mesh:
        jitted = jax.jit(step, in_shardings=(wf_sh, stat_sh, stat_sh))
        lowered = jitted.lower(specs["waveforms"], specs["med"],
                               specs["mad"])
    return lowered, dcfg


# ---------------------------------------------------------------------------
# model-flops accounting (MFU numerator)
# ---------------------------------------------------------------------------


def model_flops(cfg, spec_kind: str, global_batch: int, seq: int) -> float:
    if not isinstance(cfg, MC):
        return 0.0
    n_active = cfg.active_param_count()
    tokens = global_batch * (seq if spec_kind in ("train", "prefill") else 1)
    mult = 6.0 if spec_kind == "train" else 2.0
    return mult * n_active * tokens


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             attn_impl: str = "masked", save_hlo: bool = False,
             microbatches: int | None = None, tag: str = "",
             accum_mode: str = "scan_grads", shard_grads: bool = False,
             cfg_overrides: dict | None = None,
             uneven: bool = False, layout: str = "tp") -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_dev = mesh.size
    pod_boundary = (n_dev // mesh.shape["pod"]) if multi else None
    t0 = time.perf_counter()
    import contextlib
    uneven_ctx = (dist.allow_uneven_sharding() if uneven
                  else contextlib.nullcontext())
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                    "devices": n_dev, "attn_impl": attn_impl, "tag": tag,
                    "accum_mode": accum_mode, "shard_grads": shard_grads,
                    "uneven": uneven,
                    "cfg_overrides": cfg_overrides or {}}
    if uneven:
        from repro.dist import _UNEVEN
        _uneven_tok = _UNEVEN.set(True)
    else:
        _uneven_tok = None
    from repro.dist import _LAYOUT
    _layout_tok = _LAYOUT.set(layout)
    record["layout"] = layout
    try:
        if arch == "fast_seismic":
            lowered, dcfg = lower_detect_cell(
                shape_name, mesh,
                use_shard_map=(cfg_overrides or {}).get("shard_map", 1) == 1)
            from repro.configs import fast_seismic as fs
            mf = fs.model_flops(shape_name)
            record["kind"] = "detect"
        else:
            lowered, cfg, spec, extra = lower_lm_cell(
                arch, shape_name, mesh, attn_impl, microbatches,
                accum_mode=accum_mode, shard_grads=shard_grads,
                cfg_overrides=cfg_overrides)
            mf = model_flops(cfg, spec.kind, spec.global_batch, spec.seq_len)
            record["kind"] = spec.kind
            record.update(extra)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
        record["lower_s"] = round(t1 - t0, 2)
        record["compile_s"] = round(t2 - t1, 2)
        record["memory"] = hlo_stats.extract_memory(compiled)
        record["xla_cost_raw"] = hlo_stats.extract_cost(compiled)
        hlo = compiled.as_text()
        stats = hlo_stats.analyze_hlo(hlo, pod_boundary=pod_boundary)
        record["collectives"] = {
            "counts": stats.coll_counts,
            "bytes_by_kind": stats.coll_bytes,
            "link_bytes_ici": stats.link_bytes_ici,
            "link_bytes_dcn": stats.link_bytes_dcn,
        }
        record["roofline"] = hlo_stats.roofline_terms(stats, n_dev, mf)
        record["status"] = "ok"
        if save_hlo:
            import gzip
            hp = pathlib.Path(out_dir) / f"{_cell_name(record)}.hlo.gz"
            hp.parent.mkdir(parents=True, exist_ok=True)
            with gzip.open(hp, "wt") as f:
                f.write(hlo)
        # The two artifacts the spec asks to print:
        print(f"--- {arch} × {shape_name} × {mesh_kind} ---")
        print("memory_analysis:", json.dumps(record["memory"]))
        print("cost_analysis(raw):", json.dumps(record["xla_cost_raw"]))
        print("collectives:", json.dumps(record["collectives"]["counts"]))
        rf = record["roofline"]
        print(f"roofline: compute={rf['compute_s']:.4f}s "
              f"memory={rf['memory_s']:.4f}s "
              f"collective={rf['collective_s']:.4f}s "
              f"dominant={rf['dominant']} "
              f"useful_ratio={rf['useful_flops_ratio']:.3f}")
    except Exception as e:
        record["status"] = "fail"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        print(f"--- {arch} × {shape_name} × {mesh_kind} FAILED: "
              f"{record['error']}")
    if _uneven_tok is not None:
        from repro.dist import _UNEVEN
        _UNEVEN.reset(_uneven_tok)
    _LAYOUT.reset(_layout_tok)
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / f"{_cell_name(record)}.json").write_text(
        json.dumps(record, indent=1, default=str))
    return record


def _cell_name(record: dict) -> str:
    tag = f"__{record['tag']}" if record.get("tag") else ""
    return (f"{record['arch']}__{record['shape']}__{record['mesh']}"
            f"{tag}".replace("/", "_").replace(".", "p"))


def iter_cells(archs, shapes_arg, meshes):
    for arch in archs:
        if arch == "fast_seismic":
            from repro.configs import fast_seismic as fs
            names = list(fs.SHAPES) if shapes_arg == ["all"] else shapes_arg
        else:
            cfg = get_config(arch)
            names = shapes_for(cfg) if shapes_arg == ["all"] else shapes_arg
        for shp in names:
            for mk in meshes:
                yield arch, shp, mk


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id, comma list, or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--attn-impl", default="masked",
                    choices=["masked", "triangular"])
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose ok-status JSON already exists")
    ap.add_argument("--accum-mode", default="scan_grads",
                    choices=["scan_grads", "grad_of_scan"])
    ap.add_argument("--shard-grads", action="store_true")
    ap.add_argument("--cfg-override", default="",
                    help="comma k=v model-config overrides (ints/floats/str)")
    ap.add_argument("--uneven-sharding", action="store_true",
                    help="allow non-divisible dims to shard (XLA pads)")
    ap.add_argument("--layout", default="tp", choices=["tp", "fsdp"])
    args = ap.parse_args()

    archs = ALL_ARCHS if args.arch == "all" else args.arch.split(",")
    shapes = ["all"] if args.shape == "all" else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    cells = list(iter_cells(archs, shapes, meshes))
    if args.list:
        for c in cells:
            print(*c)
        return

    failures = 0
    for arch, shp, mk in cells:
        if args.skip_existing:
            name = _cell_name({"arch": arch, "shape": shp, "mesh": mk,
                               "tag": args.tag})
            p = pathlib.Path(args.out) / f"{name}.json"
            if p.exists() and json.loads(p.read_text()).get("status") \
                    == "ok":
                print(f"skip {arch} × {shp} × {mk} (exists)")
                continue
        overrides = {}
        for kv in args.cfg_override.split(","):
            if not kv:
                continue
            k, v = kv.split("=")
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
            overrides[k] = v
        rec = run_cell(arch, shp, mk, args.out, attn_impl=args.attn_impl,
                       save_hlo=args.save_hlo,
                       microbatches=args.microbatches, tag=args.tag,
                       accum_mode=args.accum_mode,
                       shard_grads=args.shard_grads,
                       cfg_overrides=overrides or None,
                       uneven=args.uneven_sharding, layout=args.layout)
        failures += rec["status"] != "ok"
    print(f"\n{len(cells) - failures}/{len(cells)} cells OK")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
