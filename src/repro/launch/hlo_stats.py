"""Static analysis of optimized HLO → roofline terms.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, but every
model here scans over layers / microbatches / loss chunks, so flops & bytes
would be undercounted by ~n_layers×. This module re-derives per-device
FLOPs, HBM bytes and collective traffic by walking the HLO call graph with
``known_trip_count`` multipliers (DESIGN.md §5).

Cost model (per instruction):
  dot            2 · |result| · Π contracted dims
  elementwise    |result|
  reduce         |operand|
  bytes          Σ operand sizes + result size at fusion boundaries only;
                 dynamic-update-slice/scatter cost ~2·|update| (in-place)
  collectives    all-reduce 2·size, others 1·size; replica_groups spanning
                 the pod boundary are classified DCN.
  sort           0 flops (comparison-bound; traffic captured via bytes)

Hardware model (TPU v5e-class target): 197 TFLOP/s bf16 · 819 GB/s HBM ·
~50 GB/s/link ICI · DCN modeled at 10 GB/s (assumption, recorded).
"""
from __future__ import annotations

import dataclasses
import math
import re

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 10e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "negate", "abs", "exponential", "exponential-minus-one", "log",
    "log-plus-one", "tanh", "logistic", "rsqrt", "sqrt", "cbrt", "power",
    "sine", "cosine", "tan", "atan2", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "clamp", "select",
    "compare", "and", "or", "xor", "not", "remainder", "sign",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "population-count", "is-finite",
}

_NO_BYTES = {"parameter", "get-tuple-element", "tuple", "bitcast",
             "constant", "after-all", "opt-barrier", "partition-id",
             "replica-id", "rng-get-and-update-state", "domain"}

# Ops that materialize results in HBM even under aggressive TPU fusion.
_BYTES_OPS = {
    "dot", "convolution", "fusion", "reduce", "reduce-window", "sort",
    "copy", "transpose", "concatenate", "pad", "reverse", "slice",
    "custom-call", "select-and-scatter", "rng", "rng-bit-generator",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "cholesky", "triangular-solve", "fft",
    "dynamic-reshape", "map",
}

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")

_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(\((?:[^()]|\([^()]*\))*\)|[\w\[\],{}]+)\s+"
    r"([\w\-]+)\((.*)$")

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_TOKEN.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(type_str: str) -> int:
    total = 0
    for m in _SHAPE_TOKEN.finditer(type_str):
        if m.group(1) not in _DTYPE_BYTES:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _first_dims(type_str: str) -> list[int]:
    m = _SHAPE_TOKEN.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: {k: 0 for k in _COLLECTIVES})
    coll_bytes: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    link_bytes_ici: float = 0.0
    link_bytes_dcn: float = 0.0
    unknown_trip_whiles: int = 0
    sort_elems: float = 0.0

    def add(self, other: "HloStats", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for k in _COLLECTIVES:
            self.coll_counts[k] += other.coll_counts[k] * mult
            self.coll_bytes[k] += other.coll_bytes[k] * mult
        self.link_bytes_ici += other.link_bytes_ici * mult
        self.link_bytes_dcn += other.link_bytes_dcn * mult
        self.unknown_trip_whiles += other.unknown_trip_whiles
        self.sort_elems += other.sort_elems * mult


def _split_args(rest: str) -> tuple[str, str]:
    """Split 'call args...), attr=...' at the matching close paren."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


def _operand_names(args: str) -> list[str]:
    return re.findall(r"%([\w.\-]+)", args)


class _Analyzer:
    def __init__(self, text: str, pod_boundary: int | None):
        self.pod_boundary = pod_boundary
        self.comps: dict[str, list[str]] = {}
        self.entry: str | None = None
        self.fused: set[str] = set()
        self._split(text)
        self._memo: dict[str, HloStats] = {}

    def _split(self, text: str):
        cur = None
        for line in text.splitlines():
            if not line:
                continue
            if line[0] not in " \t}":
                m = _COMP_HDR.match(line)
                if m and line.rstrip().endswith("{"):
                    cur = m.group(2)
                    self.comps[cur] = []
                    if m.group(1):
                        self.entry = cur
                    continue
                cur = None
            elif line.strip() == "}":
                cur = None
                continue
            if cur is not None:
                self.comps[cur].append(line)
        # mark fusion-called computations (their bytes don't hit HBM)
        for lines in self.comps.values():
            for line in lines:
                for m in re.finditer(r"calls=%?([\w.\-]+)", line):
                    self.fused.add(m.group(1))
        # classify fusions (fused-TPU byte model):
        #   "inplace"  — contains dynamic-update-slice / scatter: the big
        #                aliased buffer is updated in place, traffic ≈
        #                update-sized (boundary minus 2× largest part);
        #   "full"     — contains reduce/dot/sort/…: materializes, charge
        #                operand+result boundary bytes;
        #   "fused"    — pure elementwise/broadcast/slice chains: fuse
        #                into neighbors on TPU, no HBM traffic.
        self._fusion_kind: dict[str, str] = {}
        full_ops = ("reduce(", "reduce-window(", "dot(", "sort(",
                    "rng", "convolution(", "concatenate(", "gather(")
        inplace_ops = ("dynamic-update-slice(", "scatter(")
        for name in self.fused:
            body = "\n".join(self.comps.get(name, []))
            if any(op in body for op in inplace_ops):
                self._fusion_kind[name] = "inplace"
            elif any(op in body for op in full_ops):
                self._fusion_kind[name] = "full"
            else:
                self._fusion_kind[name] = "fused"

    def stats(self) -> HloStats:
        if self.entry is None:
            return HloStats()
        return self._eval(self.entry, in_fusion=False)

    # -- per-computation ---------------------------------------------------

    def _eval(self, comp: str, in_fusion: bool) -> HloStats:
        key = f"{comp}|{in_fusion}"
        if key in self._memo:
            return self._memo[key]
        total = HloStats()
        symtab: dict[str, str] = {}
        for line in self.comps.get(comp, []):
            m = _INSTR.match(line)
            if not m:
                continue
            name, rtype, opcode, rest = m.groups()
            symtab[name] = rtype
            args, attrs = _split_args(rest)
            self._instr(total, rtype, opcode, args, attrs, symtab,
                        in_fusion)
        self._memo[key] = total
        return total

    def _instr(self, total: HloStats, rtype: str, opcode: str, args: str,
               attrs: str, symtab: dict, in_fusion: bool):
        opnames = _operand_names(args)
        op_types = [symtab.get(o, "") for o in opnames]

        def op_bytes():
            return sum(_type_bytes(t) for t in op_types)

        # --- control flow / calls
        if opcode == "while":
            body = re.search(r"body=%?([\w.\-]+)", attrs)
            cond = re.search(r"condition=%?([\w.\-]+)", attrs)
            trip_m = re.search(r'known_trip_count[^0-9]*(\d+)', attrs)
            trip = int(trip_m.group(1)) if trip_m else 1
            if not trip_m:
                total.unknown_trip_whiles += 1
            sub = HloStats()
            if body:
                sub.add(self._eval(body.group(1), in_fusion))
            if cond:
                sub.add(self._eval(cond.group(1), in_fusion))
            total.add(sub, trip)
            return
        if opcode == "fusion":
            callee = re.search(r"calls=%?([\w.\-]+)", attrs)
            kind = "full"
            if callee:
                total.add(self._eval(callee.group(1), in_fusion=True))
                kind = self._fusion_kind.get(callee.group(1), "full")
            if not in_fusion:
                parts = [_type_bytes(t) for t in op_types] \
                    + [_type_bytes(rtype)]
                if kind == "full":
                    total.bytes += sum(parts)
                elif kind == "inplace" and parts:
                    total.bytes += max(0, sum(parts) - 2 * max(parts))
            return
        if opcode in ("call", "async-start", "custom-call"):
            callee = re.search(r"(?:to_apply|calls|called_computation)"
                               r"=%?([\w.\-]+)", attrs)
            if callee and callee.group(1) in self.comps:
                total.add(self._eval(callee.group(1), in_fusion))
            elif not in_fusion and opcode != "call":
                total.bytes += op_bytes() + _type_bytes(rtype)
            return
        if opcode == "conditional":
            branches = re.findall(
                r"(?:true_computation|false_computation|"
                r"branch_computations=\{[^}]*)=?%?([\w.\-]+)", attrs)
            subs = [self._eval(b, in_fusion) for b in branches
                    if b in self.comps]
            if subs:
                best = max(subs, key=lambda s: s.flops + s.bytes)
                total.add(best)
            return

        # --- collectives
        op_base = opcode[:-6] if opcode.endswith("-start") else opcode
        if op_base in _COLLECTIVES:
            size = max(_type_bytes(rtype), op_bytes())
            total.coll_counts[op_base] += 1
            total.coll_bytes[op_base] += size
            traffic = 2 * size if op_base == "all-reduce" else size
            crosses = False
            if self.pod_boundary is not None:
                g = re.search(r"replica_groups=\{(.*?)\}\}?,", attrs)
                gtxt = g.group(1) if g else ""
                if g:
                    for grp in gtxt.split("},{"):
                        ids = [int(x) for x in re.findall(r"\d+", grp)]
                        if ids and (min(ids) < self.pod_boundary
                                    <= max(ids)):
                            crosses = True
                            break
                else:
                    crosses = True
            if crosses:
                total.link_bytes_dcn += traffic
            else:
                total.link_bytes_ici += traffic
            if not in_fusion:
                total.bytes += op_bytes() + _type_bytes(rtype)
            return

        # --- compute
        if opcode == "dot":
            lhs_dims = _first_dims(op_types[0]) if op_types else []
            cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                              attrs or args)
            contract = 1
            if cdims and lhs_dims:
                for i in cdims.group(1).split(","):
                    if i:
                        contract *= lhs_dims[int(i)]
            total.flops += 2.0 * _type_elems(rtype) * contract
        elif opcode == "convolution":
            # crude: 2 · |result| · |kernel| / out_features
            kdims = _first_dims(op_types[1]) if len(op_types) > 1 else []
            kprod = 1
            for d in kdims:
                kprod *= d
            out_feat = _first_dims(rtype)[-1] if _first_dims(rtype) else 1
            total.flops += 2.0 * _type_elems(rtype) * max(
                kprod // max(out_feat, 1), 1)
        elif opcode in ("reduce", "reduce-window"):
            total.flops += float(_type_elems(op_types[0])) if op_types \
                else 0.0
        elif opcode in _ELEMENTWISE:
            total.flops += float(_type_elems(rtype))
            if opcode in ("exponential", "log", "tanh", "logistic",
                          "rsqrt", "sqrt", "power", "sine", "cosine"):
                total.transcendentals += float(_type_elems(rtype))
        elif opcode == "sort":
            n = _type_elems(op_types[0]) if op_types else 0
            total.sort_elems += float(n)

        # --- bytes: fused-TPU model. Elementwise/broadcast/select chains
        # fuse into their producers on TPU, so only materializing ops
        # charge HBM traffic (fusion boundaries, dots, reshuffles, RNG,
        # reductions, slicing/scatter, sort).
        if in_fusion or opcode in _NO_BYTES:
            return
        if opcode in ("dynamic-update-slice", "scatter"):
            upd = _type_bytes(op_types[1]) if len(op_types) > 1 else 0
            total.bytes += 2.0 * upd + sum(
                _type_bytes(t) for t in op_types[2:])
        elif opcode in ("dynamic-slice", "gather"):
            total.bytes += 2.0 * _type_bytes(rtype) + sum(
                _type_bytes(t) for t in op_types[1:])
        elif opcode in _BYTES_OPS or opcode[:-6] in _COLLECTIVES:
            total.bytes += op_bytes() + _type_bytes(rtype)


def analyze_hlo(hlo_text: str, pod_boundary: int | None = None) -> HloStats:
    return _Analyzer(hlo_text, pod_boundary).stats()


# ---------------------------------------------------------------------------
# extraction from the compiled executable
# ---------------------------------------------------------------------------


def extract_memory(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
        v = getattr(ma, field, None)
        if v is not None:
            out[field] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def extract_cost(compiled) -> dict:
    """Raw XLA cost_analysis (NOTE: while bodies counted once — see
    analyze_hlo for trip-corrected numbers)."""
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    out = {}
    for k in ("flops", "bytes accessed", "transcendentals"):
        if k in ca:
            out[k.replace(" ", "_")] = float(ca[k])
    return out


def roofline_terms(stats: HloStats, n_devices: int,
                   model_flops: float) -> dict:
    """The three §Roofline terms (seconds per step, per device)."""
    t_compute = stats.flops / PEAK_FLOPS
    t_memory = stats.bytes / HBM_BW
    t_coll = (stats.link_bytes_ici / ICI_BW
              + stats.link_bytes_dcn / DCN_BW)
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    flops_global = stats.flops * n_devices
    return {
        **terms,
        "dominant": dominant,
        "hlo_flops_per_device": stats.flops,
        "hlo_bytes_per_device": stats.bytes,
        "hlo_flops_global": flops_global,
        "collective_bytes_ici": stats.link_bytes_ici,
        "collective_bytes_dcn": stats.link_bytes_dcn,
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops / flops_global
                               if flops_global else 0.0),
        "roofline_fraction": (t_compute / bound if bound > 0 else 0.0),
        "step_time_lower_bound_s": bound,
        "unknown_trip_whiles": stats.unknown_trip_whiles,
        "sort_elems_per_device": stats.sort_elems,
    }
