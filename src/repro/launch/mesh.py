"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16×16 = 256 chips (data × model).
Multi-pod: 2×16×16 = 512 chips with a leading ``pod`` (DCN) axis used for
data parallelism (gradient all-reduce only crosses the slow links).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for subprocess tests with forced host devices."""
    return jax.make_mesh(shape, axes)
