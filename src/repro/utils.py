"""Shared utilities: integer mixing, bit packing, segment helpers.

TPU-friendly primitives used across the FAST pipeline. The paper uses
murmurhash for MinHash permutations; we use a splitmix-style mixer that
vectorizes over int32 lanes (DESIGN.md §3.8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Integer hashing (splitmix32-style finalizer, vector-lane friendly)
# ---------------------------------------------------------------------------

_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)


def mix32(x: jax.Array) -> jax.Array:
    """Avalanche mixer over uint32 lanes (murmur3 finalizer)."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 13)
    x = x * _M2
    x = x ^ (x >> 16)
    return x


def hash_u32(x: jax.Array, seed) -> jax.Array:
    """Seeded uint32 hash of integer input (any int dtype)."""
    seed = jnp.asarray(seed, jnp.uint32)
    return mix32(x.astype(jnp.uint32) + seed * _GOLDEN)


def hash_combine(a: jax.Array, b: jax.Array) -> jax.Array:
    """Order-sensitive combine of two uint32 hash streams (boost-style)."""
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    return a ^ (b + _GOLDEN + (a << 6) + (a >> 2))


def fold_hashes(h: jax.Array, axis: int = -1) -> jax.Array:
    """Reduce an axis of uint32 hashes into one uint32 via hash_combine."""
    h = jnp.moveaxis(h, axis, 0)

    def body(carry, x):
        return hash_combine(carry, x), None

    init = jnp.zeros(h.shape[1:], jnp.uint32)
    out, _ = jax.lax.scan(body, init, h)
    return out


# ---------------------------------------------------------------------------
# Bit packing for binary fingerprints
# ---------------------------------------------------------------------------


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack a boolean array (..., d) with d % 32 == 0 into uint32 (..., d//32).

    Bit j of word w corresponds to input position w * 32 + j.
    """
    d = bits.shape[-1]
    assert d % 32 == 0, f"fingerprint dim {d} not a multiple of 32"
    b = bits.astype(jnp.uint32).reshape(*bits.shape[:-1], d // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (b << shifts).sum(axis=-1).astype(jnp.uint32)


def unpack_bits(words: jax.Array, d: int) -> jax.Array:
    """Inverse of pack_bits; returns bool (..., d)."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    b = (words[..., None] >> shifts) & jnp.uint32(1)
    return b.reshape(*words.shape[:-1], words.shape[-1] * 32)[..., :d].astype(bool)


def popcount(x: jax.Array) -> jax.Array:
    """Per-lane popcount of uint32 words."""
    return jax.lax.population_count(x.astype(jnp.uint32)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Segment helpers on sorted keys (the TPU group-by substrate, DESIGN.md §3.1)
# ---------------------------------------------------------------------------


def segment_starts(sorted_keys: jax.Array) -> jax.Array:
    """Boolean mask: True where a run of equal keys begins (keys sorted)."""
    first = jnp.ones((1,) + sorted_keys.shape[1:], bool)
    return jnp.concatenate([first, sorted_keys[1:] != sorted_keys[:-1]], axis=0)


def segment_ids_from_starts(starts: jax.Array) -> jax.Array:
    """Integer segment id per element (cumsum of run starts, 0-based)."""
    return jnp.cumsum(starts.astype(jnp.int32)) - 1


def run_lengths(sorted_keys: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(segment_ids, length_of_that_segment_per_element) for sorted keys."""
    starts = segment_starts(sorted_keys)
    seg = segment_ids_from_starts(starts)
    ones = jnp.ones_like(seg)
    counts = jax.ops.segment_sum(ones, seg, num_segments=sorted_keys.shape[0])
    return seg, counts[seg]


def rank_in_run(sorted_keys: jax.Array) -> jax.Array:
    """0-based rank of each element inside its run of equal (sorted) keys."""
    starts = segment_starts(sorted_keys)
    idx = jnp.arange(sorted_keys.shape[0], dtype=jnp.int32)
    start_idx = jnp.where(starts, idx, 0)
    run_start = jax.lax.associative_scan(jnp.maximum, start_idx)
    return idx - run_start


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------


def tree_bytes(tree) -> int:
    """Total byte size of a pytree of arrays / ShapeDtypeStructs."""
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree.leaves(tree)
    )


def tree_param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b
