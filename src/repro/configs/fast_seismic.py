"""fast_seismic — the paper's own workload as a dry-runnable config.

FAST detection over continuous seismic data: fingerprint → Min-Max LSH →
occurrence filter → diagonal clustering, per waveform chunk, sharded over
every mesh axis (the pipeline is embarrassingly parallel across chunks —
the paper's §6.4 partition/parallelize structure, DESIGN.md §3.7).

Paper-faithful knobs: 100 Hz input, 8192-dim fingerprints (32×128 spectral
images, 2-bit sign encoding), t=100 tables / k=8 funcs / m=2 matches (the
optimized §6.3 setting), 1% occurrence filter, 3–20 Hz band.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import AlignConfig, DetectConfig, FingerprintConfig, LSHConfig
from repro.core.locate import LocateConfig
from repro.stream.index import StreamIndexConfig
from repro.stream.ingest import StreamConfig

ARCH_ID = "fast_seismic"


def config() -> DetectConfig:
    fp = FingerprintConfig(img_freq=32, img_time=128, img_hop=8, top_k=400,
                           mad_sample_rate=0.1)
    return DetectConfig(
        fingerprint=fp,
        lsh=LSHConfig(n_tables=100, n_funcs=8, n_matches=2, bucket_cap=4,
                      min_dt=fp.overlap_fingerprints, occurrence_frac=0.01),
        align=AlignConfig(),
    )


def smoke_config() -> DetectConfig:
    fp = FingerprintConfig(img_freq=16, img_time=32, img_hop=8, top_k=64,
                           mad_sample_rate=1.0)
    return DetectConfig(
        fingerprint=fp,
        lsh=LSHConfig(n_tables=20, n_funcs=4, n_matches=2, bucket_cap=4,
                      min_dt=fp.overlap_fingerprints, occurrence_frac=0.05),
        align=AlignConfig(min_cluster_size=1, min_cluster_sim=4),
    )


def locate_config() -> LocateConfig:
    """Paper-scale location tier (ISSUE 9): a 50 km aperture gridded
    12×12 (≈4 km coarse cells) and refined twice to sub-300 m cells, a
    homogeneous 6 km/s halfspace at 8 km focal depth — the Diablo Canyon
    network geometry regime. At the 2 s fingerprint lag the moveout
    across the aperture is a handful of lags, so the consistency gate is
    tight (2 lags of weighted residual) and cross-station coincidences
    that match no physical origin are rejected."""
    return LocateConfig(grid_n=12, extent_km=50.0, depth_km=8.0,
                        velocity_km_s=6.0, refine_levels=2,
                        moveout_tol_lags=2.0)


def locate_smoke_config() -> LocateConfig:
    """CPU-scale location tier matching the synth scenario geometry
    (``SynthConfig`` physical defaults: 50 km extent, 8 km depth,
    6 km/s). A coarser 8×8 grid keeps the vmapped stack tiny; the synth
    scenario's onsets are exact to one lag, so a 2-lag residual gate
    separates physical groups from coincidences on smoke traces too."""
    return LocateConfig(grid_n=8, extent_km=50.0, depth_km=8.0,
                        velocity_km_s=6.0, refine_levels=2,
                        moveout_tol_lags=2.0, pad_groups=16)


def located_smoke_config() -> DetectConfig:
    """``smoke_config`` + the association-layer physics: location /
    weighting / magnitude on every network detection, the tolerance-
    chaining extent cap, and moveout-consistency rejection."""
    base = smoke_config()
    return dataclasses.replace(
        base,
        align=dataclasses.replace(base.align, max_group_extent=90),
        locate=locate_smoke_config())


def stream_config() -> StreamConfig:
    """Streaming-detection block for the paper-scale config.

    256 fingerprints per jitted step (~9 min of 100 Hz data per block at
    the 2 s lag); 2^14 buckets × cap 8 per table holds ~1.3e5 resident
    fingerprints per station before ring eviction. The sliding detection
    window expires ids older than 3 days (129 600 fingerprints at the 2 s
    lag — matching the index capacity), and the rolling occurrence filter
    retires candidate pairs day-by-day (43 200 fingerprints), so both
    device and host state stay flat over an unbounded stream.
    """
    day = 43_200  # fingerprints per day at the 2 s lag (86400 s / 2 s)
    # fused/pooled default True: one donated dispatch per block, and one
    # vmapped executable for all stations of a monitoring network.
    # telemetry default True (ISSUE 6): the in-dispatch QC_FIELDS counter
    # vector rides in the same dispatch — production streams keep the
    # drop/guard breakdown live at zero extra dispatches and bit-identical
    # detections; set telemetry=False to compile the counters away.
    # Data-quality knobs sized for real telemetry (ISSUE 4): a 60 s
    # reorder horizon absorbs out-of-order packet delivery, offset jumps
    # beyond one hour are rejected as corrupt timestamps rather than
    # gap-filled, and the sample-exact duplicate guard looks one day back
    # (telemetry repeats arrive within hours).
    # The bucket-saturation quarantine is ON for unbounded streams now
    # (ISSUE 5): with a sliding window its traffic counter halves every
    # window inside the traced expire, so it tracks recent pressure —
    # average bucket traffic per 3-day window is ~130k/16384 ≈ 8 inserts,
    # and 200 sits ~25× above it while a repeating glitch hammers one
    # bucket thousands of times per day. The in-dispatch §6.5 occurrence
    # limiter caps per-fingerprint partners at 1% of the filter window
    # (the paper's occurrence fraction applied to a day), with the
    # partner-count ring sized to the 3-day detection window; the host
    # rolling filter stays on as the exact §6.5 reference.
    # Emission epilogue (ISSUE 8): the dense pair stream at this scale is
    # t=100 × 256 × cap 8 ≈ 205k slots (~2.7 MB) per station per block,
    # nearly all masked; max_pairs_per_block=4096 bounds the device→host
    # pipe at ~50× fewer slots while sitting far above the occurrence-
    # limited per-block pair budget (256 fingerprints × occ_limit would
    # need a pathological block to overflow — and overflow is counted in
    # the overflow_pairs QC field, so a saturated bound is visible, not
    # silent). verify_jaccard keeps a packed-fingerprint ring spanning
    # the 3-day window (129 600 rows × fp_dim/32 words ≈ 133 MB — ~2×
    # the signature tables, the price of exact similarity) and scores
    # every surviving candidate with exact Jaccard in the same dispatch;
    # verify_min_jaccard=0.0 keeps the pair set identical to the dense
    # path and just adds the true-similarity channel. verify_pallas is a
    # deployment knob: flip it on TPU for the fused popcount kernel.
    return StreamConfig(block_fingerprints=256,
                        index=StreamIndexConfig(n_buckets=16384,
                                                bucket_cap=8,
                                                occ_slots=3 * day,
                                                pk_slots=3 * day),
                        stats_warmup_blocks=2, reservoir_rows=4096,
                        window_fingerprints=3 * day,
                        filter_window_fingerprints=day,
                        reorder_horizon_samples=6000,
                        max_gap_samples=360_000,
                        dup_window_fingerprints=day,
                        saturation_limit=200,
                        occ_limit=day // 100,
                        max_pairs_per_block=4096,
                        verify_jaccard=True)


def stream_smoke_config() -> StreamConfig:
    """CPU-scale streaming block matching ``smoke_config``.

    Windows stay disabled: this is the parity configuration whose
    accumulated pair set is held against the offline search.
    """
    return StreamConfig(block_fingerprints=64,
                        index=StreamIndexConfig(n_buckets=2048,
                                                bucket_cap=8),
                        stats_warmup_blocks=2, reservoir_rows=1024)


def stream_compact_smoke_config() -> StreamConfig:
    """``stream_smoke_config`` + the ISSUE-8 emission epilogue.

    Same index shape and warmup as the parity smoke config, with the
    dense t=20 × 64 × cap 8 = 10 240-slot emission compacted to 512 and
    every surviving candidate scored with exact Jaccard from a 4096-row
    packed ring (covers the longest smoke trace; the smoke configs run
    unwindowed, so the ring must span the whole stream). 512 sits well
    above any smoke trace's real per-block pair count, so the pair set
    is bit-identical to ``stream_smoke_config`` — the golden parity test
    pins exactly that. ``verify_min_jaccard`` stays 0.0 here for the
    same reason; thresholding tests set it explicitly.
    """
    return StreamConfig(block_fingerprints=64,
                        index=StreamIndexConfig(n_buckets=2048,
                                                bucket_cap=8,
                                                pk_slots=4096),
                        stats_warmup_blocks=2, reservoir_rows=1024,
                        max_pairs_per_block=512,
                        verify_jaccard=True)


def stream_deferred_smoke_config() -> StreamConfig:
    """Smoke streaming with the re-binarize-after-freeze warmup hook.

    ``stats_warmup_blocks=0`` defers the MAD freeze to ``flush()``: every
    block stays buffered while the reservoir absorbs the whole trace, and
    the freeze then binarizes the buffered warmup fingerprints with the
    matured statistics. On the smoke trace (reservoir ≥ total rows) the
    self-computed statistics equal the offline two-pass statistics
    exactly, closing the ~88% self-stats pair-recall gap to 100% (pinned
    by the golden test). Host memory is O(trace) — a finite-trace /
    backfill configuration, not an unbounded-stream one.
    """
    return StreamConfig(block_fingerprints=64,
                        index=StreamIndexConfig(n_buckets=2048,
                                                bucket_cap=8),
                        stats_warmup_blocks=0, reservoir_rows=1024)


def stream_dirty_smoke_config() -> StreamConfig:
    """Quality-hardened smoke streaming (ISSUE 4): the dirty-data path.

    On clean data this configuration is **bit-identical** to
    ``stream_smoke_config`` (pinned by tests): the reorder horizon only
    *delays* block emission by 3 000 samples (30 s) so late or duplicated
    chunks can still be reconciled; the sample-exact duplicate detector
    can only fire on bit-exact repeated windows (continuous noise never
    repeats exactly); and ``saturation_limit=10`` sits at 2× the largest
    lifetime bucket traffic any clean smoke trace produces (≈5, measured
    across seeds — repeating events share buckets only a handful of
    times, while a repeating glitch hammers the same buckets tens to
    thousands of times).

    ``dup_sig_tables`` stays 0 here: on the smoke LSH config (t=20, k=4)
    the strongest legitimate repeating events can collide in up to all 20
    tables on some seeds, so the signature-level duplicate guard is a
    per-deployment knob rather than a default (see ``StreamConfig``).

    ``occ_limit=30`` is the in-dispatch §6.5 occurrence limiter (ISSUE
    5). Its counter is the raw partner-collision count (table×slot
    signature matches at id distance ≥ ``min_dt`` — the §6.3
    lookups-per-query skew signal): the densest legitimate repeater on
    the parity-pinned smoke traces accumulates ≤ 25 collisions over a
    whole trace (measured per station across the test seeds), while the
    fingerprints of an *additive* glitch train — pulses riding the live
    noise floor, invisible to the sample-exact duplicate guard — collide
    with their ring-resident siblings in most tables at once and land at
    60–100+. 30 splits the regimes: clean bit-parity is pinned, and the
    glitch-train spurious stream drops ≥ 10× (vs ~2–3× from the
    saturation quarantine alone). The partner-count ring covers the
    longest smoke trace so counts never recycle mid-test.
    """
    return StreamConfig(block_fingerprints=64,
                        index=StreamIndexConfig(n_buckets=2048,
                                                bucket_cap=8,
                                                occ_slots=4096),
                        stats_warmup_blocks=2, reservoir_rows=1024,
                        reorder_horizon_samples=3000,
                        saturation_limit=10,
                        dup_window_fingerprints=512,
                        occ_limit=30)


def stream_bounded_smoke_config() -> StreamConfig:
    """CPU-scale *bounded* streaming: sliding window + rolling filter.

    Window lengths are sized to the smoke traces (hundreds of
    fingerprints) so tests and benches exercise expiry and several window
    closes without needing hours of synthetic data.
    """
    return StreamConfig(block_fingerprints=64,
                        index=StreamIndexConfig(n_buckets=2048,
                                                bucket_cap=8),
                        stats_warmup_blocks=2, reservoir_rows=1024,
                        window_fingerprints=128,
                        filter_window_fingerprints=64)


def latency_config() -> DetectConfig:
    """Real-time alerting detection config (the e2e hot-path benchmark).

    Small spectral images (8×8) at a 1 s fingerprint lag: per-block
    compute shrinks until the *dispatch pipeline* — not FLOPs — bounds
    end-to-end throughput, which is exactly the regime the fused
    single-dispatch step and the vmapped station pool exist for (a
    monitoring network pushing short blocks for low alert latency cannot
    amortize per-stage dispatch overhead the way a batch backfill can).
    """
    fp = FingerprintConfig(stft_len=100, stft_hop=25, img_freq=8, img_time=8,
                           img_hop=4, top_k=16, mad_sample_rate=1.0)
    return DetectConfig(
        fingerprint=fp,
        lsh=LSHConfig(n_tables=8, n_funcs=4, n_matches=2, bucket_cap=4,
                      min_dt=fp.overlap_fingerprints, occurrence_frac=0.0),
        align=AlignConfig(min_cluster_size=1, min_cluster_sim=4),
    )


def stream_latency_smoke_config() -> StreamConfig:
    """Streaming block for ``latency_config``: 4 fingerprints per step =
    4 s alert latency at the 1 s lag."""
    return StreamConfig(block_fingerprints=4,
                        index=StreamIndexConfig(n_buckets=256, bucket_cap=4),
                        stats_warmup_blocks=4, reservoir_rows=512)


def stream_sharded_smoke_config() -> StreamConfig:
    """Sharded-pool smoke: the bounded streaming config with a larger
    block so each device-side step carries enough per-station work for
    the ``stations`` mesh split to beat single-device ``vmap`` on forced
    host devices (tiny blocks are dispatch-bound and sharding only adds
    transfer overhead). ``sharded`` is on by default in every config —
    this one exists so benches/tests name the sharded regime explicitly
    and get steady blocks past warmup quickly."""
    return StreamConfig(block_fingerprints=128,
                        index=StreamIndexConfig(n_buckets=2048,
                                                bucket_cap=8),
                        stats_warmup_blocks=1, reservoir_rows=1024,
                        sharded=True)


def serve_config():
    """Paper-scale serving tier (ISSUE 7): slots sized so one batched
    ``_serve_step`` dispatch amortizes across a rack of concurrent
    clients, with the admission queue bounded at ~2 s of queue wait at
    the expected service rate — beyond it requests shed instead of
    growing host state without bound. The serving pool refreshes every
    ingest chunk (~9 min of stream per block at the paper lag), so a
    served query never lags the corpus by more than one block.
    """
    from repro.launch.serve_detect import ServeConfig
    return ServeConfig(n_slots=32, max_queue=1024, top_k=64,
                       refresh_every_chunks=1)


def serve_smoke_config():
    """CPU-scale serving tier matching the smoke streaming configs: a
    handful of slots and a queue bound small enough that the overload
    tests/benches actually shed on smoke-sized bursts."""
    from repro.launch.serve_detect import ServeConfig
    return ServeConfig(n_slots=4, max_queue=8, top_k=32,
                       refresh_every_chunks=4)


# Dry-run shapes: (n_chunks, samples_per_chunk). ``station_year`` ≈ one
# station-year of 100 Hz data (3.15e9 samples) in 512 shardable chunks.
SHAPES = {
    "station_year": (512, 6_150_000),
    "station_month": (512, 512_000),
}


def model_flops(shape_name: str) -> float:
    """Algorithmic FLOPs of the fingerprint+hash stages (MFU numerator).

    STFT matmuls + Haar matmuls + Min-Max hash compares; the sort-based
    search is comparison-bound and excluded (consistent with the paper's
    treatment of search as lookup-bound, §6.3).
    """
    n_chunks, chunk = SHAPES[shape_name]
    cfg = config()
    fp = cfg.fingerprint
    nf_frames = (chunk - fp.stft_len) // fp.stft_hop + 1
    n_fp = (nf_frames - fp.img_time) // fp.img_hop + 1
    lo, hi = fp.band_bins
    k_band = hi - lo
    stft = nf_frames * 2 * (2 * fp.stft_len * k_band)
    haar = n_fp * 2 * (fp.img_freq ** 2 * fp.img_time
                       + fp.img_time ** 2 * fp.img_freq)
    lcfg = cfg.lsh
    minmax = n_fp * fp.fp_dim * lcfg.n_hash_fns * 2
    return float(n_chunks) * (stft + haar + minmax)


def input_specs(shape_name: str) -> dict:
    n_chunks, chunk = SHAPES[shape_name]
    cfg = config()
    n_coeff = cfg.fingerprint.n_coeff
    return {
        "waveforms": jax.ShapeDtypeStruct((n_chunks, chunk), jnp.float32),
        "med": jax.ShapeDtypeStruct((n_coeff,), jnp.float32),
        "mad": jax.ShapeDtypeStruct((n_coeff,), jnp.float32),
    }
