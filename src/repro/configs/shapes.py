"""Assigned input-shape sets and ShapeDtypeStruct input specs per cell.

LM shapes (seq_len × global_batch):
  train_4k     4,096 × 256   → train_step
  prefill_32k  32,768 × 32   → serve prefill
  decode_32k   32,768 × 128  → serve decode (1 new token, 32k cache)
  long_500k    524,288 × 1   → serve decode; sub-quadratic archs only

``[audio]``/``[vlm]`` backbones get stub frontends: input_specs provides
precomputed EnCodec token ids / ViT patch embeddings.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, init_cache
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shapes_for(cfg: ModelConfig) -> list[str]:
    """Applicable shape names; long_500k only for sub-quadratic archs
    (full-attention skip recorded in DESIGN.md §Arch-applicability)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        names.append("long_500k")
    return names


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of one cell.

    For train/prefill: the token batch (+ stub frontend tensors).
    For decode: the (B, 1) token plus the pre-filled cache structs.
    """
    spec = LM_SHAPES[shape_name]
    b, s = spec.global_batch, spec.seq_len
    if spec.kind in ("train", "prefill"):
        batch = {"tokens": sds((b, s), jnp.int32)}
        if spec.kind == "train":
            batch["labels"] = sds((b, s), jnp.int32)
            batch["loss_mask"] = sds((b, s), jnp.float32)
        if cfg.frontend == "patch":
            batch["patch_embeds"] = sds((b, cfg.n_patches, cfg.d_model),
                                        jnp.bfloat16)
        return batch
    # decode: tokens + cache
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    return {"tokens": sds((b, 1), jnp.int32), "cache": cache}
