"""Architecture registry: ``--arch <id>`` → config / smoke config / shapes."""
from __future__ import annotations

import importlib

_MODULES = {
    "musicgen-large": "musicgen_large",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "yi-9b": "yi_9b",
    "command-r-35b": "command_r_35b",
    "qwen2.5-14b": "qwen25_14b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "internvl2-1b": "internvl2_1b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "zamba2-1.2b": "zamba2_1p2b",
    "fast_seismic": "fast_seismic",
}

LM_ARCHS = [a for a in _MODULES if a != "fast_seismic"]
ALL_ARCHS = list(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ALL_ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str):
    return _mod(arch).config()


def get_smoke_config(arch: str):
    return _mod(arch).smoke_config()


def get_module(arch: str):
    return _mod(arch)
