"""moonshot-v1-16b-a3b [moe] — kimi/moonlight-style MoE: 64 routed
top-6 (+2 shared, moonlight-style). [hf:moonshotai/Moonlight-16B-A3B; hf].
48L d_model=2048 16H (kv=16) expert d_ff=1408 vocab=163840.
"""
from repro.models.config import ModelConfig

ARCH_ID = "moonshot-v1-16b-a3b"


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", n_layers=48, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=0, vocab_size=163840, n_experts=64,
        n_shared_experts=2, moe_top_k=6, expert_ff=1408)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b-smoke", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=512, n_experts=8,
        n_shared_experts=2, moe_top_k=2, expert_ff=64, attn_q_block=32,
        attn_kv_block=32, loss_seq_chunk=32)
