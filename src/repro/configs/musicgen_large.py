"""musicgen-large [audio] — decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf]. 48L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=2048. Frontend stub: input_specs provides precomputed EnCodec frame
token ids (single-stream; the 4-codebook interleave is upstream of the
backbone). Closest kin to the paper: the fingerprinter descends from audio
fingerprinting (Waveprint).
"""
from repro.models.config import ModelConfig

ARCH_ID = "musicgen-large"


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", n_layers=48, d_model=2048, n_heads=32,
        n_kv_heads=32, d_ff=8192, vocab_size=2048, frontend="audio")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=256, vocab_size=512, frontend="audio",
        attn_q_block=32, attn_kv_block=32, loss_seq_chunk=32)
