"""qwen2.5-14b [dense] — GQA, QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf].
48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
"""
from repro.models.config import ModelConfig

ARCH_ID = "qwen2.5-14b"


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b", n_layers=48, d_model=5120, n_heads=40,
        n_kv_heads=8, d_ff=13824, vocab_size=152064, qkv_bias=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b-smoke", n_layers=2, d_model=160, n_heads=5,
        n_kv_heads=1, d_ff=320, vocab_size=512, qkv_bias=True,
        attn_q_block=32, attn_kv_block=32, loss_seq_chunk=32)
