"""yi-9b [dense] — llama-arch GQA. [arXiv:2403.04652; hf].
48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.models.config import ModelConfig

ARCH_ID = "yi-9b"


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b", n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
        d_ff=11008, vocab_size=64000)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab_size=512, attn_q_block=32,
        attn_kv_block=32, loss_seq_chunk=32)
