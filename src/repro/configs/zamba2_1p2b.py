"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block.
[arXiv:2411.15242; hf]. 38L d_model=2048 32H (kv=32) d_ff=8192
vocab=32000 ssm_state=64. The shared transformer block (attn + d_ff MLP)
is invoked every 6 Mamba2 layers (per-invocation LoRA deltas and the
concat-with-embedding input are simplified away — noted deviations).
Runs long_500k (O(1) SSM state + seq-sharded shared-attn KV).
"""
from repro.models.config import ModelConfig

ARCH_ID = "zamba2-1.2b"


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", n_layers=38, d_model=2048, n_heads=32,
        n_kv_heads=32, d_ff=8192, vocab_size=32000, block_kind="mamba2",
        ssm_state=64, ssm_head_dim=64, ssm_conv=4, ssm_expand=2,
        ssm_chunk=64, shared_attn_every=6, subquadratic=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b-smoke", n_layers=5, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=256, vocab_size=512, block_kind="mamba2",
        ssm_state=16, ssm_head_dim=32, ssm_chunk=16, shared_attn_every=2,
        attn_q_block=32, attn_kv_block=32, loss_seq_chunk=32,
        subquadratic=True)
