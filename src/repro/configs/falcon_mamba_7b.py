"""falcon-mamba-7b [ssm] — mamba1 arch, attention-free.
[arXiv:2410.05355; unverified]. 64L d_model=4096 vocab=65024
ssm_state=16. O(1)-state decode → runs long_500k.
"""
from repro.models.config import ModelConfig

ARCH_ID = "falcon-mamba-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b", n_layers=64, d_model=4096, n_heads=1,
        n_kv_heads=1, d_ff=0, vocab_size=65024, block_kind="mamba1",
        ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_chunk=64,
        subquadratic=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b-smoke", n_layers=2, d_model=128, n_heads=1,
        n_kv_heads=1, d_ff=0, vocab_size=512, block_kind="mamba1",
        ssm_state=8, ssm_chunk=16, loss_seq_chunk=32, subquadratic=True)
