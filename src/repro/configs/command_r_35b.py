"""command-r-35b [dense] — GQA, no-bias.
[hf:CohereForAI/c4ai-command-r-v01; unverified]. 40L d_model=8192 64H
(GQA kv=8) d_ff=22528 vocab=256000. (Parallel attn+FFN blocks are
implemented faithfully — one TP psum per layer; embeddings stay untied —
noted deviation.)
"""
from repro.models.config import ModelConfig

ARCH_ID = "command-r-35b"


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b", n_layers=40, d_model=8192, n_heads=64,
        n_kv_heads=8, d_ff=22528, vocab_size=256000, parallel_block=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b-smoke", n_layers=2, d_model=128, n_heads=8,
        n_kv_heads=2, d_ff=320, vocab_size=512, parallel_block=True,
        attn_q_block=32, attn_kv_block=32, loss_seq_chunk=32)
