"""internvl2-1b [vlm] — InternViT + (qwen2-arch) LM backbone.
[arXiv:2404.16821; hf]. 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655. Frontend stub: input_specs provides precomputed patch
embeddings (B, 256, d_model); a learned projector maps them into the
sequence (first 256 positions).
"""
from repro.models.config import ModelConfig

ARCH_ID = "internvl2-1b"


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b", n_layers=24, d_model=896, n_heads=14,
        n_kv_heads=2, d_ff=4864, vocab_size=151655, qkv_bias=True,
        frontend="patch", n_patches=256)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab_size=512, qkv_bias=True,
        frontend="patch", n_patches=8, attn_q_block=32, attn_kv_block=32,
        loss_seq_chunk=32)
