"""deepseek-moe-16b [moe] — fine-grained MoE: 2 shared + 64 routed
experts, top-6. [arXiv:2401.06066; hf]. 28L d_model=2048 16H (kv=16)
expert d_ff=1408 vocab=102400. (Upstream's dense first layer is folded
into the uniform MoE stack — noted deviation.)
"""
from repro.models.config import ModelConfig

ARCH_ID = "deepseek-moe-16b"


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", n_layers=28, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=0, vocab_size=102400, n_experts=64,
        n_shared_experts=2, moe_top_k=6, expert_ff=1408)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=0, vocab_size=512, n_experts=8,
        n_shared_experts=2, moe_top_k=2, expert_ff=64, attn_q_block=32,
        attn_kv_block=32, loss_seq_chunk=32)
