"""codeqwen1.5-7b [dense] — qwen1.5 arch (QKV bias).
[hf:Qwen/CodeQwen1.5-7B; hf]. 32L d_model=4096 32H (GQA kv=32)
d_ff=13440 vocab=92416.
"""
from repro.models.config import ModelConfig

ARCH_ID = "codeqwen1.5-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=32, d_ff=13440, vocab_size=92416, qkv_bias=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=320, vocab_size=512, qkv_bias=True,
        attn_q_block=32, attn_kv_block=32, loss_seq_chunk=32)
