"""Mesh-aware sharding helpers.

Model code calls ``shard(x, *axes)`` to attach sharding constraints; the
helpers degrade to no-ops when no mesh is active (single-device tests) and
silently drop axes that do not divide the corresponding dimension (e.g.
8 KV heads on a 16-way ``model`` axis → replicated). Axes made manual by a
partial-manual shard_map (train/compression.py) are dropped from specs
inside the manual region via the ``manual_axes`` context.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Sequence

import jax
import numpy as np
from jax.interpreters import pxla
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# mesh axis name of the station-pool shard (stream/fused.py): the leading
# S axis of the stacked FusedState pytree is split over it
STATION_AXIS = "stations"

_MANUAL: contextvars.ContextVar[frozenset] = contextvars.ContextVar(
    "repro_manual_axes", default=frozenset())

_UNEVEN: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_allow_uneven", default=False)

# Layout mode: "tp" (default, megatron TP over 'model') or "fsdp"
# (pure data parallelism over pod×data×model; params fully sharded and
# gathered per use — the §Perf layout for large-batch dense training).
_LAYOUT: contextvars.ContextVar[str] = contextvars.ContextVar(
    "repro_layout", default="tp")


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=True):
    """Version-portable ``shard_map`` (new top-level API vs. experimental).

    jax ≥ 0.5 exposes ``jax.shard_map`` with ``axis_names`` (the manual
    subset) and ``check_vma``; jax 0.4.x only has
    ``jax.experimental.shard_map.shard_map`` with the complementary
    ``auto`` set and ``check_rep``. Call sites use the new-style kwargs.
    """
    if hasattr(jax, "shard_map"):
        import inspect
        sig = inspect.signature(jax.shard_map).parameters
        kw = {}
        if "check_vma" in sig:
            kw["check_vma"] = check_vma
        elif "check_rep" in sig:       # mid-band: top-level API, old kwarg
            kw["check_rep"] = check_vma
        if axis_names is not None:
            if "axis_names" in sig:
                kw["axis_names"] = set(axis_names)
            elif "auto" in sig:
                kw["auto"] = frozenset(mesh.axis_names) \
                    - frozenset(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {"check_rep": check_vma}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


@contextlib.contextmanager
def layout(mode: str):
    assert mode in ("tp", "fsdp"), mode
    tok = _LAYOUT.set(mode)
    try:
        yield
    finally:
        _LAYOUT.reset(tok)


def current_layout() -> str:
    return _LAYOUT.get()


@contextlib.contextmanager
def manual_axes(axes):
    tok = _MANUAL.set(_MANUAL.get() | frozenset(axes))
    try:
        yield
    finally:
        _MANUAL.reset(tok)


def in_manual_region() -> bool:
    """True while tracing inside a partial-manual shard_map region."""
    return bool(_MANUAL.get())


@contextlib.contextmanager
def allow_uneven_sharding():
    """Permit non-divisible dims (≥ axis size) to shard — XLA pads.

    §Perf lever: e.g. qwen2.5's 40 heads on a 16-way model axis would
    otherwise replicate ALL attention compute."""
    tok = _UNEVEN.set(True)
    try:
        yield
    finally:
        _UNEVEN.reset(tok)


def station_mesh(n_stations: int | None = None, *, devices=None,
                 axis: str = STATION_AXIS) -> Mesh | None:
    """Capability probe for the sharded station pool (ISSUE 10).

    Returns a 1-axis ``stations`` mesh over the visible devices when
    sharding the pool can possibly help, and ``None`` otherwise — the
    ``None`` is the signal for callers (``StreamingDetector``, the
    ``pool_step_*_sharded`` entries) to fall back to the single-device
    ``vmap`` pool:

    * one visible device → ``None`` (vmap already is the whole story);
    * fewer than two stations → ``None`` (nothing to split);
    * more devices than stations → the mesh is trimmed to ``n_stations``
      so no device holds an empty shard.

    The hot path runs **fully manual** over this axis with zero
    cross-station collectives, so the probe never needs to check for
    partial-manual ``shard_map`` support (the jaxlib-0.4.x scan/gather
    limitation only bites partial-manual regions).
    """
    devs = list(devices) if devices is not None else jax.devices()
    nd = len(devs)
    if n_stations is not None:
        nd = min(nd, int(n_stations))
    if nd < 2 or (n_stations is not None and n_stations < 2):
        return None
    return Mesh(np.asarray(devs[:nd]), (axis,))


def pool_sharding(mesh: Mesh, *, axis: str = STATION_AXIS) -> NamedSharding:
    """Sharding of a stacked pool pytree: leading (S,) axis split over
    ``stations``, everything else replicated (usable as a pytree-prefix
    sharding for every FusedState leaf)."""
    return NamedSharding(mesh, P(axis))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Fully-replicated placement on ``mesh`` (hash mappings, scalars)."""
    return NamedSharding(mesh, P())


def padded_pool_width(n_stations: int, mesh: Mesh | None, *,
                      axis: str = STATION_AXIS) -> int:
    """Station rows the stacked pool must carry so the leading axis
    divides the mesh: ``n_stations`` rounded up to a multiple of the
    ``stations`` axis size (``n_stations`` unchanged without a mesh).
    The pad rows are throwaway station clones — they step like real
    stations (row-independent math) and their output is never read."""
    if mesh is None or axis not in mesh.shape:
        return int(n_stations)
    d = int(mesh.shape[axis])
    return -(-int(n_stations) // d) * d


def current_mesh() -> Mesh | None:
    """The mesh installed by a ``with mesh:`` context, or None."""
    try:
        m = pxla.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def axis_size(name: str) -> int:
    mesh = current_mesh()
    if mesh is None or name not in mesh.shape:
        return 1
    return mesh.shape[name]


def batch_axes() -> tuple[str, ...]:
    """Mesh axes used for data parallelism.

    TP layout: pod × data. FSDP layout: pod × data × model (the model
    axis joins the batch; tensor-parallel constraints become no-ops)."""
    mesh = current_mesh()
    if mesh is None:
        return ()
    names = (("pod", "data", "model") if _LAYOUT.get() == "fsdp"
             else ("pod", "data"))
    return tuple(a for a in names if a in mesh.shape)


def dp_size() -> int:
    out = 1
    for a in batch_axes():
        out *= axis_size(a)
    return out


def _entry_size(entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return axis_size(entry)
    out = 1
    for a in entry:
        out *= axis_size(a)
    return out


def sanitize_spec(shape: Sequence[int], spec: Sequence) -> P | None:
    """Drop spec entries that don't exist on the mesh or don't divide."""
    mesh = current_mesh()
    if mesh is None:
        return None
    out = []
    for dim, entry in zip(shape, spec):
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        # axis aliases: "vocab" always resolves to the model axis (vocab
        # sharding survives FSDP); a bare "model" entry is a TP usage and
        # drops under the FSDP layout (the axis belongs to the batch there)
        if _LAYOUT.get() == "fsdp":
            axes = tuple("model" if a == "vocab" else a for a in axes
                         if a != "model")
        else:
            axes = tuple("model" if a == "vocab" else a for a in axes)
        # keep the subset of axes present on this mesh (e.g. ("pod","data")
        # degrades to ("data",) on the single-pod mesh); manual axes are
        # invisible to constraints inside shard_map regions
        manual = _MANUAL.get()
        axes = tuple(a for a in axes if a in mesh.shape and a not in manual)
        if not axes:
            out.append(None)
            continue
        if dim % _entry_size(axes) != 0 and not (
                _UNEVEN.get() and dim >= _entry_size(axes)):
            out.append(None)
            continue
        out.append(axes[0] if len(axes) == 1 else axes)
    # pad remaining dims
    out += [None] * (len(shape) - len(out))
    return P(*out)


def shard(x: jax.Array, *spec):
    """with_sharding_constraint that no-ops without a mesh / on misfit.

    Passes a raw PartitionSpec so the constraint resolves against the
    CONTEXT mesh — correct both in plain jit and inside partial-manual
    shard_map regions (where the concrete mesh's axis types mismatch).
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    p = sanitize_spec(x.shape, spec)
    if p is None:
        return x
    return jax.lax.with_sharding_constraint(x, p)


def shard_batch(x: jax.Array, *rest):
    """Shard the leading (batch) dim over pod×data, rest as given."""
    ba = batch_axes()
    if not ba:
        return x
    return shard(x, ba, *rest)
