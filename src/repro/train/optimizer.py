"""AdamW with mixed precision + ZeRO-1 style optimizer-state sharding.

No optax in this environment — the update is hand-rolled. Parameters live
in ``param_dtype`` (bf16 in production); the optimizer state carries an
fp32 master copy plus fp32 moments, all sharded over BOTH the parameter's
TP axes and the ``data`` axis (ZeRO-1): each data shard owns a slice of the
state, which XLA reduces/gathers around the update automatically.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import dist


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    accum_dtype: str = "bfloat16"   # gradient-accumulation dtype


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_frac·lr."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def init_opt_state(params) -> dict:
    # copy=True: the fp32 master must NOT alias fp32 params (donation)
    f32 = lambda t: jax.tree.map(
        lambda x: jnp.array(x, jnp.float32, copy=True), t)
    zeros = lambda t: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {"master": f32(params), "m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, opt_state: dict,
                  cfg: OptimizerConfig) -> tuple[Any, dict, dict]:
    """One AdamW step. grads in any dtype; math in fp32."""
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master, p):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * master
        master_new = master - lr * delta
        return m_new, v_new, master_new, master_new.astype(p.dtype)

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"],
                       opt_state["master"], params)
    m_new = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    v_new = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    master_new = jax.tree.map(lambda t: t[2], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    params_new = jax.tree.map(lambda t: t[3], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"master": master_new, "m": m_new, "v": v_new, "step": step}
    return params_new, new_state, {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------


def zero_sharding_entry(param_spec: tuple, shape: tuple[int, ...],
                        data_axes: tuple[str, ...] = ("data",)) -> tuple:
    """Extend a param's TP spec with ZeRO sharding over ``data``.

    Picks the largest dimension not already sharded whose size divides the
    data-axis product; falls back to the TP spec when none fits.
    """
    spec = list(param_spec) + [None] * (len(shape) - len(param_spec))
    used = {a for e in spec if e is not None
            for a in ((e,) if isinstance(e, str) else e)}
    if any(a in used for a in data_axes):
        return tuple(spec)
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if spec[i] is None:
            spec[i] = data_axes[0] if len(data_axes) == 1 else tuple(data_axes)
            return tuple(spec)
    return tuple(param_spec)


def opt_state_sharding_rules(param_rules, param_shapes_tree) -> dict:
    """Sharding rules for init_opt_state's tree given the param rules."""
    def extend(rule, shp):
        return zero_sharding_entry(tuple(rule), tuple(shp))

    extended = jax.tree.map(
        extend, param_rules, param_shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            e is None or isinstance(e, (str, tuple)) for e in x))
    return {"master": extended, "m": extended, "v": extended,
            "step": ()}
