"""Cross-pod gradient compression (distributed-optimization trick).

The pod axis is DCN (slow links); an fp32 ring all-reduce of the gradients
costs 2×4 bytes/param across it. Here the pod reduction is made EXPLICIT:
a partial-manual ``shard_map`` keeps data/model axes automatic (the inner
computation still SPMD-partitions normally) while the pod axis is manual,
and the gradient exchange becomes an int8 all-gather + local dequant-mean —
(P-1)/P × 1 byte/param of DCN traffic, an ~8× reduction.

Quantization is per-tensor absmax int8 (round-to-nearest). With 2 pods the
quantization error is an unbiased-ish dither on the half-gradient;
EXPERIMENTS.md §Perf carries the convergence check.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import dist


def _quantize(g):
    """Per-tensor absmax int8 quantization with a leading pod-stack axis."""
    gf = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q[None], scale[None]


def compressed_pod_mean(tree):
    """Mean of a gradient pytree across the manual 'pod' axis via int8.

    In-region variant (requires a runtime whose partitioner supports
    collectives inside manual subgroups; jaxlib 0.4.x CPU does not — the
    shard_map wrapper below routes the exchange through a reshard instead).
    """
    def one(g):
        if g.dtype == jnp.int32 or g.ndim == 0:
            return jax.lax.pmean(g, "pod")
        q, scale = _quantize(g)
        qs = jax.lax.all_gather(q[0], "pod")       # int8 on the wire
        ss = jax.lax.all_gather(scale[0], "pod")   # (P,) fp32 scales
        deq = qs.astype(jnp.float32) * ss.reshape(
            (-1,) + (1,) * g.ndim)
        return deq.mean(axis=0).astype(g.dtype)

    return jax.tree.map(one, tree)


def pod_compressed_value_and_grad(loss_fn, mesh, batch_spec_prefix=P("pod")):
    """value_and_grad whose cross-pod gradient exchange is int8.

    ``loss_fn(params, batch) -> scalar`` must compute the mean loss over
    its (pod-local) batch shard. Returns f(params, batch) -> (loss, grads)
    with grads exact over data/model (automatic) and int8-compressed over
    pod (manual).

    The exchange itself happens *outside* the manual region: the partial-
    manual body returns each pod's quantized gradients stacked over a
    leading ``pod``-sharded axis, and a reshard-to-replicated constraint on
    the int8 tensors lowers to exactly the s8 all-gather we want on the DCN
    links (an in-region ``lax.all_gather`` trips the SPMD partitioner's
    manual-subgroup check on current jaxlib).
    """
    def _exempt(leaf) -> bool:
        # integer / scalar grads take the exact pmean path (quantizing an
        # int32 or a lone scalar to absmax-int8 is lossy garbage)
        return jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.integer) \
            or jnp.ndim(leaf) == 0

    def per_pod(params, batch):
        with dist.manual_axes({"pod"}):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            loss = jax.lax.pmean(loss, "pod")
            q = jax.tree.map(
                lambda g: jax.lax.pmean(g, "pod") if _exempt(g)
                else _quantize(g)[0], grads)
            s = jax.tree.map(
                lambda g: jnp.zeros((1,), jnp.float32) if _exempt(g)
                else _quantize(g)[1], grads)
        return loss, q, s

    from jax.sharding import NamedSharding

    def wrapped(params, batch):
        in_specs = (P(), jax.tree.map(lambda _: batch_spec_prefix, batch))
        out_specs = (
            P(),
            jax.tree.map(lambda p: P() if _exempt(p) else P("pod"), params),
            jax.tree.map(lambda p: P() if _exempt(p) else P("pod"), params))
        loss, q, s = dist.shard_map(
            per_pod, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names={"pod"}, check_vma=False)(params, batch)

        def dequant_mean(g, qv, sv):
            if _exempt(g):
                return qv                  # already the exact pod mean
            # (P, *shape) int8 sharded over pod → replicate (s8 all-gather)
            qv = jax.lax.with_sharding_constraint(
                qv, NamedSharding(mesh, P()))
            sv = jax.lax.with_sharding_constraint(
                sv, NamedSharding(mesh, P()))
            deq = qv.astype(jnp.float32) * sv.reshape(
                (-1,) + (1,) * (qv.ndim - 1))
            return deq.mean(axis=0).astype(g.dtype)

        grads = jax.tree.map(dequant_mean, params, q, s)
        return loss, grads

    return wrapped
