"""Cross-pod gradient compression (distributed-optimization trick).

The pod axis is DCN (slow links); an fp32 ring all-reduce of the gradients
costs 2×4 bytes/param across it. Here the pod reduction is made EXPLICIT:
a partial-manual ``shard_map`` keeps data/model axes automatic (the inner
computation still SPMD-partitions normally) while the pod axis is manual,
and the gradient exchange becomes an int8 all-gather + local dequant-mean —
(P-1)/P × 1 byte/param of DCN traffic, an ~8× reduction.

Quantization is per-tensor absmax int8 (round-to-nearest). With 2 pods the
quantization error is an unbiased-ish dither on the half-gradient;
EXPERIMENTS.md §Perf carries the convergence check.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import dist


def compressed_pod_mean(tree):
    """Mean of a gradient pytree across the manual 'pod' axis via int8."""
    def one(g):
        if g.dtype == jnp.int32 or g.ndim == 0:
            return jax.lax.pmean(g, "pod")
        gf = g.astype(jnp.float32)
        scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        qs = jax.lax.all_gather(q, "pod")          # int8 on the wire
        ss = jax.lax.all_gather(scale, "pod")      # (P,) fp32 scales
        deq = qs.astype(jnp.float32) * ss.reshape(
            (-1,) + (1,) * g.ndim)
        return deq.mean(axis=0).astype(g.dtype)

    return jax.tree.map(one, tree)


def pod_compressed_value_and_grad(loss_fn, mesh, batch_spec_prefix=P("pod")):
    """value_and_grad whose cross-pod gradient exchange is int8.

    ``loss_fn(params, batch) -> scalar`` must compute the mean loss over
    its (pod-local) batch shard. Returns f(params, batch) -> (loss, grads)
    with grads exact over data/model (automatic) and int8-compressed over
    pod (manual).
    """
    def per_pod(params, batch):
        with dist.manual_axes({"pod"}):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = compressed_pod_mean(grads)
            loss = jax.lax.pmean(loss, "pod")
        return loss, grads

    def wrapped(params, batch):
        in_specs = (P(), jax.tree.map(lambda _: batch_spec_prefix, batch))
        out_specs = (P(), jax.tree.map(lambda _: P(), params))
        return jax.shard_map(per_pod, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names={"pod"},
                             check_vma=False)(params, batch)

    return wrapped
