"""Straggler / hang watchdog for the training loop.

On a real cluster every host runs this around its step function; hosts
whose step-time EMA exceeds μ + k·σ of the fleet (or a hard hang timeout)
trigger the policy callback — the job controller then checkpoints and
reschedules (DESIGN.md §4). Clock-injectable for deterministic tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class WatchdogConfig:
    ema_alpha: float = 0.1
    straggler_factor: float = 2.0     # flag if step > factor × EMA
    hang_timeout_s: float = 300.0     # flag if step exceeds hard timeout
    min_samples: int = 5


class StepWatchdog:
    def __init__(self, cfg: WatchdogConfig | None = None,
                 on_straggler: Callable[[dict], None] | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg or WatchdogConfig()
        self.on_straggler = on_straggler or (lambda info: None)
        self.clock = clock
        self.ema: float | None = None
        self.n = 0
        self.events: list[dict] = []
        self._t0: float | None = None

    def step_start(self):
        self._t0 = self.clock()

    def step_end(self) -> float:
        assert self._t0 is not None, "step_start not called"
        dt = self.clock() - self._t0
        self._t0 = None
        self.n += 1
        flagged = False
        if dt > self.cfg.hang_timeout_s:
            flagged = True
            reason = "hang"
        elif (self.ema is not None and self.n > self.cfg.min_samples
                and dt > self.cfg.straggler_factor * self.ema):
            flagged = True
            reason = "straggler"
        if flagged:
            info = {"step_time_s": dt, "ema_s": self.ema, "reason": reason,
                    "step": self.n}
            self.events.append(info)
            self.on_straggler(info)
        a = self.cfg.ema_alpha
        self.ema = dt if self.ema is None else (1 - a) * self.ema + a * dt
        return dt
