"""Fault-tolerant checkpointing with elastic restore.

Layout: <dir>/step_<N>/ holding ``arrays.npz`` (flattened key-paths) and
``manifest.json`` (tree structure, dtypes, step, data-iterator state).
Writes are atomic (tmp dir + fsync + rename), optionally off the critical
path (snapshot-to-host then background thread). Restore rebuilds the tree
and ``device_put``s against ANY mesh/sharding — checkpoints are
mesh-elastic, so node-count changes survive restarts (DESIGN.md §4).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import tempfile
import threading
from typing import Any, Callable

import jax
import numpy as np

_SEP = "\x1f"  # unit separator: safe key-path join


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(ckpt_dir: str, step: int, state, *, extra: dict | None
                    = None, background: bool = False, keep: int = 3):
    """Snapshot ``state`` and write step_<N> atomically.

    With ``background=True`` the device→host snapshot happens inline (fast)
    and serialization runs on a thread; returns the Thread (join() to wait).
    """
    flat = _flatten(state)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    meta = {
        "step": int(step),
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                 for k, v in host.items()},
        "extra": extra or {},
    }

    def write():
        base = pathlib.Path(ckpt_dir)
        base.mkdir(parents=True, exist_ok=True)
        tmp = tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=base)
        try:
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{k: v for k, v in host.items()})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            final = base / f"step_{step:08d}"
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
        finally:
            if os.path.isdir(tmp):
                shutil.rmtree(tmp, ignore_errors=True)
        _prune(ckpt_dir, keep)

    if background:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(list_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(pathlib.Path(ckpt_dir) / f"step_{s:08d}",
                      ignore_errors=True)


def list_steps(ckpt_dir: str) -> list[int]:
    base = pathlib.Path(ckpt_dir)
    if not base.is_dir():
        return []
    out = []
    for p in base.iterdir():
        if p.name.startswith("step_") and (p / "manifest.json").exists():
            out.append(int(p.name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_flat(ckpt_dir: str, *, step: int | None = None
                 ) -> tuple[dict[str, np.ndarray], dict, int]:
    """Rebuild the flat key-path → host array dict without a target oracle.

    For states whose leaf *shapes* are part of the state (e.g. a streaming
    ingest ring whose pending-sample buffer length varies), the caller
    cannot supply a ShapeDtypeStruct pytree up front; the manifest itself
    is the shape oracle. Returns (arrays, extra-metadata, step).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    meta = json.loads((d / "manifest.json").read_text())
    with np.load(d / "arrays.npz") as arrays:
        out = {k: arrays[k] for k in arrays.files}
    return out, meta.get("extra", {}), int(step)


def restore_checkpoint(ckpt_dir: str, target, *, step: int | None = None,
                       shardings=None) -> tuple[Any, dict]:
    """Rebuild ``target``-structured state from disk.

    ``target``: pytree of arrays or ShapeDtypeStructs (structure/dtype
    oracle). ``shardings``: optional matching pytree of NamedShardings —
    arrays are device_put against it (elastic re-shard: the checkpoint
    carries no device topology, so a pool saved on 8 devices lands on
    whatever mesh the restoring process holds). A single ``Sharding``
    instance broadcasts to every leaf — the common case for a uniformly
    sharded state such as the stacked station pool. Returns
    (state, extra-metadata).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    meta = json.loads((d / "manifest.json").read_text())
    arrays = np.load(d / "arrays.npz")

    flat_target = _flatten(target)
    if isinstance(shardings, jax.sharding.Sharding):
        flat_shardings = {k: shardings for k in flat_target}
    else:
        flat_shardings = _flatten(shardings) if shardings is not None else {}
    rebuilt = {}
    for key, ref in flat_target.items():
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"target {ref.shape}")
        arr = arr.astype(ref.dtype)
        sh = flat_shardings.get(key)
        rebuilt[key] = jax.device_put(arr, sh) if sh is not None \
            else jax.device_put(arr)

    leaves_paths = jax.tree_util.tree_flatten_with_path(target)
    keys_in_order = [
        _SEP.join(str(getattr(p, "key", getattr(p, "idx",
                                                getattr(p, "name", p))))
                  for p in path)
        for path, _ in leaves_paths[0]]
    state = jax.tree_util.tree_unflatten(
        leaves_paths[1], [rebuilt[k] for k in keys_in_order])
    return state, meta.get("extra", {})
