"""Train-step construction: microbatched grad accumulation + AdamW.

``make_train_step`` builds a single jittable step:
  batch (B, S) → shard-aligned microbatch split → lax.scan of
  value_and_grad over microbatches (accumulating in ``accum_dtype``) →
  global-norm clip → AdamW → new state.

The microbatch split keeps the batch dim sharded over pod×data at every
step (reshape is shard-aligned: B is laid out as [dp, n_mb, local]).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import dist
from repro.models import ModelConfig, lm_loss
from repro.train.optimizer import (OptimizerConfig, apply_updates,
                                   init_opt_state)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: dict
    step: jax.Array


def init_train_state(key: jax.Array, cfg: ModelConfig) -> TrainState:
    from repro.models import init_params
    params = init_params(key, cfg)
    return TrainState(params=params, opt=init_opt_state(params),
                      step=jnp.zeros((), jnp.int32))


def microbatch_split(batch: dict, n_mb: int, dp: int) -> dict:
    """(B, ...) → (n_mb, B/n_mb, ...) with dim1 still sharded over dp.

    Requires B % (dp * n_mb) == 0. Layout: B = [dp, n_mb, local] so the
    reshape/transpose never crosses shard boundaries.
    """
    def split(x):
        b = x.shape[0]
        assert b % (dp * n_mb) == 0, (b, dp, n_mb)
        local = b // (dp * n_mb)
        y = x.reshape(dp, n_mb, local, *x.shape[1:])
        y = jnp.swapaxes(y, 0, 1)  # (n_mb, dp, local, ...)
        y = dist.shard(y, None, ("pod", "data"), *([None] * (x.ndim - 1)))
        return y.reshape(n_mb, dp * local, *x.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    n_microbatches: int = 1,
                    attn_impl: str = "masked",
                    grad_reducer: Callable | None = None,
                    accum_mode: str = "scan_grads",
                    shard_grads_like_opt: bool = False):
    """Returns step(state, batch) -> (state, metrics). jit-ready.

    ``accum_mode``:
      * "scan_grads" — value_and_grad per microbatch, accumulate (the
        classic pattern; XLA reduces grads over data ONCE PER MICROBATCH);
      * "grad_of_scan" — differentiate the scanned total loss; backward
        carries partial-sum grads so the data-axis reduction happens once
        per STEP (§Perf lever: ~n_microbatches× less gradient traffic).
    ``shard_grads_like_opt``: constrain grads to the ZeRO-sharded optimizer
    layout before the update → the reduction lowers to reduce-scatter
    (half the ring traffic) and the update runs data-sharded.
    ``grad_reducer``: optional hook on the accumulated grads (e.g. the
    cross-pod int8 compressed all-reduce in train/compression.py).
    """
    accum_dt = jnp.dtype(opt_cfg.accum_dtype)

    def loss_fn(params, mb):
        loss, metrics = lm_loss(params, mb, cfg, impl=attn_impl)
        return loss, metrics

    def _shard_like_opt(grads):
        if not shard_grads_like_opt:
            return grads
        from repro.models import param_sharding_rules
        from repro.train.optimizer import zero_sharding_entry
        rules = param_sharding_rules(cfg)

        def walk(rule, g):
            if isinstance(rule, tuple):
                spec = zero_sharding_entry(rule, g.shape)
                return dist.shard(g, *spec)
            return {k: walk(rule[k], g[k]) for k in rule}

        return walk(rules, grads)

    def step(state: TrainState, batch: dict):
        dp = max(dist.dp_size(), 1)
        n_mb = n_microbatches
        mbs = microbatch_split(batch, n_mb, dp) if n_mb > 1 else \
            jax.tree.map(lambda x: x[None], batch)

        if accum_mode == "grad_of_scan":
            def total_loss(params):
                def body(carry, mb):
                    loss, _ = loss_fn(params, mb)
                    return carry + loss, None
                body = jax.checkpoint(body, prevent_cse=False)
                total, _ = jax.lax.scan(
                    body, jnp.zeros((), jnp.float32), mbs)
                return total / n_mb

            loss_mean, grads = jax.value_and_grad(total_loss)(state.params)
            loss_sum = loss_mean * n_mb
            grads = _shard_like_opt(grads)
        else:
            grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

            def micro_step(carry, mb):
                acc, loss_sum = carry
                (loss, _), grads = grad_fn(state.params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(accum_dt), acc, grads)
                return (acc, loss_sum + loss), None

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dt), state.params)
            (grads, loss_sum), _ = jax.lax.scan(
                micro_step, (acc0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(
                lambda g: g.astype(jnp.float32) * (1.0 / n_mb), grads)
            grads = _shard_like_opt(grads)
        if grad_reducer is not None:
            grads = grad_reducer(grads)
        params, opt, opt_metrics = apply_updates(state.params, grads,
                                                 state.opt, opt_cfg)
        metrics = {"loss": loss_sum / n_mb, **opt_metrics}
        return TrainState(params=params, opt=opt, step=state.step + 1), \
            metrics

    return step
