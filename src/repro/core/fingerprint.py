"""Fingerprint extraction (paper §5): waveform → binary fingerprints.

Chain (Figure 3): spectrogram → banded spectral images → 2-D Haar wavelet →
median/MAD normalization (sampled, §5.2) → top-K most anomalous coefficients
→ sign binarization (2 bits per coefficient).

The bandpass filter is applied *inside* the fingerprinter by cutting the
spectrogram at the band corners (the paper's §6.5 extension), plus an
optional time-domain windowed-sinc bandpass for the raw trace.

All steps are jit-friendly with static shapes; the heavy steps dispatch to
Pallas kernels (``use_pallas=True``) or their jnp oracles.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.ref import dft_matrices
from repro.utils import pack_bits


@dataclasses.dataclass(frozen=True)
class FingerprintConfig:
    """Defaults give the paper's 8192-dim fingerprints at 100 Hz."""

    fs: float = 100.0
    # STFT
    stft_len: int = 200          # 2 s analysis window
    stft_hop: int = 25           # 0.25 s hop
    # bandpass (paper evaluation: 3–20 Hz on the NZ dataset)
    band_lo_hz: float = 3.0
    band_hi_hz: float = 20.0
    time_domain_bandpass: bool = False   # optional windowed-sinc prefilter
    bp_taps: int = 255
    # spectral images
    img_freq: int = 32           # freq bins after pooling (power of two)
    img_time: int = 128          # spectrogram frames per image (power of two)
    img_hop: int = 8             # frames between fingerprints (2 s lag)
    # fingerprint
    top_k: int = 400             # most anomalous wavelet coefficients kept
    mad_sample_rate: float = 0.1  # §5.2 MAD-via-sampling
    use_pallas: bool = False

    @property
    def n_rfft(self) -> int:
        return self.stft_len // 2 + 1

    @property
    def band_bins(self) -> tuple[int, int]:
        """[lo, hi) rfft bin range kept by the band filter."""
        lo = int(math.ceil(self.band_lo_hz * self.stft_len / self.fs))
        hi = int(math.floor(self.band_hi_hz * self.stft_len / self.fs)) + 1
        lo = max(0, min(lo, self.n_rfft - 1))
        hi = max(lo + 1, min(hi, self.n_rfft))
        return lo, hi

    @property
    def n_coeff(self) -> int:
        return self.img_freq * self.img_time

    @property
    def fp_dim(self) -> int:
        return 2 * self.n_coeff  # sign encoding: 2 bits / coefficient

    @property
    def window_samples(self) -> int:
        """Raw samples spanned by one fingerprint."""
        return (self.img_time - 1) * self.stft_hop + self.stft_len

    @property
    def lag_samples(self) -> int:
        return self.img_hop * self.stft_hop

    def n_fingerprints(self, n_samples: int) -> int:
        nf = self.n_frames(n_samples)
        return max(0, (nf - self.img_time) // self.img_hop + 1)

    def n_frames(self, n_samples: int) -> int:
        return max(0, (n_samples - self.stft_len) // self.stft_hop + 1)

    @property
    def overlap_fingerprints(self) -> int:
        """Adjacent fingerprints sharing samples (self-match exclusion)."""
        return self.img_time // self.img_hop

    @property
    def halo_samples(self) -> int:
        """Samples a chunk boundary must overlap so that fingerprints are
        sample-exact across a chunked/streaming split (window minus lag)."""
        return self.window_samples - self.lag_samples

    def block_samples(self, n_fingerprints: int) -> int:
        """Samples spanned by a block of ``n_fingerprints`` consecutive
        fingerprints (the streaming ingest unit)."""
        return (n_fingerprints - 1) * self.lag_samples + self.window_samples


# ---------------------------------------------------------------------------
# framing + optional time-domain bandpass
# ---------------------------------------------------------------------------


def frame(x: jax.Array, frame_len: int, hop: int) -> jax.Array:
    """(T,) → (n_frames, frame_len) strided framing via gather."""
    n = max(0, (x.shape[-1] - frame_len) // hop + 1)
    idx = jnp.arange(n)[:, None] * hop + jnp.arange(frame_len)[None, :]
    return x[idx]


def bandpass_kernel(cfg: FingerprintConfig) -> np.ndarray:
    """Windowed-sinc FIR bandpass taps (no scipy dependency)."""
    nt = cfg.bp_taps
    t = np.arange(nt) - (nt - 1) / 2.0
    def lp(fc):
        h = np.sinc(2 * fc / cfg.fs * t) * (2 * fc / cfg.fs)
        return h * np.hamming(nt)
    h = lp(cfg.band_hi_hz) - lp(cfg.band_lo_hz)
    return h.astype(np.float32)


def bandpass(x: jax.Array, cfg: FingerprintConfig) -> jax.Array:
    taps = jnp.asarray(bandpass_kernel(cfg))
    return jnp.convolve(x, taps, mode="same")


# ---------------------------------------------------------------------------
# spectrogram + spectral images
# ---------------------------------------------------------------------------


def _pool_matrix(n_in: int, n_out: int) -> np.ndarray:
    """Average-pooling matrix (n_in, n_out) with near-equal bin spans."""
    edges = np.linspace(0, n_in, n_out + 1)
    m = np.zeros((n_in, n_out), np.float32)
    for j in range(n_out):
        lo, hi = edges[j], edges[j + 1]
        idx = np.arange(int(np.floor(lo)), int(np.ceil(hi)))
        for i in idx:
            w = min(hi, i + 1) - max(lo, i)
            if w > 0:
                m[i, j] = w
    m /= m.sum(axis=0, keepdims=True)
    return m


def spectrogram(x: jax.Array, cfg: FingerprintConfig) -> jax.Array:
    """(T,) waveform → (n_frames, banded_bins) power spectrogram."""
    if cfg.time_domain_bandpass:
        x = bandpass(x, cfg)
    frames = frame(x, cfg.stft_len, cfg.stft_hop)
    lo, hi = cfg.band_bins
    dr, di = dft_matrices(cfg.stft_len, cfg.n_rfft)
    window = jnp.asarray(np.hanning(cfg.stft_len).astype(np.float32))
    # Band cut at the fingerprinter (paper §6.5): only [lo, hi) columns.
    spec = ops.stft_mag(frames, window, jnp.asarray(dr[:, lo:hi]),
                        jnp.asarray(di[:, lo:hi]), use_pallas=cfg.use_pallas)
    return spec


def spectral_images(spec: jax.Array, cfg: FingerprintConfig) -> jax.Array:
    """(n_frames, B) spectrogram → (n_images, img_freq, img_time)."""
    n_frames, b = spec.shape
    pool = jnp.asarray(_pool_matrix(b, cfg.img_freq))
    pooled = spec @ pool  # (n_frames, img_freq)
    n_img = (n_frames - cfg.img_time) // cfg.img_hop + 1
    idx = (jnp.arange(n_img)[:, None] * cfg.img_hop
           + jnp.arange(cfg.img_time)[None, :])
    imgs = pooled[idx]  # (n_img, img_time, img_freq)
    return jnp.swapaxes(imgs, 1, 2)  # (n_img, img_freq, img_time)


# ---------------------------------------------------------------------------
# wavelet + MAD normalization (§5.2) + top-K binarization
# ---------------------------------------------------------------------------


def wavelet_coeffs(imgs: jax.Array, cfg: FingerprintConfig) -> jax.Array:
    """(N, F, T) → (N, F*T) Haar coefficients."""
    coeffs = ops.haar2d(imgs, use_pallas=cfg.use_pallas)
    return coeffs.reshape(imgs.shape[0], -1)


def mad_stats(coeffs: jax.Array, sample_rate: float,
              key: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Median + MAD per coefficient, estimated from a row sample (§5.2).

    sample_rate == 1.0 reproduces the exact two-pass statistics.
    """
    n = coeffs.shape[0]
    if sample_rate >= 1.0:
        sample = coeffs
    else:
        m = max(2, int(round(n * sample_rate)))
        rows = jax.random.choice(key, n, shape=(m,), replace=False)
        sample = coeffs[rows]
    med = jnp.median(sample, axis=0)
    mad = jnp.median(jnp.abs(sample - med[None, :]), axis=0)
    return med, mad


def mad_normalize(coeffs: jax.Array, med: jax.Array,
                  mad: jax.Array) -> jax.Array:
    return (coeffs - med[None, :]) / (mad[None, :] + 1e-9)


def topk_binarize(z: jax.Array, cfg: FingerprintConfig) -> jax.Array:
    """Keep top-K |z| per row; encode signs as 2 bits (paper step 4-5).

    Returns bool (N, 2*C): even positions = (coeff in top-K and > 0),
    odd positions = (coeff in top-K and < 0).
    """
    a = jnp.abs(z)
    kth = jax.lax.top_k(a, cfg.top_k)[0][:, -1]  # (N,)
    mask = a >= kth[:, None]
    pos = mask & (z > 0)
    neg = mask & (z < 0)
    inter = jnp.stack([pos, neg], axis=-1)  # (N, C, 2)
    return inter.reshape(z.shape[0], -1)


# ---------------------------------------------------------------------------
# end-to-end
# ---------------------------------------------------------------------------


def coeffs_from_waveform(x: jax.Array, cfg: FingerprintConfig) -> jax.Array:
    """Waveform (T,) → raw Haar coefficients (N, n_coeff).

    The normalization-free front half of the pipeline; streaming ingest
    calls this per block to feed its running median/MAD estimator before
    binarization (the §5.2 two-pass structure made incremental).
    """
    spec = spectrogram(x, cfg)
    imgs = spectral_images(spec, cfg)
    return wavelet_coeffs(imgs, cfg)


def binarize_coeffs(coeffs: jax.Array, cfg: FingerprintConfig,
                    med_mad: tuple[jax.Array, jax.Array]
                    ) -> tuple[jax.Array, jax.Array]:
    """(N, n_coeff) coefficients + (med, mad) → (bits, packed) fingerprints."""
    z = mad_normalize(coeffs, *med_mad)
    bits = topk_binarize(z, cfg)
    return bits, pack_bits(bits)


def fingerprints_from_waveform(
    x: jax.Array, cfg: FingerprintConfig, *, key: jax.Array | None = None,
    med_mad: tuple[jax.Array, jax.Array] | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Waveform (T,) → (fingerprints bool (N, fp_dim), packed uint32).

    If ``med_mad`` is given, those statistics are used (the paper's two-pass
    structure: stats once, then partition-parallel normalization).
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    coeffs = coeffs_from_waveform(x, cfg)
    if med_mad is None:
        med_mad = mad_stats(coeffs, cfg.mad_sample_rate, key)
    return binarize_coeffs(coeffs, cfg, med_mad)
