"""Spatiotemporal alignment (paper §7): triplets → earthquake detections.

Channel level: sort-merge-reduce of per-channel (dt, idx1, sim) triplets.
Station level: gap-tolerant clustering along similarity-matrix diagonals,
with a single merge pass across adjacent diagonals.
Network level: association across stations using the physical invariance of
inter-event time (Figure 9): groups sharing dt (±tol) and onset (±tol) at
≥ ``min_stations`` distinct stations become detections.

On-device the paper's out-of-core sort (§7.2) becomes ``lax.sort`` + segment
reductions (the pod's aggregate HBM replaces single-node disk; DESIGN.md
§3.6); ``align_streamed`` keeps a host-side external-merge path for outputs
larger than memory.
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
import os
import tempfile
from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lsh import INVALID, Pairs
from repro.utils import segment_ids_from_starts, segment_starts


@dataclasses.dataclass(frozen=True)
class AlignConfig:
    channel_threshold: int = 4     # combined-sim threshold after merge
    gap: int = 10                  # max idx1 gap within a diagonal cluster
    dt_merge_tol: int = 2          # adjacent-diagonal merge distance
    min_cluster_size: int = 2      # prune small clusters
    min_cluster_sim: int = 6
    dt_tol: int = 2                # network: inter-event-time tolerance
    onset_tol: int = 30            # network: arrival-window tolerance
    min_stations: int = 2
    # network groups start on *consecutive* deltas, so a chain of onsets
    # each within onset_tol can link events spanning many tolerances into
    # one group. The cap bounds a group's onset span (> 0); chains beyond
    # it are dropped as physically implausible — no single origin produces
    # arrivals that far apart (the locate tier's moveout-consistency
    # check is the model-based version of the same bound). 0 = unbounded.
    max_group_extent: int = 0


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Events:
    """Per-station candidate events (masked). onset/dt in fingerprint lags."""

    dt: jax.Array
    onset: jax.Array
    extent: jax.Array     # idx_max - idx_min of the cluster
    size: jax.Array       # similar-pair count in the cluster
    score: jax.Array      # summed similarity
    valid: jax.Array

    def count(self) -> jax.Array:
        return self.valid.sum()


# ---------------------------------------------------------------------------
# channel level
# ---------------------------------------------------------------------------


def _sort_triplets(dt, idx1, sim, valid):
    k1 = jnp.where(valid, dt, INVALID)
    k2 = jnp.where(valid, idx1, INVALID)
    return jax.lax.sort((k1, k2, sim, valid.astype(jnp.int32)), num_keys=2)


@functools.partial(jax.jit, static_argnames=("threshold",))
def merge_channels(triplets: Sequence[tuple], threshold: int) -> Pairs:
    """Sum similarity of identical (dt, idx1) across channels; threshold.

    ``triplets``: sequence of (dt, idx1, sim, valid) arrays per channel.
    Implements the paper's sort → merge → reduce with the combined-matrix
    threshold (§7.1 channel level).
    """
    dt = jnp.concatenate([t[0] for t in triplets])
    idx1 = jnp.concatenate([t[1] for t in triplets])
    sim = jnp.concatenate([t[2] for t in triplets])
    valid = jnp.concatenate([t[3].astype(bool) for t in triplets])
    dt_s, idx_s, sim_s, val_s = _sort_triplets(dt, idx1, sim, valid)
    p = dt_s.shape[0]
    starts = segment_starts(dt_s) | segment_starts(idx_s)
    seg = segment_ids_from_starts(starts)
    tot = jax.ops.segment_sum(jnp.where(val_s > 0, sim_s, 0), seg,
                              num_segments=p)
    keep = starts & (val_s > 0) & (tot[seg] >= threshold)
    idx2 = jnp.where(keep, idx_s + dt_s, INVALID)
    return Pairs(idx1=jnp.where(keep, idx_s, INVALID), idx2=idx2,
                 sim=jnp.where(keep, tot[seg], 0), valid=keep)


# ---------------------------------------------------------------------------
# station level
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg",))
def cluster_station(pairs: Pairs, cfg: AlignConfig) -> Events:
    """Cluster triplets along diagonals into candidate events (§7.1/7.2).

    Stage 1: sort by (dt, idx1); a new cluster starts on a dt change or an
    idx1 gap > ``gap`` (pure local boundary ⇒ no sequential scan; the
    paper's partition-point search degenerates to these boundaries).
    Stage 2: one merge pass over clusters sorted by (idx_min, dt), joining
    clusters within ``dt_merge_tol`` diagonals whose idx ranges are within
    ``gap`` (single-pass approximation of the paper's iterative merge).
    """
    dt, idx1 = pairs.dt, pairs.idx1
    sim, valid = pairs.sim, pairs.valid
    dt_s, idx_s, sim_s, val_s = _sort_triplets(dt, idx1, sim, valid)
    p = dt_s.shape[0]

    # --- stage 1: per-diagonal gap clustering
    prev_dt = jnp.concatenate([jnp.array([INVALID]), dt_s[:-1]])
    prev_ix = jnp.concatenate([jnp.array([INVALID]), idx_s[:-1]])
    new = ((dt_s != prev_dt)
           | ((idx_s - prev_ix) > cfg.gap)
           | (val_s == 0))
    cid = segment_ids_from_starts(new)
    w = (val_s > 0).astype(jnp.int32)
    c_count = jax.ops.segment_sum(w, cid, num_segments=p)
    c_score = jax.ops.segment_sum(jnp.where(val_s > 0, sim_s, 0), cid,
                                  num_segments=p)
    c_dt = jax.ops.segment_min(jnp.where(val_s > 0, dt_s, INVALID), cid,
                               num_segments=p)
    c_imin = jax.ops.segment_min(jnp.where(val_s > 0, idx_s, INVALID), cid,
                                 num_segments=p)
    c_imax = jax.ops.segment_max(jnp.where(val_s > 0, idx_s, -1), cid,
                                 num_segments=p)
    c_valid = c_count > 0

    # --- stage 2: adjacent-diagonal merge (sort clusters by idx_min, dt)
    k1 = jnp.where(c_valid, c_imin, INVALID)
    k2 = jnp.where(c_valid, c_dt, INVALID)
    s_imin, s_dt, s_imax, s_count, s_score, s_val = jax.lax.sort(
        (k1, k2, c_imax, c_count, c_score, c_valid.astype(jnp.int32)),
        num_keys=2)
    pdt = jnp.concatenate([jnp.array([INVALID]), s_dt[:-1]])
    pimax = jnp.concatenate([jnp.array([-INVALID]), s_imax[:-1]])
    sep = ((jnp.abs(s_dt - pdt) > cfg.dt_merge_tol)
           | (s_imin > pimax + cfg.gap)
           | (s_val == 0))
    gid = segment_ids_from_starts(sep)
    g_count = jax.ops.segment_sum(jnp.where(s_val > 0, s_count, 0), gid,
                                  num_segments=p)
    g_score = jax.ops.segment_sum(jnp.where(s_val > 0, s_score, 0), gid,
                                  num_segments=p)
    g_dt = jax.ops.segment_min(jnp.where(s_val > 0, s_dt, INVALID), gid,
                               num_segments=p)
    g_imin = jax.ops.segment_min(jnp.where(s_val > 0, s_imin, INVALID), gid,
                                 num_segments=p)
    g_imax = jax.ops.segment_max(jnp.where(s_val > 0, s_imax, -1), gid,
                                 num_segments=p)
    rep = sep & (s_val > 0)
    keep = (rep & (g_count[gid] >= cfg.min_cluster_size)
            & (g_score[gid] >= cfg.min_cluster_sim))
    return Events(dt=jnp.where(keep, g_dt[gid], INVALID),
                  onset=jnp.where(keep, g_imin[gid], INVALID),
                  extent=jnp.where(keep, g_imax[gid] - g_imin[gid], 0),
                  size=jnp.where(keep, g_count[gid], 0),
                  score=jnp.where(keep, g_score[gid], 0),
                  valid=keep)


# ---------------------------------------------------------------------------
# network level
# ---------------------------------------------------------------------------


def _segment_or(flags: jax.Array, words: jax.Array) -> jax.Array:
    """Running bitwise-OR of ``words`` within segments started by ``flags``.

    Classic segmented-scan monoid over (flag, value) pairs: a right
    operand that starts a segment resets the carry, so the OR never leaks
    across segment boundaries. Returns the per-row prefix OR; the full
    segment OR sits at each segment's last row.
    """

    def op(a, b):
        fa, va = a
        fb, vb = b
        return fa | fb, jnp.where(fb[:, None], vb, va | vb)

    _, run = jax.lax.associative_scan(op, (flags, words))
    return run


@functools.partial(jax.jit, static_argnames=("cfg", "n_stations",
                                             "with_onsets"))
def associate_network(events: Sequence[Events], cfg: AlignConfig,
                      n_stations: int, with_onsets: bool = False) -> dict:
    """Group per-station events by (dt, onset); require ≥ min_stations.

    Exploits the inter-event-time invariance (Figure 9): the same pair of
    reoccurring earthquakes shows the same dt at every station, with close
    onsets. Station multiplicity uses packed int32 bitmask words (32
    stations per word, ``ceil(S/32)`` words per row) segment-OR'd and
    popcounted — O(p·⌈S/32⌉) memory with no station cap, so the sharded
    100s-of-stations pool feeds through the same path.

    ``max_group_extent`` > 0 drops groups whose onset span exceeds it
    (tolerance-chaining bound; see AlignConfig). ``with_onsets`` adds the
    dense per-group (p, S) station onset / score matrices the locate tier
    stacks over — opt-in because they are the one O(p·S) output here.
    """
    if n_stations <= 0:
        raise ValueError(f"n_stations must be positive, got {n_stations}")
    if len(events) != n_stations:
        raise ValueError(f"got {len(events)} per-station Events for "
                         f"n_stations={n_stations}")
    dt = jnp.concatenate([e.dt for e in events])
    onset = jnp.concatenate([e.onset for e in events])
    score = jnp.concatenate([e.score for e in events])
    valid = jnp.concatenate([e.valid for e in events])
    sid = jnp.concatenate([
        jnp.full(e.dt.shape, i, jnp.int32) for i, e in enumerate(events)])
    p = dt.shape[0]
    k1 = jnp.where(valid, dt, INVALID)
    k2 = jnp.where(valid, onset, INVALID)
    dt_s, on_s, sc_s, sid_s, val_s = jax.lax.sort(
        (k1, k2, score, sid, valid.astype(jnp.int32)), num_keys=2)
    pdt = jnp.concatenate([jnp.array([INVALID]), dt_s[:-1]])
    pon = jnp.concatenate([jnp.array([INVALID]), on_s[:-1]])
    new = ((jnp.abs(dt_s - pdt) > cfg.dt_tol)
           | (jnp.abs(on_s - pon) > cfg.onset_tol)
           | (val_s == 0))
    gid = segment_ids_from_starts(new)
    # packed station bitmask: word w of row r holds bit (sid mod 32) iff
    # sid div 32 == w. Rows are gid-contiguous after the sort, so a
    # segmented prefix-OR + the segment's last row gives the group's
    # station set; popcount sums the multiplicity.
    n_words = -(-n_stations // 32)
    bit = jnp.where(val_s > 0,
                    jnp.left_shift(jnp.uint32(1),
                                   (sid_s % 32).astype(jnp.uint32)),
                    jnp.uint32(0))
    words = jnp.where((sid_s // 32)[:, None]
                      == jnp.arange(n_words, dtype=sid_s.dtype)[None, :],
                      bit[:, None], jnp.uint32(0))
    run_or = _segment_or(new, words)
    last = jnp.clip(jax.ops.segment_max(jnp.arange(p), gid, num_segments=p),
                    0, p - 1)
    n_st = jax.lax.population_count(run_or[last]).sum(
        axis=1).astype(jnp.int32)
    g_score = jax.ops.segment_sum(jnp.where(val_s > 0, sc_s, 0), gid,
                                  num_segments=p)
    g_dt = jax.ops.segment_min(jnp.where(val_s > 0, dt_s, INVALID), gid,
                               num_segments=p)
    g_onset = jax.ops.segment_min(jnp.where(val_s > 0, on_s, INVALID), gid,
                                  num_segments=p)
    g_on_max = jax.ops.segment_max(jnp.where(val_s > 0, on_s, -1), gid,
                                   num_segments=p)
    span = jnp.maximum(g_on_max - g_onset, 0)
    rep = new & (val_s > 0)
    keep = rep & (n_st[gid] >= cfg.min_stations)
    if cfg.max_group_extent > 0:
        keep &= span[gid] <= cfg.max_group_extent
    out = {
        "dt": jnp.where(keep, g_dt[gid], INVALID),
        "onset": jnp.where(keep, g_onset[gid], INVALID),
        "onset_span": jnp.where(keep, span[gid], 0),
        "n_stations": jnp.where(keep, n_st[gid], 0),
        "score": jnp.where(keep, g_score[gid], 0),
        "valid": keep,
    }
    if with_onsets:
        on_station = (sid_s[:, None]
                      == jnp.arange(n_stations, dtype=sid_s.dtype)[None, :])
        live = on_station & (val_s > 0)[:, None]
        onset_mat = jax.ops.segment_min(
            jnp.where(live, on_s[:, None], INVALID), gid, num_segments=p)
        score_mat = jax.ops.segment_sum(
            jnp.where(live, sc_s[:, None], 0), gid, num_segments=p)
        out["station_onset"] = jnp.where(keep[:, None], onset_mat[gid],
                                         INVALID)
        out["station_score"] = jnp.where(keep[:, None], score_mat[gid], 0)
    return out


# ---------------------------------------------------------------------------
# out-of-core channel merge (paper §7.2, host-side)
# ---------------------------------------------------------------------------


def align_streamed(channel_chunks: Sequence[Iterable[np.ndarray]],
                   threshold: int, tmpdir: str | None = None) -> np.ndarray:
    """External sort-merge-reduce of triplet chunks larger than memory.

    ``channel_chunks``: per channel, an iterable of (n, 3) int arrays with
    columns (dt, idx1, sim). Each chunk is sorted and spilled to disk; a
    heap merge streams them back, reducing consecutive equal (dt, idx1)
    rows and applying the combined threshold. Returns (m, 3) array.
    """
    tmp = tmpdir or tempfile.mkdtemp(prefix="fast_align_")
    spill_files = []
    for ci, chunks in enumerate(channel_chunks):
        for gi, arr in enumerate(chunks):
            arr = np.asarray(arr, np.int64)
            order = np.lexsort((arr[:, 1], arr[:, 0]))
            path = os.path.join(tmp, f"c{ci}_g{gi}.npy")
            np.save(path, arr[order])
            spill_files.append(path)

    def stream(path):
        arr = np.load(path, mmap_mode="r")
        for row in arr:
            yield (int(row[0]), int(row[1]), int(row[2]))

    out = []
    cur_key, cur_sim = None, 0
    for dt, idx1, sim in heapq.merge(*[stream(p) for p in spill_files]):
        if (dt, idx1) == cur_key:
            cur_sim += sim
        else:
            if cur_key is not None and cur_sim >= threshold:
                out.append((cur_key[0], cur_key[1], cur_sim))
            cur_key, cur_sim = (dt, idx1), sim
    if cur_key is not None and cur_sim >= threshold:
        out.append((cur_key[0], cur_key[1], cur_sim))
    return np.asarray(out, np.int64).reshape(-1, 3)
