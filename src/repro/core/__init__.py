"""The paper's contribution: FAST earthquake-detection pipeline in JAX."""
from repro.core.align import AlignConfig, Events  # noqa: F401
from repro.core.detect import DetectConfig, detect_events, detect_step  # noqa: F401
from repro.core.fingerprint import FingerprintConfig  # noqa: F401
from repro.core.lsh import LSHConfig, Pairs  # noqa: F401
from repro.core.synth import (ScenarioConfig, SynthConfig,  # noqa: F401
                              make_dataset, make_scenario_dataset)
