"""Synthetic seismic data generator (replaces GeoNet/NCEDC feeds; DESIGN §6).

Reproduces every phenomenon the paper's optimizations target:
  * reoccurring earthquakes: per-source waveform templates repeated at
    shared event times, arriving at each station after a fixed per-station
    travel-time delay (the Figure 9 invariance);
  * P/S wave structure: two damped oscillatory arrivals, the S wave slower
    and larger;
  * correlated repeating noise (Figure 7): an identical multi-spike pattern
    repeated frequently at selected stations — the mega-bucket generator;
  * narrowband hum outside the seismic band (for the bandpass experiments);
  * band-limited background noise.

``make_scenario_dataset`` layers the *deployment* pathologies the paper's
field sections report on top of a clean dataset — station data gaps and
dropouts (missing telemetry, marked NaN), duplicated data blocks
(telemetry repeats), repeating instrument glitch trains (the spurious-
similarity generator the occurrence filter was built for), and clock-
drifted copies. It is the shared substrate for the fault-injection test
suite (tests/test_scenarios.py) and ``bench_stream --scenario``.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SynthConfig:
    fs: float = 100.0
    duration_s: float = 600.0
    n_stations: int = 3
    n_sources: int = 3
    events_per_source: int = 4
    event_freq_hz: tuple[float, float] = (5.0, 14.0)   # in-band
    event_duration_s: float = 6.0
    event_snr: float = 2.5
    noise_sigma: float = 1.0
    # correlated repeating noise (paper Fig 7) at these stations
    repeating_noise_stations: tuple[int, ...] = ()
    repeating_noise_rate_hz: float = 0.05   # bursts per second
    # > 0: bursts arrive *periodically* at this period with a random
    # station-local phase (± 1 s jitter) instead of Poisson times — the
    # shared-period / independent-phase shape of anthropogenic noise
    # (machinery on a common duty cycle). Inter-burst times then agree
    # across stations while onsets fit no physical moveout: the
    # cross-station coincidence pressure the located-association A/B
    # (bench_stream --assoc) measures. 0 keeps the Poisson draw path
    # and the golden traces byte-identical.
    repeating_noise_period_s: float = 0.0
    repeating_noise_amp: float = 1.0        # template multiplier
    # narrowband hum (outside 3-20 Hz band) at these stations
    hum_stations: tuple[int, ...] = ()
    hum_freq_hz: float = 30.0
    hum_amp: float = 1.5
    # physical station geometry: stations and sources get coordinates on
    # a [0, extent_km]² surface grid and arrival delays become real
    # travel times (hypocentral distance / velocity) instead of uniform
    # draws — located scenarios then have ground-truth origins. Opt-in:
    # the default (False) keeps the RNG draw sequence and therefore the
    # golden traces byte-identical.
    physical_geometry: bool = False
    extent_km: float = 50.0
    depth_km: float = 8.0
    velocity_km_s: float = 6.0
    seed: int = 0


@dataclasses.dataclass
class SynthDataset:
    waveforms: np.ndarray          # (n_stations, T) float32
    event_times: np.ndarray        # (n_events,) seconds (source origin time)
    event_sources: np.ndarray      # (n_events,) int
    arrival_delays: np.ndarray     # (n_sources, n_stations) seconds
    cfg: SynthConfig
    # physical-geometry ground truth (None unless cfg.physical_geometry)
    station_xy: np.ndarray | None = None   # (n_stations, 2) km
    source_xy: np.ndarray | None = None    # (n_sources, 2) km

    def arrival_time(self, ev: int, station: int) -> float:
        return float(self.event_times[ev]
                     + self.arrival_delays[self.event_sources[ev], station])


def _source_template(rng: np.random.Generator, cfg: SynthConfig) -> np.ndarray:
    """P + S wave burst: two damped oscillations, S delayed and larger."""
    n = int(cfg.event_duration_s * cfg.fs)
    t = np.arange(n) / cfg.fs
    fp = rng.uniform(*cfg.event_freq_hz)
    fs_ = rng.uniform(*cfg.event_freq_hz)
    s_delay = rng.uniform(0.8, 2.0)
    tau_p, tau_s = rng.uniform(0.3, 0.8), rng.uniform(0.8, 1.8)
    p = np.exp(-t / tau_p) * np.sin(2 * np.pi * fp * t + rng.uniform(0, 6.28))
    ts = np.clip(t - s_delay, 0, None)
    s = (np.exp(-ts / tau_s) * np.sin(2 * np.pi * fs_ * ts)
         * (t >= s_delay) * rng.uniform(1.5, 2.5))
    return (p + s).astype(np.float32)


def _colored_noise(rng: np.random.Generator, n: int, sigma: float) -> np.ndarray:
    w = rng.standard_normal(n).astype(np.float32)
    # cheap band-shaping: first-order smoothing + diff mix ≈ mid-band noise
    sm = np.empty_like(w)
    acc = 0.0
    a = 0.7
    for start in range(0, n, 1 << 20):  # chunked to keep it vectorizable
        chunk = w[start:start + (1 << 20)]
        out = np.empty_like(chunk)
        for i, x in enumerate(chunk):
            acc = a * acc + (1 - a) * x
            out[i] = acc
        sm[start:start + (1 << 20)] = out
    return (0.6 * w + 0.8 * sm) * sigma


def _colored_noise_fast(rng: np.random.Generator, n: int,
                        sigma: float) -> np.ndarray:
    """FFT-shaped background noise (vectorized; ~1/sqrt(f) above 1 Hz)."""
    w = rng.standard_normal(n).astype(np.float32)
    spec = np.fft.rfft(w)
    f = np.fft.rfftfreq(n, d=1.0)
    shape = 1.0 / np.sqrt(np.maximum(f * n * 0.01, 1.0))
    return (np.fft.irfft(spec * shape, n) * sigma
            / max(np.std(np.fft.irfft(spec * shape, n)), 1e-9)).astype(
                np.float32)


def _repeating_noise_template(rng: np.random.Generator,
                              cfg: SynthConfig) -> np.ndarray:
    """Three-spike pattern like Figure 7 — identical at every repeat."""
    n = int(2.0 * cfg.fs)
    t = np.arange(n) / cfg.fs
    out = np.zeros(n, np.float32)
    for k, t0 in enumerate((0.2, 0.8, 1.4)):
        env = np.exp(-np.abs(t - t0) / 0.05)
        out += env * np.sin(2 * np.pi * 9.0 * (t - t0)) * (1.0 - 0.2 * k)
    return out * 3.0


def make_dataset(cfg: SynthConfig) -> SynthDataset:
    rng = np.random.default_rng(cfg.seed)
    n = int(cfg.duration_s * cfg.fs)
    wf = np.stack([
        _colored_noise_fast(rng, n, cfg.noise_sigma)
        for _ in range(cfg.n_stations)])

    # sources & events
    templates = [_source_template(rng, cfg) for _ in range(cfg.n_sources)]
    station_xy = source_xy = None
    if cfg.physical_geometry:
        # a separate generator so the main draw sequence (and the golden
        # traces pinned on it) is untouched when geometry is off
        grng = np.random.default_rng(cfg.seed ^ 0x9E0C37)
        station_xy = grng.uniform(0.05 * cfg.extent_km, 0.95 * cfg.extent_km,
                                  size=(cfg.n_stations, 2))
        source_xy = grng.uniform(0.1 * cfg.extent_km, 0.9 * cfg.extent_km,
                                 size=(cfg.n_sources, 2))
        dist = np.sqrt(((source_xy[:, None, :]
                         - station_xy[None, :, :]) ** 2).sum(-1)
                       + cfg.depth_km ** 2)
        delays = dist / cfg.velocity_km_s
    else:
        delays = rng.uniform(1.0, 8.0, size=(cfg.n_sources, cfg.n_stations))
    ev_times, ev_src = [], []
    margin = cfg.event_duration_s + delays.max() + 2.0
    for s in range(cfg.n_sources):
        times = rng.uniform(5.0, cfg.duration_s - margin,
                            size=cfg.events_per_source)
        times = np.sort(times)
        # keep events apart so ground truth is unambiguous
        keep = np.concatenate([[True], np.diff(times) > 2 * margin])
        for t0 in times[keep]:
            ev_times.append(t0)
            ev_src.append(s)
    ev_times = np.asarray(ev_times)
    ev_src = np.asarray(ev_src, np.int32)

    amp = cfg.event_snr * cfg.noise_sigma
    for t0, s in zip(ev_times, ev_src):
        tpl = templates[s]
        for st in range(cfg.n_stations):
            i0 = int((t0 + delays[s, st]) * cfg.fs)
            seg = wf[st, i0:i0 + tpl.size]
            seg += amp * tpl[: seg.size] * rng.uniform(0.9, 1.1)

    # correlated repeating noise
    rep_tpl = _repeating_noise_template(rng, cfg)
    for st in cfg.repeating_noise_stations:
        if cfg.repeating_noise_period_s > 0:
            # shared period, independent station phase: exact spacing
            # keeps the repeats aligned to the fingerprint lag grid (the
            # duty-cycle regularity that makes anthropogenic noise
            # self-similar), so inter-burst times agree across stations
            # while the onsets fit no physical moveout — the coincidence
            # pressure of the located-association A/B
            p = cfg.repeating_noise_period_s
            t0s = np.arange(rng.uniform(0, p), cfg.duration_s - 3.0, p)
        else:
            n_bursts = int(cfg.duration_s * cfg.repeating_noise_rate_hz)
            t0s = rng.uniform(0, cfg.duration_s - 3.0, size=n_bursts)
        for t0 in t0s:
            i0 = int(max(t0, 0.0) * cfg.fs)
            seg = wf[st, i0:i0 + rep_tpl.size]
            seg += cfg.repeating_noise_amp * rep_tpl[: seg.size]

    # narrowband bursts: identical out-of-band (30 Hz) tone bursts that
    # repeat — stationary hum would be cancelled by the MAD normalization
    # (a robustness property verified in tests); the paper's Fig-18 noise
    # is bursty, which is what the bandpass filter must exclude
    burst_n = int(3.0 * cfg.fs)
    tb = np.arange(burst_n) / cfg.fs
    hum_tpl = (cfg.hum_amp * np.sin(2 * np.pi * cfg.hum_freq_hz * tb)
               * np.hanning(burst_n)).astype(np.float32)
    for st in cfg.hum_stations:
        n_bursts = max(1, int(cfg.duration_s * 0.08))
        for t0 in rng.uniform(0, cfg.duration_s - 4.0, size=n_bursts):
            i0 = int(t0 * cfg.fs)
            seg = wf[st, i0:i0 + burst_n]
            seg += hum_tpl[: seg.size]

    return SynthDataset(waveforms=wf.astype(np.float32),
                        event_times=ev_times, event_sources=ev_src,
                        arrival_delays=delays, cfg=cfg,
                        station_xy=station_xy, source_xy=source_xy)


# ---------------------------------------------------------------------------
# dirty-data scenarios: the deployment pathologies layered on a clean trace
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """Fault-injection knobs over a clean ``SynthConfig`` trace.

    Missing data (gaps, dropouts) is marked with NaN — the wire format the
    streaming ingest treats as "sample never arrived". Corrupted-but-
    present data (duplicated blocks, glitch trains, drift) stays finite;
    the ``corrupt`` mask records where it lives so tests can separate the
    clean portion from the injected one.
    """

    base: SynthConfig = SynthConfig()
    # telemetry gaps: short spans of missing samples (NaN)
    n_gaps: int = 0
    gap_dur_s: tuple[float, float] = (2.0, 8.0)
    gap_stations: tuple[int, ...] | None = None   # None = any station
    # station dropout: one long missing span per listed station
    dropout_stations: tuple[int, ...] = ()
    dropout_start_frac: float = 0.45
    dropout_dur_s: float = 60.0
    # duplicated data blocks: an earlier span re-appears verbatim later
    # (telemetry repeat). dst - src is aligned to ``dup_align_samples`` so
    # the copy lands on the fingerprint lag grid (bit-exact duplicate
    # fingerprints, the worst case for the duplicate guard).
    n_dup_blocks: int = 0
    dup_block_dur_s: float = 20.0
    dup_spacing_s: float = 60.0
    dup_align_samples: int = 200
    # repeating instrument glitch trains: identical pulses at a fixed
    # period, in episodes. period = fingerprint lag makes consecutive
    # fingerprints inside a train near-identical — the mega-bucket /
    # spurious-pair generator the paper's §6.5 quality controls target.
    # ``glitch_replace=True`` models digital-origin artifacts (calibration
    # pulses, electronics steps) that *clobber* the sensor output — the
    # train is then sample-exact periodic, the worst duplicate case;
    # False adds the pulses on top of the live noise floor (near-exact at
    # the fingerprint level only — the saturation guard's case).
    glitch_stations: tuple[int, ...] = ()
    glitch_trains: int = 3
    glitch_train_dur_s: float = 24.0
    glitch_period_s: float = 2.0
    glitch_amp: float = 25.0
    glitch_replace: bool = True
    glitch_jitter: float = 0.0    # per-pulse amplitude jitter (0 = exact)
    # clock drift: the station's timeline resampled by (1 + ppm * 1e-6)
    clock_drift_stations: tuple[int, ...] = ()
    clock_drift_ppm: float = 0.0
    seed: int = 0


@dataclasses.dataclass
class ScenarioDataset:
    """A dirty stream plus everything needed to judge a detector on it."""

    clean: SynthDataset            # the underlying clean dataset
    waveforms: np.ndarray          # (S, T) float32, NaN where missing
    missing: np.ndarray            # (S, T) bool — samples that never arrived
    corrupt: np.ndarray            # (S, T) bool — samples altered in place
    injections: dict               # per-pathology logs (spans, stations)
    cfg: ScenarioConfig

    @property
    def station_xy(self) -> np.ndarray | None:
        """Ground-truth station geometry (physical-geometry bases only)."""
        return self.clean.station_xy

    @property
    def source_xy(self) -> np.ndarray | None:
        return self.clean.source_xy

    def clean_fp_ids(self, station: int, window_samples: int,
                     lag_samples: int) -> np.ndarray:
        """Fingerprint ids whose analysis window touches no injected
        pathology (neither missing nor corrupted samples) — the ids on
        which a guarded dirty run must match the clean golden exactly."""
        bad = self.missing[station] | self.corrupt[station]
        t = bad.shape[0]
        n = max(0, (t - window_samples) // lag_samples + 1)
        csum = np.concatenate([[0], np.cumsum(bad)])
        starts = np.arange(n) * lag_samples
        ok = (csum[starts + window_samples] - csum[starts]) == 0
        return np.nonzero(ok)[0].astype(np.int64)


def _glitch_template(fs: float) -> np.ndarray:
    """Repeating instrument glitch: a strong damped in-band oscillation
    (~1.5 s, 8 Hz). At the default amplitude it dominates the top-K
    anomalous coefficients of every window it lands in, so train
    fingerprints become near-identical (Jaccard ≳ 0.95) and collide in
    nearly all hash tables — the paper's mega-bucket pathology."""
    n = int(1.5 * fs)
    t = np.arange(n) / fs
    return (np.exp(-t / 0.5) * np.sin(2 * np.pi * 8.0 * t)).astype(
        np.float32)


def make_scenario_dataset(cfg: ScenarioConfig) -> ScenarioDataset:
    """Clean dataset + injected pathologies → a dirty stream with masks."""
    clean = make_dataset(cfg.base)
    rng = np.random.default_rng(cfg.seed ^ 0x5C3A51)
    wf = clean.waveforms.copy()
    s_n, t_n = wf.shape
    fs = cfg.base.fs
    missing = np.zeros((s_n, t_n), bool)
    corrupt = np.zeros((s_n, t_n), bool)
    inj: dict[str, list] = {"gaps": [], "dropouts": [], "dup_blocks": [],
                            "glitch_trains": [], "drift": []}

    gap_st = (tuple(range(s_n)) if cfg.gap_stations is None
              else cfg.gap_stations)
    for _ in range(cfg.n_gaps):
        st = int(gap_st[int(rng.integers(0, len(gap_st)))])
        dur = int(rng.uniform(*cfg.gap_dur_s) * fs)
        i0 = int(rng.integers(0, max(1, t_n - dur)))
        missing[st, i0:i0 + dur] = True
        inj["gaps"].append({"station": st, "start": i0, "len": dur})

    for st in cfg.dropout_stations:
        i0 = int(cfg.dropout_start_frac * t_n)
        dur = int(cfg.dropout_dur_s * fs)
        missing[st, i0:i0 + dur] = True
        inj["dropouts"].append({"station": st, "start": i0, "len": dur})

    blk = int(cfg.dup_block_dur_s * fs)
    align = max(1, int(cfg.dup_align_samples))
    spacing = (int(cfg.dup_spacing_s * fs) // align) * align
    for _ in range(cfg.n_dup_blocks):
        st = int(rng.integers(0, s_n))
        hi = max(align, t_n - blk - spacing)
        src = (int(rng.integers(0, hi)) // align) * align
        dst = src + spacing
        span = min(blk, t_n - dst)
        if span <= 0:      # trace too short for this spacing: no copy
            continue       # lands, so don't log a phantom injection
        wf[st, dst:dst + span] = wf[st, src:src + span]
        corrupt[st, dst:dst + span] = True
        inj["dup_blocks"].append({"station": st, "src": src, "dst": dst,
                                  "len": span})

    tpl = _glitch_template(fs)
    period = int(cfg.glitch_period_s * fs)
    train_n = int(cfg.glitch_train_dur_s * fs)
    for st in cfg.glitch_stations:
        for k in range(cfg.glitch_trains):
            # trains spaced evenly, start phase-locked to the pulse clock
            # (digital-origin artifacts fire on the instrument's clock, so
            # every repeat lands at the same phase mod period)
            slot = t_n / (cfg.glitch_trains + 1)
            i0 = int((k + 1) * slot - train_n / 2)
            i0 = max(0, min(i0, t_n - train_n - period))
            i0 = (i0 // period) * period
            for t0 in range(i0, i0 + train_n, period):
                amp = cfg.glitch_amp * cfg.base.noise_sigma
                if cfg.glitch_jitter > 0:
                    amp *= 1.0 + cfg.glitch_jitter * rng.uniform(-1.0, 1.0)
                seg = wf[st, t0:t0 + period]
                pulse = np.zeros(period, np.float32)
                pulse[: min(tpl.size, period)] = \
                    amp * tpl[: min(tpl.size, period)]
                if cfg.glitch_replace:
                    seg[:] = pulse[: seg.size]
                else:
                    seg += pulse[: seg.size]
            corrupt[st, i0:i0 + train_n + period] = True
            inj["glitch_trains"].append({"station": st, "start": i0,
                                         "len": train_n + period,
                                         "period": period})

    for st in cfg.clock_drift_stations:
        f = 1.0 + cfg.clock_drift_ppm * 1e-6
        src_t = np.clip(np.arange(t_n) * f, 0, t_n - 1)
        wf[st] = np.interp(src_t, np.arange(t_n), wf[st]).astype(np.float32)
        # the resample alters the station's entire timeline — nothing on
        # it is sample-comparable to the clean trace
        corrupt[st, :] = cfg.clock_drift_ppm != 0
        inj["drift"].append({"station": st, "ppm": cfg.clock_drift_ppm})

    dirty = wf.astype(np.float32).copy()
    dirty[missing] = np.nan
    return ScenarioDataset(clean=clean, waveforms=dirty, missing=missing,
                           corrupt=corrupt, injections=inj, cfg=cfg)
