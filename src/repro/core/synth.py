"""Synthetic seismic data generator (replaces GeoNet/NCEDC feeds; DESIGN §6).

Reproduces every phenomenon the paper's optimizations target:
  * reoccurring earthquakes: per-source waveform templates repeated at
    shared event times, arriving at each station after a fixed per-station
    travel-time delay (the Figure 9 invariance);
  * P/S wave structure: two damped oscillatory arrivals, the S wave slower
    and larger;
  * correlated repeating noise (Figure 7): an identical multi-spike pattern
    repeated frequently at selected stations — the mega-bucket generator;
  * narrowband hum outside the seismic band (for the bandpass experiments);
  * band-limited background noise.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SynthConfig:
    fs: float = 100.0
    duration_s: float = 600.0
    n_stations: int = 3
    n_sources: int = 3
    events_per_source: int = 4
    event_freq_hz: tuple[float, float] = (5.0, 14.0)   # in-band
    event_duration_s: float = 6.0
    event_snr: float = 2.5
    noise_sigma: float = 1.0
    # correlated repeating noise (paper Fig 7) at these stations
    repeating_noise_stations: tuple[int, ...] = ()
    repeating_noise_rate_hz: float = 0.05   # bursts per second
    # narrowband hum (outside 3-20 Hz band) at these stations
    hum_stations: tuple[int, ...] = ()
    hum_freq_hz: float = 30.0
    hum_amp: float = 1.5
    seed: int = 0


@dataclasses.dataclass
class SynthDataset:
    waveforms: np.ndarray          # (n_stations, T) float32
    event_times: np.ndarray        # (n_events,) seconds (source origin time)
    event_sources: np.ndarray      # (n_events,) int
    arrival_delays: np.ndarray     # (n_sources, n_stations) seconds
    cfg: SynthConfig

    def arrival_time(self, ev: int, station: int) -> float:
        return float(self.event_times[ev]
                     + self.arrival_delays[self.event_sources[ev], station])


def _source_template(rng: np.random.Generator, cfg: SynthConfig) -> np.ndarray:
    """P + S wave burst: two damped oscillations, S delayed and larger."""
    n = int(cfg.event_duration_s * cfg.fs)
    t = np.arange(n) / cfg.fs
    fp = rng.uniform(*cfg.event_freq_hz)
    fs_ = rng.uniform(*cfg.event_freq_hz)
    s_delay = rng.uniform(0.8, 2.0)
    tau_p, tau_s = rng.uniform(0.3, 0.8), rng.uniform(0.8, 1.8)
    p = np.exp(-t / tau_p) * np.sin(2 * np.pi * fp * t + rng.uniform(0, 6.28))
    ts = np.clip(t - s_delay, 0, None)
    s = (np.exp(-ts / tau_s) * np.sin(2 * np.pi * fs_ * ts)
         * (t >= s_delay) * rng.uniform(1.5, 2.5))
    return (p + s).astype(np.float32)


def _colored_noise(rng: np.random.Generator, n: int, sigma: float) -> np.ndarray:
    w = rng.standard_normal(n).astype(np.float32)
    # cheap band-shaping: first-order smoothing + diff mix ≈ mid-band noise
    sm = np.empty_like(w)
    acc = 0.0
    a = 0.7
    for start in range(0, n, 1 << 20):  # chunked to keep it vectorizable
        chunk = w[start:start + (1 << 20)]
        out = np.empty_like(chunk)
        for i, x in enumerate(chunk):
            acc = a * acc + (1 - a) * x
            out[i] = acc
        sm[start:start + (1 << 20)] = out
    return (0.6 * w + 0.8 * sm) * sigma


def _colored_noise_fast(rng: np.random.Generator, n: int,
                        sigma: float) -> np.ndarray:
    """FFT-shaped background noise (vectorized; ~1/sqrt(f) above 1 Hz)."""
    w = rng.standard_normal(n).astype(np.float32)
    spec = np.fft.rfft(w)
    f = np.fft.rfftfreq(n, d=1.0)
    shape = 1.0 / np.sqrt(np.maximum(f * n * 0.01, 1.0))
    return (np.fft.irfft(spec * shape, n) * sigma
            / max(np.std(np.fft.irfft(spec * shape, n)), 1e-9)).astype(
                np.float32)


def _repeating_noise_template(rng: np.random.Generator,
                              cfg: SynthConfig) -> np.ndarray:
    """Three-spike pattern like Figure 7 — identical at every repeat."""
    n = int(2.0 * cfg.fs)
    t = np.arange(n) / cfg.fs
    out = np.zeros(n, np.float32)
    for k, t0 in enumerate((0.2, 0.8, 1.4)):
        env = np.exp(-np.abs(t - t0) / 0.05)
        out += env * np.sin(2 * np.pi * 9.0 * (t - t0)) * (1.0 - 0.2 * k)
    return out * 3.0


def make_dataset(cfg: SynthConfig) -> SynthDataset:
    rng = np.random.default_rng(cfg.seed)
    n = int(cfg.duration_s * cfg.fs)
    wf = np.stack([
        _colored_noise_fast(rng, n, cfg.noise_sigma)
        for _ in range(cfg.n_stations)])

    # sources & events
    templates = [_source_template(rng, cfg) for _ in range(cfg.n_sources)]
    delays = rng.uniform(1.0, 8.0, size=(cfg.n_sources, cfg.n_stations))
    ev_times, ev_src = [], []
    margin = cfg.event_duration_s + delays.max() + 2.0
    for s in range(cfg.n_sources):
        times = rng.uniform(5.0, cfg.duration_s - margin,
                            size=cfg.events_per_source)
        times = np.sort(times)
        # keep events apart so ground truth is unambiguous
        keep = np.concatenate([[True], np.diff(times) > 2 * margin])
        for t0 in times[keep]:
            ev_times.append(t0)
            ev_src.append(s)
    ev_times = np.asarray(ev_times)
    ev_src = np.asarray(ev_src, np.int32)

    amp = cfg.event_snr * cfg.noise_sigma
    for t0, s in zip(ev_times, ev_src):
        tpl = templates[s]
        for st in range(cfg.n_stations):
            i0 = int((t0 + delays[s, st]) * cfg.fs)
            seg = wf[st, i0:i0 + tpl.size]
            seg += amp * tpl[: seg.size] * rng.uniform(0.9, 1.1)

    # correlated repeating noise
    rep_tpl = _repeating_noise_template(rng, cfg)
    for st in cfg.repeating_noise_stations:
        n_bursts = int(cfg.duration_s * cfg.repeating_noise_rate_hz)
        for t0 in rng.uniform(0, cfg.duration_s - 3.0, size=n_bursts):
            i0 = int(t0 * cfg.fs)
            seg = wf[st, i0:i0 + rep_tpl.size]
            seg += rep_tpl[: seg.size]

    # narrowband bursts: identical out-of-band (30 Hz) tone bursts that
    # repeat — stationary hum would be cancelled by the MAD normalization
    # (a robustness property verified in tests); the paper's Fig-18 noise
    # is bursty, which is what the bandpass filter must exclude
    burst_n = int(3.0 * cfg.fs)
    tb = np.arange(burst_n) / cfg.fs
    hum_tpl = (cfg.hum_amp * np.sin(2 * np.pi * cfg.hum_freq_hz * tb)
               * np.hanning(burst_n)).astype(np.float32)
    for st in cfg.hum_stations:
        n_bursts = max(1, int(cfg.duration_s * 0.08))
        for t0 in rng.uniform(0, cfg.duration_s - 4.0, size=n_bursts):
            i0 = int(t0 * cfg.fs)
            seg = wf[st, i0:i0 + burst_n]
            seg += hum_tpl[: seg.size]

    return SynthDataset(waveforms=wf.astype(np.float32),
                        event_times=ev_times, event_sources=ev_src,
                        arrival_delays=delays, cfg=cfg)
