"""LSH detection-probability theory (paper §6.3, Figure 6)."""
from __future__ import annotations

import math

import numpy as np


def detection_probability(s, k: int, m: int, t: int = 100):
    """P[pair with Jaccard s matches in ≥ m of t tables of k hash fns].

    P[s] = 1 - Σ_{i<m} C(t, i) (s^k)^i (1 - s^k)^{t-i}
    """
    s = np.asarray(s, np.float64)
    p = s**k
    acc = np.zeros_like(s)
    for i in range(m):
        acc += math.comb(t, i) * p**i * (1 - p) ** (t - i)
    return 1.0 - acc


def s_curve_threshold(k: int, m: int, t: int = 100,
                      level: float = 0.5) -> float:
    """Jaccard similarity at which detection probability crosses ``level``."""
    lo, hi = 0.0, 1.0
    for _ in range(60):
        mid = (lo + hi) / 2
        if detection_probability(mid, k, m, t) < level:
            lo = mid
        else:
            hi = mid
    return (lo + hi) / 2


def equivalent_m(k_old: int, m_old: int, k_new: int, t: int = 100) -> int:
    """Smallest m_new keeping the S-curve midpoint ≤ the old one (§6.3).

    This is the paper's 'increase hash functions, lower the match
    threshold, same detection probability' parameter move.
    """
    target = s_curve_threshold(k_old, m_old, t)
    for m_new in range(1, t + 1):
        if s_curve_threshold(k_new, m_new, t) >= target:
            return max(1, m_new - 1) if m_new > 1 else 1
    return t
