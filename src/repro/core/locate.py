"""Location / weighting / magnitude tier over the network association.

The paper's post-processing stops at *pairwise* network association —
groups of per-station events sharing inter-event time (§7, Figure 9).
Its headline results, though, are located, sized earthquakes. This
module is the third stage of the association anatomy:

  association  — ``core.align.associate_network`` groups per-station
                 events by (dt, onset); with ``with_onsets`` it also
                 returns each group's per-station onset matrix.
  location     — ``locate_groups`` runs a migration/stacking pass: for
                 candidate origins on a coarse-to-fine spatial grid, the
                 per-station travel-time moveout is subtracted from the
                 observed onsets and the quality-weighted residual is
                 stacked; the argmin cell (refined ``refine_levels``
                 times) is the origin estimate. The residual doubles as
                 a *moveout-consistency* check: a cross-station
                 coincidence that matches no physical origin keeps a
                 large residual and is rejected — the model-based false-
                 association filter the ROADMAP's scenario suite calls
                 for.
  magnitude    — ``relative_magnitude`` sizes a detection from the
                 amplitude ratio between the two occurrences of the
                 repeating pair: the weighted median of log10 amplitude
                 ratios, weighted by exact Jaccard where verified pairs
                 are in hand (``VerifiedPairs.jaccard``) and by the
                 station quality weights on the streaming path.

Weights come from the ingest/guard QC counters (gap / saturation / drop
rates; ``station_weights``): a station with holes or glitch quarantines
contributes less to the stack, mirroring qseek-style station weighting.

Everything device-side is static-shape: groups are padded to a fixed
multiple before the jitted stack and masked with ``valid``; the grid
search is a Python loop over ``refine_levels`` (static) of one
vectorized (G, S) evaluation each. Units: onsets and travel times in
fingerprint lags, coordinates in km on a [0, extent_km]² surface grid
with a fixed focal depth.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lsh import INVALID

# alert-row sentinels (host/int64 side): location in milli-km, relative
# magnitude in milli-magnitudes
LOC_NONE = -1
MAG_NONE = -(1 << 31)


@dataclasses.dataclass(frozen=True)
class LocateConfig:
    grid_n: int = 12               # grid_n × grid_n candidate origins/level
    extent_km: float = 50.0        # surface grid spans [0, extent_km]²
    depth_km: float = 8.0          # fixed candidate focal depth
    velocity_km_s: float = 6.0     # homogeneous P speed
    refine_levels: int = 2         # coarse-to-fine argmin refinements
    refine_factor: float = 0.25    # span shrink per refinement level
    moveout_tol_lags: float = 4.0  # consistency: max weighted |residual|
    reject_inconsistent: bool = True   # drop groups failing the check
    min_weight: float = 0.05       # station quality-weight floor
    pad_groups: int = 32           # device batch padded to this multiple

    @property
    def coarse_cell_km(self) -> float:
        """Coarse-grid cell size — the origin-error unit the located-
        scenario acceptance (median error ≤ 2 cells) is judged in."""
        return self.extent_km / self.grid_n

    @property
    def cell_km(self) -> float:
        """Finest-level cell size after all refinements."""
        span = self.extent_km * self.refine_factor ** self.refine_levels
        return span / self.grid_n


# ---------------------------------------------------------------------------
# migration / stacking (device side)
# ---------------------------------------------------------------------------


def travel_time_lags(xy: jax.Array, station_xy: jax.Array,
                     cfg: LocateConfig, lag_s: jax.Array) -> jax.Array:
    """Travel time, in fingerprint lags, from origins ``xy`` (..., 2) to
    each station (S, 2) through the homogeneous halfspace."""
    d2 = jnp.sum((xy[..., None, :] - station_xy) ** 2, axis=-1)
    dist = jnp.sqrt(d2 + cfg.depth_km ** 2)
    return dist / cfg.velocity_km_s / lag_s


def _locate_one(onsets: jax.Array, weights: jax.Array,
                station_xy: jax.Array, lag_s: jax.Array,
                cfg: LocateConfig) -> dict:
    """Coarse-to-fine stack for one group's per-station onsets (S,)."""
    present = onsets != INVALID
    w = jnp.where(present, jnp.maximum(weights, cfg.min_weight), 0.0)
    wsum = jnp.maximum(w.sum(), 1e-9)
    on = jnp.where(present, onsets, 0).astype(jnp.float32)

    def level(center, span):
        offs = (jnp.arange(cfg.grid_n, dtype=jnp.float32) + 0.5) \
            / cfg.grid_n - 0.5
        gx, gy = jnp.meshgrid(offs, offs, indexing="ij")
        cand = center[None, :] + span * jnp.stack(
            [gx.ravel(), gy.ravel()], axis=1)
        cand = jnp.clip(cand, 0.0, cfg.extent_km)
        tt = travel_time_lags(cand, station_xy, cfg, lag_s)   # (G, S)
        t0 = (w * (on - tt)).sum(axis=1) / wsum               # (G,)
        resid = (w * jnp.abs(on - tt - t0[:, None])).sum(axis=1) / wsum
        best = jnp.argmin(resid)
        return cand[best], t0[best], resid[best]

    center = jnp.full((2,), 0.5 * cfg.extent_km, jnp.float32)
    span = jnp.float32(cfg.extent_km)
    t0 = resid = jnp.float32(0.0)
    for _ in range(cfg.refine_levels + 1):
        center, t0, resid = level(center, span)
        span = span * cfg.refine_factor
    return {"xy": center, "t0": t0, "residual": resid,
            "n_used": present.sum().astype(jnp.int32)}


@functools.partial(jax.jit, static_argnames=("cfg",))
def locate_groups(onsets: jax.Array, weights: jax.Array,
                  station_xy: jax.Array, lag_s: jax.Array,
                  cfg: LocateConfig) -> dict:
    """Migration-stack ``(g, S)`` group onset matrices → per-group origin.

    ``onsets``: int32 lags, ``INVALID`` where a station is absent from
    the group. Returns ``xy`` (g, 2) km, ``t0`` (g,) lags, ``residual``
    (g,) weighted mean |lags|, ``n_used`` (g,) stations stacked, and
    ``consistent`` — residual within ``moveout_tol_lags``.
    """
    out = jax.vmap(
        lambda o: _locate_one(o, weights, station_xy, lag_s, cfg))(onsets)
    out["consistent"] = out["residual"] <= cfg.moveout_tol_lags
    return out


# ---------------------------------------------------------------------------
# station quality weights (host side, from the QC counters)
# ---------------------------------------------------------------------------


def station_weights(qualities: Sequence[dict], samples: Sequence[int],
                    fingerprints: Sequence[int],
                    cfg: LocateConfig) -> np.ndarray:
    """Per-station stack weights from the ingest/guard QC counters.

    Sample-level dirt (gaps, missing/late-dropped/rejected telemetry,
    duplicated spans) and fingerprint-level dirt (dup-probe and
    saturation-quarantine suppressions, validity-masked fingerprints)
    are turned into rates against the station's own traffic; the weight
    is ``1 - rate`` floored at ``min_weight``, so a clean station stacks
    at 1.0 and a station that spent half its stream in gaps or glitch
    quarantine contributes half — dirty stations can't drag the origin.
    """
    sample_keys = ("gap_samples", "missing_samples", "late_dropped_samples",
                   "rejected_samples", "duplicate_samples")
    fp_keys = ("duplicate_fingerprints", "masked_fingerprints",
               "saturated_lookups")
    w = np.ones(len(qualities), np.float32)
    for i, q in enumerate(qualities):
        rate = (sum(int(q.get(k, 0)) for k in sample_keys)
                / max(int(samples[i]), 1)
                + sum(int(q.get(k, 0)) for k in fp_keys)
                / max(int(fingerprints[i]), 1))
        w[i] = min(1.0, max(cfg.min_weight, 1.0 - rate))
    return w


# ---------------------------------------------------------------------------
# relative magnitude
# ---------------------------------------------------------------------------


def weighted_median(values: np.ndarray, weights: np.ndarray) -> float:
    """Host weighted median (first value reaching half the weight mass)."""
    values = np.asarray(values, np.float64).reshape(-1)
    weights = np.asarray(weights, np.float64).reshape(-1)
    ok = np.isfinite(values) & (weights > 0)
    if not ok.any():
        return float("nan")
    v, w = values[ok], weights[ok]
    order = np.argsort(v)
    v, w = v[order], w[order]
    cw = np.cumsum(w)
    return float(v[np.searchsorted(cw, 0.5 * cw[-1])])


def relative_magnitude(amp_first: np.ndarray, amp_second: np.ndarray,
                       weights: np.ndarray) -> float:
    """Relative magnitude of the re-occurrence vs. its template.

    The Richter-style size difference of a repeating pair is the log10
    amplitude ratio between the two occurrences; over a group's pairs
    (or stations) the estimate is the weighted median of those ratios —
    ``VerifiedPairs.jaccard`` as the pair weight where verified pairs
    are in hand, station quality weights on the streaming path. NaN when
    no member has two usable amplitudes.
    """
    a1 = np.asarray(amp_first, np.float64).reshape(-1)
    a2 = np.asarray(amp_second, np.float64).reshape(-1)
    w = np.asarray(weights, np.float64).reshape(-1)
    ok = np.isfinite(a1) & np.isfinite(a2) & (a1 > 0) & (a2 > 0)
    return weighted_median(np.where(ok, np.log10(np.maximum(a2, 1e-30))
                                    - np.log10(np.maximum(a1, 1e-30)),
                                    np.nan),
                           np.where(ok, w, 0.0))


def fingerprint_amplitudes(waveform: np.ndarray, lag_samples: int,
                           window_samples: int) -> np.ndarray:
    """Per-fingerprint peak |amplitude|: max over each fingerprint's
    analysis window, computed as a lag-binned max + sliding max (host,
    vectorized). NaN samples (missing telemetry) count as 0."""
    x = np.abs(np.nan_to_num(np.asarray(waveform, np.float32), nan=0.0))
    nb = -(-x.size // lag_samples)
    pad = np.zeros(nb * lag_samples, np.float32)
    pad[:x.size] = x
    bins = pad.reshape(nb, lag_samples).max(axis=1)
    w_bins = max(1, -(-window_samples // lag_samples))
    if w_bins > 1:
        bins = np.concatenate([bins, np.zeros(w_bins - 1, np.float32)])
        bins = np.lib.stride_tricks.sliding_window_view(
            bins, w_bins).max(axis=1)
    return bins


def magnitudes_from_onsets(station_onset: np.ndarray, dt: np.ndarray,
                           valid: np.ndarray, amp_fn,
                           weights: np.ndarray,
                           station_score: np.ndarray | None = None
                           ) -> np.ndarray:
    """Per-group relative magnitudes from the two occurrences' amplitudes.

    ``amp_fn(station, fp_index) -> float | nan`` abstracts the amplitude
    source: the batch driver passes whole-trace per-fingerprint peaks,
    the streaming engine its bounded amplitude timeline. The per-station
    weight is the quality weight times the group's verified-pair mass at
    that station (``station_score`` — Jaccard-weighted similarity when
    the verify epilogue is on), so dirtier stations and weaker pair
    evidence pull less. NaN where no station has both amplitudes.
    """
    station_onset = np.asarray(station_onset)
    dt = np.asarray(dt)
    valid = np.asarray(valid)
    p, s = station_onset.shape
    mags = np.full(p, np.nan, np.float32)
    for g in np.nonzero(valid)[0]:
        a1, a2, w = [], [], []
        for st in range(s):
            o = int(station_onset[g, st])
            if o == INVALID:
                continue
            f = amp_fn(st, o)
            r = amp_fn(st, o + int(dt[g]))
            if f is None or r is None:
                continue
            a1.append(f)
            a2.append(r)
            ws = float(weights[st])
            if station_score is not None:
                ws *= max(float(station_score[g, st]), 0.0)
            w.append(ws)
        if a1:
            mags[g] = relative_magnitude(np.asarray(a1), np.asarray(a2),
                                         np.asarray(w))
    return mags


# ---------------------------------------------------------------------------
# host wrapper: det dict → located det dict
# ---------------------------------------------------------------------------


def locate_detections(det: dict, station_xy: np.ndarray,
                      weights: np.ndarray, lag_s: float,
                      cfg: LocateConfig) -> dict:
    """Locate every valid associated group of an ``associate_network``
    output (run with ``with_onsets=True``).

    Compacts the valid groups, pads them to a ``pad_groups`` multiple
    (few distinct device shapes), stacks, and scatters the results back
    into det-aligned arrays: ``x_km``/``y_km``/``t0``/``residual``/
    ``n_used``/``consistent`` (NaN / False on invalid rows). The input
    dict is not modified.
    """
    if "station_onset" not in det:
        raise ValueError("locate_detections needs associate_network output "
                         "with with_onsets=True (no station_onset key)")
    v = np.asarray(det["valid"])
    onset_mat = np.asarray(det["station_onset"])
    p, s = onset_mat.shape
    idx = np.nonzero(v)[0]
    g = idx.shape[0]
    x = np.full(p, np.nan, np.float32)
    y = np.full(p, np.nan, np.float32)
    t0 = np.full(p, np.nan, np.float32)
    resid = np.full(p, np.nan, np.float32)
    n_used = np.zeros(p, np.int32)
    consistent = np.zeros(p, bool)
    if g:
        pad = max(cfg.pad_groups, -(-g // cfg.pad_groups) * cfg.pad_groups)
        mat = np.full((pad, s), INVALID, np.int32)
        mat[:g] = onset_mat[idx]
        out = jax.device_get(locate_groups(
            jnp.asarray(mat), jnp.asarray(weights, jnp.float32),
            jnp.asarray(station_xy, jnp.float32),
            jnp.float32(lag_s), cfg))
        x[idx] = out["xy"][:g, 0]
        y[idx] = out["xy"][:g, 1]
        t0[idx] = out["t0"][:g]
        resid[idx] = out["residual"][:g]
        n_used[idx] = out["n_used"][:g]
        consistent[idx] = out["consistent"][:g]
    return {"x_km": x, "y_km": y, "t0": t0, "residual": resid,
            "n_used": n_used, "consistent": consistent}


def attach_location(det: dict, station_xy: np.ndarray,
                    weights: np.ndarray, lag_s: float, cfg: LocateConfig,
                    amp_fn, stats: dict | None = None) -> dict:
    """The full location/magnitude stage over an ``associate_network``
    output (with onsets): locate + size every valid group and return a
    new detections dict with the located columns attached
    (``x_km``/``y_km``/``t0``/``residual``/``n_used``/``consistent``/
    ``magnitude``/``station_weight``). With ``reject_inconsistent``,
    groups failing the moveout check are masked out of ``valid`` and the
    count lands in ``stats["moveout_rejected"]``. Shared by the batch
    replay tail and the streaming finalize — one implementation of the
    stage, two amplitude sources via ``amp_fn``.
    """
    loc = locate_detections(det, station_xy, weights, lag_s, cfg)
    out = dict(det)
    out.update(loc)
    out["station_weight"] = np.asarray(weights, np.float32)
    out["magnitude"] = magnitudes_from_onsets(
        np.asarray(det["station_onset"]), np.asarray(det["dt"]),
        np.asarray(det["valid"]), amp_fn, weights,
        np.asarray(det["station_score"]))
    if cfg.reject_inconsistent:
        was = np.asarray(det["valid"])
        now = was & loc["consistent"]
        if stats is not None:
            stats["moveout_rejected"] = int(was.sum() - now.sum())
        out["valid"] = now
    return out
