"""End-to-end FAST detection pipeline (paper Figure 2).

``detect_events`` is the host-orchestrated path used by the examples and
benchmarks (per-stage wall times, occurrence/bandpass knobs). ``detect_step``
is the fully-jitted fixed-shape core used for distributed execution and the
production-mesh dry-run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import align as align_mod
from repro.core import fingerprint as fp_mod
from repro.core import lsh as lsh_mod
from repro.core.align import AlignConfig, Events
from repro.core.fingerprint import FingerprintConfig
from repro.core.lsh import LSHConfig, Pairs


@dataclasses.dataclass(frozen=True)
class DetectConfig:
    fingerprint: FingerprintConfig = FingerprintConfig()
    lsh: LSHConfig = LSHConfig()
    align: AlignConfig = AlignConfig()


@dataclasses.dataclass
class StageTimes:
    fingerprint_s: float = 0.0
    hashgen_s: float = 0.0
    search_s: float = 0.0
    align_s: float = 0.0

    def total(self) -> float:
        return (self.fingerprint_s + self.hashgen_s + self.search_s
                + self.align_s)


def _block(x):
    jax.block_until_ready(x)
    return time.perf_counter()


def detect_events(waveforms: np.ndarray, cfg: DetectConfig,
                  n_partitions: int = 1) -> tuple[dict, list[Events],
                                                  StageTimes, dict]:
    """(n_stations, T) waveforms → network detections.

    Returns (network detections dict, per-station events, stage wall times,
    aggregate stats).
    """
    n_stations = waveforms.shape[0]
    times = StageTimes()
    stats: dict = {}
    station_events: list[Events] = []
    fcfg, lcfg, acfg = cfg.fingerprint, cfg.lsh, cfg.align

    for st in range(n_stations):
        x = jnp.asarray(waveforms[st])
        t0 = time.perf_counter()
        bits, packed = fp_mod.fingerprints_from_waveform(
            x, fcfg, key=jax.random.PRNGKey(fcfg.stft_len + st))
        t1 = _block(bits)
        times.fingerprint_s += t1 - t0

        mp = lsh_mod.hash_mappings(fcfg.fp_dim, lcfg)
        sigs = lsh_mod.signatures(bits, mp, lcfg)
        t2 = _block(sigs)
        times.hashgen_s += t2 - t1

        if n_partitions > 1:
            blocks, _ = lsh_mod.partitioned_search(bits, lcfg, n_partitions)
            pairs = Pairs(
                idx1=jnp.concatenate([b.idx1 for b in blocks]),
                idx2=jnp.concatenate([b.idx2 for b in blocks]),
                sim=jnp.concatenate([b.sim for b in blocks]),
                valid=jnp.concatenate([b.valid for b in blocks]))
        else:
            pairs = lsh_mod.candidate_pairs(sigs, lcfg)
        if lcfg.occurrence_frac > 0:
            pairs, excluded = lsh_mod.occurrence_filter(
                pairs, bits.shape[0], lcfg.occurrence_frac)
            stats[f"station{st}_excluded"] = int(excluded.sum())
        t3 = _block(pairs.valid)
        times.search_s += t3 - t2
        stats[f"station{st}_pairs"] = int(pairs.count())
        stats[f"station{st}_fingerprints"] = int(bits.shape[0])

        merged = align_mod.merge_channels(
            [(pairs.dt, pairs.idx1, pairs.sim, pairs.valid)],
            acfg.channel_threshold)
        events = align_mod.cluster_station(merged, acfg)
        t4 = _block(events.valid)
        times.align_s += t4 - t3
        stats[f"station{st}_events"] = int(events.count())
        station_events.append(events)

    t5 = time.perf_counter()
    detections = align_mod.associate_network(station_events, acfg, n_stations)
    jax.block_until_ready(detections["valid"])
    times.align_s += time.perf_counter() - t5
    stats["detections"] = int(detections["valid"].sum())
    return detections, station_events, times, stats


# ---------------------------------------------------------------------------
# jittable core for distributed execution / dry-run
# ---------------------------------------------------------------------------


def detect_step(waveform_chunk: jax.Array, med: jax.Array, mad: jax.Array,
                cfg: DetectConfig) -> dict:
    """One shard's fingerprint→search→cluster step (fixed shapes, jittable).

    ``waveform_chunk``: (chunk_samples,) — includes halo so fingerprint
    counts are static. MAD statistics are precomputed global (two-pass
    structure, §5.2). Returns triplets + events for downstream alignment.
    """
    fcfg, lcfg, acfg = cfg.fingerprint, cfg.lsh, cfg.align
    bits, _ = fp_mod.fingerprints_from_waveform(
        waveform_chunk, fcfg, med_mad=(med, mad))
    mp = lsh_mod.hash_mappings(fcfg.fp_dim, lcfg)
    sigs = lsh_mod.signatures(bits, mp, lcfg)
    pairs = lsh_mod.candidate_pairs(sigs, lcfg)
    if lcfg.occurrence_frac > 0:
        pairs, _ = lsh_mod.occurrence_filter(pairs, bits.shape[0],
                                             lcfg.occurrence_frac)
    events = align_mod.cluster_station(pairs, acfg)
    return {
        "dt": pairs.dt, "idx1": pairs.idx1, "sim": pairs.sim,
        "pair_valid": pairs.valid,
        "ev_dt": events.dt, "ev_onset": events.onset,
        "ev_score": events.score, "ev_valid": events.valid,
    }


def detect_step_sharded(waveforms: jax.Array, med: jax.Array,
                        mad: jax.Array, cfg: DetectConfig, mesh) -> dict:
    """Chunk-parallel detect_step under shard_map (DESIGN.md §3.7).

    The per-chunk pipeline is embarrassingly parallel (the paper's §6.4
    partition structure), but the XLA partitioner lowers vmapped
    segment-sums / top_k over a sharded chunk axis to involuntary
    all-gathers of the whole buffer. shard_map pins each chunk's work to its
    device: zero collectives by construction.
    """
    import functools

    from jax.sharding import PartitionSpec as P

    from repro import dist

    all_axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.shape)
    step = jax.vmap(functools.partial(detect_step, cfg=cfg),
                    in_axes=(0, None, None))

    def per_shard(wf, md, md2):
        return step(wf, md, md2)

    return dist.shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(all_axes, None), P(), P()),
        out_specs=P(all_axes),
        check_vma=False)(waveforms, med, mad)


def recall_against_truth(detections: dict, station_events: list[Events],
                         dataset, fcfg: FingerprintConfig,
                         tol_s: float = 6.0) -> dict:
    """Fraction of injected reoccurring events recovered (any station).

    An injected event counts as detected if some station-level event onset
    falls within ``tol_s`` of its arrival time at that station.
    """
    lag_s = fcfg.lag_samples / fcfg.fs
    hit = np.zeros(len(dataset.event_times), bool)
    for st, ev in enumerate(station_events):
        onsets = np.asarray(ev.onset)[np.asarray(ev.valid)]
        extents = np.asarray(ev.extent)[np.asarray(ev.valid)]
        if onsets.size == 0:
            continue
        # each cluster covers [onset, onset+extent] on idx1 and the partner
        # occurrence at idx1+dt; check both ends
        dts = np.asarray(ev.dt)[np.asarray(ev.valid)]
        cand_times = np.concatenate([
            onsets * lag_s, (onsets + extents) * lag_s,
            (onsets + dts) * lag_s])
        for i in range(len(dataset.event_times)):
            at = dataset.arrival_time(i, st)
            if np.any(np.abs(cand_times - at) < tol_s):
                hit[i] = True
    # an event is only *detectable* if its source reoccurs
    src, cnt = np.unique(dataset.event_sources, return_counts=True)
    detectable = np.isin(dataset.event_sources, src[cnt >= 2])
    n_det = int(detectable.sum())
    return {
        "recall": float(hit[detectable].sum() / max(n_det, 1)),
        "hits": int(hit[detectable].sum()),
        "detectable": n_det,
        "n_events": len(dataset.event_times),
    }
