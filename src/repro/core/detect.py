"""End-to-end FAST detection (paper Figure 2) — one core, two drivers.

There is exactly ONE guarded detection core in this repo: the streaming
fingerprint → Min-Max hash → expire/guards → insert/query chain behind
``stream.fused`` / ``stream.index.guarded_step``. This module is the
*batch* driver over it (the QuakeFlow lesson — Zhu et al. 2022: one
workflow serves both archive reprocessing and real-time monitoring):

``detect_events``
    replays an archive trace through the vmapped station-pool step
    (``stream.fused.pool_step_block``): stations are stacked on a leading
    S axis and every block of fingerprints costs ONE pooled dispatch —
    fingerprinting, hashing and index search fused into a single traced
    program — instead of the legacy host loop's four blocking syncs per
    station per stage. Every data-quality guard the streaming service has
    (gap masks, duplicate probe, saturation quarantine, the in-dispatch
    §6.5 occurrence limiter) is therefore available to batch reprocessing
    for free through the same ``StreamConfig`` knobs. The legacy
    per-station fingerprint→signatures→search→filter chain is deleted;
    its exact output is golden-pinned (``tests/golden/batch_detect.json``,
    regenerable via ``scratch/gen_golden_batch.py``) and the replay
    reproduces it bit-exactly.

``detect_step`` / ``detect_step_sharded``
    the fixed-shape jittable cell used by the production-mesh dry-run,
    now a thin wrapper over the same shared core: one
    ``index.guarded_step`` over a fresh in-trace index instead of a
    separate sort-based search implementation.

Stage wall times: the fused replay dispatch covers fingerprint + hash +
search in one program, so ``StageTimes`` attributes it ONCE — to its own
``fused_step_s`` stage — rather than pretending to split it;
``fingerprint_s`` is the §5.2 statistics pass (the two-pass structure's
first pass), ``hashgen_s`` the hash-mapping construction, ``align_s`` the
host tail (§6.5 reference filter + clustering + network association).
``search_s`` remains as a read-only legacy alias of ``fused_step_s`` for
the golden comparisons and older callers. The attribution itself is
derived from the ``repro.obsv`` span layer: ``detect_events`` brackets
each stage in a :class:`~repro.obsv.spans.SpanTracer` span and reads the
per-name totals back, so batch replays emit the same structured trace
(JSONL / ``jax.profiler``) as the streaming service when given a tracer.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import align as align_mod
from repro.core import fingerprint as fp_mod
from repro.core import lsh as lsh_mod
from repro.core.align import AlignConfig, Events
from repro.core.fingerprint import FingerprintConfig
from repro.core.locate import LocateConfig
from repro.core.lsh import LSHConfig, Pairs


@dataclasses.dataclass(frozen=True)
class DetectConfig:
    fingerprint: FingerprintConfig = FingerprintConfig()
    lsh: LSHConfig = LSHConfig()
    align: AlignConfig = AlignConfig()
    # optional location/magnitude tier (core.locate); None = association
    # stops at the pairwise network stage, bit-identical to pre-locate.
    locate: "LocateConfig | None" = None


@dataclasses.dataclass
class StageTimes:
    """Wall seconds per phase. The fused replay step (fingerprint → hash →
    insert/query as one dispatch) is attributed once, to ``fused_step_s``;
    ``search_s`` is a read-only legacy alias of it."""

    fingerprint_s: float = 0.0   # §5.2 statistics pass (stats, not bits)
    hashgen_s: float = 0.0       # hash-mapping construction
    fused_step_s: float = 0.0    # fused replay: all per-block device work
    align_s: float = 0.0         # §6.5 filter + clustering + association

    @property
    def search_s(self) -> float:
        """Legacy name for the fused replay stage (pre-span attribution
        booked the whole pooled dispatch under 'search')."""
        return self.fused_step_s

    def total(self) -> float:
        return (self.fingerprint_s + self.hashgen_s + self.fused_step_s
                + self.align_s)

    @classmethod
    def from_spans(cls, tracer) -> "StageTimes":
        """Derive stage attribution from the span layer's per-name totals
        (the spans ``detect_events`` enters around each stage)."""
        return cls(fingerprint_s=tracer.total_s("fingerprint_stats"),
                   hashgen_s=tracer.total_s("hashgen"),
                   fused_step_s=tracer.total_s("fused_step"),
                   align_s=tracer.total_s("host_tail"))


def _block(x):
    jax.block_until_ready(x)
    return time.perf_counter()


def _locate_tail(detections: dict, waveforms: np.ndarray,
                 qc_sum: np.ndarray, n_fp: int,
                 station_xy: np.ndarray, cfg: DetectConfig,
                 stats: dict) -> dict:
    """Batch-replay location/magnitude stage: QC-counter station weights
    → migration stack over the associated groups → relative magnitudes
    from whole-trace per-fingerprint peak amplitudes. Mutates ``stats``
    (adds ``moveout_rejected``) and returns a new detections dict with
    the located columns; ``reject_inconsistent`` masks failing groups
    out of ``valid``."""
    from repro.core import locate as locate_mod
    from repro.stream import index as index_mod
    fcfg = cfg.fingerprint
    n_stations = waveforms.shape[0]
    qdicts = [{name: int(qc_sum[st, k])
               for k, name in enumerate(index_mod.QC_FIELDS)}
              for st in range(n_stations)]
    weights = locate_mod.station_weights(
        qdicts, [waveforms.shape[1]] * n_stations,
        [n_fp] * n_stations, cfg.locate)
    fp_amp = [locate_mod.fingerprint_amplitudes(
        waveforms[st], fcfg.lag_samples, fcfg.window_samples)
        for st in range(n_stations)]

    def amp(st, i):
        a = fp_amp[st]
        return float(a[i]) if 0 <= i < a.size else None

    return locate_mod.attach_location(
        detections, np.asarray(station_xy, np.float32), weights,
        fcfg.lag_samples / fcfg.fs, cfg.locate, amp, stats)


def replay_config(lcfg: LSHConfig, block_fingerprints: int = 256,
                  n_buckets: int = 4096):
    """Default ``StreamConfig`` for batch replay.

    The index bucket window matches the offline sort-based search's rank
    window (``bucket_cap``) so the replayed pair set is the legacy one;
    buckets are sized generously because a batch replay holds the whole
    partition resident (no sliding window).
    """
    from repro.stream.index import StreamIndexConfig
    from repro.stream.ingest import StreamConfig
    return StreamConfig(
        block_fingerprints=block_fingerprints,
        index=StreamIndexConfig(n_buckets=n_buckets,
                                bucket_cap=lcfg.bucket_cap))


def detect_events(waveforms: np.ndarray, cfg: DetectConfig,
                  n_partitions: int = 1, scfg=None,
                  keep_pairs: bool = False,
                  tracer=None,
                  station_xy: np.ndarray | None = None
                  ) -> tuple[dict, list[Events],
                             StageTimes, dict]:
    """(n_stations, T) waveforms → network detections, via the streaming
    core (batch = replay).

    Returns (network detections dict, per-station events, stage wall
    times, aggregate stats). ``scfg`` (a ``StreamConfig``) sizes the
    replay blocks/index and switches on any of the streaming data-quality
    guards for archive reprocessing; the default reproduces the legacy
    host-loop output bit-exactly. ``n_partitions`` is accepted for API
    compatibility: the replay is partition-bounded by construction (the
    resident index *is* the §6.4 working-set bound), so the knob is a
    no-op. ``keep_pairs`` stashes the per-station post-filter ``Pairs``
    under ``stats["_station_pairs"]`` (the golden-pin hook).

    Stage attribution goes through the span layer: each stage runs inside
    a :class:`~repro.obsv.spans.SpanTracer` span and ``StageTimes`` is
    read back from the per-name totals. Pass ``tracer`` (e.g. one built
    with ``jsonl_path=...`` or ``profile_dir=...``) to capture the
    structured trace; by default a private tracer provides the totals
    only. With ``cfg.locate`` set and ``station_xy`` (S, 2) given, the
    association output additionally carries migration-located origins,
    moveout-consistency flags and relative magnitudes (see
    :mod:`repro.core.locate`); groups failing the moveout check are
    masked out of ``valid`` when ``cfg.locate.reject_inconsistent``. With ``scfg.telemetry`` on (the default), the replay also
    collects the in-dispatch ``index.QC_FIELDS`` counters — summed over
    blocks into ``stats["drops"]`` (per guard, summed over stations) with
    per-station vectors under ``stats["station<i>_qc"]`` — at no extra
    dispatch. Span wall totals stay on the tracer (deliberately out of
    ``stats``, which is compared dict-exact by the golden tests).
    """
    from repro.obsv.spans import SpanTracer
    from repro.stream import fused as fused_mod
    from repro.stream import index as index_mod
    from repro.stream.engine import host_occurrence_filter, \
        pairs_from_triplets

    waveforms = np.atleast_2d(np.asarray(waveforms, np.float32))
    n_stations = waveforms.shape[0]
    fcfg, lcfg, acfg = cfg.fingerprint, cfg.lsh, cfg.align
    if scfg is None:
        scfg = replay_config(lcfg)
    tracer = tracer or SpanTracer()
    stats: dict = {}
    n_fp = fcfg.n_fingerprints(waveforms.shape[1])

    # §5.2 statistics: the two-pass structure's first pass, with the same
    # per-station sampling key the legacy loop used (bit-exact stats).
    # The fused replay below re-derives each block's coefficients inside
    # its own dispatch, so this pass's whole-trace coefficients are spent
    # on the statistics alone — the price of running the *identical*
    # traced program as the streaming service (which owns no whole-trace
    # buffer to begin with) rather than a batch-only coeffs-in variant
    with tracer.span("fingerprint_stats"):
        meds, mads = [], []
        for st in range(n_stations):
            coeffs = fp_mod.coeffs_from_waveform(
                jnp.asarray(waveforms[st]), fcfg)
            med, mad = fp_mod.mad_stats(
                coeffs, fcfg.mad_sample_rate,
                jax.random.PRNGKey(fcfg.stft_len + st))
            meds.append(med)
            mads.append(mad)
        _block(mads[-1])
    with tracer.span("hashgen"):
        mappings = lsh_mod.hash_mappings(fcfg.fp_dim, lcfg)
        _block(mappings)

    # fused replay: ONE pooled dispatch per block for all S stations;
    # counters ride inside the same dispatch when telemetry is on
    ctr = 1 if getattr(scfg, "telemetry", True) else 0
    mp = getattr(scfg, "max_pairs_per_block", 0)
    ver = getattr(scfg, "verify_code", 0)
    mj = getattr(scfg, "verify_min_jaccard", 0.0)
    icfg = (scfg.effective_index(fcfg.fp_dim)
            if hasattr(scfg, "effective_index") else scfg.index)
    qc_sum = np.zeros((n_stations, len(index_mod.QC_FIELDS)), np.int64)
    state = fused_mod.init_pool_state(
        [index_mod.init_index(lcfg, icfg) for _ in range(n_stations)],
        fcfg.halo_samples, meds, mads)
    b = scfg.block_fingerprints
    bs = fcfg.block_samples(b)
    tri: list[list[np.ndarray]] = [[] for _ in range(n_stations)]
    for base in range(0, n_fp, b):
        with tracer.span("fused_step", base=base):
            n_valid = min(b, n_fp - base)
            start = base * fcfg.lag_samples
            block = np.zeros((n_stations, bs), np.float32)
            seg = waveforms[:, start:start + bs]
            block[:, :seg.shape[1]] = seg
            vmask = np.broadcast_to(np.arange(b) < n_valid,
                                    (n_stations, b))
            state, pairs, qc = fused_mod.pool_step_block(
                state, jnp.asarray(block), mappings, jnp.int32(base),
                jnp.asarray(vmask), fcfg, lcfg, scfg.window_fingerprints,
                scfg.saturation_limit, scfg.dup_sig_tables, scfg.occ_limit,
                ctr, mp, ver, mj)
            # one transfer + one sync for the whole pooled step output
            (i1, i2, sim, pv), qc = jax.device_get(
                ((pairs.idx1, pairs.idx2, pairs.sim, pairs.valid), qc))
            qc_sum += np.asarray(qc, np.int64)
            for st in range(n_stations):
                m = pv[st]
                if m.any():
                    tri[st].append(np.stack(
                        [i1[st][m], i2[st][m], sim[st][m]],
                        axis=1).astype(np.int64))

    # host tail: §6.5 reference filter + channel merge + clustering,
    # shared with the streaming finalize
    with tracer.span("host_tail"):
        station_events: list[Events] = []
        station_pairs: list[Pairs] = []
        for st in range(n_stations):
            tri_st = (np.concatenate(tri[st], axis=0) if tri[st]
                      else np.zeros((0, 3), np.int64))
            pairs = pairs_from_triplets(tri_st)
            if lcfg.occurrence_frac > 0 and n_fp > 0:
                pairs, excluded = host_occurrence_filter(pairs, n_fp, lcfg)
                stats[f"station{st}_excluded"] = int(excluded.sum())
            stats[f"station{st}_pairs"] = int(pairs.count())
            stats[f"station{st}_fingerprints"] = n_fp
            merged = align_mod.merge_channels(
                [(pairs.dt, pairs.idx1, pairs.sim, pairs.valid)],
                acfg.channel_threshold)
            events = align_mod.cluster_station(merged, acfg)
            stats[f"station{st}_events"] = int(events.count())
            station_events.append(events)
            station_pairs.append(pairs)

        with_locate = cfg.locate is not None and station_xy is not None \
            and n_stations >= 2
        detections = align_mod.associate_network(
            station_events, acfg, n_stations, with_onsets=with_locate)
        jax.block_until_ready(detections["valid"])
        if with_locate:
            detections = _locate_tail(detections, waveforms, qc_sum, n_fp,
                                      station_xy, cfg, stats)
    times = StageTimes.from_spans(tracer)
    stats["detections"] = int(np.asarray(detections["valid"]).sum())
    if ctr:
        stats["drops"] = {
            name: int(qc_sum[:, k].sum())
            for k, name in enumerate(index_mod.QC_FIELDS)}
        for st in range(n_stations):
            stats[f"station{st}_qc"] = {
                name: int(qc_sum[st, k])
                for k, name in enumerate(index_mod.QC_FIELDS)}
    if keep_pairs:
        stats["_station_pairs"] = station_pairs
    return detections, station_events, times, stats


# ---------------------------------------------------------------------------
# jittable core for distributed execution / dry-run
# ---------------------------------------------------------------------------


def detect_step(waveform_chunk: jax.Array, med: jax.Array, mad: jax.Array,
                cfg: DetectConfig, icfg=None, window: int = 0,
                saturation: int = 0, dup_tables: int = 0,
                occ_limit: int = 0) -> dict:
    """One shard's detection step (fixed shapes, jittable) — a wrapper
    over the shared streaming core.

    ``waveform_chunk``: (chunk_samples,) — includes halo so fingerprint
    counts are static. MAD statistics are precomputed global (two-pass
    structure, §5.2). The chunk's fingerprints go through one
    ``index.guarded_step`` against a fresh in-trace index (the same
    insert/query, guard and limiter program as the streaming hot path —
    no separate batch search implementation), then the host-reference
    §6.5 filter and clustering. The quality knobs (``saturation``,
    ``dup_tables``, ``occ_limit``) default off; ``icfg`` sizes the
    in-trace index (``occ_limit`` > 0 needs ``icfg.occ_slots``).
    Returns triplets + events for downstream alignment.
    """
    from repro.stream import index as index_mod
    fcfg, lcfg, acfg = cfg.fingerprint, cfg.lsh, cfg.align
    if icfg is None:
        from repro.stream.index import StreamIndexConfig
        icfg = StreamIndexConfig(n_buckets=4096, bucket_cap=lcfg.bucket_cap)
    assert occ_limit == 0 or icfg.occ_slots > 0, \
        "occ_limit needs icfg.occ_slots (the partner-count ring)"
    bits, _ = fp_mod.fingerprints_from_waveform(
        waveform_chunk, fcfg, med_mad=(med, mad))
    n = bits.shape[0]
    mappings = lsh_mod.hash_mappings(fcfg.fp_dim, lcfg)
    sigs, buckets = lsh_mod.signatures_and_buckets(bits, mappings, lcfg,
                                                   icfg.n_buckets)
    ids = jnp.arange(n, dtype=jnp.int32)
    _, pairs, _ = index_mod.guarded_step(
        index_mod.init_index(lcfg, icfg), sigs, buckets, ids, None, lcfg,
        window, saturation=saturation, dup_tables=dup_tables,
        occ_limit=occ_limit)
    if lcfg.occurrence_frac > 0:
        pairs, _ = lsh_mod.occurrence_filter(pairs, n, lcfg.occurrence_frac)
    events = align_mod.cluster_station(pairs, acfg)
    return {
        "dt": pairs.dt, "idx1": pairs.idx1, "sim": pairs.sim,
        "pair_valid": pairs.valid,
        "ev_dt": events.dt, "ev_onset": events.onset,
        "ev_score": events.score, "ev_valid": events.valid,
    }


def detect_step_sharded(waveforms: jax.Array, med: jax.Array,
                        mad: jax.Array, cfg: DetectConfig, mesh,
                        **knobs) -> dict:
    """Chunk-parallel detect_step under shard_map (DESIGN.md §3.7).

    The per-chunk pipeline is embarrassingly parallel (the paper's §6.4
    partition structure), but the XLA partitioner lowers vmapped
    segment-sums / top_k over a sharded chunk axis to involuntary
    all-gathers of the whole buffer. shard_map pins each chunk's work to
    its device: zero collectives by construction. ``knobs`` forward the
    quality/limiter parameters to ``detect_step``.
    """
    import functools

    from jax.sharding import PartitionSpec as P

    from repro import dist

    all_axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.shape)
    step = jax.vmap(functools.partial(detect_step, cfg=cfg, **knobs),
                    in_axes=(0, None, None))

    def per_shard(wf, md, md2):
        return step(wf, md, md2)

    return dist.shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(all_axes, None), P(), P()),
        out_specs=P(all_axes),
        check_vma=False)(waveforms, med, mad)


def recall_against_truth(detections: dict, station_events: list[Events],
                         dataset, fcfg: FingerprintConfig,
                         tol_s: float = 6.0) -> dict:
    """Fraction of injected reoccurring events recovered (any station).

    An injected event counts as detected if some station-level event onset
    falls within ``tol_s`` of its arrival time at that station.
    """
    lag_s = fcfg.lag_samples / fcfg.fs
    hit = np.zeros(len(dataset.event_times), bool)
    for st, ev in enumerate(station_events):
        onsets = np.asarray(ev.onset)[np.asarray(ev.valid)]
        extents = np.asarray(ev.extent)[np.asarray(ev.valid)]
        if onsets.size == 0:
            continue
        # each cluster covers [onset, onset+extent] on idx1 and the partner
        # occurrence at idx1+dt; check both ends
        dts = np.asarray(ev.dt)[np.asarray(ev.valid)]
        cand_times = np.concatenate([
            onsets * lag_s, (onsets + extents) * lag_s,
            (onsets + dts) * lag_s])
        for i in range(len(dataset.event_times)):
            at = dataset.arrival_time(i, st)
            if np.any(np.abs(cand_times - at) < tol_s):
                hit[i] = True
    # an event is only *detectable* if its source reoccurs
    src, cnt = np.unique(dataset.event_sources, return_counts=True)
    detectable = np.isin(dataset.event_sources, src[cnt >= 2])
    n_det = int(detectable.sum())
    return {
        "recall": float(hit[detectable].sum() / max(n_det, 1)),
        "hits": int(hit[detectable].sum()),
        "detectable": n_det,
        "n_events": len(dataset.event_times),
    }
