"""MinHash / Min-Max LSH similarity search (paper §6), TPU-native.

The paper's hash-table search is re-expressed as a sort-based group-by
(DESIGN.md §3.1): per hash table, fingerprints sharing a signature form a
run of equal keys after ``lax.sort``; candidate pairs are emitted from a
bounded rank-window (``bucket_cap``) within each run. Mega-buckets — the
exact skew pathology the paper battles in §6.3/§6.5 — are therefore capped
structurally, and the paper's own remedies (more hash functions, the
occurrence filter) make the cap a no-op on healthy data.

Everything is static-shape / mask-based so the whole search jits, shards
(fingerprint axis), and dry-runs on the production meshes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.utils import (fold_hashes, hash_combine, hash_u32, mix32,
                         segment_ids_from_starts, segment_starts)

INVALID = np.int32(np.iinfo(np.int32).max)


@dataclasses.dataclass(frozen=True)
class LSHConfig:
    n_tables: int = 100          # t
    n_funcs: int = 8             # k  (Min-Max evaluates k/2 hash fns)
    n_matches: int = 2           # m  (matches out of t required)
    use_minmax: bool = True      # §6.2 (False = baseline MinHash)
    bucket_cap: int = 8          # rank window per bucket (TPU adaptation)
    min_dt: int = 16             # self-match exclusion (overlapping windows)
    occurrence_frac: float = 0.01  # §6.5 (<=0 disables)
    seed: int = 1234
    use_pallas: bool = False

    @property
    def funcs_per_table(self) -> int:
        return self.n_funcs // 2 if self.use_minmax else self.n_funcs

    @property
    def n_hash_fns(self) -> int:
        return self.n_tables * self.funcs_per_table


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Pairs:
    """Fixed-size masked set of similar-fingerprint pairs.

    idx1 < idx2 where valid; sim = number of hash tables in which the pair
    collided (the paper's similarity proxy and output triplet format §7.2).
    """

    idx1: jax.Array
    idx2: jax.Array
    sim: jax.Array
    valid: jax.Array

    @property
    def dt(self) -> jax.Array:
        return jnp.where(self.valid, self.idx2 - self.idx1, INVALID)

    def count(self) -> jax.Array:
        return self.valid.sum()


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class VerifiedPairs:
    """Compacted pair emission with the exact-similarity verify channel.

    Same masked-pair contract as ``Pairs`` (idx1 < idx2 where valid;
    ``sim`` = number of hash tables matched — the paper's similarity
    proxy), plus ``jac``: exact Jaccard similarity of the two bit-packed
    fingerprints, scored in-dispatch from the index's packed ring
    (ISSUE 8 verify epilogue; all-zero when verification is disabled).
    The arrays are O(max_pairs_per_block), not O(t * N * cap) — this is
    the shape that actually crosses the device→host boundary.
    """

    idx1: jax.Array
    idx2: jax.Array
    sim: jax.Array
    jac: jax.Array
    valid: jax.Array

    @property
    def dt(self) -> jax.Array:
        return jnp.where(self.valid, self.idx2 - self.idx1, INVALID)

    def count(self) -> jax.Array:
        return self.valid.sum()


# ---------------------------------------------------------------------------
# hash mappings + signatures (§6.1–6.2)
# ---------------------------------------------------------------------------


def hash_mappings(d: int, cfg: LSHConfig) -> jax.Array:
    """(d, n_hash_fns) int32 hash values in [0, 2**31).

    The splitmix-style mixer replaces murmurhash (DESIGN.md §3.8): each
    column is an independent random mapping of fingerprint dimensions.
    """
    dims = jnp.arange(d, dtype=jnp.uint32)[:, None]
    fns = jnp.arange(cfg.n_hash_fns, dtype=jnp.uint32)[None, :]
    h = hash_combine(hash_u32(dims, cfg.seed), hash_u32(fns, cfg.seed ^ 0xABCD))
    return (mix32(h) >> 1).astype(jnp.int32)


def signatures(fp: jax.Array, mappings: jax.Array, cfg: LSHConfig,
               valid: jax.Array | None = None) -> jax.Array:
    """Binary fingerprints (N, D) → per-table signatures (N, t) uint32."""
    n = fp.shape[0]
    t, f = cfg.n_tables, cfg.funcs_per_table
    mins, maxs = ops.minmax_hash(fp, mappings, use_pallas=cfg.use_pallas)
    mins = mins.reshape(n, t, f).astype(jnp.uint32)
    if cfg.use_minmax:
        maxs = maxs.reshape(n, t, f).astype(jnp.uint32)
        per_fn = hash_combine(mins, maxs)  # (N, t, f)
    else:
        per_fn = mins
    sig = fold_hashes(per_fn, axis=-1)  # (N, t)
    if valid is not None:
        sig = jnp.where(valid[:, None], sig, _filler_signatures(n, t, cfg))
    return sig


def minhash_signatures_baseline(fp: jax.Array, cfg: LSHConfig) -> jax.Array:
    """Unoptimized MinHash (paper baseline): k hash fns per table."""
    base = dataclasses.replace(cfg, use_minmax=False)
    mp = hash_mappings(fp.shape[1], base)
    return signatures(fp, mp, base)


# ---------------------------------------------------------------------------
# bucket addressing (shared by the streaming index and the fused kernel)
# ---------------------------------------------------------------------------


def bucket_salts(n_tables: int, seed: int) -> jax.Array:
    """(t,) uint32 per-table salts for bucket addressing."""
    return hash_u32(jnp.arange(n_tables, dtype=jnp.uint32), seed ^ 0xB0C4E7)


def bucket_ids(sigs: jax.Array, n_buckets: int, seed: int) -> jax.Array:
    """(N, t) signatures → (N, t) bucket indices, salted per table."""
    salts = bucket_salts(sigs.shape[1], seed)
    h = hash_combine(sigs.astype(jnp.uint32), salts[None, :])
    return (h & jnp.uint32(n_buckets - 1)).astype(jnp.int32)


def _filler_signatures(n: int, t: int, cfg: LSHConfig) -> jax.Array:
    """Unique-ish (N, t) signatures for invalid rows so they never collide."""
    row = hash_u32(jnp.arange(n, dtype=jnp.uint32), cfg.seed ^ 0x5EED)
    tbl = hash_u32(jnp.arange(t, dtype=jnp.uint32), cfg.seed ^ 0x7AB1)
    return hash_combine(row[:, None], tbl[None, :])


def signatures_and_buckets(
    fp: jax.Array, mappings: jax.Array, cfg: LSHConfig, n_buckets: int,
    valid: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fingerprints (N, D) → (signatures (N, t), bucket ids (N, t)).

    The streaming hot path needs both the per-table signature and its
    bucket address; computing them together means the signature fold and
    the bucket hash run once per step instead of once in ``insert`` and
    again in ``query``. With ``use_pallas`` the fold + addressing are fused
    into the Min-Max kernel epilogue (``ops.minmax_sig_buckets``); the jnp
    composition below is the bit-exact oracle.
    """
    n = fp.shape[0]
    t = cfg.n_tables
    if cfg.use_pallas:
        sig, bkt = ops.minmax_sig_buckets(
            fp, mappings, bucket_salts(t, cfg.seed),
            use_minmax=cfg.use_minmax, n_buckets=n_buckets)
    else:
        sig = signatures(fp, mappings, cfg)
        bkt = bucket_ids(sig, n_buckets, cfg.seed)
    if valid is not None:
        filler = _filler_signatures(n, t, cfg)
        sig = jnp.where(valid[:, None], sig, filler)
        bkt = jnp.where(valid[:, None], bkt,
                        bucket_ids(filler, n_buckets, cfg.seed))
    return sig, bkt


# ---------------------------------------------------------------------------
# sort-based bucket group-by → candidate pairs (§6.1 search, TPU-native)
# ---------------------------------------------------------------------------


def _pairs_one_table(keys: jax.Array, cap: int) -> tuple[jax.Array, jax.Array]:
    """(N,) signature keys → (cap*N,) canonical pair endpoints (masked).

    Pairs are emitted between elements at rank distance 1..cap inside runs
    of equal keys. Invalid slots get INVALID endpoints.
    """
    n = keys.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    sk, si = jax.lax.sort((keys, idx), num_keys=1)
    a_all, b_all = [], []
    for w in range(1, cap + 1):
        same = sk[w:] == sk[:-w]
        a = jnp.where(same, si[:-w], INVALID)
        b = jnp.where(same, si[w:], INVALID)
        pad = jnp.full((w,), INVALID, jnp.int32)
        a_all.append(jnp.concatenate([a, pad]))
        b_all.append(jnp.concatenate([b, pad]))
    a = jnp.stack(a_all).reshape(-1)
    b = jnp.stack(b_all).reshape(-1)
    lo = jnp.minimum(a, b)
    hi = jnp.maximum(a, b)
    return lo, hi


def count_pair_multiplicity(lo: jax.Array, hi: jax.Array,
                            n_matches: int) -> Pairs:
    """Sort all (lo, hi) pairs; count duplicates (= #tables matched)."""
    p = lo.shape[0]
    lo_s, hi_s = jax.lax.sort((lo, hi), num_keys=2)
    starts = segment_starts(lo_s) | segment_starts(hi_s)
    seg = segment_ids_from_starts(starts)
    ones = (lo_s != INVALID).astype(jnp.int32)
    counts = jax.ops.segment_sum(ones, seg, num_segments=p)
    sim = counts[seg]
    valid = starts & (lo_s != INVALID) & (sim >= n_matches)
    return Pairs(idx1=lo_s, idx2=hi_s, sim=jnp.where(valid, sim, 0),
                 valid=valid)


def finalize_pairs(lo: jax.Array, hi: jax.Array, cfg: LSHConfig) -> Pairs:
    """Canonical endpoint streams → thresholded Pairs (shared batch/stream).

    Applies the self-match exclusion (``min_dt``) and the m-of-t collision
    threshold (``n_matches``). ``lo``/``hi`` are flat per-table emission
    streams with INVALID in masked slots; a pair's similarity is its
    multiplicity across the streams (= #tables in which it collided).
    Both the offline sort-based search and the streaming index query end
    in exactly this reduction.
    """
    if cfg.min_dt > 0:  # self-match exclusion
        ok = (hi - lo) >= cfg.min_dt
        lo = jnp.where(ok, lo, INVALID)
        hi = jnp.where(ok, hi, INVALID)
    return count_pair_multiplicity(lo, hi, cfg.n_matches)


@functools.partial(jax.jit, static_argnames=("cfg",))
def candidate_pairs(sigs: jax.Array, cfg: LSHConfig) -> Pairs:
    """(N, t) signatures → Pairs of size t * bucket_cap * N (masked)."""
    n, t = sigs.shape
    lo, hi = jax.vmap(lambda k: _pairs_one_table(k, cfg.bucket_cap),
                      in_axes=1)(sigs)  # (t, cap*N) each
    return finalize_pairs(lo.reshape(-1), hi.reshape(-1), cfg)


# ---------------------------------------------------------------------------
# occurrence filter (§6.5)
# ---------------------------------------------------------------------------


def occurrence_filter(pairs: Pairs, n_fp: int, frac: float,
                      limit: int | None = None) -> tuple[Pairs, jax.Array]:
    """Drop fingerprints matching more than ``frac`` of the partition.

    Also drops their match partners (the paper excludes "this fingerprint
    as well as its neighbors"). Returns (filtered pairs, excluded mask).

    ``n_fp`` sizes the id space (segment count); ``limit`` overrides the
    occurrence cap when the partition whose fraction is meant differs from
    the id space — the rolling streaming filter counts occurrences over a
    window of ids whose partners may reach back a further lookback span.
    """
    v = pairs.valid
    i1 = jnp.where(v, pairs.idx1, 0)
    i2 = jnp.where(v, pairs.idx2, 0)
    w = v.astype(jnp.int32)
    cnt = (jax.ops.segment_sum(w, i1, num_segments=n_fp)
           + jax.ops.segment_sum(w, i2, num_segments=n_fp))
    limit = jnp.int32(max(1, int(frac * n_fp)) if limit is None
                      else max(1, int(limit)))
    excluded = cnt > limit
    # neighbors of excluded fingerprints
    nb1 = jax.ops.segment_max(jnp.where(v, excluded[i2].astype(jnp.int32), 0),
                              i1, num_segments=n_fp)
    nb2 = jax.ops.segment_max(jnp.where(v, excluded[i1].astype(jnp.int32), 0),
                              i2, num_segments=n_fp)
    excluded_full = excluded | (nb1 > 0) | (nb2 > 0)
    new_valid = v & ~excluded_full[i1] & ~excluded_full[i2]
    out = Pairs(idx1=pairs.idx1, idx2=pairs.idx2,
                sim=jnp.where(new_valid, pairs.sim, 0), valid=new_valid)
    return out, excluded_full


# ---------------------------------------------------------------------------
# whole search (+ partitioned variant, §6.4)
# ---------------------------------------------------------------------------


def search(fp: jax.Array, cfg: LSHConfig,
           valid: jax.Array | None = None) -> tuple[Pairs, dict]:
    """Fingerprints (N, D) → similar pairs + search statistics."""
    n = fp.shape[0]
    mp = hash_mappings(fp.shape[1], cfg)
    sigs = signatures(fp, mp, cfg, valid=valid)
    pairs = candidate_pairs(sigs, cfg)
    stats = {"pre_filter_pairs": pairs.count()}
    if cfg.occurrence_frac > 0:
        pairs, excluded = occurrence_filter(pairs, n, cfg.occurrence_frac)
        stats["excluded_fingerprints"] = excluded.sum()
    stats["pairs"] = pairs.count()
    stats.update(bucket_stats(sigs))
    return pairs, stats


def partitioned_search(fp: jax.Array, cfg: LSHConfig,
                       n_partitions: int) -> tuple[list[Pairs], dict]:
    """§6.4: memory-bounded search over partition pair-blocks.

    Signatures are computed once; candidate generation sorts only the keys
    of one partition-block (p, q) at a time, so the working set shrinks by
    ~n_partitions while results stay exactly the union over blocks (each
    cross pair lives in exactly one block).
    """
    n = fp.shape[0]
    assert n % n_partitions == 0, (n, n_partitions)
    psize = n // n_partitions
    mp = hash_mappings(fp.shape[1], cfg)
    sigs = signatures(fp, mp, cfg)

    @functools.partial(jax.jit, static_argnames=("intra",))
    def block(sig_a, base_a, sig_b, base_b, intra: bool):
        if intra:
            sig = sig_a
            gids = base_a + jnp.arange(psize, dtype=jnp.int32)
        else:
            sig = jnp.concatenate([sig_a, sig_b])
            gids = jnp.concatenate([
                base_a + jnp.arange(psize, dtype=jnp.int32),
                base_b + jnp.arange(psize, dtype=jnp.int32)])
        pr = candidate_pairs(sig, cfg)
        # local → global ids; for cross blocks keep only cross pairs
        g1 = jnp.where(pr.valid, gids[jnp.where(pr.valid, pr.idx1, 0)], INVALID)
        g2 = jnp.where(pr.valid, gids[jnp.where(pr.valid, pr.idx2, 0)], INVALID)
        val = pr.valid
        if not intra:
            cross = ((pr.idx1 < psize) & (pr.idx2 >= psize))
            val = val & cross
        lo = jnp.minimum(g1, g2)
        hi = jnp.maximum(g1, g2)
        if cfg.min_dt > 0:
            val = val & ((hi - lo) >= cfg.min_dt)
        return Pairs(idx1=jnp.where(val, lo, INVALID),
                     idx2=jnp.where(val, hi, INVALID),
                     sim=jnp.where(val, pr.sim, 0), valid=val)

    out: list[Pairs] = []
    for p in range(n_partitions):
        sa = sigs[p * psize:(p + 1) * psize]
        for q in range(p, n_partitions):
            sb = sigs[q * psize:(q + 1) * psize]
            out.append(block(sa, jnp.int32(p * psize), sb,
                             jnp.int32(q * psize), p == q))
    stats = {
        "blocks": len(out),
        "block_sort_keys": (2 * psize) * cfg.n_tables,
        "working_set_bytes": 2 * psize * cfg.n_tables
        * (4 + 4) * cfg.bucket_cap,
    }
    return out, stats


# ---------------------------------------------------------------------------
# diagnostics (§6.3) + exact verification
# ---------------------------------------------------------------------------


def bucket_stats(sigs: jax.Array) -> dict:
    """Skew diagnostics: selectivity, lookups/query, largest-bucket mass."""
    n, t = sigs.shape

    def per_table(keys):
        sk = jax.lax.sort(keys)
        starts = segment_starts(sk)
        seg = segment_ids_from_starts(starts)
        sizes = jax.ops.segment_sum(jnp.ones_like(sk, jnp.int32), seg,
                                    num_segments=n)
        lookups = (sizes * (sizes - 1)).sum()  # sum_b s(s-1)
        return lookups, sizes.max()

    lookups, max_bucket = jax.vmap(per_table, in_axes=1)(sigs)
    avg_lookups_per_query = lookups.sum() / (n * t)
    return {
        "selectivity": avg_lookups_per_query / n,
        "avg_lookups_per_query": avg_lookups_per_query,
        "max_bucket": max_bucket.max(),
    }


def verify_jaccard(packed: jax.Array, pairs: Pairs,
                   use_pallas: bool = False) -> jax.Array:
    """Exact Jaccard for candidate pairs from packed fingerprints."""
    i1 = jnp.where(pairs.valid, pairs.idx1, 0)
    i2 = jnp.where(pairs.valid, pairs.idx2, 0)
    sim = ops.jaccard_popcount(packed[i1], packed[i2], use_pallas=use_pallas)
    return jnp.where(pairs.valid, sim, 0.0)


def brute_force_pairs(fp: jax.Array, threshold: float,
                      min_dt: int = 0) -> np.ndarray:
    """O(N²) exact Jaccard join (test/benchmark oracle). Returns (P, 3)."""
    fpb = np.asarray(fp, dtype=bool)
    inter = (fpb.astype(np.int32) @ fpb.T.astype(np.int32))
    sizes = fpb.sum(1)
    union = sizes[:, None] + sizes[None, :] - inter
    jac = np.where(union > 0, inter / np.maximum(union, 1), 0.0)
    n = fpb.shape[0]
    iu = np.triu_indices(n, k=max(1, min_dt))
    mask = jac[iu] >= threshold
    return np.stack([iu[0][mask], iu[1][mask], jac[iu][mask]], axis=1)
