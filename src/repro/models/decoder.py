"""Unified decoder covering all assigned architecture families.

Layers are stacked along a leading L dim and executed with ``lax.scan`` so
HLO size (and CPU compile time for the 512-device dry-run) is independent of
depth. Hybrid (Zamba2) stacks scan groups of Mamba2 layers with a *shared*
attention block invoked between groups.

The vocabulary is padded to a multiple of 2048 so embeddings / logits shard
cleanly over the ``model`` axis; the CE loss is computed in sequence chunks
so full (B, S, V) logits never materialize.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import dist
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.utils import round_up

VOCAB_PAD = 2048


def padded_vocab(cfg: ModelConfig) -> int:
    return round_up(cfg.vocab_size, VOCAB_PAD)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _norm(key, shape, scale=0.02, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _layer_param_shapes(cfg: ModelConfig) -> dict:
    """Per-layer parameter shapes (without the leading L stack dim)."""
    d, hd, hq, hkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    out: dict = {}
    if cfg.block_kind == "attn":
        attn = {"ln": (d,), "wq": (d, hq, hd), "wk": (d, hkv, hd),
                "wv": (d, hkv, hd), "wo": (hq, hd, d)}
        if cfg.qkv_bias:
            attn.update({"bq": (hq, hd), "bk": (hkv, hd), "bv": (hkv, hd)})
        out["attn"] = attn
        if cfg.is_moe:
            moe = {"ln": (d,), "router": (d, cfg.n_experts),
                   "wg": (cfg.n_experts, d, cfg.expert_ff),
                   "wu": (cfg.n_experts, d, cfg.expert_ff),
                   "wd": (cfg.n_experts, cfg.expert_ff, d)}
            if cfg.n_shared_experts:
                sf = cfg.n_shared_experts * cfg.expert_ff
                moe.update({"swg": (d, sf), "swu": (d, sf), "swd": (sf, d)})
            out["moe"] = moe
        else:
            out["mlp"] = {"ln": (d,), "wg": (d, cfg.d_ff),
                          "wu": (d, cfg.d_ff), "wd": (cfg.d_ff, d)}
    elif cfg.block_kind == "mamba1":
        di, n, r = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
        out["ssm"] = {"ln": (d,), "in_proj": (d, 2 * di),
                      "conv_w": (cfg.ssm_conv, di), "conv_b": (di,),
                      "x_proj": (di, r + 2 * n), "dt_w": (r, di),
                      "dt_bias": (di,), "a_log": (di, n), "d_skip": (di,),
                      "out_proj": (di, d)}
    elif cfg.block_kind == "mamba2":
        di, n, hh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        conv_dim = di + 2 * n
        out["ssm"] = {"ln": (d,), "in_proj": (d, 2 * di + 2 * n + hh),
                      "conv_w": (cfg.ssm_conv, conv_dim),
                      "conv_b": (conv_dim,), "dt_bias": (hh,),
                      "a_log": (hh,), "d_skip": (hh,), "out_ln": (di,),
                      "out_proj": (di, d)}
    else:
        raise ValueError(cfg.block_kind)
    return out


def _shared_attn_shapes(cfg: ModelConfig) -> dict:
    d, hd, hq, hkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    return {"ln": (d,), "wq": (d, hq, hd), "wk": (d, hkv, hd),
            "wv": (d, hkv, hd), "wo": (hq, hd, d)}


def param_shapes(cfg: ModelConfig) -> dict:
    """Full parameter tree as shape tuples (stacked layer dim first)."""
    v = padded_vocab(cfg)
    d = cfg.d_model
    tree: dict = {
        "embed": (v, d),
        "final_ln": (d,),
        "lm_head": (d, v),
        "layers": jax.tree.map(
            lambda shp: (cfg.n_layers, *shp), _layer_param_shapes(cfg),
            is_leaf=lambda x: isinstance(x, tuple)),
    }
    if cfg.shared_attn_every:
        tree["shared_attn"] = _shared_attn_shapes(cfg)
        if cfg.d_ff:
            # Zamba2's shared block is a full transformer block (attn+MLP)
            tree["shared_mlp"] = {"ln": (d,), "wg": (d, cfg.d_ff),
                                  "wu": (d, cfg.d_ff), "wd": (cfg.d_ff, d)}
    if cfg.frontend == "patch":
        tree["patch_proj"] = (d, d)
    return tree


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    shapes = param_shapes(cfg)
    leaves, treedef = jax.tree.flatten(
        shapes, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(leaves))

    def make(k, shp):
        scale = 0.02
        if len(shp) >= 2:
            scale = 1.0 / math.sqrt(shp[-2] if len(shp) == 2 else shp[-2])
        return _norm(k, shp, min(scale, 0.02), cfg.pdtype)

    params = jax.tree.unflatten(treedef, [make(k, s)
                                          for k, s in zip(keys, leaves)])
    # SSM-specific sane initializations (dt bias, A_log) + unit norms
    def fix(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name == "a_log":
            vals = jnp.log(jnp.arange(1, x.shape[-1] + 1, dtype=jnp.float32))
            return jnp.broadcast_to(vals, x.shape).astype(x.dtype)
        if name == "dt_bias":
            return jnp.full(x.shape, -4.6, x.dtype)  # softplus⁻¹(0.01)
        if name in ("ln", "out_ln", "final_ln"):
            return jnp.ones_like(x)
        return x

    return jax.tree_util.tree_map_with_path(fix, params)


def param_sharding_rules(cfg: ModelConfig) -> dict:
    """PartitionSpec entries per parameter (same tree shape as params).

    Mamba2 keeps its fused in_proj/conv replicated: the fused output dim
    mixes (z | x | B | C | dt) whose boundaries don't align with shard
    boundaries (DESIGN.md §4); Mamba1's clean 2·d_inner split stays TP.

    Under the FSDP layout (dist.layout("fsdp")) every non-embedding param
    shards its largest dim over pod×data×model and is all-gathered at use;
    embeddings/lm_head stay vocab-sharded (the "vocab" alias survives).
    """
    from repro import dist as _dist
    m2 = cfg.block_kind == "mamba2"
    fsdp = _dist.current_layout() == "fsdp"

    def spec_for(path_names: tuple[str, ...], shp: tuple[int, ...]):
        name = path_names[-1]
        stacked = path_names[0] == "layers"
        lead = (None,) if stacked else ()
        if fsdp and name not in ("embed", "lm_head"):
            dims = shp[1:] if stacked else shp
            if not dims:
                return lead + (None,) * 0
            big = max(range(len(dims)), key=lambda i: dims[i])
            body = tuple(("pod", "data", "model") if i == big else None
                         for i in range(len(dims)))
            return lead + body
        body: tuple
        if name == "embed":
            body = ("vocab", ("pod", "data")) if fsdp else ("vocab", None)
        elif name == "lm_head":
            body = ((("pod", "data")), "vocab") if fsdp else (None, "vocab")
        elif name in ("wq", "wk", "wv"):
            body = (None, "model", None)
        elif name == "wo":
            body = ("model", None, None)
        elif name in ("bq", "bk", "bv"):
            body = ("model", None)
        elif name in ("wg", "wu"):
            body = (("model", None, None) if len(shp) - len(lead) == 3
                    else (None, "model"))
        elif name == "wd":
            body = (("model", None, None) if len(shp) - len(lead) == 3
                    else ("model", None))
        elif name in ("swg", "swu"):
            body = (None, "model")
        elif name == "swd":
            body = ("model", None)
        elif name == "in_proj":
            body = (None, None) if m2 else (None, "model")
        elif name == "out_proj":
            body = ("model", None)
        elif name == "conv_w":
            body = (None, None) if m2 else (None, "model")
        elif name == "conv_b":
            body = (None,) if m2 else ("model",)
        elif name == "x_proj":
            body = ("model", None)
        elif name == "dt_w":
            body = (None, "model")
        elif name == "a_log":
            body = (("model", None) if len(shp) - len(lead) == 2
                    else (None,))
        elif name == "d_skip":
            body = (None,) if m2 else ("model",)
        elif name == "dt_bias":
            body = (None,) if m2 else ("model",)
        elif name == "out_ln":
            body = (None,)
        else:
            body = tuple(None for _ in range(len(shp) - len(lead)))
        full = lead + body
        full = full + tuple(None for _ in range(len(shp) - len(full)))
        return full[: len(shp)]

    shapes = param_shapes(cfg)

    def walk(path, node):
        if isinstance(node, tuple):
            return spec_for(path, node)
        return {k: walk(path + (k,), v) for k, v in node.items()}

    return walk((), shapes)


# ---------------------------------------------------------------------------
# forward (training / scoring)
# ---------------------------------------------------------------------------


def _block_body(cfg: ModelConfig, impl: str):
    def body(carry, layer_params):
        x, aux = carry
        pos = jnp.arange(x.shape[1])
        if cfg.block_kind == "attn":
            if cfg.parallel_block and not cfg.is_moe:
                x = L.parallel_attn_mlp_block(
                    layer_params["attn"], layer_params["mlp"], x, cfg, pos,
                    impl=impl)
            else:
                x = L.attention_block(layer_params["attn"], x, cfg, pos,
                                      impl=impl)
                if cfg.is_moe:
                    x, a = L.moe_block(layer_params["moe"], x, cfg)
                    aux = aux + a
                else:
                    x = L.mlp_block(layer_params["mlp"], x, cfg)
        elif cfg.block_kind == "mamba1":
            x = S.mamba1_block(layer_params["ssm"], x, cfg)
        else:
            x = S.mamba2_block(layer_params["ssm"], x, cfg)
        return (x, aux), None

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    elif cfg.remat == "block_dots":
        body = jax.checkpoint(
            body, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return body


def _scan_blocks(body, carry, xs):
    """``lax.scan`` over stacked layer params, unrolled in manual regions.

    jaxlib 0.4.x's SPMD partitioner aborts (manual-subgroup check) on a
    scan whose xs/closure carry partial-manual shardings — the per-step
    dynamic gathers lose the subgroup annotation. A Python unroll turns
    them into static slices, which partition fine; outside manual regions
    this is the usual depth-invariant scan.
    """
    if not dist.in_manual_region():
        return jax.lax.scan(body, carry, xs)
    ys = []
    n = jax.tree.leaves(xs)[0].shape[0]
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


def _embed_inputs(params: dict, batch: dict, cfg: ModelConfig) -> jax.Array:
    x = L.embed_tokens(params["embed"], batch["tokens"], cfg)
    if cfg.frontend == "patch" and "patch_embeds" in batch:
        pe = jnp.einsum("bpd,de->bpe",
                        batch["patch_embeds"].astype(cfg.cdtype),
                        params["patch_proj"].astype(cfg.cdtype))
        np_ = pe.shape[1]
        x = jnp.concatenate([pe, x[:, : x.shape[1] - np_]], axis=1)
    return x


def forward(params: dict, batch: dict, cfg: ModelConfig,
            impl: str = "masked") -> tuple[jax.Array, jax.Array]:
    """→ (final hidden states (B, S, D), moe aux loss scalar)."""
    x = _embed_inputs(params, batch, cfg)
    aux0 = jnp.zeros((), jnp.float32)
    body = _block_body(cfg, impl)

    if cfg.shared_attn_every:
        k = cfg.shared_attn_every
        n_groups, tail = cfg.n_layers // k, cfg.n_layers % k
        stacked = params["layers"]

        def regroup(p, lo, hi):
            return jax.tree.map(lambda a: a[lo:hi], p)

        aux = aux0
        for g in range(n_groups):
            grp = regroup(stacked, g * k, (g + 1) * k)
            (x, aux), _ = _scan_blocks(body, (x, aux), grp)
            pos = jnp.arange(x.shape[1])
            x = L.attention_block(params["shared_attn"], x, cfg, pos,
                                  impl=impl)
            if "shared_mlp" in params:
                x = L.mlp_block(params["shared_mlp"], x, cfg)
        if tail:
            grp = regroup(stacked, n_groups * k, cfg.n_layers)
            (x, aux), _ = _scan_blocks(body, (x, aux), grp)
    else:
        (x, aux), _ = _scan_blocks(body, (x, aux0), params["layers"])

    x = L.rms_norm(x, params["final_ln"], cfg.rms_eps)
    return x, aux


def lm_loss(params: dict, batch: dict, cfg: ModelConfig,
            impl: str = "masked") -> tuple[jax.Array, dict]:
    """Next-token CE, computed in sequence chunks (no full logits)."""
    hidden, aux = forward(params, batch, cfg, impl=impl)
    b, s, d = hidden.shape
    labels = batch["labels"]
    mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
    w_out = params["lm_head"].astype(cfg.cdtype)
    sc = min(cfg.loss_seq_chunk, s)
    ns = s // sc

    def _chunk_loss(h, y, m):
        # rematted (by both wrappers below): the (B, sc, V) logits are
        # recomputed in backward instead of being stored per chunk
        # (DESIGN.md §4 memory note)
        # pin the loss layout: batch over pod×data only, vocab over model —
        # under FSDP the hidden arrives batch-sharded over the model axis
        # too, and without this the partitioner REPLICATES the CE matmul
        h = dist.shard(h, ("pod", "data"), None, None)
        logits = jnp.einsum("bsd,dv->bsv", h, w_out,
                            preferred_element_type=jnp.float32)
        logits = dist.shard(logits, ("pod", "data"), None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        nll = (lse - picked) * m
        return nll.sum(), m.sum()

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_step(carry, i):
        # slices INSIDE the remat: the scan stores only (carry, i) per
        # step, not a second full copy of hidden/labels/mask
        tot, cnt = carry
        h = jax.lax.dynamic_slice_in_dim(hidden, i * sc, sc, axis=1)
        y = jax.lax.dynamic_slice_in_dim(labels, i * sc, sc, axis=1)
        m = jax.lax.dynamic_slice_in_dim(mask, i * sc, sc, axis=1)
        t, c = _chunk_loss(h, y, m)
        return (tot + t, cnt + c), None

    zero = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    if dist.in_manual_region():
        # static chunk starts: traced-start dynamic-slices inside a scan
        # abort jaxlib 0.4.x's partitioner in partial-manual regions (see
        # _scan_blocks)
        ckpt_loss = functools.partial(jax.checkpoint,
                                      prevent_cse=False)(_chunk_loss)
        totals, counts = zero
        for i in range(ns):
            t, c = ckpt_loss(
                jax.lax.slice_in_dim(hidden, i * sc, (i + 1) * sc, axis=1),
                jax.lax.slice_in_dim(labels, i * sc, (i + 1) * sc, axis=1),
                jax.lax.slice_in_dim(mask, i * sc, (i + 1) * sc, axis=1))
            totals, counts = totals + t, counts + c
    else:
        (totals, counts), _ = jax.lax.scan(chunk_step, zero, jnp.arange(ns))
    loss = totals / jnp.maximum(counts, 1.0)
    total = loss + cfg.router_aux_coef * aux
    return total, {"ce": loss, "aux": aux, "tokens": counts}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int) -> dict:
    """Decode cache pytree (bf16 KV / fp32 SSM states)."""
    hd, hkv = cfg.hd, cfg.n_kv_heads
    kvdt = jnp.dtype(cfg.cache_dtype)
    cache: dict = {"pos": jnp.zeros((batch_size,), jnp.int32)}
    ldim = cfg.n_layers
    if cfg.block_kind == "attn":
        cache["k"] = jnp.zeros((ldim, batch_size, max_len, hkv, hd), kvdt)
        cache["v"] = jnp.zeros((ldim, batch_size, max_len, hkv, hd), kvdt)
    elif cfg.block_kind == "mamba1":
        di, n = cfg.d_inner, cfg.ssm_state
        cache["conv"] = jnp.zeros((ldim, batch_size, cfg.ssm_conv - 1, di),
                                  kvdt)
        cache["ssm"] = jnp.zeros((ldim, batch_size, di, n), jnp.float32)
    else:  # mamba2
        di, n, hh, p = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                        cfg.ssm_head_dim)
        conv_dim = di + 2 * n
        cache["conv"] = jnp.zeros(
            (ldim, batch_size, cfg.ssm_conv - 1, conv_dim), kvdt)
        cache["ssm"] = jnp.zeros((ldim, batch_size, hh, p, n), jnp.float32)
    if cfg.shared_attn_every:
        groups = cfg.n_layers // cfg.shared_attn_every
        cache["sa_k"] = jnp.zeros((groups, batch_size, max_len, hkv, hd),
                                  kvdt)
        cache["sa_v"] = jnp.zeros((groups, batch_size, max_len, hkv, hd),
                                  kvdt)
    return cache


def cache_sharding_rules(cfg: ModelConfig) -> dict:
    """Sequence dim of KV caches shards over model (flash-decode)."""
    rules: dict = {"pos": (None,)}
    if cfg.block_kind == "attn":
        rules["k"] = (None, ("pod", "data"), "model", None, None)
        rules["v"] = (None, ("pod", "data"), "model", None, None)
    elif cfg.block_kind == "mamba1":
        rules["conv"] = (None, ("pod", "data"), None, "model")
        rules["ssm"] = (None, ("pod", "data"), "model", None)
    else:
        rules["conv"] = (None, ("pod", "data"), None, "model")
        rules["ssm"] = (None, ("pod", "data"), "model", None, None)
    if cfg.shared_attn_every:
        rules["sa_k"] = (None, ("pod", "data"), "model", None, None)
        rules["sa_v"] = (None, ("pod", "data"), "model", None, None)
    return rules


def decode_step(params: dict, cache: dict, tokens: jax.Array,
                cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """One decode step. tokens: (B, 1) int32 → (logits (B, V), new cache)."""
    pos = cache["pos"]
    x = L.embed_tokens(params["embed"], tokens, cfg)

    if cfg.block_kind == "attn":
        def body(x, inp):
            lp, kc, vc = inp
            if cfg.parallel_block and not cfg.is_moe:
                x, new = L.parallel_attn_mlp_block(
                    lp["attn"], lp["mlp"], x, cfg, None,
                    cache={"k": kc, "v": vc}, pos=pos)
            else:
                x, new = L.attention_block_decode(
                    lp["attn"], x, {"k": kc, "v": vc}, pos, cfg)
                if cfg.is_moe:
                    x, _ = L.moe_block(lp["moe"], x, cfg)
                else:
                    x = L.mlp_block(lp["mlp"], x, cfg)
            return x, (new["k"], new["v"])

        x, (ks, vs) = jax.lax.scan(body, x,
                                   (params["layers"], cache["k"],
                                    cache["v"]))
        new_cache = dict(cache, k=ks, v=vs, pos=pos + 1)
    elif cfg.block_kind == "mamba1":
        def body(x, inp):
            lp, conv, ssm_st = inp
            x, new = S.mamba1_decode(lp["ssm"], x,
                                     {"conv": conv, "ssm": ssm_st}, cfg)
            return x, (new["conv"], new["ssm"])

        x, (convs, ssms) = jax.lax.scan(
            body, x, (params["layers"], cache["conv"], cache["ssm"]))
        new_cache = dict(cache, conv=convs, ssm=ssms, pos=pos + 1)
    else:  # mamba2 (+ optional shared attention)
        def body(x, inp):
            lp, conv, ssm_st = inp
            x, new = S.mamba2_decode(lp["ssm"], x,
                                     {"conv": conv, "ssm": ssm_st}, cfg)
            return x, (new["conv"], new["ssm"])

        if cfg.shared_attn_every:
            k = cfg.shared_attn_every
            n_groups, tail = cfg.n_layers // k, cfg.n_layers % k
            convs_out, ssms_out, saks, savs = [], [], [], []
            for g in range(n_groups):
                sl = slice(g * k, (g + 1) * k)
                grp = jax.tree.map(lambda a: a[sl], params["layers"])
                x, (cv, sm) = jax.lax.scan(
                    body, x, (grp, cache["conv"][sl], cache["ssm"][sl]))
                convs_out.append(cv)
                ssms_out.append(sm)
                x, sa_new = L.attention_block_decode(
                    params["shared_attn"], x,
                    {"k": cache["sa_k"][g], "v": cache["sa_v"][g]}, pos, cfg)
                saks.append(sa_new["k"])
                savs.append(sa_new["v"])
                if "shared_mlp" in params:
                    x = L.mlp_block(params["shared_mlp"], x, cfg)
            if tail:
                sl = slice(n_groups * k, cfg.n_layers)
                grp = jax.tree.map(lambda a: a[sl], params["layers"])
                x, (cv, sm) = jax.lax.scan(
                    body, x, (grp, cache["conv"][sl], cache["ssm"][sl]))
                convs_out.append(cv)
                ssms_out.append(sm)
            new_cache = dict(
                cache, conv=jnp.concatenate(convs_out),
                ssm=jnp.concatenate(ssms_out),
                sa_k=jnp.stack(saks), sa_v=jnp.stack(savs), pos=pos + 1)
        else:
            x, (convs, ssms) = jax.lax.scan(
                body, x, (params["layers"], cache["conv"], cache["ssm"]))
            new_cache = dict(cache, conv=convs, ssm=ssms, pos=pos + 1)

    x = L.rms_norm(x, params["final_ln"], cfg.rms_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(cfg.cdtype),
                        preferred_element_type=jnp.float32)
    return logits[:, 0], new_cache


def prefill(params: dict, batch: dict, cfg: ModelConfig,
            impl: str = "masked") -> tuple[jax.Array, dict]:
    """Prefill: forward pass that also builds the decode cache."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = _embed_inputs(params, batch, cfg)
    pos = jnp.arange(s)
    cache = init_cache(cfg, b, s)

    if cfg.block_kind == "attn":
        def body(carry, lp):
            x, = carry
            if cfg.parallel_block and not cfg.is_moe:
                x, (k, v) = L.parallel_attn_mlp_block(
                    lp["attn"], lp["mlp"], x, cfg, pos, impl=impl,
                    return_kv=True)
            else:
                x, (k, v) = L.attention_block(lp["attn"], x, cfg, pos,
                                              impl=impl, return_kv=True)
                if cfg.is_moe:
                    x, _ = L.moe_block(lp["moe"], x, cfg)
                else:
                    x = L.mlp_block(lp["mlp"], x, cfg)
            return (x,), (k.astype(jnp.dtype(cfg.cache_dtype)),
                          v.astype(jnp.dtype(cfg.cache_dtype)))

        if cfg.remat != "none":
            body = jax.checkpoint(body, prevent_cse=False)
        (x,), (ks, vs) = jax.lax.scan(body, (x,), params["layers"])
        # (L, B, S, Hkv, hd) ← collected (L, B, S, Hkv, hd)
        cache["k"] = dist.shard(ks, None, ("pod", "data"), "model", None,
                                None)
        cache["v"] = dist.shard(vs, None, ("pod", "data"), "model", None,
                                None)
    elif cfg.block_kind == "mamba1":
        def body(carry, lp):
            x, = carry
            x, st = S.mamba1_block(lp["ssm"], x, cfg, return_state=True)
            return (x,), (st["conv"], st["ssm"])

        if cfg.remat != "none":
            body = jax.checkpoint(body, prevent_cse=False)
        (x,), (convs, ssms) = jax.lax.scan(body, (x,), params["layers"])
        cache["conv"], cache["ssm"] = convs, ssms
    else:  # mamba2 (+ optional shared attention groups)
        def body(carry, lp):
            x, = carry
            x, st = S.mamba2_block(lp["ssm"], x, cfg, return_state=True)
            return (x,), (st["conv"], st["ssm"])

        if cfg.remat != "none":
            body = jax.checkpoint(body, prevent_cse=False)
        if cfg.shared_attn_every:
            k_ev = cfg.shared_attn_every
            n_groups, tail = cfg.n_layers // k_ev, cfg.n_layers % k_ev
            convs_l, ssms_l, saks, savs = [], [], [], []
            for g in range(n_groups):
                sl = slice(g * k_ev, (g + 1) * k_ev)
                grp = jax.tree.map(lambda a: a[sl], params["layers"])
                (x,), (cv, sm) = jax.lax.scan(body, (x,), grp)
                convs_l.append(cv)
                ssms_l.append(sm)
                x, (sak, sav) = L.attention_block(
                    params["shared_attn"], x, cfg, pos, impl=impl,
                    return_kv=True)
                saks.append(sak.astype(jnp.dtype(cfg.cache_dtype)))
                savs.append(sav.astype(jnp.dtype(cfg.cache_dtype)))
                if "shared_mlp" in params:
                    x = L.mlp_block(params["shared_mlp"], x, cfg)
            if tail:
                sl = slice(n_groups * k_ev, cfg.n_layers)
                grp = jax.tree.map(lambda a: a[sl], params["layers"])
                (x,), (cv, sm) = jax.lax.scan(body, (x,), grp)
                convs_l.append(cv)
                ssms_l.append(sm)
            cache["conv"] = jnp.concatenate(convs_l)
            cache["ssm"] = jnp.concatenate(ssms_l)
            cache["sa_k"] = dist.shard(jnp.stack(saks), None,
                                       ("pod", "data"), "model", None, None)
            cache["sa_v"] = dist.shard(jnp.stack(savs), None,
                                       ("pod", "data"), "model", None, None)
        else:
            (x,), (convs, ssms) = jax.lax.scan(body, (x,), params["layers"])
            cache["conv"], cache["ssm"] = convs, ssms

    x = L.rms_norm(x, params["final_ln"], cfg.rms_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1],
                        params["lm_head"].astype(cfg.cdtype),
                        preferred_element_type=jnp.float32)
    cache["pos"] = jnp.full((b,), s, jnp.int32)
    return logits, cache
