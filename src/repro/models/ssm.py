"""State-space blocks: Mamba1 (selective scan) and Mamba2 (SSD).

TPU adaptation: Mamba1 uses a time-chunked associative scan (VPU-friendly,
bounded intermediates); Mamba2 uses the chunked state-space-dual (SSD)
formulation — intra-chunk attention-like matmuls + a tiny inter-chunk state
scan — which is the MXU-native form (DESIGN.md §4). Decode is the O(1)
recurrence, which is what makes ``long_500k`` tractable for these archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import dist
from repro.models.config import ModelConfig
from repro.models.layers import rms_norm


def causal_depthwise_conv(x: jax.Array, w: jax.Array,
                          b: jax.Array) -> jax.Array:
    """x: (B, S, C); w: (d_conv, C); left-padded causal conv via shifts."""
    d_conv = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(d_conv):
        shift = d_conv - 1 - i
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xs * w[i][None, None, :]
    return out + b[None, None, :]


def _segsum(a: jax.Array) -> jax.Array:
    """(..., L) log-decays → (..., L, L) lower-tri cumulative log-decay."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :] + a[..., None, :] * 0
    # L[i, j] = sum_{k=j+1..i} a_k = cs[i] - cs[j]
    ii = jnp.arange(l)[:, None]
    jj = jnp.arange(l)[None, :]
    return jnp.where(ii >= jj, diff, -jnp.inf)


# ---------------------------------------------------------------------------
# Mamba1
# ---------------------------------------------------------------------------


def mamba1_scan(xdt: jax.Array, da: jax.Array, b: jax.Array, c: jax.Array,
                h0: jax.Array, chunk: int):
    """Chunked selective scan.

    xdt: (B, S, Di) — dt ⊙ x;  da: (B, S, Di, N) — dt ⊙ A (log decay);
    b, c: (B, S, N). h0: (B, Di, N). Returns (y (B, S, Di), h_final).
    """
    bsz, s, di = xdt.shape
    n = b.shape[-1]
    nc = s // chunk
    xdt = xdt.reshape(bsz, nc, chunk, di)
    da = da.reshape(bsz, nc, chunk, di, n)
    b_ = b.reshape(bsz, nc, chunk, n)
    c_ = c.reshape(bsz, nc, chunk, n)

    def chunk_step(h, inputs):
        xc, dac, bc, cc = inputs  # (B, Lc, ...)
        g = jnp.exp(dac)                       # (B, Lc, Di, N)
        u = xc[..., None] * bc[:, :, None, :]  # (B, Lc, Di, N)

        def combine(l, r):
            gl, ul = l
            gr, ur = r
            return gl * gr, ur + gr * ul

        g_cum, u_cum = jax.lax.associative_scan(combine, (g, u), axis=1)
        h_t = g_cum * h[:, None] + u_cum       # (B, Lc, Di, N)
        y = jnp.einsum("bldn,bln->bld", h_t, cc)
        return h_t[:, -1], y

    h_final, ys = jax.lax.scan(
        chunk_step, h0,
        (xdt.transpose(1, 0, 2, 3), da.transpose(1, 0, 2, 3, 4),
         b_.transpose(1, 0, 2, 3), c_.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, s, di)
    return y, h_final


def mamba1_block(params: dict, x: jax.Array, cfg: ModelConfig,
                 return_state: bool = False):
    """Full Mamba1 residual block (training/prefill path)."""
    bsz, s, d = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    h = rms_norm(x, params["ln"], cfg.rms_eps)
    xz = jnp.einsum("bsd,de->bse", h, params["in_proj"].astype(cfg.cdtype))
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = dist.shard_batch(xin, None, "model")
    z = dist.shard_batch(z, None, "model")
    xc = jax.nn.silu(causal_depthwise_conv(
        xin, params["conv_w"].astype(cfg.cdtype),
        params["conv_b"].astype(cfg.cdtype)))
    proj = jnp.einsum("bse,ep->bsp", xc, params["x_proj"].astype(cfg.cdtype))
    dt_raw = proj[..., : cfg.dt_rank]
    bmat = proj[..., cfg.dt_rank: cfg.dt_rank + n].astype(jnp.float32)
    cmat = proj[..., cfg.dt_rank + n:].astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_raw, params["dt_w"].astype(cfg.cdtype))
        .astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # (Di, N)
    da = dt[..., None] * a[None, None]                  # (B,S,Di,N)
    sdt = jnp.dtype(cfg.ssm_scan_dtype)
    xdt = (dt * xc.astype(jnp.float32)).astype(sdt)
    da = da.astype(sdt)
    h0 = jnp.zeros((bsz, di, n), sdt)
    y, h_final = mamba1_scan(xdt, da, bmat.astype(sdt), cmat.astype(sdt),
                             h0, min(cfg.ssm_chunk, s))
    y = y.astype(jnp.float32)
    h_final = h_final.astype(jnp.float32)
    y = y + params["d_skip"].astype(jnp.float32)[None, None] \
        * xc.astype(jnp.float32)
    y = (y.astype(cfg.cdtype) * jax.nn.silu(z))
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(cfg.cdtype))
    out = x + dist.shard_batch(out, None, None)
    if return_state:
        state = {"conv": xin[:, -(cfg.ssm_conv - 1):].astype(
            jnp.dtype(cfg.cache_dtype)), "ssm": h_final}
        return out, state
    return out


def mamba1_decode(params: dict, x: jax.Array, cache: dict,
                  cfg: ModelConfig):
    """Single-token Mamba1 step. x: (B, 1, D); cache: conv (B, dc-1, Di),
    ssm (B, Di, N)."""
    di, n = cfg.d_inner, cfg.ssm_state
    h = rms_norm(x, params["ln"], cfg.rms_eps)
    xz = jnp.einsum("bsd,de->bse", h, params["in_proj"].astype(cfg.cdtype))
    xin, z = jnp.split(xz, 2, axis=-1)
    conv_in = jnp.concatenate([cache["conv"], xin], axis=1)  # (B, dc, Di)
    w = params["conv_w"].astype(cfg.cdtype)
    xc = jax.nn.silu((conv_in * w[None]).sum(axis=1, keepdims=True)
                     + params["conv_b"].astype(cfg.cdtype))
    proj = jnp.einsum("bse,ep->bsp", xc, params["x_proj"].astype(cfg.cdtype))
    dt_raw = proj[..., : cfg.dt_rank]
    bmat = proj[..., cfg.dt_rank: cfg.dt_rank + n].astype(jnp.float32)
    cmat = proj[..., cfg.dt_rank + n:].astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_raw, params["dt_w"].astype(cfg.cdtype))
        .astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    g = jnp.exp(dt[:, 0, :, None] * a[None])
    hs = (g * cache["ssm"]
          + (dt[:, 0, :, None] * xc.astype(jnp.float32)[:, 0, :, None])
          * bmat[:, 0, None, :])
    y = jnp.einsum("bdn,bn->bd", hs, cmat[:, 0])
    y = y + params["d_skip"].astype(jnp.float32)[None] \
        * xc.astype(jnp.float32)[:, 0]
    y = (y[:, None].astype(cfg.cdtype) * jax.nn.silu(z))
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(cfg.cdtype))
    new_cache = {"conv": conv_in[:, 1:], "ssm": hs}
    return x + out, new_cache


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------


def ssd(xdt: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
        h0: jax.Array, chunk: int):
    """Chunked state-space dual. xdt: (B,S,H,P) (dt-scaled inputs);
    a: (B,S,H) log-decay; b, c: (B,S,N). h0: (B,H,P,N).

    Returns (y (B,S,H,P), h_final). Matmul-heavy: intra-chunk terms are
    (Lc × Lc) attention-like products on the MXU.
    """
    bsz, s, hh, p = xdt.shape
    n = b.shape[-1]
    nc = s // chunk
    x_ = xdt.reshape(bsz, nc, chunk, hh, p)
    a_ = a.reshape(bsz, nc, chunk, hh).transpose(0, 1, 3, 2)  # (B,nc,H,Lc)
    b_ = b.reshape(bsz, nc, chunk, n)
    c_ = c.reshape(bsz, nc, chunk, n)

    a_cs = jnp.cumsum(a_, axis=-1)                       # (B,nc,H,Lc)
    l_mat = jnp.exp(_segsum(a_))                         # (B,nc,H,Lc,Lc)
    att = jnp.einsum("bcln,bcsn->bcls", c_, b_,
                     preferred_element_type=jnp.float32)
    y_diag = jnp.einsum("bchls,bcls,bcshp->bclhp",
                        l_mat, att, x_.astype(jnp.float32))
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)        # (B,nc,H,Lc)
    states = jnp.einsum("bcln,bchl,bclhp->bchpn", b_, decay_states,
                        x_.astype(jnp.float32))          # (B,nc,H,P,N)
    chunk_decay = jnp.exp(a_cs[..., -1])                 # (B,nc,H)

    def step(h, inp):
        st, dec = inp
        h_new = dec[..., None, None] * h + st
        return h_new, h

    h_final, h_prev = jax.lax.scan(
        step, h0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)             # (B,nc,H,P,N)
    state_decay = jnp.exp(a_cs)                          # (B,nc,H,Lc)
    y_off = jnp.einsum("bcln,bchpn,bchl->bclhp", c_, h_prev, state_decay)
    y = (y_diag + y_off).reshape(bsz, s, hh, p)
    return y, h_final


def mamba2_block(params: dict, x: jax.Array, cfg: ModelConfig,
                 return_state: bool = False):
    bsz, s, d = x.shape
    di, n, hh, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = rms_norm(x, params["ln"], cfg.rms_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", h,
                        params["in_proj"].astype(cfg.cdtype))
    z, xbc_raw, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    xbc = causal_depthwise_conv(xbc_raw,
                                params["conv_w"].astype(cfg.cdtype),
                                params["conv_b"].astype(cfg.cdtype))
    xbc = jax.nn.silu(xbc)
    xin, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
    xin = dist.shard_batch(xin, None, "model")
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,S,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))               # (H,)
    xh = xin.reshape(bsz, s, hh, p)
    xdt = xh.astype(jnp.float32) * dt[..., None]
    h0 = jnp.zeros((bsz, hh, p, n), jnp.float32)
    y, h_final = ssd(xdt, dt * a[None, None], bmat.astype(jnp.float32),
                     cmat.astype(jnp.float32), h0, min(cfg.ssm_chunk, s))
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, di).astype(cfg.cdtype)
    y = rms_norm(y * jax.nn.silu(z), params["out_ln"], cfg.rms_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(cfg.cdtype))
    out = x + dist.shard_batch(out, None, None)
    if return_state:
        state = {"conv": xbc_raw[:, -(cfg.ssm_conv - 1):].astype(
            jnp.dtype(cfg.cache_dtype)), "ssm": h_final}
        return out, state
    return out


def mamba2_decode(params: dict, x: jax.Array, cache: dict,
                  cfg: ModelConfig):
    """Single-token Mamba2 step; cache: conv (B, dc-1, 2Di+2N... xbc dims),
    ssm (B, H, P, N)."""
    bsz = x.shape[0]
    di, n, hh, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = rms_norm(x, params["ln"], cfg.rms_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", h,
                        params["in_proj"].astype(cfg.cdtype))
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([cache["conv"], xbc], axis=1)
    w = params["conv_w"].astype(cfg.cdtype)
    xbc1 = jax.nn.silu((conv_in * w[None]).sum(axis=1, keepdims=True)
                       + params["conv_b"].astype(cfg.cdtype))
    xin, bmat, cmat = jnp.split(xbc1, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))[:, 0]
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    g = jnp.exp(dt * a[None])                            # (B,H)
    xh = xin[:, 0].reshape(bsz, hh, p).astype(jnp.float32)
    upd = (dt[..., None, None] * xh[..., None]
           * bmat[:, 0, None, None, :].astype(jnp.float32))
    hs = g[..., None, None] * cache["ssm"] + upd
    y = jnp.einsum("bhpn,bn->bhp", hs, cmat[:, 0].astype(jnp.float32))
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(bsz, 1, di).astype(cfg.cdtype)
    y = rms_norm(y * jax.nn.silu(z), params["out_ln"], cfg.rms_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(cfg.cdtype))
    return x + out, {"conv": conv_in[:, 1:], "ssm": hs}
