"""Transformer building blocks: RMSNorm, RoPE, blocked attention, SwiGLU,
MoE with expert parallelism.

Sharding convention (DESIGN.md §4): activations (B, S, D) shard B over
pod×data; attention heads / FFN hidden / experts / vocab shard over
``model``. KV-head and expert dims that don't divide the model axis fall
back to replication (dist.sanitize_spec).
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import dist
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# norms / rope / embeddings
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def rope_tables(positions: jax.Array, head_dim: int,
                theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables (..., head_dim/2) for given positions."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (B, S, hd/2) or (S, hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1).astype(x.dtype)


def embed_tokens(table: jax.Array, tokens: jax.Array,
                 cfg: ModelConfig) -> jax.Array:
    out = jnp.take(table.astype(cfg.cdtype), tokens, axis=0)
    return dist.shard_batch(out, None, None)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def qkv_project(params: dict, x: jax.Array, cfg: ModelConfig,
                positions: jax.Array):
    """x (B,S,D) → q (B,S,Hq,hd), k/v (B,S,Hkv,hd) with RoPE applied."""
    b, s, d = x.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,dhk->bshk", x,
                   params["wq"].astype(cfg.cdtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(cfg.cdtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(cfg.cdtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(cfg.cdtype)
        k = k + params["bk"].astype(cfg.cdtype)
        v = v + params["bv"].astype(cfg.cdtype)
    cos, sin = rope_tables(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = dist.shard_batch(q, None, "model", None)
    k = dist.shard_batch(k, None, "model", None)
    v = dist.shard_batch(v, None, "model", None)
    return q, k, v


def _attend_block(q, k, v, mask, scale):
    """One (bq × bk) online-softmax update. All fp32 accumulation."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, -1e30)
    return s


def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      cfg: ModelConfig, *, causal: bool = True,
                      impl: str = "masked") -> jax.Array:
    """Memory-bounded causal attention with online softmax.

    q: (B, S, Hq, hd); k/v: (B, S, Hkv, hd). Scores never exceed
    (B, Hq, bq, bk). ``impl='masked'`` runs all KV blocks with masking
    (simple, 2× causal FLOPs); ``impl='triangular'`` unrolls query blocks
    and visits only allowed KV blocks (the §Perf compute optimization).
    """
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    bq = min(cfg.attn_q_block, s)
    bk = min(cfg.attn_kv_block, s)
    nq, nk = s // bq, s // bk
    scale = 1.0 / math.sqrt(hd)
    qT = jnp.swapaxes(q, 1, 2)  # (B, Hq, S, hd)
    kT = jnp.swapaxes(jnp.repeat(k, group, axis=2), 1, 2)
    vT = jnp.swapaxes(jnp.repeat(v, group, axis=2), 1, 2)
    kT = dist.shard_batch(kT, "model", None, None)
    vT = dist.shard_batch(vT, "model", None, None)

    # jaxlib 0.4.x partial-manual regions cannot partition scans whose
    # bodies gather region inputs with traced starts (see decoder.
    # _scan_blocks) — unroll both loops there; static slices are fine.
    unroll = dist.in_manual_region()

    def q_block(iq, qblk):
        # qblk: (B, Hq, bq, hd)
        def _kv_math(carry, kblk, vblk, ik):
            acc, m, l = carry
            qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            ki = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = (ki <= qi) if causal else jnp.ones((bq, bk), bool)
            sc = _attend_block(qblk, kblk, vblk, mask[None, None], scale)
            m_new = jnp.maximum(m, sc.max(-1))
            p = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            pv = jnp.einsum("bhqk,bhkd->bhqd", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * alpha[..., None] + pv
            return acc_new, m_new, l_new

        kv_math = functools.partial(jax.checkpoint,
                                    prevent_cse=False)(_kv_math)

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def kv_step(carry, ik):
            # slices INSIDE the remat: the kv scan stores only (carry, ik)
            # per step, never a second full copy of kT/vT
            kblk = jax.lax.dynamic_slice_in_dim(kT, ik * bk, bk, axis=2)
            vblk = jax.lax.dynamic_slice_in_dim(vT, ik * bk, bk, axis=2)
            return _kv_math(carry, kblk, vblk, ik), None

        acc0 = jnp.zeros((b, hq, bq, hd), jnp.float32)
        m0 = jnp.full((b, hq, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hq, bq), jnp.float32)
        if impl == "triangular" and causal:
            n_allowed = int(iq) * bq // bk + 1  # static per unrolled block
        else:
            n_allowed = nk
        if unroll:
            carry = (acc0, m0, l0)
            for ik in range(n_allowed):
                kblk = jax.lax.slice_in_dim(kT, ik * bk, (ik + 1) * bk,
                                            axis=2)
                vblk = jax.lax.slice_in_dim(vT, ik * bk, (ik + 1) * bk,
                                            axis=2)
                carry = kv_math(carry, kblk, vblk, jnp.int32(ik))
            acc, m, l = carry
        else:
            (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                          jnp.arange(n_allowed))
        return (acc / jnp.maximum(l[..., None], 1e-20)).astype(q.dtype)

    if (impl == "triangular" and causal) or unroll:
        outs = [q_block(i, jax.lax.slice_in_dim(qT, i * bq, (i + 1) * bq,
                                                axis=2))
                for i in range(nq)]
        out = jnp.concatenate(outs, axis=2)
    else:
        qblocks = qT.reshape(b, hq, nq, bq, hd).transpose(2, 0, 1, 3, 4)
        out = jax.lax.map(lambda args: q_block(args[0], args[1]),
                          (jnp.arange(nq), qblocks))
        out = out.transpose(1, 2, 0, 3, 4).reshape(b, hq, s, hd)
    return jnp.swapaxes(out, 1, 2)  # (B, S, Hq, hd)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Single-token attention against a (B, Skv, Hkv, hd) cache.

    The cache's sequence dim is sharded over ``model`` (distributed
    flash-decode): XLA turns the softmax max/sum and the weighted sum into
    three small all-reduces (DESIGN.md §4).
    """
    b, one, hq, hd = q.shape
    hkv = k_cache.shape[2]
    group = hq // hkv
    kx = jnp.repeat(k_cache, group, axis=2)
    vx = jnp.repeat(v_cache, group, axis=2)
    # pin the flash-decode layout: cache stays sequence-sharded with heads
    # replicated — otherwise XLA reshards the (huge) cache toward the
    # head-sharded o_proj instead of resharding the (tiny) output
    kx = dist.shard_batch(kx, "model", None, None)
    vx = dist.shard_batch(vx, "model", None, None)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kx,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    ki = jnp.arange(k_cache.shape[1])[None, None, None, :]
    s = jnp.where(ki <= pos[:, None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w.astype(vx.dtype), vx,
                     preferred_element_type=jnp.float32)
    out = dist.shard_batch(out, None, None, None)
    return out.astype(q.dtype)


def attention_block(params: dict, x: jax.Array, cfg: ModelConfig,
                    positions: jax.Array, *, impl: str = "masked",
                    return_kv: bool = False):
    """Full pre-norm attention residual block (training / prefill)."""
    h = rms_norm(x, params["ln"], cfg.rms_eps)
    q, k, v = qkv_project(params, h, cfg, positions)
    o = blocked_attention(q, k, v, cfg, impl=impl)
    o = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(cfg.cdtype))
    o = dist.shard_batch(o, None, None)
    if return_kv:
        return x + o, (k, v)
    return x + o


def attention_block_decode(params: dict, x: jax.Array, cache: dict,
                           pos: jax.Array, cfg: ModelConfig):
    """Decode-step attention block; updates the KV cache in place.

    x: (B, 1, D); cache: {"k": (B, S, Hkv, hd), "v": ...}; pos: (B,) int32.
    """
    h = rms_norm(x, params["ln"], cfg.rms_eps)
    q, k_new, v_new = qkv_project(params, h, cfg, pos[:, None])
    # flash-decode layout: q heads REPLICATED across model (decode flops
    # are negligible); the cache keeps its sequence dim sharded so the
    # softmax reductions become three small all-reduces — avoids the
    # heads-vs-sequence sharding conflict XLA otherwise resolves with an
    # all-gather of the cache.
    q = dist.shard_batch(q, None, None, None)
    if cfg.uniform_decode_pos:
        # one shared position → dynamic-update-slice, which the SPMD
        # partitioner handles on the seq-sharded cache without gathering
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype),
            (0, pos[0], 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype),
            (0, pos[0], 0, 0))
    else:
        # per-slot positions (continuous batching): batched scatter
        bidx = jnp.arange(x.shape[0])
        k_cache = cache["k"].at[bidx, pos].set(
            k_new[:, 0].astype(cache["k"].dtype))
        v_cache = cache["v"].at[bidx, pos].set(
            v_new[:, 0].astype(cache["v"].dtype))
    k_cache = dist.shard_batch(k_cache, "model", None, None)
    v_cache = dist.shard_batch(v_cache, "model", None, None)
    o = decode_attention(q, k_cache, v_cache, pos, cfg)
    o = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(cfg.cdtype))
    return x + o, {"k": k_cache, "v": v_cache}


def parallel_attn_mlp_block(attn_params: dict, mlp_params: dict,
                            x: jax.Array, cfg: ModelConfig,
                            positions: jax.Array, *, impl: str = "masked",
                            cache: dict | None = None,
                            pos: jax.Array | None = None,
                            return_kv: bool = False):
    """Command-r-style parallel block: y = x + attn(ln(x)) + mlp(ln(x)).

    Both sub-blocks produce TP partial sums that are ADDED before a single
    sharding constraint, so XLA emits ONE all-reduce per layer instead of
    two — half the TP activation traffic (§Perf) and faithful to the
    upstream architecture.
    """
    h = rms_norm(x, attn_params["ln"], cfg.rms_eps)
    extra = None
    if cache is not None:  # decode
        q, k_new, v_new = qkv_project(attn_params, h, cfg, pos[:, None])
        q = dist.shard_batch(q, None, None, None)
        if cfg.uniform_decode_pos:
            k_cache = jax.lax.dynamic_update_slice(
                cache["k"], k_new.astype(cache["k"].dtype),
                (0, pos[0], 0, 0))
            v_cache = jax.lax.dynamic_update_slice(
                cache["v"], v_new.astype(cache["v"].dtype),
                (0, pos[0], 0, 0))
        else:
            bidx = jnp.arange(x.shape[0])
            k_cache = cache["k"].at[bidx, pos].set(
                k_new[:, 0].astype(cache["k"].dtype))
            v_cache = cache["v"].at[bidx, pos].set(
                v_new[:, 0].astype(cache["v"].dtype))
        k_cache = dist.shard_batch(k_cache, "model", None, None)
        v_cache = dist.shard_batch(v_cache, "model", None, None)
        o = decode_attention(q, k_cache, v_cache, pos, cfg)
        extra = {"k": k_cache, "v": v_cache}
    else:
        q, k, v = qkv_project(attn_params, h, cfg, positions)
        o = blocked_attention(q, k, v, cfg, impl=impl)
        if return_kv:
            extra = (k, v)
    ao = jnp.einsum("bshk,hkd->bsd", o, attn_params["wo"].astype(cfg.cdtype))
    g = jnp.einsum("bsd,df->bsf", h, mlp_params["wg"].astype(cfg.cdtype))
    u = jnp.einsum("bsd,df->bsf", h, mlp_params["wu"].astype(cfg.cdtype))
    g = dist.shard_batch(g, None, "model")
    u = dist.shard_batch(u, None, "model")
    mo = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                    mlp_params["wd"].astype(cfg.cdtype))
    y = x + dist.shard_batch(ao + mo, None, None)   # single psum
    if extra is not None or return_kv:
        return y, extra
    return y


# ---------------------------------------------------------------------------
# dense MLP (SwiGLU)
# ---------------------------------------------------------------------------


def mlp_block(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = rms_norm(x, params["ln"], cfg.rms_eps)
    g = jnp.einsum("bsd,df->bsf", h, params["wg"].astype(cfg.cdtype))
    u = jnp.einsum("bsd,df->bsf", h, params["wu"].astype(cfg.cdtype))
    g = dist.shard_batch(g, None, "model")
    u = dist.shard_batch(u, None, "model")
    y = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                   params["wd"].astype(cfg.cdtype))
    return x + dist.shard_batch(y, None, None)


# ---------------------------------------------------------------------------
# MoE (shared + routed experts, EP over the model axis)
# ---------------------------------------------------------------------------


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(n_tokens * cfg.moe_top_k * cfg.capacity_factor
                      / cfg.n_experts))
    return max(8, c)


def _route(h2: jax.Array, router_w: jax.Array, cfg: ModelConfig):
    """(T, D) tokens → (top-k expert ids, combine weights, aux loss)."""
    logits = jnp.einsum("td,de->te", h2.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, cfg.moe_top_k)  # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    # load-balance auxiliary (Switch-style)
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], cfg.n_experts), axis=0)
    p_mean = probs.mean(axis=0)
    aux = cfg.n_experts * jnp.sum(density * p_mean)
    return top_e.astype(jnp.int32), top_w, aux


def _rank_within_expert(flat_e: jax.Array, n_experts: int) -> jax.Array:
    """Arrival rank of each (token, slot) within its expert, O(T·k) memory."""
    tk = flat_e.shape[0]
    chunk = 8
    rank = jnp.zeros((tk,), jnp.int32)
    for e0 in range(0, n_experts, chunk):
        onehot = (flat_e[:, None] == jnp.arange(e0, e0 + chunk)[None, :])
        csum = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1
        rank = rank + jnp.where(onehot, csum, 0).sum(axis=1)
    return rank


def _moe_local(h2: jax.Array, top_e: jax.Array, top_w: jax.Array,
               wg: jax.Array, wu: jax.Array, wd: jax.Array,
               e_base: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Dispatch→grouped GEMM→combine for the locally held experts.

    h2: (T, D); wg/wu: (E_loc, D, F); wd: (E_loc, F, D). ``e_base`` is the
    first global expert id held locally. Returns partial output (T, D)
    covering tokens routed to local experts (others zero).
    """
    t, d = h2.shape
    e_loc = wg.shape[0]
    k = cfg.moe_top_k
    cap = _capacity(t, cfg)
    flat_e = top_e.reshape(-1)                     # (T*k,) global ids
    rank = _rank_within_expert(flat_e, cfg.n_experts)
    local_e = flat_e - e_base
    ok = (local_e >= 0) & (local_e < e_loc) & (rank < cap)
    le = jnp.where(ok, local_e, 0)
    rr = jnp.where(ok, rank, cap)                  # cap → dropped
    src = jnp.repeat(h2, k, axis=0)                # (T*k, D)
    buf = jnp.zeros((e_loc, cap + 1, d), h2.dtype)
    buf = buf.at[le, rr].add(src, mode="drop")
    buf = buf[:, :cap]
    g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(h2.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(h2.dtype))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                   wd.astype(h2.dtype))            # (E_loc, cap, D)
    y = jnp.concatenate([y, jnp.zeros((e_loc, 1, d), y.dtype)], axis=1)
    gathered = y[le, rr]                           # (T*k, D)
    gathered = jnp.where(ok[:, None], gathered, 0)
    w = top_w.reshape(-1)[:, None].astype(h2.dtype)
    return (gathered * w).reshape(t, k, d).sum(axis=1)


def moe_block(params: dict, x: jax.Array, cfg: ModelConfig):
    """Shared-expert + routed-expert MoE block.

    Routed experts are sharded over ``model`` (EP). Activations are
    replicated over ``model`` (they're sharded over pod×data only), so the
    EP combine is a single psum — the same collective volume as a TP MLP
    (DESIGN.md §4). Returns (y, aux_loss).
    """
    b, s, d = x.shape
    h = rms_norm(x, params["ln"], cfg.rms_eps)
    h2 = h.reshape(b * s, d)
    mesh = dist.current_mesh()
    use_ep = (mesh is not None and "model" in mesh.shape
              and cfg.n_experts % mesh.shape["model"] == 0)

    if use_ep:
        tp = mesh.shape["model"]
        e_loc = cfg.n_experts // tp
        ba = dist.batch_axes()

        def per_shard(h2s, rw, wg, wu, wd):
            top_e, top_w, aux = _route(h2s, rw, cfg)
            e_base = jax.lax.axis_index("model") * e_loc
            y = _moe_local(h2s, top_e, top_w, wg, wu, wd, e_base, cfg)
            y = jax.lax.psum(y, "model")
            # per-DATA-shard balance loss averaged over the whole mesh
            # (standard device-level balance objective; identical across
            # model shards, differs per data shard)
            all_axes = tuple(mesh.axis_names)
            aux = jax.lax.pmean(aux, all_axes)
            return y, aux

        spec_h = P(ba if ba else None, None)
        out = dist.shard_map(
            per_shard, mesh=mesh,
            in_specs=(spec_h, P(None, None), P("model", None, None),
                      P("model", None, None), P("model", None, None)),
            out_specs=(spec_h, P()),
            check_vma=False,
        )(h2, params["router"], params["wg"], params["wu"], params["wd"])
        y, aux = out
    else:
        top_e, top_w, aux = _route(h2, params["router"], cfg)
        y = _moe_local(h2, top_e, top_w, params["wg"], params["wu"],
                       params["wd"], jnp.int32(0), cfg)
    y = y.reshape(b, s, d)
    # shared experts: dense SwiGLU over all tokens
    if cfg.n_shared_experts > 0:
        g = jnp.einsum("bsd,df->bsf", h, params["swg"].astype(cfg.cdtype))
        u = jnp.einsum("bsd,df->bsf", h, params["swu"].astype(cfg.cdtype))
        g = dist.shard_batch(g, None, "model")
        u = dist.shard_batch(u, None, "model")
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                           params["swd"].astype(cfg.cdtype))
    return x + dist.shard_batch(y, None, None), aux
