"""Model configuration covering all assigned architecture families.

One dataclass describes dense GQA transformers, MoE (shared + routed
experts), Mamba1/Mamba2 SSMs, and Zamba2-style hybrids with a shared
attention block; modality frontends (ViT patches / EnCodec tokens) are
stubs whose precomputed embeddings arrive via ``input_specs`` per the
assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

BlockKind = Literal["attn", "mamba1", "mamba2"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: int = 0                  # 0 → d_model // n_heads
    qkv_bias: bool = False             # qwen-family
    parallel_block: bool = False       # command-r: attn+FFN share the norm
                                       # and sum before ONE TP psum/layer
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    # block layout
    block_kind: BlockKind = "attn"     # homogeneous stack kind
    shared_attn_every: int = 0         # zamba2: shared attn block cadence
    # MoE (0 experts → dense)
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    expert_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    # SSM
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0               # 0 → ceil(d_model / 16)
    ssm_head_dim: int = 64             # mamba2 P
    ssm_chunk: int = 64                # SSD / chunked-scan length
    ssm_scan_dtype: str = "float32"    # chunked-scan pair dtype (perf knob)
    # frontend stubs
    frontend: Literal["none", "patch", "audio"] = "none"
    n_patches: int = 0                 # vlm: patch embeddings prepended
    # numerics / execution
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    cache_dtype: str = "bfloat16"
    remat: Literal["none", "block", "block_dots"] = "block"
    attn_q_block: int = 512
    attn_kv_block: int = 512
    loss_vocab_chunk: int = 2048       # CE computed in sequence chunks
    loss_seq_chunk: int = 512
    # decode cache update: True → all sequences share one position and the
    # KV write lowers to dynamic-update-slice (partitions cleanly along the
    # seq-sharded cache); False → per-slot positions via scatter (the
    # continuous-batching engine path — XLA gathers the cache, §Perf).
    uniform_decode_pos: bool = True
    # sub-quadratic attention capability (long_500k eligibility)
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def ssm_heads(self) -> int:
        assert self.d_inner % self.ssm_head_dim == 0
        return self.d_inner // self.ssm_head_dim

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab_size * d * 2  # untied in/out embeddings
        per_layer = 0
        if self.block_kind == "attn" or self.shared_attn_every:
            qkv = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
            o = (self.n_heads * hd) * d
            attn = qkv + o
        else:
            attn = 0
        if self.block_kind == "attn":
            per_layer += attn
        if self.block_kind in ("mamba1", "mamba2"):
            di, n = self.d_inner, self.ssm_state
            if self.block_kind == "mamba1":
                per_layer += (d * 2 * di + self.ssm_conv * di
                              + di * (self.dt_rank + 2 * n)
                              + self.dt_rank * di + di * n + di + di * d)
            else:
                h = self.ssm_heads
                per_layer += (d * (2 * di + 2 * n + h) + self.ssm_conv
                              * (di + 2 * n) + h * 2 + di + di * d)
        if self.is_moe:
            per_layer += d * self.n_experts  # router
            per_layer += self.n_experts * 3 * d * self.expert_ff
            per_layer += self.n_shared_experts * 3 * d * self.expert_ff
        elif self.d_ff and self.block_kind == "attn":
            per_layer += 3 * d * self.d_ff
        per_layer += 2 * d  # norms
        total = emb + self.n_layers * per_layer
        if self.shared_attn_every:
            total += attn + d
            if self.d_ff:
                total += 3 * d * self.d_ff  # shared block MLP
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        routed_all = self.n_experts * 3 * d * self.expert_ff
        routed_active = self.moe_top_k * 3 * d * self.expert_ff
        return (self.param_count()
                - self.n_layers * (routed_all - routed_active))
