"""Model stack: unified decoder over all assigned architecture families."""
from repro.models.config import ModelConfig  # noqa: F401
from repro.models.decoder import (cache_sharding_rules, decode_step,  # noqa: F401
                                  forward, init_cache, init_params, lm_loss,
                                  padded_vocab, param_shapes,
                                  param_sharding_rules, prefill)
