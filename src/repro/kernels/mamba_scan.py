"""Pallas TPU kernel: fused Mamba1 selective scan.

The XLA-lowered chunked scan materializes (B, Lc, d_inner, N) decay/update
tensors in HBM (§Roofline: the falcon-mamba memory wall). This kernel keeps
the whole recurrence in VMEM: per (batch, d_inner-block) program, the state
h (bd, N) lives in registers/VMEM and time is a sequential fori_loop —
HBM traffic collapses to the linear inputs/outputs (x·dt, dt, B, C → y).

Grid: (B, Di/bd). Block shapes: (1, S, bd) activations, (bd, N) A-matrix,
(1, S, N) B/C projections.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xdt_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, *,
            seq_len: int):
    a = a_ref[...].astype(jnp.float32)           # (bd, N)
    bd, n = a.shape

    def step(t, h):
        xr = xdt_ref[0, t, :].astype(jnp.float32)        # (bd,)
        dtr = dt_ref[0, t, :].astype(jnp.float32)        # (bd,)
        br = b_ref[0, t, :].astype(jnp.float32)          # (N,)
        cr = c_ref[0, t, :].astype(jnp.float32)          # (N,)
        g = jnp.exp(dtr[:, None] * a)                    # (bd, N)
        h = g * h + xr[:, None] * br[None, :]
        y_ref[0, t, :] = (h * cr[None, :]).sum(axis=1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, seq_len, step,
                          jnp.zeros((bd, n), jnp.float32))
    hout_ref[0, :, :] = h.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def mamba_scan(xdt: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
               c: jax.Array, *, bd: int = 128,
               interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """xdt/dt: (B, S, Di); a: (Di, N); b/c: (B, S, N).

    Returns (y (B, S, Di), h_final (B, Di, N)). Di % bd == 0.
    """
    bsz, s, di = xdt.shape
    n = a.shape[1]
    assert di % bd == 0, (di, bd)
    grid = (bsz, di // bd)
    kernel = functools.partial(_kernel, seq_len=s)
    y, h_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, s, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, s, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((bd, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, s, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s, n), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, s, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, bd, n), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, di), xdt.dtype),
            jax.ShapeDtypeStruct((bsz, di, n), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, dt, a, b, c)
    return y, h_final
