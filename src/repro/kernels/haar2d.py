"""Pallas TPU kernel: fused standard-decomposition 2-D Haar transform.

The multilevel 1-D Haar transform is a fixed orthogonal matrix (≤128×128
here), so the standard 2-D decomposition is two dense matmuls — an exact
MXU fit. The kernel fuses both matmuls per image block so intermediate
coefficients never round-trip to HBM (DESIGN.md §3.4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(img_ref, th_ref, tw_ref, out_ref):
    x = img_ref[...]  # (bn, H, W)
    th = th_ref[...]  # (H, H)
    tw = tw_ref[...]  # (W, W)
    # rows: y[n, h, v] = sum_w x[n, h, w] * tw[v, w]
    y = jax.lax.dot_general(
        x, tw, (((2,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (bn, H, V)
    # cols: z[n, u, v] = sum_h th[u, h] * y[n, h, v]
    z = jax.lax.dot_general(
        y, th, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (bn, V, U) -> transpose
    out_ref[...] = jnp.swapaxes(z, 1, 2).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def haar2d(imgs: jax.Array, th: jax.Array, tw: jax.Array, *, bn: int = 128,
           interpret: bool = False) -> jax.Array:
    """imgs: (N, H, W) float; th: (H, H); tw: (W, W). N % bn == 0."""
    n, h, w = imgs.shape
    assert n % bn == 0, (n, bn)
    grid = (n // bn,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((h, h), lambda i: (0, 0)),
            pl.BlockSpec((w, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, h, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h, w), imgs.dtype),
        interpret=interpret,
    )(imgs, th, tw)
