"""Pallas TPU kernel: blocked causal flash attention with GQA.

Online-softmax attention with (bq × bk) score tiles resident in VMEM;
KV blocks stream over the innermost (sequential) grid axis. GQA is handled
in the BlockSpec index map (query head h reads kv head h // group), so KV
is never materialized per-q-head in HBM. Fully-masked KV tiles are skipped
(`pl.when`), which matters for long causal sequences: ~2× fewer tiles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, acc_ref, m_ref, l_ref, *,
            sm_scale: float, causal: bool, sq: int, sk: int, bq: int,
            bk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Causal block skip: query rows [q0, q0+bq) attend keys <= q + offset.
    offset = sk - sq
    q0 = iq * bq
    k0 = ik * bk
    run = (not causal) or (k0 <= q0 + bq - 1 + offset)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0, :, :].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0, :, :].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale  # (bq, bk)
        if causal:
            qi = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + offset
            ki = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(ki <= qi, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        out_ref[0, 0, :, :] = (acc_ref[...] / l).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "bq", "bk", "interpret", "sm_scale"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, sm_scale: float | None = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D); Hq % Hkv == 0.

    Sq % bq == 0 and Sk % bk == 0 (ops.py pads).
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    grid = (b, hq, sq // bq, sk // bk)
    kernel = functools.partial(_kernel, sm_scale=sm_scale, causal=causal,
                               sq=sq, sk=sk, bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j: (b_, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
