"""Pallas TPU kernel for Min-Max hash signature generation (paper §6.2).

The paper's CPU optimization is cache blocking: iterate fingerprint
*dimensions* outermost so rows of the hash-mapping table stay resident in
cache and are reused across the >60%-overlapping neighboring fingerprints.
The TPU translation (DESIGN.md §3.2) is VMEM tiling: a (bn × bd) fingerprint
tile and the matching (bd × bh) hash-mapping tile are co-resident in VMEM and
min/max-accumulated over the D grid axis — dimensions are again the reduction
(outer) loop, hash-mapping rows are again the reused operand.

Grid: (N/bn, H/bh, D/bd) with D innermost (sequential reduction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BIG = np.int32(2**31 - 1)


def _kernel(fp_ref, map_ref, min_ref, max_ref):
    kd = pl.program_id(2)

    @pl.when(kd == 0)
    def _init():
        min_ref[...] = jnp.full_like(min_ref, BIG)
        max_ref[...] = jnp.zeros_like(max_ref)

    fp = fp_ref[...]  # (bn, bd) int8 {0,1}
    hm = map_ref[...]  # (bd, bh) int32
    mask = (fp > 0)[:, :, None]  # (bn, bd, 1)
    mvals = hm[None, :, :]  # (1, bd, bh)
    cur_min = jnp.where(mask, mvals, BIG).min(axis=1)  # (bn, bh)
    cur_max = jnp.where(mask, mvals, jnp.int32(0)).max(axis=1)
    min_ref[...] = jnp.minimum(min_ref[...], cur_min)
    max_ref[...] = jnp.maximum(max_ref[...], cur_max)


@functools.partial(jax.jit, static_argnames=("bn", "bd", "bh", "interpret"))
def minmax_hash(fp: jax.Array, mappings: jax.Array, *, bn: int = 16,
                bd: int = 256, bh: int = 256,
                interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """fp: (N, D) int8/bool; mappings: (D, H) int32. Returns (N,H)x2 int32.

    N % bn == 0, D % bd == 0, H % bh == 0 (ops.py pads as needed).
    """
    n, d = fp.shape
    d2, h = mappings.shape
    assert d == d2, (fp.shape, mappings.shape)
    assert n % bn == 0 and d % bd == 0 and h % bh == 0, (n, d, h, bn, bd, bh)
    fp = fp.astype(jnp.int8)
    grid = (n // bn, h // bh, d // bd)
    out_shape = [
        jax.ShapeDtypeStruct((n, h), jnp.int32),
        jax.ShapeDtypeStruct((n, h), jnp.int32),
    ]
    mins, maxs = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bd, bh), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((bn, bh), lambda i, j, k: (i, j)),
            pl.BlockSpec((bn, bh), lambda i, j, k: (i, j)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(fp, mappings)
    return mins, maxs
