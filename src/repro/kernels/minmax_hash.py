"""Pallas TPU kernel for Min-Max hash signature generation (paper §6.2).

The paper's CPU optimization is cache blocking: iterate fingerprint
*dimensions* outermost so rows of the hash-mapping table stay resident in
cache and are reused across the >60%-overlapping neighboring fingerprints.
The TPU translation (DESIGN.md §3.2) is VMEM tiling: a (bn × bd) fingerprint
tile and the matching (bd × bh) hash-mapping tile are co-resident in VMEM and
min/max-accumulated over the D grid axis — dimensions are again the reduction
(outer) loop, hash-mapping rows are again the reused operand.

Grid: (N/bn, H/bh, D/bd) with D innermost (sequential reduction).

``minmax_sig_buckets`` extends the kernel with a fused epilogue (ISSUE 3):
on the last D step it folds the per-function min/max hashes into the
per-table signature and derives the salted bucket address in-register —
the signature fold + bucket addressing that previously ran as separate jnp
ops after the kernel returned. One pass over VMEM instead of three HBM
round-trips; the jnp composition in ``core/lsh.signatures_and_buckets``
stays the bit-exact oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.utils import hash_combine, hash_u32

BIG = np.int32(2**31 - 1)


def _kernel(fp_ref, map_ref, min_ref, max_ref):
    kd = pl.program_id(2)

    @pl.when(kd == 0)
    def _init():
        min_ref[...] = jnp.full_like(min_ref, BIG)
        max_ref[...] = jnp.zeros_like(max_ref)

    fp = fp_ref[...]  # (bn, bd) int8 {0,1}
    hm = map_ref[...]  # (bd, bh) int32
    mask = (fp > 0)[:, :, None]  # (bn, bd, 1)
    mvals = hm[None, :, :]  # (1, bd, bh)
    cur_min = jnp.where(mask, mvals, BIG).min(axis=1)  # (bn, bh)
    cur_max = jnp.where(mask, mvals, jnp.int32(0)).max(axis=1)
    min_ref[...] = jnp.minimum(min_ref[...], cur_min)
    max_ref[...] = jnp.maximum(max_ref[...], cur_max)


@functools.partial(jax.jit, static_argnames=("bn", "bd", "bh", "interpret"))
def minmax_hash(fp: jax.Array, mappings: jax.Array, *, bn: int = 16,
                bd: int = 256, bh: int = 256,
                interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """fp: (N, D) int8/bool; mappings: (D, H) int32. Returns (N,H)x2 int32.

    N % bn == 0, D % bd == 0, H % bh == 0 (ops.py pads as needed).
    """
    n, d = fp.shape
    d2, h = mappings.shape
    assert d == d2, (fp.shape, mappings.shape)
    assert n % bn == 0 and d % bd == 0 and h % bh == 0, (n, d, h, bn, bd, bh)
    fp = fp.astype(jnp.int8)
    grid = (n // bn, h // bh, d // bd)
    out_shape = [
        jax.ShapeDtypeStruct((n, h), jnp.int32),
        jax.ShapeDtypeStruct((n, h), jnp.int32),
    ]
    mins, maxs = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bd, bh), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((bn, bh), lambda i, j, k: (i, j)),
            pl.BlockSpec((bn, bh), lambda i, j, k: (i, j)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(fp, mappings)
    return mins, maxs


# ---------------------------------------------------------------------------
# fused signature fold + bucket addressing epilogue (ISSUE 3)
# ---------------------------------------------------------------------------


def _sig_kernel(fp_ref, map_ref, salt_ref, min_ref, max_ref, sig_ref,
                bkt_ref, *, f: int, use_minmax: bool, n_buckets: int):
    kd = pl.program_id(2)

    @pl.when(kd == 0)
    def _init():
        min_ref[...] = jnp.full_like(min_ref, BIG)
        max_ref[...] = jnp.zeros_like(max_ref)

    fp = fp_ref[...]                     # (bn, bt*f) int8 {0,1}
    hm = map_ref[...]                    # (bd, bt*f) int32
    mask = (fp > 0)[:, :, None]
    mvals = hm[None, :, :]
    min_ref[...] = jnp.minimum(min_ref[...],
                               jnp.where(mask, mvals, BIG).min(axis=1))
    max_ref[...] = jnp.maximum(max_ref[...],
                               jnp.where(mask, mvals, jnp.int32(0)).max(axis=1))

    # Epilogue on the final reduction step: fold the f per-function hashes
    # of each table into its signature, then the salted bucket address —
    # still in VMEM, no extra HBM pass over the (N, H) min/max planes.
    @pl.when(kd == pl.num_programs(2) - 1)
    def _fold():
        bn, bh = min_ref.shape
        mins = min_ref[...].astype(jnp.uint32)
        if use_minmax:
            per_fn = hash_combine(mins, max_ref[...].astype(jnp.uint32))
        else:
            per_fn = mins
        per_fn = per_fn.reshape(bn, bh // f, f)
        sig = jnp.zeros((bn, bh // f), jnp.uint32)
        for q in range(f):               # static fold, matches fold_hashes
            sig = hash_combine(sig, per_fn[:, :, q])
        sig_ref[...] = sig
        bkt = hash_combine(sig, salt_ref[...])
        bkt_ref[...] = (bkt & jnp.uint32(n_buckets - 1)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=(
    "f", "use_minmax", "n_buckets", "bn", "bd", "bt", "interpret"))
def minmax_sig_buckets(fp: jax.Array, mappings: jax.Array, salts: jax.Array,
                       *, f: int, use_minmax: bool, n_buckets: int,
                       bn: int = 16, bd: int = 256, bt: int = 32,
                       interpret: bool = False
                       ) -> tuple[jax.Array, jax.Array]:
    """fp (N, D) × mappings (D, T*f) → (signatures (N, T) uint32,
    bucket ids (N, T) int32), T*f laid out func-fastest like
    ``lsh.hash_mappings``. ``salts`` is the (1, T) per-table bucket salt
    (``lsh.bucket_salts``). N % bn == 0, D % bd == 0, T % bt == 0.
    """
    n, d = fp.shape
    h = mappings.shape[1]
    t = h // f
    assert h == t * f and salts.shape == (1, t), (mappings.shape, salts.shape)
    assert n % bn == 0 and d % bd == 0 and t % bt == 0, (n, d, t, bn, bd, bt)
    fp = fp.astype(jnp.int8)
    grid = (n // bn, t // bt, d // bd)
    bh = bt * f
    _, _, sig, bkt = pl.pallas_call(
        functools.partial(_sig_kernel, f=f, use_minmax=use_minmax,
                          n_buckets=n_buckets),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bd, bh), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bt), lambda i, j, k: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bn, bh), lambda i, j, k: (i, j)),
            pl.BlockSpec((bn, bh), lambda i, j, k: (i, j)),
            pl.BlockSpec((bn, bt), lambda i, j, k: (i, j)),
            pl.BlockSpec((bn, bt), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, h), jnp.int32),
            jax.ShapeDtypeStruct((n, h), jnp.int32),
            jax.ShapeDtypeStruct((n, t), jnp.uint32),
            jax.ShapeDtypeStruct((n, t), jnp.int32),
        ],
        interpret=interpret,
    )(fp, mappings, salts)
    return sig, bkt
