"""Pallas TPU kernel: fused windowing + DFT matmul + power magnitude.

FFT butterflies map poorly onto the 128×128 systolic MXU; for the short,
fixed analysis windows used by the fingerprinter the STFT is a dense
(frames @ DFT) matmul (DESIGN.md §3.3). The kernel fuses the Hann window,
both real/imag matmuls and |·|² so only the power spectrogram hits HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(fr_ref, win_ref, dr_ref, di_ref, out_ref):
    x = fr_ref[...] * win_ref[...]  # (bf, L) * (1, L)
    re = jax.lax.dot_general(x, dr_ref[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    im = jax.lax.dot_general(x, di_ref[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    out_ref[...] = (re * re + im * im).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bf", "interpret"))
def stft_mag(frames: jax.Array, window: jax.Array, dft_r: jax.Array,
             dft_i: jax.Array, *, bf: int = 256,
             interpret: bool = False) -> jax.Array:
    """frames: (N, L); window: (1, L); dft_r/i: (L, K). N % bf == 0."""
    n, l = frames.shape
    k = dft_r.shape[1]
    assert n % bf == 0, (n, bf)
    grid = (n // bf,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bf, l), lambda i: (i, 0)),
            pl.BlockSpec((1, l), lambda i: (0, 0)),
            pl.BlockSpec((l, k), lambda i: (0, 0)),
            pl.BlockSpec((l, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bf, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(frames, window, dft_r, dft_i)
