"""Pure-jnp oracles for every Pallas kernel in this package.

Each function here defines the exact semantics the corresponding kernel in
``kernels/<name>.py`` must reproduce (tests assert allclose across shape /
dtype sweeps, with the kernel run in interpret mode on CPU).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Min-Max hash (paper §6.2, Algorithm 1) — the LSH hot spot
# ---------------------------------------------------------------------------


def minmax_hash(fp: jax.Array, mappings: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Min and max of hash mappings over the non-zero dims of each fingerprint.

    Args:
      fp: (N, D) boolean fingerprints.
      mappings: (D, H) int32 hash values in [0, 2**31) — one column per hash fn.

    Returns:
      (mins, maxs): each (N, H) int32. Rows with an all-zero fingerprint get
      mins = BIG, maxs = 0 (callers mask them out).
    """
    big = jnp.int32(np.int32(2**31 - 1))
    m = mappings[None, :, :]
    mask = fp[:, :, None]
    mins = jnp.where(mask, m, big).min(axis=1)
    maxs = jnp.where(mask, m, jnp.int32(0)).max(axis=1)
    return mins.astype(jnp.int32), maxs.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Standard-decomposition 2-D Haar wavelet transform (paper §5.1 step 2)
# ---------------------------------------------------------------------------


def haar_matrix(n: int) -> np.ndarray:
    """Full multilevel orthonormal 1-D Haar transform matrix (n x n), n=2^k.

    Row-ordering: [approximation, detail(level=log2(n)) ... detail(level=1)],
    i.e. the classic recursive construction: H_n = [[H_{n/2} ⊗ avg],
    [I_{n/2} ⊗ diff]].
    """
    assert n & (n - 1) == 0, f"haar size {n} must be a power of two"
    h = np.array([[1.0]])
    while h.shape[0] < n:
        m = h.shape[0]
        top = np.kron(h, np.array([[1.0, 1.0]]) / math.sqrt(2.0))
        bot = np.kron(np.eye(m), np.array([[1.0, -1.0]]) / math.sqrt(2.0))
        h = np.concatenate([top, bot], axis=0)
    return h.astype(np.float32)


def haar2d(imgs: jax.Array) -> jax.Array:
    """Standard-decomposition 2-D Haar transform of (..., H, W) images.

    The standard (tensor-product) decomposition is two dense orthogonal
    matmuls — the MXU-native formulation (DESIGN.md §3.4).
    """
    h, w = imgs.shape[-2:]
    th = jnp.asarray(haar_matrix(h), imgs.dtype)
    tw = jnp.asarray(haar_matrix(w), imgs.dtype)
    return jnp.einsum("ij,...jk,lk->...il", th, imgs, tw)


# ---------------------------------------------------------------------------
# STFT magnitude via DFT matmul (paper §5.1 step 1)
# ---------------------------------------------------------------------------


def dft_matrices(frame_len: int, n_freq: int) -> tuple[np.ndarray, np.ndarray]:
    """Real/imag DFT analysis matrices (frame_len, n_freq) for rfft bins."""
    t = np.arange(frame_len)[:, None]
    k = np.arange(n_freq)[None, :]
    ang = -2.0 * np.pi * t * k / frame_len
    return np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32)


def stft_mag(frames: jax.Array, window: jax.Array, dft_r: jax.Array,
             dft_i: jax.Array) -> jax.Array:
    """Power spectrogram of pre-framed data.

    frames: (N, L); window: (L,); dft_r/dft_i: (L, K). Returns (N, K) power.
    """
    xw = frames * window[None, :]
    re = xw @ dft_r
    im = xw @ dft_i
    return re * re + im * im


# ---------------------------------------------------------------------------
# Packed-bit Jaccard similarity (candidate verification)
# ---------------------------------------------------------------------------


def jaccard_popcount(a: jax.Array, b: jax.Array) -> jax.Array:
    """Jaccard similarity of row-aligned packed binary vectors.

    a, b: (P, W) uint32 packed fingerprints. Returns (P,) float32; empty
    unions give 0.
    """
    inter = jax.lax.population_count(a & b).astype(jnp.int32).sum(axis=-1)
    union = jax.lax.population_count(a | b).astype(jnp.int32).sum(axis=-1)
    return jnp.where(union > 0, inter / jnp.maximum(union, 1), 0.0).astype(
        jnp.float32
    )


# ---------------------------------------------------------------------------
# Flash attention (serving/training hot spot; GQA + causal)
# ---------------------------------------------------------------------------


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True) -> jax.Array:
    """Reference attention. q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D).

    Hq % Hkv == 0 (GQA). Softmax in fp32. Returns (B, Hq, Sq, D) in q.dtype.
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        qi = jnp.arange(sq)[:, None] + (sk - sq)
        ki = jnp.arange(sk)[None, :]
        scores = jnp.where(ki <= qi, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, vx.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Fused Mamba1 selective scan (falcon-mamba memory-wall fix)
# ---------------------------------------------------------------------------


def mamba_scan(xdt: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
               c: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Sequential-reference selective scan.

    xdt/dt: (B, S, Di); a: (Di, N); b/c: (B, S, N) →
    (y (B, S, Di), h_final (B, Di, N)).
    """
    bsz, s, di = xdt.shape
    n = a.shape[1]

    def step(h, t):
        g = jnp.exp(dt[:, t, :, None] * a[None])          # (B, Di, N)
        h = g * h + xdt[:, t, :, None] * b[:, t, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c[:, t])
        return h, y

    h0 = jnp.zeros((bsz, di, n), jnp.float32)
    h_final, ys = jax.lax.scan(step, h0, jnp.arange(s))
    return jnp.swapaxes(ys, 0, 1).astype(xdt.dtype), h_final
