"""Pallas TPU kernel: packed-bit Jaccard similarity for candidate pairs.

Fingerprints are packed 32 bits/lane; Jaccard = popcount(a&b)/popcount(a|b)
evaluated on the VPU. Used to exactly verify LSH candidate pairs (an
exactness knob the paper's hash-match-count proxy lacks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, out_ref):
    a = a_ref[...]
    b = b_ref[...]
    inter = jax.lax.population_count(a & b).astype(jnp.int32).sum(axis=-1)
    union = jax.lax.population_count(a | b).astype(jnp.int32).sum(axis=-1)
    out_ref[...] = jnp.where(
        union > 0, inter.astype(jnp.float32) / jnp.maximum(union, 1), 0.0)


@functools.partial(jax.jit, static_argnames=("bp", "interpret"))
def jaccard_popcount(a: jax.Array, b: jax.Array, *, bp: int = 512,
                     interpret: bool = False) -> jax.Array:
    """a, b: (P, W) uint32 packed rows. Returns (P,) float32. P % bp == 0."""
    p, w = a.shape
    assert a.shape == b.shape and p % bp == 0, (a.shape, b.shape, bp)
    grid = (p // bp,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, w), lambda i: (i, 0)),
            pl.BlockSpec((bp, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.float32),
        interpret=interpret,
    )(a, b)
