"""Pallas TPU kernels for the FAST pipeline + LM serving hot spots.

Layout (per the repo contract): ``<name>.py`` holds the pl.pallas_call +
BlockSpec kernel, ``ops.py`` the jit'd padding/dispatch wrappers, ``ref.py``
the pure-jnp oracles used by the tests.
"""
from repro.kernels import ops, ref  # noqa: F401
