"""Jit'd public wrappers for the Pallas kernels.

Each wrapper:
  * pads inputs to kernel-friendly block multiples and un-pads outputs,
  * selects interpret mode automatically off-TPU (kernels VALIDATE on CPU
    via interpret=True; TPU is the compile target),
  * falls back to the pure-jnp oracle when ``use_pallas=False`` (the default
    for distributed dry-run lowering, where XLA-partitionable HLO is wanted).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as _ref
from repro.kernels import flash_attention as _fa
from repro.kernels import mamba_scan as _ms
from repro.kernels import haar2d as _haar
from repro.kernels import jaccard_popcount as _jac
from repro.kernels import minmax_hash as _mm
from repro.kernels import stft_mag as _stft
from repro.utils import round_up


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_axis(x: jax.Array, axis: int, mult: int, value=0) -> jax.Array:
    n = x.shape[axis]
    pad = round_up(max(n, 1), mult) - n
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------


def minmax_hash(fp: jax.Array, mappings: jax.Array, *, use_pallas: bool = True,
                bn: int = 16, bd: int = 256, bh: int = 256):
    """(N, D) fingerprints × (D, H) mappings -> (mins, maxs), each (N, H)."""
    if not use_pallas:
        return _ref.minmax_hash(fp.astype(bool), mappings)
    n, d = fp.shape
    h = mappings.shape[1]
    bn = min(bn, round_up(n, 8))
    bd = min(bd, round_up(d, 128))
    bh = min(bh, round_up(h, 128))
    fp_p = _pad_axis(_pad_axis(fp.astype(jnp.int8), 0, bn), 1, bd)
    mp_p = _pad_axis(_pad_axis(mappings, 0, bd), 1, bh, value=0)
    mins, maxs = _mm.minmax_hash(fp_p, mp_p, bn=bn, bd=bd, bh=bh,
                                 interpret=_interpret())
    return mins[:n, :h], maxs[:n, :h]


def minmax_sig_buckets(fp: jax.Array, mappings: jax.Array, salts: jax.Array,
                       *, use_minmax: bool, n_buckets: int, bn: int = 16,
                       bd: int = 256, bt: int = 32):
    """(N, D) fingerprints × (D, T*f) mappings → per-table (signatures,
    bucket ids), each (N, T) — the Min-Max kernel with the signature fold
    + bucket addressing fused into its epilogue.

    Pallas-only entry: the bit-exact jnp oracle lives in
    ``core/lsh.signatures_and_buckets`` (which is also the only caller
    that decides between the two).
    """
    n, d = fp.shape
    t = salts.shape[0]
    f = mappings.shape[1] // t
    bn = min(bn, round_up(n, 8))
    bd = min(bd, round_up(d, 128))
    bt = min(bt, round_up(t, 8))
    fp_p = _pad_axis(_pad_axis(fp.astype(jnp.int8), 0, bn), 1, bd)
    # padding H to a multiple of bt*f pads whole tables (func-fastest
    # layout), which the final [:t] slice drops again
    mp_p = _pad_axis(_pad_axis(mappings, 0, bd), 1, bt * f, value=0)
    salt_p = _pad_axis(salts.reshape(1, -1).astype(jnp.uint32), 1, bt)
    sig, bkt = _mm.minmax_sig_buckets(
        fp_p, mp_p, salt_p, f=f, use_minmax=use_minmax, n_buckets=n_buckets,
        bn=bn, bd=bd, bt=bt, interpret=_interpret())
    return sig[:n, :t], bkt[:n, :t]


def haar2d(imgs: jax.Array, *, use_pallas: bool = True, bn: int = 128):
    """Standard-decomposition 2-D Haar transform of (N, H, W) images."""
    if not use_pallas:
        return _ref.haar2d(imgs)
    n, h, w = imgs.shape
    th = jnp.asarray(_ref.haar_matrix(h), imgs.dtype)
    tw = jnp.asarray(_ref.haar_matrix(w), imgs.dtype)
    bn = min(bn, round_up(n, 8))
    imgs_p = _pad_axis(imgs, 0, bn)
    out = _haar.haar2d(imgs_p, th, tw, bn=bn, interpret=_interpret())
    return out[:n]


def stft_mag(frames: jax.Array, window: jax.Array, dft_r: jax.Array,
             dft_i: jax.Array, *, use_pallas: bool = True, bf: int = 256):
    """(N, L) frames -> (N, K) power spectrogram."""
    if not use_pallas:
        return _ref.stft_mag(frames, window, dft_r, dft_i)
    n, l = frames.shape
    k = dft_r.shape[1]
    bf = min(bf, round_up(n, 8))
    lp = round_up(l, 128)
    kp = round_up(k, 128)
    frames_p = _pad_axis(_pad_axis(frames, 0, bf), 1, lp)
    win_p = _pad_axis(window.reshape(1, -1), 1, lp)
    dr_p = _pad_axis(_pad_axis(dft_r, 0, lp), 1, kp)
    di_p = _pad_axis(_pad_axis(dft_i, 0, lp), 1, kp)
    out = _stft.stft_mag(frames_p, win_p, dr_p, di_p, bf=bf,
                         interpret=_interpret())
    return out[:n, :k]


def jaccard_popcount(a: jax.Array, b: jax.Array, *, use_pallas: bool = True,
                     bp: int = 512):
    """Row-wise Jaccard of packed (P, W) uint32 fingerprints -> (P,) f32."""
    if not use_pallas:
        return _ref.jaccard_popcount(a, b)
    p, w = a.shape
    bp = min(bp, round_up(p, 8))
    a_p = _pad_axis(a, 0, bp)
    b_p = _pad_axis(b, 0, bp)
    out = _jac.jaccard_popcount(a_p, b_p, bp=bp, interpret=_interpret())
    return out[:p]


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, use_pallas: bool = True,
                    bq: int = 128, bk: int = 128):
    """GQA flash attention; q (B,Hq,Sq,D), k/v (B,Hkv,Sk,D) -> (B,Hq,Sq,D)."""
    if not use_pallas:
        return _ref.flash_attention(q, k, v, causal=causal)
    b, hq, sq, d = q.shape
    sk = k.shape[2]
    bq_ = min(bq, round_up(sq, 8))
    bk_ = min(bk, round_up(sk, 8))
    sq_p = round_up(sq, bq_)
    sk_p = round_up(sk, bk_)
    q_p = _pad_axis(q, 2, bq_)
    # Pad keys at the FRONT would shift causal offsets; pad at the back and
    # mask padded keys via an explicit -inf trick: padded k rows are zeros,
    # which under causal masking with offset sk-sq are attended — so instead
    # pad queries/keys and rely on the kernel's causal mask computed with the
    # ORIGINAL sq/sk. Simplest correct path: require multiples or fall back.
    if sq_p != sq or sk_p != sk:
        return _ref.flash_attention(q, k, v, causal=causal)
    del q_p
    return _fa.flash_attention(q, k, v, causal=causal, bq=bq_, bk=bk_,
                               interpret=_interpret())


def mamba_scan(xdt, dt, a, b, c, *, use_pallas: bool = True, bd: int = 128):
    """Fused selective scan; (B,S,Di)×(Di,N) → (y, h_final)."""
    if not use_pallas:
        return _ref.mamba_scan(xdt, dt, a, b, c)
    di = xdt.shape[2]
    bd = min(bd, di)
    while di % bd:
        bd //= 2
    return _ms.mamba_scan(xdt, dt, a, b, c, bd=max(bd, 1),
                          interpret=_interpret())
