"""Streaming detector: ring → fingerprints → index → pairs → events.

``StationStream`` owns one station's ingestion state: a ``WaveformRing``
(chunk framing + halo), a ``StreamingMAD`` (running §5.2 statistics), and a
``StreamingIndex`` state. Each ready block runs one jitted fixed-shape
step — fingerprint, sign, expire, insert, query — and the emitted pairs
either accumulate host-side (parity mode) or flow through a
``RollingPairFilter`` (bounded mode). ``StreamingDetector`` composes
stations and finishes with the *same* alignment stack as the offline path
(occurrence filter → channel merge → ``cluster_station`` → network
association), so a streamed trace yields the same detections as a batch
re-run, at O(chunk) cost per arrival instead of O(history).

Two memory regimes, selected by ``StreamConfig``:

* **parity mode** (defaults): every emitted triplet is kept until
  ``finalize`` runs the offline occurrence filter + clustering over the
  full accumulation — exact offline semantics, O(stream) host state.
* **bounded mode** (``window_fingerprints`` + ``filter_window_fingerprints``
  > 0): the jitted step expires index entries older than the sliding
  window, and triplets are retired window-by-window through the rolling
  occurrence filter into compact event rows — O(window) host state for an
  unbounded stream (the paper's §5.3/§6.5 partition-bounded post-processing
  made continuous). With ≥2 stations, ``poll_detections`` additionally
  associates closed-window events across stations after every push, so
  network detections surface near-real-time instead of only at finalize.

``snapshot``/``restore`` checkpoint the whole detector (index pytree, ring,
reservoir, pending blocks, rolling-filter state) through
``train/checkpoint.py``: a killed service restored from its last snapshot
reproduces the uninterrupted run's detections exactly.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import align as align_mod
from repro.core import fingerprint as fp_mod
from repro.core import lsh as lsh_mod
from repro.core.align import AlignConfig, Events
from repro.core.detect import DetectConfig
from repro.core.fingerprint import FingerprintConfig
from repro.core.lsh import INVALID, LSHConfig, Pairs
from repro.stream import index as index_mod
from repro.stream.index import IndexState
from repro.stream.ingest import StreamConfig, StreamingMAD, WaveformRing
from repro.train import checkpoint as ckpt_mod


@functools.partial(jax.jit, static_argnames=("fcfg",))
def block_coeffs(block: jax.Array, fcfg: FingerprintConfig) -> jax.Array:
    """(block_samples,) → (block_fp, n_coeff) Haar coefficients."""
    return fp_mod.coeffs_from_waveform(block, fcfg)


@functools.partial(jax.jit, static_argnames=("fcfg", "lcfg", "window"),
                   donate_argnums=(0,))
def stream_step(state: IndexState, coeffs: jax.Array, med: jax.Array,
                mad: jax.Array, mappings: jax.Array, base_id: jax.Array,
                valid: jax.Array, fcfg: FingerprintConfig, lcfg: LSHConfig,
                window: int = 0) -> tuple[IndexState, Pairs]:
    """One fixed-shape streaming step: binarize → sign → expire → insert →
    query.

    Same-shape blocks reuse one executable (base_id and the valid mask are
    traced, configs and the window length are static); insert-then-query
    with the id-ordered emission rule yields each (earlier, later) pair
    exactly once per colliding table. Invalid rows (zero-padded flush
    tails) get unique filler signatures, are not stored, and cannot match.

    ``window`` > 0 expires index entries older than the newest id in this
    block minus the window *before* inserting it, so every emitted pair
    satisfies idx2 - idx1 < window — the sliding detection window.
    """
    bits, _ = fp_mod.binarize_coeffs(coeffs, fcfg, (med, mad))
    sigs = lsh_mod.signatures(bits, mappings, lcfg, valid=valid)
    ids = base_id + jnp.arange(sigs.shape[0], dtype=jnp.int32)
    if window > 0:
        newest = base_id + valid.sum(dtype=jnp.int32)
        state = index_mod.expire(state, newest - jnp.int32(window))
    state = index_mod.insert(state, sigs, ids, lcfg, valid=valid)
    pairs = index_mod.query(state, sigs, ids, lcfg)
    return state, pairs


def pairs_from_triplets(tri: np.ndarray, pad_to: int = 1024) -> Pairs:
    """(m, 3) host triplets (idx1, idx2, sim) → masked fixed-size ``Pairs``.

    Padded to a multiple of ``pad_to`` so downstream jitted consumers see
    few distinct shapes.
    """
    tri = np.asarray(tri).reshape(-1, 3)
    m = tri.shape[0]
    size = max(pad_to, -(-max(m, 1) // pad_to) * pad_to)
    idx1 = np.full(size, INVALID, np.int32)
    idx2 = np.full(size, INVALID, np.int32)
    sim = np.zeros(size, np.int32)
    val = np.zeros(size, bool)
    idx1[:m] = tri[:, 0]
    idx2[:m] = tri[:, 1]
    sim[:m] = tri[:, 2]
    val[:m] = True
    return Pairs(idx1=jnp.asarray(idx1), idx2=jnp.asarray(idx2),
                 sim=jnp.asarray(sim), valid=jnp.asarray(val))


def events_to_rows(events: Events) -> np.ndarray:
    """Valid entries of an ``Events`` pytree → compact (k, 5) int64 rows
    (dt, onset, extent, size, score)."""
    v = np.asarray(events.valid)
    return np.stack(
        [np.asarray(events.dt)[v], np.asarray(events.onset)[v],
         np.asarray(events.extent)[v], np.asarray(events.size)[v],
         np.asarray(events.score)[v]], axis=1).astype(np.int64)


def events_from_rows(rows: np.ndarray, pad_to: int = 256) -> Events:
    """(k, 5) rows → masked ``Events`` padded to a multiple of ``pad_to``."""
    rows = np.asarray(rows, np.int64).reshape(-1, 5)
    k = rows.shape[0]
    size = max(pad_to, -(-max(k, 1) // pad_to) * pad_to)
    full = np.zeros((size, 5), np.int64)
    full[:k] = rows
    val = np.arange(size) < k
    fill = np.where(val, 0, INVALID)
    return Events(
        dt=jnp.asarray((full[:, 0] + fill).astype(np.int32)),
        onset=jnp.asarray((full[:, 1] + fill).astype(np.int32)),
        extent=jnp.asarray(full[:, 2].astype(np.int32)),
        size=jnp.asarray(full[:, 3].astype(np.int32)),
        score=jnp.asarray(full[:, 4].astype(np.int32)),
        valid=jnp.asarray(val))


class RollingPairFilter:
    """Rolling per-window §6.5 occurrence filter + clustering.

    Every emitted pair is assigned to the window of its *later* member (the
    query id that emitted it). Once the processed-id frontier passes a
    window's end, no further pair can land in it, so the window closes:
    the occurrence filter runs over its pairs with ids rebased into the
    static [w_start - lookback, w_start + window) span (the sliding index
    window guarantees partners reach back at most ``lookback``), survivors
    are channel-merged and diagonal-clustered exactly like finalize, and
    only the resulting compact event rows are retained. Buffered host pair
    state is therefore O(window) for an unbounded stream — the streaming
    analogue of the paper's partition-bounded post-processing.
    """

    def __init__(self, cfg: DetectConfig, window: int, lookback: int,
                 pad_to: int = 1024):
        if window <= 0 or lookback <= 0:
            raise ValueError(f"need positive filter window and lookback, "
                             f"got {window}, {lookback}")
        self.cfg = cfg
        self.window = int(window)
        self.lookback = int(lookback)
        self.pad_to = pad_to
        self.w_start = 0
        self.buf: list[np.ndarray] = []     # open-window (m, 3) triplets
        self.buf_rows = 0
        self.peak_rows = 0
        self.event_rows: list[np.ndarray] = []  # closed (k, 5) rows, active
        self.archive_rows: list[np.ndarray] = []  # retired from association
        self.windows_closed = 0
        self.pairs_seen = 0
        self.pairs_kept = 0

    def add(self, tri: np.ndarray) -> None:
        tri = np.asarray(tri).reshape(-1, 3)
        if tri.shape[0]:
            self.buf.append(tri)
            self.buf_rows += tri.shape[0]
            self.peak_rows = max(self.peak_rows, self.buf_rows)
            self.pairs_seen += tri.shape[0]

    def advance(self, frontier: int) -> int:
        """Close every window whose end the processed frontier has passed."""
        closed = 0
        while frontier >= self.w_start + self.window:
            self._close(self.w_start + self.window)
            closed += 1
        return closed

    def close_all(self, frontier: int) -> None:
        """Flush the open tail window (finalize boundary)."""
        self.advance(frontier)
        if self.buf_rows:
            self._close(self.w_start + self.window)

    def rows_tail(self, min_onset: int) -> np.ndarray:
        """Active event rows with onset ≥ ``min_onset`` (association feed)."""
        if not self.event_rows:
            return np.zeros((0, 5), np.int64)
        rows = np.concatenate(self.event_rows, axis=0)
        return rows[rows[:, 1] >= min_onset]

    def retire_below(self, min_onset: int) -> None:
        """Move rows the association floor has passed into the archive.

        Retired rows can never alert again (``rows_tail`` already excluded
        them), so keeping them out of the active list makes the per-push
        association scan O(active window), not O(stream). They remain part
        of ``all_rows`` for the authoritative finalize.
        """
        if not self.event_rows:
            return
        rows = np.concatenate(self.event_rows, axis=0)
        old = rows[:, 1] < min_onset
        if not old.any():
            return
        self.archive_rows.append(rows[old])
        keep = rows[~old]
        self.event_rows = [keep] if keep.shape[0] else []

    def all_rows(self) -> np.ndarray:
        rows = self.archive_rows + self.event_rows
        if not rows:
            return np.zeros((0, 5), np.int64)
        return np.concatenate(rows, axis=0)

    def _close(self, w_end: int) -> None:
        tri = (np.concatenate(self.buf, axis=0) if self.buf
               else np.zeros((0, 3), np.int64))
        in_w = tri[:, 1] < w_end
        cur, rest = tri[in_w], tri[~in_w]
        self.buf = [rest] if rest.shape[0] else []
        self.buf_rows = int(rest.shape[0])
        if cur.shape[0]:
            rows = self._filter_cluster(cur)
            if rows.shape[0]:
                self.event_rows.append(rows)
        self.w_start = w_end
        self.windows_closed += 1

    def _filter_cluster(self, tri: np.ndarray) -> np.ndarray:
        """One window's triplets → occurrence-filtered clustered rows."""
        lcfg, acfg = self.cfg.lsh, self.cfg.align
        pairs = pairs_from_triplets(tri, self.pad_to)
        if lcfg.occurrence_frac > 0:
            base = self.w_start - self.lookback
            v = pairs.valid
            local = Pairs(
                idx1=jnp.where(v, pairs.idx1 - base, INVALID),
                idx2=jnp.where(v, pairs.idx2 - base, INVALID),
                sim=pairs.sim, valid=v)
            filt, _ = lsh_mod.occurrence_filter(
                local, self.lookback + self.window, lcfg.occurrence_frac,
                limit=max(1, int(lcfg.occurrence_frac * self.window)))
            keep = filt.valid
            pairs = Pairs(idx1=jnp.where(keep, pairs.idx1, INVALID),
                          idx2=jnp.where(keep, pairs.idx2, INVALID),
                          sim=jnp.where(keep, pairs.sim, 0), valid=keep)
        self.pairs_kept += int(pairs.count())
        merged = align_mod.merge_channels(
            [(pairs.dt, pairs.idx1, pairs.sim, pairs.valid)],
            acfg.channel_threshold)
        events = align_mod.cluster_station(merged, acfg)
        return events_to_rows(events)

    def snapshot(self) -> tuple[dict, dict]:
        buf = (np.concatenate(self.buf, axis=0).astype(np.int64)
               if self.buf else np.zeros((0, 3), np.int64))
        return ({"buf": buf, "events": self.all_rows()},
                {"w_start": self.w_start, "windows_closed":
                 self.windows_closed, "pairs_seen": self.pairs_seen,
                 "pairs_kept": self.pairs_kept, "peak_rows": self.peak_rows})

    def restore(self, arrays: dict, scalars: dict) -> None:
        buf = np.asarray(arrays["buf"], np.int64).reshape(-1, 3)
        self.buf = [buf] if buf.shape[0] else []
        self.buf_rows = int(buf.shape[0])
        rows = np.asarray(arrays["events"], np.int64).reshape(-1, 5)
        self.event_rows = [rows] if rows.shape[0] else []
        self.w_start = int(scalars["w_start"])
        self.windows_closed = int(scalars["windows_closed"])
        self.pairs_seen = int(scalars["pairs_seen"])
        self.pairs_kept = int(scalars["pairs_kept"])
        self.peak_rows = int(scalars["peak_rows"])


@dataclasses.dataclass
class StreamStats:
    chunks: int = 0
    blocks: int = 0
    samples: int = 0
    fingerprints: int = 0
    pairs: int = 0
    chunk_wall_s: list = dataclasses.field(default_factory=list)

    def summary(self) -> dict:
        wall = np.asarray(self.chunk_wall_s or [0.0])
        total = float(wall.sum())
        return {
            "chunks": self.chunks,
            "blocks": self.blocks,
            "samples": self.samples,
            "fingerprints": self.fingerprints,
            "pairs": self.pairs,
            "wall_s": round(total, 4),
            "chunk_ms_p50": round(float(np.percentile(wall, 50)) * 1e3, 3),
            "chunk_ms_p95": round(float(np.percentile(wall, 95)) * 1e3, 3),
            "chunks_per_s": round(self.chunks / max(total, 1e-9), 2),
            "samples_per_s": round(self.samples / max(total, 1e-9), 1),
        }


class StationStream:
    """Incremental detection state for a single station."""

    def __init__(self, cfg: DetectConfig, scfg: StreamConfig,
                 med_mad: tuple[np.ndarray, np.ndarray] | None = None):
        self.cfg = cfg
        self.scfg = scfg
        fcfg, lcfg = cfg.fingerprint, cfg.lsh
        self.ring = WaveformRing(fcfg, scfg.block_fingerprints)
        self.mad = StreamingMAD(scfg.reservoir_rows, fcfg.n_coeff,
                                seed=scfg.seed)
        self.state = index_mod.init_index(lcfg, scfg.index)
        self.mappings = lsh_mod.hash_mappings(fcfg.fp_dim, lcfg)
        self.med_mad = None
        if med_mad is not None:
            self.med_mad = (jnp.asarray(med_mad[0]), jnp.asarray(med_mad[1]))
        self.pending: list[tuple[int, jax.Array]] = []  # pre-freeze blocks
        self.triplets: list[np.ndarray] = []            # (m, 3) idx1,idx2,sim
        self.rolling = scfg.filter_window_fingerprints > 0
        self.filter = (RollingPairFilter(cfg, scfg.filter_window_fingerprints,
                                         scfg.window_fingerprints)
                       if self.rolling else None)
        self.processed_fp = 0       # ids fully through the jitted step
        self._tri_rows = 0
        self.peak_tri_rows = 0
        self.stats = StreamStats()

    @property
    def stats_frozen(self) -> bool:
        return self.med_mad is not None

    def host_state_rows(self) -> int:
        """Candidate triplet rows currently buffered host-side — the
        quantity the rolling filter bounds."""
        return self.filter.buf_rows if self.rolling else self._tri_rows

    def push(self, chunk: np.ndarray) -> int:
        """Ingest one chunk; returns pairs emitted by its ready blocks."""
        t0 = time.perf_counter()
        emitted = 0
        for base_id, block in self.ring.push(chunk):
            coeffs = block_coeffs(jnp.asarray(block), self.cfg.fingerprint)
            if not self.stats_frozen:
                self.mad.update(np.asarray(coeffs))
                self.pending.append((base_id, coeffs))
                if len(self.pending) >= self.scfg.stats_warmup_blocks:
                    self._freeze_stats()
                    emitted += self._drain_pending()
            else:
                emitted += self._process(base_id, coeffs)
        self.stats.chunks += 1
        self.stats.samples += int(np.asarray(chunk).size)
        self.stats.chunk_wall_s.append(time.perf_counter() - t0)
        return emitted

    def _freeze_stats(self) -> None:
        med, mad = self.mad.stats()
        self.med_mad = (jnp.asarray(med), jnp.asarray(mad))

    def _drain_pending(self) -> int:
        emitted = 0
        for base_id, coeffs in self.pending:
            emitted += self._process(base_id, coeffs)
        self.pending = []
        return emitted

    def _process(self, base_id: int, coeffs: jax.Array,
                 valid: np.ndarray | None = None) -> int:
        med, mad = self.med_mad
        n = int(coeffs.shape[0])
        vmask = (np.ones(n, bool) if valid is None
                 else np.asarray(valid, bool))
        self.state, pairs = stream_step(
            self.state, coeffs, med, mad, self.mappings,
            jnp.int32(base_id), jnp.asarray(vmask),
            self.cfg.fingerprint, self.cfg.lsh,
            self.scfg.window_fingerprints)
        pv = np.asarray(pairs.valid)
        m = int(pv.sum())
        self.processed_fp = base_id + int(vmask.sum())
        if m:
            tri = np.stack([
                np.asarray(pairs.idx1)[pv],
                np.asarray(pairs.idx2)[pv],
                np.asarray(pairs.sim)[pv]], axis=1).astype(np.int64)
            if self.rolling:
                self.filter.add(tri)
            else:
                self.triplets.append(tri)
                self._tri_rows += m
        if self.rolling:
            self.filter.advance(self.processed_fp)
            self.peak_tri_rows = max(self.peak_tri_rows,
                                     self.filter.peak_rows)
        else:
            self.peak_tri_rows = max(self.peak_tri_rows, self._tri_rows)
        self.stats.blocks += 1
        self.stats.fingerprints += int(vmask.sum())
        self.stats.pairs += m
        return m

    def flush(self) -> int:
        """Process the buffered tail: freeze stats if still warming up,
        drain pending blocks, and run the partial last block (masked)."""
        emitted = 0
        part = self.ring.flush_partial()
        part_coeffs = None
        if part is not None:
            base_id, block, n_valid = part
            part_coeffs = block_coeffs(jnp.asarray(block),
                                       self.cfg.fingerprint)
            if not self.stats_frozen:
                self.mad.update(np.asarray(part_coeffs)[:n_valid])
        if not self.stats_frozen:
            if self.mad.filled < 2:
                return 0  # not enough signal ever arrived
            self._freeze_stats()
            emitted += self._drain_pending()
        if part is not None:
            base_id, block, n_valid = part
            vmask = np.arange(part_coeffs.shape[0]) < n_valid
            emitted += self._process(base_id, part_coeffs, valid=vmask)
        return emitted

    def accumulated_pairs(self, pad_to: int = 1024) -> Pairs:
        """All emitted triplets as a masked fixed-size ``Pairs``."""
        tri = (np.concatenate(self.triplets, axis=0) if self.triplets
               else np.zeros((0, 3), np.int64))
        return pairs_from_triplets(tri, pad_to)

    def finalize(self) -> tuple[Events, Pairs, dict]:
        """Occurrence filter + channel merge + diagonal clustering.

        Parity mode runs the offline reduction over the full accumulated
        pair set. Bounded mode closes the open rolling window and returns
        the concatenation of per-window events; raw pairs were already
        retired window-by-window, so the returned ``Pairs`` is empty.
        """
        self.flush()
        lcfg, acfg = self.cfg.lsh, self.cfg.align
        n_fp = self.ring.next_fp
        if self.rolling:
            self.filter.close_all(self.processed_fp)
            events = events_from_rows(self.filter.all_rows())
            fstats = {
                "fingerprints": n_fp,
                "pairs": self.filter.pairs_kept,
                "windows": self.filter.windows_closed,
                "events": int(events.count()),
                "peak_buffered_triplets": self.peak_tri_rows,
            }
            return events, pairs_from_triplets(np.zeros((0, 3))), fstats
        pairs = self.accumulated_pairs()
        fstats = {"fingerprints": n_fp}
        if lcfg.occurrence_frac > 0 and n_fp > 0:
            pairs, excluded = lsh_mod.occurrence_filter(
                pairs, n_fp, lcfg.occurrence_frac)
            fstats["excluded_fingerprints"] = int(excluded.sum())
        merged = align_mod.merge_channels(
            [(pairs.dt, pairs.idx1, pairs.sim, pairs.valid)],
            acfg.channel_threshold)
        events = align_mod.cluster_station(merged, acfg)
        fstats["pairs"] = int(pairs.count())
        fstats["events"] = int(events.count())
        fstats["peak_buffered_triplets"] = self.peak_tri_rows
        return events, pairs, fstats

    # -- snapshot / restore -------------------------------------------------

    def snapshot_state(self) -> tuple[dict, dict]:
        """(flat arrays, json-able extra) capturing this station exactly."""
        arrays = {
            "index/sig": np.asarray(jax.device_get(self.state.sig)),
            "index/ids": np.asarray(jax.device_get(self.state.ids)),
            "index/cursor": np.asarray(jax.device_get(self.state.cursor)),
            "index/inserted": np.asarray(jax.device_get(
                self.state.inserted)),
        }
        ring_a, ring_s = self.ring.snapshot()
        arrays["ring/buf"] = ring_a["buf"]
        mad_a, mad_s = self.mad.snapshot()
        arrays["mad/rows"] = mad_a["rows"]
        arrays["stats/chunk_wall_s"] = np.asarray(self.stats.chunk_wall_s,
                                                  np.float64)
        extra = {
            "ring": ring_s, "mad": mad_s,
            "frozen": self.stats_frozen,
            "processed_fp": self.processed_fp,
            "peak_tri_rows": self.peak_tri_rows,
            "stats": {"chunks": self.stats.chunks,
                      "blocks": self.stats.blocks,
                      "samples": self.stats.samples,
                      "fingerprints": self.stats.fingerprints,
                      "pairs": self.stats.pairs},
        }
        if self.stats_frozen:
            arrays["med"] = np.asarray(self.med_mad[0])
            arrays["mad_stat"] = np.asarray(self.med_mad[1])
        if self.pending:
            arrays["pending/base"] = np.asarray(
                [b for b, _ in self.pending], np.int64)
            arrays["pending/coeffs"] = np.stack(
                [np.asarray(c) for _, c in self.pending]).astype(np.float32)
        if self.rolling:
            f_a, f_s = self.filter.snapshot()
            arrays["filter/buf"] = f_a["buf"]
            arrays["filter/events"] = f_a["events"]
            extra["filter"] = f_s
        else:
            arrays["triplets"] = (
                np.concatenate(self.triplets, axis=0).astype(np.int64)
                if self.triplets else np.zeros((0, 3), np.int64))
        return arrays, extra

    def restore_state(self, arrays: dict, extra: dict) -> None:
        t, b, c = self.state.shape
        self.state = IndexState(
            sig=jnp.asarray(arrays["index/sig"], jnp.uint32),
            ids=jnp.asarray(arrays["index/ids"], jnp.int32),
            cursor=jnp.asarray(arrays["index/cursor"], jnp.int32),
            inserted=jnp.asarray(arrays["index/inserted"], jnp.int32))
        assert self.state.shape == (t, b, c), \
            (self.state.shape, (t, b, c))
        self.ring.restore({"buf": arrays["ring/buf"]}, extra["ring"])
        self.mad.restore({"rows": arrays["mad/rows"]}, extra["mad"])
        self.med_mad = None
        if extra["frozen"]:
            self.med_mad = (jnp.asarray(arrays["med"]),
                            jnp.asarray(arrays["mad_stat"]))
        self.pending = []
        if "pending/base" in arrays:
            bases = np.asarray(arrays["pending/base"], np.int64)
            coeffs = np.asarray(arrays["pending/coeffs"], np.float32)
            self.pending = [(int(bases[i]), jnp.asarray(coeffs[i]))
                            for i in range(bases.shape[0])]
        if self.rolling:
            self.filter.restore(
                {"buf": arrays["filter/buf"],
                 "events": arrays["filter/events"]}, extra["filter"])
            self.triplets = []
            self._tri_rows = 0
        else:
            tri = np.asarray(arrays["triplets"], np.int64).reshape(-1, 3)
            self.triplets = [tri] if tri.shape[0] else []
            self._tri_rows = int(tri.shape[0])
        self.processed_fp = int(extra["processed_fp"])
        self.peak_tri_rows = int(extra["peak_tri_rows"])
        s = extra["stats"]
        self.stats = StreamStats(
            chunks=int(s["chunks"]), blocks=int(s["blocks"]),
            samples=int(s["samples"]),
            fingerprints=int(s["fingerprints"]), pairs=int(s["pairs"]),
            chunk_wall_s=np.asarray(arrays["stats/chunk_wall_s"],
                                    np.float64).tolist())


class StreamingDetector:
    """Multi-station streaming FAST: push chunks, read detections.

    ``push`` accepts (n_stations, chunk_len) or a 1-D chunk for a single
    station; chunk lengths may vary call to call. ``finalize`` runs the
    per-station alignment and (when n_stations ≥ 2) the network
    association, mirroring ``detect_events``. In bounded mode each push
    also polls the incremental association: newly final multi-station
    detections land in ``alerts`` as they close, not only at finalize.
    """

    def __init__(self, cfg: DetectConfig, scfg: StreamConfig | None = None,
                 n_stations: int = 1,
                 med_mad: tuple[np.ndarray, np.ndarray] | None = None):
        self.cfg = cfg
        self.scfg = scfg or StreamConfig()
        self.stations = [StationStream(cfg, self.scfg, med_mad=med_mad)
                         for _ in range(n_stations)]
        self.rolling = self.scfg.filter_window_fingerprints > 0
        self.alerts: list[np.ndarray] = []   # (k, 4) dt, onset, n_st, score
        self._emitted = np.zeros((0, 2), np.int64)  # alerted (dt, onset)
        self._assoc_lo = 0
        self._polled_windows = 0  # window closes seen by the last poll

    def push(self, chunk: np.ndarray) -> int:
        chunk = np.asarray(chunk, np.float32)
        if chunk.ndim == 1:
            chunk = chunk[None, :]
        assert chunk.shape[0] == len(self.stations), \
            (chunk.shape, len(self.stations))
        emitted = sum(st.push(chunk[i])
                      for i, st in enumerate(self.stations))
        if self.rolling and len(self.stations) >= 2:
            new = self.poll_detections()
            if new.shape[0]:
                self.alerts.append(new)
        return emitted

    def poll_detections(self) -> np.ndarray:
        """Incremental network association over closed-window events.

        Returns (k, 4) int64 rows (dt, onset, n_stations, score) for
        groups not alerted before — the near-real-time view. ``finalize``
        remains the authoritative association over the full event history.
        """
        acfg = self.cfg.align
        if not self.rolling or len(self.stations) < 2:
            return np.zeros((0, 4), np.int64)
        # the active rows only change when a window closes — don't repeat
        # the association dispatch on pushes that closed nothing
        closed = sum(st.filter.windows_closed for st in self.stations)
        if closed == self._polled_windows:
            return np.zeros((0, 4), np.int64)
        self._polled_windows = closed
        per_station = [st.filter.rows_tail(self._assoc_lo)
                       for st in self.stations]
        if sum(r.shape[0] for r in per_station) == 0:
            return np.zeros((0, 4), np.int64)
        events = [events_from_rows(r) for r in per_station]
        det = align_mod.associate_network(events, acfg, len(self.stations))
        v = np.asarray(det["valid"])
        rows = np.stack([np.asarray(det["dt"])[v],
                         np.asarray(det["onset"])[v],
                         np.asarray(det["n_stations"])[v],
                         np.asarray(det["score"])[v]],
                        axis=1).astype(np.int64)
        if self._emitted.shape[0] and rows.shape[0]:
            near = ((np.abs(rows[:, 0, None] - self._emitted[None, :, 0])
                     <= acfg.dt_tol)
                    & (np.abs(rows[:, 1, None] - self._emitted[None, :, 1])
                       <= acfg.onset_tol))
            rows = rows[~near.any(axis=1)]
        if rows.shape[0]:
            self._emitted = np.concatenate([self._emitted, rows[:, :2]])
        # onsets below every station's closed frontier minus the sliding
        # window can gain no further members — stop rescanning them, and
        # archive rows + dedup keys the floor has passed so the per-push
        # scan stays O(active window) instead of O(stream)
        frontier = min(st.filter.w_start for st in self.stations)
        self._assoc_lo = max(self._assoc_lo, frontier
                             - self.scfg.window_fingerprints
                             - 2 * acfg.onset_tol)
        for st in self.stations:
            st.filter.retire_below(self._assoc_lo)
        if self._emitted.shape[0]:
            live = self._emitted[:, 1] >= self._assoc_lo - acfg.onset_tol
            self._emitted = self._emitted[live]
        return rows

    def finalize(self) -> tuple[dict | None, list[Events], dict]:
        station_events, stats = [], {}
        for i, st in enumerate(self.stations):
            events, _, fstats = st.finalize()
            station_events.append(events)
            for k, v in fstats.items():
                stats[f"station{i}_{k}"] = v
        detections = None
        if len(self.stations) >= 2:
            detections = align_mod.associate_network(
                station_events, self.cfg.align, len(self.stations))
            stats["detections"] = int(detections["valid"].sum())
        if self.rolling:
            stats["alerts"] = int(sum(a.shape[0] for a in self.alerts))
        stats["ingest"] = [st.stats.summary() for st in self.stations]
        return detections, station_events, stats

    # -- snapshot / restore -------------------------------------------------

    def snapshot(self, ckpt_dir: str, step: int | None = None, *,
                 background: bool = False, keep: int = 3):
        """Checkpoint the whole detector through ``train/checkpoint.py``.

        One ``step_<N>`` directory holds every station's index pytree, ring
        buffer, MAD reservoir, pending blocks, and (bounded mode) rolling
        filter state, plus the detector's alert dedup keys — everything
        needed for ``restore`` to continue the stream bit-exactly.
        """
        arrays: dict[str, np.ndarray] = {}
        st_extra = []
        for i, st in enumerate(self.stations):
            a, e = st.snapshot_state()
            arrays.update({f"s{i}/{k}": v for k, v in a.items()})
            st_extra.append(e)
        arrays["detector/emitted"] = self._emitted
        arrays["detector/alerts"] = (
            np.concatenate(self.alerts, axis=0).astype(np.int64)
            if self.alerts else np.zeros((0, 4), np.int64))
        extra = {"n_stations": len(self.stations), "stations": st_extra,
                 "assoc_lo": self._assoc_lo,
                 "scfg": {
                     "block_fingerprints": self.scfg.block_fingerprints,
                     "window_fingerprints": self.scfg.window_fingerprints,
                     "filter_window_fingerprints":
                         self.scfg.filter_window_fingerprints,
                 }}
        if step is None:
            step = self.stations[0].stats.chunks
        return ckpt_mod.save_checkpoint(ckpt_dir, step, arrays, extra=extra,
                                        background=background, keep=keep)

    @classmethod
    def restore(cls, ckpt_dir: str, cfg: DetectConfig,
                scfg: StreamConfig | None = None, *,
                step: int | None = None) -> tuple["StreamingDetector", int]:
        """Rebuild a detector from its latest (or given) snapshot.

        The snapshot records the streaming mode it was taken under; a
        ``scfg`` whose block size or window lengths differ is rejected up
        front (the station state layouts are not interchangeable).
        """
        arrays, extra, step = ckpt_mod.restore_flat(ckpt_dir, step=step)
        det = cls(cfg, scfg, n_stations=int(extra["n_stations"]))
        saved = extra.get("scfg", {})
        for key, have in (
                ("block_fingerprints", det.scfg.block_fingerprints),
                ("window_fingerprints", det.scfg.window_fingerprints),
                ("filter_window_fingerprints",
                 det.scfg.filter_window_fingerprints)):
            if key in saved and int(saved[key]) != int(have):
                raise ValueError(
                    f"snapshot was taken with {key}={saved[key]} but the "
                    f"restoring StreamConfig has {have}; pass a matching "
                    f"config (e.g. the same --window-fp/--filter-window-fp "
                    f"flags the snapshotting service ran with)")
        for i, st in enumerate(det.stations):
            prefix = f"s{i}/"
            sub = {k[len(prefix):]: v for k, v in arrays.items()
                   if k.startswith(prefix)}
            st.restore_state(sub, extra["stations"][i])
        det._emitted = np.asarray(arrays["detector/emitted"],
                                  np.int64).reshape(-1, 2)
        alerts = np.asarray(arrays["detector/alerts"],
                            np.int64).reshape(-1, 4)
        det.alerts = [alerts] if alerts.shape[0] else []
        det._assoc_lo = int(extra["assoc_lo"])
        if det.rolling:
            det._polled_windows = sum(st.filter.windows_closed
                                      for st in det.stations)
        return det, step
