"""Streaming detector: ring → fingerprints → index → pairs → events.

``StationStream`` owns one station's ingestion state: a ``WaveformRing``
(chunk framing + halo), a ``StreamingMAD`` (running §5.2 statistics), and a
``StreamingIndex`` state. Each ready block runs one jitted fixed-shape
step — fingerprint, sign, insert, query — and the emitted pairs accumulate
host-side. ``StreamingDetector`` composes stations and finishes with the
*same* alignment stack as the offline path (occurrence filter →
channel merge → ``cluster_station`` → network association), so a streamed
trace yields the same detections as a batch re-run, at O(chunk) cost per
arrival instead of O(history).
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import align as align_mod
from repro.core import fingerprint as fp_mod
from repro.core import lsh as lsh_mod
from repro.core.align import AlignConfig, Events
from repro.core.detect import DetectConfig
from repro.core.fingerprint import FingerprintConfig
from repro.core.lsh import INVALID, LSHConfig, Pairs
from repro.stream import index as index_mod
from repro.stream.index import IndexState
from repro.stream.ingest import StreamConfig, StreamingMAD, WaveformRing


@functools.partial(jax.jit, static_argnames=("fcfg",))
def block_coeffs(block: jax.Array, fcfg: FingerprintConfig) -> jax.Array:
    """(block_samples,) → (block_fp, n_coeff) Haar coefficients."""
    return fp_mod.coeffs_from_waveform(block, fcfg)


@functools.partial(jax.jit, static_argnames=("fcfg", "lcfg"),
                   donate_argnums=(0,))
def stream_step(state: IndexState, coeffs: jax.Array, med: jax.Array,
                mad: jax.Array, mappings: jax.Array, base_id: jax.Array,
                valid: jax.Array, fcfg: FingerprintConfig, lcfg: LSHConfig
                ) -> tuple[IndexState, Pairs]:
    """One fixed-shape streaming step: binarize → sign → insert → query.

    Same-shape blocks reuse one executable (base_id and the valid mask are
    traced, configs are static); insert-then-query with the id-ordered
    emission rule yields each (earlier, later) pair exactly once per
    colliding table. Invalid rows (zero-padded flush tails) get unique
    filler signatures, are not stored, and cannot match.
    """
    bits, _ = fp_mod.binarize_coeffs(coeffs, fcfg, (med, mad))
    sigs = lsh_mod.signatures(bits, mappings, lcfg, valid=valid)
    ids = base_id + jnp.arange(sigs.shape[0], dtype=jnp.int32)
    state = index_mod.insert(state, sigs, ids, lcfg, valid=valid)
    pairs = index_mod.query(state, sigs, ids, lcfg)
    return state, pairs


@dataclasses.dataclass
class StreamStats:
    chunks: int = 0
    blocks: int = 0
    samples: int = 0
    fingerprints: int = 0
    pairs: int = 0
    chunk_wall_s: list = dataclasses.field(default_factory=list)

    def summary(self) -> dict:
        wall = np.asarray(self.chunk_wall_s or [0.0])
        total = float(wall.sum())
        return {
            "chunks": self.chunks,
            "blocks": self.blocks,
            "samples": self.samples,
            "fingerprints": self.fingerprints,
            "pairs": self.pairs,
            "wall_s": round(total, 4),
            "chunk_ms_p50": round(float(np.percentile(wall, 50)) * 1e3, 3),
            "chunk_ms_p95": round(float(np.percentile(wall, 95)) * 1e3, 3),
            "chunks_per_s": round(self.chunks / max(total, 1e-9), 2),
            "samples_per_s": round(self.samples / max(total, 1e-9), 1),
        }


class StationStream:
    """Incremental detection state for a single station."""

    def __init__(self, cfg: DetectConfig, scfg: StreamConfig,
                 med_mad: tuple[np.ndarray, np.ndarray] | None = None):
        self.cfg = cfg
        self.scfg = scfg
        fcfg, lcfg = cfg.fingerprint, cfg.lsh
        self.ring = WaveformRing(fcfg, scfg.block_fingerprints)
        self.mad = StreamingMAD(scfg.reservoir_rows, fcfg.n_coeff,
                                seed=scfg.seed)
        self.state = index_mod.init_index(lcfg, scfg.index)
        self.mappings = lsh_mod.hash_mappings(fcfg.fp_dim, lcfg)
        self.med_mad = None
        if med_mad is not None:
            self.med_mad = (jnp.asarray(med_mad[0]), jnp.asarray(med_mad[1]))
        self.pending: list[tuple[int, jax.Array]] = []  # pre-freeze blocks
        self.triplets: list[np.ndarray] = []            # (m, 3) idx1,idx2,sim
        self.stats = StreamStats()

    @property
    def stats_frozen(self) -> bool:
        return self.med_mad is not None

    def push(self, chunk: np.ndarray) -> int:
        """Ingest one chunk; returns pairs emitted by its ready blocks."""
        t0 = time.perf_counter()
        emitted = 0
        for base_id, block in self.ring.push(chunk):
            coeffs = block_coeffs(jnp.asarray(block), self.cfg.fingerprint)
            if not self.stats_frozen:
                self.mad.update(np.asarray(coeffs))
                self.pending.append((base_id, coeffs))
                if len(self.pending) >= self.scfg.stats_warmup_blocks:
                    self._freeze_stats()
                    emitted += self._drain_pending()
            else:
                emitted += self._process(base_id, coeffs)
        self.stats.chunks += 1
        self.stats.samples += int(np.asarray(chunk).size)
        self.stats.chunk_wall_s.append(time.perf_counter() - t0)
        return emitted

    def _freeze_stats(self) -> None:
        med, mad = self.mad.stats()
        self.med_mad = (jnp.asarray(med), jnp.asarray(mad))

    def _drain_pending(self) -> int:
        emitted = 0
        for base_id, coeffs in self.pending:
            emitted += self._process(base_id, coeffs)
        self.pending = []
        return emitted

    def _process(self, base_id: int, coeffs: jax.Array,
                 valid: np.ndarray | None = None) -> int:
        med, mad = self.med_mad
        n = int(coeffs.shape[0])
        vmask = (np.ones(n, bool) if valid is None
                 else np.asarray(valid, bool))
        self.state, pairs = stream_step(
            self.state, coeffs, med, mad, self.mappings,
            jnp.int32(base_id), jnp.asarray(vmask),
            self.cfg.fingerprint, self.cfg.lsh)
        pv = np.asarray(pairs.valid)
        m = int(pv.sum())
        if m:
            self.triplets.append(np.stack([
                np.asarray(pairs.idx1)[pv],
                np.asarray(pairs.idx2)[pv],
                np.asarray(pairs.sim)[pv]], axis=1).astype(np.int64))
        self.stats.blocks += 1
        self.stats.fingerprints += int(vmask.sum())
        self.stats.pairs += m
        return m

    def flush(self) -> int:
        """Process the buffered tail: freeze stats if still warming up,
        drain pending blocks, and run the partial last block (masked)."""
        emitted = 0
        part = self.ring.flush_partial()
        part_coeffs = None
        if part is not None:
            base_id, block, n_valid = part
            part_coeffs = block_coeffs(jnp.asarray(block),
                                       self.cfg.fingerprint)
            if not self.stats_frozen:
                self.mad.update(np.asarray(part_coeffs)[:n_valid])
        if not self.stats_frozen:
            if self.mad.filled < 2:
                return 0  # not enough signal ever arrived
            self._freeze_stats()
            emitted += self._drain_pending()
        if part is not None:
            base_id, block, n_valid = part
            vmask = np.arange(part_coeffs.shape[0]) < n_valid
            emitted += self._process(base_id, part_coeffs, valid=vmask)
        return emitted

    def accumulated_pairs(self, pad_to: int = 1024) -> Pairs:
        """All emitted triplets as a masked fixed-size ``Pairs``."""
        tri = (np.concatenate(self.triplets, axis=0) if self.triplets
               else np.zeros((0, 3), np.int64))
        m = tri.shape[0]
        size = max(pad_to, -(-max(m, 1) // pad_to) * pad_to)
        idx1 = np.full(size, INVALID, np.int32)
        idx2 = np.full(size, INVALID, np.int32)
        sim = np.zeros(size, np.int32)
        val = np.zeros(size, bool)
        idx1[:m] = tri[:, 0]
        idx2[:m] = tri[:, 1]
        sim[:m] = tri[:, 2]
        val[:m] = True
        return Pairs(idx1=jnp.asarray(idx1), idx2=jnp.asarray(idx2),
                     sim=jnp.asarray(sim), valid=jnp.asarray(val))

    def finalize(self) -> tuple[Events, Pairs, dict]:
        """Occurrence filter + channel merge + diagonal clustering."""
        self.flush()
        lcfg, acfg = self.cfg.lsh, self.cfg.align
        pairs = self.accumulated_pairs()
        n_fp = self.ring.next_fp
        fstats: dict = {"fingerprints": n_fp}
        if lcfg.occurrence_frac > 0 and n_fp > 0:
            pairs, excluded = lsh_mod.occurrence_filter(
                pairs, n_fp, lcfg.occurrence_frac)
            fstats["excluded_fingerprints"] = int(excluded.sum())
        merged = align_mod.merge_channels(
            [(pairs.dt, pairs.idx1, pairs.sim, pairs.valid)],
            acfg.channel_threshold)
        events = align_mod.cluster_station(merged, acfg)
        fstats["pairs"] = int(pairs.count())
        fstats["events"] = int(events.count())
        return events, pairs, fstats


class StreamingDetector:
    """Multi-station streaming FAST: push chunks, read detections.

    ``push`` accepts (n_stations, chunk_len) or a 1-D chunk for a single
    station; chunk lengths may vary call to call. ``finalize`` runs the
    per-station alignment and (when n_stations ≥ 2) the network
    association, mirroring ``detect_events``.
    """

    def __init__(self, cfg: DetectConfig, scfg: StreamConfig | None = None,
                 n_stations: int = 1,
                 med_mad: tuple[np.ndarray, np.ndarray] | None = None):
        self.cfg = cfg
        self.scfg = scfg or StreamConfig()
        self.stations = [StationStream(cfg, self.scfg, med_mad=med_mad)
                         for _ in range(n_stations)]

    def push(self, chunk: np.ndarray) -> int:
        chunk = np.asarray(chunk, np.float32)
        if chunk.ndim == 1:
            chunk = chunk[None, :]
        assert chunk.shape[0] == len(self.stations), \
            (chunk.shape, len(self.stations))
        return sum(st.push(chunk[i]) for i, st in enumerate(self.stations))

    def finalize(self) -> tuple[dict | None, list[Events], dict]:
        station_events, stats = [], {}
        for i, st in enumerate(self.stations):
            events, _, fstats = st.finalize()
            station_events.append(events)
            for k, v in fstats.items():
                stats[f"station{i}_{k}"] = v
        detections = None
        if len(self.stations) >= 2:
            detections = align_mod.associate_network(
                station_events, self.cfg.align, len(self.stations))
            stats["detections"] = int(detections["valid"].sum())
        stats["ingest"] = [st.stats.summary() for st in self.stations]
        return detections, station_events, stats
