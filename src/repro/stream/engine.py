"""Streaming detector: ring → fingerprints → index → pairs → events.

``StationStream`` owns one station's ingestion state: a ``WaveformRing``
(chunk framing + halo), a ``StreamingMAD`` (running §5.2 statistics), and
the device-resident detection state. Each ready block runs one jitted
fixed-shape step — fingerprint, sign, expire, insert, query — and the
emitted pairs either accumulate host-side (parity mode) or flow through a
``RollingPairFilter`` (bounded mode). ``StreamingDetector`` composes
stations and finishes with the *same* alignment stack as the offline path
(occurrence filter → channel merge → ``cluster_station`` → network
association), so a streamed trace yields the same detections as a batch
re-run, at O(chunk) cost per arrival instead of O(history).

Hot path anatomy (the one-dispatch invariant, ISSUE 3): with
``StreamConfig.fused`` (the default) the steady-state per-block work is a
**single** ``jax.jit`` dispatch — ``fused.step_advance`` — whose input is
only the block's *new* samples and whose entire state (index tables, ring
halo, MAD statistics) is a donated ``FusedState`` pytree reused in place
chunk after chunk. Multi-station detectors additionally run **pooled**:
all S stations' states are stacked on a leading axis and stepped through
one vmapped executable (``fused.pool_step_advance``), so S stations cost
one dispatch, not S. See ``stream/fused.py`` for the full anatomy and
``tests/test_stream.py`` for the parity / retracing / donation guards
that pin it. ``fused=False`` keeps the PR-1/2 multi-call chain
(``block_coeffs`` + ``stream_step``) as the bit-exact parity reference.

Two memory regimes, selected by ``StreamConfig``:

* **parity mode** (defaults): every emitted triplet is kept until
  ``finalize`` runs the offline occurrence filter + clustering over the
  full accumulation — exact offline semantics, O(stream) host state.
* **bounded mode** (``window_fingerprints`` + ``filter_window_fingerprints``
  > 0): the jitted step expires index entries older than the sliding
  window, and triplets are retired window-by-window through the rolling
  occurrence filter into compact event rows — O(window) host state for an
  unbounded stream (the paper's §5.3/§6.5 partition-bounded post-processing
  made continuous). Clusters split at a filter-window boundary are
  re-merged by ``merge_boundary_rows`` before any consumer sees them.
  With ≥2 stations, ``poll_detections`` additionally associates
  closed-window events across stations after every push, so network
  detections surface near-real-time instead of only at finalize.

``snapshot``/``restore`` checkpoint the whole detector (index pytree, ring,
reservoir, pending blocks, rolling-filter state) through
``train/checkpoint.py``: a killed service restored from its last snapshot
reproduces the uninterrupted run's detections exactly.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import hashlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import dist
from repro.core import align as align_mod
from repro.core import fingerprint as fp_mod
from repro.core import locate as locate_mod
from repro.core import lsh as lsh_mod
from repro.core.align import AlignConfig, Events
from repro.core.detect import DetectConfig
from repro.core.fingerprint import FingerprintConfig
from repro.core.lsh import INVALID, LSHConfig, Pairs
from repro.obsv.metrics import merge_counts
from repro.stream import fused as fused_mod
from repro.stream import index as index_mod
from repro.stream import telemetry as tele_mod
from repro.stream.index import IndexState
from repro.stream.ingest import StreamConfig, StreamingMAD, WaveformRing
from repro.stream.telemetry import StreamTelemetry
from repro.train import checkpoint as ckpt_mod


@functools.partial(jax.jit, static_argnames=("fcfg",))
def block_coeffs(block: jax.Array, fcfg: FingerprintConfig) -> jax.Array:
    """(block_samples,) → (block_fp, n_coeff) Haar coefficients."""
    return fp_mod.coeffs_from_waveform(block, fcfg)


@functools.partial(jax.jit, static_argnames=("fcfg",))
def pool_block_coeffs(blocks: jax.Array,
                      fcfg: FingerprintConfig) -> jax.Array:
    """(S, block_samples) → (S, block_fp, n_coeff) coefficients (one
    dispatch for the whole station pool's warmup)."""
    return jax.vmap(lambda b: fp_mod.coeffs_from_waveform(b, fcfg))(blocks)


@functools.partial(jax.jit, static_argnames=("fcfg", "lcfg", "window",
                                             "saturation", "dup_tables",
                                             "occ_limit", "counters",
                                             "max_pairs", "verify",
                                             "min_jac"),
                   donate_argnums=(0,))
def stream_step(state: IndexState, coeffs: jax.Array, med: jax.Array,
                mad: jax.Array, mappings: jax.Array, base_id: jax.Array,
                valid: jax.Array, fcfg: FingerprintConfig, lcfg: LSHConfig,
                window: int = 0, saturation: int = 0, dup_tables: int = 0,
                occ_limit: int = 0, counters: int = 0, max_pairs: int = 0,
                verify: int = 0, min_jac: float = 0.0
                ) -> tuple[IndexState, Pairs, jax.Array]:
    """One fixed-shape streaming step: binarize → sign → expire → guards →
    insert → query. (The *unfused* half of the PR-1/2 chain — kept as the
    parity reference and benchmark baseline for the fused step.)

    Same-shape blocks reuse one executable (base_id and the valid mask are
    traced, configs, the window length and the quality knobs are static);
    insert-then-query with the id-ordered emission rule yields each
    (earlier, later) pair exactly once per colliding table. Invalid rows
    (zero-padded flush tails, gap-masked fingerprints) get unique filler
    signatures, are not stored, and cannot match.

    ``window`` > 0 expires index entries older than the newest id in this
    block minus the window *before* inserting it, so every emitted pair
    satisfies idx2 - idx1 < window — the sliding detection window. The
    expire/guard/insert/query tail is ``index.guarded_step``, shared with
    the fused path, so the two hot paths stay bit-identical with the
    quality guards on or off.
    """
    bits, packed = fp_mod.binarize_coeffs(coeffs, fcfg, (med, mad))
    sigs, buckets = lsh_mod.signatures_and_buckets(
        bits, mappings, lcfg, state.shape[1], valid=valid)
    ids = base_id + jnp.arange(sigs.shape[0], dtype=jnp.int32)
    return index_mod.guarded_step(state, sigs, buckets, ids, valid, lcfg,
                                  window, saturation=saturation,
                                  dup_tables=dup_tables,
                                  occ_limit=occ_limit, counters=counters,
                                  packed=packed if verify > 0 else None,
                                  max_pairs=max_pairs, verify=verify,
                                  min_jac=min_jac)


def pairs_from_triplets(tri: np.ndarray, pad_to: int = 1024) -> Pairs:
    """(m, 3) host triplets (idx1, idx2, sim) → masked fixed-size ``Pairs``.

    Padded to a multiple of ``pad_to`` so downstream jitted consumers see
    few distinct shapes.
    """
    tri = np.asarray(tri).reshape(-1, 3)
    m = tri.shape[0]
    size = max(pad_to, -(-max(m, 1) // pad_to) * pad_to)
    idx1 = np.full(size, INVALID, np.int32)
    idx2 = np.full(size, INVALID, np.int32)
    sim = np.zeros(size, np.int32)
    val = np.zeros(size, bool)
    idx1[:m] = tri[:, 0]
    idx2[:m] = tri[:, 1]
    sim[:m] = tri[:, 2]
    val[:m] = True
    return Pairs(idx1=jnp.asarray(idx1), idx2=jnp.asarray(idx2),
                 sim=jnp.asarray(sim), valid=jnp.asarray(val))


# alert row layout: (dt, onset, n_stations, score, upgrade, x_mkm, y_mkm,
# mag_milli) — locations in milli-km (LOC_NONE without a locate tier),
# magnitudes in milli-magnitudes (MAG_NONE when no amplitude is in hand),
# upgrade=1 on a re-emission whose station multiplicity grew
ALERT_COLS = 8


def events_to_rows(events: Events) -> np.ndarray:
    """Valid entries of an ``Events`` pytree → compact (k, 5) int64 rows
    (dt, onset, extent, size, score)."""
    v = np.asarray(events.valid)
    return np.stack(
        [np.asarray(events.dt)[v], np.asarray(events.onset)[v],
         np.asarray(events.extent)[v], np.asarray(events.size)[v],
         np.asarray(events.score)[v]], axis=1).astype(np.int64)


def events_from_rows(rows: np.ndarray, pad_to: int = 256) -> Events:
    """(k, 5) rows → masked ``Events`` padded to a multiple of ``pad_to``."""
    rows = np.asarray(rows, np.int64).reshape(-1, 5)
    k = rows.shape[0]
    size = max(pad_to, -(-max(k, 1) // pad_to) * pad_to)
    full = np.zeros((size, 5), np.int64)
    full[:k] = rows
    val = np.arange(size) < k
    fill = np.where(val, 0, INVALID)
    return Events(
        dt=jnp.asarray((full[:, 0] + fill).astype(np.int32)),
        onset=jnp.asarray((full[:, 1] + fill).astype(np.int32)),
        extent=jnp.asarray(full[:, 2].astype(np.int32)),
        size=jnp.asarray(full[:, 3].astype(np.int32)),
        score=jnp.asarray(full[:, 4].astype(np.int32)),
        valid=jnp.asarray(val))


def merge_boundary_rows(rows: np.ndarray, acfg: AlignConfig) -> np.ndarray:
    """Re-merge event rows split at rolling-filter window boundaries.

    Bounded-mode clustering closes per filter window, so a diagonal
    cluster straddling a boundary surfaces as two rows: (nearly) the same
    dt, abutting idx ranges. This pass re-joins rows whose dt differ by at
    most ``dt_merge_tol`` and whose [onset, onset + extent] spans are
    within ``gap`` of each other — the same criteria ``cluster_station``
    uses for its in-window merge, applied across windows. Host-side and
    O(k log k) in the (small) number of event rows; runs before any
    consumer (association feed, finalize) sees the rows.
    """
    rows = np.asarray(rows, np.int64).reshape(-1, 5)
    k = rows.shape[0]
    if k <= 1:
        return rows
    order = np.lexsort((rows[:, 0], rows[:, 1]))  # by (onset, dt)
    rows = rows[order]
    dt, onset, ext = rows[:, 0], rows[:, 1], rows[:, 2]
    end = onset + ext
    # union-find over pairwise near-edges between the ORIGINAL rows: the
    # merge criteria are evaluated on unmerged rows only (no mid-pass
    # mutation), so the result is independent of encounter order, and a
    # chain of ≥3 straddling rows collapses into one component instead of
    # first-match-only partial merges.
    parent = np.arange(k)

    def find(i: int) -> int:
        while parent[i] != i:
            parent[i] = parent[parent[i]]   # path halving
            i = parent[i]
        return i

    for i in range(k):
        for j in range(i + 1, k):
            apart = int(onset[j]) - int(end[i])
            if apart > acfg.gap:
                break            # onsets monotone: no later j can be near
            if abs(int(dt[i]) - int(dt[j])) <= acfg.dt_merge_tol:
                ri, rj = find(i), find(j)
                if ri != rj:
                    parent[max(ri, rj)] = min(ri, rj)
    roots = np.fromiter((find(i) for i in range(k)), np.int64, k)
    out: list[np.ndarray] = []
    for r in np.unique(roots):               # root order == onset order
        m = roots == r
        # representative dt: the highest-score member's ORIGINAL dt
        # (ties → earliest in the onset sort), matching the in-window
        # merge's strongest-diagonal convention
        rep = np.nonzero(m)[0][np.argmax(rows[m, 4])]
        out.append(np.array([dt[rep], onset[m].min(),
                             end[m].max() - onset[m].min(),
                             rows[m, 3].sum(), rows[m, 4].sum()], np.int64))
    return np.stack(out, axis=0)


def host_occurrence_filter(pairs: Pairs, n_fp: int, lcfg: LSHConfig, *,
                           base: int = 0, limit: int | None = None
                           ) -> tuple[Pairs, jax.Array]:
    """The host-side §6.5 occurrence filter over an accumulated pair set.

    The one shared invocation behind every host-side call site — the
    parity-mode ``finalize``, the rolling per-window filter, and the
    batch replay driver (``core.detect.detect_events``) — kept as the
    bit-exact reference/fallback for the in-dispatch occurrence limiter
    (``index.occurrence_limit_pairs``). ``base`` rebases ids into a
    static [0, n_fp) span first (the rolling filter's window-local id
    space) and restores the original ids on the way out; ``limit``
    overrides the ``frac * n_fp`` occurrence cap when the partition whose
    fraction is meant differs from the id span. Returns
    (filtered pairs, excluded-fingerprint mask over the rebased span).
    """
    v = pairs.valid
    local = pairs if base == 0 else Pairs(
        idx1=jnp.where(v, pairs.idx1 - base, INVALID),
        idx2=jnp.where(v, pairs.idx2 - base, INVALID),
        sim=pairs.sim, valid=v)
    filt, excluded = lsh_mod.occurrence_filter(
        local, n_fp, lcfg.occurrence_frac, limit=limit)
    if base == 0:
        return filt, excluded
    keep = filt.valid
    return Pairs(idx1=jnp.where(keep, pairs.idx1, INVALID),
                 idx2=jnp.where(keep, pairs.idx2, INVALID),
                 sim=jnp.where(keep, pairs.sim, 0),
                 valid=keep), excluded


class RollingPairFilter:
    """Rolling per-window §6.5 occurrence filter + clustering.

    Every emitted pair is assigned to the window of its *later* member (the
    query id that emitted it). Once the processed-id frontier passes a
    window's end, no further pair can land in it, so the window closes:
    the occurrence filter runs over its pairs with ids rebased into the
    static [w_start - lookback, w_start + window) span (the sliding index
    window guarantees partners reach back at most ``lookback``), survivors
    are channel-merged and diagonal-clustered exactly like finalize, and
    only the resulting compact event rows are retained. Buffered host pair
    state is therefore O(window) for an unbounded stream — the streaming
    analogue of the paper's partition-bounded post-processing. Rows handed
    out (``rows_tail``/``all_rows``) pass the cross-window
    ``merge_boundary_rows`` pass first, so clusters split at a window
    close re-merge before association.
    """

    def __init__(self, cfg: DetectConfig, window: int, lookback: int,
                 pad_to: int = 1024):
        if window <= 0 or lookback <= 0:
            raise ValueError(f"need positive filter window and lookback, "
                             f"got {window}, {lookback}")
        self.cfg = cfg
        self.window = int(window)
        self.lookback = int(lookback)
        self.pad_to = pad_to
        self.w_start = 0
        self.buf: list[np.ndarray] = []     # open-window (m, 3) triplets
        self.buf_rows = 0
        self.peak_rows = 0
        self.event_rows: list[np.ndarray] = []  # closed (k, 5) rows, active
        self.archive_rows: list[np.ndarray] = []  # retired from association
        self.windows_closed = 0
        self.pairs_seen = 0
        self.pairs_kept = 0

    def add(self, tri: np.ndarray) -> None:
        tri = np.asarray(tri).reshape(-1, 3)
        if tri.shape[0]:
            self.buf.append(tri)
            self.buf_rows += tri.shape[0]
            self.peak_rows = max(self.peak_rows, self.buf_rows)
            self.pairs_seen += tri.shape[0]

    def advance(self, frontier: int) -> int:
        """Close every window whose end the processed frontier has passed."""
        closed = 0
        while frontier >= self.w_start + self.window:
            self._close(self.w_start + self.window)
            closed += 1
        return closed

    def close_all(self, frontier: int) -> None:
        """Flush the open tail window (finalize boundary)."""
        self.advance(frontier)
        if self.buf_rows:
            self._close(self.w_start + self.window)

    def rows_tail(self, min_onset: int) -> np.ndarray:
        """Active event rows reaching ``min_onset`` or later (association
        feed), boundary-merged.

        The floor is applied to the *end* of each merged span
        (onset + extent), not the onset: a fresh boundary row merged into
        an older cluster inherits the older onset, and filtering on onset
        would drop the merged row — and with it the fresh contribution —
        from this poll's association.
        """
        if not self.event_rows:
            return np.zeros((0, 5), np.int64)
        rows = merge_boundary_rows(np.concatenate(self.event_rows, axis=0),
                                   self.cfg.align)
        return rows[rows[:, 1] + rows[:, 2] >= min_onset]

    def retire_below(self, min_onset: int) -> None:
        """Move rows the association floor has passed into the archive.

        Retired rows can never alert again (``rows_tail`` already excluded
        them), so keeping them out of the active list makes the per-push
        association scan O(active window), not O(stream). They remain part
        of ``all_rows`` for the authoritative finalize.
        """
        if not self.event_rows:
            return
        rows = np.concatenate(self.event_rows, axis=0)
        old = rows[:, 1] < min_onset
        if not old.any():
            return
        self.archive_rows.append(rows[old])
        keep = rows[~old]
        self.event_rows = [keep] if keep.shape[0] else []

    def all_rows(self) -> np.ndarray:
        rows = self.archive_rows + self.event_rows
        if not rows:
            return np.zeros((0, 5), np.int64)
        return merge_boundary_rows(np.concatenate(rows, axis=0),
                                   self.cfg.align)

    def _close(self, w_end: int) -> None:
        tri = (np.concatenate(self.buf, axis=0) if self.buf
               else np.zeros((0, 3), np.int64))
        in_w = tri[:, 1] < w_end
        cur, rest = tri[in_w], tri[~in_w]
        self.buf = [rest] if rest.shape[0] else []
        self.buf_rows = int(rest.shape[0])
        if cur.shape[0]:
            rows = self._filter_cluster(cur)
            if rows.shape[0]:
                self.event_rows.append(rows)
        self.w_start = w_end
        self.windows_closed += 1

    def _filter_cluster(self, tri: np.ndarray) -> np.ndarray:
        """One window's triplets → occurrence-filtered clustered rows."""
        lcfg, acfg = self.cfg.lsh, self.cfg.align
        pairs = pairs_from_triplets(tri, self.pad_to)
        if lcfg.occurrence_frac > 0:
            pairs, _ = host_occurrence_filter(
                pairs, self.lookback + self.window, lcfg,
                base=self.w_start - self.lookback,
                limit=max(1, int(lcfg.occurrence_frac * self.window)))
        self.pairs_kept += int(pairs.count())
        merged = align_mod.merge_channels(
            [(pairs.dt, pairs.idx1, pairs.sim, pairs.valid)],
            acfg.channel_threshold)
        events = align_mod.cluster_station(merged, acfg)
        return events_to_rows(events)

    def snapshot(self) -> tuple[dict, dict]:
        buf = (np.concatenate(self.buf, axis=0).astype(np.int64)
               if self.buf else np.zeros((0, 3), np.int64))
        rows = self.archive_rows + self.event_rows
        raw = (np.concatenate(rows, axis=0) if rows
               else np.zeros((0, 5), np.int64))
        return ({"buf": buf, "events": raw},
                {"w_start": self.w_start, "windows_closed":
                 self.windows_closed, "pairs_seen": self.pairs_seen,
                 "pairs_kept": self.pairs_kept, "peak_rows": self.peak_rows})

    def restore(self, arrays: dict, scalars: dict) -> None:
        buf = np.asarray(arrays["buf"], np.int64).reshape(-1, 3)
        self.buf = [buf] if buf.shape[0] else []
        self.buf_rows = int(buf.shape[0])
        rows = np.asarray(arrays["events"], np.int64).reshape(-1, 5)
        self.archive_rows = []
        self.event_rows = [rows] if rows.shape[0] else []
        self.w_start = int(scalars["w_start"])
        self.windows_closed = int(scalars["windows_closed"])
        self.pairs_seen = int(scalars["pairs_seen"])
        self.pairs_kept = int(scalars["pairs_kept"])
        self.peak_rows = int(scalars["peak_rows"])


# per-chunk wall samples retained for the percentile view; older samples
# fold into wall_total_s, so host memory is O(1) on unbounded streams
# (the pre-ISSUE-6 list grew with the stream)
WALL_WINDOW = 1024


@dataclasses.dataclass
class StreamStats:
    chunks: int = 0
    blocks: int = 0
    samples: int = 0
    fingerprints: int = 0
    pairs: int = 0
    wall_total_s: float = 0.0
    chunk_wall_s: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=WALL_WINDOW))

    def record_wall(self, dt: float) -> None:
        self.wall_total_s += dt
        self.chunk_wall_s.append(dt)

    def summary(self) -> dict:
        wall = np.asarray(self.chunk_wall_s or [0.0])
        total = float(self.wall_total_s)
        return {
            "chunks": self.chunks,
            "blocks": self.blocks,
            "samples": self.samples,
            "fingerprints": self.fingerprints,
            "pairs": self.pairs,
            "wall_s": round(total, 4),
            # percentiles over the rolling window (recent behavior)
            "chunk_ms_p50": round(float(np.percentile(wall, 50)) * 1e3, 3),
            "chunk_ms_p95": round(float(np.percentile(wall, 95)) * 1e3, 3),
            "chunks_per_s": round(self.chunks / max(total, 1e-9), 2),
            "samples_per_s": round(self.samples / max(total, 1e-9), 1),
        }


class StationStream:
    """Incremental detection state for a single station.

    ``external=True`` (set by a pooled ``StreamingDetector``) keeps only
    host-side state here — ring framing, reservoir, rolling filter,
    stats — while the owner steps the device state through the vmapped
    station pool and feeds this station's slice back via ``_consume``.
    """

    def __init__(self, cfg: DetectConfig, scfg: StreamConfig,
                 med_mad: tuple[np.ndarray, np.ndarray] | None = None,
                 external: bool = False,
                 telemetry: StreamTelemetry | None = None):
        self.cfg = cfg
        self.scfg = scfg
        # detector-shared telemetry hub; a standalone station gets its own
        self.telemetry = telemetry or StreamTelemetry(1)
        fcfg, lcfg = cfg.fingerprint, cfg.lsh
        self.external = external
        self.fused = scfg.fused
        self.ring = WaveformRing(fcfg, scfg.block_fingerprints,
                                 reorder_horizon=scfg.reorder_horizon_samples,
                                 max_gap=scfg.max_gap_samples)
        self.mad = StreamingMAD(scfg.reservoir_rows, fcfg.n_coeff,
                                seed=scfg.seed)
        # pk_words resolved against this detector's fingerprint dim so
        # the verify ring rows match what the binarizer packs
        self.icfg = scfg.effective_index(fcfg.fp_dim)
        self._state: IndexState | None = index_mod.init_index(lcfg,
                                                              self.icfg)
        self.mappings = lsh_mod.hash_mappings(fcfg.fp_dim, lcfg)
        self.fstate: fused_mod.FusedState | None = None
        self._halo_ok = False
        self._med_mad: tuple[jax.Array, jax.Array] | None = None
        self._owner = None          # pooled detector backref (+ index)
        self._pool_idx = 0
        if med_mad is not None:
            self._set_frozen(med_mad[0], med_mad[1])
        # (base_id, block, coeffs-or-None, gap_mask-or-None)
        self.pending: list[tuple[int, np.ndarray, jax.Array | None,
                                 np.ndarray | None]] = []
        # in-dispatch guard counters (ring.quality covers the ingest
        # side). suppressed_fingerprints counts every fingerprint masked
        # out of the dispatch for ANY reason — gap overlap or duplicate
        # flag — so it is a superset of duplicate_fingerprints; the
        # gap-specific volume is ring.quality's gap/missing counters.
        self.qc = {"duplicate_fingerprints": 0, "saturated_lookups": 0,
                   "suppressed_fingerprints": 0, "limited_pairs": 0}
        # sample-exact repeated-segment detector state (window hashes of
        # the last dup_window_fingerprints fingerprints)
        self.dup_window = scfg.dup_window_fingerprints
        self._dup_hist: collections.deque[tuple[int, int]] = \
            collections.deque()
        self._dup_map: dict[int, int] = {}   # hash -> newest fp id
        self.triplets: list[np.ndarray] = []            # (m, 3) idx1,idx2,sim
        self.rolling = scfg.filter_window_fingerprints > 0
        self.filter = (RollingPairFilter(cfg, scfg.filter_window_fingerprints,
                                         scfg.window_fingerprints)
                       if self.rolling else None)
        self.processed_fp = 0       # ids fully through the jitted step
        self._tri_rows = 0
        self.peak_tri_rows = 0
        self.stats = StreamStats()

    # -- device-state views --------------------------------------------------

    @property
    def state(self) -> IndexState:
        """This station's index state, wherever it currently lives."""
        if self.fstate is not None:
            return self.fstate.index
        if self._owner is not None and self._owner.pstate is not None:
            return index_mod.slice_state(self._owner.pstate.index,
                                         self._pool_idx)
        assert self._state is not None, "station has no device state"
        return self._state

    @property
    def med_mad(self) -> tuple[jax.Array, jax.Array] | None:
        return self._med_mad

    @property
    def stats_frozen(self) -> bool:
        return self._med_mad is not None

    def _set_frozen(self, med, mad) -> None:
        self._med_mad = (jnp.asarray(med), jnp.asarray(mad))
        if self.fused and not self.external:
            self.fstate = fused_mod.init_state(
                self._state, self.cfg.fingerprint.halo_samples, med, mad)
            self._state = None      # the fused state owns the buffers now
            self._halo_ok = False

    def host_state_rows(self) -> int:
        """Candidate triplet rows currently buffered host-side — the
        quantity the rolling filter bounds."""
        return self.filter.buf_rows if self.rolling else self._tri_rows

    def quality_summary(self) -> dict:
        """Ingest reconciliation + in-dispatch guard counters (ISSUE 4),
        assembled on the shared telemetry aggregation path (key set is
        the stable contract; the pooled detector sums these per-station
        dicts through the same ``merge_counts``)."""
        return tele_mod.quality_view(self.ring.quality, self.qc)

    # -- ingestion -----------------------------------------------------------

    def push(self, chunk: np.ndarray, offset: int | None = None) -> int:
        """Ingest one chunk (optionally placed at an absolute sample
        ``offset`` — late/overlapping/gapped arrivals are reconciled by
        the ring); returns pairs emitted by its ready blocks."""
        assert not self.external, \
            "pooled stations are pushed through their StreamingDetector"
        self.telemetry.start()
        t0 = time.perf_counter()
        emitted = 0
        with self.telemetry.tracer.span("ingest", station=self._pool_idx):
            for base_id, block, mask in self.ring.push(chunk, offset):
                emitted += self._ingest_block(base_id, block, mask)
        n_samples = int(np.asarray(chunk).size)
        self.stats.chunks += 1
        self.stats.samples += n_samples
        wall = time.perf_counter() - t0
        self.stats.record_wall(wall)
        self.telemetry.record_chunk(self._pool_idx, wall, n_samples)
        return emitted

    def _flag_duplicates(self, base_id: int, block: np.ndarray,
                         mask: np.ndarray | None,
                         end_id: int | None = None) -> np.ndarray | None:
        """Sample-exact repeated-segment detector (ISSUE 4, host side).

        Hashes every (still-valid) fingerprint's raw sample window and
        flags exact repeats of any window seen within the last
        ``dup_window_fingerprints`` ids — telemetry-duplicated blocks and
        flat-lined channels produce *bit-exact* windows, repeating
        earthquakes never do (independent noise floors), so the guard
        cannot touch clean data. Flagged fingerprints merge into the
        block's validity mask: suppressed in-dispatch, never inserted.
        ``end_id`` is one past the last fingerprint this block consumes
        from the id space (a flush tail consumes fewer than a whole
        block; defaulting to a full block there would purge the hash
        history early and leak copies whose original sits at the
        horizon's edge).
        """
        if self.dup_window <= 0:
            return mask
        fcfg = self.cfg.fingerprint
        w, lag = fcfg.window_samples, fcfg.lag_samples
        n = self.scfg.block_fingerprints
        valid = (np.ones(n, bool) if mask is None
                 else np.asarray(mask, bool).copy())
        flagged = 0
        block = np.ascontiguousarray(block, np.float32)
        # fingerprint windows overlap by w - lag, so hashing each whole
        # window would re-hash every byte ~w/lag times. Instead each
        # lag-aligned stride is digested once and a fingerprint's hash
        # combines its k full-stride digests plus the sub-stride tail —
        # still exactly window-equality (up to hash collision), at ~1x
        # the input bytes.
        k, tail = w // lag, w % lag
        strides: list[bytes | None] = [None] * (n + k)

        def stride(s: int) -> bytes:
            if strides[s] is None:
                strides[s] = hashlib.blake2b(
                    block[s * lag: (s + 1) * lag].tobytes(),
                    digest_size=8).digest()
            return strides[s]

        for i in range(n):
            if not valid[i]:
                continue
            fid = base_id + i
            parts = b"".join(stride(i + j) for j in range(k))
            if tail:
                parts += block[(i + k) * lag: (i + k) * lag + tail].tobytes()
            h = int.from_bytes(
                hashlib.blake2b(parts, digest_size=8).digest(), "little")
            if h in self._dup_map:
                valid[i] = False
                flagged += 1
            else:
                self._dup_map[h] = fid
                self._dup_hist.append((fid, h))
        floor = (base_id + n if end_id is None else end_id) \
            - self.dup_window
        while self._dup_hist and self._dup_hist[0][0] < floor:
            old_id, old_h = self._dup_hist.popleft()
            if self._dup_map.get(old_h) == old_id:
                del self._dup_map[old_h]
        if flagged:
            self.qc["duplicate_fingerprints"] += flagged
            return valid
        return mask

    def _ingest_block(self, base_id: int, block: np.ndarray,
                      mask: np.ndarray | None = None) -> int:
        mask = self._flag_duplicates(base_id, block, mask)
        if not self.stats_frozen:
            coeffs = block_coeffs(jnp.asarray(block), self.cfg.fingerprint)
            rows = np.asarray(coeffs)
            # gap-masked fingerprints hold sentinel samples — keep their
            # rows out of the §5.2 statistics reservoir
            self.mad.update(rows if mask is None else rows[mask])
            # the fused drain recomputes coefficients inside its single
            # dispatch — retaining them here (O(warmup), O(trace) in the
            # deferred-freeze mode) would be dead weight; the unfused
            # drain replays the exact buffered coefficients
            self.pending.append((base_id, np.asarray(block, np.float32),
                                 None if self.fused else coeffs, mask))
            warm = self.scfg.stats_warmup_blocks
            if warm > 0 and len(self.pending) >= warm:
                self._freeze_stats()
                return self._drain_pending()
            return 0
        return self._process(base_id, block=block, valid=mask, primed=True)

    def _freeze_stats(self) -> None:
        med, mad = self.mad.stats()
        self._set_frozen(med, mad)

    def _drain_pending(self) -> int:
        emitted = 0
        for base_id, block, coeffs, mask in self.pending:
            emitted += self._process(base_id, block=block, coeffs=coeffs,
                                     valid=mask, primed=True)
        self.pending = []
        return emitted

    def _absorb_qc(self, qc: np.ndarray, n_masked: int) -> None:
        qc = np.asarray(qc).reshape(-1)
        self.qc["duplicate_fingerprints"] += int(qc[0])
        self.qc["saturated_lookups"] += int(qc[1])
        self.qc["limited_pairs"] += int(qc[2])
        # n_masked covers host-side suppression (gap overlap + sample-
        # exact dup flags); qc[0] adds the in-dispatch dup_sig_tables
        # suppressions so the superset invariant holds either way
        self.qc["suppressed_fingerprints"] += int(n_masked) + int(qc[0])
        # the telemetry tail of the vector (pairs emitted, device-masked
        # fingerprints, collision counts) mirrors into registry counters
        self.telemetry.record_step(self._pool_idx, qc)

    def _process(self, base_id: int, *, block: np.ndarray | None = None,
                 coeffs: jax.Array | None = None,
                 valid: np.ndarray | None = None,
                 primed: bool = False, n_adv: int | None = None) -> int:
        """One block through the device step (fused or legacy chain).

        ``valid`` masks fingerprints suppressed in-dispatch (gap overlap
        or a zero-padded flush tail). ``primed`` says the block is fully
        framed — its tail correctly primes the device halo even when some
        fingerprints are masked (gap blocks), unlike a padded tail.
        ``n_adv`` is the id-space advance (defaults to a whole block; a
        flush tail advances only by its consumed fingerprints).
        """
        fcfg, lcfg = self.cfg.fingerprint, self.cfg.lsh
        window = self.scfg.window_fingerprints
        sat = self.scfg.saturation_limit
        dup = self.scfg.dup_sig_tables
        occ = self.scfg.occ_limit
        ctr = 1 if self.scfg.telemetry else 0
        mp = self.scfg.max_pairs_per_block
        ver = self.scfg.verify_code
        mj = self.scfg.verify_min_jaccard
        n = self.scfg.block_fingerprints
        vmask = (np.ones(n, bool) if valid is None
                 else np.asarray(valid, bool))
        if n_adv is None:
            n_adv = n
        wd = self.telemetry.watchdog
        wd.step_start()
        with self.telemetry.tracer.span("fused_step",
                                        station=self._pool_idx):
            if self.fused:
                if valid is None and self._halo_ok:
                    adv = np.asarray(block, np.float32)[-self.ring.advance:]
                    self.fstate, pairs, qc = fused_mod.step_advance(
                        self.fstate, jnp.asarray(adv), self.mappings,
                        jnp.int32(base_id), fcfg, lcfg, window, sat, dup,
                        occ, ctr, mp, ver, mj)
                else:
                    self.fstate, pairs, qc = fused_mod.step_block(
                        self.fstate, jnp.asarray(block), self.mappings,
                        jnp.int32(base_id), jnp.asarray(vmask), fcfg, lcfg,
                        window, sat, dup, occ, ctr, mp, ver, mj)
                    # a zero-padded tail leaves the device halo dirty and
                    # the next block must re-seed through step_block; a
                    # fully framed (gap-masked) block primes it clean
                    self._halo_ok = valid is None or primed
            else:
                if coeffs is None:
                    coeffs = block_coeffs(jnp.asarray(block), fcfg)
                med, mad = self._med_mad
                self._state, pairs, qc = stream_step(
                    self._state, coeffs, med, mad, self.mappings,
                    jnp.int32(base_id), jnp.asarray(vmask), fcfg, lcfg,
                    window, sat, dup, occ, ctr, mp, ver, mj)
            # one device_get over the whole step output (ISSUE 8: a
            # single transfer+sync, not four) blocks on the dispatch, so
            # the watchdog step (and the fused-wall histogram) covers
            # device time incl. sync. With compaction on, the pulled
            # pair arrays are O(max_pairs), not O(t·N·cap).
            pairs_np, qc = jax.device_get(
                ((pairs.idx1, pairs.idx2, pairs.sim, pairs.valid), qc))
        self.telemetry.record_fused_wall(str(self._pool_idx), wd.step_end())
        self._absorb_qc(qc, n_adv - int(vmask[:n_adv].sum()))
        t_host = time.perf_counter()
        with self.telemetry.tracer.span("host_tail",
                                        station=self._pool_idx):
            m = self._consume(base_id, n_adv, int(vmask.sum()), pairs_np)
        self.telemetry.record_host_tail(self._pool_idx,
                                        time.perf_counter() - t_host)
        return m

    def _consume(self, base_id: int, n_adv: int, n_valid: int,
                 pairs_np: tuple[np.ndarray, ...]) -> int:
        """Host-side tail of a step: triplet accounting + rolling filter.

        Shared by the solo path and the pooled detector (which hands each
        station its slice of the vmapped step output). ``n_adv`` advances
        the processed-id frontier (full id-space coverage of the block,
        gaps included); ``n_valid`` counts the real fingerprints.
        """
        i1, i2, sim, pv = pairs_np
        m = int(pv.sum())
        self.processed_fp = base_id + n_adv
        if m:
            tri = np.stack([i1[pv], i2[pv], sim[pv]], axis=1).astype(np.int64)
            if self.rolling:
                self.filter.add(tri)
            else:
                self.triplets.append(tri)
                self._tri_rows += m
        if self.rolling:
            self.filter.advance(self.processed_fp)
            self.peak_tri_rows = max(self.peak_tri_rows,
                                     self.filter.peak_rows)
        else:
            self.peak_tri_rows = max(self.peak_tri_rows, self._tri_rows)
        self.stats.blocks += 1
        self.stats.fingerprints += n_valid
        self.stats.pairs += m
        return m

    def flush(self) -> int:
        """Process the buffered tail: freeze stats if still warming up,
        drain pending blocks, and run the partial last block (masked).

        With ``stats_warmup_blocks == 0`` this is where the freeze always
        happens: the reservoir has absorbed the whole stream, so the
        buffered warmup fingerprints are binarized with the matured
        statistics (the re-binarize-after-freeze hook).
        """
        if self.external:
            return 0                # the owning detector flushes the pool
        emitted = 0
        ready = 0
        for base_id, block, mask in self.ring.flush_ready():
            ready += self._ingest_block(base_id, block, mask)
        part = self.ring.flush_partial()
        part_coeffs = None
        if part is not None:
            base_id, block, mask = part
            mask = self._flag_duplicates(base_id, block, mask,
                                         end_id=self.ring.next_fp)
            part = (base_id, block, mask)
            if not self.stats_frozen or not self.fused:
                part_coeffs = block_coeffs(jnp.asarray(block),
                                           self.cfg.fingerprint)
            if not self.stats_frozen:
                self.mad.update(np.asarray(part_coeffs)[mask])
        if not self.stats_frozen:
            if self.mad.filled < 2:
                return ready  # not enough signal ever arrived
            self._freeze_stats()
            emitted += self._drain_pending()
        emitted += ready
        if part is not None:
            base_id, block, mask = part
            emitted += self._process(base_id, block=block,
                                     coeffs=part_coeffs, valid=mask,
                                     n_adv=self.ring.next_fp - base_id)
        return emitted

    def accumulated_pairs(self, pad_to: int = 1024) -> Pairs:
        """All emitted triplets as a masked fixed-size ``Pairs``."""
        tri = (np.concatenate(self.triplets, axis=0) if self.triplets
               else np.zeros((0, 3), np.int64))
        return pairs_from_triplets(tri, pad_to)

    def finalize(self) -> tuple[Events, Pairs, dict]:
        """Occurrence filter + channel merge + diagonal clustering.

        Parity mode runs the offline reduction over the full accumulated
        pair set. Bounded mode closes the open rolling window and returns
        the concatenation of per-window events (boundary-merged); raw
        pairs were already retired window-by-window, so the returned
        ``Pairs`` is empty.
        """
        self.flush()
        lcfg, acfg = self.cfg.lsh, self.cfg.align
        n_fp = self.ring.next_fp
        if self.rolling:
            self.filter.close_all(self.processed_fp)
            events = events_from_rows(self.filter.all_rows())
            fstats = {
                "fingerprints": n_fp,
                "pairs": self.filter.pairs_kept,
                "windows": self.filter.windows_closed,
                "events": int(events.count()),
                "peak_buffered_triplets": self.peak_tri_rows,
                "quality": self.quality_summary(),
            }
            return events, pairs_from_triplets(np.zeros((0, 3))), fstats
        pairs = self.accumulated_pairs()
        fstats = {"fingerprints": n_fp, "quality": self.quality_summary()}
        if lcfg.occurrence_frac > 0 and n_fp > 0:
            pairs, excluded = host_occurrence_filter(pairs, n_fp, lcfg)
            fstats["excluded_fingerprints"] = int(excluded.sum())
        merged = align_mod.merge_channels(
            [(pairs.dt, pairs.idx1, pairs.sim, pairs.valid)],
            acfg.channel_threshold)
        events = align_mod.cluster_station(merged, acfg)
        fstats["pairs"] = int(pairs.count())
        fstats["events"] = int(events.count())
        fstats["peak_buffered_triplets"] = self.peak_tri_rows
        return events, pairs, fstats

    # -- snapshot / restore -------------------------------------------------

    def snapshot_state(self) -> tuple[dict, dict]:
        """(flat arrays, json-able extra) capturing this station exactly."""
        state = self.state
        arrays = {
            "index/sig": np.asarray(jax.device_get(state.sig)),
            "index/ids": np.asarray(jax.device_get(state.ids)),
            "index/cursor": np.asarray(jax.device_get(state.cursor)),
            "index/inserted": np.asarray(jax.device_get(state.inserted)),
            "index/traffic": np.asarray(jax.device_get(state.traffic)),
            "index/occ": np.asarray(jax.device_get(state.occ)),
            "index/epoch": np.asarray(jax.device_get(state.epoch)),
            "index/pk": np.asarray(jax.device_get(state.pk)),
        }
        ring_a, ring_s = self.ring.snapshot()
        arrays["ring/buf"] = ring_a["buf"]
        arrays["ring/vbuf"] = ring_a["vbuf"]
        mad_a, mad_s = self.mad.snapshot()
        arrays["mad/rows"] = mad_a["rows"]
        arrays["stats/chunk_wall_s"] = np.asarray(self.stats.chunk_wall_s,
                                                  np.float64)
        extra = {
            "ring": ring_s, "mad": mad_s,
            "frozen": self.stats_frozen,
            "processed_fp": self.processed_fp,
            "peak_tri_rows": self.peak_tri_rows,
            "qc": dict(self.qc),
            "stats": {"chunks": self.stats.chunks,
                      "blocks": self.stats.blocks,
                      "samples": self.stats.samples,
                      "fingerprints": self.stats.fingerprints,
                      "pairs": self.stats.pairs,
                      "wall_total_s": self.stats.wall_total_s},
        }
        if self.stats_frozen:
            arrays["med"] = np.asarray(self._med_mad[0])
            arrays["mad_stat"] = np.asarray(self._med_mad[1])
        if self.dup_window > 0:
            arrays["dup/ids"] = np.asarray(
                [i for i, _ in self._dup_hist], np.int64)
            arrays["dup/hash"] = np.asarray(
                [h for _, h in self._dup_hist], np.uint64)
        if self.pending:
            n = self.scfg.block_fingerprints
            arrays["pending/base"] = np.asarray(
                [b for b, _, _, _ in self.pending], np.int64)
            arrays["pending/blocks"] = np.stack(
                [b for _, b, _, _ in self.pending]).astype(np.float32)
            # gap masks; an all-True row restores to None (clean block)
            arrays["pending/valid"] = np.stack(
                [np.ones(n, bool) if m is None else np.asarray(m, bool)
                 for _, _, _, m in self.pending])
            if not self.fused:      # unfused drains replay exact coeffs
                arrays["pending/coeffs"] = np.stack(
                    [np.asarray(c) for _, _, c, _ in self.pending]) \
                    .astype(np.float32)
        if self.rolling:
            f_a, f_s = self.filter.snapshot()
            arrays["filter/buf"] = f_a["buf"]
            arrays["filter/events"] = f_a["events"]
            extra["filter"] = f_s
        else:
            arrays["triplets"] = (
                np.concatenate(self.triplets, axis=0).astype(np.int64)
                if self.triplets else np.zeros((0, 3), np.int64))
        return arrays, extra

    def restore_state(self, arrays: dict, extra: dict) -> None:
        init = index_mod.init_index(self.cfg.lsh, self.icfg)
        restored = IndexState(
            sig=jnp.asarray(arrays["index/sig"], jnp.uint32),
            ids=jnp.asarray(arrays["index/ids"], jnp.int32),
            cursor=jnp.asarray(arrays["index/cursor"], jnp.int32),
            inserted=jnp.asarray(arrays["index/inserted"], jnp.int32),
            # pre-limiter snapshots lack the guard counters: the cursor
            # restores the lifetime traffic those snapshots ran under,
            # and the epoch is re-derived from the processed frontier —
            # an epoch of 0 would make the first windowed expire
            # right-shift the counter by the whole elapsed epoch span
            # and release every quarantined bucket at once
            traffic=jnp.asarray(arrays.get("index/traffic",
                                           arrays["index/cursor"]),
                                jnp.int32),
            occ=jnp.asarray(arrays["index/occ"], jnp.int32)
            if "index/occ" in arrays else init.occ,
            epoch=jnp.asarray(arrays["index/epoch"], jnp.int32)
            if "index/epoch" in arrays else jnp.asarray(
                max(0, int(extra["processed_fp"])
                    - self.scfg.window_fingerprints)
                // max(self.scfg.window_fingerprints, 1), jnp.int32),
            # pre-verify snapshots lack the packed-fingerprint ring; an
            # empty ring only costs already-inserted ids their exact
            # Jaccard (scored 0) until the window rolls over
            pk=jnp.asarray(arrays["index/pk"], jnp.uint32)
            if "index/pk" in arrays else init.pk)
        assert restored.shape == init.shape, (restored.shape, init.shape)
        assert restored.occ.shape == init.occ.shape, \
            (restored.occ.shape, init.occ.shape)
        assert restored.pk.shape == init.pk.shape, \
            (restored.pk.shape, init.pk.shape)
        self._state = restored
        self.fstate = None
        self._halo_ok = False
        ring_a = {"buf": arrays["ring/buf"]}
        if "ring/vbuf" in arrays:
            ring_a["vbuf"] = arrays["ring/vbuf"]
        self.ring.restore(ring_a, extra["ring"])
        self.mad.restore({"rows": arrays["mad/rows"]}, extra["mad"])
        self.qc.update(extra.get("qc", {}))
        self._dup_hist.clear()
        self._dup_map = {}
        if "dup/ids" in arrays:
            ids = np.asarray(arrays["dup/ids"], np.int64)
            hashes = np.asarray(arrays["dup/hash"], np.uint64)
            for i in range(ids.shape[0]):
                fid, h = int(ids[i]), int(hashes[i])
                self._dup_hist.append((fid, h))
                self._dup_map[h] = fid
        self._med_mad = None
        if extra["frozen"]:
            self._set_frozen(arrays["med"], arrays["mad_stat"])
        self.pending = []
        if "pending/base" in arrays:
            bases = np.asarray(arrays["pending/base"], np.int64)
            blocks = np.asarray(arrays["pending/blocks"], np.float32)
            coeffs = (np.asarray(arrays["pending/coeffs"], np.float32)
                      if "pending/coeffs" in arrays else None)
            masks = (np.asarray(arrays["pending/valid"], bool)
                     if "pending/valid" in arrays else None)

            def _mask(i):
                if masks is None or masks[i].all():
                    return None
                return masks[i]

            self.pending = [
                (int(bases[i]), blocks[i],
                 None if coeffs is None else jnp.asarray(coeffs[i]),
                 _mask(i))
                for i in range(bases.shape[0])]
        if self.rolling:
            self.filter.restore(
                {"buf": arrays["filter/buf"],
                 "events": arrays["filter/events"]}, extra["filter"])
            self.triplets = []
            self._tri_rows = 0
        else:
            tri = np.asarray(arrays["triplets"], np.int64).reshape(-1, 3)
            self.triplets = [tri] if tri.shape[0] else []
            self._tri_rows = int(tri.shape[0])
        self.processed_fp = int(extra["processed_fp"])
        self.peak_tri_rows = int(extra["peak_tri_rows"])
        s = extra["stats"]
        wall = np.asarray(arrays["stats/chunk_wall_s"], np.float64)
        self.stats = StreamStats(
            chunks=int(s["chunks"]), blocks=int(s["blocks"]),
            samples=int(s["samples"]),
            fingerprints=int(s["fingerprints"]), pairs=int(s["pairs"]),
            # pre-ISSUE-6 snapshots carry the full per-chunk list and no
            # running total: their window-truncated restore keeps the
            # exact total via the stored sum
            wall_total_s=float(s.get("wall_total_s", wall.sum())),
            chunk_wall_s=collections.deque(wall.tolist(),
                                           maxlen=WALL_WINDOW))


class StreamingDetector:
    """Multi-station streaming FAST: push chunks, read detections.

    ``push`` accepts (n_stations, chunk_len) or a 1-D chunk for a single
    station; chunk lengths may vary call to call. ``finalize`` runs the
    per-station alignment and (when n_stations ≥ 2) the network
    association, mirroring ``detect_events``. In bounded mode each push
    also polls the incremental association: newly final multi-station
    detections land in ``alerts`` as they close, not only at finalize.

    With ``StreamConfig.pooled`` (the default) and ≥2 stations, the
    per-station device states are stacked into one pool and every ready
    block steps all stations through a single vmapped fused dispatch —
    the per-station ``StationStream`` objects keep only host-side state
    (ring framing, reservoir, rolling filter, stats).
    """

    def __init__(self, cfg: DetectConfig, scfg: StreamConfig | None = None,
                 n_stations: int = 1,
                 med_mad: tuple[np.ndarray, np.ndarray] | None = None,
                 station_xy: np.ndarray | None = None):
        self.cfg = cfg
        self.scfg = scfg or StreamConfig()
        self.station_xy = (np.asarray(station_xy, np.float32)
                           if station_xy is not None else None)
        if self.station_xy is not None \
                and self.station_xy.shape != (n_stations, 2):
            raise ValueError(f"station_xy must be ({n_stations}, 2) km, "
                             f"got {self.station_xy.shape}")
        # location/magnitude tier: active when a LocateConfig and station
        # geometry are both in hand (and there is a network to associate)
        self.locating = (cfg.locate is not None
                         and self.station_xy is not None
                         and n_stations >= 2)
        self.pooled = (self.scfg.fused and self.scfg.pooled
                       and n_stations >= 2)
        # sharded station pool (ISSUE 10): the capability probe returns a
        # 1-axis ``stations`` mesh when >1 device is visible, else None —
        # the None keeps every pool dispatch on the single-device vmap
        # path. The pool is padded up to a multiple of the mesh width
        # with throwaway station rows (row-independent math; their output
        # is never read) so the leading axis always divides the mesh.
        self.mesh = (dist.station_mesh(n_stations)
                     if self.pooled and self.scfg.sharded else None)
        self.pool_pad = dist.padded_pool_width(n_stations,
                                               self.mesh) - n_stations
        self.telemetry = StreamTelemetry(n_stations)
        self.stations = [StationStream(cfg, self.scfg, med_mad=med_mad,
                                       external=self.pooled,
                                       telemetry=self.telemetry)
                         for _ in range(n_stations)]
        self.pstate: fused_mod.FusedState | None = None
        self._halo_ok = False
        self.mappings = self.stations[0].mappings
        for i, st in enumerate(self.stations):
            st._owner, st._pool_idx = self, i
        if self.pooled and med_mad is not None:
            self._build_pool()
        self.rolling = self.scfg.filter_window_fingerprints > 0
        self.alerts: list[np.ndarray] = []   # (k, ALERT_COLS) rows
        # alerted keys + the best station multiplicity each has alerted
        # at: (dt, onset, best_n_stations). A group whose multiplicity
        # later grows past its recorded best re-emits as an upgrade.
        self._emitted = np.zeros((0, 3), np.int64)
        self._assoc_lo = 0
        # bounded amplitude timeline (magnitude source): per station,
        # lag-bin → peak |sample| seen for that bin, max-merged across
        # (possibly late / duplicated) arrivals and pruned with the
        # association floor. Approximate by design — amplitudes are read
        # at fingerprint-lag resolution, which is what the relative-
        # magnitude ratio needs.
        self._amp: list[dict[int, float]] = [{} for _ in range(n_stations)]
        self._polled_windows = 0  # window closes seen by the last poll
        # monotonic corpus version: bumps whenever ingestion may have
        # changed the index pool, so a serving engine can gate its
        # pool_serving_state() refreshes on "did anything arrive?"
        self.serving_version = 0

    def push(self, chunk: np.ndarray, offset: int | None = None) -> int:
        """Ingest one network chunk; ``offset`` places it at an absolute
        sample offset on every station's timeline (late / duplicated /
        gapped telemetry is reconciled per station by the rings; chunks
        are network-aligned, so one offset serves all stations — a
        single-station outage is NaN samples inside the chunk)."""
        chunk = np.asarray(chunk, np.float32)
        if chunk.ndim == 1:
            chunk = chunk[None, :]
        assert chunk.shape[0] == len(self.stations), \
            (chunk.shape, len(self.stations))
        if self.locating:
            pos = (self.stations[0].ring.frontier if offset is None
                   else int(offset))
            for i in range(chunk.shape[0]):
                self._note_amps(i, pos, chunk[i])
        if self.pooled:
            emitted = self._pool_push(chunk, offset)
        else:
            emitted = sum(st.push(chunk[i], offset)
                          for i, st in enumerate(self.stations))
        if self.rolling and len(self.stations) >= 2:
            new = self.poll_detections()
            if new.shape[0]:
                self.alerts.append(new)
        self.serving_version += 1
        return emitted

    # -- pooled stepping ----------------------------------------------------

    def _build_pool(self) -> None:
        """Stack the stations' device state into one vmappable pool.

        With a mesh in hand the stacked pytree is padded to a multiple
        of the mesh width (throwaway station rows cloned from fresh
        index state + station 0's statistics) and every leaf is placed
        with ``NamedSharding(mesh, P('stations'))`` — per-shard
        ``device_put``, so the donated steady state never pays a cross-
        device reshard."""
        states = [st._state for st in self.stations]
        meds = [st._med_mad[0] for st in self.stations]
        mads = [st._med_mad[1] for st in self.stations]
        if self.pool_pad:
            states += [index_mod.init_index(self.cfg.lsh,
                                            self.stations[0].icfg)
                       for _ in range(self.pool_pad)]
            meds += [meds[0]] * self.pool_pad
            mads += [mads[0]] * self.pool_pad
        pstate = fused_mod.init_pool_state(
            states, self.cfg.fingerprint.halo_samples, meds, mads)
        if self.mesh is not None:
            pstate = jax.device_put(pstate,
                                    dist.pool_sharding(self.mesh))
            # replicate the hash mappings across the mesh once: passing
            # the device-0-committed copy would re-broadcast it on every
            # dispatch
            self._pool_mappings = jax.device_put(
                self.mappings, dist.replicated_sharding(self.mesh))
        else:
            self._pool_mappings = self.mappings
        self.pstate = pstate
        for st in self.stations:
            st._state = None        # the pool owns the buffers now
        self._halo_ok = False

    def _pad_rows(self, x: np.ndarray, fill=0) -> np.ndarray:
        """Append the pool's pad-station rows to a host-side (S, ...)
        input (zero samples / all-invalid masks — the pad rows' output
        is never read, this just keeps the shapes mesh-divisible)."""
        if not self.pool_pad:
            return x
        pad = np.full((self.pool_pad,) + x.shape[1:], fill, x.dtype)
        return np.concatenate([x, pad])

    def _pool_push(self, chunk: np.ndarray, offset: int | None = None
                   ) -> int:
        self.telemetry.start()
        t0 = time.perf_counter()
        per_st = [st.ring.push(chunk[i], offset)
                  for i, st in enumerate(self.stations)]
        emitted = 0
        with self.telemetry.tracer.span("ingest", station="pool"):
            for k in range(len(per_st[0])):   # rings advance in lockstep
                base_id = per_st[0][k][0]
                blocks = np.stack([per_st[i][k][1]
                                   for i in range(len(self.stations))])
                masks = [per_st[i][k][2]
                         for i in range(len(self.stations))]
                emitted += self._pool_ingest_block(base_id, blocks, masks)
        wall = time.perf_counter() - t0
        for i, st in enumerate(self.stations):
            st.stats.chunks += 1
            st.stats.samples += int(chunk[i].size)
            st.stats.record_wall(wall)  # stations share the dispatch
            self.telemetry.record_chunk(i, wall, int(chunk[i].size))
        return emitted

    def _pool_ingest_block(self, base_id: int, blocks: np.ndarray,
                           masks: list | None = None) -> int:
        if masks is None:
            masks = [None] * len(self.stations)
        masks = [st._flag_duplicates(base_id, blocks[i], masks[i])
                 for i, st in enumerate(self.stations)]
        if self.pstate is None:
            coeffs = np.asarray(pool_block_coeffs(jnp.asarray(blocks),
                                                  self.cfg.fingerprint))
            for i, st in enumerate(self.stations):
                st.mad.update(coeffs[i] if masks[i] is None
                              else coeffs[i][masks[i]])
                st.pending.append((base_id, blocks[i], None, masks[i]))
            warm = self.scfg.stats_warmup_blocks
            if warm > 0 and len(self.stations[0].pending) >= warm:
                self._freeze_pool()
                return self._drain_pool()
            return 0
        return self._pool_process(base_id, blocks, masks=masks)

    def _freeze_pool(self) -> None:
        for st in self.stations:
            if not st.stats_frozen:
                st._freeze_stats()  # external: records stats only
        self._build_pool()

    def _drain_pool(self) -> int:
        emitted = 0
        pend = [st.pending for st in self.stations]
        for k in range(len(pend[0])):
            base_id = pend[0][k][0]
            blocks = np.stack([pend[i][k][1]
                               for i in range(len(self.stations))])
            masks = [pend[i][k][3] for i in range(len(self.stations))]
            emitted += self._pool_process(base_id, blocks, masks=masks)
        for st in self.stations:
            st.pending = []
        return emitted

    def _pool_process(self, base_id: int, blocks: np.ndarray,
                      masks: list | None = None, primed: bool = True,
                      n_adv: int | None = None) -> int:
        """One lockstep block through the vmapped pool step.

        ``masks``: per-station gap masks (None entries = clean); a flush
        tail passes the shared tail mask per station with
        ``primed=False`` and the consumed id advance ``n_adv``.
        """
        fcfg, lcfg = self.cfg.fingerprint, self.cfg.lsh
        window = self.scfg.window_fingerprints
        sat = self.scfg.saturation_limit
        dup = self.scfg.dup_sig_tables
        occ = self.scfg.occ_limit
        ctr = 1 if self.scfg.telemetry else 0
        mp = self.scfg.max_pairs_per_block
        ver = self.scfg.verify_code
        mj = self.scfg.verify_min_jaccard
        n = self.scfg.block_fingerprints
        s = len(self.stations)
        clean = masks is None or all(m is None for m in masks)
        if n_adv is None:
            n_adv = n
        wd = self.telemetry.watchdog
        wd.step_start()
        # per-station host inputs go straight to their shard: under a
        # mesh, a plain jnp.asarray would land the whole array on device
        # 0 and pay a second device-0 → shards scatter inside dispatch
        put = (jnp.asarray if self.mesh is None else
               functools.partial(jax.device_put,
                                 device=dist.pool_sharding(self.mesh)))
        with self.telemetry.tracer.span("fused_step", station="pool"):
            if clean and self._halo_ok and n_adv == n:
                adv = self._pad_rows(
                    blocks[:, -self.stations[0].ring.advance:])
                self.pstate, pairs, qc = fused_mod.pool_step_advance_sharded(
                    self.pstate, put(adv), self._pool_mappings,
                    jnp.int32(base_id), fcfg, lcfg, window, sat, dup, occ,
                    ctr, mp, ver, mj, mesh=self.mesh)
                vm = np.ones((s, n), bool)
            else:
                vm = np.stack([
                    np.ones(n, bool) if (masks is None or masks[i] is None)
                    else np.asarray(masks[i], bool) for i in range(s)])
                self.pstate, pairs, qc = fused_mod.pool_step_block_sharded(
                    self.pstate, put(self._pad_rows(blocks)),
                    self._pool_mappings, jnp.int32(base_id),
                    put(self._pad_rows(vm, fill=False)), fcfg,
                    lcfg, window, sat, dup, occ, ctr, mp, ver, mj,
                    mesh=self.mesh)
                self._halo_ok = clean or primed
            # one transfer + one sync for the whole pooled step output
            (i1, i2, sim, pv), qc = jax.device_get(
                ((pairs.idx1, pairs.idx2, pairs.sim, pairs.valid), qc))
        # one watchdog step per pooled dispatch (all stations share it)
        self.telemetry.record_fused_wall("pool", wd.step_end())
        t_host = time.perf_counter()
        emitted = 0
        with self.telemetry.tracer.span("host_tail", station="pool"):
            for i, st in enumerate(self.stations):
                st._absorb_qc(qc[i], n_adv - int(vm[i, :n_adv].sum()))
                emitted += st._consume(base_id, n_adv, int(vm[i].sum()),
                                       (i1[i], i2[i], sim[i], pv[i]))
        self.telemetry.record_host_tail("pool",
                                        time.perf_counter() - t_host)
        return emitted

    def _pool_flush(self) -> int:
        """Pool counterpart of ``StationStream.flush`` (lockstep rings ⇒
        every station tails at the same base id / consumed count)."""
        emitted = 0
        ready = 0
        per_st = [st.ring.flush_ready() for st in self.stations]
        for k in range(len(per_st[0])):
            base_id = per_st[0][k][0]
            blocks = np.stack([per_st[i][k][1]
                               for i in range(len(self.stations))])
            masks = [per_st[i][k][2] for i in range(len(self.stations))]
            ready += self._pool_ingest_block(base_id, blocks, masks)
        parts = [st.ring.flush_partial() for st in self.stations]
        part = parts[0]
        if part is not None:
            parts = [(p[0], p[1],
                      st._flag_duplicates(p[0], p[1], p[2],
                                          end_id=st.ring.next_fp))
                     for st, p in zip(self.stations, parts)]
            part = parts[0]
        blocks = (np.stack([p[1] for p in parts])
                  if part is not None else None)
        if self.pstate is None:
            if part is not None:
                coeffs = np.asarray(pool_block_coeffs(
                    jnp.asarray(blocks), self.cfg.fingerprint))
                for i, st in enumerate(self.stations):
                    st.mad.update(coeffs[i][parts[i][2]])
            if any(st.mad.filled < 2 for st in self.stations):
                return ready
            self._freeze_pool()
            emitted += self._drain_pool()
        emitted += ready
        if part is not None:
            base_id = part[0]
            masks = [p[2] for p in parts]
            n_adv = self.stations[0].ring.next_fp - base_id
            emitted += self._pool_process(base_id, blocks, masks=masks,
                                          primed=False, n_adv=n_adv)
        return emitted

    def flush(self) -> int:
        """Process buffered tails on every station (pool-aware)."""
        self.serving_version += 1
        if self.pooled:
            return self._pool_flush()
        return sum(st.flush() for st in self.stations)

    def pool_serving_state(self) -> tuple[IndexState, jax.Array, jax.Array]:
        """(stacked index, med (S, C), mad (S, C)) for the serving loop —
        uniform whether the detector ran pooled or solo.

        Returns **copies**: the detector's own pool buffers are donated on
        every subsequent step, so handing out live references would let
        one more ``push`` delete the arrays a ``ServeDetectEngine`` is
        querying. The copy makes the serving state a stable read-only
        snapshot of the index at call time.
        """
        assert all(st.stats_frozen for st in self.stations)
        if self.pstate is not None:
            s = len(self.stations)
            # the slice also drops the mesh-pad rows of a sharded pool,
            # so serving always sees exactly the real stations
            return jax.tree.map(lambda x: jnp.array(x[:s]),
                                (self.pstate.index, self.pstate.med,
                                 self.pstate.mad))
        return (index_mod.stack_states([st.state for st in self.stations]),
                jnp.stack([st.med_mad[0] for st in self.stations]),
                jnp.stack([st.med_mad[1] for st in self.stations]))

    # -- elastic pool membership (ISSUE 10) ----------------------------------

    def _materialize_stations(self) -> None:
        """Pull each real station's index slice out of the (possibly
        sharded, possibly padded) pool back into per-station state —
        the first half of any pool re-pack. Pad rows are dropped here;
        they are re-cloned fresh by the next ``_build_pool``."""
        if self.pstate is None:
            return
        for st in self.stations:
            st._state = jax.tree.map(
                jnp.array,
                index_mod.slice_state(self.pstate.index, st._pool_idx))
        self.pstate = None

    def _repack_pool(self) -> None:
        """Re-probe the mesh for the current width, re-pad, re-shard and
        rebuild the stacked pool. The next block routes through the
        (already-traced-per-shape) ``pool_step_block`` seed path, so a
        width change costs one compile of the new-width executable and
        nothing else — donation and the ≤1-steady-state-trace invariant
        hold per pool width."""
        self.mesh = (dist.station_mesh(len(self.stations))
                     if self.scfg.sharded else None)
        self.pool_pad = dist.padded_pool_width(
            len(self.stations), self.mesh) - len(self.stations)
        self.telemetry.n_stations = len(self.stations)
        self._build_pool()

    def add_station(self, med_mad: tuple[np.ndarray, np.ndarray]
                    | None = None) -> int:
        """Elastically grow the live pool by one station; returns the new
        station's index.

        The stacked pytree is re-padded and re-sharded for the new width
        (``_repack_pool``). The joining station enters at the network
        frontier: its ring mirrors a peer's framing position with the
        whole pre-join span marked missing, so lockstep block emission
        (shared base ids) holds and the join span is suppressed
        in-dispatch rather than invented. ``med_mad`` defaults to station
        0's frozen statistics (network stations see similar noise floors;
        pass real statistics for production use). Serving engines built
        over the old width keep serving their snapshot — rebuild them to
        pick up the grown pool (``ServeDetectEngine`` pins its width).
        """
        if not self.pooled:
            raise ValueError(
                "add_station needs a pooled detector (StreamConfig.fused"
                " + pooled with ≥2 stations at construction)")
        if self.locating:
            raise ValueError(
                "add_station cannot extend the locate tier: station_xy "
                "geometry is fixed at construction — rebuild the "
                "detector with the new geometry instead")
        if self.pstate is None \
                or not all(st.stats_frozen for st in self.stations):
            raise ValueError(
                "add_station requires a live pool (statistics frozen and "
                "the stacked state built); push warmup chunks first")
        if med_mad is None:
            med_mad = tuple(np.asarray(m)
                            for m in self.stations[0].med_mad)
        self._materialize_stations()
        st = StationStream(self.cfg, self.scfg, med_mad=med_mad,
                           external=True, telemetry=self.telemetry)
        st._owner, st._pool_idx = self, len(self.stations)
        peer = self.stations[0]
        st.ring.start = peer.ring.start
        st.ring.next_fp = peer.ring.next_fp
        st.ring.buf = np.zeros(peer.ring.buf.size, np.float32)
        st.ring.vbuf = np.zeros(peer.ring.buf.size, bool)
        st.ring.quality["missing_samples"] += int(peer.ring.buf.size)
        st.processed_fp = peer.processed_fp
        if st.rolling and st.processed_fp:
            st.filter.advance(st.processed_fp)  # join cost paid up front
        self.stations.append(st)
        self._amp.append({})
        self._repack_pool()
        self.serving_version += 1
        return st._pool_idx

    def remove_station(self, station: int) -> None:
        """Elastically drop one station from the live pool (its index
        state and host buffers are discarded; remaining stations shift
        down, which renumbers pair/event station indices from here on).
        The pool is re-padded and re-sharded for the new width."""
        if not self.pooled or self.pstate is None:
            raise ValueError("remove_station requires a live pooled "
                             "detector (statistics frozen)")
        if self.locating:
            raise ValueError(
                "remove_station cannot shrink the locate tier: "
                "station_xy geometry is fixed at construction")
        if not 0 <= station < len(self.stations):
            raise IndexError(station)
        if len(self.stations) < 2:
            raise ValueError("cannot remove the last station")
        self._materialize_stations()
        dropped = self.stations.pop(station)
        dropped._owner = None
        dropped._state = None
        self._amp.pop(station)
        for i, st in enumerate(self.stations):
            st._pool_idx = i
        self._repack_pool()
        self.serving_version += 1

    # -- association / location / finalize ----------------------------------

    def _note_amps(self, st_i: int, pos: int, chunk: np.ndarray) -> None:
        """Max-merge a chunk's |samples| into station ``st_i``'s lag-bin
        amplitude timeline (idempotent under duplicate delivery; NaN
        telemetry contributes nothing)."""
        lag = self.cfg.fingerprint.lag_samples
        b0 = pos // lag
        lead = pos - b0 * lag
        x = np.full(lead + chunk.size, np.nan, np.float32)
        x[lead:] = chunk
        nb = -(-x.size // lag)
        x = np.concatenate([x, np.full(nb * lag - x.size, np.nan,
                                       np.float32)])
        a = np.abs(x).reshape(nb, lag)
        vals = np.where(np.isfinite(a), a, -1.0).max(axis=1)
        d = self._amp[st_i]
        for b, vv in enumerate(vals):
            if vv >= 0:
                key = b0 + b
                prev = d.get(key)
                if prev is None or vv > prev:
                    d[key] = float(vv)

    def _amp_fn(self, st_i: int, fp_index: int) -> float | None:
        """Peak |amplitude| over fingerprint ``fp_index``'s analysis
        window, from the bounded timeline (None when no bin survives)."""
        fcfg = self.cfg.fingerprint
        w_bins = max(1, -(-fcfg.window_samples // fcfg.lag_samples))
        d = self._amp[st_i]
        vals = [d[b] for b in range(fp_index, fp_index + w_bins) if b in d]
        return max(vals) if vals else None

    def _station_weights(self) -> np.ndarray:
        """Live per-station stack weights from the ingest/guard QC
        counters (``core.locate.station_weights``)."""
        return locate_mod.station_weights(
            [st.quality_summary() for st in self.stations],
            [st.stats.samples for st in self.stations],
            [st.ring.next_fp for st in self.stations], self.cfg.locate)

    def _locate_rows(self, rows: np.ndarray, onset_mat: np.ndarray,
                     score_mat: np.ndarray) -> tuple[np.ndarray, int]:
        """Location/magnitude columns for fresh alert rows; returns the
        (possibly moveout-filtered) rows and the rejected count."""
        lcfg = self.cfg.locate
        fcfg = self.cfg.fingerprint
        t0 = time.perf_counter()
        weights = self._station_weights()
        det = {"valid": np.ones(rows.shape[0], bool),
               "station_onset": onset_mat}
        loc = locate_mod.locate_detections(
            det, self.station_xy, weights, fcfg.lag_samples / fcfg.fs,
            lcfg)
        mags = locate_mod.magnitudes_from_onsets(
            onset_mat, rows[:, 0], det["valid"], self._amp_fn, weights,
            score_mat)
        ok = np.isfinite(loc["x_km"])
        rows[:, 5] = np.where(ok, np.round(
            np.nan_to_num(loc["x_km"]) * 1e3), locate_mod.LOC_NONE
            ).astype(np.int64)
        rows[:, 6] = np.where(ok, np.round(
            np.nan_to_num(loc["y_km"]) * 1e3), locate_mod.LOC_NONE
            ).astype(np.int64)
        mok = np.isfinite(mags)
        rows[:, 7] = np.where(mok, np.round(
            np.nan_to_num(mags) * 1e3), locate_mod.MAG_NONE
            ).astype(np.int64)
        rejected = 0
        if lcfg.reject_inconsistent:
            keep = np.asarray(loc["consistent"])
            rejected = int(rows.shape[0] - keep.sum())
            rows = rows[keep]
        self.telemetry.record_locate(
            groups=int(det["valid"].sum()),
            located=int(rows.shape[0]), rejected=rejected,
            wall=time.perf_counter() - t0)
        return rows, rejected

    def poll_detections(self) -> np.ndarray:
        """Incremental network association over closed-window events.

        Returns (k, ``ALERT_COLS``) int64 rows (dt, onset, n_stations,
        score, upgrade, x_mkm, y_mkm, mag_milli) for groups not alerted
        before, plus *upgrade* re-emissions — a previously alerted group
        whose station multiplicity has since grown re-emits with
        ``upgrade=1`` (and a refreshed location/magnitude). With the
        locate tier active, each fresh group is migration-located and
        sized; moveout-inconsistent groups are dropped (they may return
        later via the upgrade path if more stations join). ``finalize``
        remains the authoritative association over the full event history.
        """
        acfg = self.cfg.align
        if not self.rolling or len(self.stations) < 2:
            return np.zeros((0, ALERT_COLS), np.int64)
        # the active rows only change when a window closes — don't repeat
        # the association dispatch on pushes that closed nothing
        closed = sum(st.filter.windows_closed for st in self.stations)
        if closed == self._polled_windows:
            return np.zeros((0, ALERT_COLS), np.int64)
        self._polled_windows = closed
        per_station = [st.filter.rows_tail(self._assoc_lo)
                       for st in self.stations]
        if sum(r.shape[0] for r in per_station) == 0:
            return np.zeros((0, ALERT_COLS), np.int64)
        events = [events_from_rows(r) for r in per_station]
        det = align_mod.associate_network(events, acfg, len(self.stations),
                                          with_onsets=self.locating)
        v = np.asarray(det["valid"])
        rows = np.zeros((int(v.sum()), ALERT_COLS), np.int64)
        rows[:, 0] = np.asarray(det["dt"])[v]
        rows[:, 1] = np.asarray(det["onset"])[v]
        rows[:, 2] = np.asarray(det["n_stations"])[v]
        rows[:, 3] = np.asarray(det["score"])[v]
        rows[:, 5:7] = locate_mod.LOC_NONE
        rows[:, 7] = locate_mod.MAG_NONE
        onset_mat = (np.asarray(det["station_onset"])[v]
                     if self.locating else None)
        score_mat = (np.asarray(det["station_score"])[v]
                     if self.locating else None)
        if self._emitted.shape[0] and rows.shape[0]:
            near = ((np.abs(rows[:, 0, None] - self._emitted[None, :, 0])
                     <= acfg.dt_tol)
                    & (np.abs(rows[:, 1, None] - self._emitted[None, :, 1])
                       <= acfg.onset_tol))
            matched = near.any(axis=1)
            # best multiplicity this key has alerted at; a matched group
            # that now exceeds it re-emits as an upgrade
            best = np.where(matched,
                            (near * self._emitted[None, :, 2]).max(axis=1),
                            0)
            upgrade = matched & (rows[:, 2] > best)
            for r in np.nonzero(upgrade)[0]:
                js = np.nonzero(near[r])[0]
                self._emitted[js, 2] = np.maximum(self._emitted[js, 2],
                                                  rows[r, 2])
            rows[:, 4] = upgrade.astype(np.int64)
            keep = ~matched | upgrade
            rows = rows[keep]
            if self.locating:
                onset_mat, score_mat = onset_mat[keep], score_mat[keep]
        fresh = rows[rows[:, 4] == 0]
        if fresh.shape[0]:
            self._emitted = np.concatenate([self._emitted, fresh[:, :3]])
        if self.locating and rows.shape[0]:
            rows, _ = self._locate_rows(rows, onset_mat, score_mat)
        # onsets below every station's closed frontier minus the sliding
        # window can gain no further members — stop rescanning them, and
        # archive rows + dedup keys + amplitude bins the floor has passed
        # so the per-push scan stays O(active window) instead of O(stream)
        frontier = min(st.filter.w_start for st in self.stations)
        self._assoc_lo = max(self._assoc_lo, frontier
                             - self.scfg.window_fingerprints
                             - 2 * acfg.onset_tol)
        for st in self.stations:
            st.filter.retire_below(self._assoc_lo)
        if self._emitted.shape[0]:
            live = self._emitted[:, 1] >= self._assoc_lo - acfg.onset_tol
            self._emitted = self._emitted[live]
        amp_floor = self._assoc_lo - acfg.onset_tol
        if amp_floor > 0:
            for d in self._amp:
                for b in [b for b in d if b < amp_floor]:
                    del d[b]
        return rows

    def finalize(self) -> tuple[dict | None, list[Events], dict]:
        if self.pooled:
            self._pool_flush()
        station_events, stats = [], {}
        for i, st in enumerate(self.stations):
            events, _, fstats = st.finalize()
            station_events.append(events)
            for k, v in fstats.items():
                stats[f"station{i}_{k}"] = v
        detections = None
        if len(self.stations) >= 2:
            detections = align_mod.associate_network(
                station_events, self.cfg.align, len(self.stations),
                with_onsets=self.locating)
            if self.locating:
                t0 = time.perf_counter()
                fcfg = self.cfg.fingerprint
                was = int(np.asarray(detections["valid"]).sum())
                detections = locate_mod.attach_location(
                    detections, self.station_xy, self._station_weights(),
                    fcfg.lag_samples / fcfg.fs, self.cfg.locate,
                    self._amp_fn, stats)
                self.telemetry.record_locate(
                    groups=was,
                    located=int(np.asarray(detections["valid"]).sum()),
                    rejected=stats.get("moveout_rejected", 0),
                    wall=time.perf_counter() - t0)
            stats["detections"] = int(np.asarray(
                detections["valid"]).sum())
        if self.rolling:
            stats["alerts"] = int(sum(a.shape[0] for a in self.alerts))
        stats["ingest"] = [st.stats.summary() for st in self.stations]
        stats["quality"] = self.quality_summary()
        return detections, station_events, stats

    def quality_summary(self) -> dict:
        """Network-wide data-quality counters — the per-station summaries
        folded through the one shared aggregation path (same keys as
        ``StationStream.quality_summary``)."""
        return merge_counts(st.quality_summary() for st in self.stations)

    def metrics_snapshot(self) -> dict:
        """The single structured telemetry view of this detector (schema
        ``stream-metrics/v1``): aggregate + per-station throughput, the
        in-dispatch drop breakdown and rates, quality counters, wall-time
        histograms, span totals, and watchdog state. Consumed by
        ``serve_detect``, ``bench_stream``/``bench_e2e``, the examples,
        and the tier-1 schema test."""
        return tele_mod.metrics_snapshot(self)

    # -- snapshot / restore -------------------------------------------------

    def snapshot(self, ckpt_dir: str, step: int | None = None, *,
                 background: bool = False, keep: int = 3):
        """Checkpoint the whole detector through ``train/checkpoint.py``.

        One ``step_<N>`` directory holds every station's index pytree, ring
        buffer, MAD reservoir, pending blocks, and (bounded mode) rolling
        filter state, plus the detector's alert dedup keys — everything
        needed for ``restore`` to continue the stream bit-exactly. Pooled
        detectors snapshot per-station slices, so the on-disk layout is
        identical either way.
        """
        arrays: dict[str, np.ndarray] = {}
        st_extra = []
        for i, st in enumerate(self.stations):
            a, e = st.snapshot_state()
            arrays.update({f"s{i}/{k}": v for k, v in a.items()})
            st_extra.append(e)
        arrays["detector/emitted"] = self._emitted
        arrays["detector/alerts"] = (
            np.concatenate(self.alerts, axis=0).astype(np.int64)
            if self.alerts else np.zeros((0, ALERT_COLS), np.int64))
        for i, d in enumerate(self._amp):
            arrays[f"detector/amp{i}"] = (
                np.array([[b, a] for b, a in sorted(d.items())], np.float64)
                if d else np.zeros((0, 2), np.float64))
        extra = {"n_stations": len(self.stations), "stations": st_extra,
                 "assoc_lo": self._assoc_lo,
                 "telemetry": self.telemetry.snapshot(),
                 "scfg": {
                     "block_fingerprints": self.scfg.block_fingerprints,
                     "window_fingerprints": self.scfg.window_fingerprints,
                     "filter_window_fingerprints":
                         self.scfg.filter_window_fingerprints,
                     "reorder_horizon_samples":
                         self.scfg.reorder_horizon_samples,
                     "saturation_limit": self.scfg.saturation_limit,
                     "dup_window_fingerprints":
                         self.scfg.dup_window_fingerprints,
                     "dup_sig_tables": self.scfg.dup_sig_tables,
                     "occ_limit": self.scfg.occ_limit,
                     "max_pairs_per_block": self.scfg.max_pairs_per_block,
                     "verify_jaccard": int(self.scfg.verify_jaccard),
                 }}
        if step is None:
            step = self.stations[0].stats.chunks
        return ckpt_mod.save_checkpoint(ckpt_dir, step, arrays, extra=extra,
                                        background=background, keep=keep)

    @classmethod
    def restore(cls, ckpt_dir: str, cfg: DetectConfig,
                scfg: StreamConfig | None = None, *,
                step: int | None = None,
                station_xy: np.ndarray | None = None,
                ) -> tuple["StreamingDetector", int]:
        """Rebuild a detector from its latest (or given) snapshot.

        The snapshot records the streaming mode it was taken under; a
        ``scfg`` whose block size or window lengths differ is rejected up
        front (the station state layouts are not interchangeable).
        ``station_xy`` is not snapshotted (it is deployment geometry, not
        stream state) — pass it again to keep the locate tier running
        across the restart.
        """
        arrays, extra, step = ckpt_mod.restore_flat(ckpt_dir, step=step)
        det = cls(cfg, scfg, n_stations=int(extra["n_stations"]),
                  station_xy=station_xy)
        saved = extra.get("scfg", {})
        for key, have in (
                ("block_fingerprints", det.scfg.block_fingerprints),
                ("window_fingerprints", det.scfg.window_fingerprints),
                ("filter_window_fingerprints",
                 det.scfg.filter_window_fingerprints),
                ("reorder_horizon_samples",
                 det.scfg.reorder_horizon_samples),
                ("saturation_limit", det.scfg.saturation_limit),
                ("dup_window_fingerprints",
                 det.scfg.dup_window_fingerprints),
                ("dup_sig_tables", det.scfg.dup_sig_tables),
                ("occ_limit", det.scfg.occ_limit),
                # verify toggles the packed-fingerprint ring, which is
                # part of the station state layout (max_pairs is not —
                # it only shapes the per-step output, so it may differ)
                ("verify_jaccard", det.scfg.verify_jaccard)):
            if key in saved and int(saved[key]) != int(have):
                raise ValueError(
                    f"snapshot was taken with {key}={saved[key]} but the "
                    f"restoring StreamConfig has {have}; pass a matching "
                    f"config (e.g. the same --window-fp/--filter-window-fp "
                    f"flags the snapshotting service ran with)")
        for i, st in enumerate(det.stations):
            prefix = f"s{i}/"
            sub = {k[len(prefix):]: v for k, v in arrays.items()
                   if k.startswith(prefix)}
            st.restore_state(sub, extra["stations"][i])
        if det.pooled and all(st.stats_frozen for st in det.stations):
            det._build_pool()
        emitted = np.asarray(arrays["detector/emitted"], np.int64)
        if emitted.ndim == 2 and emitted.shape[1] == 2:
            # pre-ISSUE-9 snapshot: (k, 2) keys without a best-
            # multiplicity column — seed it at the floor, so any growth
            # past min_stations re-emits as an upgrade
            emitted = np.concatenate(
                [emitted, np.full((emitted.shape[0], 1),
                                  cfg.align.min_stations, np.int64)],
                axis=1)
        det._emitted = emitted.reshape(-1, 3)
        alerts = np.asarray(arrays["detector/alerts"], np.int64)
        if alerts.ndim == 2 and alerts.shape[1] == 4:
            # pre-ISSUE-9 snapshot: (k, 4) rows — pad the upgrade /
            # location / magnitude columns with their sentinels
            pad = np.zeros((alerts.shape[0], ALERT_COLS - 4), np.int64)
            pad[:, 1:3] = locate_mod.LOC_NONE
            pad[:, 3] = locate_mod.MAG_NONE
            alerts = np.concatenate([alerts, pad], axis=1)
        alerts = alerts.reshape(-1, ALERT_COLS)
        det.alerts = [alerts] if alerts.shape[0] else []
        for i in range(len(det.stations)):
            amp = arrays.get(f"detector/amp{i}")
            if amp is not None and amp.size:
                det._amp[i] = {int(b): float(a)
                               for b, a in np.asarray(amp).reshape(-1, 2)}
        det._assoc_lo = int(extra["assoc_lo"])
        if "telemetry" in extra:    # pre-ISSUE-6 snapshots: fresh registry
            det.telemetry.restore(extra["telemetry"])
        if det.rolling:
            det._polled_windows = sum(st.filter.windows_closed
                                      for st in det.stations)
        return det, step


def ingest_chunks(det: StreamingDetector, waveforms: np.ndarray,
                  n_chunks: int = 16, *, skip: int = 0,
                  warmup_chunks: int = 0, snapshot_every: int = 0,
                  snapshot_dir: str | None = None,
                  metrics_every: int = 0,
                  metrics_file: str | None = None,
                  heartbeat=print, on_chunk=None) -> dict:
    """Push a trace through a detector in equal chunks — the one shared
    ingest loop behind serving, benchmarks, and examples.

    ``waveforms``: (T,) or (n_stations, T). ``skip`` resumes mid-stream
    (samples already ingested before a snapshot restore are not re-pushed;
    a partially-covered chunk is trimmed). ``warmup_chunks`` excludes the
    first chunks (trace compilation + stats freeze) from the timed span.
    ``metrics_every`` > 0 turns on the live health surface: every N
    pushed chunks a heartbeat line (real-time factor, throughput, drop
    rates, quality counters) goes to ``heartbeat`` and, when
    ``metrics_file`` is set, the Prometheus text exposition is rewritten
    atomically at the same cadence (a scrape never sees a torn file).
    ``on_chunk(ci)`` runs after each pushed chunk — the interleave hook
    the serving tier uses to admit arrivals, refresh its pool snapshot,
    and pump query ticks between ingest chunks (``ServeSession``).
    Returns {"chunks", "timed_chunks", "wall_s", "warmup_wall_s",
    "samples"}.
    """
    waveforms = np.atleast_2d(np.asarray(waveforms, np.float32))
    chunks = np.array_split(waveforms, n_chunks, axis=1)
    seen = 0
    pushed = timed = 0
    samples = 0
    t_start = time.perf_counter()
    t_timed = None
    for ci, chunk in enumerate(chunks):
        seen += chunk.shape[1]
        if seen <= skip:
            continue
        if seen - chunk.shape[1] < skip:
            chunk = chunk[:, chunk.shape[1] - (seen - skip):]
        if pushed == warmup_chunks and t_timed is None:
            t_timed = time.perf_counter()
        det.push(chunk)
        pushed += 1
        if pushed > warmup_chunks:
            timed += 1
            samples += int(chunk.size)
        if snapshot_every and (ci + 1) % snapshot_every == 0:
            det.snapshot(snapshot_dir, step=ci + 1)
        if metrics_every and pushed % metrics_every == 0:
            heartbeat(det.telemetry.heartbeat_line(det))
            if metrics_file:
                det.telemetry.write_prometheus(metrics_file, det)
        if on_chunk is not None:
            on_chunk(ci)
    t_end = time.perf_counter()
    if t_timed is None:
        t_timed = t_end
    return {"chunks": pushed, "timed_chunks": timed,
            "wall_s": t_end - t_timed,
            "warmup_wall_s": t_timed - t_start, "samples": samples}
