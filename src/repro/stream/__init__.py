"""Streaming detection subsystem: FAST as a continuous service.

The paper's pipeline is strictly batch — fingerprint everything, sort
everything, then search — so a decade of history is re-sorted whenever one
new week of data arrives (§6.4 exists to make that giant sort fit in
memory). This package re-expresses detection as *query-against-index* over
an unbounded stream:

``ingest``   ``WaveformRing`` turns arbitrary-length chunks into fixed
             fingerprint blocks with the exact STFT halo across
             boundaries, and ``StreamingMAD`` keeps the §5.2 median/MAD
             statistics as a uniform reservoir (no second pass).

``index``    ``StreamingIndex``: the LSH hash tables materialized as
             fixed-capacity device-resident bucket arrays with jitted
             O(batch) ``insert``/``query`` (ring-buffer eviction caps
             mega-buckets structurally). Pair semantics — min_dt,
             m-of-t matches — are shared with the offline search via
             ``core.lsh.finalize_pairs``.

``engine``   ``StreamingDetector`` composes ring → fingerprints →
             signatures → insert+query → incremental pair accumulation →
             the offline alignment stack, per station, with per-chunk
             latency/throughput stats.

``launch/serve_detect.py`` wraps a shared index in a slot/refill request
loop (the ``ServeEngine`` idiom) for concurrent query-window serving, with
periodic snapshots (``--snapshot-every``) and restart (``--restore``).

Unbounded streams run *bounded*: with ``StreamConfig.window_fingerprints``
the jitted step expires index entries beyond a sliding detection window,
and with ``filter_window_fingerprints`` the ``RollingPairFilter`` retires
candidate pairs window-by-window through the §6.5 occurrence filter into
compact event rows — O(window) host state, near-real-time multi-station
alerts via ``StreamingDetector.poll_detections``, and exact kill/restore
via ``snapshot``/``restore`` (checkpointed through ``train/checkpoint``).

A parity test (tests/test_stream.py) holds the streamed path to ≥95% of
the offline ``lsh.search`` pair set on synthetic traces; a golden test
(tests/golden/) pins the exact streamed pair set against drift.
"""
from repro.stream.engine import (RollingPairFilter,  # noqa: F401
                                 StationStream, StreamingDetector,
                                 StreamStats, block_coeffs,
                                 events_from_rows, events_to_rows,
                                 pairs_from_triplets, stream_step)
from repro.stream.index import (IndexState, StreamIndexConfig,  # noqa: F401
                                expire, index_stats, init_index, insert,
                                query)
from repro.stream.ingest import (StreamConfig, StreamingMAD,  # noqa: F401
                                 WaveformRing)
