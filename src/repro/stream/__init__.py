"""Streaming detection subsystem: FAST as a continuous service.

The paper's pipeline is strictly batch — fingerprint everything, sort
everything, then search — so a decade of history is re-sorted whenever one
new week of data arrives (§6.4 exists to make that giant sort fit in
memory). This package re-expresses detection as *query-against-index* over
an unbounded stream:

``ingest``   ``WaveformRing`` turns arbitrary-length chunks into fixed
             fingerprint blocks with the exact STFT halo across
             boundaries, and ``StreamingMAD`` keeps the §5.2 median/MAD
             statistics as a uniform reservoir (no second pass).

``index``    ``StreamingIndex``: the LSH hash tables materialized as
             fixed-capacity device-resident bucket arrays with jitted
             O(batch) ``insert``/``query`` (ring-buffer eviction caps
             mega-buckets structurally). Pair semantics — min_dt,
             m-of-t matches — are shared with the offline search via
             ``core.lsh.finalize_pairs``.

``engine``   ``StreamingDetector`` composes ring → fingerprints →
             signatures → insert+query → incremental pair accumulation →
             the offline alignment stack, per station, with per-chunk
             latency/throughput stats.

``fused``    the single-dispatch hot path (ISSUE 3): the whole per-block
             chain above as **one** jitted step over a donated
             ``FusedState`` pytree, plus the vmapped station pool.

Hot path anatomy — the one-dispatch invariant, one core two drivers
-------------------------------------------------------------------

Steady state (statistics frozen, no flush pending) must stay a *single*
device dispatch per block, per detector. The traced program is::

  step_advance(FusedState{index, halo, med, mad}, new_samples)
    wave   = concat(halo, new_samples)          # WaveformRing advance
    coeffs = haar2d(spectral_images(stft(wave)))  # fingerprint chain
    bits   = topk_binarize((coeffs - med) / mad)  # §5.2 binarization
    sig,bk = signatures_and_buckets(bits)       # Min-Max fold + addressing
    index  = insert(expire(index), sig, bk)     # sliding window + decay
    pairs  = query(index, sig, bk)              # id-ordered emission
    pairs  = occurrence_limit(index, pairs)     # in-dispatch §6.5 limiter
    pairs  = verify(compact(pairs))             # bounded emission + exact
    return FusedState{index', wave[-halo:], med, mad}, pairs, qc  # Jaccard

(the expire/guards/insert/query/limit tail is ``index.guarded_step``; the
duplicate probe and saturation quarantine run inside it, and with every
knob at 0 the whole tail compiles down to the unguarded program exactly).

Every ``FusedState`` leaf is **donated**: chunk N+1 overwrites chunk N's
buffers in place (zero steady-state HBM allocation), and the halo — the
STFT overlap between consecutive blocks — never leaves the device. Multi-
station detectors stack the state on a leading S axis and run the same
program under ``vmap`` (``pool_step_advance``): S stations, one dispatch.
Signature fold + bucket addressing are computed once and shared by insert
and query (and fuse into the Pallas Min-Max kernel epilogue on TPU).

**Batch = replay.** This is the repo's ONLY detection core (ISSUE 5):
the offline pipeline, ``core.detect.detect_events``, is a thin batch
driver that stacks an archive's stations and drives whole-trace blocks
through ``pool_step_block`` — the legacy host-orchestrated per-station
fingerprint→signatures→search→filter chain is deleted, its output
golden-pinned bit-exact against the replay
(``tests/golden/batch_detect.json``). Every guard below is therefore
available to archive reprocessing through the same ``StreamConfig``
knobs, and any future guard or kernel lands in one place and serves
both drivers. ``detect_step`` (the dry-run cell) wraps the same
``guarded_step`` tail over a fresh in-trace index.

Future PRs must not re-split this step: anything added to the per-block
path (new filters, extra statistics) belongs *inside* the traced program
or strictly on the host side of the pair stream. The retracing guard
(≤1 trace across same-shape chunks), the donation guard (flat
``jax.live_arrays`` across steady-state chunks), and the fused-vs-unfused
parity test in ``tests/test_stream.py`` enforce the invariant; the
unfused chain (``block_coeffs`` + ``stream_step``, ``fused=False``) stays
as the bit-exact reference.

Data-quality path (ISSUE 4)
---------------------------

Real telemetry is pathological — gaps, out-of-order and duplicated
chunks, repeating instrument glitches — and every defense lives either
*before* the dispatch or *inside* the one traced program, never as an
extra dispatch:

* **gap-aware ingest** (``WaveformRing``): ``push(chunk, offset)``
  places chunks on the absolute sample timeline; NaN samples and offset
  jumps become sentinel-filled invalid spans, and each emitted block
  carries a per-fingerprint validity mask (a fingerprint is valid iff
  its whole window holds real samples). Masked blocks route through the
  already-traced ``step_block`` — suppressed fingerprints get filler
  signatures in-dispatch, are never inserted or queried, and the
  donation/retracing invariants are untouched (pinned by
  ``test_quality_path_single_dispatch_invariants``).
* **reorder reconciliation** (``StreamConfig.reorder_horizon_samples``):
  block emission is held back by the horizon so late chunks splice into
  place and duplicated deliveries drop deterministically (first writer
  wins); permutation-invariance within the horizon is a pinned property.
* **repeated-segment guard** (``dup_window_fingerprints``): sample-exact
  window hashes flag telemetry-duplicated blocks and flat-lined channels
  *before* the dispatch — structurally zero false positives on clean
  data (continuous noise never repeats bit-exactly).
* **bucket-saturation quarantine** (``saturation_limit``, in-dispatch):
  buckets whose insert-traffic counter exceeds the limit stop emitting
  pairs — the paper's repeating-glitch mega-bucket fix. With a sliding
  window the counter is *window-relative*: it halves once per window
  inside the traced ``expire`` (``IndexState.traffic`` — separate from
  the monotonic ring ``cursor``), so quarantined buckets recover once a
  glitching channel is repaired and the guard is safe on unbounded
  multi-month streams. The signature-level ``dup_sig_tables`` guard is
  the aggressive per-deployment variant (strong legitimate repeaters can
  collide in all tables).
* **in-dispatch §6.5 occurrence limiter** (``occ_limit``, ISSUE 5): raw
  partner collisions — (table, slot) signature matches at id distance ≥
  ``min_dt``, the §6.3 lookups-per-query skew signal — are accumulated
  per fingerprint in an id-keyed ring (``IndexState.occ``, slots
  recycled as the window slides), and pairs touching a fingerprint past
  the limit are dropped inside the same traced program. This is what
  suppresses *additive* (non-sample-exact) glitch trains ≥10× — they
  ride the live noise floor, so the duplicate guard cannot see them and
  the saturation quarantine alone only managed ~2×. The host-side
  ``occurrence_filter`` (one shared invocation,
  ``engine.host_occurrence_filter``, used by finalize, the rolling
  filter, and the batch replay driver) remains the bit-exact §6.5
  reference/fallback.

With every knob at its default (off) — and on clean data even with the
knobs on — the traced program and the emitted pair set are bit-identical
to the unguarded path (``test_quality_path_clean_bit_parity``). The
scenario generator (``core.synth.make_scenario_dataset``) is the shared
fault-injection substrate for ``tests/test_scenarios.py`` and
``bench_stream --scenario`` (spurious-pair suppression recorded in
``BENCH_stream.json``); reconciliation and guard counters surface
through ``StreamingDetector.quality_summary`` and ``serve_detect``.

Observability path (ISSUE 6)
----------------------------

Telemetry follows the same discipline as the quality path: everything is
either *inside* the already-traced program or on the host side of the
pair stream — never an extra dispatch, never a change to detections.

* **in-dispatch counters** (``index.QC_FIELDS``): every fused/unfused
  step returns a per-station counter vector computed inside the traced
  program — pairs emitted, fingerprints masked by validity, raw and
  quarantined collisions, duplicate-suppressed fingerprints, limiter
  drops. The guard counters are always live; the telemetry-only entries
  are gated by the static ``StreamConfig.telemetry`` knob (default on)
  and constant-fold to zero when off, so telemetry-off compiles the
  exact pre-ISSUE-6 program and telemetry-on stays one dispatch with
  bit-identical detections (both pinned in ``tests/test_telemetry.py``).
  Counters only *read* the guard masks; they never feed back into pairs.
* **host metrics registry** (``repro.obsv.metrics``): labeled counters,
  gauges, and log-bucketed histograms (chunk-ingest / fused-dispatch /
  host-tail walls, ``host_state_rows``, ring reorder+gap counters) with
  O(1) memory per series; snapshots/restores alongside the detector so a
  restarted service resumes its counters. Rendered as Prometheus text
  exposition (``repro.obsv.metrics.render_prometheus``).
* **span tracing** (``repro.obsv.spans.SpanTracer``): nested wall-clock
  spans over ingest → fused_step → host_tail (and the batch replay's
  stages — ``core.detect.StageTimes`` is *derived* from the span totals),
  optional structured JSONL emission and a ``jax.profiler`` trace hook.
* **watchdog**: the training loop's ``train/watchdog.StepWatchdog``
  wraps every streaming dispatch — one step per pooled dispatch —
  flagging stragglers into ``straggler_steps_total``.
* **health surface**: ``StreamingDetector.metrics_snapshot()`` is the
  single structured view (schema ``stream-metrics/v1``) consumed by
  ``bench_stream`` / ``bench_e2e`` artifacts, the examples, and
  ``serve_detect --metrics-every/--metrics-file`` (heartbeat JSON lines
  with real-time factor + per-guard drop rates; atomically rewritten
  Prometheus exposition). The hub tying these together is
  ``stream.telemetry.StreamTelemetry`` (one per detector, shared by its
  stations).

Serving tier (ISSUE 7)
----------------------

``launch/serve_detect.py`` grows the slot/refill idiom into a
concurrent, backpressured query service over the index pool; the flow
per request is **admission queue → batched ``_serve_step`` → refresh
cadence → shed path**:

* **admission** (``ServeDetectEngine.submit``): a bounded FIFO in front
  of the slots. Depth past ``max_queue`` load-sheds — the request
  completes immediately with ``outcome="rejected"`` (the overload
  contract: answer *something* fast instead of queueing without bound;
  a burst of B > max_queue sheds exactly B − max_queue, pinned by
  ``tests/test_serve.py``). Every request carries arrival-time
  accounting: queue wait (submit → slot) and service time (slot →
  done) are split in the latency records.
* **batched ticks** (``ServeDetectEngine.tick``): each tick admits
  queued requests into free slots and runs **one** jitted dispatch that
  fingerprints all active slots once and queries every station's index
  read-only — concurrent requests share device dispatches exactly like
  decode slots share a decode step, and the answers are pinned
  identical to sequential single-slot serving. Idle ticks (no active
  slots) return without assembling a batch or dispatching.
* **refresh cadence** (``refresh_from`` / ``ServeSession``): serving
  runs against a *copied* ``pool_serving_state()`` snapshot (donation
  safety), refreshed at a configured chunk cadence and gated on
  ``StreamingDetector.serving_version`` so an unchanged corpus costs
  nothing. ``ServeSession`` is the cooperative single-thread loop —
  ingest chunks keep growing the pool while query ticks run between
  them (``ingest_chunks(..., on_chunk=...)``), so the corpus grows
  under live queries (``serve_detect --interleave``).
* **telemetry**: the engine publishes through the shared PR-6 registry
  (``serve_requests_total{outcome}``, queue-depth/slot-occupancy
  gauges, queue-wait/service/latency histograms,
  ``serve_state_refreshes_total``), surfaced in the heartbeat,
  the Prometheus exposition, and ``metrics_snapshot()["serve"]``;
  ``benchmarks/bench_serve.py`` records sustained QPS, the p50/p99
  latency split, and shed rates under closed-loop concurrent clients
  (``BENCH_serve.json``).

Snapshots (``--snapshot-every``), restart (``--restore``, which grows
the restored pool elastically when ``--stations`` exceeds the snapshot
width — ISSUE 10 — and rejects shrinks, which would discard station
identities), and the live
health surface (``--metrics-every``, ``--metrics-file``,
``--trace-jsonl``, ``--dirty``) ride the same CLI.

Emission path (ISSUE 8)
-----------------------

The dense pair emission is O(t · N · cap) slots per block — at the paper
configuration (t=100, cap=8) that is ~205k candidate slots per station
per 256-fingerprint block, nearly all invalid, every one transferred to
the host and scanned there. Two in-dispatch epilogue stages shrink the
pipe to O(max_pairs):

* **compaction** (``index.compact_pairs``, ``max_pairs_per_block`` > 0):
  after the m-of-t reduction, surviving pairs are gathered into a
  bounded static-shape ``(max_pairs,)`` buffer via a ``top_k`` over
  stream position — deterministic (first ``max_pairs`` valid positions
  = lexicographically smallest (idx1, idx2) survive; re-running a block
  drops the *same* pairs), donation-safe, and counted: overflow drops
  land in the ``overflow_pairs`` slot of ``QC_FIELDS`` and surface
  through ``drop_breakdown()`` / ``step_overflow_pairs_total``.
* **exact-Jaccard verify** (``index.verify_pairs``,
  ``verify_jaccard``): the binarizer's bit-packed fingerprints are
  stashed in a window-sized device ring (``IndexState.pk``, keyed by
  id % pk_slots, carried through snapshot/restore) and every compacted
  candidate is scored with exact Jaccard via
  ``kernels.jaccard_popcount`` — the jnp oracle, or the Pallas popcount
  kernel with ``verify_pallas`` (interpret-mode parity pinned in
  ``tests/test_kernels.py``). Pairs then emit as
  ``core.lsh.VerifiedPairs`` (idx1, idx2, hash matches, jaccard), and
  ``verify_min_jaccard`` drops false LSH collisions in-dispatch so
  downstream thresholds act on true similarity, not the hash proxy.

Both stages run inside the same traced program (one dispatch, donated
buffers), in every driver — solo and pooled streaming, batch replay,
and the serving tier's read-only slot queries. With the knobs at 0 the
dense emission and the traced program are exactly as before; with
compaction sized above the true pair rate the emitted pair set is
bit-identical to dense (golden-pinned). ``benchmarks/bench_e2e.py``
records the A/B (``emission`` section: pair bytes per block, device-
step vs host-tail wall split) and ``make bench-emit`` refreshes it.

Association → location → magnitude (ISSUE 9)
--------------------------------------------

Detection ends the paper's pipeline at "same (dt, onset±tol) at ≥2
stations" (§7, Figure 9) — a detection is a *coincidence*, with no
place, no size, and no defense against cross-station coincidences that
fit no physical moveout. The location tier (``core/locate.py``) turns
each associated group into a located, weighted, sized detection, in
three host-side stages downstream of the pair stream (never an extra
per-block dispatch):

* **association** (``core.align.associate_network(..., with_onsets)``):
  the §7 grouping, with station multiplicity counted through packed
  int32 bitmask words (no 32-station cap) and, when the locate tier is
  on, per-group ``(p, S)`` station-onset / station-score matrices —
  each present station's earliest onset and Jaccard-weighted mass.
* **location** (``locate.locate_groups``): a coarse-to-fine migration
  stack — candidate origins on a ``grid_n²`` surface grid, per-station
  travel-time moveouts subtracted from the onset matrix, the weighted
  t0/residual evaluated everywhere at once (jit + vmap over groups),
  argmin refined ``refine_levels`` times. The weighted mean absolute
  residual doubles as the **moveout-consistency gate**: a group whose
  onsets fit no candidate origin within ``moveout_tol_lags`` is a
  cross-station coincidence and (``reject_inconsistent``) is dropped —
  discriminative from 3 stations up (two stations always fit). Station
  weights come from the PR-4/PR-6 QC counters
  (``locate.station_weights``): dirty stations pull the stack less,
  dead ones are floored at ``min_weight``, never zero.
* **magnitude** (``locate.relative_magnitude``): per detection, the
  weighted median over stations of ``log10`` peak-amplitude ratios
  between the re-occurrence and the first occurrence — batch reads
  whole-trace per-fingerprint peaks (``locate.fingerprint_amplitudes``),
  streaming keeps a bounded per-station lag-bin amplitude timeline
  pruned with the association floor; both feed the same
  ``locate.attach_location`` stage via an ``amp_fn`` closure.

Both drivers share the stage: batch ``detect_events(station_xy=...)``
appends it after association, and the streaming detector runs it in
``poll_detections`` (alerts grow upgrade/x/y/magnitude columns — an
alert re-emits flagged when a late station upgrades its multiplicity)
and ``finalize``. Telemetry rides the PR-6 registry
(``locate_view()``: passes, located, moveout-rejected, stack-wall
histogram); ``bench_stream --assoc`` records the A/B where the moveout
gate cuts ≥3-station false associations under shared-period noise
pressure while keeping every true group (``BENCH_stream.json``,
``located_scenario`` key; ``make bench-assoc`` refreshes it).

Sharded station pool (ISSUE 10)
-------------------------------

The pooled hot path stacks every station's ``FusedState`` on a leading S
axis; sharding splits that axis across a 1-axis ``stations`` device mesh
(``dist.station_mesh``) so the network's ceiling is the fleet, not one
chip. Three properties make this the cheap kind of distribution:

* **zero in-region collectives**: stations are independent until the
  host-side association tail, so ``pool_step_*_sharded`` run the same
  per-station ``core`` under ``dist.shard_map`` **fully manual** over
  the ``stations`` axis — no cross-device communication inside the
  traced program, which also sidesteps the jaxlib-0.4.x partial-manual
  scan/gather limitation (only partial-manual regions hit it). Donation
  and the one-dispatch-per-block invariant carry over per shard; the
  pair/QC outputs come back through the same single ``device_get``.
* **capability probe, vmap fallback**: ``dist.station_mesh`` returns
  ``None`` on one visible device or fewer than two stations, and the
  sharded entries then delegate to the bit-identical ``vmap`` pool —
  ``StreamConfig.sharded`` (default on) is inert on a laptop and a
  no-code-change scale-out on a multi-device host. When S does not
  divide the mesh, the pool pads with throwaway station clones (row-
  independent math; outputs never read) rather than idling devices.
* **mesh-elastic state**: snapshots store per-station slices (device
  topology never reaches disk), so a pool saved under 8 devices
  restores onto 1 or 4 unchanged — and the live pool is elastic too:
  ``StreamingDetector.add_station`` / ``remove_station`` re-pad and
  re-shard the stacked pytree mid-stream (the joiner mirrors a peer's
  ring framing with its pre-join span masked missing, so lockstep block
  emission holds from the first post-join block).

``benchmarks/bench_e2e.py`` records the device-count × stations scaling
grid (``sharded_pool`` section, ``make bench-sharded``) under
``--xla_force_host_platform_device_count``, with exact step percentiles
and per-point sharded-vs-vmap pair parity; forced host devices time-
slice the physical cores, so the recorded speedup only reads as a
scaling curve when ``host_cores`` ≥ the device count.

Unbounded streams run *bounded*: with ``StreamConfig.window_fingerprints``
the jitted step expires index entries beyond a sliding detection window,
and with ``filter_window_fingerprints`` the ``RollingPairFilter`` retires
candidate pairs window-by-window through the §6.5 occurrence filter into
compact event rows — O(window) host state, near-real-time multi-station
alerts via ``StreamingDetector.poll_detections``, and exact kill/restore
via ``snapshot``/``restore`` (checkpointed through ``train/checkpoint``).

A parity test (tests/test_stream.py) holds the streamed path to ≥95% of
the offline ``lsh.search`` pair set on synthetic traces; a golden test
(tests/golden/) pins the exact streamed pair set against drift.
"""
from repro.stream.engine import (RollingPairFilter,  # noqa: F401
                                 StationStream, StreamingDetector,
                                 StreamStats, block_coeffs, ingest_chunks,
                                 events_from_rows, events_to_rows,
                                 host_occurrence_filter,
                                 merge_boundary_rows, pairs_from_triplets,
                                 pool_block_coeffs, stream_step)
from repro.stream.fused import (FusedState, init_pool_state,  # noqa: F401
                                init_state, pool_step_advance,
                                pool_step_advance_sharded, pool_step_block,
                                pool_step_block_sharded, step_advance,
                                step_block)
from repro.stream.index import (IndexState, QC_FIELDS,  # noqa: F401
                                StreamIndexConfig, compact_pairs, expire,
                                index_stats, init_index, init_pool, insert,
                                query, slice_state, stack_states,
                                verify_pairs)
from repro.stream.ingest import (StreamConfig, StreamingMAD,  # noqa: F401
                                 WaveformRing)
from repro.stream.telemetry import (METRICS_SCHEMA,  # noqa: F401
                                    StreamTelemetry, metrics_snapshot)
