"""Continuous ingestion: arbitrary chunks → fixed fingerprint blocks.

``WaveformRing`` buffers incoming samples and emits *blocks* — fixed-size
windows that each yield exactly ``block_fingerprints`` fingerprints — while
retaining the STFT/spectral-image halo (``FingerprintConfig.halo_samples``)
across block boundaries. Because block starts are aligned to the
fingerprint lag, block fingerprints are **sample-exact** equal to the
offline ones computed over the whole trace: the streaming path changes
*when* work happens, not *what* is computed.

``StreamingMAD`` replaces the paper's two-pass §5.2 median/MAD structure
with a uniform reservoir over coefficient rows: every row ever seen has
equal probability of being in the sample, so the statistics converge to
the offline sampled statistics without a second pass over history.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.fingerprint import FingerprintConfig
from repro.stream.index import StreamIndexConfig


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Streaming-side knobs (capacity/cadence; detection semantics stay in
    LSHConfig/AlignConfig so offline and streaming share one meaning).

    ``window_fingerprints`` > 0 turns the detector into a sliding-window
    service: index entries older than the newest id minus the window are
    expired inside the jitted step, so a fingerprint only ever pairs with
    partners at most one window behind it. ``filter_window_fingerprints``
    > 0 additionally replaces the finalize-only occurrence filter with a
    rolling per-window filter + clustering pass, bounding the host-side
    pair/triplet state by the window size (requires a sliding window; see
    ``engine.RollingPairFilter``). Both default to 0: the unbounded
    accumulate-then-finalize path with exact offline parity.

    One fingerprint spans ``FingerprintConfig.lag_samples / fs`` seconds of
    stream time (2 s at paper settings), so a window of N days is
    ``N * 86400 * fs / lag_samples`` fingerprints.

    ``fused`` selects the single-dispatch hot path (``stream/fused.py``):
    ring advance + fingerprint chain + hashing + expire/insert/query as
    one donated-buffer jitted step; False keeps the PR-1/2 multi-call
    chain (the parity reference and unfused benchmark baseline).
    ``pooled`` steps all stations of a multi-station detector through one
    vmapped executable instead of S sequential engines (requires
    ``fused``). ``sharded`` additionally splits the pooled station axis
    over a device mesh (``dist.station_mesh``) when more than one device
    is visible — the fused step then runs fully-manual ``shard_map``
    with S/D stations per device and zero cross-station collectives. On
    a single device the knob is inert (the capability probe returns no
    mesh and the pool stays the plain vmap), so the default is on:
    detection output is bit-identical either way.

    ``stats_warmup_blocks == 0`` defers the MAD-statistics freeze to
    ``flush()``: every block stays buffered and the reservoir absorbs the
    whole stream before the freeze binarizes the buffered warmup
    fingerprints with the matured statistics — the re-binarize-after-
    freeze hook that closes the self-computed-stats recall gap on finite
    traces (host memory is then O(stream); use a positive warmup for
    unbounded ingestion).

    Data-quality knobs (ISSUE 4; all default off = the clean-stream
    semantics, bit-identical to the pre-quality path):

    * ``reorder_horizon_samples`` — block emission is held back this many
      samples so late/out-of-order chunks (within the horizon) can still
      be spliced into place, duplicated chunks dropped deterministically
      (first writer wins), and gaps healed before their block is
      committed. 0 = emit as soon as a block completes (in-order only).
      Gap masking itself (NaN samples / offset jumps → suppressed
      fingerprints) is always on — it needs no knob because it is an
      exact no-op on contiguous finite input.
    * ``max_gap_samples`` — the largest forward offset jump accepted as a
      genuine gap. A chunk arriving further ahead is a corrupted or
      unit-mismatched timestamp, not telemetry loss: accepting it would
      allocate the whole bogus span as sentinel fill (a single bad
      header could demand gigabytes) and burn thousands of all-invalid
      dispatches. Such chunks are rejected and counted instead
      (``quality["rejected_chunks"]``). 0 = unbounded (trusted feeds).
    * ``saturation_limit`` — buckets whose insert-traffic counter exceeds
      this are quarantined from pair emission inside the jitted step (the
      paper's repeating-glitch mega-bucket fix, applied structurally).
      Size it well above any healthy bucket's traffic over a detection
      window so clean data never trips it. With a sliding window
      (``window_fingerprints`` > 0) the counter is *window-relative*: it
      halves every window of stream time inside the already-traced
      ``expire``, so it tracks recent pressure and quarantined buckets
      recover once a glitching channel is repaired — safe to leave on
      for unbounded multi-month streams. Without a window the counter is
      lifetime traffic (the pre-window behavior). 0 = off.
    * ``dup_window_fingerprints`` — sample-exact repeated-segment
      detector: every fingerprint's raw sample window is hashed and
      compared against the previous N fingerprints' hashes; an exact
      repeat (telemetry-duplicated data block, flat-lined channel) is
      suppressed *before* the dispatch — never inserted, never queried.
      Repeating earthquakes are never sample-exact (independent noise),
      so this guard has zero false positives on real signal and clean
      bit-parity is structural, not tuned. 0 = off.
    * ``dup_sig_tables`` — the aggressive in-dispatch variant: a
      fingerprint whose *signature* collides with a resident (or earlier
      same-batch) fingerprint in at least this many of the t tables at
      distance ≥ ``min_dt`` is treated as a near-exact repeat. Strong
      repeating earthquakes can legitimately collide in many (sometimes
      all) tables, so this knob trades recall of the strongest repeaters
      for glitch suppression — size it above your workload's strongest
      legitimate repeat, or leave it 0 and let the saturation guard
      handle glitch trains. 0 = off.
    * ``occ_limit`` — the in-dispatch §6.5 occurrence limiter (ISSUE 5):
      per-fingerprint emitted-partner counts are carried in the index
      state (a ring of ``index.occ_slots`` slots keyed by id, recycled as
      the window slides) and pairs touching a fingerprint past the limit
      are dropped inside the already-traced step. This is what suppresses
      *additive* (non-sample-exact) glitch trains, which ride the live
      noise floor and so evade the duplicate guard; the host-side
      ``occurrence_filter`` at finalize remains the bit-exact §6.5
      reference. Size it above the densest legitimate repeater's partner
      count within a window (clean data then never trips it — bit-exact
      parity with the limiter off is pinned). Requires
      ``index.occ_slots`` ≥ the id span pairs can reach back over
      (the sliding window, or the whole stream when unwindowed). 0 = off.

    Emission-path knobs (ISSUE 8; both off = the dense t * N * cap
    emission, bit-identical program):

    * ``max_pairs_per_block`` — in-dispatch emission compaction: the
      dense pair stream is sorted by validity inside the traced step and
      only a bounded ``(max_pairs_per_block,)`` buffer crosses the
      device→host boundary (at paper scale that is ~205k dense slots →
      a few thousand real pairs per station per block). Valid pairs past
      the bound drop deterministically (lexicographically smallest
      (idx1, idx2) kept) and are counted in the ``overflow_pairs`` QC
      field — size it so overflow stays 0 on healthy data and the pair
      set is bit-identical to the dense path (pinned). 0 = dense.
    * ``verify_jaccard`` — the exact-verification epilogue: the step
      keeps a ring of bit-packed fingerprints in the index state
      (``index.pk_slots`` rows — must span the sliding window, or the
      stream when unwindowed; ``index.pk_words`` = fp_dim // 32, derived
      by the engine when 0) and scores every compacted candidate with
      exact Jaccard in the same dispatch, emitting
      (idx1, idx2, hash_matches, jaccard). Requires
      ``max_pairs_per_block`` > 0 (the dense stream is never verified).
    * ``verify_pallas`` — route the verify scoring through the Pallas
      ``jaccard_popcount`` kernel (interpret-parity-tested on CPU; the
      real win is on TPU where the whole fingerprint→hash→bucket→query→
      verify→compact chain is one fused device program).
    * ``verify_min_jaccard`` — in-dispatch threshold on the *verified*
      similarity: compacted pairs whose exact Jaccard falls below this
      are dropped before emission, so downstream thresholds act on true
      similarity instead of the hash-match proxy. 0.0 = keep all.
    """

    block_fingerprints: int = 64   # fingerprints per jitted step
    index: StreamIndexConfig = StreamIndexConfig()  # resident index shape
    stats_warmup_blocks: int = 2   # blocks buffered before MAD stats freeze
                                   # (0 = freeze only at flush, see above)
    reservoir_rows: int = 2048     # coefficient rows kept for median/MAD
    seed: int = 0
    window_fingerprints: int = 0   # sliding detection window (0 = keep all)
    filter_window_fingerprints: int = 0  # rolling occurrence filter window
    fused: bool = True             # single-dispatch fused hot path
    pooled: bool = True            # vmapped station pool when multi-station
    sharded: bool = True           # mesh-shard the pool when >1 device
    reorder_horizon_samples: int = 0  # late-chunk splice window (0 = none)
    max_gap_samples: int = 0       # largest offset jump gap-filled (0 = ∞)
    saturation_limit: int = 0      # quarantine buckets past this traffic
    dup_window_fingerprints: int = 0  # sample-exact repeat horizon
    dup_sig_tables: int = 0        # signature matches that flag a repeat
    occ_limit: int = 0             # in-dispatch §6.5 partner-count limiter
    max_pairs_per_block: int = 0   # emission compaction bound (0 = dense)
    verify_jaccard: bool = False   # exact-Jaccard verify epilogue
    verify_pallas: bool = False    # verify through the Pallas kernel
    verify_min_jaccard: float = 0.0  # in-dispatch true-similarity floor
    telemetry: bool = True         # in-dispatch step counters (ISSUE 6):
                                   # the fused step also returns pairs-
                                   # emitted / masked / collision counts,
                                   # folded into the same traced program
                                   # (no extra dispatch; detections are
                                   # bit-identical on or off — pinned).
                                   # False compiles the counters away.

    def __post_init__(self):
        if self.stats_warmup_blocks < 0:
            raise ValueError(
                f"stats_warmup_blocks must be >= 0 (0 = freeze at flush), "
                f"got {self.stats_warmup_blocks}")
        if min(self.reorder_horizon_samples, self.max_gap_samples,
               self.saturation_limit, self.dup_window_fingerprints,
               self.dup_sig_tables, self.occ_limit) < 0:
            raise ValueError(
                "data-quality knobs (reorder_horizon_samples, "
                "max_gap_samples, saturation_limit, "
                "dup_window_fingerprints, dup_sig_tables, occ_limit) "
                "must be >= 0 (0 = off)")
        if self.occ_limit > 0 and self.index.occ_slots <= 0:
            raise ValueError(
                "occ_limit needs a partner-count ring: set "
                "StreamIndexConfig.occ_slots to at least the sliding "
                "window (window_fingerprints), or the expected stream "
                "length when unwindowed")
        if self.occ_limit > 0 and 0 < self.index.occ_slots \
                < self.window_fingerprints:
            # a ring narrower than the window makes two live in-window
            # fingerprints share a slot: the newcomer's slot reset zeroes
            # a still-active partner count (under-suppression) and merged
            # counts can push clean fingerprints past the limit (silent
            # clean-pair drops) — reject rather than degrade silently
            raise ValueError(
                f"occ_slots={self.index.occ_slots} is narrower than the "
                f"sliding window ({self.window_fingerprints}): every id a "
                f"pair can reach back to needs its own partner-count slot")
        if self.pooled and not self.fused:
            raise ValueError(
                "pooled station stepping runs through the fused chunk step;"
                " set fused=True (or pooled=False for the sequential path)")
        # ValueError (not assert): these are reachable from CLI flags and
        # must hold under `python -O` too — a filter window without an
        # expire window would let partners reach arbitrarily far back and
        # silently break the rolling filter's rebased id space.
        if self.filter_window_fingerprints > 0 \
                and self.window_fingerprints <= 0:
            raise ValueError(
                "rolling occurrence filter needs a sliding window "
                "(window_fingerprints > 0): the expire window is what "
                "bounds how far back partners reach")
        if 0 < self.window_fingerprints < self.block_fingerprints:
            raise ValueError(
                f"window_fingerprints={self.window_fingerprints} smaller "
                f"than one block ({self.block_fingerprints}) would expire "
                f"the block being inserted")
        if self.max_pairs_per_block < 0:
            raise ValueError(
                f"max_pairs_per_block must be >= 0 (0 = dense emission), "
                f"got {self.max_pairs_per_block}")
        if self.verify_jaccard and self.max_pairs_per_block <= 0:
            raise ValueError(
                "verify_jaccard scores the *compacted* emission; set "
                "max_pairs_per_block > 0 (the dense t*N*cap stream is "
                "never verified)")
        if self.verify_jaccard and self.index.pk_slots <= 0:
            raise ValueError(
                "verify_jaccard needs a packed-fingerprint ring: set "
                "StreamIndexConfig.pk_slots to at least the sliding "
                "window (window_fingerprints), or the expected stream "
                "length when unwindowed")
        if self.verify_jaccard and 0 < self.index.pk_slots \
                < self.window_fingerprints:
            # a ring narrower than the window makes two live in-window
            # fingerprints share a packed row: the newcomer overwrites a
            # still-pairable partner's bits and the verify scores garbage
            raise ValueError(
                f"pk_slots={self.index.pk_slots} is narrower than the "
                f"sliding window ({self.window_fingerprints}): every id a "
                f"pair can reach back to needs its own packed row")
        if self.verify_pallas and not self.verify_jaccard:
            raise ValueError(
                "verify_pallas selects the kernel for the verify "
                "epilogue; it needs verify_jaccard=True")
        if not 0.0 <= self.verify_min_jaccard <= 1.0:
            raise ValueError(
                f"verify_min_jaccard must be in [0, 1], got "
                f"{self.verify_min_jaccard}")
        if self.verify_min_jaccard > 0.0 and not self.verify_jaccard:
            raise ValueError(
                "verify_min_jaccard thresholds the verified similarity; "
                "it needs verify_jaccard=True")

    @property
    def verify_code(self) -> int:
        """Static verify selector for the fused step: 0 = off, 1 = jnp
        oracle, 2 = Pallas kernel."""
        if not self.verify_jaccard:
            return 0
        return 2 if self.verify_pallas else 1

    def effective_index(self, fp_dim: int) -> StreamIndexConfig:
        """Index config with the verify ring's row width resolved.

        ``pk_words == 0`` means "derive from the fingerprint config":
        packed fingerprints are ``fp_dim // 32`` uint32 words
        (``utils.pack_bits``; fp_dim is a multiple of 32 by
        construction). Every engine that materializes an ``IndexState``
        from a ``StreamConfig`` goes through here so snapshots, the
        batch driver and the live service agree on the ring shape.
        """
        icfg = self.index
        if self.verify_jaccard and icfg.pk_words == 0:
            icfg = dataclasses.replace(icfg, pk_words=fp_dim // 32)
        return icfg


class WaveformRing:
    """Host-side sample ring for one station, gap/reorder aware.

    push() accepts chunks of any length and returns zero or more
    fixed-size blocks; a ``halo_samples`` tail is retained so adjacent
    blocks overlap exactly like the offline sliding windows.

    Real telemetry is not contiguous, so every sample carries a validity
    bit alongside its value:

    * NaN samples in a chunk are "never arrived": stored as 0.0, marked
      invalid.
    * ``push(chunk, offset)`` places the chunk at an absolute sample
      offset. A jump past the contiguous frontier opens a *gap* — the
      missing span is sentinel-filled (0.0) and marked invalid, keeping
      the fingerprint id grid aligned to absolute time.
    * An offset behind the frontier is a late / out-of-order / duplicated
      chunk. Samples still inside the un-emitted buffer are reconciled
      deterministically: invalid positions are healed (spliced), already-
      valid positions are dropped first-writer-wins (re-sent duplicates
      are no-ops). Samples behind the buffer are dropped and counted.
      ``reorder_horizon`` holds block emission back that many samples so
      the buffer keeps a splice window open.

    Emitted blocks are ``(base_fingerprint_id, block, valid_mask)`` where
    ``valid_mask`` is None for fully-valid blocks (the clean hot path) or
    a per-fingerprint bool mask: a fingerprint is valid iff its whole
    analysis window holds valid samples. ``quality`` counts every
    reconciliation decision for monitoring.
    """

    def __init__(self, fcfg: FingerprintConfig, block_fingerprints: int,
                 reorder_horizon: int = 0, max_gap: int = 0):
        assert block_fingerprints >= 1
        assert reorder_horizon >= 0 and max_gap >= 0
        self.fcfg = fcfg
        self.block_fp = block_fingerprints
        self.block_samples = fcfg.block_samples(block_fingerprints)
        self.advance = block_fingerprints * fcfg.lag_samples
        self.horizon = int(reorder_horizon)
        self.max_gap = int(max_gap)
        self.buf = np.zeros(0, np.float32)
        self.vbuf = np.zeros(0, bool)   # per-sample validity
        self.start = 0            # absolute offset of buf[0]
        self.next_fp = 0          # global index of the next fingerprint
        self.samples_in = 0
        self.quality = {
            "gaps": 0, "gap_samples": 0, "missing_samples": 0,
            "late_spliced_samples": 0, "late_dropped_samples": 0,
            "duplicate_samples": 0, "rejected_chunks": 0,
            "rejected_samples": 0,
        }

    @property
    def frontier(self) -> int:
        """Absolute offset one past the last buffered sample."""
        return self.start + self.buf.size

    def push(self, chunk: np.ndarray, offset: int | None = None
             ) -> list[tuple[int, np.ndarray, np.ndarray | None]]:
        """Place samples at ``offset`` (default: the contiguous frontier);
        emit ready (base_fingerprint_id, block, valid_mask) tuples."""
        chunk = np.asarray(chunk, np.float32).reshape(-1)
        self.samples_in += chunk.size
        off = self.frontier if offset is None else int(offset)
        if self.max_gap > 0 and off - self.frontier > self.max_gap:
            # corrupted / unit-mismatched timestamp, not telemetry loss:
            # gap-filling the bogus span could demand unbounded memory
            self.quality["rejected_chunks"] += 1
            self.quality["rejected_samples"] += chunk.size
            return []
        finite = np.isfinite(chunk)
        if not finite.all():
            chunk = np.where(finite, chunk, np.float32(0.0))
        if off > self.frontier:          # gap: sentinel-fill to the offset
            fill = off - self.frontier
            self.quality["gaps"] += 1
            self.quality["gap_samples"] += fill
            self.buf = np.concatenate([self.buf,
                                       np.zeros(fill, np.float32)])
            self.vbuf = np.concatenate([self.vbuf, np.zeros(fill, bool)])
            off = self.frontier
        # the last emitted block's content is immutable: its tail is also
        # the device-resident halo of the fused path, so healing those
        # samples host-side would silently diverge from the halo already
        # committed on device. Late data below the committed frontier is
        # dropped (the committed region's validity mask stays authoritative).
        committed = self.start + (self.fcfg.halo_samples
                                  if self.next_fp > 0 else 0)
        if off < committed:              # beyond the reorder horizon
            cut = min(committed - off, chunk.size)
            self.quality["late_dropped_samples"] += int(finite[:cut].sum())
            chunk, finite = chunk[cut:], finite[cut:]
            off = committed
        overlap = min(self.frontier - off, chunk.size)
        if overlap > 0:                  # splice into the buffered region
            lo = off - self.start
            held = self.vbuf[lo:lo + overlap]
            heal = finite[:overlap] & ~held
            dup = finite[:overlap] & held
            self.buf[lo:lo + overlap][heal] = chunk[:overlap][heal]
            held[heal] = True
            self.quality["late_spliced_samples"] += int(heal.sum())
            self.quality["duplicate_samples"] += int(dup.sum())
            chunk, finite = chunk[overlap:], finite[overlap:]
        if chunk.size:                   # in-order tail append
            # count missing telemetry only in newly-accepted territory:
            # NaNs in re-delivered / late-dropped spans were either never
            # accepted or already accounted (gap fill)
            self.quality["missing_samples"] += int((~finite).sum())
            self.buf = np.concatenate([self.buf, chunk])
            self.vbuf = np.concatenate([self.vbuf, finite])
        out = []
        while self.buf.size >= self.block_samples + self.horizon:
            out.append(self._emit_block())
        return out

    def _fp_mask(self, v: np.ndarray) -> np.ndarray | None:
        """Per-fingerprint validity of a framed sample-validity span
        (None = all valid): fp i is valid iff v[i*lag : i*lag + w].all()."""
        if v.all():
            return None
        w, lag = self.fcfg.window_samples, self.fcfg.lag_samples
        csum = np.concatenate([[0], np.cumsum(~v)])
        starts = np.arange(self.block_fp) * lag
        return (csum[starts + w] - csum[starts]) == 0

    def _emit_block(self) -> tuple[int, np.ndarray, np.ndarray | None]:
        item = (self.next_fp, self.buf[:self.block_samples].copy(),
                self._fp_mask(self.vbuf[:self.block_samples]))
        self.buf = self.buf[self.advance:]
        self.vbuf = self.vbuf[self.advance:]
        self.start += self.advance
        self.next_fp += self.block_fp
        return item

    def flush_ready(self) -> list[tuple[int, np.ndarray,
                                        np.ndarray | None]]:
        """Emit complete blocks held back only by the reorder horizon
        (flush boundary: late chunks for them can no longer splice)."""
        out = []
        while self.buf.size >= self.block_samples:
            out.append(self._emit_block())
        return out

    def flush_partial(self) -> tuple[int, np.ndarray, np.ndarray] | None:
        """Emit the tail as a zero-padded block with a validity mask.

        Returns (base_fingerprint_id, block, valid_mask) covering however
        many whole fingerprints the buffer still holds, or None if fewer
        than one. The mask combines the tail cut (fingerprints whose
        window would run past the buffered samples) with gap validity.
        Consumes those fingerprints (the halo stays), so ingestion may
        continue afterwards — flush is a checkpoint, not a terminator.
        Call ``flush_ready()`` first when a reorder horizon is set.
        """
        w, lag = self.fcfg.window_samples, self.fcfg.lag_samples
        if self.buf.size < w:
            return None
        assert self.buf.size < self.block_samples, \
            "drain flush_ready() before flush_partial()"
        n_valid = (self.buf.size - w) // lag + 1
        block = np.zeros(self.block_samples, np.float32)
        block[: self.buf.size] = self.buf
        mask = np.arange(self.block_fp) < n_valid
        vfull = np.zeros(self.block_samples, bool)
        vfull[: self.buf.size] = self.vbuf
        gap_mask = self._fp_mask(vfull)
        if gap_mask is not None:
            mask = mask & gap_mask
        out = (self.next_fp, block, mask)
        self.buf = self.buf[n_valid * lag:]
        self.vbuf = self.vbuf[n_valid * lag:]
        self.start += n_valid * lag
        self.next_fp += n_valid
        return out

    @property
    def pending_samples(self) -> int:
        return int(self.buf.size)

    def snapshot(self) -> tuple[dict, dict]:
        """(arrays, json-able scalars) capturing the ring exactly."""
        return ({"buf": self.buf.copy(), "vbuf": self.vbuf.copy()},
                {"next_fp": self.next_fp, "samples_in": self.samples_in,
                 "quality": dict(self.quality)})

    def restore(self, arrays: dict, scalars: dict) -> None:
        self.buf = np.asarray(arrays["buf"], np.float32).reshape(-1).copy()
        if "vbuf" in arrays:
            self.vbuf = np.asarray(arrays["vbuf"], bool).reshape(-1).copy()
        else:                      # pre-quality snapshot: all samples valid
            self.vbuf = np.ones(self.buf.size, bool)
        assert self.vbuf.size == self.buf.size
        self.next_fp = int(scalars["next_fp"])
        self.samples_in = int(scalars["samples_in"])
        # start is not independent state: every consumption path advances
        # it in lockstep with next_fp (both by whole fingerprints)
        self.start = self.next_fp * self.fcfg.lag_samples
        self.quality.update(scalars.get("quality", {}))


class StreamingMAD:
    """Uniform reservoir of coefficient rows → running median/MAD (§5.2).

    Deterministic given the seed and arrival order; ``stats()`` matches
    ``fingerprint.mad_stats`` computed over a uniform row sample.
    """

    def __init__(self, n_rows: int, n_coeff: int, seed: int = 0):
        self.n_rows = n_rows
        self.rows = np.zeros((n_rows, n_coeff), np.float32)
        self.rng = np.random.default_rng(seed)
        self.seen = 0
        self.filled = 0

    def update(self, coeffs: np.ndarray) -> None:
        coeffs = np.asarray(coeffs, np.float32)
        for row in coeffs:
            self.seen += 1
            if self.filled < self.n_rows:
                self.rows[self.filled] = row
                self.filled += 1
            else:
                j = int(self.rng.integers(0, self.seen))
                if j < self.n_rows:
                    self.rows[j] = row

    def snapshot(self) -> tuple[dict, dict]:
        """(arrays, json-able scalars incl. PCG state) — exact restore."""
        return ({"rows": self.rows.copy()},
                {"seen": self.seen, "filled": self.filled,
                 "rng_state": self.rng.bit_generator.state})

    def restore(self, arrays: dict, scalars: dict) -> None:
        rows = np.asarray(arrays["rows"], np.float32)
        assert rows.shape == self.rows.shape, (rows.shape, self.rows.shape)
        self.rows = rows.copy()
        self.seen = int(scalars["seen"])
        self.filled = int(scalars["filled"])
        self.rng.bit_generator.state = scalars["rng_state"]

    def stats(self) -> tuple[np.ndarray, np.ndarray]:
        assert self.filled >= 2, "need ≥2 coefficient rows for MAD stats"
        sample = self.rows[: self.filled]
        med = np.median(sample, axis=0)
        mad = np.median(np.abs(sample - med[None, :]), axis=0)
        return med.astype(np.float32), mad.astype(np.float32)
