"""Continuous ingestion: arbitrary chunks → fixed fingerprint blocks.

``WaveformRing`` buffers incoming samples and emits *blocks* — fixed-size
windows that each yield exactly ``block_fingerprints`` fingerprints — while
retaining the STFT/spectral-image halo (``FingerprintConfig.halo_samples``)
across block boundaries. Because block starts are aligned to the
fingerprint lag, block fingerprints are **sample-exact** equal to the
offline ones computed over the whole trace: the streaming path changes
*when* work happens, not *what* is computed.

``StreamingMAD`` replaces the paper's two-pass §5.2 median/MAD structure
with a uniform reservoir over coefficient rows: every row ever seen has
equal probability of being in the sample, so the statistics converge to
the offline sampled statistics without a second pass over history.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.fingerprint import FingerprintConfig
from repro.stream.index import StreamIndexConfig


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Streaming-side knobs (capacity/cadence; detection semantics stay in
    LSHConfig/AlignConfig so offline and streaming share one meaning).

    ``window_fingerprints`` > 0 turns the detector into a sliding-window
    service: index entries older than the newest id minus the window are
    expired inside the jitted step, so a fingerprint only ever pairs with
    partners at most one window behind it. ``filter_window_fingerprints``
    > 0 additionally replaces the finalize-only occurrence filter with a
    rolling per-window filter + clustering pass, bounding the host-side
    pair/triplet state by the window size (requires a sliding window; see
    ``engine.RollingPairFilter``). Both default to 0: the unbounded
    accumulate-then-finalize path with exact offline parity.

    One fingerprint spans ``FingerprintConfig.lag_samples / fs`` seconds of
    stream time (2 s at paper settings), so a window of N days is
    ``N * 86400 * fs / lag_samples`` fingerprints.

    ``fused`` selects the single-dispatch hot path (``stream/fused.py``):
    ring advance + fingerprint chain + hashing + expire/insert/query as
    one donated-buffer jitted step; False keeps the PR-1/2 multi-call
    chain (the parity reference and unfused benchmark baseline).
    ``pooled`` steps all stations of a multi-station detector through one
    vmapped executable instead of S sequential engines (requires
    ``fused``).

    ``stats_warmup_blocks == 0`` defers the MAD-statistics freeze to
    ``flush()``: every block stays buffered and the reservoir absorbs the
    whole stream before the freeze binarizes the buffered warmup
    fingerprints with the matured statistics — the re-binarize-after-
    freeze hook that closes the self-computed-stats recall gap on finite
    traces (host memory is then O(stream); use a positive warmup for
    unbounded ingestion).
    """

    block_fingerprints: int = 64   # fingerprints per jitted step
    index: StreamIndexConfig = StreamIndexConfig()  # resident index shape
    stats_warmup_blocks: int = 2   # blocks buffered before MAD stats freeze
                                   # (0 = freeze only at flush, see above)
    reservoir_rows: int = 2048     # coefficient rows kept for median/MAD
    seed: int = 0
    window_fingerprints: int = 0   # sliding detection window (0 = keep all)
    filter_window_fingerprints: int = 0  # rolling occurrence filter window
    fused: bool = True             # single-dispatch fused hot path
    pooled: bool = True            # vmapped station pool when multi-station

    def __post_init__(self):
        if self.stats_warmup_blocks < 0:
            raise ValueError(
                f"stats_warmup_blocks must be >= 0 (0 = freeze at flush), "
                f"got {self.stats_warmup_blocks}")
        if self.pooled and not self.fused:
            raise ValueError(
                "pooled station stepping runs through the fused chunk step;"
                " set fused=True (or pooled=False for the sequential path)")
        # ValueError (not assert): these are reachable from CLI flags and
        # must hold under `python -O` too — a filter window without an
        # expire window would let partners reach arbitrarily far back and
        # silently break the rolling filter's rebased id space.
        if self.filter_window_fingerprints > 0 \
                and self.window_fingerprints <= 0:
            raise ValueError(
                "rolling occurrence filter needs a sliding window "
                "(window_fingerprints > 0): the expire window is what "
                "bounds how far back partners reach")
        if 0 < self.window_fingerprints < self.block_fingerprints:
            raise ValueError(
                f"window_fingerprints={self.window_fingerprints} smaller "
                f"than one block ({self.block_fingerprints}) would expire "
                f"the block being inserted")


class WaveformRing:
    """Host-side sample ring for one station.

    push() accepts chunks of any length and returns zero or more
    fixed-size blocks; a ``halo_samples`` tail is retained so adjacent
    blocks overlap exactly like the offline sliding windows.
    """

    def __init__(self, fcfg: FingerprintConfig, block_fingerprints: int):
        assert block_fingerprints >= 1
        self.fcfg = fcfg
        self.block_fp = block_fingerprints
        self.block_samples = fcfg.block_samples(block_fingerprints)
        self.advance = block_fingerprints * fcfg.lag_samples
        self.buf = np.zeros(0, np.float32)
        self.next_fp = 0          # global index of the next fingerprint
        self.samples_in = 0

    def push(self, chunk: np.ndarray) -> list[tuple[int, np.ndarray]]:
        """Append samples; emit ready (base_fingerprint_id, block) tuples."""
        chunk = np.asarray(chunk, np.float32).reshape(-1)
        self.samples_in += chunk.size
        self.buf = np.concatenate([self.buf, chunk])
        out = []
        while self.buf.size >= self.block_samples:
            out.append((self.next_fp, self.buf[:self.block_samples].copy()))
            self.buf = self.buf[self.advance:]
            self.next_fp += self.block_fp
        return out

    def flush_partial(self) -> tuple[int, np.ndarray, int] | None:
        """Emit the tail as a zero-padded block with a valid-count.

        Returns (base_fingerprint_id, block, n_valid) covering however many
        whole fingerprints the buffer still holds, or None if fewer than
        one. Consumes those fingerprints (the halo stays), so ingestion may
        continue afterwards — flush is a checkpoint, not a terminator.
        """
        w, lag = self.fcfg.window_samples, self.fcfg.lag_samples
        if self.buf.size < w:
            return None
        n_valid = (self.buf.size - w) // lag + 1
        block = np.zeros(self.block_samples, np.float32)
        block[: self.buf.size] = self.buf
        out = (self.next_fp, block, n_valid)
        self.buf = self.buf[n_valid * lag:]
        self.next_fp += n_valid
        return out

    @property
    def pending_samples(self) -> int:
        return int(self.buf.size)

    def snapshot(self) -> tuple[dict, dict]:
        """(arrays, json-able scalars) capturing the ring exactly."""
        return ({"buf": self.buf.copy()},
                {"next_fp": self.next_fp, "samples_in": self.samples_in})

    def restore(self, arrays: dict, scalars: dict) -> None:
        self.buf = np.asarray(arrays["buf"], np.float32).reshape(-1).copy()
        self.next_fp = int(scalars["next_fp"])
        self.samples_in = int(scalars["samples_in"])


class StreamingMAD:
    """Uniform reservoir of coefficient rows → running median/MAD (§5.2).

    Deterministic given the seed and arrival order; ``stats()`` matches
    ``fingerprint.mad_stats`` computed over a uniform row sample.
    """

    def __init__(self, n_rows: int, n_coeff: int, seed: int = 0):
        self.n_rows = n_rows
        self.rows = np.zeros((n_rows, n_coeff), np.float32)
        self.rng = np.random.default_rng(seed)
        self.seen = 0
        self.filled = 0

    def update(self, coeffs: np.ndarray) -> None:
        coeffs = np.asarray(coeffs, np.float32)
        for row in coeffs:
            self.seen += 1
            if self.filled < self.n_rows:
                self.rows[self.filled] = row
                self.filled += 1
            else:
                j = int(self.rng.integers(0, self.seen))
                if j < self.n_rows:
                    self.rows[j] = row

    def snapshot(self) -> tuple[dict, dict]:
        """(arrays, json-able scalars incl. PCG state) — exact restore."""
        return ({"rows": self.rows.copy()},
                {"seen": self.seen, "filled": self.filled,
                 "rng_state": self.rng.bit_generator.state})

    def restore(self, arrays: dict, scalars: dict) -> None:
        rows = np.asarray(arrays["rows"], np.float32)
        assert rows.shape == self.rows.shape, (rows.shape, self.rows.shape)
        self.rows = rows.copy()
        self.seen = int(scalars["seen"])
        self.filled = int(scalars["filled"])
        self.rng.bit_generator.state = scalars["rng_state"]

    def stats(self) -> tuple[np.ndarray, np.ndarray]:
        assert self.filled >= 2, "need ≥2 coefficient rows for MAD stats"
        sample = self.rows[: self.filled]
        med = np.median(sample, axis=0)
        mad = np.median(np.abs(sample - med[None, :]), axis=0)
        return med.astype(np.float32), mad.astype(np.float32)
