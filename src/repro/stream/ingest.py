"""Continuous ingestion: arbitrary chunks → fixed fingerprint blocks.

``WaveformRing`` buffers incoming samples and emits *blocks* — fixed-size
windows that each yield exactly ``block_fingerprints`` fingerprints — while
retaining the STFT/spectral-image halo (``FingerprintConfig.halo_samples``)
across block boundaries. Because block starts are aligned to the
fingerprint lag, block fingerprints are **sample-exact** equal to the
offline ones computed over the whole trace: the streaming path changes
*when* work happens, not *what* is computed.

``StreamingMAD`` replaces the paper's two-pass §5.2 median/MAD structure
with a uniform reservoir over coefficient rows: every row ever seen has
equal probability of being in the sample, so the statistics converge to
the offline sampled statistics without a second pass over history.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.fingerprint import FingerprintConfig
from repro.stream.index import StreamIndexConfig


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Streaming-side knobs (capacity/cadence; detection semantics stay in
    LSHConfig/AlignConfig so offline and streaming share one meaning)."""

    block_fingerprints: int = 64   # fingerprints per jitted step
    index: StreamIndexConfig = StreamIndexConfig()  # resident index shape
    stats_warmup_blocks: int = 2   # blocks buffered before MAD stats freeze
    reservoir_rows: int = 2048     # coefficient rows kept for median/MAD
    seed: int = 0


class WaveformRing:
    """Host-side sample ring for one station.

    push() accepts chunks of any length and returns zero or more
    fixed-size blocks; a ``halo_samples`` tail is retained so adjacent
    blocks overlap exactly like the offline sliding windows.
    """

    def __init__(self, fcfg: FingerprintConfig, block_fingerprints: int):
        assert block_fingerprints >= 1
        self.fcfg = fcfg
        self.block_fp = block_fingerprints
        self.block_samples = fcfg.block_samples(block_fingerprints)
        self.advance = block_fingerprints * fcfg.lag_samples
        self.buf = np.zeros(0, np.float32)
        self.next_fp = 0          # global index of the next fingerprint
        self.samples_in = 0

    def push(self, chunk: np.ndarray) -> list[tuple[int, np.ndarray]]:
        """Append samples; emit ready (base_fingerprint_id, block) tuples."""
        chunk = np.asarray(chunk, np.float32).reshape(-1)
        self.samples_in += chunk.size
        self.buf = np.concatenate([self.buf, chunk])
        out = []
        while self.buf.size >= self.block_samples:
            out.append((self.next_fp, self.buf[:self.block_samples].copy()))
            self.buf = self.buf[self.advance:]
            self.next_fp += self.block_fp
        return out

    def flush_partial(self) -> tuple[int, np.ndarray, int] | None:
        """Emit the tail as a zero-padded block with a valid-count.

        Returns (base_fingerprint_id, block, n_valid) covering however many
        whole fingerprints the buffer still holds, or None if fewer than
        one. Consumes those fingerprints (the halo stays), so ingestion may
        continue afterwards — flush is a checkpoint, not a terminator.
        """
        w, lag = self.fcfg.window_samples, self.fcfg.lag_samples
        if self.buf.size < w:
            return None
        n_valid = (self.buf.size - w) // lag + 1
        block = np.zeros(self.block_samples, np.float32)
        block[: self.buf.size] = self.buf
        out = (self.next_fp, block, n_valid)
        self.buf = self.buf[n_valid * lag:]
        self.next_fp += n_valid
        return out

    @property
    def pending_samples(self) -> int:
        return int(self.buf.size)


class StreamingMAD:
    """Uniform reservoir of coefficient rows → running median/MAD (§5.2).

    Deterministic given the seed and arrival order; ``stats()`` matches
    ``fingerprint.mad_stats`` computed over a uniform row sample.
    """

    def __init__(self, n_rows: int, n_coeff: int, seed: int = 0):
        self.n_rows = n_rows
        self.rows = np.zeros((n_rows, n_coeff), np.float32)
        self.rng = np.random.default_rng(seed)
        self.seen = 0
        self.filled = 0

    def update(self, coeffs: np.ndarray) -> None:
        coeffs = np.asarray(coeffs, np.float32)
        for row in coeffs:
            self.seen += 1
            if self.filled < self.n_rows:
                self.rows[self.filled] = row
                self.filled += 1
            else:
                j = int(self.rng.integers(0, self.seen))
                if j < self.n_rows:
                    self.rows[j] = row

    def stats(self) -> tuple[np.ndarray, np.ndarray]:
        assert self.filled >= 2, "need ≥2 coefficient rows for MAD stats"
        sample = self.rows[: self.filled]
        med = np.median(sample, axis=0)
        mad = np.median(np.abs(sample - med[None, :]), axis=0)
        return med.astype(np.float32), mad.astype(np.float32)
