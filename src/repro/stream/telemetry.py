"""Streaming telemetry hub (ISSUE 6 tentpole).

One :class:`StreamTelemetry` instance per detector (shared by all of its
stations) ties the observability primitives of ``repro.obsv`` to the
detection hot path:

* **in-dispatch counters** — every fused/unfused step returns the
  ``index.QC_FIELDS`` counter vector computed *inside* the already-traced
  program (no extra dispatch); ``record_step`` mirrors it into per-station
  registry counters (``step_<field>_total``). These are the device's own
  view of its guard activity, reconciled against the host-side quality
  dicts by the telemetry tests.
* **wall-time histograms** — chunk ingest wall, fused-dispatch wall, and
  host-tail wall land in log-bucketed histograms with per-station labels
  (the pooled dispatch is shared by all stations and is labeled
  ``station="pool"``).
* **StepWatchdog** — the training loop's straggler/hang watchdog
  (``train/watchdog.py``) wraps the streaming step; flagged steps
  increment ``straggler_steps_total`` and stay queryable via
  ``watchdog.events``.
* **span tracing** — a :class:`~repro.obsv.spans.SpanTracer` (JSONL +
  optional ``jax.profiler`` hook) is carried here so serving can turn it
  on with a flag; per-name totals feed ``metrics_snapshot``.
* **health surface** — ``heartbeat(det)`` builds the periodic liveness
  dict (real-time factor, throughput, drop-rate breakdown, quality
  counters) and ``prometheus(det)`` the text exposition, both consumed by
  ``serve_detect --metrics-every/--metrics-file``.
* **serving tier** (ISSUE 7) — ``ServeDetectEngine`` publishes through
  the same registry via the ``record_serve_*`` hooks: admission outcomes
  (``serve_requests_total{outcome=accepted|served|shed}``), per-tick
  queue-depth/slot-occupancy gauges, and the queue-wait/service/latency
  histogram split; ``serve_view()`` is the derived summary carried by
  the heartbeat and ``metrics_snapshot``.

The registry (and the watchdog's EMA) snapshot/restore alongside the
detector, so a restored service resumes its counters instead of zeroing
the dashboards.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.obsv.metrics import MetricsRegistry, merge_counts
from repro.obsv.spans import SpanTracer
from repro.stream.index import QC_FIELDS
from repro.train.watchdog import StepWatchdog, WatchdogConfig

METRICS_SCHEMA = "stream-metrics/v1"


class StreamTelemetry:
    def __init__(self, n_stations: int = 1, *,
                 registry: MetricsRegistry | None = None,
                 tracer: SpanTracer | None = None,
                 watchdog: StepWatchdog | None = None,
                 clock=time.perf_counter):
        self.n_stations = n_stations
        self.raw_walls: dict[str, list] | None = None
        self.registry = registry or MetricsRegistry()
        self.tracer = tracer or SpanTracer()
        if watchdog is None:
            watchdog = StepWatchdog(WatchdogConfig(hang_timeout_s=60.0),
                                    on_straggler=self._on_straggler)
        else:                       # chain the caller's policy with ours
            prev = watchdog.on_straggler
            watchdog.on_straggler = \
                lambda info: (prev(info), self._on_straggler(info))[0]
        self.watchdog = watchdog
        self.clock = clock
        self.t_start: float | None = None   # first chunk arrival
        # uptime carried over restores (wall time is not checkpointable)
        self._uptime_base = 0.0

    def _on_straggler(self, info: dict) -> None:
        self.registry.counter("straggler_steps_total").inc()

    # -- recording hooks (called from the engine hot path) -------------------

    def start(self) -> None:
        if self.t_start is None:
            self.t_start = self.clock()

    def uptime_s(self) -> float:
        if self.t_start is None:
            return self._uptime_base
        return self._uptime_base + (self.clock() - self.t_start)

    def record_chunk(self, station: int, wall_s: float, samples: int) -> None:
        s = str(station)
        self.registry.counter("chunks_total", station=s).inc()
        self.registry.counter("samples_total", station=s).inc(samples)
        self.registry.histogram("chunk_ingest_wall_seconds",
                                station=s).record(wall_s)

    def record_step(self, station: int, qc: np.ndarray) -> None:
        """Mirror one step's in-dispatch counter vector into the registry."""
        s = str(station)
        for name, v in zip(QC_FIELDS, np.asarray(qc).reshape(-1)):
            self.registry.counter(f"step_{name}_total", station=s).inc(int(v))

    def capture_raw_walls(self) -> dict[str, list]:
        """Opt in to exact wall-sample capture (bench_e2e).

        The registry histograms are log-bucketed — good enough for live
        health, but percentile() returns the bucket upper edge, which
        quantizes sub-2ms steps onto identical values. Benchmarks that
        publish percentiles call this once and compute them from the raw
        samples instead; the histogram-derived values stay available
        under separate keys for comparison."""
        if self.raw_walls is None:
            self.raw_walls = {"fused_step": [], "host_tail": []}
        return self.raw_walls

    def record_fused_wall(self, label: str, wall_s: float) -> None:
        if self.raw_walls is not None:
            self.raw_walls["fused_step"].append(wall_s)
        self.registry.histogram("fused_step_wall_seconds",
                                station=label).record(wall_s)

    def record_host_tail(self, station: int, wall_s: float) -> None:
        if self.raw_walls is not None:
            self.raw_walls["host_tail"].append(wall_s)
        self.registry.histogram("host_tail_wall_seconds",
                                station=str(station)).record(wall_s)

    # -- location-tier hooks (ISSUE 9) ---------------------------------------

    def record_locate(self, groups: int, located: int, rejected: int,
                      wall: float) -> None:
        """One migration-stack pass over associated groups: how many went
        in, how many located detections came out, how many fell to the
        moveout-consistency gate, and the stack's wall time."""
        self.registry.counter("locate_passes_total").inc()
        self.registry.counter("locate_groups_total").inc(int(groups))
        self.registry.counter("located_detections_total").inc(int(located))
        self.registry.counter("moveout_rejected_total").inc(int(rejected))
        self.registry.histogram("locate_stack_wall_seconds").record(wall)

    def locate_view(self) -> dict:
        """Location-tier summary: stack passes, group flow, and the
        moveout-rejection count. All-zero without a locate tier."""
        reg = self.registry
        h = reg.histogram_merged("locate_stack_wall_seconds")
        return {
            "passes": int(reg.total("locate_passes_total")),
            "groups": int(reg.total("locate_groups_total")),
            "located": int(reg.total("located_detections_total")),
            "moveout_rejected": int(reg.total("moveout_rejected_total")),
            "stack_wall": {"count": h.count,
                           "p50_ms": round(h.percentile(0.50) * 1e3, 3),
                           "p95_ms": round(h.percentile(0.95) * 1e3, 3)},
        }

    # -- serving-tier hooks (called from ServeDetectEngine) ------------------

    def record_serve_admission(self, accepted: bool) -> None:
        """One admission decision: queued, or load-shed at the bound."""
        outcome = "accepted" if accepted else "shed"
        self.registry.counter("serve_requests_total", outcome=outcome).inc()
        if not accepted:
            self.registry.counter("serve_shed_total").inc()

    def record_serve_tick(self, active_slots: int, queue_depth: int) -> None:
        """One service tick: occupancy + backlog gauges, dispatch count
        (idle ticks — zero active slots — don't dispatch)."""
        self.registry.counter("serve_ticks_total").inc()
        if active_slots:
            self.registry.counter("serve_dispatches_total").inc()
            self.registry.counter("serve_slot_ticks_total").inc(active_slots)
        self.registry.gauge("serve_active_slots").set(active_slots)
        self.registry.gauge("serve_queue_depth").set(queue_depth)

    def record_serve_done(self, queue_wait_s: float, service_s: float,
                          latency_s: float) -> None:
        """One served request's arrival-time accounting: where the
        latency went (admission-queue wait vs. in-slot service)."""
        self.registry.counter("serve_requests_total", outcome="served").inc()
        self.registry.histogram("serve_queue_wait_seconds").record(
            queue_wait_s)
        self.registry.histogram("serve_service_seconds").record(service_s)
        self.registry.histogram("serve_latency_seconds").record(latency_s)

    def record_serve_refresh(self) -> None:
        self.registry.counter("serve_state_refreshes_total").inc()

    # -- derived views -------------------------------------------------------

    def drop_breakdown(self) -> dict:
        """Device-side step counters summed over stations (QC layout)."""
        return {name: int(self.registry.total(f"step_{name}_total"))
                for name in QC_FIELDS}

    def drop_rates(self) -> dict:
        """Per-guard drop rates relative to the raw pair/collision flow."""
        d = self.drop_breakdown()
        emitted = d["pairs_emitted"]
        denom = max(emitted + d["limited_pairs"], 1)
        raw = max(d["raw_collisions"], 1)
        return {
            "limited_pairs": round(d["limited_pairs"] / denom, 6),
            "quarantined_collisions":
                round(d["quarantined_collisions"] / raw, 6),
            "masked_fingerprints": round(
                d["masked_fingerprints"]
                / max(d["masked_fingerprints"] + emitted, 1), 6),
        }

    def serve_view(self) -> dict:
        """Serving-tier summary from the registry: admission outcomes,
        tick/dispatch counts, live occupancy gauges, and the (bucketed)
        latency split. All-zero when no serving engine shares this hub.
        """
        reg = self.registry

        def hist_ms(name):
            h = reg.histogram_merged(name)
            return {"count": h.count,
                    "p50_ms": round(h.percentile(0.50) * 1e3, 3),
                    "p95_ms": round(h.percentile(0.95) * 1e3, 3)}

        def tot(name, **labels):
            if labels:
                return int(reg.counter(name, **labels).value)
            return int(reg.total(name))

        return {
            "accepted": tot("serve_requests_total", outcome="accepted"),
            "served": tot("serve_requests_total", outcome="served"),
            "shed": tot("serve_requests_total", outcome="shed"),
            "ticks": tot("serve_ticks_total"),
            "dispatches": tot("serve_dispatches_total"),
            "slot_ticks": tot("serve_slot_ticks_total"),
            "refreshes": tot("serve_state_refreshes_total"),
            "queue_depth": int(reg.gauge("serve_queue_depth").value),
            "active_slots": int(reg.gauge("serve_active_slots").value),
            "latency": hist_ms("serve_latency_seconds"),
            "queue_wait": hist_ms("serve_queue_wait_seconds"),
            "service": hist_ms("serve_service_seconds"),
        }

    def stream_seconds(self, det) -> float:
        """Absolute-timeline seconds the detector has processed (the
        network ingests in lockstep — any station's sample count works)."""
        fs = det.cfg.fingerprint.fs
        if not det.stations:
            return 0.0
        return min(st.stats.samples for st in det.stations) / fs

    def real_time_factor(self, det) -> float:
        """Processed stream seconds per wall second since the first chunk
        (> 1 keeps up with real time; < 1 falls behind)."""
        wall = self.uptime_s()
        return self.stream_seconds(det) / max(wall, 1e-9)

    def heartbeat(self, det) -> dict:
        """The periodic liveness record ``serve_detect`` prints."""
        chunks = int(self.registry.total("chunks_total"))
        wall = self.uptime_s()
        return {
            "uptime_s": round(wall, 3),
            "stream_s": round(self.stream_seconds(det), 3),
            "rtf": round(self.real_time_factor(det), 3),
            "chunks": chunks,
            "pairs": int(self.registry.total("step_pairs_emitted_total")),
            "fp_per_s": [
                round(st.stats.fingerprints / max(wall, 1e-9), 1)
                for st in det.stations],
            "drop_rates": self.drop_rates(),
            "quality": det.quality_summary(),
            "serve": self.serve_view(),
            "stragglers": int(self.registry.total("straggler_steps_total")),
        }

    def heartbeat_line(self, det) -> str:
        return "HEARTBEAT " + json.dumps(self.heartbeat(det))

    def prometheus(self, det=None) -> str:
        """Text exposition of the registry, with point-in-time gauges
        (host_state_rows, rtf) and the host-side quality counters synced
        in first so the scrape is self-contained."""
        if det is not None:
            for i, st in enumerate(det.stations):
                self.registry.gauge("host_state_rows",
                                    station=str(i)).set(st.host_state_rows())
                for k, v in st.quality_summary().items():
                    self.registry.counter(f"quality_{k}_total",
                                          station=str(i)).set_total(int(v))
            self.registry.gauge("real_time_factor").set(
                self.real_time_factor(det))
            self.registry.gauge("uptime_seconds").set(self.uptime_s())
        return self.registry.render()

    def write_prometheus(self, path: str, det=None) -> None:
        tmp = str(path) + ".tmp"
        with open(tmp, "w") as fh:
            fh.write(self.prometheus(det))
        os.replace(tmp, path)

    # -- persistence ---------------------------------------------------------

    def snapshot(self) -> dict:
        return {
            "schema": "stream-telemetry/v1",
            "registry": self.registry.snapshot(),
            "uptime_s": self.uptime_s(),
            "watchdog": {"ema": self.watchdog.ema, "n": self.watchdog.n},
        }

    def restore(self, snap: dict) -> None:
        self.registry.restore(snap["registry"])
        self._uptime_base = float(snap.get("uptime_s", 0.0))
        self.t_start = None
        wd = snap.get("watchdog", {})
        self.watchdog.ema = wd.get("ema")
        self.watchdog.n = int(wd.get("n", 0))


def quality_view(ring_quality: dict, qc: dict) -> dict:
    """One station's quality summary: ingest reconciliation counters +
    in-dispatch guard counters, merged on the single shared aggregation
    path (``merge_counts``). Key set is the stable public contract."""
    return merge_counts([ring_quality, qc])


def metrics_snapshot(det) -> dict:
    """The single structured metrics view of a detector.

    Consumed by ``bench_stream`` / ``bench_e2e`` (the ``metrics`` section
    of their JSON artifacts), the examples, ``serve_detect``, and the
    tier-1 schema test — one shape for every dashboard.
    """
    tel = det.telemetry
    reg = tel.registry
    stream = merge_counts([st.stats.summary() for st in det.stations])
    # wall stats don't sum meaningfully across lockstep stations; report
    # the slowest station's view plus merged histograms below
    for k in ("wall_s", "chunk_ms_p50", "chunk_ms_p95", "chunks_per_s",
              "samples_per_s"):
        stream[k] = max(st.stats.summary()[k] for st in det.stations)
    return {
        "schema": METRICS_SCHEMA,
        "stations": len(det.stations),
        "uptime_s": round(tel.uptime_s(), 3),
        "stream_s": round(tel.stream_seconds(det), 3),
        "rtf": round(tel.real_time_factor(det), 3),
        "stream": stream,
        "per_station": [
            {"station": i, **st.stats.summary(),
             "host_state_rows": st.host_state_rows(),
             "quality": st.quality_summary()}
            for i, st in enumerate(det.stations)],
        "drops": tel.drop_breakdown(),
        "drop_rates": tel.drop_rates(),
        "quality": det.quality_summary(),
        "histograms": {
            name: reg.histogram_merged(name).summary()
            for name in ("chunk_ingest_wall_seconds",
                         "fused_step_wall_seconds",
                         "host_tail_wall_seconds",
                         "serve_latency_seconds",
                         "serve_queue_wait_seconds",
                         "locate_stack_wall_seconds")},
        "serve": tel.serve_view(),
        "locate": tel.locate_view(),
        "spans": tel.tracer.summary(),
        "watchdog": {"steps": tel.watchdog.n,
                     "stragglers": len(tel.watchdog.events)},
    }
