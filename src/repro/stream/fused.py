"""Single-dispatch streaming hot path: fused chunk step + station pool.

The PR-1/2 hot path ran each per-block stage as its own jitted call —
``block_coeffs`` (STFT → band cut → Haar), then ``stream_step`` (binarize →
sign → expire → insert → query) — with the ring advance and all staging on
the host in between. Here the whole chain is **one** ``jax.jit`` entry with
``donate_argnums`` on the full device state:

  ``FusedState`` = index tables + ring halo + frozen MAD statistics.

``step_advance`` is the steady-state entry: its input is only the *new*
samples of the next block (``block_fingerprints * lag_samples`` of them);
the overlapping head — the STFT halo — is the ``halo`` buffer retained on
device from the previous step, so the WaveformRing advance is part of the
traced program, not a host copy. ``step_block`` is the re-seeding entry
(first block after a freeze, restore, or masked flush tail): it takes a
whole framed block plus a fingerprint-valid mask and leaves the halo
primed for subsequent advance steps.

Because every buffer of ``FusedState`` is donated, chunk N+1 writes into
chunk N's memory: steady state runs with zero per-chunk HBM allocation and
exactly one dispatch (the retracing/donation guards in
``tests/test_stream.py`` pin both properties).

``pool_step_advance`` / ``pool_step_block`` are the same two entries with
every state leaf carrying a leading station axis, stepped via ``vmap``:
one executable serves S stations (the ISSUE-3 index pool) instead of S
sequential single-station engines each paying their own dispatch. When a
fingerprint-sharded mesh is available the pool axis is the natural
candidate for ``shard_map``; on a single device the vmap alone already
amortizes dispatch + pipeline overheads across stations.

``pool_step_block`` is also the **batch** entry (ISSUE 5, one core two
drivers): ``core.detect.detect_events`` replays archive traces through
it block by block — whole framed blocks with a tail mask, no ring state
needed — so offline reprocessing and the live service run the identical
guarded program.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import dist
from repro.core import fingerprint as fp_mod
from repro.core import lsh as lsh_mod
from repro.core.fingerprint import FingerprintConfig
from repro.core.lsh import LSHConfig, Pairs
from repro.stream import index as index_mod
from repro.stream.index import IndexState


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FusedState:
    """Everything the fused step owns on device (all donated).

    Solo form: ``index`` (t, B, C), ``halo`` (halo_samples,), ``med``/
    ``mad`` (n_coeff,). Pool form: the same leaves with a leading (S,)
    station axis (see ``init_pool_state``).
    """

    index: IndexState
    halo: jax.Array
    med: jax.Array
    mad: jax.Array


def init_state(index: IndexState, halo_samples: int, med, mad) -> FusedState:
    # jnp.array (not asarray): the state is donated on every step, so it
    # must own its buffers — aliasing a caller's med/mad array would
    # delete the caller's copy on the first dispatch
    return FusedState(index=index,
                      halo=jnp.zeros((halo_samples,), jnp.float32),
                      med=jnp.array(med), mad=jnp.array(mad))


def init_pool_state(indexes: list[IndexState], halo_samples: int,
                    meds, mads) -> FusedState:
    """Stack per-station pieces into one pool state (leading S axis)."""
    n = len(indexes)
    return FusedState(
        index=index_mod.stack_states(indexes),
        halo=jnp.zeros((n, halo_samples), jnp.float32),
        med=jnp.stack([jnp.asarray(m) for m in meds]),
        mad=jnp.stack([jnp.asarray(m) for m in mads]))


def _chunk_core(index: IndexState, med: jax.Array, mad: jax.Array,
                wave: jax.Array, mappings: jax.Array, base_id: jax.Array,
                valid: jax.Array | None, fcfg: FingerprintConfig,
                lcfg: LSHConfig, window: int, saturation: int = 0,
                dup_tables: int = 0, occ_limit: int = 0, counters: int = 0,
                max_pairs: int = 0, verify: int = 0, min_jac: float = 0.0
                ) -> tuple[IndexState, Pairs, jax.Array]:
    """One station's block: fingerprint → hash → expire → guards →
    insert → query.

    Shared by the solo and the vmapped pool entries; bit-identical to the
    unfused ``block_coeffs`` + ``stream_step`` chain (the parity test's
    contract). Signatures and bucket addresses are computed together once
    (``signatures_and_buckets``) instead of once in insert and again in
    query. The data-quality guards (duplicate probe, bucket-saturation
    quarantine, in-dispatch §6.5 occurrence limiter —
    ``index.guarded_step``) run inside this same traced program: with the
    knobs at 0 they compile away and the step is the pre-quality program
    exactly. Returns the per-step counter vector ``qc`` (layout
    ``index.QC_FIELDS``: guard counters + the ISSUE-6 telemetry counters,
    the latter live only when ``counters`` is set) alongside pairs.

    ``max_pairs``/``verify``/``min_jac`` (ISSUE 8) enable the emission
    epilogue inside the same dispatch: the dense pair stream is compacted
    to ``(max_pairs,)`` and, with ``verify``, scored with exact Jaccard —
    the bit-packed fingerprints the binarizer already produces feed the
    ``IndexState.pk`` ring, so fingerprint → hash → bucket → query →
    verify → compact is literally one fused device program.
    """
    coeffs = fp_mod.coeffs_from_waveform(wave, fcfg)
    bits, packed = fp_mod.binarize_coeffs(coeffs, fcfg, (med, mad))
    n = bits.shape[0]
    sigs, buckets = lsh_mod.signatures_and_buckets(
        bits, mappings, lcfg, index.shape[1], valid=valid)
    ids = base_id + jnp.arange(n, dtype=jnp.int32)
    return index_mod.guarded_step(index, sigs, buckets, ids, valid, lcfg,
                                  window, saturation=saturation,
                                  dup_tables=dup_tables,
                                  occ_limit=occ_limit, counters=counters,
                                  packed=packed if verify > 0 else None,
                                  max_pairs=max_pairs, verify=verify,
                                  min_jac=min_jac)


_QUALITY_STATICS = ("fcfg", "lcfg", "window", "saturation",
                    "dup_tables", "occ_limit", "counters",
                    "max_pairs", "verify", "min_jac")


@functools.partial(jax.jit, static_argnames=_QUALITY_STATICS,
                   donate_argnums=(0,))
def step_advance(state: FusedState, new_samples: jax.Array,
                 mappings: jax.Array, base_id: jax.Array,
                 fcfg: FingerprintConfig, lcfg: LSHConfig,
                 window: int = 0, saturation: int = 0, dup_tables: int = 0,
                 occ_limit: int = 0, counters: int = 0, max_pairs: int = 0,
                 verify: int = 0, min_jac: float = 0.0
                 ) -> tuple[FusedState, Pairs, jax.Array]:
    """Steady-state fused step: device halo + new samples → pairs.

    ``new_samples`` is (advance,) = block_fingerprints * lag_samples; the
    block is reassembled on device from the donated halo, and the new halo
    (the block tail) is written back in place.
    """
    wave = jnp.concatenate([state.halo, new_samples])
    index, pairs, qc = _chunk_core(state.index, state.med, state.mad, wave,
                                   mappings, base_id, None, fcfg, lcfg,
                                   window, saturation, dup_tables,
                                   occ_limit, counters, max_pairs, verify,
                                   min_jac)
    return FusedState(index=index, halo=wave[-state.halo.shape[-1]:],
                      med=state.med, mad=state.mad), pairs, qc


@functools.partial(jax.jit, static_argnames=_QUALITY_STATICS,
                   donate_argnums=(0,))
def step_block(state: FusedState, block: jax.Array, mappings: jax.Array,
               base_id: jax.Array, valid: jax.Array,
               fcfg: FingerprintConfig, lcfg: LSHConfig,
               window: int = 0, saturation: int = 0, dup_tables: int = 0,
               occ_limit: int = 0, counters: int = 0, max_pairs: int = 0,
               verify: int = 0, min_jac: float = 0.0
               ) -> tuple[FusedState, Pairs, jax.Array]:
    """Re-seeding fused step: a whole framed block + fingerprint mask.

    Used for the first block after a freeze/restore, for gap-masked
    blocks (fingerprints whose window overlaps missing data are
    suppressed in-dispatch), and for masked flush tails; also reprimes
    the halo so the next step can take the advance path (a zero-padded
    tail leaves the halo dirty — the caller tracks that and routes the
    next block through here again; a gap-masked but fully framed block
    leaves it primed).
    """
    index, pairs, qc = _chunk_core(state.index, state.med, state.mad, block,
                                   mappings, base_id, valid, fcfg, lcfg,
                                   window, saturation, dup_tables,
                                   occ_limit, counters, max_pairs, verify,
                                   min_jac)
    return FusedState(index=index, halo=block[-state.halo.shape[-1]:],
                      med=state.med, mad=state.mad), pairs, qc


@functools.partial(jax.jit, static_argnames=_QUALITY_STATICS,
                   donate_argnums=(0,))
def pool_step_advance(state: FusedState, new_samples: jax.Array,
                      mappings: jax.Array, base_id: jax.Array,
                      fcfg: FingerprintConfig, lcfg: LSHConfig,
                      window: int = 0, saturation: int = 0,
                      dup_tables: int = 0, occ_limit: int = 0,
                      counters: int = 0, max_pairs: int = 0,
                      verify: int = 0, min_jac: float = 0.0
                      ) -> tuple[FusedState, Pairs, jax.Array]:
    """``step_advance`` over a station pool: state leaves and
    ``new_samples`` carry a leading (S,) axis; ids/base advance in
    lockstep (stations ingest the same chunk cadence)."""
    wave = jnp.concatenate([state.halo, new_samples], axis=-1)
    core = functools.partial(_chunk_core, fcfg=fcfg, lcfg=lcfg,
                             window=window, saturation=saturation,
                             dup_tables=dup_tables, occ_limit=occ_limit,
                             counters=counters, max_pairs=max_pairs,
                             verify=verify, min_jac=min_jac)
    index, pairs, qc = jax.vmap(core, in_axes=(0, 0, 0, 0, None, None,
                                               None))(
        state.index, state.med, state.mad, wave, mappings, base_id, None)
    return FusedState(index=index, halo=wave[:, -state.halo.shape[-1]:],
                      med=state.med, mad=state.mad), pairs, qc


@functools.partial(jax.jit, static_argnames=_QUALITY_STATICS,
                   donate_argnums=(0,))
def pool_step_block(state: FusedState, blocks: jax.Array,
                    mappings: jax.Array, base_id: jax.Array,
                    valid: jax.Array, fcfg: FingerprintConfig,
                    lcfg: LSHConfig, window: int = 0, saturation: int = 0,
                    dup_tables: int = 0, occ_limit: int = 0,
                    counters: int = 0, max_pairs: int = 0,
                    verify: int = 0, min_jac: float = 0.0
                    ) -> tuple[FusedState, Pairs, jax.Array]:
    """``step_block`` over a station pool (blocks (S, block_samples),
    valid (S, block_fingerprints) — per-station gap masks differ when one
    station drops out while the others keep streaming)."""
    core = functools.partial(_chunk_core, fcfg=fcfg, lcfg=lcfg,
                             window=window, saturation=saturation,
                             dup_tables=dup_tables, occ_limit=occ_limit,
                             counters=counters, max_pairs=max_pairs,
                             verify=verify, min_jac=min_jac)
    index, pairs, qc = jax.vmap(core, in_axes=(0, 0, 0, 0, None, None, 0))(
        state.index, state.med, state.mad, blocks, mappings, base_id, valid)
    return FusedState(index=index, halo=blocks[:, -state.halo.shape[-1]:],
                      med=state.med, mad=state.mad), pairs, qc


# ---------------------------------------------------------------------------
# sharded station pool (ISSUE 10): the same pool entries over a device mesh
# ---------------------------------------------------------------------------
#
# The leading S axis of every FusedState leaf is split over the mesh's
# ``stations`` axis via the version-portable ``dist.shard_map`` wrapper;
# inside the region each device runs the identical vmapped per-station
# core over its own S/D rows. The hot path has **zero** cross-station
# communication (association is a host tail), so the region is fully
# manual and needs no collectives — which is exactly what sidesteps the
# jaxlib-0.4.x partial-manual shard_map scan/gather limitation the
# ROADMAP names as the blocker: only partial-manual regions hit it.
#
# ``mappings`` and ``base_id`` are replicated (every station hashes with
# the same tables and ingests the same block cadence); all outputs carry
# the station axis, so pair emission stays one ``device_get`` of a
# station-sharded buffer. Entries are cached per (mesh, statics) — the
# one-dispatch invariant's retracing half holds exactly as in the vmap
# pool (≤1 steady-state trace per entry, pinned by tests).

_SHARDED_ENTRIES: dict = {}


def _mesh_width(mesh) -> int:
    return int(mesh.devices.size) if mesh is not None else 1


def _sharded_entry(mesh, advance: bool, statics: tuple):
    key = (mesh, advance, statics)
    fn = _SHARDED_ENTRIES.get(key)
    if fn is not None:
        return fn
    (fcfg, lcfg, window, saturation, dup_tables, occ_limit, counters,
     max_pairs, verify, min_jac) = statics
    core = functools.partial(_chunk_core, fcfg=fcfg, lcfg=lcfg,
                             window=window, saturation=saturation,
                             dup_tables=dup_tables, occ_limit=occ_limit,
                             counters=counters, max_pairs=max_pairs,
                             verify=verify, min_jac=min_jac)
    axis = mesh.axis_names[0]
    if advance:
        def body(state, new_samples, mappings, base_id):
            wave = jnp.concatenate([state.halo, new_samples], axis=-1)
            index, pairs, qc = jax.vmap(
                core, in_axes=(0, 0, 0, 0, None, None, None))(
                state.index, state.med, state.mad, wave, mappings,
                base_id, None)
            return FusedState(index=index,
                              halo=wave[:, -state.halo.shape[-1]:],
                              med=state.med, mad=state.mad), pairs, qc

        in_specs = (P(axis), P(axis), P(), P())
    else:
        def body(state, blocks, mappings, base_id, valid):
            index, pairs, qc = jax.vmap(
                core, in_axes=(0, 0, 0, 0, None, None, 0))(
                state.index, state.med, state.mad, blocks, mappings,
                base_id, valid)
            return FusedState(index=index,
                              halo=blocks[:, -state.halo.shape[-1]:],
                              med=state.med, mad=state.mad), pairs, qc

        in_specs = (P(axis), P(axis), P(), P(), P(axis))
    sharded = dist.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=(P(axis), P(axis), P(axis)),
                             axis_names=(axis,))
    fn = jax.jit(sharded, donate_argnums=(0,))
    _SHARDED_ENTRIES[key] = fn
    return fn


def pool_step_advance_sharded(state: FusedState, new_samples: jax.Array,
                              mappings: jax.Array, base_id: jax.Array,
                              fcfg: FingerprintConfig, lcfg: LSHConfig,
                              window: int = 0, saturation: int = 0,
                              dup_tables: int = 0, occ_limit: int = 0,
                              counters: int = 0, max_pairs: int = 0,
                              verify: int = 0, min_jac: float = 0.0, *,
                              mesh=None
                              ) -> tuple[FusedState, Pairs, jax.Array]:
    """``pool_step_advance`` with the station axis split over ``mesh``.

    Falls back to the single-device vmap pool when ``mesh`` is absent or
    1-device, or when the pool width does not divide the mesh (the
    caller pads the pool — ``dist.padded_pool_width`` — so hitting the
    fallback means the pool was built without this mesh in hand). The
    fallback is bit-identical: the sharded region runs the same vmapped
    per-station core, just split across devices."""
    if _mesh_width(mesh) < 2 or state.halo.shape[0] % _mesh_width(mesh):
        return pool_step_advance(state, new_samples, mappings, base_id,
                                 fcfg, lcfg, window, saturation,
                                 dup_tables, occ_limit, counters,
                                 max_pairs, verify, min_jac)
    statics = (fcfg, lcfg, window, saturation, dup_tables, occ_limit,
               counters, max_pairs, verify, min_jac)
    return _sharded_entry(mesh, True, statics)(state, new_samples,
                                               mappings, base_id)


def pool_step_block_sharded(state: FusedState, blocks: jax.Array,
                            mappings: jax.Array, base_id: jax.Array,
                            valid: jax.Array, fcfg: FingerprintConfig,
                            lcfg: LSHConfig, window: int = 0,
                            saturation: int = 0, dup_tables: int = 0,
                            occ_limit: int = 0, counters: int = 0,
                            max_pairs: int = 0, verify: int = 0,
                            min_jac: float = 0.0, *, mesh=None
                            ) -> tuple[FusedState, Pairs, jax.Array]:
    """``pool_step_block`` over a ``stations`` mesh axis (see
    ``pool_step_advance_sharded`` for the fallback contract)."""
    if _mesh_width(mesh) < 2 or state.halo.shape[0] % _mesh_width(mesh):
        return pool_step_block(state, blocks, mappings, base_id, valid,
                               fcfg, lcfg, window, saturation, dup_tables,
                               occ_limit, counters, max_pairs, verify,
                               min_jac)
    statics = (fcfg, lcfg, window, saturation, dup_tables, occ_limit,
               counters, max_pairs, verify, min_jac)
    return _sharded_entry(mesh, False, statics)(state, blocks, mappings,
                                                base_id, valid)
