"""Device-resident incremental LSH index (streaming replacement for §6).

The offline search re-sorts every signature on every run; here the hash
tables are *materialized* as fixed-capacity bucket arrays that live on
device across chunks:

  ``sig[t, B, C]``  stored per-table signature of each slot (uint32)
  ``ids[t, B, C]``  global fingerprint id of each slot (INVALID = empty)
  ``cursor[t, B]``  per-bucket ring write position (monotonic)

``insert`` scatters a batch of signatures into their buckets — within a
batch, same-bucket rows get consecutive ring positions via a sort +
rank-in-run, so a bucket overflowing its capacity ``C`` evicts its oldest
entries (the paper's mega-bucket pathology is therefore *structurally*
capped, like ``bucket_cap`` in the offline sort-based search). ``query``
gathers each signature's bucket occupants, keeps exact-signature hits, and
feeds the per-table emission streams through the same
``finalize_pairs`` (min_dt self-match exclusion + m-of-t threshold) as the
batch path — one implementation of the pair semantics, two search engines.

Both ops are jitted with static shapes: chunk after chunk of the same
batch size reuses one executable (no retracing), which is what makes the
incremental path O(batch) instead of O(corpus).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lsh as lsh_mod
from repro.core.lsh import (INVALID, LSHConfig, Pairs, VerifiedPairs,
                            finalize_pairs)
from repro.kernels import ops
from repro.utils import rank_in_run, run_lengths

# Layout of the per-step quality/telemetry counter vector returned by
# ``guarded_step`` (and therefore by every fused step entry). The first
# three are the ISSUE-4/5 guard counters and are always live; the rest
# are the ISSUE-6 telemetry extension, computed inside the same traced
# program when ``counters`` is set and constant-folded to 0 otherwise.
QC_FIELDS = (
    "duplicate_fingerprints",    # fingerprints suppressed by the dup probe
    "saturated_lookups",         # valid lookups landing in hot buckets
    "limited_pairs",             # pairs dropped by the §6.5 occ ring
    "pairs_emitted",             # finalized valid pairs leaving the step
    "masked_fingerprints",       # fingerprints suppressed by the validity
                                 # mask (gaps / dup samples / flush tails)
    "raw_collisions",            # (table, slot) sig matches pre-guard —
                                 # the §6.3 lookups-per-query skew signal
    "quarantined_collisions",    # raw collisions killed by the bucket-
                                 # saturation quarantine
    "overflow_pairs",            # valid pairs dropped by the emission
                                 # compaction bound (ISSUE 8; 0 when the
                                 # compacted buffer fit every pair)
)


@dataclasses.dataclass(frozen=True)
class StreamIndexConfig:
    """Shape of the resident index (capacity knobs, not semantics)."""

    n_buckets: int = 4096     # buckets per table (power of two)
    bucket_cap: int = 8       # slots per bucket (ring, oldest evicted)
    occ_slots: int = 0        # per-fingerprint partner-count ring (ISSUE 5:
                              # the in-dispatch §6.5 limiter; 0 = no ring)
    pk_slots: int = 0         # bit-packed fingerprint ring rows (ISSUE 8:
                              # the in-dispatch verify epilogue; 0 = none)
    pk_words: int = 0         # uint32 words per packed row (fp_dim // 32;
                              # 0 lets the engine derive it from the
                              # fingerprint config)

    def __post_init__(self):
        assert self.n_buckets & (self.n_buckets - 1) == 0, \
            f"n_buckets must be a power of two, got {self.n_buckets}"
        assert self.occ_slots >= 0, self.occ_slots
        assert self.pk_slots >= 0, self.pk_slots
        assert self.pk_words >= 0, self.pk_words

    def state_bytes(self, n_tables: int) -> int:
        slots = n_tables * self.n_buckets * self.bucket_cap
        return (slots * (4 + 4) + 2 * n_tables * self.n_buckets * 4
                + max(self.occ_slots, 1) * 4
                + max(self.pk_slots, 1) * max(self.pk_words, 1) * 4)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class IndexState:
    sig: jax.Array      # (t, B, C) uint32
    ids: jax.Array      # (t, B, C) int32, INVALID where empty
    cursor: jax.Array   # (t, B) int32 monotonic ring cursor
    inserted: jax.Array  # () int32 total rows ever inserted
    traffic: jax.Array  # (t, B) int32 bucket insert traffic; unlike
                        # ``cursor`` (the ring write position, which must
                        # stay monotonic) it DECAYS under a sliding window
                        # so the saturation quarantine is window-relative
    occ: jax.Array      # (L,) int32 per-fingerprint emitted-partner counts
                        # (ring keyed by id % L; L = occ_slots or 1)
    epoch: jax.Array    # () int32 last traffic-decay epoch (expire)
    pk: jax.Array       # (P, W) uint32 bit-packed fingerprint ring keyed
                        # by id % P (ISSUE 8 verify; P = pk_slots or 1)

    @property
    def shape(self) -> tuple[int, int, int]:
        return self.sig.shape


def init_index(lcfg: LSHConfig, icfg: StreamIndexConfig) -> IndexState:
    t, b, c = lcfg.n_tables, icfg.n_buckets, icfg.bucket_cap
    return IndexState(
        sig=jnp.zeros((t, b, c), jnp.uint32),
        ids=jnp.full((t, b, c), INVALID, jnp.int32),
        cursor=jnp.zeros((t, b), jnp.int32),
        inserted=jnp.zeros((), jnp.int32),
        traffic=jnp.zeros((t, b), jnp.int32),
        occ=jnp.zeros((max(icfg.occ_slots, 1),), jnp.int32),
        epoch=jnp.zeros((), jnp.int32),
        pk=jnp.zeros((max(icfg.pk_slots, 1), max(icfg.pk_words, 1)),
                     jnp.uint32),
    )


# bucket addressing lives in core/lsh.py (shared with the fused kernel
# epilogue); kept as a local alias for callers of the old private name
_bucket_ids = lsh_mod.bucket_ids


def _insert_one_table(sig_tb, ids_tb, cursor_tb, traffic_tb, buckets, keys,
                      new_ids, valid):
    """Scatter one batch into one table's (B, C) bucket arrays."""
    b, c = sig_tb.shape
    n = buckets.shape[0]
    order_key = jnp.where(valid, buckets, jnp.int32(b))  # invalid rows last
    sb, perm = jax.lax.sort((order_key, jnp.arange(n, dtype=jnp.int32)),
                            num_keys=1)
    rank = rank_in_run(sb)
    _, lens = run_lengths(sb)
    keep = (sb < b) & (rank >= lens - c)   # newest C of each bucket run
    pos = (cursor_tb[jnp.where(sb < b, sb, 0)] + rank) % c
    slot = jnp.where(keep, sb * c + pos, b * c)  # OOB → dropped
    k_s = keys[perm]
    id_s = new_ids[perm]
    new_sig = sig_tb.reshape(-1).at[slot].set(k_s, mode="drop").reshape(b, c)
    new_ids_tb = ids_tb.reshape(-1).at[slot].set(id_s, mode="drop") \
        .reshape(b, c)
    # advance cursors by the full run length (ring continues past drops);
    # the traffic counter advances identically but may later decay
    adds = valid.astype(jnp.int32)
    new_cursor = cursor_tb.at[buckets].add(adds, mode="drop")
    new_traffic = traffic_tb.at[buckets].add(adds, mode="drop")
    return new_sig, new_ids_tb, new_cursor, new_traffic


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0,))
def insert(state: IndexState, sigs: jax.Array, ids: jax.Array,
           cfg: LSHConfig, valid: jax.Array | None = None,
           buckets: jax.Array | None = None) -> IndexState:
    """Insert a batch of per-table signatures under global fingerprint ids.

    sigs: (N, t) uint32; ids: (N,) int32 (monotone across the stream).
    Fixed shapes — one trace per (N, index shape) combination.
    ``buckets`` (N, t) skips bucket addressing when the caller already has
    it (the fused chunk step computes it once for insert *and* query).
    """
    t, b, c = state.shape
    n = sigs.shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)
    if buckets is None:
        buckets = lsh_mod.bucket_ids(sigs, b, cfg.seed)   # (N, t)
    new_sig, new_ids, new_cursor, new_traffic = jax.vmap(
        _insert_one_table, in_axes=(0, 0, 0, 0, 1, 1, None, None))(
        state.sig, state.ids, state.cursor, state.traffic, buckets,
        sigs.astype(jnp.uint32), ids, valid)
    return IndexState(sig=new_sig, ids=new_ids, cursor=new_cursor,
                      inserted=state.inserted + valid.sum(dtype=jnp.int32),
                      traffic=new_traffic, occ=state.occ, epoch=state.epoch,
                      pk=state.pk)


@functools.partial(jax.jit, static_argnames=("cfg", "saturation", "counts",
                                              "max_pairs"))
def query(state: IndexState, sigs: jax.Array, qids: jax.Array,
          cfg: LSHConfig, buckets: jax.Array | None = None,
          qvalid: jax.Array | None = None, saturation: int = 0,
          counts: int = 0, max_pairs: int = 0):
    """Find stored partners of a signature batch → thresholded Pairs.

    Only partners with stored id < query id are emitted, so a batch that
    was just inserted pairs exactly once with every earlier fingerprint
    (including same-batch ones) per colliding table — the streaming
    equivalent of the offline rank-window emission. Returns a masked
    ``Pairs`` of static size t * N * C.

    ``qvalid`` suppresses emission for flagged query rows (duplicate-
    guarded fingerprints keep their real signatures but must not pair).
    ``saturation`` > 0 quarantines saturated buckets from emission: hits
    inside a bucket whose insert-traffic counter exceeds the limit are
    dropped — the repeating-glitch mega-bucket fix. The counter is
    ``state.traffic``, which a sliding window decays (see ``expire``), so
    quarantined buckets recover once the offending channel is repaired.
    Both default off, leaving the traced program unchanged.

    ``counts`` (static, telemetry) additionally returns
    ``(pairs, [raw_collisions, quarantined_collisions])`` — the pre-guard
    (table, slot) signature-match total (the §6.3 lookups-per-query skew
    signal; dup-suppressed rows keep their real signatures so their
    collisions are intentionally included) and the subset of it killed by
    the saturation quarantine. Two reductions over masks the program
    already materializes — no new dispatch, pair outputs untouched.

    ``max_pairs`` (static, ISSUE 8) > 0 compacts the dense emission
    through :func:`compact_pairs` so the returned ``Pairs`` has static
    size ``max_pairs`` instead of t * N * C — the O(P) shape serving-tier
    callers reduce over. Overflow past the bound drops deterministically
    (see ``compact_pairs``); callers needing the overflow count use
    ``guarded_step``, which also appends it to the QC vector.
    """
    t, b, c = state.shape
    n = sigs.shape[0]
    if buckets is None:
        buckets = lsh_mod.bucket_ids(sigs, b, cfg.seed)   # (N, t)

    def one_table(sig_tb, ids_tb, cur_tb, bkt, keys):
        occ_sig = sig_tb[bkt]                          # (N, C)
        occ_id = ids_tb[bkt]                           # (N, C)
        raw = (occ_sig == keys[:, None]) & (occ_id != INVALID) \
            & (occ_id < qids[:, None])
        hit = raw
        n_quar = jnp.int32(0)
        if saturation > 0:
            ok = (cur_tb[bkt] <= jnp.int32(saturation))[:, None]
            hit = hit & ok
            if counts:
                n_quar = (raw & ~ok).sum(dtype=jnp.int32)
        if qvalid is not None:
            hit = hit & qvalid[:, None]
        lo = jnp.where(hit, occ_id, INVALID)
        hi = jnp.where(hit, qids[:, None], INVALID)
        n_raw = raw.sum(dtype=jnp.int32) if counts else jnp.int32(0)
        return lo, hi, n_raw, n_quar

    lo, hi, n_raw, n_quar = jax.vmap(one_table, in_axes=(0, 0, 0, 1, 1))(
        state.sig, state.ids, state.traffic, buckets,
        sigs.astype(jnp.uint32))
    pairs = finalize_pairs(lo.reshape(-1), hi.reshape(-1), cfg)
    if max_pairs > 0:
        pairs, _ = compact_pairs(pairs, max_pairs)
    if not counts:
        return pairs
    return pairs, jnp.stack([n_raw.sum(), n_quar.sum()])


@functools.partial(jax.jit, static_argnames=("half_life",))
def expire(state: IndexState, min_id: jax.Array,
           half_life: int = 0) -> IndexState:
    """Sliding detection window: drop entries with id < min_id.

    ``half_life`` > 0 additionally makes the bucket-saturation traffic
    counter *window-relative*: every time ``min_id`` crosses a half-life
    boundary the counter is halved (a right shift per crossed epoch), so
    ``traffic`` approximates recent-window insert pressure instead of
    lifetime totals and quarantined buckets recover once a glitching
    channel is repaired. 0 keeps the lifetime counter (and the exact
    pre-decay traced program).
    """
    keep = state.ids >= jnp.int32(min_id)
    traffic, epoch = state.traffic, state.epoch
    if half_life > 0:
        new_epoch = jnp.maximum(jnp.asarray(min_id, jnp.int32), 0) \
            // jnp.int32(half_life)
        shift = jnp.clip(new_epoch - epoch, 0, 31)
        traffic = traffic >> shift          # halve once per crossed epoch
        epoch = new_epoch
    return IndexState(sig=state.sig,
                      ids=jnp.where(keep, state.ids, INVALID),
                      cursor=state.cursor, inserted=state.inserted,
                      traffic=traffic, occ=state.occ, epoch=epoch,
                      pk=state.pk)


# ---------------------------------------------------------------------------
# degenerate-similarity guards (ISSUE 4): duplicate probe + saturation
# ---------------------------------------------------------------------------


def duplicate_flags(state: IndexState, sigs: jax.Array, ids: jax.Array,
                    cfg: LSHConfig, dup_tables: int,
                    buckets: jax.Array | None = None,
                    valid: jax.Array | None = None) -> jax.Array:
    """(N,) bool — near-exact repeated segments, flagged *before* insert.

    A fingerprint is a repeat when its per-table signatures collide with
    resident index entries (or earlier rows of the same batch) in at
    least ``dup_tables`` of the t tables, at id distance ≥ ``min_dt``.
    Bit-exact duplicated data blocks collide in all t tables; repeating
    glitches in nearly all; genuine repeating earthquakes (differing
    noise floors) in only a few — a threshold near t separates artifact
    from signal. Traced inline by the fused step (no extra dispatch).
    """
    t, b, c = state.shape
    if buckets is None:
        buckets = lsh_mod.bucket_ids(sigs, b, cfg.seed)
    keys = sigs.astype(jnp.uint32)
    far = ids[:, None] - jnp.int32(max(cfg.min_dt, 1))

    def one_table(sig_tb, ids_tb, bkt, k):
        occ_sig = sig_tb[bkt]                          # (N, C)
        occ_id = ids_tb[bkt]
        hit = ((occ_sig == k[:, None]) & (occ_id != INVALID)
               & (occ_id <= far))
        return hit.any(axis=1)                         # (N,)

    resident = jax.vmap(one_table, in_axes=(0, 0, 1, 1))(
        state.sig, state.ids, buckets, keys).sum(axis=0)    # (N,)
    # earlier rows of this batch (they are not yet resident)
    same = (keys[:, None, :] == keys[None, :, :]).sum(-1)   # (N, N)
    earlier = ids[None, :] <= far
    if valid is not None:
        earlier = earlier & valid[None, :]
    intra = jnp.where(earlier, same, 0).max(axis=1)
    dup = jnp.maximum(resident, intra) >= jnp.int32(dup_tables)
    if valid is not None:
        dup = dup & valid
    return dup


def saturated_lookup_count(state: IndexState, buckets: jax.Array,
                           saturation: int,
                           valid: jax.Array | None = None) -> jax.Array:
    """How many of this batch's valid (row, table) lookups landed in a
    quarantined bucket — the saturation monitoring counter. Invalid rows
    carry pseudo-random filler buckets and must not pollute the count."""
    cur = jax.vmap(lambda c, b: c[b], in_axes=(0, 1))(
        state.traffic, buckets)                        # (t, N)
    hot = cur > jnp.int32(saturation)
    if valid is not None:
        hot = hot & valid[None, :]
    return hot.sum(dtype=jnp.int32)


def occurrence_limit_pairs(state: IndexState, sigs: jax.Array,
                           buckets: jax.Array, ids: jax.Array,
                           qvalid: jax.Array | None, cfg: LSHConfig,
                           pairs: Pairs, limit: int
                           ) -> tuple[IndexState, Pairs, jax.Array]:
    """In-dispatch §6.5 occurrence limiter (ISSUE 5 tentpole).

    Counts every raw partner collision — a (table, slot) signature match
    at id distance ≥ ``min_dt``, the §6.3 lookups-per-query skew signal,
    *before* any ring-cap / threshold / quarantine attenuation — against
    both endpoints' per-fingerprint counters in the ``occ`` ring (keyed
    by id % L; slots recycle as the window slides, so counts are
    window-relative like the host filter's per-partition fractions).
    Pairs touching a fingerprint whose accumulated count exceeds
    ``limit`` are then dropped *inside the already-traced program*. A
    repeating glitch train collides with its ring-resident siblings in
    nearly every table, so its fingerprints blow past the limit within
    their first block and the train's pairs — including additive,
    non-sample-exact trains the duplicate guard cannot see — die
    in-dispatch; a legitimate repeater's lifetime total stays near the
    sum of its pair similarities, far below a sanely sized limit, so
    clean data is bit-identical with the limiter on or off (pinned).
    The host-side ``occurrence_filter`` stays as the exact §6.5
    reference/fallback. Returns (state, limited pairs, pairs dropped).
    """
    t, b, c = state.shape
    ring = state.occ.shape[0]
    keys = sigs.astype(jnp.uint32)
    far = ids[:, None] - jnp.int32(max(cfg.min_dt, 1) - 1)  # id dist ≥ min_dt

    def one_table(sig_tb, ids_tb, bkt, k):
        occ_sig = sig_tb[bkt]                          # (N, C)
        occ_id = ids_tb[bkt]
        hit = ((occ_sig == k[:, None]) & (occ_id != INVALID)
               & (occ_id < far))
        if qvalid is not None:
            hit = hit & qvalid[:, None]
        return hit, occ_id

    hit, occ_id = jax.vmap(one_table, in_axes=(0, 0, 1, 1))(
        state.sig, state.ids, buckets, keys)           # (t, N, C) each
    q_counts = hit.sum(axis=(0, 2), dtype=jnp.int32)   # (N,)
    pslot = jnp.where(hit, occ_id % ring, ring).reshape(-1)  # OOB → dropped
    occ = state.occ.at[ids % ring].add(q_counts, mode="drop") \
        .at[pslot].add(hit.reshape(-1).astype(jnp.int32), mode="drop")
    hot = occ > jnp.int32(limit)
    v = pairs.valid
    s1 = jnp.where(v, pairs.idx1 % ring, 0)
    s2 = jnp.where(v, pairs.idx2 % ring, 0)
    keep = v & ~hot[s1] & ~hot[s2]
    dropped = (v & ~keep).sum(dtype=jnp.int32)
    limited = Pairs(idx1=pairs.idx1, idx2=pairs.idx2,
                    sim=jnp.where(keep, pairs.sim, 0), valid=keep)
    return dataclasses.replace(state, occ=occ), limited, dropped


# ---------------------------------------------------------------------------
# emission epilogue (ISSUE 8): compaction + exact-Jaccard verify
# ---------------------------------------------------------------------------


def compact_pairs(pairs: Pairs, max_pairs: int
                  ) -> tuple[Pairs, jax.Array]:
    """Validity compaction of the dense emission stream (ISSUE 8).

    The dense stream leaving ``finalize_pairs`` is t * N * C slots,
    almost all masked; this gathers the surviving pairs into a bounded
    ``(max_pairs,)`` buffer so only real pairs cross the device→host
    boundary. The drop rule on overflow is deterministic: the stream is
    (idx1, idx2)-sorted (valid pairs sit at segment starts of the
    ``lax.sort`` in ``count_pair_multiplicity``), and the compaction
    keeps the *first* ``max_pairs`` valid positions — i.e. the
    lexicographically smallest (idx1, idx2) pairs — independent of
    backend reduction order. Returns (compacted pairs, overflow count).
    """
    m = pairs.valid.shape[0]
    k = min(max_pairs, m)
    pos = jnp.arange(m, dtype=jnp.int32)
    # valid rows outrank invalid ones; within each class earlier stream
    # positions outrank later ones, so top_k takes the first k valid
    # positions (padding from the stream head when fewer are valid)
    score = jnp.where(pairs.valid, 2 * m - pos, m - pos)
    _, take = jax.lax.top_k(score, k)
    kept = pairs.valid[take]
    overflow = (pairs.valid.sum(dtype=jnp.int32)
                - kept.sum(dtype=jnp.int32))
    return Pairs(idx1=pairs.idx1[take], idx2=pairs.idx2[take],
                 sim=pairs.sim[take], valid=kept), overflow


def verify_pairs(state: IndexState, pairs: Pairs,
                 use_pallas: bool = False) -> jax.Array:
    """Exact Jaccard of compacted candidates from the packed ring.

    Gathers both endpoints' bit-packed fingerprints out of the
    ``IndexState.pk`` ring (keyed by id % pk_slots — valid as long as the
    ring spans the detection window, which config validation enforces)
    and scores them with ``kernels.jaccard_popcount`` (the jnp oracle, or
    the interpret-parity-tested Pallas kernel when ``use_pallas``).
    O(max_pairs) work — call on the *compacted* emission, never the dense
    stream. Invalid rows score 0.
    """
    ring = state.pk.shape[0]
    i1 = jnp.where(pairs.valid, pairs.idx1, 0) % jnp.int32(ring)
    i2 = jnp.where(pairs.valid, pairs.idx2, 0) % jnp.int32(ring)
    jac = ops.jaccard_popcount(state.pk[i1], state.pk[i2],
                               use_pallas=use_pallas)
    return jnp.where(pairs.valid, jac, jnp.float32(0.0))


def guarded_step(state: IndexState, sigs: jax.Array, buckets: jax.Array,
                 ids: jax.Array, valid: jax.Array | None, cfg: LSHConfig,
                 window: int, saturation: int = 0, dup_tables: int = 0,
                 occ_limit: int = 0, counters: int = 0,
                 packed: jax.Array | None = None, max_pairs: int = 0,
                 verify: int = 0, min_jac: float = 0.0
                 ) -> tuple[IndexState, Pairs, jax.Array]:
    """expire → duplicate guard → insert → saturation-guarded query →
    occurrence limiter → emission compaction + exact-Jaccard verify.

    The one shared insert/query tail of EVERY detection path — the fused
    ``_chunk_core``, the unfused ``stream_step``, and the batch replay
    driver (``core.detect``) — so the guards are bit-identical in all of
    them. Returns (state, pairs, qc) where ``qc`` is the
    ``len(QC_FIELDS)`` counter vector laid out by :data:`QC_FIELDS`: the
    three guard counters (each 0 when the corresponding knob is off —
    the program then matches the unguarded step exactly) followed by the
    telemetry counters (pairs emitted, mask-suppressed fingerprints, raw
    collisions, quarantined collisions), which are computed in the same
    traced program when ``counters`` is set and constant 0 otherwise.
    Counters never feed back into the pair outputs, so detections are
    bit-identical with telemetry on or off (pinned).

    ``occ_limit`` > 0 enables the in-dispatch §6.5 occurrence limiter
    (``occurrence_limit_pairs``): per-fingerprint partner counts carried
    in ``state.occ``, decayed with the sliding window (each incoming id
    reclaims its ring slot — the previous owner is ≥ occ_slots older and
    long expired), capping pair emission per query with no extra
    dispatch. ``window`` > 0 with ``saturation`` > 0 also switches the
    saturation quarantine to the window-relative decaying traffic counter
    (see ``expire``).

    ``max_pairs`` > 0 (static, ISSUE 8) enables the emission epilogue:
    the dense t * N * C pair stream is compacted to a bounded
    ``(max_pairs,)`` buffer (``compact_pairs``; deterministic drop on
    overflow, counted in ``overflow_pairs``), and with ``verify`` > 0
    the compacted candidates are scored with exact Jaccard from the
    bit-packed fingerprint ring (``verify_pairs``; ``packed`` supplies
    this block's (N, pk_words) uint32 rows, written into ``state.pk`` at
    id % pk_slots before the query; ``verify == 2`` routes the scoring
    through the Pallas kernel). The step then returns a
    ``lsh.VerifiedPairs`` — (idx1, idx2, hash matches, jaccard) — and
    ``min_jac`` > 0 drops pairs whose *true* similarity falls below the
    threshold in-dispatch, so downstream thresholds can act on exact
    Jaccard instead of the hash-match proxy. All knobs at 0 leave the
    dense emission and the traced program exactly as before.
    """
    if occ_limit > 0:
        # recycle the incoming ids' partner-count slots (window decay:
        # a slot's previous owner is a full ring behind — outside any
        # window the ring was sized for)
        ring = state.occ.shape[0]
        state = dataclasses.replace(
            state, occ=state.occ.at[ids % ring].set(0))
    if window > 0:
        # newest = one past the last valid id (prefix masks reduce to
        # base + n_valid, the pre-quality behavior; hole-y gap masks
        # still anchor the window to absolute stream time)
        newest = (ids[-1] + 1 if valid is None
                  else jnp.max(jnp.where(valid, ids + 1, ids[0])))
        state = expire(state, newest - jnp.int32(window),
                       half_life=window if saturation > 0 else 0)
    ins_valid, qvalid = valid, None
    qc_dup = jnp.int32(0)
    if dup_tables > 0:
        n = sigs.shape[0]
        v = jnp.ones((n,), bool) if valid is None else valid
        dup = duplicate_flags(state, sigs, ids, cfg, dup_tables,
                              buckets=buckets, valid=v)
        ins_valid = v & ~dup
        qvalid = ins_valid
        qc_dup = dup.sum(dtype=jnp.int32)
    if verify > 0:
        # stash this block's bit-packed fingerprints in the ring so the
        # verify epilogue can gather both endpoints of any within-window
        # pair (suppressed rows never pair, so their slots stay stale)
        assert max_pairs > 0, "verify requires max_pairs (compaction)"
        ring = state.pk.shape[0]
        wv = (jnp.ones(ids.shape, bool) if ins_valid is None else ins_valid)
        slot = jnp.where(wv, ids % jnp.int32(ring), jnp.int32(ring))
        state = dataclasses.replace(
            state, pk=state.pk.at[slot].set(packed.astype(jnp.uint32),
                                            mode="drop"))
    state = insert(state, sigs, ids, cfg, valid=ins_valid, buckets=buckets)
    qc_sat = (saturated_lookup_count(state, buckets, saturation,
                                     valid=ins_valid)
              if saturation > 0 else jnp.int32(0))
    qc_raw = qc_quar = jnp.int32(0)
    if counters:
        pairs, qcounts = query(state, sigs, ids, cfg, buckets=buckets,
                               qvalid=qvalid, saturation=saturation,
                               counts=1)
        qc_raw, qc_quar = qcounts[0], qcounts[1]
    else:
        pairs = query(state, sigs, ids, cfg, buckets=buckets, qvalid=qvalid,
                      saturation=saturation)
    qc_occ = jnp.int32(0)
    if occ_limit > 0:
        state, pairs, qc_occ = occurrence_limit_pairs(
            state, sigs, buckets, ids, qvalid, cfg, pairs, occ_limit)
    qc_overflow = jnp.int32(0)
    if max_pairs > 0:
        pairs, qc_overflow = compact_pairs(pairs, max_pairs)
        jac = jnp.zeros(pairs.valid.shape, jnp.float32)
        if verify > 0:
            jac = verify_pairs(state, pairs, use_pallas=(verify == 2))
            if min_jac > 0.0:
                keep = pairs.valid & (jac >= jnp.float32(min_jac))
                pairs = Pairs(idx1=pairs.idx1, idx2=pairs.idx2,
                              sim=jnp.where(keep, pairs.sim, 0),
                              valid=keep)
                jac = jnp.where(keep, jac, jnp.float32(0.0))
        pairs = VerifiedPairs(idx1=pairs.idx1, idx2=pairs.idx2,
                              sim=pairs.sim, jac=jac, valid=pairs.valid)
    qc_pairs = qc_masked = jnp.int32(0)
    if counters:
        qc_pairs = pairs.valid.sum(dtype=jnp.int32)
        if valid is not None:
            qc_masked = (~valid).sum(dtype=jnp.int32)
    return state, pairs, jnp.stack([qc_dup, qc_sat, qc_occ, qc_pairs,
                                    qc_masked, qc_raw, qc_quar,
                                    qc_overflow])


# ---------------------------------------------------------------------------
# station pools: the same IndexState with a leading station axis
# ---------------------------------------------------------------------------


def init_pool(lcfg: LSHConfig, icfg: StreamIndexConfig,
              n_stations: int) -> IndexState:
    """Stacked per-station index: every leaf gains a leading (S,) axis.

    The pool is stepped via ``vmap`` inside the fused chunk step — one
    executable serves S stations (ISSUE 3), instead of S sequential
    engines each paying their own dispatch.
    """
    return stack_states([init_index(lcfg, icfg)] * n_stations)


def stack_states(states: list[IndexState]) -> IndexState:
    """Per-station states → one pool state with a leading station axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def slice_state(pool: IndexState, station: int) -> IndexState:
    """One station's view of a pool state (used by snapshot + serving)."""
    return jax.tree.map(lambda x: x[station], pool)


def index_stats(state: IndexState) -> dict:
    """Occupancy / skew diagnostics (host-side, for monitoring)."""
    occupied = np.asarray(state.ids != INVALID)
    per_bucket = occupied.sum(axis=2)
    return {
        "inserted": int(state.inserted),
        "resident": int(occupied.sum()),
        "occupancy": float(occupied.mean()),
        "full_buckets": int((per_bucket == state.ids.shape[2]).sum()),
        "max_bucket_fill": int(per_bucket.max()),
    }
