"""Host-side training-data pipeline: synthetic corpus → LSH dedup → packed
batches, with checkpointable iterator state and host→device prefetch.

The synthetic corpus intentionally injects near-duplicate documents
(templated boilerplate with small token perturbations) so the LSH dedup
stage (data/dedup.py) has real work — mirroring the repeating-noise
pathology FAST's occurrence filter targets.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

from repro.data.dedup import DedupConfig, find_duplicates


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 1024
    seq_len: int = 256
    global_batch: int = 8
    seed: int = 0
    dup_frac: float = 0.3          # injected near-duplicate fraction
    dedup: bool = True
    dedup_buffer: int = 64         # sequences per dedup window
    prefetch: int = 2


@dataclasses.dataclass
class IteratorState:
    epoch_seed: int
    position: int

    def to_dict(self) -> dict:
        return {"epoch_seed": self.epoch_seed, "position": self.position}

    @classmethod
    def from_dict(cls, d: dict) -> "IteratorState":
        return cls(epoch_seed=int(d["epoch_seed"]),
                   position=int(d["position"]))


def _make_docs(rng: np.random.Generator, n: int, cfg: DataConfig
               ) -> np.ndarray:
    """Documents with zipf-ish tokens; ~dup_frac are near-duplicates."""
    base = rng.integers(1, cfg.vocab_size,
                        size=(n, cfg.seq_len)).astype(np.int32)
    n_dup = int(n * cfg.dup_frac)
    if n_dup:
        srcs = rng.integers(0, n - n_dup, size=n_dup)
        for j, s in enumerate(srcs):
            doc = base[s].copy()
            flips = rng.integers(0, cfg.seq_len, size=max(1, cfg.seq_len
                                                          // 50))
            doc[flips] = rng.integers(1, cfg.vocab_size, size=flips.size)
            base[n - n_dup + j] = doc
    return base


class TokenPipeline:
    """Checkpointable batch iterator with optional LSH dedup + prefetch."""

    def __init__(self, cfg: DataConfig, state: IteratorState | None = None):
        self.cfg = cfg
        self.state = state or IteratorState(epoch_seed=cfg.seed, position=0)
        self.dedup_stats: dict = {"dropped": 0, "seen": 0}

    def _buffer(self, index: int) -> np.ndarray:
        rng = np.random.default_rng(
            (self.state.epoch_seed * 1_000_003 + index) & 0x7FFFFFFF)
        docs = _make_docs(rng, self.cfg.dedup_buffer, self.cfg)
        if self.cfg.dedup:
            keep, stats = find_duplicates(docs)
            self.dedup_stats["dropped"] += stats["dropped"]
            self.dedup_stats["seen"] += len(docs)
            docs = docs[keep]
        return docs

    def batches(self) -> Iterator[dict]:
        """Yields {"tokens", "labels", "loss_mask"} of (B, S) arrays.

        Leftover sequences beyond the batch are DISCARDED at each batch
        boundary so the iterator state (= next buffer index) makes resume
        bit-exact after checkpoint/restart.
        """
        cfg = self.cfg
        while True:
            idx = self.state.position
            pending: list[np.ndarray] = []
            while sum(len(p) for p in pending) < cfg.global_batch:
                pending.append(self._buffer(idx))
                idx += 1
            pool = np.concatenate(pending)
            batch_docs = pool[: cfg.global_batch]
            self.state.position = idx
            tokens = batch_docs
            labels = np.concatenate(
                [tokens[:, 1:], np.zeros((tokens.shape[0], 1), np.int32)],
                axis=1)
            mask = np.ones_like(labels, np.float32)
            mask[:, -1] = 0.0
            yield {"tokens": tokens, "labels": labels, "loss_mask": mask}

    def prefetched(self) -> Iterator[dict]:
        q: queue.Queue = queue.Queue(maxsize=self.cfg.prefetch)
        stop = object()

        def worker():
            for b in self.batches():
                q.put(b)

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        while True:
            yield q.get()
