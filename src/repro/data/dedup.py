"""LSH near-duplicate detection for training data — the paper's pipeline
as a first-class data-pipeline stage.

Token sequences → n-gram shingles → feature-hashed sparse binary vectors →
the exact ``core.lsh`` Min-Max signature + sort-based search machinery →
near-duplicate groups → keep one representative per group.

This is the canonical production transplant of FAST's shape (fingerprint →
LSH → postprocess): corpus dedup instead of earthquake detection, same
skew pathologies (boilerplate ≈ repeating background noise — the
occurrence filter drops it the same way).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lsh as lsh_mod
from repro.core.lsh import LSHConfig
from repro.utils import hash_u32, mix32


@dataclasses.dataclass(frozen=True)
class DedupConfig:
    shingle: int = 8           # n-gram length
    feature_dim: int = 1024    # feature-hash buckets (fingerprint dim)
    lsh: LSHConfig = LSHConfig(n_tables=32, n_funcs=4, n_matches=2,
                               bucket_cap=8, min_dt=0,
                               occurrence_frac=0.0, seed=99)
    jaccard_threshold: float = 0.5   # exact verification threshold


def shingle_fingerprints(tokens: jax.Array, cfg: DedupConfig) -> jax.Array:
    """(N, S) int tokens → (N, feature_dim) binary shingle fingerprints."""
    n, s = tokens.shape
    k = cfg.shingle
    windows = jnp.stack([tokens[:, i:s - k + 1 + i] for i in range(k)],
                        axis=-1)  # (N, S-k+1, k)
    h = jnp.zeros(windows.shape[:2], jnp.uint32)
    for i in range(k):
        h = mix32(h ^ hash_u32(windows[..., i], 0x51AB + i))
    buckets = (h % jnp.uint32(cfg.feature_dim)).astype(jnp.int32)
    onehot = jax.nn.one_hot(buckets, cfg.feature_dim, dtype=jnp.bool_)
    return onehot.any(axis=1)


def find_duplicates(tokens: np.ndarray, cfg: DedupConfig | None = None
                    ) -> tuple[np.ndarray, dict]:
    """Return (keep_mask (N,), stats) over a buffer of token sequences."""
    cfg = cfg or DedupConfig()
    fp = shingle_fingerprints(jnp.asarray(tokens), cfg)
    pairs, stats = lsh_mod.search(fp, cfg.lsh)
    # exact verification (the knob the paper's proxy lacks)
    from repro.utils import pack_bits
    packed = pack_bits(fp)
    jac = lsh_mod.verify_jaccard(packed, pairs)
    dup = np.asarray(pairs.valid & (jac >= cfg.jaccard_threshold))
    i1 = np.asarray(pairs.idx1)[dup]
    i2 = np.asarray(pairs.idx2)[dup]
    keep = np.ones(tokens.shape[0], bool)
    # union-find-lite: drop the higher index of each verified pair
    keep[i2] = False
    sstats = {"candidate_pairs": int(np.asarray(pairs.count())),
              "verified_dups": int(dup.sum()),
              "dropped": int((~keep).sum())}
    return keep, sstats
