"""Property-based tests for StreamingIndex / ingestion invariants.

Each property lives in a ``check_*`` helper invoked two ways: through
hypothesis (via the ``_hypothesis_compat`` shim — skipped cleanly when
hypothesis is not installed) and through a deterministic seed sweep so the
invariants are exercised in network-less environments too.

Properties (ISSUE 2):
  * insert→query roundtrip is split-invariant: pairs found when a batch is
    inserted in arbitrary sub-batches equal the single-batch result;
  * ring eviction never resurrects ids: a fresh query sees exactly the
    ``cap`` newest same-signature residents;
  * ``expire(min_id)`` leaves no reachable id < min_id;
  * chunked ingestion is sample-exact for random chunk lengths.

Data-quality properties (ISSUE 4):
  * gap-masked ingest is sample-exact vs contiguous ingest on the non-gap
    region, and the emitted fingerprint masks are exactly the windows
    that touch a missing sample;
  * reorder reconciliation is permutation-invariant within the horizon,
    and re-pushing an already-delivered chunk is always a no-op;
  * quarantined (saturated) buckets never emit pairs.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import fingerprint as F
from repro.core.lsh import INVALID, LSHConfig
from repro.stream import StreamIndexConfig, WaveformRing
from repro.stream import index as SI

CFG = LSHConfig(n_tables=12, n_funcs=4, n_matches=1, bucket_cap=8,
                min_dt=1, occurrence_frac=0.0)
SET = settings(max_examples=15, deadline=None)


def _sigs_with_dups(rng, n, n_dups, t=CFG.n_tables):
    """Random signatures with ``n_dups`` rows copied from earlier rows."""
    sigs = rng.integers(0, 2**32, (n, t), dtype=np.uint32)
    for _ in range(n_dups):
        src, dst = sorted(rng.integers(0, n, 2).tolist())
        if src != dst:
            sigs[dst] = sigs[src]
    return jnp.asarray(sigs)


def _pair_map(pairs):
    v = np.asarray(pairs.valid)
    return dict(zip(zip(np.asarray(pairs.idx1)[v].tolist(),
                        np.asarray(pairs.idx2)[v].tolist()),
                    np.asarray(pairs.sim)[v].tolist()))


def _splits(rng, n, k):
    """n items into ≤k random non-empty contiguous batches."""
    cuts = np.unique(rng.integers(1, n, size=max(0, k - 1)))
    return np.split(np.arange(n), cuts)


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------


def check_split_invariance(seed: int, n_batches: int):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 40))
    sigs = _sigs_with_dups(rng, n, n_dups=int(rng.integers(1, 5)))
    ids = jnp.arange(n, dtype=jnp.int32)
    icfg = StreamIndexConfig(n_buckets=1024, bucket_cap=n)  # no eviction

    one = SI.init_index(CFG, icfg)
    one = SI.insert(one, sigs, ids, CFG)
    expect = _pair_map(SI.query(one, sigs, ids, CFG))

    split = SI.init_index(CFG, icfg)
    got = {}
    for idx in _splits(rng, n, n_batches):
        b_sigs, b_ids = sigs[idx], ids[idx]
        split = SI.insert(split, b_sigs, b_ids, CFG)
        got.update(_pair_map(SI.query(split, b_sigs, b_ids, CFG)))
    assert got == expect, (seed, n_batches, got, expect)


def check_eviction_never_resurrects(seed: int, cap: int, n_ins: int):
    rng = np.random.default_rng(seed)
    cfg = LSHConfig(n_tables=4, n_funcs=4, n_matches=1, bucket_cap=8,
                    min_dt=1, occurrence_frac=0.0)
    state = SI.init_index(cfg, StreamIndexConfig(n_buckets=64,
                                                 bucket_cap=cap))
    sig = jnp.asarray(rng.integers(0, 2**32, (1, 4), dtype=np.uint32))
    for idx in _splits(rng, n_ins, int(rng.integers(1, n_ins + 1))):
        batch = jnp.tile(sig, (len(idx), 1))
        state = SI.insert(state, batch,
                          jnp.asarray(idx, jnp.int32), cfg)
    pairs = SI.query(state, sig, jnp.asarray([n_ins], jnp.int32), cfg)
    v = np.asarray(pairs.valid)
    partners = set(np.asarray(pairs.idx1)[v].tolist())
    newest = set(range(max(0, n_ins - cap), n_ins))
    assert partners == newest, (seed, cap, n_ins, partners, newest)


def check_expire_unreachable(seed: int):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 48))
    sigs = _sigs_with_dups(rng, n, n_dups=int(rng.integers(2, 8)))
    state = SI.init_index(CFG, StreamIndexConfig(n_buckets=256,
                                                 bucket_cap=8))
    state = SI.insert(state, sigs, jnp.arange(n, dtype=jnp.int32), CFG)
    min_id = int(rng.integers(0, n + 1))
    state = SI.expire(state, min_id)
    resident = np.asarray(state.ids)
    resident = resident[resident != INVALID]
    assert (resident >= min_id).all(), (seed, min_id, resident.min())
    pairs = SI.query(state, sigs,
                     1000 + jnp.arange(n, dtype=jnp.int32), CFG)
    v = np.asarray(pairs.valid)
    assert (np.asarray(pairs.idx1)[v] >= min_id).all(), (seed, min_id)


def check_chunked_ingest_sample_exact(seed: int):
    rng = np.random.default_rng(seed)
    fcfg = F.FingerprintConfig(img_freq=8, img_time=16, img_hop=4, top_k=16,
                               mad_sample_rate=1.0)
    block_fp = int(rng.integers(2, 9))
    ring = WaveformRing(fcfg, block_fingerprints=block_fp)
    n_samples = int(rng.integers(4_000, 20_000))
    wf = rng.standard_normal(n_samples).astype(np.float32)
    # random chunk lengths, including tiny and empty-ish chunks
    pos, blocks = 0, []
    while pos < n_samples:
        step = int(rng.integers(1, 3_000))
        blocks.extend(ring.push(wf[pos: pos + step]))
        pos += step
    lag, bs = fcfg.lag_samples, fcfg.block_samples(block_fp)
    for base, blk, mask in blocks:
        assert mask is None
        np.testing.assert_array_equal(blk, wf[base * lag: base * lag + bs])
    tail = ring.flush_partial()
    got = len(blocks) * block_fp
    if tail is not None:
        base, blk, mask = tail
        n_valid = int(mask.sum())
        assert mask[:n_valid].all()      # clean tail mask is a prefix
        # the tail block carries every remaining buffered sample, padded
        extent = min(bs, n_samples - base * lag)
        np.testing.assert_array_equal(
            blk[:extent], wf[base * lag: base * lag + extent])
        assert (blk[extent:] == 0).all()
        # valid fingerprints must fit fully inside real samples
        w = fcfg.window_samples
        assert (n_valid - 1) * lag + w <= extent
        got += n_valid
    assert got == fcfg.n_fingerprints(n_samples), (seed, got)


def _ring_fcfg():
    return F.FingerprintConfig(img_freq=8, img_time=16, img_hop=4, top_k=16,
                               mad_sample_rate=1.0)


def _drain(ring):
    """All remaining (base, block, mask) items: held-back blocks + tail."""
    out = ring.flush_ready()
    tail = ring.flush_partial()
    if tail is not None:
        out.append(tail)
    return out


def _blocks_equal(a, b):
    assert len(a) == len(b), (len(a), len(b))
    for (b1, blk1, m1), (b2, blk2, m2) in zip(a, b):
        assert b1 == b2
        np.testing.assert_array_equal(blk1, blk2)
        if m1 is None or m2 is None:
            assert (m1 is None or np.asarray(m1).all())
            assert (m2 is None or np.asarray(m2).all())
        else:
            np.testing.assert_array_equal(m1, m2)


def check_gap_masked_ingest_sample_exact(seed: int):
    """NaN holes: non-gap samples are bit-exact vs the clean run, and the
    fingerprint masks are exactly the windows touching a hole."""
    rng = np.random.default_rng(seed)
    fcfg = _ring_fcfg()
    block_fp = int(rng.integers(2, 9))
    n_samples = int(rng.integers(6_000, 16_000))
    wf = rng.standard_normal(n_samples).astype(np.float32)
    missing = np.zeros(n_samples, bool)
    for _ in range(int(rng.integers(1, 4))):
        dur = int(rng.integers(50, 900))
        i0 = int(rng.integers(0, max(1, n_samples - dur)))
        missing[i0:i0 + dur] = True
    dirty = wf.copy()
    dirty[missing] = np.nan

    clean_ring = WaveformRing(fcfg, block_fingerprints=block_fp)
    dirty_ring = WaveformRing(fcfg, block_fingerprints=block_fp)
    clean_blocks, dirty_blocks = [], []
    pos = 0
    while pos < n_samples:
        step = int(rng.integers(1, 2_500))
        clean_blocks.extend(clean_ring.push(wf[pos: pos + step]))
        dirty_blocks.extend(dirty_ring.push(dirty[pos: pos + step]))
        pos += step
    clean_blocks += _drain(clean_ring)
    dirty_blocks += _drain(dirty_ring)
    assert dirty_ring.quality["missing_samples"] == int(missing.sum())

    w, lag = fcfg.window_samples, fcfg.lag_samples
    assert len(clean_blocks) == len(dirty_blocks)
    for (cb, cblk, cm), (db, dblk, dm) in zip(clean_blocks, dirty_blocks):
        assert cb == db
        ok = ~missing[cb * lag: cb * lag + dblk.size]
        ok = np.pad(ok, (0, dblk.size - ok.size))
        # non-gap samples are bit-exact; gap samples are sentinel zeros
        np.testing.assert_array_equal(dblk[ok], cblk[ok])
        assert (dblk[~ok] == 0).all()
        # fingerprint mask == "window touches no missing sample"
        cmask = (np.ones(block_fp, bool) if cm is None
                 else np.asarray(cm, bool))
        dmask = (np.ones(block_fp, bool) if dm is None
                 else np.asarray(dm, bool))
        for i in range(block_fp):
            if not cmask[i]:              # tail-cut fp: same in both runs
                assert not dmask[i]
                continue
            touches = missing[(cb + i) * lag: (cb + i) * lag + w].any()
            assert dmask[i] == (not touches), (seed, cb, i)


def check_reorder_permutation_invariant(seed: int):
    """Chunks delivered in any order within the horizon (including exact
    re-deliveries) yield the identical block/mask stream."""
    rng = np.random.default_rng(seed)
    fcfg = _ring_fcfg()
    block_fp = int(rng.integers(2, 7))
    chunk_len = int(rng.integers(200, 1_200))
    n_chunks = int(rng.integers(8, 20))
    swap_span = 2                         # max displacement in chunks
    horizon = (swap_span + 1) * chunk_len
    wf = rng.standard_normal(n_chunks * chunk_len).astype(np.float32)
    chunks = [(i * chunk_len, wf[i * chunk_len:(i + 1) * chunk_len])
              for i in range(n_chunks)]
    order = np.arange(n_chunks)
    for i in range(0, n_chunks - swap_span, swap_span + 1):
        seg = order[i:i + swap_span + 1]
        rng.shuffle(seg)                  # local shuffle ≤ horizon

    ref = WaveformRing(fcfg, block_fp, reorder_horizon=horizon)
    got = WaveformRing(fcfg, block_fp, reorder_horizon=horizon)
    ref_blocks, got_blocks = [], []
    for off, c in chunks:
        ref_blocks.extend(ref.push(c, off))
    for k in order:
        got_blocks.extend(got.push(chunks[k][1], chunks[k][0]))
        if rng.random() < 0.3:            # duplicate re-delivery: a no-op
            got_blocks.extend(got.push(chunks[k][1], chunks[k][0]))
    ref_blocks += _drain(ref)
    got_blocks += _drain(got)
    _blocks_equal(ref_blocks, got_blocks)
    assert got.quality["late_dropped_samples"] == 0
    # in-order delivery through the horizon matches a no-horizon ring too
    plain = WaveformRing(fcfg, block_fp)
    plain_blocks = []
    for off, c in chunks:
        plain_blocks.extend(plain.push(c, off))
    plain_blocks += _drain(plain)
    _blocks_equal(ref_blocks, plain_blocks)


def check_quarantined_bucket_never_emits(seed: int, saturation: int):
    """Once a bucket's lifetime traffic passes the saturation limit, no
    further pair is emitted from it; below the limit pairs flow."""
    rng = np.random.default_rng(seed)
    cfg = LSHConfig(n_tables=4, n_funcs=4, n_matches=1, bucket_cap=8,
                    min_dt=1, occurrence_frac=0.0)
    state = SI.init_index(cfg, StreamIndexConfig(n_buckets=64,
                                                 bucket_cap=8))
    sig = jnp.asarray(rng.integers(0, 2**32, (1, 4), dtype=np.uint32))
    n_ins = saturation + int(rng.integers(1, 6))
    for i in range(n_ins):
        state = SI.insert(state, sig, jnp.asarray([i], jnp.int32), cfg)
        pairs = SI.query(state, sig, jnp.asarray([i], jnp.int32), cfg,
                         saturation=saturation)
        emitted = int(np.asarray(pairs.valid).sum())
        if i + 1 > saturation:            # bucket traffic past the limit
            assert emitted == 0, (seed, i)
        elif i > 0:
            assert emitted > 0, (seed, i)
    # an unguarded query still sees the residents (quarantine ≠ eviction)
    pairs = SI.query(state, sig, jnp.asarray([n_ins], jnp.int32), cfg)
    assert int(np.asarray(pairs.valid).sum()) > 0


# ---------------------------------------------------------------------------
# hypothesis drivers (skip when hypothesis is missing)
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**31 - 1), st.integers(1, 6))
@SET
def test_split_invariance_hyp(seed, n_batches):
    check_split_invariance(seed, n_batches)


@given(st.integers(0, 2**31 - 1), st.integers(1, 4), st.integers(5, 12))
@SET
def test_eviction_hyp(seed, cap, n_ins):
    check_eviction_never_resurrects(seed, cap, n_ins)


@given(st.integers(0, 2**31 - 1))
@SET
def test_expire_hyp(seed):
    check_expire_unreachable(seed)


@given(st.integers(0, 2**31 - 1))
@SET
def test_chunked_ingest_hyp(seed):
    check_chunked_ingest_sample_exact(seed)


@given(st.integers(0, 2**31 - 1))
@SET
def test_gap_masked_ingest_hyp(seed):
    check_gap_masked_ingest_sample_exact(seed)


@given(st.integers(0, 2**31 - 1))
@SET
def test_reorder_permutation_hyp(seed):
    check_reorder_permutation_invariant(seed)


@given(st.integers(0, 2**31 - 1), st.integers(2, 12))
@SET
def test_quarantine_hyp(seed, saturation):
    check_quarantined_bucket_never_emits(seed, saturation)


# ---------------------------------------------------------------------------
# deterministic seed sweep (always runs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(5))
def test_split_invariance(seed):
    check_split_invariance(seed, n_batches=(seed % 5) + 1)


@pytest.mark.parametrize("seed,cap,n_ins",
                         [(0, 1, 5), (1, 2, 7), (2, 3, 12), (3, 4, 9)])
def test_eviction_never_resurrects(seed, cap, n_ins):
    check_eviction_never_resurrects(seed, cap, n_ins)


@pytest.mark.parametrize("seed", range(5))
def test_expire_unreachable(seed):
    check_expire_unreachable(seed)


@pytest.mark.parametrize("seed", range(4))
def test_chunked_ingest_sample_exact(seed):
    check_chunked_ingest_sample_exact(seed)


@pytest.mark.parametrize("seed", range(4))
def test_gap_masked_ingest_sample_exact(seed):
    check_gap_masked_ingest_sample_exact(seed)


@pytest.mark.parametrize("seed", range(4))
def test_reorder_permutation_invariant(seed):
    check_reorder_permutation_invariant(seed)


@pytest.mark.parametrize("seed,saturation", [(0, 2), (1, 5), (2, 8), (3, 3)])
def test_quarantined_bucket_never_emits(seed, saturation):
    check_quarantined_bucket_never_emits(seed, saturation)
