"""Fingerprint extraction (paper §5): shapes, MAD sampling, band cut."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import fingerprint as F

CFG = F.FingerprintConfig(img_freq=16, img_time=32, img_hop=8, top_k=64,
                          mad_sample_rate=1.0)


def _wave(rng, seconds=120.0):
    return jnp.asarray(rng.standard_normal(int(seconds * CFG.fs))
                       .astype(np.float32))


def test_shapes_and_counts(rng):
    x = _wave(rng)
    bits, packed = F.fingerprints_from_waveform(x, CFG)
    n_expected = CFG.n_fingerprints(x.shape[0])
    assert bits.shape == (n_expected, CFG.fp_dim)
    assert packed.shape == (n_expected, CFG.fp_dim // 32)


def test_topk_sets_exactly_k_bits_per_row(rng):
    x = _wave(rng)
    bits, _ = F.fingerprints_from_waveform(x, CFG)
    per_row = np.asarray(bits).sum(axis=1)
    # ties can add a few extra; never fewer than K
    assert (per_row >= CFG.top_k).all()
    assert (per_row <= CFG.top_k + 8).all()


def test_deterministic(rng):
    x = _wave(rng, 60.0)
    b1, _ = F.fingerprints_from_waveform(x, CFG, key=jax.random.PRNGKey(1))
    b2, _ = F.fingerprints_from_waveform(x, CFG, key=jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))


def test_mad_sampling_accuracy(rng):
    """§5.2/Table 6: sampled MAD stats ≈ full stats → fingerprints mostly
    identical."""
    x = _wave(rng, 240.0)
    full, _ = F.fingerprints_from_waveform(
        x, F.FingerprintConfig(**{**CFG.__dict__, "mad_sample_rate": 1.0}))
    sampled, _ = F.fingerprints_from_waveform(
        x, F.FingerprintConfig(**{**CFG.__dict__, "mad_sample_rate": 0.2}),
        key=jax.random.PRNGKey(7))
    agree = (np.asarray(full) == np.asarray(sampled)).mean()
    # paper Table 6 reports 99.5% at 10% sampling on 1.3M fingerprints;
    # our test corpus is ~900 fingerprints so the estimator is noisier
    assert agree > 0.93, agree


def test_band_cut_excludes_out_of_band_energy(rng):
    """§6.5: a strong 30 Hz hum must not move in-band (3–20 Hz) features."""
    t = np.arange(int(120 * CFG.fs)) / CFG.fs
    base = rng.standard_normal(t.size).astype(np.float32)
    hum = 5.0 * np.sin(2 * np.pi * 30.0 * t).astype(np.float32)
    s_base = np.asarray(F.spectrogram(jnp.asarray(base), CFG))
    s_hum = np.asarray(F.spectrogram(jnp.asarray(base + hum), CFG))
    # banded spectrogram only covers 3-20 Hz → hum adds spectral leakage
    # only; relative change stays small
    rel = np.abs(s_hum - s_base).mean() / (np.abs(s_base).mean() + 1e-9)
    assert rel < 0.15, rel


def test_mad_normalize_robust_to_scale(rng):
    c = jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32))
    med, mad = F.mad_stats(c, 1.0, jax.random.PRNGKey(0))
    z1 = F.mad_normalize(c, med, mad)
    med2, mad2 = F.mad_stats(c * 10, 1.0, jax.random.PRNGKey(0))
    z2 = F.mad_normalize(c * 10, med2, mad2)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), atol=1e-3)


def test_frame_strides():
    x = jnp.arange(10.0)
    fr = F.frame(x, 4, 2)
    np.testing.assert_array_equal(np.asarray(fr[0]), [0, 1, 2, 3])
    np.testing.assert_array_equal(np.asarray(fr[1]), [2, 3, 4, 5])
    assert fr.shape == (4, 4)
