"""End-to-end detection on synthetic seismic data (paper Figure 2 system
behaviour): recall vs injected ground truth, occurrence-filter effects,
and the one-core golden pin — the unified batch driver (``detect_events``
replaying through the streaming station pool) must reproduce the deleted
legacy host loop bit-exactly (``tests/golden/batch_detect.json``,
regenerable via ``scratch/gen_golden_batch.py``)."""
import json
import pathlib

import numpy as np
import pytest

from repro.core import (AlignConfig, DetectConfig, FingerprintConfig,
                        LSHConfig, SynthConfig, make_dataset)
from repro.core.detect import detect_events, recall_against_truth


def _cfg(fcfg=None):
    fcfg = fcfg or FingerprintConfig(img_time=32, img_hop=4, top_k=200,
                                     mad_sample_rate=1.0)
    lcfg = LSHConfig(n_tables=100, n_funcs=4, n_matches=2, bucket_cap=8,
                     min_dt=fcfg.overlap_fingerprints, occurrence_frac=0.05)
    acfg = AlignConfig(channel_threshold=3, min_cluster_sim=4,
                       min_cluster_size=1, min_stations=2,
                       onset_tol=int(10 * fcfg.fs / fcfg.lag_samples))
    return DetectConfig(fingerprint=fcfg, lsh=lcfg, align=acfg)


@pytest.fixture(scope="module")
def dataset():
    return make_dataset(SynthConfig(duration_s=420.0, n_stations=3,
                                    n_sources=2, events_per_source=4,
                                    repeating_noise_stations=(0,),
                                    event_snr=3.0, seed=3))


def test_detection_recall(dataset):
    cfg = _cfg()
    det, events, times, stats = detect_events(dataset.waveforms, cfg)
    rec = recall_against_truth(det, events, dataset, cfg.fingerprint)
    assert rec["recall"] >= 0.75, rec
    assert stats["detections"] >= 1


def test_network_filter_reduces_single_station_noise(dataset):
    """Station-level events at the noisy station exceed network-confirmed
    detections (the alignment stage suppresses single-station matches)."""
    cfg = _cfg()
    det, events, _, stats = detect_events(dataset.waveforms, cfg)
    station_total = sum(int(e.count()) for e in events)
    assert stats["detections"] <= station_total


def test_occurrence_filter_only_fires_on_noisy_station(dataset):
    cfg = _cfg()
    _, _, _, stats = detect_events(dataset.waveforms, cfg)
    # station 0 carries injected repeating noise; others should be ~clean
    assert stats["station0_excluded"] >= 0
    assert stats["station1_excluded"] <= stats["station0_excluded"] + 5


BATCH_GOLDEN = pathlib.Path(__file__).parent / "golden" / "batch_detect.json"


def test_unified_driver_matches_legacy_golden(dataset):
    """One core, two drivers (ISSUE 5 acceptance): the replayed batch
    driver reproduces the legacy per-station host loop's post-filter pair
    triplets (idx1, idx2, sim), per-station stats, detections count, and
    ``recall_against_truth`` numbers bit-exactly on the seed synthetic
    dataset."""
    gold = json.loads(BATCH_GOLDEN.read_text())
    assert gold["synth"]["seed"] == dataset.cfg.seed  # same pinned dataset
    assert gold["synth"]["duration_s"] == dataset.cfg.duration_s
    cfg = _cfg()
    det, events, times, stats = detect_events(dataset.waveforms, cfg,
                                              keep_pairs=True)
    pairs = stats.pop("_station_pairs")
    # ISSUE-6 in-dispatch telemetry counters ride alongside the legacy
    # stats; the golden pin covers the pre-telemetry key set
    qc = {k: stats.pop(k) for k in list(stats)
          if k == "drops" or k.endswith("_qc")}
    assert qc["drops"]["pairs_emitted"] > 0  # counters actually ran
    assert stats == gold["stats"]
    rec = recall_against_truth(det, events, dataset, cfg.fingerprint)
    assert rec == gold["recall"]
    for st, p in enumerate(pairs):
        v = np.asarray(p.valid)
        got = sorted(zip(np.asarray(p.idx1)[v].tolist(),
                         np.asarray(p.idx2)[v].tolist(),
                         np.asarray(p.sim)[v].tolist()))
        want = [tuple(t) for t in gold["station_pairs"][st]]
        assert got == want, (st, len(got), len(want))
    # the replay attributed its stages via the span layer: the fused step
    # is its own stage and search_s stays as a read-only alias of it
    assert times.search_s > 0 and times.total() > 0
    assert times.fused_step_s == times.search_s


def test_unified_driver_quality_knobs_in_batch(dataset):
    """The streaming guards are available to batch replay: an occ-limited
    replay of the noisy station still runs end-to-end, and with the
    limiter off the scfg override reproduces the default pair set."""
    import dataclasses
    from repro.core.detect import replay_config
    cfg = _cfg()
    n_fp = cfg.fingerprint.n_fingerprints(dataset.waveforms.shape[1])
    base = replay_config(cfg.lsh)
    limited = dataclasses.replace(
        base, occ_limit=10_000,
        index=dataclasses.replace(base.index, occ_slots=n_fp))
    _, _, _, s_def = detect_events(dataset.waveforms, cfg)
    _, _, _, s_lim = detect_events(dataset.waveforms, cfg, scfg=limited)
    # a sky-high limit never fires: identical stats incl. pair counts
    assert s_lim == s_def


def test_detect_step_jittable(dataset):
    import jax
    import jax.numpy as jnp
    from repro.core.detect import detect_step
    from repro.core import fingerprint as F
    cfg = _cfg(FingerprintConfig(img_time=32, img_hop=8, top_k=64,
                                 mad_sample_rate=1.0, img_freq=16))
    x = jnp.asarray(dataset.waveforms[1][:12000])
    spec = F.spectrogram(x, cfg.fingerprint)
    imgs = F.spectral_images(spec, cfg.fingerprint)
    coeffs = F.wavelet_coeffs(imgs, cfg.fingerprint)
    med, mad = F.mad_stats(coeffs, 1.0, jax.random.PRNGKey(0))
    import functools
    step = jax.jit(functools.partial(detect_step, cfg=cfg))
    out = step(x, med, mad)
    assert np.isfinite(np.asarray(out["ev_score"])).all()
    out2 = step(x, med, mad)
    np.testing.assert_array_equal(np.asarray(out["pair_valid"]),
                                  np.asarray(out2["pair_valid"]))
