"""End-to-end detection on synthetic seismic data (paper Figure 2 system
behaviour): recall vs injected ground truth, occurrence-filter effects."""
import numpy as np
import pytest

from repro.core import (AlignConfig, DetectConfig, FingerprintConfig,
                        LSHConfig, SynthConfig, make_dataset)
from repro.core.detect import detect_events, recall_against_truth


def _cfg(fcfg=None):
    fcfg = fcfg or FingerprintConfig(img_time=32, img_hop=4, top_k=200,
                                     mad_sample_rate=1.0)
    lcfg = LSHConfig(n_tables=100, n_funcs=4, n_matches=2, bucket_cap=8,
                     min_dt=fcfg.overlap_fingerprints, occurrence_frac=0.05)
    acfg = AlignConfig(channel_threshold=3, min_cluster_sim=4,
                       min_cluster_size=1, min_stations=2,
                       onset_tol=int(10 * fcfg.fs / fcfg.lag_samples))
    return DetectConfig(fingerprint=fcfg, lsh=lcfg, align=acfg)


@pytest.fixture(scope="module")
def dataset():
    return make_dataset(SynthConfig(duration_s=420.0, n_stations=3,
                                    n_sources=2, events_per_source=4,
                                    repeating_noise_stations=(0,),
                                    event_snr=3.0, seed=3))


def test_detection_recall(dataset):
    cfg = _cfg()
    det, events, times, stats = detect_events(dataset.waveforms, cfg)
    rec = recall_against_truth(det, events, dataset, cfg.fingerprint)
    assert rec["recall"] >= 0.75, rec
    assert stats["detections"] >= 1


def test_network_filter_reduces_single_station_noise(dataset):
    """Station-level events at the noisy station exceed network-confirmed
    detections (the alignment stage suppresses single-station matches)."""
    cfg = _cfg()
    det, events, _, stats = detect_events(dataset.waveforms, cfg)
    station_total = sum(int(e.count()) for e in events)
    assert stats["detections"] <= station_total


def test_occurrence_filter_only_fires_on_noisy_station(dataset):
    cfg = _cfg()
    _, _, _, stats = detect_events(dataset.waveforms, cfg)
    # station 0 carries injected repeating noise; others should be ~clean
    assert stats["station0_excluded"] >= 0
    assert stats["station1_excluded"] <= stats["station0_excluded"] + 5


def test_detect_step_jittable(dataset):
    import jax
    import jax.numpy as jnp
    from repro.core.detect import detect_step
    from repro.core import fingerprint as F
    cfg = _cfg(FingerprintConfig(img_time=32, img_hop=8, top_k=64,
                                 mad_sample_rate=1.0, img_freq=16))
    x = jnp.asarray(dataset.waveforms[1][:12000])
    spec = F.spectrogram(x, cfg.fingerprint)
    imgs = F.spectral_images(spec, cfg.fingerprint)
    coeffs = F.wavelet_coeffs(imgs, cfg.fingerprint)
    med, mad = F.mad_stats(coeffs, 1.0, jax.random.PRNGKey(0))
    import functools
    step = jax.jit(functools.partial(detect_step, cfg=cfg))
    out = step(x, med, mad)
    assert np.isfinite(np.asarray(out["ev_score"])).all()
    out2 = step(x, med, mad)
    np.testing.assert_array_equal(np.asarray(out["pair_valid"]),
                                  np.asarray(out2["pair_valid"]))
