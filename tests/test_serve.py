"""Serving-tier tests (ISSUE 7): the concurrent, backpressured query
service over the pooled streaming index.

Pins the tentpole semantics — batched ticks answer exactly what
sequential single-slot serving answers, the admission bound sheds
deterministically, idle ticks never dispatch — plus the three serving
bugfixes (empty-run percentiles, unfinished-request latency, restore
pool-width validation, bare ``--metrics-file``) and the interleaved
ingest/serve session. A slow-marked guard mirrors the ``bench_e2e``
pattern for ``BENCH_serve.json``.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.configs.fast_seismic import (latency_config,
                                        stream_latency_smoke_config)
from repro.core.synth import SynthConfig, make_dataset
from repro.launch import serve_detect
from repro.launch.serve_detect import (QueryRequest, ServeDetectEngine,
                                       ServeSession)
from repro.stream.engine import StreamingDetector

_CACHE = {}


def _corpus():
    """One ingested 2-station detector (latency config — tiny blocks)
    shared by the engine tests; engines built from its *copied* serving
    state never mutate it."""
    if "det" not in _CACHE:
        cfg, scfg = latency_config(), stream_latency_smoke_config()
        ds = make_dataset(SynthConfig(duration_s=60.0, n_stations=2,
                                      n_sources=2, events_per_source=4,
                                      event_snr=3.0, seed=7))
        det = StreamingDetector(cfg, scfg, n_stations=2)
        for start in range(0, ds.waveforms.shape[1], 1000):
            det.push(ds.waveforms[:, start: start + 1000])
        det.flush()
        assert all(st.stats_frozen for st in det.stations)
        _CACHE.update(cfg=cfg, scfg=scfg, ds=ds, det=det,
                      serving=det.pool_serving_state())
    return _CACHE


def _engine(n_slots=4, max_queue=64, **kw) -> ServeDetectEngine:
    """Fresh engine (own telemetry registry) over the shared corpus."""
    c = _corpus()
    state, med, mad = c["serving"]
    return ServeDetectEngine(c["cfg"], c["scfg"], state, (med, mad),
                             n_slots=n_slots, max_queue=max_queue, **kw)


def _requests(n, win_s=8.0, seed=5) -> list:
    c = _corpus()
    wf = c["ds"].waveforms[0]
    win = int(win_s * c["cfg"].fingerprint.fs)
    rng = np.random.default_rng(seed)
    starts = rng.integers(0, wf.size - win, size=n)
    return [QueryRequest(rid=i, window=wf[s: s + win])
            for i, s in enumerate(starts)]


# ---------------------------------------------------------------------------
# tentpole semantics
# ---------------------------------------------------------------------------


def test_batched_ticks_match_sequential_single_slot():
    """Concurrent slots change the packing, never the answers: every
    request returns the identical match set whether it shared a batched
    dispatch with three neighbours or had the engine to itself."""
    reqs_a = _requests(6)
    reqs_b = _requests(6)
    stats_a = _engine(n_slots=4).run(reqs_a)
    stats_b = _engine(n_slots=1).run(reqs_b)
    assert stats_a["served"] == stats_b["served"] == 6
    for ra, rb in zip(reqs_a, reqs_b):
        assert ra.outcome == rb.outcome == "served"
        assert sorted(ra.matches) == sorted(rb.matches)
    assert stats_a["hit_requests"] == stats_b["hit_requests"] >= 1
    # 6 requests (one block each at this window) pack into 2 four-slot
    # dispatches vs 6 single-slot dispatches
    assert stats_a["dispatches"] < stats_b["dispatches"]


def test_load_shedding_is_deterministic():
    """The admission bound is a contract: a burst of B > max_queue
    submissions sheds exactly B - max_queue, and everything accepted is
    served (the queue never grows past the bound)."""
    eng = _engine(n_slots=2, max_queue=3)
    reqs = _requests(10)
    accepted = [eng.submit(r) for r in reqs]
    assert accepted.count(True) == 3 and accepted.count(False) == 7
    shed = [r for r in reqs if r.outcome == "rejected"]
    assert len(shed) == 7 and all(r.done for r in shed)
    assert all(r.latency_s >= 0.0 for r in shed)        # completed at once
    assert len(eng.queue) == 3                          # bounded, always
    eng.drain()
    assert sum(1 for r in reqs if r.outcome == "served") == 3
    # the shared-registry view agrees
    reg = eng.telemetry.registry
    assert reg.total("serve_shed_total") == 7
    assert reg.counter("serve_requests_total", outcome="served").value == 3
    summary = eng.summary(reqs, 1.0)
    assert summary["shed"] == 7 and summary["served"] == 3


def test_idle_ticks_do_no_host_work(monkeypatch):
    """A tick with no active slots must not assemble a batch or reach the
    device dispatch at all."""
    eng = _engine(n_slots=4)

    def boom(*a, **k):
        raise AssertionError("idle tick reached the device dispatch")

    monkeypatch.setattr(serve_detect, "_serve_step", boom)
    for _ in range(3):
        assert eng.tick() == 0
    assert eng.ticks == 3 and eng.dispatches == 0
    reg = eng.telemetry.registry
    assert reg.total("serve_ticks_total") == 3
    assert reg.total("serve_dispatches_total") == 0


def test_lazy_state_queues_until_first_refresh():
    """An engine can start before the detector's statistics freeze:
    requests queue, ticks stay idle, and the first version-gated refresh
    unblocks serving."""
    c = _corpus()
    eng = ServeDetectEngine(c["cfg"], c["scfg"], n_slots=2, max_queue=8)
    reqs = _requests(3)
    for r in reqs:
        eng.submit(r)
    assert eng.tick() == 0 and eng.pending() == 3       # idle: no state
    assert eng.refresh_from(c["det"]) is True
    assert eng.serving_version == c["det"].serving_version
    assert eng.refresh_from(c["det"]) is False          # version-gated
    eng.drain()
    assert all(r.outcome == "served" for r in reqs)
    assert eng.telemetry.registry.total("serve_state_refreshes_total") == 1


def test_interleaved_session_serves_while_ingesting():
    """The cooperative loop: corpus chunks and query ticks share one
    thread, the pool snapshot refreshes mid-stream, and requests that
    arrived early are answered against the grown corpus."""
    c = _corpus()
    cfg, scfg, ds = c["cfg"], c["scfg"], c["ds"]
    det = StreamingDetector(cfg, scfg, n_stations=2)
    eng = ServeDetectEngine(cfg, scfg, n_slots=2, max_queue=16,
                            telemetry=det.telemetry)
    session = ServeSession(det, eng, refresh_every_chunks=2)
    reqs = _requests(6)
    chunks = np.array_split(ds.waveforms, 12, axis=1)
    for ci, chunk in enumerate(chunks):
        if ci % 2 == 0 and reqs[ci // 2:]:
            session.submit(reqs[ci // 2])
        session.ingest(chunk)
    served_live = sum(1 for r in reqs if r.outcome == "served")
    session.finish()
    assert all(r.done for r in reqs)
    assert sum(1 for r in reqs if r.outcome == "served") == 6
    assert session.refreshes >= 2            # pool grew under the engine
    assert eng.serving_version == det.serving_version
    assert served_live >= 1                  # answered while still ingesting
    # queue wait vs service split is populated and consistent
    for r in reqs:
        assert r.latency_s >= r.service_s >= 0.0
        assert abs(r.latency_s - (r.queue_wait_s + r.service_s)) < 1e-6


# ---------------------------------------------------------------------------
# satellite bugfixes
# ---------------------------------------------------------------------------


def test_empty_request_list_summary():
    """`run([])` used to crash in np.percentile over an empty list."""
    eng = _engine(n_slots=2)
    stats = eng.run([])
    assert stats["requests"] == 0 and stats["served"] == 0
    assert stats["latency_ms_p50"] == 0.0
    assert stats["latency_ms_p99"] == 0.0


def test_all_shed_summary_has_no_percentile_crash():
    """Percentiles are over *served* requests only — an all-shed burst
    (nothing served) must still summarize."""
    eng = _engine(n_slots=2, max_queue=0)
    reqs = _requests(4)
    for r in reqs:
        eng.submit(r)
    stats = eng.summary(reqs, 1.0)
    assert stats["shed"] == 4 and stats["served"] == 0
    assert stats["latency_ms_p50"] == 0.0


def test_unfinished_request_latency_is_guarded():
    """`latency_s` used to return a negative number while a request was
    in flight (t_done=0.0 minus a live t_submit)."""
    r = QueryRequest(rid=0, window=np.zeros(16, np.float32))
    r.t_submit = 123.456
    assert r.latency_s == 0.0
    assert r.queue_wait_s == 0.0 and r.service_s == 0.0
    r.t_admit = 124.0
    assert r.service_s == 0.0                # admitted but not done
    r.t_done = 125.0
    assert r.latency_s > 0.0 and r.service_s > 0.0


def test_restore_validates_station_count(tmp_path):
    """`--restore` with a `--stations` that contradicts the snapshot's
    pool width must fail loudly instead of serving a mismatched pool."""
    from repro.configs.fast_seismic import (smoke_config,
                                            stream_smoke_config)
    det = StreamingDetector(smoke_config(), stream_smoke_config(),
                            n_stations=3)
    det.snapshot(str(tmp_path), step=1)
    with pytest.raises(SystemExit, match="3-station.*--stations 2"):
        serve_detect.main(["--restore", "--snapshot-dir", str(tmp_path),
                           "--stations", "2", "--duration-s", "400"])


def test_restore_grows_pool_elastically(tmp_path):
    """`--restore` with MORE stations than the snapshot no longer fails:
    the live pool grows elastically (ISSUE 10) — new stations join at
    the frontier and the service runs at the requested width."""
    from repro.configs.fast_seismic import (smoke_config,
                                            stream_smoke_config)
    cfg, scfg = smoke_config(), stream_smoke_config()
    ds = make_dataset(SynthConfig(duration_s=400.0, n_stations=2,
                                  n_sources=1, events_per_source=3,
                                  event_snr=3.0, seed=5))
    det = StreamingDetector(cfg, scfg, n_stations=2)
    for start in range(0, ds.waveforms.shape[1], 6000):
        det.push(ds.waveforms[:, start:start + 6000])
    assert det.pstate is not None          # stats frozen, pool live
    det.snapshot(str(tmp_path), step=1)
    stats = serve_detect.main(["--restore", "--snapshot-dir",
                               str(tmp_path), "--stations", "3",
                               "--requests", "2", "--slots", "2",
                               "--duration-s", "400"])
    assert stats["stations"] == 3


def test_metrics_file_written_without_metrics_every(tmp_path):
    """A bare ``--metrics-file`` (no ``--metrics-every``) used to gate
    the exposition rewrite on the heartbeat cadence and silently write
    nothing; it now always does a final write."""
    prom = tmp_path / "serve.prom"
    stats = serve_detect.main(["--requests", "2", "--slots", "2",
                               "--duration-s", "400",
                               "--metrics-file", str(prom)])
    assert stats["served"] == 2
    text = prom.read_text()
    assert "repro_chunks_total" in text
    assert "repro_real_time_factor" in text


# ---------------------------------------------------------------------------
# bench guard (mirrors test_bench_e2e_smoke)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_bench_serve_schema(tmp_path, monkeypatch):
    """``make bench-smoke`` contract for the serving tier: the quick
    benchmark runs, emits a schema-stable BENCH_serve.json with QPS /
    latency-split / shed-rate points at ≥3 concurrency levels per
    station count, and overload sheds deterministically."""
    import sys
    root = str(pathlib.Path(__file__).parent.parent)
    if root not in sys.path:
        sys.path.insert(0, root)
    monkeypatch.setenv("BENCH_OUT_DIR", str(tmp_path))
    from benchmarks import bench_serve
    out = bench_serve.main(["--quick"])
    assert out["schema"] == "bench-serve/v1"
    assert set(out) >= {"config_hash", "backend", "points", "overload",
                        "interleaved", "metrics"}
    written = json.loads((tmp_path / "BENCH_serve.json").read_text())
    assert written["config_hash"] == out["config_hash"]
    assert sorted({p["stations"] for p in out["points"]}) == [1, 4, 8]
    assert len(out["clients_levels"]) >= 3
    for p in out["points"]:
        assert {"qps", "shed_rate", "latency_ms", "queue_wait_ms",
                "service_ms"} <= set(p)
        assert {"p50", "p99"} <= set(p["latency_ms"])
        assert {"p50", "p99"} <= set(p["queue_wait_ms"])
        assert p["served"] + p["shed"] == p["requests"]
    # every station count sees at least one overloaded level shedding
    for s in (1, 4, 8):
        assert any(p["shed_rate"] > 0 for p in out["points"]
                   if p["stations"] == s)
    assert out["overload"]["deterministic"] is True
    assert out["overload"]["shed"] == \
        out["overload"]["burst"] - out["overload"]["max_queue"]
    inter = out["interleaved"]
    assert inter["served"] + inter["shed"] == inter["requests"]
    assert inter["refreshes"] >= 1
    # the serving engines publish into the detector's telemetry hub
    assert out["metrics"]["serve"]["served"] > 0
