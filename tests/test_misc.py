"""Theory curves, HLO analyzer, serve engine, synth ground truth."""
import numpy as np
import pytest

from repro.core import theory


def test_s_curve_monotone_in_similarity():
    s = np.linspace(0, 1, 21)
    p = theory.detection_probability(s, k=4, m=2, t=100)
    assert (np.diff(p) >= -1e-12).all()
    assert p[0] == pytest.approx(0.0, abs=1e-9)
    assert p[-1] == pytest.approx(1.0, abs=1e-9)


def test_s_curve_shifts_right_with_k_and_m():
    t50_a = theory.s_curve_threshold(4, 2)
    t50_b = theory.s_curve_threshold(8, 2)
    t50_c = theory.s_curve_threshold(4, 8)
    assert t50_b > t50_a and t50_c > t50_a


def test_equivalent_m_drops_when_k_rises():
    """§6.3: more hash functions → lower match threshold, same S-curve."""
    m_new = theory.equivalent_m(k_old=6, m_old=5, k_new=8)
    assert m_new < 5


def test_hlo_analyzer_counts_scan_trips():
    import jax
    import jax.numpy as jnp
    from repro.launch.hlo_stats import analyze_hlo

    def f(x):
        def body(c, _):
            return c @ x, None
        out, _ = jax.lax.scan(body, x, None, length=5)
        return out.sum()

    comp = jax.jit(f).lower(jnp.ones((32, 32))).compile()
    st = analyze_hlo(comp.as_text())
    dot_flops = 2 * 32**3
    assert st.flops >= 5 * dot_flops, st.flops
    assert st.flops < 20 * dot_flops
    assert st.unknown_trip_whiles == 0


def test_hlo_analyzer_collectives():
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.hlo_stats import analyze_hlo
    if jax.device_count() < 2:
        pytest.skip("needs >1 device")


def test_serve_engine_completes():
    from repro.launch.serve import main
    stats = main(["--arch", "smoke", "--requests", "3", "--slots", "2",
                  "--max-new", "4", "--prompt-len", "8", "--max-len", "32"])
    assert stats["requests"] == 3 and stats["generated"] >= 3


def test_synth_ground_truth_arrivals():
    from repro.core import SynthConfig, make_dataset
    ds = make_dataset(SynthConfig(duration_s=120.0, n_stations=2,
                                  n_sources=1, events_per_source=3,
                                  seed=1))
    assert ds.waveforms.shape[0] == 2
    for ev in range(len(ds.event_times)):
        for stn in range(2):
            at = ds.arrival_time(ev, stn)
            assert 0 < at < 120.0
    # reoccurring events share a source template: correlate windows
    if len(ds.event_times) >= 2 and ds.event_sources[0] == \
            ds.event_sources[1]:
        fs = ds.cfg.fs
        n = int(4 * fs)
        a0 = int(ds.arrival_time(0, 0) * fs)
        a1 = int(ds.arrival_time(1, 0) * fs)
        w0 = ds.waveforms[0, a0:a0 + n]
        w1 = ds.waveforms[0, a1:a1 + n]
        c = np.corrcoef(w0, w1)[0, 1]
        assert c > 0.3, c
