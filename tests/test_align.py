"""Spatiotemporal alignment (paper §7): channel merge vs dict reference,
diagonal clustering, network dt-invariance, out-of-core path."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import align as A
from repro.core.align import AlignConfig, Events
from repro.core.lsh import INVALID, Pairs


def triplets(rows, pad_to=None):
    """rows: list of (dt, idx1, sim). → masked arrays."""
    rows = list(rows)
    n = pad_to or len(rows)
    dt = np.full(n, INVALID, np.int32)
    i1 = np.full(n, INVALID, np.int32)
    sim = np.zeros(n, np.int32)
    val = np.zeros(n, bool)
    for k, (d, i, s) in enumerate(rows):
        dt[k], i1[k], sim[k], val[k] = d, i, s, True
    return (jnp.asarray(dt), jnp.asarray(i1), jnp.asarray(sim),
            jnp.asarray(val))


def test_merge_channels_matches_dict(rng):
    chans = []
    expect = {}
    for c in range(3):
        rows = []
        for _ in range(30):
            d, i, s = int(rng.integers(0, 5)), int(rng.integers(0, 10)), \
                int(rng.integers(1, 5))
            rows.append((d, i, s))
            expect[(d, i)] = expect.get((d, i), 0) + s
        chans.append(triplets(rows, pad_to=40))
    merged = A.merge_channels(chans, threshold=4)
    got = {}
    for d, i, s, v in zip(np.asarray(merged.dt), np.asarray(merged.idx1),
                          np.asarray(merged.sim), np.asarray(merged.valid)):
        if v:
            got[(int(d), int(i))] = int(s)
    expect = {k: v for k, v in expect.items() if v >= 4}
    assert got == expect


def test_cluster_station_basic():
    """Two diagonal clusters + one isolated entry (pruned)."""
    # NOTE: the merge pass is single-sweep in (idx_min, dt) order
    # (DESIGN.md §7 approximation); the isolated entry sits at idx 70 so it
    # does not interleave between A's diagonals.
    rows = ([(100, i, 3) for i in range(5, 11)]          # cluster A
            + [(101, 8, 3)]                               # adjacent diag → A
            + [(250, i, 4) for i in (40, 44, 47)]         # cluster B
            + [(999, 70, 2)])                             # isolated
    pairs = Pairs(
        idx1=triplets(rows, 20)[1], idx2=jnp.asarray(
            np.asarray(triplets(rows, 20)[0])
            + np.asarray(triplets(rows, 20)[1])),
        sim=triplets(rows, 20)[2], valid=triplets(rows, 20)[3])
    cfg = AlignConfig(gap=5, dt_merge_tol=2, min_cluster_size=2,
                      min_cluster_sim=6)
    ev = A.cluster_station(pairs, cfg)
    v = np.asarray(ev.valid)
    dts = sorted(np.asarray(ev.dt)[v].tolist())
    assert len(dts) == 2, (dts,)
    assert dts[0] == 100 and dts[1] == 250
    sizes = np.asarray(ev.size)[v]
    assert sorted(sizes.tolist()) == [3, 7]


def _events(rows, pad_to=None):
    """rows: (dt, onset, score) per event."""
    rows = list(rows)
    n = pad_to or len(rows)
    dt = np.full(n, INVALID, np.int32)
    onset = np.full(n, INVALID, np.int32)
    score = np.zeros(n, np.int32)
    valid = np.zeros(n, bool)
    for k, (d, o, s) in enumerate(rows):
        dt[k], onset[k], score[k], valid[k] = d, o, s, True
    return Events(dt=jnp.asarray(dt), onset=jnp.asarray(onset),
                  extent=jnp.zeros(n, jnp.int32),
                  size=jnp.ones(n, jnp.int32), score=jnp.asarray(score),
                  valid=jnp.asarray(valid))


def test_network_association_dt_invariance():
    """Same (dt, onset±tol) at ≥2 stations → detection; others dropped.

    This encodes Figure 9: inter-event time is station-invariant while
    onset shifts by travel time only (within the tolerance window).
    """
    cfg = AlignConfig(dt_tol=2, onset_tol=10, min_stations=2)
    st0 = _events([(500, 100, 5), (800, 300, 4)], 6)
    st1 = _events([(501, 105, 6), (1200, 50, 9)], 6)
    st2 = _events([(499, 97, 3)], 6)
    det = A.associate_network([st0, st1, st2], cfg, 3)
    v = np.asarray(det["valid"])
    dts = np.asarray(det["dt"])[v]
    n_st = np.asarray(det["n_stations"])[v]
    assert len(dts) == 1 and abs(int(dts[0]) - 500) <= 2
    assert int(n_st[0]) == 3


def test_network_association_respects_min_stations():
    cfg = AlignConfig(dt_tol=1, onset_tol=5, min_stations=3)
    st0 = _events([(500, 100, 5)], 4)
    st1 = _events([(500, 102, 6)], 4)
    det = A.associate_network([st0, st1], cfg, 2)
    assert int(np.asarray(det["valid"]).sum()) == 0


def test_network_association_beyond_32_stations():
    """The packed-bitmask multiplicity has no station cap (the old dense
    one_hot asserted n_stations <= 32): a 40-station network associates,
    and multiplicity counts each station once even with multiple events
    per station in the group."""
    cfg = AlignConfig(dt_tol=2, onset_tol=10, min_stations=35)
    stations = [_events([(500, 100 + (i % 7), 5)], 4) for i in range(40)]
    det = A.associate_network(stations, cfg, 40)
    v = np.asarray(det["valid"])
    assert int(v.sum()) == 1
    assert int(np.asarray(det["n_stations"])[v][0]) == 40
    # same station twice in a group counts once (bitmask OR, not a sum)
    st0 = _events([(500, 100, 5), (500, 103, 4)], 4)
    st1 = _events([(501, 102, 6)], 4)
    cfg2 = AlignConfig(dt_tol=2, onset_tol=10, min_stations=2)
    det2 = A.associate_network([st0, st1], cfg2, 2)
    v2 = np.asarray(det2["valid"])
    assert int(v2.sum()) == 1
    assert int(np.asarray(det2["n_stations"])[v2][0]) == 2


def test_network_association_bad_input_raises():
    st = _events([(500, 100, 5)], 4)
    with pytest.raises(ValueError, match="n_stations"):
        A.associate_network([st], AlignConfig(), 0)
    with pytest.raises(ValueError, match="per-station"):
        A.associate_network([st, st], AlignConfig(), 3)


def test_network_association_tolerance_chaining_and_extent_cap():
    """Groups start on *consecutive* deltas, so onsets each within
    onset_tol chain into one group spanning many tolerances (pinned
    here), and ``max_group_extent`` bounds the chain."""
    cfg = AlignConfig(dt_tol=1, onset_tol=10, min_stations=2)
    # a chain of onsets 8 apart — each within onset_tol of its neighbor,
    # the ends 32 apart (> 3 tolerances)
    st0 = _events([(700, 100, 5), (700, 116, 5), (700, 132, 5)], 6)
    st1 = _events([(700, 108, 5), (700, 124, 5)], 6)
    det = A.associate_network([st0, st1], cfg, 2)
    v = np.asarray(det["valid"])
    assert int(v.sum()) == 1                   # one chained group...
    assert int(np.asarray(det["onset_span"])[v][0]) == 32   # ...spanning 32
    # the extent cap drops the physically implausible chain
    capped = AlignConfig(dt_tol=1, onset_tol=10, min_stations=2,
                         max_group_extent=20)
    det2 = A.associate_network([st0, st1], capped, 2)
    assert int(np.asarray(det2["valid"]).sum()) == 0
    # a compact group passes the same cap
    st2 = _events([(700, 100, 5)], 6)
    st3 = _events([(700, 104, 5)], 6)
    det3 = A.associate_network([st2, st3], capped, 2)
    assert int(np.asarray(det3["valid"]).sum()) == 1


def test_network_association_station_onset_matrix():
    """``with_onsets`` returns the per-group (p, S) onset / score
    matrices the locate tier stacks over: each present station's earliest
    onset and summed score, INVALID / 0 where absent."""
    cfg = AlignConfig(dt_tol=2, onset_tol=10, min_stations=2)
    st0 = _events([(500, 100, 5), (500, 104, 2)], 6)
    st1 = _events([(501, 105, 6)], 6)
    st2 = _events([(900, 40, 3)], 6)
    det = A.associate_network([st0, st1, st2], cfg, 3, with_onsets=True)
    v = np.asarray(det["valid"])
    assert int(v.sum()) == 1
    g = np.nonzero(v)[0][0]
    onset = np.asarray(det["station_onset"])[g]
    score = np.asarray(det["station_score"])[g]
    assert onset.tolist() == [100, 105, INVALID]
    assert score.tolist() == [7, 6, 0]


def test_align_streamed_matches_in_memory(rng, tmp_path):
    chans = []
    expect = {}
    for c in range(2):
        chunks = []
        for g in range(3):
            rows = np.stack([
                rng.integers(0, 6, 25), rng.integers(0, 12, 25),
                rng.integers(1, 4, 25)], axis=1)
            chunks.append(rows)
            for d, i, s in rows:
                expect[(int(d), int(i))] = expect.get((int(d), int(i)), 0) \
                    + int(s)
        chans.append(chunks)
    out = A.align_streamed(chans, threshold=5, tmpdir=str(tmp_path))
    got = {(int(d), int(i)): int(s) for d, i, s in out}
    assert got == {k: v for k, v in expect.items() if v >= 5}
