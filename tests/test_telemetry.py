"""Telemetry subsystem tests (ISSUE 6).

Pins the observability contract end to end:

  * ``repro.obsv`` primitives — registry counters/gauges/histograms,
    snapshot/restore, Prometheus text exposition (parsed back by the
    format guard), span tracer nesting + JSONL records;
  * telemetry on/off **bit-parity** — the in-dispatch counter vector is
    observation only: the same dirty trace streamed with
    ``telemetry=False`` yields the identical pair set and quality
    counters, with the counter tail compiled to zeros;
  * device-vs-host **reconciliation** — the device's own step counters
    (``step_<field>_total``) agree with the host-side accounting
    (``StreamStats.pairs``, ``quality_summary``) on dirty scenarios;
  * detector **snapshot/restore** carries the registry and watchdog EMA,
    so a restored service resumes its counters instead of zeroing;
  * ``metrics_snapshot`` schema (``stream-metrics/v1``) — the one
    structured view serve_detect / bench_stream / bench_e2e embed;
  * the ``StepWatchdog`` straggler path increments
    ``straggler_steps_total`` while still honoring a caller's callback.
"""
import dataclasses
import json
import math
import pathlib
import re
import sys

import numpy as np
import pytest

from repro.configs.fast_seismic import (smoke_config,
                                        stream_dirty_smoke_config)
from repro.core.synth import (ScenarioConfig, SynthConfig,
                              make_scenario_dataset)
from repro.obsv.metrics import (Histogram, MetricsRegistry, merge_counts,
                                render_prometheus)
from repro.obsv.spans import SpanTracer
from repro.stream import (METRICS_SCHEMA, QC_FIELDS, StreamingDetector,
                          metrics_snapshot)
from repro.stream.telemetry import StreamTelemetry
from repro.train.watchdog import StepWatchdog, WatchdogConfig

ROOT = str(pathlib.Path(__file__).parent.parent)
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)             # the benchmarks package

from benchmarks.common import frozen_smoke_stats as _frozen  # noqa: E402


# ---------------------------------------------------------------------------
# registry primitives
# ---------------------------------------------------------------------------


def test_counter_labels_and_totals():
    reg = MetricsRegistry()
    reg.counter("pairs_total", station="0").inc(3)
    reg.counter("pairs_total", station="1").inc(4)
    reg.counter("pairs_total", station="0").inc()       # same instance
    assert reg.counter("pairs_total", station="0").value == 4
    assert reg.total("pairs_total") == 8
    assert reg.total("absent_total") == 0
    # set_total mirrors an external count and never goes backwards
    c = reg.counter("quality_gaps_total")
    c.set_total(7)
    c.set_total(5)
    assert c.value == 7
    # one name, one kind
    with pytest.raises(AssertionError):
        reg.gauge("pairs_total")


def test_gauge_point_in_time():
    reg = MetricsRegistry()
    g = reg.gauge("rtf")
    g.set(3)
    g.set(1.5)
    assert reg.gauge("rtf").value == 1.5


def test_histogram_buckets_summary_percentiles():
    h = Histogram()
    for v in [0.001] * 98 + [0.5, 1.0]:
        h.record(v)
    s = h.summary()
    assert s["count"] == 100
    assert s["sum"] == pytest.approx(98 * 0.001 + 1.5)
    assert s["min"] == 0.001 and s["max"] == 1.0
    # bucket-resolution percentiles: ≤ 2x overestimate, never below exact
    assert 0.001 <= s["p50"] <= 0.002
    assert 0.001 <= s["p95"] <= 0.002
    # values clamp to the edge buckets instead of erroring
    h.record(1e-12)
    h.record(1e9)
    assert h._bucket(1e-12) == 0
    assert h._bucket(1e9) == Histogram.N_BUCKETS - 1
    assert sum(h.counts) == h.count == 102
    # empty histogram summarizes to zeros, not inf
    assert Histogram().summary() == {"count": 0, "sum": 0.0, "min": 0.0,
                                     "max": 0.0, "p50": 0.0, "p95": 0.0}


def test_histogram_merged_across_labels():
    reg = MetricsRegistry()
    reg.histogram("wall_seconds", station="0").record(0.01)
    reg.histogram("wall_seconds", station="1").record(0.04)
    m = reg.histogram_merged("wall_seconds")
    assert m.count == 2
    assert m.total == pytest.approx(0.05)
    assert m.vmin == 0.01 and m.vmax == 0.04


def test_merge_counts_sums_and_order():
    out = merge_counts([{"a": 1, "b": 2}, {"b": 3, "c": 4}])
    assert out == {"a": 1, "b": 5, "c": 4}
    assert list(out) == ["a", "b", "c"]      # first-seen key order


def test_registry_snapshot_restore_roundtrip():
    reg = MetricsRegistry()
    reg.counter("pairs_total", station="0").inc(12)
    reg.gauge("rtf").set(7.5)
    reg.histogram("wall_seconds", station="0").record(0.02)
    reg.histogram("empty_seconds")           # registered but never recorded
    snap = reg.snapshot()
    assert snap["schema"] == "metrics/v1"
    json.dumps(snap)                         # JSON-able (rides checkpoints)
    reg2 = MetricsRegistry()
    reg2.restore(snap)
    assert reg2.snapshot() == snap
    assert reg2.render() == reg.render()
    h = reg2.histogram("empty_seconds")
    assert h.count == 0 and h.vmin == math.inf


# ---------------------------------------------------------------------------
# Prometheus exposition format guard
# ---------------------------------------------------------------------------

_LINE = re.compile(r"^([a-zA-Z_][a-zA-Z0-9_]*)"
                   r"(\{[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\""
                   r"(,[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\")*\})? (\S+)$")


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    reg.counter("pairs_total", station="0").inc(5)
    reg.gauge("rtf").set(2.25)
    h = reg.histogram("wall_seconds", station="0")
    for v in (0.001, 0.002, 0.004, 1.0):
        h.record(v)
    text = render_prometheus(reg, namespace="repro")
    lines = text.strip().split("\n")
    # one TYPE comment per metric family, kinds as registered
    types = {m.group(1): m.group(2) for ln in lines
             if (m := re.match(r"# TYPE (\S+) (\S+)$", ln))}
    assert types == {"repro_pairs_total": "counter", "repro_rtf": "gauge",
                     "repro_wall_seconds": "histogram"}
    samples = [ln for ln in lines if not ln.startswith("#")]
    parsed = {}
    for ln in samples:
        m = _LINE.match(ln)
        assert m, f"unparseable exposition line: {ln!r}"
        float(m.group(4))                    # value is numeric
        parsed[m.group(1) + (m.group(2) or "")] = float(m.group(4))
    assert parsed['repro_pairs_total{station="0"}'] == 5
    assert parsed["repro_rtf"] == 2.25
    # histogram: cumulative non-decreasing buckets, +Inf == _count
    buckets = [(ln, float(_LINE.match(ln).group(4))) for ln in samples
               if ln.startswith("repro_wall_seconds_bucket")]
    counts = [v for _, v in buckets]
    assert counts == sorted(counts)
    assert '+Inf' in buckets[-1][0]
    assert buckets[-1][1] == 4
    assert parsed['repro_wall_seconds_count{station="0"}'] == 4
    assert parsed['repro_wall_seconds_sum{station="0"}'] == \
        pytest.approx(1.007)


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_span_nesting_totals_and_jsonl(tmp_path):
    clk = _FakeClock()
    path = tmp_path / "spans.jsonl"
    tr = SpanTracer(jsonl_path=str(path), clock=clk)
    with tr.span("outer", station=0):
        clk.t += 1.0
        with tr.span("inner"):
            clk.t += 0.25
    with tr.span("inner"):
        clk.t += 0.25
    tr.close()
    assert tr.total_s("outer") == pytest.approx(1.25)
    assert tr.total_s("inner") == pytest.approx(0.5)
    assert tr.total_s("absent") == 0.0
    assert tr.summary() == {
        "outer": {"count": 1, "total_s": pytest.approx(1.25)},
        "inner": {"count": 2, "total_s": pytest.approx(0.5)}}
    recs = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert len(recs) == 3                    # exit order: inner, outer, inner
    assert recs[0]["path"] == "outer/inner" and recs[0]["depth"] == 1
    assert recs[1]["path"] == "outer" and recs[1]["depth"] == 0
    assert recs[1]["station"] == 0           # span attrs ride the record
    assert recs[2]["path"] == "inner" and recs[2]["depth"] == 0
    assert all(r["dur_s"] >= 0 and "ts" in r for r in recs)


# ---------------------------------------------------------------------------
# watchdog integration
# ---------------------------------------------------------------------------


def test_watchdog_straggler_counts_and_callback_chain():
    clk = _FakeClock()
    seen = []
    wd = StepWatchdog(WatchdogConfig(min_samples=2, straggler_factor=2.0,
                                     hang_timeout_s=1000.0),
                      on_straggler=seen.append, clock=clk)
    tel = StreamTelemetry(1, watchdog=wd)    # chains, never replaces
    for _ in range(5):                       # EMA settles at 0.1 s
        wd.step_start()
        clk.t += 0.1
        wd.step_end()
    assert tel.registry.total("straggler_steps_total") == 0
    wd.step_start()
    clk.t += 5.0                             # 50× EMA, below hang timeout
    wd.step_end()
    assert tel.registry.total("straggler_steps_total") == 1
    assert len(seen) == 1                    # caller's policy still fired
    assert seen[0]["reason"] == "straggler"
    assert wd.events == seen


# ---------------------------------------------------------------------------
# streaming integration (dirty scenarios)
# ---------------------------------------------------------------------------


def _raw_pairs(st):
    tri = (np.concatenate(st.triplets, axis=0) if st.triplets
           else np.zeros((0, 3), np.int64))
    return set(zip(tri[:, 0].tolist(), tri[:, 1].tolist()))


def _stream(cfg, scfg, wf, med_mad, n_stations=1, n_chunks=10):
    det = StreamingDetector(cfg, scfg, n_stations=n_stations,
                            med_mad=med_mad)
    wf = np.atleast_2d(np.asarray(wf, np.float32))
    for chunk in np.array_split(wf, n_chunks, axis=1):
        det.push(chunk if n_stations > 1 else chunk[0])
    det.flush()
    return [_raw_pairs(st) for st in det.stations], det


def _base_synth(**over):
    kw = dict(duration_s=600.0, n_stations=1, n_sources=2,
              events_per_source=5, event_snr=3.0, seed=3)
    kw.update(over)
    return SynthConfig(**kw)


def _dirty_scenario(**over):
    kw = dict(base=_base_synth(), n_gaps=2, gap_dur_s=(2.0, 5.0),
              glitch_stations=(0,), glitch_trains=1,
              glitch_train_dur_s=150.0, seed=1)
    kw.update(over)
    return make_scenario_dataset(ScenarioConfig(**kw))


def test_telemetry_off_bit_parity_on_dirty_trace():
    """The counter tail is observation only: telemetry=False compiles it
    away and the detections — pair set AND host quality counters — are
    bit-identical on a gap+glitch trace."""
    cfg = smoke_config()
    scfg_on = stream_dirty_smoke_config()
    assert scfg_on.telemetry                 # the production default
    scfg_off = dataclasses.replace(scfg_on, telemetry=False)
    scen = _dirty_scenario()
    med_mad = _frozen(cfg, scen.clean.waveforms[0])
    (on,), det_on = _stream(cfg, scfg_on, scen.waveforms[0], med_mad)
    (off,), det_off = _stream(cfg, scfg_off, scen.waveforms[0], med_mad)
    assert on == off
    assert det_on.quality_summary() == det_off.quality_summary()
    # with telemetry on, the full counter vector is live…
    d_on = det_on.telemetry.drop_breakdown()
    assert d_on["pairs_emitted"] > 0
    assert d_on["masked_fingerprints"] > 0   # the gaps
    assert d_on["raw_collisions"] >= d_on["pairs_emitted"]
    # …with it off, the telemetry tail constant-folds to zero while the
    # always-on guard fields keep counting
    d_off = det_off.telemetry.drop_breakdown()
    for name in ("pairs_emitted", "masked_fingerprints", "raw_collisions",
                 "quarantined_collisions"):
        assert d_off[name] == 0
    for name in ("duplicate_fingerprints", "saturated_lookups",
                 "limited_pairs"):
        assert d_off[name] == d_on[name]


def test_device_host_counter_reconciliation_pooled():
    """The device's in-dispatch counters and the host-side accounting are
    two independent views of the same stream — they must agree, per
    station, on a dirty pooled run."""
    cfg = smoke_config()
    scfg = stream_dirty_smoke_config()
    scen = _dirty_scenario(base=_base_synth(n_stations=2),
                           glitch_stations=(1,))
    med_mad = _frozen(cfg, scen.clean.waveforms[0])
    _, det = _stream(cfg, scfg, scen.waveforms, med_mad, n_stations=2)
    assert det.pooled
    reg = det.telemetry.registry
    drops = det.telemetry.drop_breakdown()
    # device pairs_emitted == host StreamStats.pairs, station by station
    for i, st in enumerate(det.stations):
        dev = reg.counter("step_pairs_emitted_total", station=str(i)).value
        assert dev == st.stats.pairs
    assert drops["pairs_emitted"] == sum(st.stats.pairs
                                         for st in det.stations)
    # guard fields whose only source is the device vector surface
    # identically in quality_summary…
    q = det.quality_summary()
    for name in ("saturated_lookups", "limited_pairs"):
        assert drops[name] == q[name]
    # …while duplicate_fingerprints also absorbs the host-side
    # sample-exact guard, so the device view is a lower bound
    assert drops["duplicate_fingerprints"] <= q["duplicate_fingerprints"]
    assert drops["pairs_emitted"] > 0
    assert drops["masked_fingerprints"] > 0  # the gaps masked in-dispatch
    # rates are consistent with the breakdown they summarize
    rates = det.telemetry.drop_rates()
    denom = drops["pairs_emitted"] + drops["limited_pairs"]
    assert rates["limited_pairs"] == \
        pytest.approx(drops["limited_pairs"] / denom, abs=1e-6)
    assert 0.0 <= rates["masked_fingerprints"] <= 1.0


def test_detector_snapshot_restores_telemetry(tmp_path):
    """A restored detector resumes its counters (and the watchdog EMA)
    instead of zeroing the dashboards, and keeps counting on top."""
    cfg = smoke_config()
    scfg = stream_dirty_smoke_config()
    scen = _dirty_scenario()
    med_mad = _frozen(cfg, scen.clean.waveforms[0])
    wf = np.atleast_2d(scen.waveforms[0])
    chunks = np.array_split(wf, 10, axis=1)
    det = StreamingDetector(cfg, scfg, n_stations=1, med_mad=med_mad)
    for c in chunks[:6]:
        det.push(c[0])
    drops_mid = det.telemetry.drop_breakdown()
    wd_mid = (det.telemetry.watchdog.ema, det.telemetry.watchdog.n)
    det.snapshot(str(tmp_path))
    det2, _ = StreamingDetector.restore(str(tmp_path), cfg, scfg)
    assert det2.telemetry.drop_breakdown() == drops_mid
    assert (det2.telemetry.watchdog.ema, det2.telemetry.watchdog.n) == wd_mid
    assert det2.telemetry.uptime_s() > 0     # uptime carries over
    for c in chunks[6:]:                     # counters keep growing
        det2.push(c[0])
    det2.flush()
    drops_end = det2.telemetry.drop_breakdown()
    assert drops_end["pairs_emitted"] >= drops_mid["pairs_emitted"]
    assert drops_end["pairs_emitted"] == det2.stations[0].stats.pairs


def test_metrics_snapshot_schema_and_prometheus_surface():
    """``metrics_snapshot`` is the one structured view every consumer
    (serve_detect, bench_stream, bench_e2e, examples) embeds — pin its
    shape; and the Prometheus surface scrapes the same registry."""
    cfg = smoke_config()
    scfg = stream_dirty_smoke_config()
    scen = _dirty_scenario()
    med_mad = _frozen(cfg, scen.clean.waveforms[0])
    _, det = _stream(cfg, scfg, scen.waveforms[0], med_mad)
    m = det.metrics_snapshot()
    m2 = metrics_snapshot(det)               # the method is the function
    wall_keys = ("uptime_s", "rtf")          # live clock: not comparable
    assert {k: v for k, v in m.items() if k not in wall_keys} == \
        {k: v for k, v in m2.items() if k not in wall_keys}
    json.dumps(m)                            # artifact-ready
    assert m["schema"] == METRICS_SCHEMA == "stream-metrics/v1"
    assert set(m) == {"schema", "stations", "uptime_s", "stream_s", "rtf",
                      "stream", "per_station", "drops", "drop_rates",
                      "quality", "histograms", "serve", "locate", "spans",
                      "watchdog"}
    assert m["stations"] == 1
    assert set(m["drops"]) == set(QC_FIELDS)
    assert m["quality"] == det.quality_summary()
    assert len(m["per_station"]) == 1
    ps = m["per_station"][0]
    assert ps["station"] == 0 and "host_state_rows" in ps
    assert set(m["histograms"]) == {"chunk_ingest_wall_seconds",
                                    "fused_step_wall_seconds",
                                    "host_tail_wall_seconds",
                                    "serve_latency_seconds",
                                    "serve_queue_wait_seconds",
                                    "locate_stack_wall_seconds"}
    # no serving engine shares this detector's hub → all-zero serve view
    assert m["serve"]["served"] == 0 and m["serve"]["shed"] == 0
    # no locate tier on this detector → all-zero locate view
    assert m["locate"]["passes"] == 0 and m["locate"]["located"] == 0
    assert m["histograms"]["fused_step_wall_seconds"]["count"] == \
        m["watchdog"]["steps"] > 0
    for name in ("ingest", "fused_step", "host_tail"):
        assert m["spans"][name]["count"] > 0
    assert m["stream"]["pairs"] == m["drops"]["pairs_emitted"]
    # the scrape carries the same registry plus point-in-time gauges and
    # the host quality counters, every line parseable
    text = det.telemetry.prometheus(det)
    for ln in text.strip().split("\n"):
        assert ln.startswith("# TYPE ") or _LINE.match(ln), ln
    assert 'repro_step_pairs_emitted_total{station="0"} ' \
        f'{m["drops"]["pairs_emitted"]}' in text
    assert "# TYPE repro_real_time_factor gauge" in text
    assert 'repro_quality_suppressed_fingerprints_total{station="0"}' in text
    assert 'repro_host_state_rows{station="0"}' in text
