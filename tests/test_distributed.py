"""Multi-device semantics via subprocess (8 forced host devices):
sharded step == single-device step, EP-MoE == dense, elastic checkpoint
restore across mesh shapes, tiny-mesh dry-run smoke."""
import pytest

from conftest import run_forced_devices as run_py


COMMON = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import ModelConfig, init_params, lm_loss
from repro.models import param_sharding_rules
from repro import dist

CFG = ModelConfig(name="d", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  d_ff=128, vocab_size=2048, attn_q_block=32,
                  attn_kv_block=32, loss_seq_chunk=32,
                  param_dtype="float32", compute_dtype="float32",
                  remat="none")
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(0, CFG.vocab_size, (8, 64)), jnp.int32)
batch = {"tokens": toks, "labels": toks,
         "loss_mask": jnp.ones((8, 64), jnp.float32)}
params = init_params(jax.random.PRNGKey(0), CFG)
"""


def test_sharded_loss_and_grads_match_single_device():
    out = run_py(COMMON + """
# single device reference
loss_ref, _ = lm_loss(params, batch, CFG)
grads_ref = jax.grad(lambda p: lm_loss(p, batch, CFG)[0])(params)

mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = param_sharding_rules(CFG)

def to_sh(rule_tree, tree):
    def walk(r, t):
        if isinstance(r, tuple):
            spec = dist.sanitize_spec(t.shape, r)
            return NamedSharding(mesh, spec if spec is not None else P())
        return {k: walk(r[k], t[k]) for k in r}
    return walk(rule_tree, tree)

with mesh:
    psh = to_sh(rules, params)
    params_s = jax.device_put(params, psh)
    batch_s = jax.device_put(batch, NamedSharding(mesh, P(("data",))))
    f = jax.jit(lambda p, b: lm_loss(p, b, CFG)[0], in_shardings=(psh,
                NamedSharding(mesh, P(("data",)))))
    loss_s = f(params_s, batch_s)
    grads_s = jax.jit(jax.grad(lambda p: lm_loss(p, batch_s, CFG)[0]),
                      in_shardings=(psh,))(params_s)
print("LOSS", float(loss_ref), float(loss_s))
assert abs(float(loss_ref) - float(loss_s)) < 1e-4
for a, b in zip(jax.tree.leaves(grads_ref), jax.tree.leaves(grads_s)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4)
print("SHARDED_OK")
""")
    assert "SHARDED_OK" in out


def test_moe_ep_shardmap_matches_dense():
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import ModelConfig, init_params
from repro.models import layers as L

CFG = ModelConfig(name="m", n_layers=1, d_model=64, n_heads=4, n_kv_heads=4,
                  d_ff=0, vocab_size=256, n_experts=8, n_shared_experts=1,
                  moe_top_k=2, expert_ff=32, capacity_factor=8.0,
                  param_dtype="float32", compute_dtype="float32")
rng = np.random.default_rng(0)
params = init_params(jax.random.PRNGKey(0), CFG)
lp = jax.tree.map(lambda a: a[0], params["layers"])
x = jnp.asarray(rng.standard_normal((4, 16, 64)), jnp.float32)

y_dense, aux_dense = L.moe_block(lp["moe"], x, CFG)   # no mesh → dense path

mesh = jax.make_mesh((2, 4), ("data", "model"))
with mesh:
    moe_sh = {k: NamedSharding(mesh, P("model", None, None))
              if k in ("wg", "wu", "wd") else NamedSharding(mesh, P())
              for k in lp["moe"]}
    lp_s = {"moe": jax.device_put(lp["moe"], moe_sh)}
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    f = jax.jit(lambda p, xx: L.moe_block(p, xx, CFG))
    y_ep, aux_ep = f(lp_s["moe"], xs)
np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_ep),
                           atol=1e-4, rtol=1e-4)
# EP aux is the per-data-shard balance loss meaned over shards — close to
# but not identical with the global-batch aux
assert abs(float(aux_dense) - float(aux_ep)) / max(float(aux_dense), 1e-9) < 0.3
print("MOE_EP_OK")
""")
    assert "MOE_EP_OK" in out


def test_decode_seq_sharded_cache_matches_unsharded():
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import (ModelConfig, decode_step, init_cache, init_params,
                          cache_sharding_rules)
from repro import dist

CFG = ModelConfig(name="d", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  d_ff=128, vocab_size=256, param_dtype="float32",
                  compute_dtype="float32", cache_dtype="float32")
rng = np.random.default_rng(0)
params = init_params(jax.random.PRNGKey(0), CFG)
cache = init_cache(CFG, 4, 32)
# advance a few tokens unsharded
toks = [jnp.asarray(rng.integers(0, 256, (4, 1)), jnp.int32)
        for _ in range(5)]
c = cache
for t in toks[:-1]:
    logits_ref, c = decode_step(params, c, t, CFG)
logits_ref, _ = decode_step(params, c, toks[-1], CFG)

mesh = jax.make_mesh((2, 4), ("data", "model"))
with mesh:
    rules = cache_sharding_rules(CFG)
    def sh(rule, t):
        spec = dist.sanitize_spec(t.shape, rule)
        return NamedSharding(mesh, spec if spec is not None else P())
    cs = {k: sh(rules[k], v) for k, v in cache.items()}
    c2 = jax.device_put(cache, cs)
    f = jax.jit(lambda p, c, t: decode_step(p, c, t, CFG))
    for t in toks[:-1]:
        logits_s, c2 = f(params, c2, t)
    logits_s, _ = f(params, c2, toks[-1])
np.testing.assert_allclose(np.asarray(logits_ref), np.asarray(logits_s),
                           atol=2e-4)
print("DECODE_SHARDED_OK")
""")
    assert "DECODE_SHARDED_OK" in out


def test_elastic_checkpoint_restore_new_mesh(tmp_path):
    out = run_py(f"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint as C

mesh_a = jax.make_mesh((2, 4), ("data", "model"))
state = {{"w": jnp.arange(64.0).reshape(8, 8)}}
state = jax.device_put(state, NamedSharding(mesh_a, P("data", "model")))
C.save_checkpoint(r"{tmp_path}", 1, state)

mesh_b = jax.make_mesh((4, 2), ("data", "model"))
target = {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
sh = {{"w": NamedSharding(mesh_b, P("model", "data"))}}
restored, _ = C.restore_checkpoint(r"{tmp_path}", target, shardings=sh)
np.testing.assert_array_equal(np.asarray(restored["w"]),
                              np.arange(64.0).reshape(8, 8))
assert restored["w"].sharding.spec == P("model", "data")
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out


@pytest.mark.slow
def test_dryrun_machinery_tiny_mesh():
    """The dry-run lowering path works on a small mesh (8 devices)."""
    out = run_py("""
import jax
from repro.launch.dryrun import lower_lm_cell, _cell_name
from repro.launch import hlo_stats

mesh = jax.make_mesh((2, 4), ("data", "model"))
lowered, cfg, spec, extra = lower_lm_cell(
    "internvl2-1b", "train_4k", mesh, "masked", 2)
compiled = lowered.compile()
st = hlo_stats.analyze_hlo(compiled.as_text())
assert st.flops > 0 and st.bytes > 0
print("DRYRUN_TINY_OK", st.flops > 0)
""", timeout=2400)
    assert "DRYRUN_TINY_OK" in out
