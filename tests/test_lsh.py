"""LSH search (paper §6): correctness vs brute force, S-curve behavior,
occurrence filter, partitioned search equivalence, skew diagnostics."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import lsh as L
from repro.core import theory


def make_planted(rng, n=96, d=512, n_bits=40, n_pairs=8, overlap=0.9):
    """Random sparse fingerprints + planted near-duplicate pairs."""
    fp = np.zeros((n, d), bool)
    for i in range(n):
        fp[i, rng.choice(d, n_bits, replace=False)] = True
    pairs = []
    for p in range(n_pairs):
        i = 2 * p
        j = n - 1 - 2 * p
        fp[j] = fp[i].copy()
        flip = rng.choice(d, int(n_bits * (1 - overlap) * 2), replace=False)
        fp[j, flip] = ~fp[j, flip]
        pairs.append((min(i, j), max(i, j)))
    return fp, pairs


CFG = L.LSHConfig(n_tables=50, n_funcs=4, n_matches=2, bucket_cap=8,
                  min_dt=1, occurrence_frac=0.0)


def test_planted_pairs_found(rng):
    fp, planted = make_planted(rng)
    pairs, stats = L.search(jnp.asarray(fp), CFG)
    found = {(int(a), int(b))
             for a, b, v in zip(np.asarray(pairs.idx1),
                                np.asarray(pairs.idx2),
                                np.asarray(pairs.valid)) if v}
    hit = sum(p in found for p in planted)
    assert hit >= len(planted) - 1, (hit, len(planted))


def test_matches_brute_force_high_threshold(rng):
    """Every reported pair must be genuinely similar (precision against
    the exact O(N²) join at the S-curve floor)."""
    fp, _ = make_planted(rng, n_pairs=6)
    pairs, _ = L.search(jnp.asarray(fp), CFG)
    exact = L.brute_force_pairs(fp, threshold=0.2, min_dt=1)
    exact_set = {(int(a), int(b)) for a, b, _ in exact}
    for a, b, v, s in zip(np.asarray(pairs.idx1), np.asarray(pairs.idx2),
                          np.asarray(pairs.valid), np.asarray(pairs.sim)):
        if v and s >= 10:  # strong matches must be truly similar
            assert (int(a), int(b)) in exact_set


def test_recall_tracks_theory(rng):
    """Detection rate of planted pairs ≈ theoretical S-curve value."""
    hits, total, probs = 0, 0, []
    for trial in range(4):
        r = np.random.default_rng(trial)
        fp, planted = make_planted(r, n=64, n_pairs=6, overlap=0.92)
        fpj = jnp.asarray(fp)
        pairs, _ = L.search(fpj, CFG)
        found = {(int(a), int(b))
                 for a, b, v in zip(np.asarray(pairs.idx1),
                                    np.asarray(pairs.idx2),
                                    np.asarray(pairs.valid)) if v}
        from repro.utils import pack_bits
        packed = np.asarray(pack_bits(fpj))
        for (a, b) in planted:
            inter = bin(int.from_bytes(
                (packed[a] & packed[b]).tobytes(), "little")).count("1")
            union = bin(int.from_bytes(
                (packed[a] | packed[b]).tobytes(), "little")).count("1")
            s = inter / max(union, 1)
            probs.append(theory.detection_probability(
                s, CFG.n_funcs, CFG.n_matches, CFG.n_tables))
            hits += (a, b) in found
            total += 1
    expected = float(np.mean(probs))
    rate = hits / total
    assert abs(rate - expected) < 0.3, (rate, expected)


def test_min_dt_excludes_adjacent(rng):
    fp, _ = make_planted(rng)
    cfg = L.LSHConfig(**{**CFG.__dict__, "min_dt": 10})
    pairs, _ = L.search(jnp.asarray(fp), cfg)
    v = np.asarray(pairs.valid)
    dt = np.asarray(pairs.idx2)[v] - np.asarray(pairs.idx1)[v]
    assert (dt >= 10).all()


def test_occurrence_filter_kills_hub(rng):
    """A 'repeating noise' hub matching everything gets dropped (§6.5)."""
    n, d, nb = 80, 512, 40
    fp = np.zeros((n, d), bool)
    hub_bits = rng.choice(d, nb, replace=False)
    for i in range(40):  # 40 near-identical noise fingerprints
        fp[i, hub_bits] = True
        fp[i, rng.choice(d, 3)] = True
    for i in range(40, n):
        fp[i, rng.choice(d, nb, replace=False)] = True
    # one planted earthquake pair among the clean rows
    fp[n - 1] = fp[40].copy()
    cfg = L.LSHConfig(**{**CFG.__dict__, "occurrence_frac": 0.2,
                         "min_dt": 1})
    pairs, stats = L.search(jnp.asarray(fp), cfg)
    v = np.asarray(pairs.valid)
    i1 = np.asarray(pairs.idx1)[v]
    i2 = np.asarray(pairs.idx2)[v]
    assert not ((i1 < 40) & (i2 < 40)).any(), "hub pairs survived"
    assert ((i1 == 40) & (i2 == n - 1)).any(), "planted pair lost"
    assert int(stats["excluded_fingerprints"]) >= 40


def test_partitioned_equals_global(rng):
    fp, _ = make_planted(rng, n=64)
    cfg = L.LSHConfig(**{**CFG.__dict__, "occurrence_frac": 0.0})
    g_pairs, _ = L.search(jnp.asarray(fp), cfg)
    blocks, _ = L.partitioned_search(jnp.asarray(fp), cfg, n_partitions=4)

    def valid_set(prs):
        out = set()
        for pr in prs:
            for a, b, v in zip(np.asarray(pr.idx1), np.asarray(pr.idx2),
                               np.asarray(pr.valid)):
                if v:
                    out.add((int(a), int(b)))
        return out

    g = valid_set([g_pairs])
    p = valid_set(blocks)
    # identical pair sets (the paper: "partitioned search yields identical
    # results")
    assert g == p, (len(g), len(p), g ^ p)


def test_more_funcs_fewer_lookups(rng):
    """§6.3: raising k shrinks buckets → selectivity drops."""
    fp, _ = make_planted(rng, n=128)
    fpj = jnp.asarray(fp)
    stats = {}
    for k in (2, 4, 8):
        cfg = L.LSHConfig(n_tables=20, n_funcs=k, n_matches=1)
        mp = L.hash_mappings(fp.shape[1], cfg)
        sigs = L.signatures(fpj, mp, cfg)
        stats[k] = float(L.bucket_stats(sigs)["avg_lookups_per_query"])
    assert stats[2] >= stats[4] >= stats[8]


def test_signatures_valid_mask(rng):
    fp, _ = make_planted(rng, n=32)
    cfg = CFG
    mp = L.hash_mappings(fp.shape[1], cfg)
    valid = jnp.asarray(np.arange(32) < 16)
    sigs = L.signatures(jnp.asarray(fp), mp, cfg, valid=valid)
    s = np.asarray(sigs)
    # invalid rows must not collide with each other
    assert len(np.unique(s[16:], axis=0)) == 16


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_minmax_estimator_sanity(seed):
    """Min-Max signatures collide more for more-similar inputs."""
    rng = np.random.default_rng(seed)
    d, nb = 256, 30
    base = np.zeros(d, bool)
    base[rng.choice(d, nb, replace=False)] = True
    sim = base.copy()
    flip = rng.choice(d, 4, replace=False)
    sim[flip] = ~sim[flip]
    rand = np.zeros(d, bool)
    rand[rng.choice(d, nb, replace=False)] = True
    cfg = L.LSHConfig(n_tables=60, n_funcs=4, n_matches=1)
    mp = L.hash_mappings(d, cfg)
    sigs = np.asarray(L.signatures(jnp.asarray(np.stack([base, sim, rand])),
                                   mp, cfg))
    close = (sigs[0] == sigs[1]).sum()
    far = (sigs[0] == sigs[2]).sum()
    assert close >= far
