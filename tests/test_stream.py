"""Streaming detection subsystem: index semantics, ingest halo exactness,
offline/streaming parity (incl. golden pin), bounded sliding-window mode,
snapshot/restore, retracing discipline, serving smoke."""
import json
import pathlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.fast_seismic import (smoke_config,
                                        stream_bounded_smoke_config,
                                        stream_smoke_config)
from repro.core import fingerprint as F
from repro.core import lsh as L
from repro.core.lsh import INVALID, LSHConfig
from repro.core.synth import SynthConfig, make_dataset
from repro.stream import (StreamConfig, StreamingDetector, StreamIndexConfig,
                          WaveformRing)
from repro.stream import index as SI
from repro.stream.engine import stream_step
from repro.stream.ingest import StreamingMAD

CFG = LSHConfig(n_tables=20, n_funcs=4, n_matches=2, bucket_cap=8,
                min_dt=1, occurrence_frac=0.0)


def _random_sigs(rng, n, t=CFG.n_tables):
    return jnp.asarray(rng.integers(0, 2**32, (n, t), dtype=np.uint32))


# ---------------------------------------------------------------------------
# StreamingIndex unit semantics
# ---------------------------------------------------------------------------


def test_index_insert_query_roundtrip(rng):
    icfg = StreamIndexConfig(n_buckets=256, bucket_cap=4)
    state = SI.init_index(CFG, icfg)
    sigs = _random_sigs(rng, 16)
    # duplicate signatures → guaranteed collisions in every table
    sigs = sigs.at[12].set(sigs[3])
    ids = jnp.arange(16, dtype=jnp.int32)
    state = SI.insert(state, sigs, ids, CFG)
    pairs = SI.query(state, sigs, ids, CFG)
    v = np.asarray(pairs.valid)
    found = set(zip(np.asarray(pairs.idx1)[v].tolist(),
                    np.asarray(pairs.idx2)[v].tolist()))
    assert (3, 12) in found
    sims = np.asarray(pairs.sim)[v]
    got = {p: s for p, s in zip(found, sims)}
    assert got[(3, 12)] == CFG.n_tables  # collided in every table
    # random signatures should not pair up
    assert len(found) == 1


def test_index_cross_batch_pairs_and_id_order(rng):
    state = SI.init_index(CFG, StreamIndexConfig(n_buckets=256, bucket_cap=4))
    s1 = _random_sigs(rng, 8)
    s2 = _random_sigs(rng, 8)
    s2 = s2.at[5].set(s1[2])      # batch-2 row matches batch-1 row
    state = SI.insert(state, s1, jnp.arange(8, dtype=jnp.int32), CFG)
    pairs1 = SI.query(state, s1, jnp.arange(8, dtype=jnp.int32), CFG)
    state = SI.insert(state, s2, 8 + jnp.arange(8, dtype=jnp.int32), CFG)
    pairs2 = SI.query(state, s2, 8 + jnp.arange(8, dtype=jnp.int32), CFG)
    v2 = np.asarray(pairs2.valid)
    found = set(zip(np.asarray(pairs2.idx1)[v2].tolist(),
                    np.asarray(pairs2.idx2)[v2].tolist()))
    assert found == {(2, 13)}
    assert int(np.asarray(pairs1.valid).sum()) == 0


def test_index_min_dt_exclusion(rng):
    cfg = L.LSHConfig(n_tables=8, n_funcs=4, n_matches=1, bucket_cap=8,
                      min_dt=4, occurrence_frac=0.0)
    state = SI.init_index(cfg, StreamIndexConfig(n_buckets=64, bucket_cap=8))
    sigs = jnp.tile(_random_sigs(rng, 1, t=8), (6, 1))   # all identical
    ids = jnp.arange(6, dtype=jnp.int32)
    state = SI.insert(state, sigs, ids, cfg)
    pairs = SI.query(state, sigs, ids, cfg)
    v = np.asarray(pairs.valid)
    dts = (np.asarray(pairs.idx2) - np.asarray(pairs.idx1))[v]
    assert (dts >= 4).all() and v.sum() > 0


def test_index_ring_eviction(rng):
    """A bucket holds at most cap entries; oldest get evicted."""
    cfg = L.LSHConfig(n_tables=4, n_funcs=4, n_matches=1, bucket_cap=8,
                      min_dt=1, occurrence_frac=0.0)
    state = SI.init_index(cfg, StreamIndexConfig(n_buckets=64, bucket_cap=2))
    sig = _random_sigs(rng, 1, t=4)
    for i in range(5):            # same signature, five separate inserts
        state = SI.insert(state, sig, jnp.asarray([i], jnp.int32), cfg)
    pairs = SI.query(state, sig, jnp.asarray([5], jnp.int32), cfg)
    v = np.asarray(pairs.valid)
    partners = np.asarray(pairs.idx1)[v]
    # only the 2 newest residents can pair (ids 3 and 4)
    assert set(partners.tolist()) == {3, 4}
    st = SI.index_stats(state)
    assert st["max_bucket_fill"] <= 2
    assert st["inserted"] == 5


def test_index_expire_sliding_window(rng):
    state = SI.init_index(CFG, StreamIndexConfig(n_buckets=256, bucket_cap=4))
    sigs = _random_sigs(rng, 8)
    state = SI.insert(state, sigs, jnp.arange(8, dtype=jnp.int32), CFG)
    state = SI.expire(state, 5)
    resident = np.asarray(state.ids)
    assert (resident[resident != INVALID] >= 5).all()
    # expired entries no longer pair
    pairs = SI.query(state, sigs, 100 + jnp.arange(8, dtype=jnp.int32), CFG)
    v = np.asarray(pairs.valid)
    assert (np.asarray(pairs.idx1)[v] >= 5).all()


def test_index_valid_mask_not_stored(rng):
    state = SI.init_index(CFG, StreamIndexConfig(n_buckets=256, bucket_cap=4))
    sigs = _random_sigs(rng, 8)
    valid = jnp.asarray([True] * 4 + [False] * 4)
    state = SI.insert(state, sigs, jnp.arange(8, dtype=jnp.int32), CFG,
                      valid=valid)
    assert SI.index_stats(state)["resident"] == 4 * CFG.n_tables


# ---------------------------------------------------------------------------
# ingest: ring framing + halo exactness + reservoir stats
# ---------------------------------------------------------------------------


def test_ring_blocks_are_sample_exact(rng):
    fcfg = F.FingerprintConfig(img_freq=16, img_time=32, img_hop=8, top_k=64,
                               mad_sample_rate=1.0)
    wf = rng.standard_normal(30_000).astype(np.float32)
    ring = WaveformRing(fcfg, block_fingerprints=16)
    blocks = []
    for chunk in np.array_split(wf, 7):   # uneven chunk lengths
        blocks.extend(ring.push(chunk))
    tail = ring.flush_partial()
    coeffs_off = np.asarray(F.coeffs_from_waveform(jnp.asarray(wf), fcfg))
    got = 0
    for base, blk in blocks:
        cb = np.asarray(F.coeffs_from_waveform(jnp.asarray(blk), fcfg))
        np.testing.assert_allclose(cb, coeffs_off[base: base + 16],
                                   rtol=1e-5, atol=1e-5)
        got += cb.shape[0]
    assert tail is not None
    base, blk, n_valid = tail
    cb = np.asarray(F.coeffs_from_waveform(jnp.asarray(blk), fcfg))[:n_valid]
    np.testing.assert_allclose(cb, coeffs_off[base: base + n_valid],
                               rtol=1e-5, atol=1e-5)
    assert got + n_valid == fcfg.n_fingerprints(wf.size)


def test_streaming_mad_matches_full_sample(rng):
    coeffs = rng.standard_normal((200, 32)).astype(np.float32)
    sm = StreamingMAD(n_rows=400, n_coeff=32, seed=0)   # reservoir > rows
    for part in np.array_split(coeffs, 9):
        sm.update(part)
    med, mad = sm.stats()
    np.testing.assert_allclose(med, np.median(coeffs, axis=0), atol=1e-6)
    np.testing.assert_allclose(
        mad, np.median(np.abs(coeffs - np.median(coeffs, 0)[None]), 0),
        atol=1e-6)
    # capped reservoir keeps exactly n_rows with uniform-ish coverage
    sm2 = StreamingMAD(n_rows=64, n_coeff=32, seed=0)
    for part in np.array_split(coeffs, 9):
        sm2.update(part)
    assert sm2.filled == 64 and sm2.seen == 200


# ---------------------------------------------------------------------------
# parity: streamed chunks == offline search (acceptance criterion)
# ---------------------------------------------------------------------------


def _parity_setup():
    cfg = smoke_config()
    ds = make_dataset(SynthConfig(duration_s=600.0, n_stations=1,
                                  n_sources=2, events_per_source=5,
                                  event_snr=3.0, seed=3))
    wf = ds.waveforms[0]
    fcfg = cfg.fingerprint
    bits, _ = F.fingerprints_from_waveform(jnp.asarray(wf), fcfg,
                                           key=jax.random.PRNGKey(0))
    pairs_off, _ = L.search(bits, cfg.lsh)
    v = np.asarray(pairs_off.valid)
    off = set(zip(np.asarray(pairs_off.idx1)[v].tolist(),
                  np.asarray(pairs_off.idx2)[v].tolist()))
    med_mad = F.mad_stats(F.coeffs_from_waveform(jnp.asarray(wf), fcfg),
                          1.0, jax.random.PRNGKey(0))
    return cfg, wf, off, (np.asarray(med_mad[0]), np.asarray(med_mad[1]))


def _stream_pairs(cfg, wf, n_chunks, med_mad=None, scfg=None):
    scfg = scfg or StreamConfig(
        block_fingerprints=64,
        index=StreamIndexConfig(n_buckets=2048, bucket_cap=8),
        stats_warmup_blocks=2)
    det = StreamingDetector(cfg, scfg, n_stations=1, med_mad=med_mad)
    for chunk in np.array_split(wf, n_chunks):
        det.push(chunk)
    events, pairs, fstats = det.stations[0].finalize()
    v = np.asarray(pairs.valid)
    got = set(zip(np.asarray(pairs.idx1)[v].tolist(),
                  np.asarray(pairs.idx2)[v].tolist()))
    return got, fstats, det


@pytest.mark.slow
def test_streaming_parity_with_offline_search():
    """≥95% of offline pairs recovered from ≥8 chunks, no spurious blowup."""
    cfg, wf, off, med_mad = _parity_setup()
    got, fstats, det = _stream_pairs(cfg, wf, n_chunks=10, med_mad=med_mad)
    assert len(off) > 0
    recovered = len(off & got) / len(off)
    assert recovered >= 0.95, (recovered, len(off), len(got))
    assert len(got - off) <= max(2, int(0.1 * len(off))), (got - off)
    # event counts must not blow up vs the offline pair population
    assert fstats["events"] <= max(4, 2 * len(off))


@pytest.mark.slow
def test_streaming_parity_self_stats():
    """Self-computed reservoir statistics stay close to offline results."""
    cfg, wf, off, _ = _parity_setup()
    got, fstats, _ = _stream_pairs(cfg, wf, n_chunks=10)
    recovered = len(off & got) / max(len(off), 1)
    assert recovered >= 0.7, (recovered, len(off), len(got))
    assert len(got - off) <= max(3, len(off))
    assert fstats["events"] <= 2 * max(2, len(off))


GOLDEN = pathlib.Path(__file__).parent / "golden" / "stream_pairs.json"


def test_streaming_golden_pair_parity():
    """Golden pin: fixed-seed trace, expected pair sets under tests/golden/.

    Two-pass stats must reproduce the stored streamed pair set *exactly*
    (and with it 100% recovery of the stored offline set); self-computed
    reservoir stats must stay at or above the recorded ~88% recovery. Any
    parity drift fails loudly here instead of sliding under the slow
    threshold tests.
    """
    gold = json.loads(GOLDEN.read_text())
    cfg = smoke_config()
    ds = make_dataset(SynthConfig(**gold["synth"]))
    wf = ds.waveforms[0]
    fcfg = cfg.fingerprint
    med_mad = F.mad_stats(F.coeffs_from_waveform(jnp.asarray(wf), fcfg),
                          1.0, jax.random.PRNGKey(0))
    med_mad = (np.asarray(med_mad[0]), np.asarray(med_mad[1]))
    off = {tuple(p) for p in gold["offline_pairs"]}
    expect_two = {tuple(p) for p in gold["stream_two_pass_pairs"]}

    got_two, _, _ = _stream_pairs(cfg, wf, gold["n_chunks"],
                                  med_mad=med_mad)
    assert got_two == expect_two, (
        sorted(got_two - expect_two), sorted(expect_two - got_two))
    assert len(off & got_two) == len(off)      # 100% of offline recovered

    got_self, _, _ = _stream_pairs(cfg, wf, gold["n_chunks"])
    recovered = len(off & got_self) / len(off)
    floor = gold["self_stats_recall"] - 0.03   # small slack under the pin
    assert recovered >= floor, (recovered, gold["self_stats_recall"])


# ---------------------------------------------------------------------------
# bounded mode: sliding window + rolling filter + incremental association
# ---------------------------------------------------------------------------


def _bounded_setup(n_stations=3, duration_s=600.0, seed=11):
    cfg, scfg = smoke_config(), stream_bounded_smoke_config()
    ds = make_dataset(SynthConfig(duration_s=duration_s,
                                  n_stations=n_stations, n_sources=2,
                                  events_per_source=5, event_snr=3.0,
                                  seed=seed))
    return cfg, scfg, ds


def test_bounded_mode_windows_and_alerts():
    """Sliding window + rolling filter: pairs respect the window, host
    triplet state stays bounded, and multi-station alerts surface before
    finalize."""
    cfg, scfg, ds = _bounded_setup()
    det = StreamingDetector(cfg, scfg, n_stations=3)
    for start in range(0, ds.waveforms.shape[1], 6000):
        det.push(ds.waveforms[:, start: start + 6000])
    # near-real-time association fired during the stream
    assert sum(a.shape[0] for a in det.alerts) >= 1
    detections, events, stats = det.finalize()
    assert stats["detections"] >= 1
    assert stats["alerts"] >= 1
    for i in range(3):
        # rolling filter closed windows and bounded the buffered pairs
        assert stats[f"station{i}_windows"] >= 2
        assert (stats[f"station{i}_peak_buffered_triplets"]
                <= 32 * scfg.filter_window_fingerprints)
        # every retained pair honored the sliding window
        st = det.stations[i]
        assert st.host_state_rows() <= st.peak_tri_rows
        rows = st.filter.all_rows()
        if rows.shape[0]:
            assert (rows[:, 0] < scfg.window_fingerprints).all()


def test_bounded_mode_expiry_caps_pair_reach():
    """With a sliding window, emitted pair dt never exceeds the window."""
    cfg, scfg, ds = _bounded_setup(n_stations=1)
    det = StreamingDetector(cfg, scfg, n_stations=1)
    st = det.stations[0]
    seen = []
    inner_add = st.filter.add
    st.filter.add = lambda tri: (seen.append(np.asarray(tri)),
                                 inner_add(tri))[1]
    for chunk in np.array_split(ds.waveforms[0], 8):
        det.push(chunk)
    st.flush()
    tri = np.concatenate(seen, axis=0)
    assert tri.shape[0] > 0
    assert ((tri[:, 1] - tri[:, 0]) < scfg.window_fingerprints).all()
    # and without a window the same trace emits farther-reaching pairs
    det2 = StreamingDetector(cfg, stream_smoke_config(), n_stations=1)
    for chunk in np.array_split(ds.waveforms[0], 8):
        det2.push(chunk)
    det2.stations[0].flush()
    tri2 = (np.concatenate(det2.stations[0].triplets, axis=0)
            if det2.stations[0].triplets else np.zeros((0, 3), np.int64))
    assert (tri2[:, 1] - tri2[:, 0]).max() >= scfg.window_fingerprints


def test_snapshot_restore_roundtrip(tmp_path):
    """Kill/restore mid-stream reproduces the uninterrupted detections
    exactly (acceptance criterion)."""
    cfg, scfg, ds = _bounded_setup()
    wf = ds.waveforms
    starts = list(range(0, wf.shape[1], 6000))
    half = len(starts) // 2

    run = StreamingDetector(cfg, scfg, n_stations=3)
    for s in starts[:half]:
        run.push(wf[:, s: s + 6000])
    run.snapshot(str(tmp_path), step=half)

    restored, step = StreamingDetector.restore(str(tmp_path), cfg, scfg)
    assert step == half
    for s in starts[half:]:
        run.push(wf[:, s: s + 6000])
        restored.push(wf[:, s: s + 6000])

    uninterrupted = StreamingDetector(cfg, scfg, n_stations=3)
    for s in starts:
        uninterrupted.push(wf[:, s: s + 6000])

    d0, _, s0 = uninterrupted.finalize()
    d1, _, s1 = run.finalize()
    d2, _, s2 = restored.finalize()
    for name in ("dt", "onset", "n_stations", "score", "valid"):
        np.testing.assert_array_equal(np.asarray(d0[name]),
                                      np.asarray(d2[name]), err_msg=name)
        np.testing.assert_array_equal(np.asarray(d0[name]),
                                      np.asarray(d1[name]), err_msg=name)
    assert s2["detections"] == s0["detections"]
    # alert history also carries across the restore
    assert (sum(a.shape[0] for a in restored.alerts)
            == sum(a.shape[0] for a in run.alerts))


def test_snapshot_restore_rejects_mode_mismatch(tmp_path):
    """Restoring under a different streaming mode fails up front with a
    clear error, not a KeyError deep in state reconstruction."""
    cfg, scfg, ds = _bounded_setup(n_stations=1, duration_s=400.0)
    det = StreamingDetector(cfg, scfg, n_stations=1)
    for chunk in np.array_split(ds.waveforms[0], 4):
        det.push(chunk)
    det.snapshot(str(tmp_path))
    with pytest.raises(ValueError, match="window_fingerprints"):
        StreamingDetector.restore(str(tmp_path), cfg, stream_smoke_config())


def test_snapshot_restore_parity_mode(tmp_path):
    """Snapshot/restore is exact in the unbounded parity mode too (the
    accumulated triplets and reservoir state travel with the index)."""
    cfg, scfg = smoke_config(), stream_smoke_config()
    ds = make_dataset(SynthConfig(duration_s=400.0, n_stations=1,
                                  n_sources=2, events_per_source=4,
                                  event_snr=3.0, seed=5))
    wf = ds.waveforms[0]
    chunks = np.array_split(wf, 8)

    run = StreamingDetector(cfg, scfg, n_stations=1)
    for c in chunks[:3]:
        run.push(c)
    run.snapshot(str(tmp_path))
    restored, _ = StreamingDetector.restore(str(tmp_path), cfg, scfg)
    for c in chunks[3:]:
        run.push(c)
        restored.push(c)
    e1, p1, f1 = run.stations[0].finalize()
    e2, p2, f2 = restored.stations[0].finalize()
    np.testing.assert_array_equal(np.asarray(p1.idx1), np.asarray(p2.idx1))
    np.testing.assert_array_equal(np.asarray(p1.valid), np.asarray(p2.valid))
    assert f1 == f2


def test_stream_step_no_retracing():
    """Same-shape chunks reuse one executable for insert/query/step."""
    cfg, wf, _, med_mad = _parity_setup()
    scfg = StreamConfig(block_fingerprints=64,
                        index=StreamIndexConfig(n_buckets=512, bucket_cap=8))
    det = StreamingDetector(cfg, scfg, n_stations=1, med_mad=med_mad)
    st = det.stations[0]
    chunks = np.array_split(wf, 10)
    for c in chunks[:3]:
        det.push(c)
    blocks_before = st.stats.blocks
    traces_before = stream_step._cache_size()
    ins_before = SI.insert._cache_size()
    q_before = SI.query._cache_size()
    for c in chunks[3:]:
        det.push(c)
    assert st.stats.blocks > blocks_before   # more same-shape blocks ran
    assert stream_step._cache_size() == traces_before
    assert SI.insert._cache_size() == ins_before
    assert SI.query._cache_size() == q_before


def test_bounded_stream_step_no_retracing():
    """Expire + rolling-filter steps trigger no recompilation across
    chunks: the sliding window is a static arg (one extra trace total) and
    window closes reuse the padded merge/cluster executables."""
    from repro.core import align as align_mod

    cfg, scfg, ds = _bounded_setup(n_stations=1)
    wf = ds.waveforms[0]
    fcfg = cfg.fingerprint
    med_mad = F.mad_stats(F.coeffs_from_waveform(jnp.asarray(wf), fcfg),
                          1.0, jax.random.PRNGKey(0))
    det = StreamingDetector(cfg, scfg, n_stations=1,
                            med_mad=(np.asarray(med_mad[0]),
                                     np.asarray(med_mad[1])))
    st = det.stations[0]
    chunks = np.array_split(wf, 12)
    for c in chunks[:5]:
        det.push(c)
    # warmup must have closed at least one rolling window (so the filter's
    # merge/cluster executables exist) and run several expiring steps
    assert st.filter.windows_closed >= 1
    step_traces = stream_step._cache_size()
    merge_traces = align_mod.merge_channels._cache_size()
    cluster_traces = align_mod.cluster_station._cache_size()
    windows_before = st.filter.windows_closed
    for c in chunks[5:]:
        det.push(c)
    assert st.filter.windows_closed > windows_before  # more closes ran
    assert stream_step._cache_size() == step_traces
    assert align_mod.merge_channels._cache_size() == merge_traces
    assert align_mod.cluster_station._cache_size() == cluster_traces


# ---------------------------------------------------------------------------
# engine composition + serving
# ---------------------------------------------------------------------------


def test_multi_station_streaming_detections():
    cfg, scfg = smoke_config(), stream_smoke_config()
    ds = make_dataset(SynthConfig(duration_s=600.0, n_stations=3,
                                  n_sources=2, events_per_source=5,
                                  event_snr=3.0, seed=11))
    det = StreamingDetector(cfg, scfg, n_stations=3)
    for start in range(0, ds.waveforms.shape[1], 6000):
        det.push(ds.waveforms[:, start: start + 6000])
    detections, events, stats = det.finalize()
    assert detections is not None
    assert stats["detections"] >= 1          # reoccurring sources found
    assert len(stats["ingest"]) == 3
    assert all(s["fingerprints"] > 0 for s in stats["ingest"])


def test_serve_detect_end_to_end():
    from repro.launch import serve_detect
    stats = serve_detect.main(["--requests", "6", "--slots", "3",
                               "--duration-s", "400"])
    assert stats["requests"] == 6
    assert stats["hit_requests"] >= 1        # event windows match corpus
