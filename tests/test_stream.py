"""Streaming detection subsystem: index semantics, ingest halo exactness,
offline/streaming parity (incl. golden pin), fused single-dispatch hot
path (parity / retracing / donation guards), bounded sliding-window mode
with cross-window merge, snapshot/restore, serving smoke."""
import dataclasses
import json
import pathlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.fast_seismic import (smoke_config,
                                        stream_bounded_smoke_config,
                                        stream_compact_smoke_config,
                                        stream_deferred_smoke_config,
                                        stream_smoke_config)
from repro.core import fingerprint as F
from repro.core import lsh as L
from repro.core.align import AlignConfig
from repro.core.detect import DetectConfig
from repro.core.lsh import INVALID, LSHConfig
from repro.core.synth import SynthConfig, make_dataset
from repro.stream import (StreamConfig, StreamingDetector, StreamIndexConfig,
                          WaveformRing)
from repro.stream import fused as FU
from repro.stream import index as SI
from repro.stream.engine import (RollingPairFilter, merge_boundary_rows,
                                 stream_step)
from repro.stream.ingest import StreamingMAD

CFG = LSHConfig(n_tables=20, n_funcs=4, n_matches=2, bucket_cap=8,
                min_dt=1, occurrence_frac=0.0)


def _random_sigs(rng, n, t=CFG.n_tables):
    return jnp.asarray(rng.integers(0, 2**32, (n, t), dtype=np.uint32))


# ---------------------------------------------------------------------------
# StreamingIndex unit semantics
# ---------------------------------------------------------------------------


def test_index_insert_query_roundtrip(rng):
    icfg = StreamIndexConfig(n_buckets=256, bucket_cap=4)
    state = SI.init_index(CFG, icfg)
    sigs = _random_sigs(rng, 16)
    # duplicate signatures → guaranteed collisions in every table
    sigs = sigs.at[12].set(sigs[3])
    ids = jnp.arange(16, dtype=jnp.int32)
    state = SI.insert(state, sigs, ids, CFG)
    pairs = SI.query(state, sigs, ids, CFG)
    v = np.asarray(pairs.valid)
    found = set(zip(np.asarray(pairs.idx1)[v].tolist(),
                    np.asarray(pairs.idx2)[v].tolist()))
    assert (3, 12) in found
    sims = np.asarray(pairs.sim)[v]
    got = {p: s for p, s in zip(found, sims)}
    assert got[(3, 12)] == CFG.n_tables  # collided in every table
    # random signatures should not pair up
    assert len(found) == 1


def test_index_cross_batch_pairs_and_id_order(rng):
    state = SI.init_index(CFG, StreamIndexConfig(n_buckets=256, bucket_cap=4))
    s1 = _random_sigs(rng, 8)
    s2 = _random_sigs(rng, 8)
    s2 = s2.at[5].set(s1[2])      # batch-2 row matches batch-1 row
    state = SI.insert(state, s1, jnp.arange(8, dtype=jnp.int32), CFG)
    pairs1 = SI.query(state, s1, jnp.arange(8, dtype=jnp.int32), CFG)
    state = SI.insert(state, s2, 8 + jnp.arange(8, dtype=jnp.int32), CFG)
    pairs2 = SI.query(state, s2, 8 + jnp.arange(8, dtype=jnp.int32), CFG)
    v2 = np.asarray(pairs2.valid)
    found = set(zip(np.asarray(pairs2.idx1)[v2].tolist(),
                    np.asarray(pairs2.idx2)[v2].tolist()))
    assert found == {(2, 13)}
    assert int(np.asarray(pairs1.valid).sum()) == 0


def test_index_min_dt_exclusion(rng):
    cfg = L.LSHConfig(n_tables=8, n_funcs=4, n_matches=1, bucket_cap=8,
                      min_dt=4, occurrence_frac=0.0)
    state = SI.init_index(cfg, StreamIndexConfig(n_buckets=64, bucket_cap=8))
    sigs = jnp.tile(_random_sigs(rng, 1, t=8), (6, 1))   # all identical
    ids = jnp.arange(6, dtype=jnp.int32)
    state = SI.insert(state, sigs, ids, cfg)
    pairs = SI.query(state, sigs, ids, cfg)
    v = np.asarray(pairs.valid)
    dts = (np.asarray(pairs.idx2) - np.asarray(pairs.idx1))[v]
    assert (dts >= 4).all() and v.sum() > 0


def test_index_ring_eviction(rng):
    """A bucket holds at most cap entries; oldest get evicted."""
    cfg = L.LSHConfig(n_tables=4, n_funcs=4, n_matches=1, bucket_cap=8,
                      min_dt=1, occurrence_frac=0.0)
    state = SI.init_index(cfg, StreamIndexConfig(n_buckets=64, bucket_cap=2))
    sig = _random_sigs(rng, 1, t=4)
    for i in range(5):            # same signature, five separate inserts
        state = SI.insert(state, sig, jnp.asarray([i], jnp.int32), cfg)
    pairs = SI.query(state, sig, jnp.asarray([5], jnp.int32), cfg)
    v = np.asarray(pairs.valid)
    partners = np.asarray(pairs.idx1)[v]
    # only the 2 newest residents can pair (ids 3 and 4)
    assert set(partners.tolist()) == {3, 4}
    st = SI.index_stats(state)
    assert st["max_bucket_fill"] <= 2
    assert st["inserted"] == 5


def test_index_expire_sliding_window(rng):
    state = SI.init_index(CFG, StreamIndexConfig(n_buckets=256, bucket_cap=4))
    sigs = _random_sigs(rng, 8)
    state = SI.insert(state, sigs, jnp.arange(8, dtype=jnp.int32), CFG)
    state = SI.expire(state, 5)
    resident = np.asarray(state.ids)
    assert (resident[resident != INVALID] >= 5).all()
    # expired entries no longer pair
    pairs = SI.query(state, sigs, 100 + jnp.arange(8, dtype=jnp.int32), CFG)
    v = np.asarray(pairs.valid)
    assert (np.asarray(pairs.idx1)[v] >= 5).all()


def test_index_valid_mask_not_stored(rng):
    state = SI.init_index(CFG, StreamIndexConfig(n_buckets=256, bucket_cap=4))
    sigs = _random_sigs(rng, 8)
    valid = jnp.asarray([True] * 4 + [False] * 4)
    state = SI.insert(state, sigs, jnp.arange(8, dtype=jnp.int32), CFG,
                      valid=valid)
    assert SI.index_stats(state)["resident"] == 4 * CFG.n_tables


# ---------------------------------------------------------------------------
# in-dispatch §6.5 occurrence limiter + window-relative saturation (ISSUE 5)
# ---------------------------------------------------------------------------


def _guarded_batch(state, sigs, base, cfg, n_buckets, **kw):
    n = sigs.shape[0]
    buckets = L.bucket_ids(sigs, n_buckets, cfg.seed)
    ids = base + jnp.arange(n, dtype=jnp.int32)
    return SI.guarded_step(state, sigs, buckets, ids, None, cfg, **kw)


def test_occ_limiter_quarantines_dense_repeaters(rng):
    """A fingerprint family colliding in every table (glitch-train shape)
    accumulates raw partner collisions past the limit within its very
    first batch — in-step counting, so even the first block's pairs die —
    and stays quarantined; sparse random batches through the same limiter
    are bit-identical to the limiter-off program."""
    cfg = L.LSHConfig(n_tables=8, n_funcs=4, n_matches=1, bucket_cap=8,
                      min_dt=1, occurrence_frac=0.0)
    icfg = StreamIndexConfig(n_buckets=256, bucket_cap=8, occ_slots=512)
    glitch = jnp.tile(_random_sigs(rng, 1, t=8), (4, 1))   # identical sigs
    emitted, limited = [], 0
    state = SI.init_index(cfg, icfg)
    for step in range(4):
        state, pairs, qc = _guarded_batch(state, glitch, jnp.int32(4 * step),
                                          cfg, 256, window=0, occ_limit=20)
        emitted.append(int(np.asarray(pairs.valid).sum()))
        limited += int(np.asarray(qc)[2])
    assert sum(emitted) == 0             # never a single train pair out
    assert limited > 0                   # …because the limiter dropped them
    assert int(np.asarray(state.occ).max()) > 20
    # a sparse batch through the same limiter config is untouched
    state2 = SI.init_index(cfg, icfg)
    sparse = _random_sigs(rng, 8, t=8)
    state2, p1, qc1 = _guarded_batch(state2, sparse, jnp.int32(0), cfg, 256,
                                     window=0, occ_limit=20)
    state3 = SI.init_index(cfg, icfg)
    state3, p0, _ = _guarded_batch(state3, sparse, jnp.int32(0), cfg, 256,
                                   window=0, occ_limit=0)
    np.testing.assert_array_equal(np.asarray(p1.valid), np.asarray(p0.valid))
    assert int(np.asarray(qc1)[2]) == 0


def test_occ_limiter_ring_recycles_with_stream():
    """Partner counts die as the id stream advances past the ring span
    (the expire-coupled decay): a fingerprint family quarantined early
    emits again once its counts have been recycled."""
    rng = np.random.default_rng(1)
    cfg = L.LSHConfig(n_tables=8, n_funcs=4, n_matches=1, bucket_cap=8,
                      min_dt=1, occurrence_frac=0.0)
    icfg = StreamIndexConfig(n_buckets=256, bucket_cap=8, occ_slots=32)
    window = 16
    sig = jnp.asarray(rng.integers(0, 2**32, (1, 8), dtype=np.uint32))
    dense = jnp.tile(sig, (4, 1))
    state = SI.init_index(cfg, icfg)
    # batch 1 emits (intra-batch counts under the limit); batch 2's rows
    # also hit batch 1's residents, cross the limit, and are quarantined
    state, p0, _ = _guarded_batch(state, dense, jnp.int32(0), cfg, 256,
                                  window=window, occ_limit=30)
    assert int(np.asarray(p0.valid).sum()) > 0
    state, p1, _ = _guarded_batch(state, dense, jnp.int32(4), cfg, 256,
                                  window=window, occ_limit=30)
    assert int(np.asarray(p1.valid).sum()) == 0
    # a full ring of unrelated ids later, the family's slots recycled
    # (and the window expired the old residents): emission resumes
    base = 8
    for k in range(8):
        filler = jnp.asarray(rng.integers(0, 2**32, (4, 8), dtype=np.uint32))
        state, _, _ = _guarded_batch(state, filler, jnp.int32(base + 4 * k),
                                     cfg, 256, window=window, occ_limit=30)
    state, p2, _ = _guarded_batch(state, dense, jnp.int32(base + 32), cfg,
                                  256, window=window, occ_limit=30)
    assert int(np.asarray(p2.valid).sum()) > 0


def test_occ_limit_requires_ring():
    """The limiter without a partner-count ring is a config error, caught
    up front (not a silent (1,)-ring that quarantines everything)."""
    with pytest.raises(ValueError, match="occ_slots"):
        StreamConfig(occ_limit=10)
    # a ring narrower than the sliding window would alias live counters
    with pytest.raises(ValueError, match="narrower"):
        StreamConfig(occ_limit=10, window_fingerprints=8192,
                     index=StreamIndexConfig(occ_slots=1024))
    # and the dirty smoke config carries a properly sized ring
    from repro.configs.fast_seismic import stream_dirty_smoke_config
    scfg = stream_dirty_smoke_config()
    assert scfg.occ_limit > 0 and scfg.index.occ_slots >= 4096


def test_saturation_traffic_decays_with_window():
    """Window-relative saturation (the ROADMAP follow-up): a bucket
    quarantined by a traffic burst recovers after the sliding window
    passes (its counter halves per window), unlike the old lifetime
    counter which never forgave."""
    rng = np.random.default_rng(2)
    cfg = L.LSHConfig(n_tables=4, n_funcs=4, n_matches=1, bucket_cap=8,
                      min_dt=1, occurrence_frac=0.0)
    icfg = StreamIndexConfig(n_buckets=64, bucket_cap=8)
    window = 16
    sig = jnp.asarray(rng.integers(0, 2**32, (1, 4), dtype=np.uint32))
    dense = jnp.tile(sig, (4, 1))
    state = SI.init_index(cfg, icfg)
    # hammer one bucket family past the saturation limit
    for step in range(4):
        state, pairs, qc = _guarded_batch(
            state, dense, jnp.int32(4 * step), cfg, 64,
            window=window, saturation=10)
    assert int(np.asarray(qc)[1]) > 0            # quarantine engaged
    assert int(np.asarray(pairs.valid).sum()) == 0
    hot_before = int(np.asarray(state.traffic).max())
    assert hot_before > 10
    # the glitching channel is "repaired": several windows of benign
    # traffic later the counter has halved back under the limit
    base = 16
    for k in range(8):
        filler = jnp.asarray(rng.integers(0, 2**32, (4, 4), dtype=np.uint32))
        state, _, _ = _guarded_batch(state, filler, jnp.int32(base + 4 * k),
                                     cfg, 64, window=window, saturation=10)
    assert int(np.asarray(state.traffic).max()) <= 10
    # the family pairs again (its old residents expired; new inserts are
    # below the limit)
    state, p2, _ = _guarded_batch(state, dense, jnp.int32(base + 32), cfg,
                                  64, window=window, saturation=10)
    assert int(np.asarray(p2.valid).sum()) > 0
    # lifetime behavior (window=0) keeps the quarantine forever
    state_l = SI.init_index(cfg, icfg)
    for step in range(4):
        state_l, _, _ = _guarded_batch(state_l, dense, jnp.int32(4 * step),
                                       cfg, 64, window=0, saturation=10)
    for k in range(8):
        filler = jnp.asarray(rng.integers(0, 2**32, (4, 4), dtype=np.uint32))
        state_l, _, _ = _guarded_batch(state_l, filler,
                                       jnp.int32(16 + 4 * k), cfg, 64,
                                       window=0, saturation=10)
    assert int(np.asarray(state_l.traffic).max()) > 10


# ---------------------------------------------------------------------------
# ingest: ring framing + halo exactness + reservoir stats
# ---------------------------------------------------------------------------


def test_ring_blocks_are_sample_exact(rng):
    fcfg = F.FingerprintConfig(img_freq=16, img_time=32, img_hop=8, top_k=64,
                               mad_sample_rate=1.0)
    wf = rng.standard_normal(30_000).astype(np.float32)
    ring = WaveformRing(fcfg, block_fingerprints=16)
    blocks = []
    for chunk in np.array_split(wf, 7):   # uneven chunk lengths
        blocks.extend(ring.push(chunk))
    tail = ring.flush_partial()
    coeffs_off = np.asarray(F.coeffs_from_waveform(jnp.asarray(wf), fcfg))
    got = 0
    for base, blk, mask in blocks:
        assert mask is None               # contiguous input: all valid
        cb = np.asarray(F.coeffs_from_waveform(jnp.asarray(blk), fcfg))
        np.testing.assert_allclose(cb, coeffs_off[base: base + 16],
                                   rtol=1e-5, atol=1e-5)
        got += cb.shape[0]
    assert tail is not None
    base, blk, mask = tail
    n_valid = int(mask.sum())
    assert mask[:n_valid].all()           # clean tail mask is a prefix
    cb = np.asarray(F.coeffs_from_waveform(jnp.asarray(blk), fcfg))[:n_valid]
    np.testing.assert_allclose(cb, coeffs_off[base: base + n_valid],
                               rtol=1e-5, atol=1e-5)
    assert got + n_valid == fcfg.n_fingerprints(wf.size)


def test_streaming_mad_matches_full_sample(rng):
    coeffs = rng.standard_normal((200, 32)).astype(np.float32)
    sm = StreamingMAD(n_rows=400, n_coeff=32, seed=0)   # reservoir > rows
    for part in np.array_split(coeffs, 9):
        sm.update(part)
    med, mad = sm.stats()
    np.testing.assert_allclose(med, np.median(coeffs, axis=0), atol=1e-6)
    np.testing.assert_allclose(
        mad, np.median(np.abs(coeffs - np.median(coeffs, 0)[None]), 0),
        atol=1e-6)
    # capped reservoir keeps exactly n_rows with uniform-ish coverage
    sm2 = StreamingMAD(n_rows=64, n_coeff=32, seed=0)
    for part in np.array_split(coeffs, 9):
        sm2.update(part)
    assert sm2.filled == 64 and sm2.seen == 200


# ---------------------------------------------------------------------------
# parity: streamed chunks == offline search (acceptance criterion)
# ---------------------------------------------------------------------------


def _parity_setup():
    cfg = smoke_config()
    ds = make_dataset(SynthConfig(duration_s=600.0, n_stations=1,
                                  n_sources=2, events_per_source=5,
                                  event_snr=3.0, seed=3))
    wf = ds.waveforms[0]
    fcfg = cfg.fingerprint
    bits, _ = F.fingerprints_from_waveform(jnp.asarray(wf), fcfg,
                                           key=jax.random.PRNGKey(0))
    pairs_off, _ = L.search(bits, cfg.lsh)
    v = np.asarray(pairs_off.valid)
    off = set(zip(np.asarray(pairs_off.idx1)[v].tolist(),
                  np.asarray(pairs_off.idx2)[v].tolist()))
    med_mad = F.mad_stats(F.coeffs_from_waveform(jnp.asarray(wf), fcfg),
                          1.0, jax.random.PRNGKey(0))
    return cfg, wf, off, (np.asarray(med_mad[0]), np.asarray(med_mad[1]))


def _stream_pairs(cfg, wf, n_chunks, med_mad=None, scfg=None):
    scfg = scfg or StreamConfig(
        block_fingerprints=64,
        index=StreamIndexConfig(n_buckets=2048, bucket_cap=8),
        stats_warmup_blocks=2)
    det = StreamingDetector(cfg, scfg, n_stations=1, med_mad=med_mad)
    for chunk in np.array_split(wf, n_chunks):
        det.push(chunk)
    events, pairs, fstats = det.stations[0].finalize()
    v = np.asarray(pairs.valid)
    got = set(zip(np.asarray(pairs.idx1)[v].tolist(),
                  np.asarray(pairs.idx2)[v].tolist()))
    return got, fstats, det


@pytest.mark.slow
def test_streaming_parity_with_offline_search():
    """≥95% of offline pairs recovered from ≥8 chunks, no spurious blowup."""
    cfg, wf, off, med_mad = _parity_setup()
    got, fstats, det = _stream_pairs(cfg, wf, n_chunks=10, med_mad=med_mad)
    assert len(off) > 0
    recovered = len(off & got) / len(off)
    assert recovered >= 0.95, (recovered, len(off), len(got))
    assert len(got - off) <= max(2, int(0.1 * len(off))), (got - off)
    # event counts must not blow up vs the offline pair population
    assert fstats["events"] <= max(4, 2 * len(off))


@pytest.mark.slow
def test_streaming_parity_self_stats():
    """Self-computed reservoir statistics stay close to offline results."""
    cfg, wf, off, _ = _parity_setup()
    got, fstats, _ = _stream_pairs(cfg, wf, n_chunks=10)
    recovered = len(off & got) / max(len(off), 1)
    assert recovered >= 0.7, (recovered, len(off), len(got))
    assert len(got - off) <= max(3, len(off))
    assert fstats["events"] <= 2 * max(2, len(off))


GOLDEN = pathlib.Path(__file__).parent / "golden" / "stream_pairs.json"


def test_streaming_golden_pair_parity():
    """Golden pin: fixed-seed trace, expected pair sets under tests/golden/.

    Two-pass stats must reproduce the stored streamed pair set *exactly*
    (and with it 100% recovery of the stored offline set); self-computed
    reservoir stats with the default warmup must stay at or above the
    recorded ~88% recovery; and the re-binarize-after-freeze hook
    (``stats_warmup_blocks=0``: the reservoir absorbs the whole trace
    before the flush-time freeze binarizes the buffered warmup blocks)
    must close that gap completely — the deferred-freeze self-computed
    statistics reproduce the two-pass pair set exactly, 100% offline
    recall. Any parity drift fails loudly here instead of sliding under
    the slow threshold tests.
    """
    gold = json.loads(GOLDEN.read_text())
    cfg = smoke_config()
    ds = make_dataset(SynthConfig(**gold["synth"]))
    wf = ds.waveforms[0]
    fcfg = cfg.fingerprint
    med_mad = F.mad_stats(F.coeffs_from_waveform(jnp.asarray(wf), fcfg),
                          1.0, jax.random.PRNGKey(0))
    med_mad = (np.asarray(med_mad[0]), np.asarray(med_mad[1]))
    off = {tuple(p) for p in gold["offline_pairs"]}
    expect_two = {tuple(p) for p in gold["stream_two_pass_pairs"]}

    got_two, _, _ = _stream_pairs(cfg, wf, gold["n_chunks"],
                                  med_mad=med_mad)
    assert got_two == expect_two, (
        sorted(got_two - expect_two), sorted(expect_two - got_two))
    assert len(off & got_two) == len(off)      # 100% of offline recovered

    got_self, _, _ = _stream_pairs(cfg, wf, gold["n_chunks"])
    recovered = len(off & got_self) / len(off)
    floor = gold["self_stats_recall"] - 0.03   # small slack under the pin
    assert recovered >= floor, (recovered, gold["self_stats_recall"])

    # deferred freeze: self-computed stats == offline two-pass stats
    got_def, _, _ = _stream_pairs(cfg, wf, gold["n_chunks"],
                                  scfg=stream_deferred_smoke_config())
    assert got_def == expect_two, (
        sorted(got_def - expect_two), sorted(expect_two - got_def))
    assert len(off & got_def) == len(off)      # gap closed: 100% recall

    # ISSUE 8: compacted emission + exact-Jaccard verify reproduces the
    # golden pair set bit-exactly (the bound sits above every real
    # per-block pair count, so nothing overflows on clean data)
    got_cmp, _, det_cmp = _stream_pairs(cfg, wf, gold["n_chunks"],
                                        med_mad=med_mad,
                                        scfg=stream_compact_smoke_config())
    assert got_cmp == expect_two, (
        sorted(got_cmp - expect_two), sorted(expect_two - got_cmp))
    assert det_cmp.telemetry.drop_breakdown()["overflow_pairs"] == 0


# ---------------------------------------------------------------------------
# emission epilogue (ISSUE 8): compaction, overflow, verify ring
# ---------------------------------------------------------------------------


def test_compact_pairs_deterministic_overflow(rng):
    """Overflow drops are deterministic and counted: the compaction keeps
    the first ``max_pairs`` valid stream positions (the lexicographically
    smallest (idx1, idx2), since the stream is pair-sorted) and reports
    exactly the surplus — identically on every run."""
    m = 64
    valid = np.zeros(m, bool)
    valid[[3, 7, 10, 21, 40, 41, 59]] = True
    pairs = L.Pairs(idx1=jnp.arange(m, dtype=jnp.int32),
                    idx2=jnp.arange(m, 2 * m, dtype=jnp.int32),
                    sim=jnp.full((m,), 5, jnp.int32),
                    valid=jnp.asarray(valid))
    outs = [SI.compact_pairs(pairs, 4) for _ in range(2)]
    for compact, overflow in outs:
        kept = np.asarray(compact.valid)
        assert int(kept.sum()) == 4
        assert int(overflow) == 3
        # first four valid stream positions survive
        assert sorted(np.asarray(compact.idx1)[kept].tolist()) \
            == [3, 7, 10, 21]
    a, b = outs
    assert np.array_equal(np.asarray(a[0].idx1), np.asarray(b[0].idx1))
    # bound above the valid count: everything kept, zero overflow
    all_kept, overflow = SI.compact_pairs(pairs, 16)
    assert int(overflow) == 0
    assert int(np.asarray(all_kept.valid).sum()) == 7


def test_stream_overflow_counted_and_deterministic():
    """A bound below the real per-block pair count drops deterministically
    and reconciles: dense emission − compacted emission = the registry's
    ``step_overflow_pairs_total`` (mirrored from the in-dispatch QC
    vector), and two runs of the starved config emit identical pairs."""
    cfg = smoke_config()
    ds = make_dataset(SynthConfig(duration_s=240.0, n_stations=1,
                                  n_sources=2, events_per_source=6,
                                  event_snr=4.0, seed=13))
    wf = ds.waveforms[0]

    def run(scfg):
        det = StreamingDetector(cfg, scfg, n_stations=1)
        for chunk in np.array_split(wf, 6):
            det.push(chunk)
        det.flush()
        tri = det.stations[0].accumulated_pairs()
        v = np.asarray(tri.valid)
        got = set(zip(np.asarray(tri.idx1)[v].tolist(),
                      np.asarray(tri.idx2)[v].tolist()))
        return got, det

    dense, _ = run(stream_smoke_config())
    starved = dataclasses.replace(stream_compact_smoke_config(),
                                  max_pairs_per_block=1)
    got1, det1 = run(starved)
    got2, det2 = run(starved)
    assert got1 == got2                      # deterministic drop rule
    assert got1 <= dense                     # never invents pairs
    overflow = det1.telemetry.drop_breakdown()["overflow_pairs"]
    assert overflow == det2.telemetry.drop_breakdown()["overflow_pairs"]
    assert len(dense) - len(got1) == overflow, \
        (len(dense), len(got1), overflow)
    assert overflow > 0                      # the bound actually bit


def test_compact_snapshot_restores_packed_ring(tmp_path):
    """Mid-stream snapshot under the verify config: the bit-packed
    fingerprint ring restores bit-exactly and the resumed stream emits
    the uninterrupted stream's pairs."""
    cfg, scfg = smoke_config(), stream_compact_smoke_config()
    ds = make_dataset(SynthConfig(duration_s=600.0, n_stations=1,
                                  n_sources=2, events_per_source=5,
                                  event_snr=3.0, seed=9))
    chunks = np.array_split(ds.waveforms[:1], 8, axis=1)

    det = StreamingDetector(cfg, scfg, n_stations=1)
    for c in chunks[:5]:
        det.push(c)
    det.snapshot(str(tmp_path), step=5)
    pk_before = np.asarray(jax.device_get(det.stations[0].state.pk))
    assert pk_before.any()       # the ring has really been written
    for c in chunks[5:]:
        det.push(c)

    det2, step = StreamingDetector.restore(str(tmp_path), cfg, scfg)
    assert step == 5
    pk_after = np.asarray(jax.device_get(det2.stations[0].state.pk))
    assert np.array_equal(pk_before, pk_after)
    for c in chunks[5:]:
        det2.push(c)
    e0, p0, f0 = det.stations[0].finalize()
    e1, p1, f1 = det2.stations[0].finalize()
    np.testing.assert_array_equal(np.asarray(p0.idx1), np.asarray(p1.idx1))
    np.testing.assert_array_equal(np.asarray(p0.valid),
                                  np.asarray(p1.valid))
    assert f0 == f1

    # layout guard: restoring a verify snapshot without verify is rejected
    with pytest.raises(ValueError, match="verify_jaccard"):
        StreamingDetector.restore(str(tmp_path), cfg, stream_smoke_config())


def test_verify_jaccard_channel_and_threshold(rng):
    """The verify epilogue emits exact Jaccard for every surviving pair
    (identical fingerprints score 1.0) and ``verify_min_jaccard`` drops
    low-similarity hash matches in-dispatch."""
    lcfg = CFG
    icfg = StreamIndexConfig(n_buckets=256, bucket_cap=4, pk_slots=64,
                             pk_words=4)
    n = 16
    packed = jnp.asarray(rng.integers(0, 2**32, (n, 4), dtype=np.uint32))
    packed = packed.at[12].set(packed[3])     # exact repeat → Jaccard 1.0
    bits = np.unpackbits(
        np.asarray(packed).view(np.uint8), axis=1, bitorder="little")
    sigs = L.signatures(jnp.asarray(bits), L.hash_mappings(128, lcfg), lcfg)
    ids = jnp.arange(n, dtype=jnp.int32)
    buckets = L.bucket_ids(sigs, icfg.n_buckets, lcfg.seed)

    def step(min_jac):
        state = SI.init_index(lcfg, icfg)
        _, pairs, qc = SI.guarded_step(
            state, sigs, buckets, ids, None, lcfg, window=0,
            packed=packed, max_pairs=32, verify=1, min_jac=min_jac)
        return pairs

    pairs = step(0.0)
    v = np.asarray(pairs.valid)
    got = {p: j for p, j in zip(
        zip(np.asarray(pairs.idx1)[v].tolist(),
            np.asarray(pairs.idx2)[v].tolist()),
        np.asarray(pairs.jac)[v].tolist())}
    assert got[(3, 12)] == pytest.approx(1.0)
    assert all(0.0 <= j <= 1.0 for j in got.values())

    # threshold just under 1.0: only the exact repeat survives
    strict = step(0.99)
    sv = np.asarray(strict.valid)
    kept = set(zip(np.asarray(strict.idx1)[sv].tolist(),
                   np.asarray(strict.idx2)[sv].tolist()))
    assert kept == {(3, 12)}


# ---------------------------------------------------------------------------
# bounded mode: sliding window + rolling filter + incremental association
# ---------------------------------------------------------------------------


def _bounded_setup(n_stations=3, duration_s=600.0, seed=11):
    cfg, scfg = smoke_config(), stream_bounded_smoke_config()
    ds = make_dataset(SynthConfig(duration_s=duration_s,
                                  n_stations=n_stations, n_sources=2,
                                  events_per_source=5, event_snr=3.0,
                                  seed=seed))
    return cfg, scfg, ds


def test_bounded_mode_windows_and_alerts():
    """Sliding window + rolling filter: pairs respect the window, host
    triplet state stays bounded, and multi-station alerts surface before
    finalize."""
    cfg, scfg, ds = _bounded_setup()
    det = StreamingDetector(cfg, scfg, n_stations=3)
    for start in range(0, ds.waveforms.shape[1], 6000):
        det.push(ds.waveforms[:, start: start + 6000])
    # near-real-time association fired during the stream
    assert sum(a.shape[0] for a in det.alerts) >= 1
    detections, events, stats = det.finalize()
    assert stats["detections"] >= 1
    assert stats["alerts"] >= 1
    for i in range(3):
        # rolling filter closed windows and bounded the buffered pairs
        assert stats[f"station{i}_windows"] >= 2
        assert (stats[f"station{i}_peak_buffered_triplets"]
                <= 32 * scfg.filter_window_fingerprints)
        # every retained pair honored the sliding window
        st = det.stations[i]
        assert st.host_state_rows() <= st.peak_tri_rows
        rows = st.filter.all_rows()
        if rows.shape[0]:
            assert (rows[:, 0] < scfg.window_fingerprints).all()


def test_bounded_mode_expiry_caps_pair_reach():
    """With a sliding window, emitted pair dt never exceeds the window."""
    cfg, scfg, ds = _bounded_setup(n_stations=1)
    det = StreamingDetector(cfg, scfg, n_stations=1)
    st = det.stations[0]
    seen = []
    inner_add = st.filter.add
    st.filter.add = lambda tri: (seen.append(np.asarray(tri)),
                                 inner_add(tri))[1]
    for chunk in np.array_split(ds.waveforms[0], 8):
        det.push(chunk)
    st.flush()
    tri = np.concatenate(seen, axis=0)
    assert tri.shape[0] > 0
    assert ((tri[:, 1] - tri[:, 0]) < scfg.window_fingerprints).all()
    # and without a window the same trace emits farther-reaching pairs
    det2 = StreamingDetector(cfg, stream_smoke_config(), n_stations=1)
    for chunk in np.array_split(ds.waveforms[0], 8):
        det2.push(chunk)
    det2.stations[0].flush()
    tri2 = (np.concatenate(det2.stations[0].triplets, axis=0)
            if det2.stations[0].triplets else np.zeros((0, 3), np.int64))
    assert (tri2[:, 1] - tri2[:, 0]).max() >= scfg.window_fingerprints


def test_snapshot_restore_roundtrip(tmp_path):
    """Kill/restore mid-stream reproduces the uninterrupted detections
    exactly (acceptance criterion)."""
    cfg, scfg, ds = _bounded_setup()
    wf = ds.waveforms
    starts = list(range(0, wf.shape[1], 6000))
    half = len(starts) // 2

    run = StreamingDetector(cfg, scfg, n_stations=3)
    for s in starts[:half]:
        run.push(wf[:, s: s + 6000])
    run.snapshot(str(tmp_path), step=half)

    restored, step = StreamingDetector.restore(str(tmp_path), cfg, scfg)
    assert step == half
    for s in starts[half:]:
        run.push(wf[:, s: s + 6000])
        restored.push(wf[:, s: s + 6000])

    uninterrupted = StreamingDetector(cfg, scfg, n_stations=3)
    for s in starts:
        uninterrupted.push(wf[:, s: s + 6000])

    d0, _, s0 = uninterrupted.finalize()
    d1, _, s1 = run.finalize()
    d2, _, s2 = restored.finalize()
    for name in ("dt", "onset", "n_stations", "score", "valid"):
        np.testing.assert_array_equal(np.asarray(d0[name]),
                                      np.asarray(d2[name]), err_msg=name)
        np.testing.assert_array_equal(np.asarray(d0[name]),
                                      np.asarray(d1[name]), err_msg=name)
    assert s2["detections"] == s0["detections"]
    # alert history also carries across the restore
    assert (sum(a.shape[0] for a in restored.alerts)
            == sum(a.shape[0] for a in run.alerts))


def test_snapshot_restore_rejects_mode_mismatch(tmp_path):
    """Restoring under a different streaming mode fails up front with a
    clear error, not a KeyError deep in state reconstruction."""
    cfg, scfg, ds = _bounded_setup(n_stations=1, duration_s=400.0)
    det = StreamingDetector(cfg, scfg, n_stations=1)
    for chunk in np.array_split(ds.waveforms[0], 4):
        det.push(chunk)
    det.snapshot(str(tmp_path))
    with pytest.raises(ValueError, match="window_fingerprints"):
        StreamingDetector.restore(str(tmp_path), cfg, stream_smoke_config())


def test_snapshot_restore_parity_mode(tmp_path):
    """Snapshot/restore is exact in the unbounded parity mode too (the
    accumulated triplets and reservoir state travel with the index)."""
    cfg, scfg = smoke_config(), stream_smoke_config()
    ds = make_dataset(SynthConfig(duration_s=400.0, n_stations=1,
                                  n_sources=2, events_per_source=4,
                                  event_snr=3.0, seed=5))
    wf = ds.waveforms[0]
    chunks = np.array_split(wf, 8)

    run = StreamingDetector(cfg, scfg, n_stations=1)
    for c in chunks[:3]:
        run.push(c)
    run.snapshot(str(tmp_path))
    restored, _ = StreamingDetector.restore(str(tmp_path), cfg, scfg)
    for c in chunks[3:]:
        run.push(c)
        restored.push(c)
    e1, p1, f1 = run.stations[0].finalize()
    e2, p2, f2 = restored.stations[0].finalize()
    np.testing.assert_array_equal(np.asarray(p1.idx1), np.asarray(p2.idx1))
    np.testing.assert_array_equal(np.asarray(p1.valid), np.asarray(p2.valid))
    assert f1 == f2


def test_stream_step_no_retracing():
    """Same-shape chunks reuse one executable, in both hot paths.

    Unfused: ``block_coeffs`` + ``stream_step`` + insert/query caches stay
    flat. Fused: the steady state is exactly ONE ``step_advance`` trace —
    the one-dispatch invariant's retracing half (≤1 trace across ≥3
    same-shape chunks after warmup).
    """
    cfg, wf, _, med_mad = _parity_setup()
    chunks = np.array_split(wf, 10)

    # -- unfused chain
    scfg = StreamConfig(block_fingerprints=64,
                        index=StreamIndexConfig(n_buckets=512, bucket_cap=8),
                        fused=False, pooled=False)
    det = StreamingDetector(cfg, scfg, n_stations=1, med_mad=med_mad)
    st = det.stations[0]
    for c in chunks[:3]:
        det.push(c)
    blocks_before = st.stats.blocks
    traces_before = stream_step._cache_size()
    ins_before = SI.insert._cache_size()
    q_before = SI.query._cache_size()
    for c in chunks[3:]:
        det.push(c)
    assert st.stats.blocks > blocks_before   # more same-shape blocks ran
    assert stream_step._cache_size() == traces_before
    assert SI.insert._cache_size() == ins_before
    assert SI.query._cache_size() == q_before

    # -- fused single-dispatch path
    scfg_f = dataclasses.replace(scfg, fused=True)
    adv_start = FU.step_advance._cache_size()
    det = StreamingDetector(cfg, scfg_f, n_stations=1, med_mad=med_mad)
    st = det.stations[0]
    for c in chunks[:5]:        # ≥2 blocks: step_block seed + step_advance
        det.push(c)
    assert st.stats.blocks >= 2
    blocks_before = st.stats.blocks
    adv_before = FU.step_advance._cache_size()
    blk_before = FU.step_block._cache_size()
    assert adv_before - adv_start == 1  # one steady-state trace, total
    assert len(chunks[5:]) >= 3     # ≥3 same-shape chunks follow
    for c in chunks[5:]:
        det.push(c)
    assert st.stats.blocks >= blocks_before + 2
    assert FU.step_advance._cache_size() == adv_before  # ≤1 trace total
    assert FU.step_block._cache_size() == blk_before


def test_bounded_stream_step_no_retracing():
    """Expire + rolling-filter steps trigger no recompilation across
    chunks: the sliding window is a static arg (one extra trace total) and
    window closes reuse the padded merge/cluster executables — in the
    fused hot path too."""
    from repro.core import align as align_mod

    cfg, scfg, ds = _bounded_setup(n_stations=1)
    wf = ds.waveforms[0]
    fcfg = cfg.fingerprint
    med_mad = F.mad_stats(F.coeffs_from_waveform(jnp.asarray(wf), fcfg),
                          1.0, jax.random.PRNGKey(0))
    det = StreamingDetector(cfg, scfg, n_stations=1,
                            med_mad=(np.asarray(med_mad[0]),
                                     np.asarray(med_mad[1])))
    st = det.stations[0]
    chunks = np.array_split(wf, 12)
    for c in chunks[:6]:        # ≥2 blocks: step_advance is traced too
        det.push(c)
    # warmup must have closed at least one rolling window (so the filter's
    # merge/cluster executables exist) and run several expiring steps
    assert st.stats.blocks >= 2
    assert st.filter.windows_closed >= 1
    adv_traces = FU.step_advance._cache_size()
    blk_traces = FU.step_block._cache_size()
    merge_traces = align_mod.merge_channels._cache_size()
    cluster_traces = align_mod.cluster_station._cache_size()
    windows_before = st.filter.windows_closed
    for c in chunks[6:]:
        det.push(c)
    assert st.filter.windows_closed > windows_before  # more closes ran
    assert FU.step_advance._cache_size() == adv_traces
    assert FU.step_block._cache_size() == blk_traces
    assert align_mod.merge_channels._cache_size() == merge_traces
    assert align_mod.cluster_station._cache_size() == cluster_traces


# ---------------------------------------------------------------------------
# fused single-dispatch hot path (ISSUE 3): parity + donation guards
# ---------------------------------------------------------------------------


def _pair_set(det, station=0):
    _, pairs, fstats = det.stations[station].finalize()
    v = np.asarray(pairs.valid)
    return set(zip(np.asarray(pairs.idx1)[v].tolist(),
                   np.asarray(pairs.idx2)[v].tolist())), fstats


def test_fused_step_parity_with_multi_call_path():
    """The fused single dispatch is bit-identical to the unfused
    ``block_coeffs`` + ``stream_step`` chain on ``stream_smoke_config`` —
    same pair set with given stats, with self-computed warmup stats, and
    across the masked flush tail (acceptance criterion)."""
    cfg, wf, _, med_mad = _parity_setup()
    scfg_f = stream_smoke_config()
    scfg_u = dataclasses.replace(scfg_f, fused=False, pooled=False)
    for mm in (med_mad, None):
        got = {}
        for name, scfg in (("fused", scfg_f), ("unfused", scfg_u)):
            det = StreamingDetector(cfg, scfg, n_stations=1, med_mad=mm)
            for c in np.array_split(wf, 10):
                det.push(c)
            got[name], fstats = _pair_set(det)
            assert fstats["fingerprints"] > 0
        assert got["fused"] == got["unfused"], (
            mm is None, sorted(got["fused"] ^ got["unfused"]))
        assert len(got["fused"]) > 0


def test_pooled_detector_matches_sequential():
    """The vmapped station pool yields the same per-station pairs/events
    as S sequential single-station engines."""
    cfg, scfg, ds = _bounded_setup(n_stations=3)
    det_p = StreamingDetector(cfg, scfg, n_stations=3)
    det_s = StreamingDetector(cfg, dataclasses.replace(scfg, pooled=False),
                              n_stations=3)
    assert det_p.pooled and not det_s.pooled
    for start in range(0, ds.waveforms.shape[1], 6000):
        det_p.push(ds.waveforms[:, start: start + 6000])
        det_s.push(ds.waveforms[:, start: start + 6000])
    dp, ep, sp = det_p.finalize()
    ds_, es, ss = det_s.finalize()
    for i in range(3):
        for k in ("fingerprints", "pairs", "events", "windows"):
            assert sp[f"station{i}_{k}"] == ss[f"station{i}_{k}"], (i, k)
    assert sp["detections"] == ss["detections"]
    for name in ("dt", "onset", "n_stations", "score", "valid"):
        np.testing.assert_array_equal(np.asarray(dp[name]),
                                      np.asarray(ds_[name]), err_msg=name)


def test_fused_step_donation_no_new_allocations():
    """The donation half of the one-dispatch invariant: after warmup the
    steady state retains ZERO new device bytes per chunk — every state
    buffer is an in-place donated reuse (``jax.live_arrays`` delta)."""
    cfg, wf, _, med_mad = _parity_setup()
    scfg = stream_smoke_config()
    det = StreamingDetector(cfg, scfg, n_stations=1, med_mad=med_mad)
    st = det.stations[0]
    chunks = np.array_split(wf, 10)
    for c in chunks[:5]:        # compile step_block + step_advance
        det.push(c)
    assert st.stats.blocks >= 2
    jax.block_until_ready(st.fstate.index.cursor)
    n0 = len(jax.live_arrays())
    b0 = sum(a.nbytes for a in jax.live_arrays())
    blocks_before = st.stats.blocks
    for c in chunks[5:]:
        det.push(c)
    jax.block_until_ready(st.fstate.index.cursor)
    assert st.stats.blocks > blocks_before
    n1 = len(jax.live_arrays())
    b1 = sum(a.nbytes for a in jax.live_arrays())
    assert (n1, b1) == (n0, b0), (n1 - n0, b1 - b0)


def test_fused_state_does_not_alias_caller_stats():
    """Donating the fused state must not delete the caller's med/mad
    arrays (the state copies them at freeze)."""
    cfg, wf, _, med_mad = _parity_setup()
    mm = (jnp.asarray(med_mad[0]), jnp.asarray(med_mad[1]))
    det = StreamingDetector(cfg, stream_smoke_config(), n_stations=1,
                            med_mad=mm)
    for c in np.array_split(wf, 6):
        det.push(c)
    # the originals survive the donated dispatches…
    assert np.isfinite(np.asarray(mm[0])).all()
    # …and the station still exposes usable statistics
    med, mad = det.stations[0].med_mad
    np.testing.assert_array_equal(np.asarray(med), med_mad[0])


# ---------------------------------------------------------------------------
# data-quality path (ISSUE 4): clean bit-parity + one-dispatch invariants
# ---------------------------------------------------------------------------


def test_quality_path_clean_bit_parity():
    """Acceptance criterion: with every quality feature enabled (reorder
    horizon, saturation quarantine, sample-exact duplicate guard) but no
    pathologies present, the emitted pair set is identical to the
    pre-quality fused path — for given and for self-computed statistics —
    and every quality counter stays zero."""
    from repro.configs.fast_seismic import stream_dirty_smoke_config
    cfg, wf, _, med_mad = _parity_setup()
    for mm in (med_mad, None):
        got, quality = {}, None
        for name, scfg in (("base", stream_smoke_config()),
                           ("quality", stream_dirty_smoke_config())):
            det = StreamingDetector(cfg, scfg, n_stations=1, med_mad=mm)
            for c in np.array_split(wf, 10):
                det.push(c)
            got[name], fstats = _pair_set(det)
            quality = fstats["quality"]
        assert got["base"] == got["quality"], (
            mm is None, sorted(got["base"] ^ got["quality"]))
        assert len(got["base"]) > 0
        assert all(v == 0 for v in quality.values()), quality


def test_quality_path_single_dispatch_invariants():
    """Acceptance criterion: the one-dispatch invariants survive the
    quality path — ≤1 steady-state trace and zero retained bytes/chunk,
    including across a gap-masked block mid-steady-state (masks route
    through the already-traced ``step_block``, never re-splitting or
    retracing the hot path)."""
    from repro.configs.fast_seismic import stream_dirty_smoke_config
    cfg, wf, _, med_mad = _parity_setup()
    scfg = stream_dirty_smoke_config()
    wf = wf.copy()
    mid = wf.size * 3 // 4
    wf[mid: mid + 900] = np.nan           # a gap inside the steady state
    det = StreamingDetector(cfg, scfg, n_stations=1, med_mad=med_mad)
    st = det.stations[0]
    chunks = np.array_split(wf, 10)
    adv_start = FU.step_advance._cache_size()
    for c in chunks[:5]:
        det.push(c)
    assert st.stats.blocks >= 2
    jax.block_until_ready(st.fstate.index.cursor)
    adv_before = FU.step_advance._cache_size()
    blk_before = FU.step_block._cache_size()
    # ≤1 new steady-state trace (0 when another quality test already
    # traced these statics in-process)
    assert adv_before - adv_start <= 1
    n0 = len(jax.live_arrays())
    b0 = sum(a.nbytes for a in jax.live_arrays())
    blocks_before = st.stats.blocks
    for c in chunks[5:]:
        det.push(c)
    jax.block_until_ready(st.fstate.index.cursor)
    assert st.stats.blocks > blocks_before
    assert st.qc["suppressed_fingerprints"] > 0   # the gap really was masked
    assert FU.step_advance._cache_size() == adv_before
    assert FU.step_block._cache_size() == blk_before
    n1 = len(jax.live_arrays())
    b1 = sum(a.nbytes for a in jax.live_arrays())
    assert (n1, b1) == (n0, b0), (n1 - n0, b1 - b0)


# ---------------------------------------------------------------------------
# cross-window merge pass (bounded-mode boundary artifact)
# ---------------------------------------------------------------------------


def _merge_cfg():
    fp = F.FingerprintConfig(img_freq=16, img_time=32, img_hop=8, top_k=64,
                             mad_sample_rate=1.0)
    return DetectConfig(
        fingerprint=fp,
        lsh=LSHConfig(n_tables=20, n_funcs=4, n_matches=2, bucket_cap=4,
                      min_dt=fp.overlap_fingerprints, occurrence_frac=0.0),
        align=AlignConfig(min_cluster_size=1, min_cluster_sim=4))


def test_cross_window_merge_boundary_cluster():
    """A diagonal cluster straddling a rolling-filter window boundary is
    split by the per-window clustering and re-merged by the cross-window
    pass before association (regression for the ROADMAP artifact)."""
    cfg = _merge_cfg()
    filt = RollingPairFilter(cfg, window=64, lookback=128)
    # one repeating source: pairs on diagonal dt=40 whose later members
    # span the first window close at id 64
    idx2 = np.arange(58, 71)
    tri = np.stack([idx2 - 40, idx2, np.full_like(idx2, 8)], axis=1)
    filt.add(tri)
    filt.advance(200)           # closes [0,64), [64,128), [128,192)
    assert filt.windows_closed >= 2
    raw = np.concatenate(filt.event_rows, axis=0)
    assert raw.shape[0] == 2    # the boundary split happened…
    merged = filt.all_rows()
    assert merged.shape[0] == 1  # …and the merge pass undoes it
    dt, onset, extent, size, score = merged[0]
    assert dt == 40 and onset == 18 and extent == 12
    assert size == raw[:, 3].sum() and score == raw[:, 4].sum()
    # rows_tail (the incremental association feed) sees the merged row too
    assert filt.rows_tail(0).shape[0] == 1


def test_merge_boundary_rows_keeps_distinct_clusters():
    """Rows on far diagonals or with disjoint idx ranges never merge."""
    acfg = AlignConfig()
    rows = np.array([
        [40, 18, 5, 6, 48],     # base cluster
        [40, 60, 4, 5, 40],     # same diagonal, far beyond gap → distinct
        [90, 18, 5, 6, 48],     # different diagonal → distinct
        [41, 24, 6, 7, 56],     # adjacent diagonal, touching → merges
    ], np.int64)
    out = merge_boundary_rows(rows, acfg)
    assert out.shape[0] == 3
    merged = out[(out[:, 1] == 18) & (out[:, 0] != 90)]
    assert merged.shape[0] == 1 and merged[0, 3] == 13
    # higher-score member donates the representative dt
    assert merged[0, 0] == 41


def test_merge_boundary_rows_bridge_union():
    """Regression (ISSUE 9): two clusters not pairwise-near are joined by
    a bridging row near both. The old first-match-only pass merged the
    bridge into the first cluster and left the second stranded (2 rows);
    the union pass yields one deterministic component."""
    acfg = AlignConfig()                      # dt_merge_tol=2, gap=10
    rows = np.array([
        [40, 0, 5, 6, 48],      # cluster 1
        [44, 8, 2, 3, 24],      # cluster 2: |44-40| > dt_merge_tol
        [42, 12, 3, 4, 60],     # bridge: within tol + gap of BOTH
    ], np.int64)
    out = merge_boundary_rows(rows, acfg)
    assert out.shape[0] == 1, out
    dt, onset, extent, size, score = out[0]
    assert dt == 42             # highest-score member's original dt
    assert onset == 0 and extent == 15
    assert size == 13 and score == 132
    # deterministic under any input ordering
    for perm in ([1, 0, 2], [2, 1, 0], [1, 2, 0]):
        assert np.array_equal(merge_boundary_rows(rows[perm], acfg), out)


def test_merge_boundary_rows_three_window_chain():
    """A single diagonal straddling THREE rolling-filter windows surfaces
    as three boundary rows and re-merges into one span."""
    cfg = _merge_cfg()
    filt = RollingPairFilter(cfg, window=64, lookback=128)
    idx2 = np.arange(58, 136)   # later members span closes at 64 and 128
    tri = np.stack([idx2 - 40, idx2, np.full_like(idx2, 8)], axis=1)
    filt.add(tri)
    filt.advance(260)           # closes [0,64), [64,128), [128,192)
    assert filt.windows_closed >= 3
    raw = np.concatenate(filt.event_rows, axis=0)
    assert raw.shape[0] == 3    # split at both boundaries…
    merged = filt.all_rows()
    assert merged.shape[0] == 1  # …and the chain re-joins end to end
    dt, onset, extent, size, score = merged[0]
    assert dt == 40 and onset == 18 and onset + extent == 95
    assert size == raw[:, 3].sum() and score == raw[:, 4].sum()


# ---------------------------------------------------------------------------
# engine composition + serving
# ---------------------------------------------------------------------------


def test_poll_reemits_on_station_multiplicity_upgrade():
    """Regression (ISSUE 9): a group first alerted at 2 stations re-emits
    (flagged as an upgrade) when a third station's events arrive in a
    later window — the old (dt, onset)-only dedup suppressed it forever."""
    from repro.stream.engine import ALERT_COLS
    cfg, scfg = smoke_config(), stream_bounded_smoke_config()
    det = StreamingDetector(cfg, scfg, n_stations=3)

    def close_with(station, row):
        det.stations[station].filter.event_rows.append(
            np.asarray([row], np.int64))
        det.stations[station].filter.windows_closed += 1

    # two stations see the repeating pair first
    close_with(0, (50, 100, 4, 3, 24))
    close_with(1, (50, 103, 4, 3, 21))
    first = det.poll_detections()
    assert first.shape == (1, ALERT_COLS)
    assert first[0, 2] == 2 and first[0, 4] == 0       # fresh, 2 stations
    # a re-poll with no new window closes is silent
    assert det.poll_detections().shape[0] == 0
    # the third station reports in a later window → upgrade re-emission
    close_with(2, (51, 105, 4, 3, 18))
    second = det.poll_detections()
    assert second.shape == (1, ALERT_COLS), second
    assert second[0, 2] == 3 and second[0, 4] == 1     # upgraded to 3
    # same multiplicity again → deduped as before
    close_with(0, (50, 101, 4, 2, 16))
    assert det.poll_detections().shape[0] == 0


def test_streaming_located_alerts_end_to_end():
    """The streaming locate tier: physical-geometry scenario in, alerts
    carry milli-km locations + milli-magnitudes, the finalize detections
    carry the located columns, and the telemetry locate view counts the
    stack passes."""
    from repro.configs.fast_seismic import located_smoke_config
    from repro.core import locate as LO
    from repro.stream.engine import ALERT_COLS
    cfg, scfg = located_smoke_config(), stream_bounded_smoke_config()
    ds = make_dataset(SynthConfig(duration_s=900.0, n_stations=4,
                                  n_sources=2, events_per_source=6,
                                  event_snr=3.0, seed=11,
                                  physical_geometry=True))
    det = StreamingDetector(cfg, scfg, n_stations=4,
                            station_xy=ds.station_xy)
    assert det.locating
    for start in range(0, ds.waveforms.shape[1], 6000):
        det.push(ds.waveforms[:, start: start + 6000])
    alerts = np.concatenate(det.alerts, axis=0)
    assert alerts.shape[0] >= 1 and alerts.shape[1] == ALERT_COLS
    located = alerts[alerts[:, 5] != LO.LOC_NONE]
    assert located.shape[0] >= 1       # at least one alert localized
    assert (located[:, 5] >= 0).all() and (located[:, 5] <= 50_000).all()
    assert (located[:, 7] != LO.MAG_NONE).any()   # …and sized
    detections, _, stats = det.finalize()
    assert "moveout_rejected" in stats
    v = np.asarray(detections["valid"])
    assert int(v.sum()) == stats["detections"] >= 1
    assert np.isfinite(np.asarray(detections["x_km"])[v]).all()
    assert (np.asarray(detections["station_weight"]) > 0).all()
    view = det.telemetry.locate_view()
    assert view["passes"] >= 2 and view["located"] >= 1
    assert view["stack_wall"]["count"] == view["passes"]
    snap = det.metrics_snapshot()
    assert snap["locate"]["passes"] == view["passes"]


def test_multi_station_streaming_detections():
    cfg, scfg = smoke_config(), stream_smoke_config()
    ds = make_dataset(SynthConfig(duration_s=600.0, n_stations=3,
                                  n_sources=2, events_per_source=5,
                                  event_snr=3.0, seed=11))
    det = StreamingDetector(cfg, scfg, n_stations=3)
    for start in range(0, ds.waveforms.shape[1], 6000):
        det.push(ds.waveforms[:, start: start + 6000])
    detections, events, stats = det.finalize()
    assert detections is not None
    assert stats["detections"] >= 1          # reoccurring sources found
    assert len(stats["ingest"]) == 3
    assert all(s["fingerprints"] > 0 for s in stats["ingest"])


def test_serve_detect_end_to_end():
    """The slot/refill loop now answers against the per-station index
    pool (default 2 stations)."""
    from repro.launch import serve_detect
    stats = serve_detect.main(["--requests", "6", "--slots", "3",
                               "--duration-s", "400"])
    assert stats["requests"] == 6
    assert stats["stations"] == 2
    assert stats["hit_requests"] >= 1        # event windows match corpus


@pytest.mark.slow
def test_bench_e2e_smoke(tmp_path, monkeypatch):
    """``make bench-smoke`` contract: the quick e2e benchmark runs, emits
    a schema-stable BENCH_e2e.json, and the fused path does not regress
    below the unfused chain (perf regressions are one command to spot)."""
    import sys
    root = str(pathlib.Path(__file__).parent.parent)
    if root not in sys.path:
        sys.path.insert(0, root)
    monkeypatch.setenv("BENCH_OUT_DIR", str(tmp_path))
    from benchmarks import bench_e2e
    out = bench_e2e.main(["--quick"])
    assert out["schema"] == "bench-e2e/v4"
    assert set(out) >= {"config_hash", "backend", "step", "points",
                        "offline_replay", "emission", "sharded_pool",
                        "ratios", "metrics"}
    assert out["metrics"]["schema"] == "stream-metrics/v1"
    assert out["metrics"]["stations"] == 4
    written = json.loads((tmp_path / "BENCH_e2e.json").read_text())
    assert written["config_hash"] == out["config_hash"]
    stations = sorted(p["stations"] for p in out["points"] if p["fused"])
    assert stations == [1, 4, 8]
    # the headline claim, with slack for shared-machine timing noise
    assert out["ratios"]["fused_speedup_vs_unfused_chain"] >= 1.2
    # donation: the fused steady state retains no device memory per chunk
    # (the unfused reference may release warmup buffers → delta ≤ 0)
    assert all(p["live_bytes_delta_per_chunk"] == 0
               for p in out["points"] if p["fused"])
    assert all(p["live_bytes_delta_per_chunk"] <= 0 for p in out["points"])
    # offline replay (ISSUE 5): unified batch driver at 1/4/8 stations,
    # at least as fast as the legacy host loop at 4 stations
    replay = out["offline_replay"]
    assert sorted(p["stations"] for p in replay["points"]) == [1, 4, 8]
    assert replay["speedup_vs_legacy_4st"] >= 1.0
    assert out["ratios"]["offline_replay_speedup_vs_legacy_4st"] \
        == replay["speedup_vs_legacy_4st"]
    # v3: the repeat-seeded stream exercises real emission (the v2 points
    # all recorded pairs: 0) and every point carries the wall split
    assert all(p["pairs"] > 0 for p in out["emission"]["points"])
    for p in out["points"]:
        assert p["pairs"] > 0
        assert {"device_step_ms_p50", "host_tail_ms_p50",
                "pair_bytes_per_block"} <= set(p)
        # v4: the primary percentiles are exact wall quantiles; the
        # log-bucketed histogram values moved to *_hist keys
        assert {"device_step_ms_p50_hist",
                "host_tail_ms_p50_hist"} <= set(p)
    # v4: the sharded-pool device grid ran with exact step percentiles
    # and bit-identical pair counts between the sharded and vmap pools
    sp = out["sharded_pool"]
    assert sp["points"] and all(p["pair_parity"] for p in sp["points"])
    assert any(p["devices"] == 8 and p["stations"] == 8
               for p in sp["points"])
    assert out["ratios"]["sharded_pool_speedup_8st_8dev"] \
        == sp["speedup_8st_8dev"]
    # emission A/B (ISSUE 8): dense vs compact at 1/4/8 stations, the
    # compacted pipe is the configured ≥10x smaller, and compaction
    # drops nothing on the clean seeded stream (identical pair counts)
    em = {(p["stations"], p["variant"]): p
          for p in out["emission"]["points"]}
    assert sorted(em) == [(s, v) for s in (1, 4, 8)
                          for v in ("compact", "dense")]
    assert out["emission"]["pair_byte_reduction_t100"] >= 10.0
    assert out["ratios"]["emission_pair_byte_reduction_t100"] \
        == out["emission"]["pair_byte_reduction_t100"]
    for s in (1, 4, 8):
        assert em[(s, "compact")]["pairs"] == em[(s, "dense")]["pairs"]
        assert em[(s, "compact")]["overflow_pairs"] == 0
