"""Per-arch smoke tests (reduced configs, one forward/train step on CPU,
shape + finiteness assertions) plus SSM-vs-recurrence oracles and blocked
attention equivalence."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import LM_ARCHS, get_smoke_config
from repro.models import (ModelConfig, decode_step, forward, init_cache,
                          init_params, lm_loss, padded_vocab, param_shapes,
                          param_sharding_rules, prefill)
from repro.models import layers as L
from repro.models import ssm as S

B, SEQ = 2, 64


def _batch(rng, cfg, b=B, s=SEQ):
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    batch = {"tokens": toks, "labels": toks,
             "loss_mask": jnp.ones((b, s), jnp.float32)}
    if cfg.frontend == "patch":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_patches, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_arch_smoke_forward_and_grad(rng, arch):
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(rng, cfg)
    loss, metrics = lm_loss(params, batch, cfg)
    assert np.isfinite(float(loss)), (arch, loss)
    hidden, _ = forward(params, batch, cfg)
    assert hidden.shape == (B, SEQ, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()
    grads = jax.grad(lambda p: lm_loss(p, batch, cfg)[0])(params)
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, (arch, gn)


@pytest.mark.parametrize("arch", ["yi-9b", "falcon-mamba-7b",
                                  "zamba2-1.2b", "deepseek-moe-16b"])
def test_arch_smoke_decode(rng, arch):
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    cache = init_cache(cfg, B, 16)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    logits, cache = decode_step(params, cache, tok, cfg)
    assert logits.shape == (B, padded_vocab(cfg))
    assert np.isfinite(np.asarray(logits)).all()
    assert int(cache["pos"][0]) == 1


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "falcon-mamba-7b",
                                  "zamba2-1.2b", "command-r-35b"])
def test_prefill_then_decode_matches_forward(rng, arch):
    cfg = get_smoke_config(arch)
    cfg = type(cfg)(**{**cfg.__dict__, "param_dtype": "float32",
                       "compute_dtype": "float32",
                       "cache_dtype": "float32"})
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(rng, cfg, b=1, s=32)
    hidden, _ = forward(params, batch, cfg)
    w = params["lm_head"].astype(jnp.float32)
    want = jnp.einsum("bd,dv->bv", hidden[:, -1].astype(jnp.float32), w)
    logits_last, cache = prefill(params, batch, cfg)
    np.testing.assert_allclose(np.asarray(logits_last), np.asarray(want),
                               atol=2e-3, rtol=1e-3)


def test_param_shapes_and_rules_align():
    for arch in LM_ARCHS:
        cfg = get_smoke_config(arch)
        shapes = param_shapes(cfg)
        rules = param_sharding_rules(cfg)

        def walk(s, r):
            if isinstance(s, tuple):
                assert isinstance(r, tuple) and len(r) == len(s), (s, r)
                return
            assert set(s) == set(r), (set(s), set(r))
            for k in s:
                walk(s[k], r[k])

        walk(shapes, rules)


# ---------------------------------------------------------------------------
# SSM oracles: chunked scans == naive step-by-step recurrence
# ---------------------------------------------------------------------------


def test_mamba1_scan_matches_recurrence(rng):
    b, s, di, n = 2, 24, 8, 4
    xdt = rng.standard_normal((b, s, di)).astype(np.float32)
    da = -np.abs(rng.standard_normal((b, s, di, n))).astype(np.float32)
    bm = rng.standard_normal((b, s, n)).astype(np.float32)
    cm = rng.standard_normal((b, s, n)).astype(np.float32)
    h0 = np.zeros((b, di, n), np.float32)
    y, hf = S.mamba1_scan(*map(jnp.asarray, (xdt, da, bm, cm, h0)), chunk=8)
    # naive
    h = h0.copy()
    ys = []
    for t in range(s):
        h = np.exp(da[:, t]) * h + xdt[:, t][..., None] * bm[:, t][:, None]
        ys.append((h * cm[:, t][:, None]).sum(-1))
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), h, atol=1e-4)


def test_ssd_matches_recurrence(rng):
    b, s, hh, p, n = 2, 16, 3, 4, 5
    xdt = rng.standard_normal((b, s, hh, p)).astype(np.float32)
    a = -np.abs(rng.standard_normal((b, s, hh))).astype(np.float32) * 0.3
    bm = rng.standard_normal((b, s, n)).astype(np.float32)
    cm = rng.standard_normal((b, s, n)).astype(np.float32)
    h0 = np.zeros((b, hh, p, n), np.float32)
    y, hf = S.ssd(*map(jnp.asarray, (xdt, a, bm, cm, h0)), chunk=4)
    h = h0.copy()
    ys = []
    for t in range(s):
        g = np.exp(a[:, t])[..., None, None]
        h = g * h + xdt[:, t][..., None] * bm[:, t][:, None, None, :]
        ys.append(np.einsum("bhpn,bn->bhp", h, cm[:, t]))
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1), atol=1e-3)
    np.testing.assert_allclose(np.asarray(hf), h, atol=1e-3)


# ---------------------------------------------------------------------------
# attention equivalence (masked vs triangular vs reference)
# ---------------------------------------------------------------------------


def test_blocked_attention_impls_agree(rng):
    cfg = ModelConfig(attn_q_block=16, attn_kv_block=16)
    q = jnp.asarray(rng.standard_normal((2, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 64, 2, 16)), jnp.float32)
    masked = L.blocked_attention(q, k, v, cfg, impl="masked")
    tri = L.blocked_attention(q, k, v, cfg, impl="triangular")
    np.testing.assert_allclose(np.asarray(masked), np.asarray(tri),
                               atol=2e-5)
    from repro.kernels import ref
    want = ref.flash_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                               jnp.swapaxes(v, 1, 2), causal=True)
    np.testing.assert_allclose(np.asarray(jnp.swapaxes(masked, 1, 2)),
                               np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------


def test_moe_router_mass_conservation(rng):
    cfg = get_smoke_config("deepseek-moe-16b")
    h2 = jnp.asarray(rng.standard_normal((32, cfg.d_model)), jnp.float32)
    rw = jnp.asarray(rng.standard_normal((cfg.d_model, cfg.n_experts)),
                     jnp.float32)
    top_e, top_w, aux = L._route(h2, rw, cfg)
    w = np.asarray(top_w)
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-5)
    assert (np.asarray(top_e) < cfg.n_experts).all()
    assert float(aux) > 0


def test_moe_capacity_drops_only_overflow(rng):
    """With generous capacity, every token gets its full top-k output."""
    cfg0 = get_smoke_config("deepseek-moe-16b")
    cfg = type(cfg0)(**{**cfg0.__dict__, "capacity_factor": 8.0,
                        "param_dtype": "float32",
                        "compute_dtype": "float32"})
    params = init_params(jax.random.PRNGKey(0), cfg)
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    y, aux = L.moe_block(lp["moe"], x, cfg)
    # reference: dense per-token expert mix (no capacity)
    h = L.rms_norm(x, lp["moe"]["ln"], cfg.rms_eps)
    h2 = h.reshape(-1, cfg.d_model)
    top_e, top_w, _ = L._route(h2, lp["moe"]["router"], cfg)
    wg, wu, wd = (lp["moe"][k].astype(jnp.float32) for k in
                  ("wg", "wu", "wd"))
    dense = jnp.zeros_like(h2)
    for slot in range(cfg.moe_top_k):
        e = top_e[:, slot]
        g = jnp.einsum("td,tdf->tf", h2, wg[e])
        u = jnp.einsum("td,tdf->tf", h2, wu[e])
        o = jnp.einsum("tf,tfd->td", jax.nn.silu(g) * u, wd[e])
        dense = dense + top_w[:, slot:slot + 1] * o
    # add shared experts
    sg = jnp.einsum("td,df->tf", h2, lp["moe"]["swg"].astype(jnp.float32))
    su = jnp.einsum("td,df->tf", h2, lp["moe"]["swu"].astype(jnp.float32))
    dense = dense + jnp.einsum("tf,fd->td", jax.nn.silu(sg) * su,
                               lp["moe"]["swd"].astype(jnp.float32))
    want = x + dense.reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-3,
                               rtol=1e-3)
