"""Training substrate: optimizer math, microbatch equivalence, loss
decreases, watchdog."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import ModelConfig, init_params, lm_loss
from repro.train.loop import (TrainState, init_train_state, make_train_step,
                              microbatch_split)
from repro.train.optimizer import (OptimizerConfig, apply_updates,
                                   global_norm, init_opt_state, schedule)
from repro.train.watchdog import StepWatchdog, WatchdogConfig

CFG = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  d_ff=128, vocab_size=256, attn_q_block=32,
                  attn_kv_block=32, loss_seq_chunk=32,
                  param_dtype="float32", compute_dtype="float32",
                  remat="none")


def _batch(rng, b=8, s=64):
    toks = jnp.asarray(rng.integers(0, CFG.vocab_size, (b, s)), jnp.int32)
    return {"tokens": toks, "labels": toks,
            "loss_mask": jnp.ones((b, s), jnp.float32)}


def test_adamw_matches_reference_scalar():
    """Hand-checked AdamW on a single scalar parameter."""
    cfg = OptimizerConfig(lr=0.1, beta1=0.9, beta2=0.99, eps=1e-8,
                          weight_decay=0.0, clip_norm=1e9, warmup_steps=0,
                          total_steps=10**9, min_lr_frac=1.0)
    p = {"w": jnp.asarray(2.0)}
    opt = init_opt_state(p)
    g = {"w": jnp.asarray(0.5)}
    p2, opt2, _ = apply_updates(p, g, opt, cfg)
    # step 1: m=0.05, v=0.0025; mhat=0.5, vhat=0.25 → delta = 1.0
    np.testing.assert_allclose(float(p2["w"]), 2.0 - 0.1 * (0.5 / 0.5),
                               rtol=1e-5)


def test_grad_clipping():
    cfg = OptimizerConfig(clip_norm=1.0, warmup_steps=0, weight_decay=0.0)
    p = {"w": jnp.ones(4)}
    opt = init_opt_state(p)
    g = {"w": jnp.full(4, 100.0)}
    _, opt2, metrics = apply_updates(p, g, opt, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)
    # clipped: m = (1-b1) * g*scale, scale = 1/200
    np.testing.assert_allclose(np.asarray(opt2["m"]["w"]),
                               0.1 * 100.0 / 200.0, rtol=1e-4)


def test_schedule_warmup_and_decay():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1)


def test_microbatch_split_layout(rng):
    batch = {"x": jnp.arange(32).reshape(16, 2)}
    out = microbatch_split(batch, n_mb=4, dp=2)["x"]
    assert out.shape == (4, 4, 2)
    # each microbatch must contain one block from each dp shard
    flat = np.asarray(out).reshape(4, 4, 2)
    first_col = flat[:, :, 0] // 2  # original row ids
    for mb in range(4):
        rows = set(first_col[mb].tolist())
        assert any(r < 8 for r in rows) and any(r >= 8 for r in rows)


def test_microbatching_equivalent_grads(rng):
    """1 vs 4 microbatches give the same update (fp32 accumulation)."""
    opt_cfg = OptimizerConfig(accum_dtype="float32", warmup_steps=0)
    batch = _batch(rng)
    s1 = init_train_state(jax.random.PRNGKey(0), CFG)
    s4 = jax.tree.map(lambda x: x, s1)
    step1 = make_train_step(CFG, opt_cfg, n_microbatches=1)
    step4 = make_train_step(CFG, opt_cfg, n_microbatches=4)
    s1b, m1 = step1(s1, batch)
    s4b, m4 = step4(s4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1b.params), jax.tree.leaves(s4b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@pytest.mark.slow
def test_loss_decreases(rng):
    opt_cfg = OptimizerConfig(lr=1e-2, warmup_steps=2, total_steps=30,
                              accum_dtype="float32")
    state = init_train_state(jax.random.PRNGKey(0), CFG)
    step = jax.jit(make_train_step(CFG, opt_cfg), donate_argnums=(0,))
    batch = _batch(rng)  # overfit one batch
    losses = []
    for _ in range(30):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])


def test_watchdog_flags_straggler():
    times = iter([0.0, 1.0, 10.0, 11.0, 20.0, 21.0, 30.0, 31.0, 40.0, 41.0,
                  50.0, 51.0, 60.0, 75.0])
    clock = lambda: next(times)
    events = []
    wd = StepWatchdog(WatchdogConfig(min_samples=3, straggler_factor=2.0,
                                     hang_timeout_s=1000.0),
                      on_straggler=events.append, clock=clock)
    for _ in range(7):
        wd.step_start()
        wd.step_end()
    assert len(events) == 1 and events[0]["reason"] == "straggler"


def test_watchdog_flags_hang():
    times = iter([0.0, 500.0])
    wd = StepWatchdog(WatchdogConfig(hang_timeout_s=300.0),
                      clock=lambda: next(times))
    wd.step_start()
    wd.step_end()
    assert wd.events and wd.events[0]["reason"] == "hang"
