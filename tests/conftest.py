import os
import sys

# keep the test process at 1 visible device (the dry-run sets 512 in its
# own subprocess; tests must NOT inherit that)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


def pytest_collection_modifyitems(config, items):
    # `tier1` is an alias marker: every test not marked slow belongs to the
    # tier-1 suite, so `-m tier1` selects exactly the fast default set.
    for item in items:
        if "slow" not in item.keywords:
            item.add_marker(pytest.mark.tier1)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
