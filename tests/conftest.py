import os
import sys

# keep the test process at 1 visible device (the dry-run sets 512 in its
# own subprocess; tests must NOT inherit that)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
