import os
import subprocess
import sys
import textwrap

# keep the test process at 1 visible device (the dry-run sets 512 in its
# own subprocess; tests must NOT inherit that)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_forced_devices(code: str, timeout=1200, devices: int = 8) -> str:
    """Run ``code`` in a child interpreter with ``devices`` forced host
    devices. Device count binds at backend init, so every multi-device
    test needs its own process; this is the one place the
    ``XLA_FLAGS=--xla_force_host_platform_device_count`` pattern lives
    (previously copy-pasted per test module). Asserts a clean exit and
    returns stdout."""
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               JAX_PLATFORMS="cpu")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=env, capture_output=True, timeout=timeout)
    assert p.returncode == 0, (p.stdout.decode()[-2000:]
                               + p.stderr.decode()[-3000:])
    return p.stdout.decode()


@pytest.fixture(scope="session")
def forced_devices():
    """The subprocess runner as a fixture (tests take it as an argument
    instead of importing across test modules)."""
    return run_forced_devices


def pytest_collection_modifyitems(config, items):
    # `tier1` is an alias marker: every test not marked slow belongs to the
    # tier-1 suite, so `-m tier1` selects exactly the fast default set.
    for item in items:
        if "slow" not in item.keywords:
            item.add_marker(pytest.mark.tier1)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
