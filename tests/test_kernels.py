"""Per-kernel interpret-mode validation against the pure-jnp oracles,
with shape/dtype sweeps (repo contract for kernels/)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# minmax_hash
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,d,h", [(7, 96, 33), (64, 256, 128), (1, 32, 1),
                                   (130, 513, 130)])
@pytest.mark.parametrize("density", [0.02, 0.3])
def test_minmax_hash_matches_ref(rng, n, d, h, density):
    fp = rng.random((n, d)) < density
    mp = rng.integers(0, 2**31 - 1, size=(d, h), dtype=np.int32)
    mins_k, maxs_k = ops.minmax_hash(jnp.asarray(fp), jnp.asarray(mp))
    mins_r, maxs_r = ref.minmax_hash(jnp.asarray(fp), jnp.asarray(mp))
    np.testing.assert_array_equal(np.asarray(mins_k), np.asarray(mins_r))
    np.testing.assert_array_equal(np.asarray(maxs_k), np.asarray(maxs_r))


def test_minmax_hash_empty_rows(rng):
    fp = np.zeros((4, 64), bool)
    mp = rng.integers(0, 2**31 - 1, size=(64, 8), dtype=np.int32)
    mins, maxs = ops.minmax_hash(jnp.asarray(fp), jnp.asarray(mp))
    assert int(jnp.min(mins)) == 2**31 - 1
    assert int(jnp.max(maxs)) == 0


@pytest.mark.parametrize("n,t,n_funcs,use_minmax",
                         [(7, 20, 4, True), (33, 100, 8, True),
                          (16, 12, 4, False), (5, 7, 6, True)])
def test_minmax_sig_buckets_matches_signature_oracle(rng, n, t, n_funcs,
                                                     use_minmax):
    """The fused signature-fold + bucket-addressing kernel epilogue is
    bit-identical to the jnp composition (signatures → bucket_ids) for
    every table layout, including non-multiple table counts."""
    import dataclasses
    from repro.core import lsh as L

    cfg = L.LSHConfig(n_tables=t, n_funcs=n_funcs, use_minmax=use_minmax,
                      seed=99)
    d = 256
    fp = jnp.asarray(rng.random((n, d)) < 0.3)
    mp = L.hash_mappings(d, cfg)
    n_buckets = 1024
    sig_o = L.signatures(fp, mp, cfg)
    bkt_o = L.bucket_ids(sig_o, n_buckets, cfg.seed)
    sig_k, bkt_k = ops.minmax_sig_buckets(
        fp, mp, L.bucket_salts(t, cfg.seed), use_minmax=use_minmax,
        n_buckets=n_buckets)
    np.testing.assert_array_equal(np.asarray(sig_k), np.asarray(sig_o))
    np.testing.assert_array_equal(np.asarray(bkt_k), np.asarray(bkt_o))
    # and through the config-level entry with a validity mask
    pcfg = dataclasses.replace(cfg, use_pallas=True)
    valid = jnp.asarray(rng.random(n) < 0.6)
    s1, b1 = L.signatures_and_buckets(fp, mp, pcfg, n_buckets, valid=valid)
    s2 = L.signatures(fp, mp, cfg, valid=valid)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(
        np.asarray(b1), np.asarray(L.bucket_ids(s2, n_buckets, cfg.seed)))


# ---------------------------------------------------------------------------
# haar2d
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,h,w", [(5, 8, 8), (9, 32, 64), (2, 16, 128)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_haar2d_matches_ref(rng, n, h, w, dtype):
    imgs = rng.standard_normal((n, h, w)).astype(dtype)
    out_k = ops.haar2d(jnp.asarray(imgs))
    out_r = ref.haar2d(jnp.asarray(imgs))
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=5e-4, rtol=1e-4)


def test_haar_matrix_orthonormal():
    for n in (2, 8, 64):
        t = ref.haar_matrix(n)
        np.testing.assert_allclose(t @ t.T, np.eye(n), atol=1e-5)


def test_haar2d_preserves_energy(rng):
    imgs = rng.standard_normal((3, 16, 32)).astype(np.float32)
    out = np.asarray(ref.haar2d(jnp.asarray(imgs)))
    np.testing.assert_allclose((out**2).sum(), (imgs**2).sum(), rtol=1e-4)


# ---------------------------------------------------------------------------
# stft_mag
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,l,k", [(10, 200, 101), (3, 64, 33), (257, 128,
                                                                 65)])
def test_stft_mag_matches_ref(rng, n, l, k):
    frames = rng.standard_normal((n, l)).astype(np.float32)
    win = np.hanning(l).astype(np.float32)
    dr, di = ref.dft_matrices(l, k)
    args = [jnp.asarray(a) for a in (frames, win, dr, di)]
    out_k = ops.stft_mag(*args)
    out_r = ref.stft_mag(*args)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=5e-4, atol=5e-3)


def test_stft_matches_numpy_rfft(rng):
    x = rng.standard_normal((4, 128)).astype(np.float32)
    win = np.hanning(128).astype(np.float32)
    dr, di = ref.dft_matrices(128, 65)
    ours = np.asarray(ref.stft_mag(*map(jnp.asarray, (x, win, dr, di))))
    theirs = np.abs(np.fft.rfft(x * win, axis=-1)) ** 2
    np.testing.assert_allclose(ours, theirs, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# jaccard_popcount
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p,w", [(16, 4), (513, 8), (1, 256)])
def test_jaccard_matches_ref(rng, p, w):
    a = rng.integers(0, 2**32, size=(p, w), dtype=np.uint32)
    b = rng.integers(0, 2**32, size=(p, w), dtype=np.uint32)
    out_k = ops.jaccard_popcount(jnp.asarray(a), jnp.asarray(b))
    out_r = ref.jaccard_popcount(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=1e-6)


def test_jaccard_identical_and_disjoint():
    a = np.asarray([[0b1010, 0], [0, 0b1]], np.uint32)
    b = np.asarray([[0b0101, 0], [0, 0b1]], np.uint32)
    out = np.asarray(ref.jaccard_popcount(jnp.asarray(a), jnp.asarray(b)))
    assert out[0] == 0.0 and out[1] == 1.0


def test_verify_epilogue_pallas_parity(rng):
    """The ISSUE-8 exact-Jaccard verify epilogue is bit-identical whether
    it scores through the jnp oracle (``verify=1``) or the Pallas
    popcount kernel in interpret mode (``verify=2``) — both the raw
    ``verify_pairs`` gather and the full ``guarded_step`` emission."""
    import dataclasses
    from repro.core import lsh as L
    from repro.stream import index as SI

    lcfg = L.LSHConfig(n_tables=20, n_funcs=4, n_matches=2, bucket_cap=4,
                       min_dt=0)
    icfg = SI.StreamIndexConfig(n_buckets=256, bucket_cap=4, pk_slots=64,
                                pk_words=4)
    n = 32
    packed = jnp.asarray(rng.integers(0, 2**32, (n, 4), dtype=np.uint32))
    packed = packed.at[20].set(packed[5])     # one exact repeat
    bits = np.unpackbits(np.asarray(packed).view(np.uint8), axis=1,
                         bitorder="little")
    sigs = L.signatures(jnp.asarray(bits), L.hash_mappings(128, lcfg), lcfg)
    ids = jnp.arange(n, dtype=jnp.int32)
    buckets = L.bucket_ids(sigs, icfg.n_buckets, lcfg.seed)

    # raw verify_pairs: oracle vs Pallas on the same ring + candidates
    state = dataclasses.replace(SI.init_index(lcfg, icfg),
                                pk=jnp.zeros((64, 4), jnp.uint32)
                                .at[ids % 64].set(packed))
    cand = L.Pairs(idx1=ids[:16], idx2=jnp.roll(ids, 7)[:16],
                   sim=jnp.ones(16, jnp.float32),
                   valid=jnp.asarray(rng.random(16) < 0.75))
    j_ref = np.asarray(SI.verify_pairs(state, cand, use_pallas=False))
    j_pal = np.asarray(SI.verify_pairs(state, cand, use_pallas=True))
    np.testing.assert_array_equal(j_ref, j_pal)

    # full in-dispatch epilogue: identical VerifiedPairs either route
    def step(verify):
        _, pairs, _ = SI.guarded_step(
            SI.init_index(lcfg, icfg), sigs, buckets, ids, None, lcfg,
            window=0, packed=packed, max_pairs=32, verify=verify)
        return pairs
    p1, p2 = step(1), step(2)
    for f in ("idx1", "idx2", "sim", "jac", "valid"):
        np.testing.assert_array_equal(np.asarray(getattr(p1, f)),
                                      np.asarray(getattr(p2, f)))
    assert np.asarray(p1.valid).any()   # the parity claim is non-vacuous


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,hq,hkv,sq,sk,d", [
    (2, 4, 2, 128, 128, 64),
    (1, 8, 1, 64, 64, 32),
    (2, 4, 4, 8, 128, 64),     # decode-ish: short q against long cache
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(rng, b, hq, hkv, sq, sk, d, causal):
    q = rng.standard_normal((b, hq, sq, d)).astype(np.float32)
    k = rng.standard_normal((b, hkv, sk, d)).astype(np.float32)
    v = rng.standard_normal((b, hkv, sk, d)).astype(np.float32)
    out_k = ops.flash_attention(jnp.asarray(q), jnp.asarray(k),
                                jnp.asarray(v), causal=causal,
                                bq=min(64, sq), bk=64)
    out_r = ref.flash_attention(jnp.asarray(q), jnp.asarray(k),
                                jnp.asarray(v), causal=causal)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=5e-5)


# ---------------------------------------------------------------------------
# fused mamba scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,s,di,n,bd", [(2, 16, 8, 4, 8), (1, 33, 24, 5, 8),
                                         (3, 8, 128, 16, 128)])
def test_mamba_scan_matches_ref(rng, b, s, di, n, bd):
    xdt = rng.standard_normal((b, s, di)).astype(np.float32)
    dt = np.abs(rng.standard_normal((b, s, di))).astype(np.float32) * 0.1
    a = -np.abs(rng.standard_normal((di, n))).astype(np.float32)
    bm = rng.standard_normal((b, s, n)).astype(np.float32)
    cm = rng.standard_normal((b, s, n)).astype(np.float32)
    args = [jnp.asarray(x) for x in (xdt, dt, a, bm, cm)]
    yk, hk = ops.mamba_scan(*args, bd=bd)
    yr, hr = ref.mamba_scan(*args)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), atol=2e-5)


def test_mamba_scan_consistent_with_model_scan(rng):
    """Kernel semantics == the model's chunked associative scan."""
    from repro.models.ssm import mamba1_scan
    b, s, di, n = 2, 32, 8, 4
    xdt = rng.standard_normal((b, s, di)).astype(np.float32)
    dt = np.abs(rng.standard_normal((b, s, di))).astype(np.float32) * 0.1
    a = -np.abs(rng.standard_normal((di, n))).astype(np.float32)
    bm = rng.standard_normal((b, s, n)).astype(np.float32)
    cm = rng.standard_normal((b, s, n)).astype(np.float32)
    yk, hk = ref.mamba_scan(*[jnp.asarray(x) for x in (xdt, dt, a, bm, cm)])
    da = dt[..., None] * a[None, None]
    y2, h2 = mamba1_scan(jnp.asarray(xdt), jnp.asarray(da), jnp.asarray(bm),
                         jnp.asarray(cm), jnp.zeros((b, di, n)), chunk=8)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(h2), atol=1e-4)
