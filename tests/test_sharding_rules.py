"""Sharding-rule invariants: rules↔shapes alignment for every arch under
both layouts, sanitize_spec semantics (mesh-subset degrade, uneven mode,
manual axes, vocab alias), ZeRO extension properties."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import LM_ARCHS, get_config, get_smoke_config
from repro.dist import _LAYOUT, _MANUAL, _UNEVEN
from repro.models import param_shapes, param_sharding_rules
from repro.train.optimizer import zero_sharding_entry


def _walk(shapes, rules, fn):
    if isinstance(shapes, tuple):
        fn(shapes, rules)
        return
    assert set(shapes) == set(rules)
    for k in shapes:
        _walk(shapes[k], rules[k], fn)


@pytest.mark.parametrize("arch", LM_ARCHS)
@pytest.mark.parametrize("layout", ["tp", "fsdp"])
def test_rules_align_with_shapes_all_layouts(arch, layout):
    cfg = get_config(arch)  # FULL configs — rules are shape-only
    tok = _LAYOUT.set(layout)
    try:
        rules = param_sharding_rules(cfg)
    finally:
        _LAYOUT.reset(tok)
    shapes = param_shapes(cfg)

    def check(shp, rule):
        assert len(rule) == len(shp), (shp, rule)
        for entry in rule:
            assert entry is None or isinstance(entry, (str, tuple))

    _walk(shapes, rules, check)


def test_fsdp_rules_shard_every_big_param():
    cfg = get_config("command-r-35b")
    tok = _LAYOUT.set("fsdp")
    try:
        rules = param_sharding_rules(cfg)
    finally:
        _LAYOUT.reset(tok)
    shapes = param_shapes(cfg)

    def check(shp, rule):
        n = int(np.prod(shp))
        if n >= 1 << 20:  # every big tensor must shard over something
            assert any(e is not None for e in rule), (shp, rule)

    _walk(shapes, rules, check)


def test_zero_sharding_entry_properties():
    # extends with data on the largest unsharded dim
    assert zero_sharding_entry((None, "model", None), (48, 64, 128)) \
        == (None, "model", "data")
    # never double-shards a tensor already using data
    spec = zero_sharding_entry(("data", None), (16, 8))
    assert spec == ("data", None)
    # scalar-ish: unchanged
    assert zero_sharding_entry((None,), (7,)) in ((None,), ("data",))


@given(st.lists(st.integers(1, 512), min_size=1, max_size=4))
@settings(max_examples=30, deadline=None)
def test_zero_entry_never_invents_axes(dims):
    spec = zero_sharding_entry(tuple(None for _ in dims), tuple(dims))
    assert len(spec) == len(dims)
    for e in spec:
        assert e in (None, "data") or isinstance(e, tuple)


def test_sanitize_subset_and_uneven(monkeypatch):
    """Pure-python behaviours of sanitize_spec via a fake mesh."""
    import repro.dist as dist

    class FakeMesh:
        shape = {"data": 4, "model": 8}
        axis_names = ("data", "model")
        empty = False

    monkeypatch.setattr(dist, "current_mesh", lambda: FakeMesh())
    # subset degrade: pod missing → ("pod","data") → ("data",)
    assert dist.sanitize_spec((16, 8), (("pod", "data"), None))[0] == "data"
    # divisibility drop
    assert dist.sanitize_spec((6, 8), ("data", None))[0] is None
    # uneven mode keeps dim >= axis size
    tok = _UNEVEN.set(True)
    try:
        assert dist.sanitize_spec((6, 8), ("data", None))[0] == "data"
        assert dist.sanitize_spec((3, 8), ("data", None))[0] is None
    finally:
        _UNEVEN.reset(tok)
    # manual axes invisible
    tok = _MANUAL.set(frozenset({"model"}))
    try:
        assert dist.sanitize_spec((8, 8), (None, "model"))[1] is None
    finally:
        _MANUAL.reset(tok)
    # vocab alias resolves to model in tp...
    assert dist.sanitize_spec((64, 8), ("vocab", None))[0] == "model"
    # ...and survives fsdp while bare model drops
    tok = _LAYOUT.set("fsdp")
    try:
        assert dist.sanitize_spec((64, 8), ("vocab", None))[0] == "model"
        assert dist.sanitize_spec((64, 8), ("model", None))[0] is None
    finally:
        _LAYOUT.reset(tok)
