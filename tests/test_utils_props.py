"""Property-based tests (hypothesis) for the bit/hash/segment substrate."""
import numpy as np
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro import utils

SET = settings(max_examples=25, deadline=None)


@given(st.integers(1, 6), st.integers(0, 2**31 - 1))
@SET
def test_pack_unpack_roundtrip(words, seed):
    rng = np.random.default_rng(seed)
    d = words * 32
    bits = rng.random((3, d)) < 0.3
    packed = utils.pack_bits(jnp.asarray(bits))
    back = utils.unpack_bits(packed, d)
    np.testing.assert_array_equal(np.asarray(back), bits)


@given(st.integers(0, 2**31 - 1))
@SET
def test_popcount_matches_numpy(seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 2**32, size=64, dtype=np.uint32)
    ours = np.asarray(utils.popcount(jnp.asarray(x)))
    theirs = np.array([bin(v).count("1") for v in x])
    np.testing.assert_array_equal(ours, theirs)


@given(st.integers(0, 2**31 - 1))
@SET
def test_mix32_is_permutation_like(seed):
    # injective on a small domain: no collisions among 4096 consecutive ints
    x = jnp.arange(4096, dtype=jnp.uint32) + jnp.uint32(seed % 2**20)
    h = np.asarray(utils.mix32(x))
    assert len(np.unique(h)) == 4096


@given(st.lists(st.integers(0, 5), min_size=1, max_size=50))
@SET
def test_run_lengths_and_rank(keys):
    keys = np.sort(np.asarray(keys, np.int32))
    seg, lens = utils.run_lengths(jnp.asarray(keys))
    rank = utils.rank_in_run(jnp.asarray(keys))
    seg, lens, rank = map(np.asarray, (seg, lens, rank))
    # check against pure-python group-by
    from itertools import groupby
    expect_lens, expect_rank, expect_seg = [], [], []
    for si, (_, grp) in enumerate(groupby(keys)):
        grp = list(grp)
        expect_lens += [len(grp)] * len(grp)
        expect_rank += list(range(len(grp)))
        expect_seg += [si] * len(grp)
    np.testing.assert_array_equal(lens, expect_lens)
    np.testing.assert_array_equal(rank, expect_rank)
    np.testing.assert_array_equal(seg, expect_seg)


def test_hash_combine_order_sensitive():
    a = jnp.uint32(123)
    b = jnp.uint32(456)
    assert int(utils.hash_combine(a, b)) != int(utils.hash_combine(b, a))


def test_tree_bytes():
    tree = {"a": np.zeros((4, 4), np.float32), "b": np.zeros(3, np.int8)}
    assert utils.tree_bytes(tree) == 64 + 3
