"""Location / weighting / magnitude tier (ISSUE 9): migration stack
recovery, moveout-consistency rejection, QC-driven station weights,
relative magnitudes, and the located batch scenario acceptance."""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import locate as L
from repro.core.locate import LocateConfig
from repro.core.lsh import INVALID


def _geometry(seed=0, n=6, extent=50.0):
    rng = np.random.default_rng(seed)
    return rng.uniform(0.05 * extent, 0.95 * extent, (n, 2)).astype(
        np.float32)


def _onsets_for(src, t0, station_xy, cfg, lag_s):
    tt = np.asarray(L.travel_time_lags(jnp.asarray(src, jnp.float32),
                                       jnp.asarray(station_xy),
                                       cfg, jnp.float32(lag_s)))
    return np.round(t0 + tt).astype(np.int32)


def test_locate_groups_recovers_origin_and_flags_coincidence():
    """A physical moveout across 6 stations localizes near the true
    origin with a tiny residual; random cross-station onsets match no
    origin and fail the consistency gate."""
    cfg = LocateConfig(grid_n=12, extent_km=50.0, refine_levels=3,
                       moveout_tol_lags=2.0)
    xy = _geometry(1)
    lag_s = 0.5
    src = np.array([30.0, 12.0], np.float32)
    good = _onsets_for(src, 100.0, xy, cfg, lag_s)
    bad = np.array([100, 160, 115, 180, 140, 105], np.int32)
    onsets = np.stack([good, bad])
    out = {k: np.asarray(v) for k, v in L.locate_groups(
        jnp.asarray(onsets), jnp.ones(6, jnp.float32), jnp.asarray(xy),
        jnp.float32(lag_s), cfg).items()}
    err = np.linalg.norm(out["xy"][0] - src)
    assert err <= 2 * cfg.coarse_cell_km, (out["xy"][0], err)
    assert bool(out["consistent"][0])
    assert out["residual"][0] < out["residual"][1]
    assert not bool(out["consistent"][1])
    assert out["n_used"].tolist() == [6, 6]


def test_locate_groups_masks_absent_stations():
    cfg = LocateConfig(grid_n=10, refine_levels=2, moveout_tol_lags=2.0)
    xy = _geometry(2)
    lag_s = 0.5
    src = np.array([18.0, 35.0], np.float32)
    on = _onsets_for(src, 50.0, xy, cfg, lag_s)
    on[2] = INVALID                       # station absent from the group
    on[5] = INVALID
    out = L.locate_groups(jnp.asarray(on[None, :]),
                          jnp.ones(6, jnp.float32), jnp.asarray(xy),
                          jnp.float32(lag_s), cfg)
    assert int(np.asarray(out["n_used"])[0]) == 4
    err = np.linalg.norm(np.asarray(out["xy"])[0] - src)
    assert err <= 2 * cfg.coarse_cell_km


def test_station_weights_downweight_dirty_stations():
    cfg = LocateConfig(min_weight=0.05)
    clean = {k: 0 for k in ("gap_samples", "missing_samples",
                            "late_dropped_samples", "rejected_samples",
                            "duplicate_samples", "duplicate_fingerprints",
                            "masked_fingerprints", "saturated_lookups")}
    gappy = dict(clean, gap_samples=5000)          # half the stream in gaps
    glitchy = dict(clean, saturated_lookups=50)    # half the fps quarantined
    dead = dict(clean, gap_samples=10**9)
    w = L.station_weights([clean, gappy, glitchy, dead],
                          samples=[10000] * 4, fingerprints=[100] * 4,
                          cfg=cfg)
    assert w[0] == 1.0
    assert w[1] == pytest.approx(0.5)
    assert w[2] == pytest.approx(0.5)
    assert w[3] == cfg.min_weight                  # floored, never zero
    # a dirty station pulls the stack less: equal onsets, the weighted
    # mean t0 leans toward the clean stations
    assert np.all(w[1:] < w[0])


def test_weighted_median_and_relative_magnitude():
    assert L.weighted_median(np.array([1.0, 2.0, 100.0]),
                             np.ones(3)) == 2.0
    # weight mass moves the median
    assert L.weighted_median(np.array([1.0, 2.0, 100.0]),
                             np.array([1.0, 1.0, 5.0])) == 100.0
    assert np.isnan(L.weighted_median(np.array([np.nan]), np.ones(1)))
    # a re-occurrence at 10x the template amplitude is +1 magnitude
    mag = L.relative_magnitude(np.array([1.0, 2.0]), np.array([10.0, 20.0]),
                               np.ones(2))
    assert mag == pytest.approx(1.0)
    # non-positive amplitudes are excluded, not propagated
    mag2 = L.relative_magnitude(np.array([1.0, 0.0]), np.array([10.0, 5.0]),
                                np.ones(2))
    assert mag2 == pytest.approx(1.0)
    assert np.isnan(L.relative_magnitude(np.zeros(2), np.ones(2),
                                         np.ones(2)))


def test_fingerprint_amplitudes_window_peaks():
    lag, window = 4, 8
    x = np.zeros(40, np.float32)
    x[21] = -3.0                 # lag bin 5
    amps = L.fingerprint_amplitudes(x, lag, window)
    # the spike is inside the analysis window of fingerprints 4 and 5
    assert amps[4] == 3.0 and amps[5] == 3.0
    assert amps[3] == 0.0 and amps[6] == 0.0
    # NaN telemetry counts as silence, not a poisoned max
    x[10] = np.nan
    assert np.isfinite(L.fingerprint_amplitudes(x, lag, window)).all()


def test_locate_detections_scatters_back_to_det_rows():
    cfg = LocateConfig(grid_n=10, refine_levels=2, pad_groups=8,
                       moveout_tol_lags=2.0)
    xy = _geometry(3)
    lag_s = 0.5
    src = np.array([25.0, 25.0], np.float32)
    on = _onsets_for(src, 80.0, xy, cfg, lag_s)
    p = 5
    onset_mat = np.full((p, 6), INVALID, np.int32)
    onset_mat[2] = on
    det = {"valid": np.arange(p) == 2, "station_onset": onset_mat}
    out = L.locate_detections(det, xy, np.ones(6, np.float32), lag_s, cfg)
    assert out["x_km"].shape == (p,)
    assert np.isfinite(out["x_km"][2]) and bool(out["consistent"][2])
    for g in (0, 1, 3, 4):                     # invalid rows stay masked
        assert np.isnan(out["x_km"][g]) and not bool(out["consistent"][g])
    with pytest.raises(ValueError, match="with_onsets"):
        L.locate_detections({"valid": det["valid"]}, xy,
                            np.ones(6, np.float32), lag_s, cfg)


def test_located_batch_scenario_origin_error():
    """Acceptance: the located synth scenario's well-constrained groups
    (≥4 stations) locate with median origin error within 2 coarse grid
    cells of a true source, and magnitudes come out finite and small for
    equal-amplitude repeats."""
    from repro.configs import fast_seismic as fs
    from repro.core.detect import detect_events
    from repro.core.synth import SynthConfig, make_dataset

    cfg = fs.located_smoke_config()
    ds = make_dataset(SynthConfig(seed=3, n_stations=6, duration_s=600.0,
                                  n_sources=3, events_per_source=4,
                                  event_snr=3.0, physical_geometry=True))
    det, _, _, stats = detect_events(ds.waveforms, cfg,
                                     station_xy=ds.station_xy)
    v = np.asarray(det["valid"]) & (np.asarray(det["n_stations"]) >= 4)
    assert int(v.sum()) >= 2
    errs, mags = [], []
    for g in np.nonzero(v)[0]:
        p = np.array([det["x_km"][g], det["y_km"][g]])
        errs.append(np.min(np.linalg.norm(ds.source_xy - p, axis=1)))
        mags.append(float(det["magnitude"][g]))
    assert np.median(errs) <= 2 * cfg.locate.coarse_cell_km, errs
    # equal-amplitude repeats: relative magnitude near zero
    mags = np.asarray(mags)
    assert np.isfinite(mags).all() and np.abs(np.median(mags)) < 0.5
    assert "moveout_rejected" in stats
