"""Checkpointing: roundtrip, atomicity, pruning, and the fault-tolerance
contract (failure-injection restart via subprocess)."""
import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train import checkpoint as C

SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.zeros(4)},
            "opt": {"m": jnp.ones((8, 4)), "step": jnp.asarray(3)}}


def test_roundtrip_bit_exact(tmp_path):
    state = _state()
    C.save_checkpoint(str(tmp_path), 7, state, extra={"iterator": {"p": 5}})
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          state)
    restored, extra = C.restore_checkpoint(str(tmp_path), target)
    assert extra == {"iterator": {"p": 5}}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_background_save_and_prune(tmp_path):
    state = _state()
    threads = [C.save_checkpoint(str(tmp_path), s, state, background=True,
                                 keep=2) for s in (1, 2, 3)]
    for t in threads:
        t.join()
    steps = C.list_steps(str(tmp_path))
    assert steps[-1] == 3 and len(steps) <= 2


def test_no_partial_dirs_on_overwrite(tmp_path):
    state = _state()
    C.save_checkpoint(str(tmp_path), 1, state)
    C.save_checkpoint(str(tmp_path), 1, state)  # overwrite same step
    entries = [p.name for p in tmp_path.iterdir()]
    assert entries == ["step_00000001"], entries


def test_shape_mismatch_rejected(tmp_path):
    C.save_checkpoint(str(tmp_path), 1, _state())
    bad_target = {"params": {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32),
                             "b": jax.ShapeDtypeStruct((4,), jnp.float32)},
                  "opt": {"m": jax.ShapeDtypeStruct((8, 4), jnp.float32),
                          "step": jax.ShapeDtypeStruct((), jnp.int32)}}
    with pytest.raises(ValueError):
        C.restore_checkpoint(str(tmp_path), bad_target)


@pytest.mark.slow
def test_failure_injection_and_resume(tmp_path):
    """Kill training mid-run; resumed run must match an uninterrupted one."""
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    common = [sys.executable, "-m", "repro.launch.train", "--arch", "smoke",
              "--steps", "8", "--seq", "64", "--batch", "4",
              "--ckpt-every", "2", "--no-dedup", "--seed", "3"]
    # uninterrupted reference
    ref_metrics = tmp_path / "ref.json"
    subprocess.run(common + ["--ckpt-dir", str(tmp_path / "ref"),
                             "--metrics-out", str(ref_metrics)],
                   env=env, check=True, capture_output=True, timeout=900)
    # crashing run
    crash_dir = str(tmp_path / "crash")
    p = subprocess.run(common + ["--ckpt-dir", crash_dir,
                                 "--inject-failure-at", "5"],
                       env=env, capture_output=True, timeout=900)
    assert p.returncode == 42, p.stderr.decode()[-500:]
    assert C.latest_step(crash_dir) == 4
    # resume
    res_metrics = tmp_path / "res.json"
    subprocess.run(common + ["--ckpt-dir", crash_dir, "--resume",
                             "--metrics-out", str(res_metrics)],
                   env=env, check=True, capture_output=True, timeout=900)
    ref = json.loads(ref_metrics.read_text())
    res = json.loads(res_metrics.read_text())
    assert abs(ref["final_loss"] - res["final_loss"]) < 1e-4, (ref, res)
