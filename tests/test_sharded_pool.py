"""Mesh-sharded station pool (ISSUE 10): single-device fallback parity,
elastic add/remove, 8-forced-device bit-parity with donation/retrace
guards, mesh-elastic snapshot round-trip (save@8 → restore@1/4), and the
bench-e2e/v4 sharded-grid schema guard."""
import dataclasses
import hashlib
import json
import pathlib

import numpy as np
import pytest

from conftest import run_forced_devices
from repro.configs.fast_seismic import (latency_config, smoke_config,
                                        stream_bounded_smoke_config,
                                        stream_latency_smoke_config)
from repro.core.synth import SynthConfig, make_dataset
from repro.stream import StreamingDetector


def _stream(cfg, scfg, wf, n_stations, chunk=6000):
    det = StreamingDetector(cfg, scfg, n_stations=n_stations)
    for start in range(0, wf.shape[1], chunk):
        det.push(wf[:n_stations, start:start + chunk])
    return det


def test_sharded_falls_back_without_mesh():
    """On a single visible device ``sharded=True`` is inert: the mesh
    probe returns None, the pool pads nothing, and the stream is
    bit-identical to an explicit ``sharded=False`` run (the
    ``pool_step_*_sharded`` entries delegate to the vmap pool)."""
    cfg, scfg = smoke_config(), stream_bounded_smoke_config()
    ds = make_dataset(SynthConfig(duration_s=600.0, n_stations=3,
                                  n_sources=2, events_per_source=5,
                                  event_snr=3.0, seed=11))
    assert scfg.sharded                      # on by default
    det_s = _stream(cfg, scfg, ds.waveforms, 3)
    assert det_s.mesh is None and det_s.pool_pad == 0
    det_v = _stream(cfg, dataclasses.replace(scfg, sharded=False),
                    ds.waveforms, 3)
    out_s, out_v = det_s.finalize(), det_v.finalize()
    for a, b in zip(out_s[0], out_v[0]):     # detections, bit-identical
        assert np.array_equal(a, b)
    assert [int(st.stats.pairs) for st in det_s.stations] \
        == [int(st.stats.pairs) for st in det_v.stations]


def test_elastic_add_remove_station():
    """``add_station`` grows the live pool at the network frontier and
    ``remove_station`` shrinks it back; both re-pack the stacked pytree
    and the stream keeps running across the width changes."""
    cfg, scfg = latency_config(), stream_latency_smoke_config()
    rng = np.random.default_rng(3)
    chunk = scfg.block_fingerprints * cfg.fingerprint.lag_samples
    det = StreamingDetector(cfg, scfg, n_stations=2)
    with pytest.raises(ValueError, match="live pool"):
        det.add_station()                     # stats not frozen yet
    for c in range(scfg.stats_warmup_blocks + 4):
        det.push(rng.standard_normal((2, chunk)).astype(np.float32))
    assert det.pstate is not None
    i = det.add_station()
    assert i == 2 and len(det.stations) == 3
    # the joiner mirrors a peer's framing position with an all-missing
    # pre-join span, so lockstep block emission holds immediately
    assert det.stations[2].ring.start == det.stations[0].ring.start
    assert det.stations[2].ring.quality["missing_samples"] > 0
    for c in range(4):
        det.push(rng.standard_normal((3, chunk)).astype(np.float32))
    assert all(st.stats.chunks > 0 for st in det.stations)
    det.remove_station(1)
    assert len(det.stations) == 2
    assert [st._pool_idx for st in det.stations] == [0, 1]
    for c in range(2):
        det.push(rng.standard_normal((2, chunk)).astype(np.float32))
    with pytest.raises(ValueError, match="last station"):
        det.remove_station(0), det.remove_station(0)


@pytest.mark.slow
def test_sharded_pool_bit_parity_8_devices():
    """Property test on 8 forced host devices: the mesh-sharded pool ==
    the vmap pool == the sequential solo stations, bit for bit, and the
    sharded entries hold the donation + ≤1-steady-state-trace
    invariants."""
    run_forced_devices("""
import dataclasses, numpy as np, jax
from repro.configs.fast_seismic import smoke_config, \\
    stream_bounded_smoke_config
from repro.core.synth import SynthConfig, make_dataset
from repro.stream import StreamingDetector
from repro.stream import fused as FU

assert jax.device_count() == 8
cfg, scfg = smoke_config(), stream_bounded_smoke_config()
ds = make_dataset(SynthConfig(duration_s=600.0, n_stations=3,
                              n_sources=2, events_per_source=5,
                              event_snr=3.0, seed=11))
wf = ds.waveforms
chunks = [wf[:, s:s + 6000] for s in range(0, wf.shape[1], 6000)]

det = StreamingDetector(cfg, scfg, n_stations=3)
for c in chunks[:6]:
    det.push(c)
assert det.mesh is not None and det.mesh.devices.size == 3
assert det.pool_pad == 0
# donation: steady-state chunks retain zero device bytes
live0 = sum(a.nbytes for a in jax.live_arrays())
for c in chunks[6:8]:
    det.push(c)
assert sum(a.nbytes for a in jax.live_arrays()) == live0
# retracing: one block entry + one advance entry, one trace each
assert len(FU._SHARDED_ENTRIES) <= 2
assert all(fn._cache_size() == 1 for fn in FU._SHARDED_ENTRIES.values())
for c in chunks[8:]:
    det.push(c)
assert all(fn._cache_size() == 1 for fn in FU._SHARDED_ENTRIES.values())

det_v = StreamingDetector(cfg, dataclasses.replace(scfg, sharded=False),
                          n_stations=3)
seq = StreamingDetector(cfg, dataclasses.replace(
    scfg, pooled=False, sharded=False), n_stations=3)
for c in chunks:
    det_v.push(c)
    seq.push(c)
out, out_v, out_seq = det.finalize(), det_v.finalize(), seq.finalize()
for a, b, c in zip(out[0], out_v[0], out_seq[0]):
    assert np.array_equal(a, b) and np.array_equal(a, c)
pairs = [int(st.stats.pairs) for st in det.stations]
assert pairs == [int(st.stats.pairs) for st in det_v.stations]
assert pairs == [int(st.stats.pairs) for st in seq.stations]
print("PARITY", pairs)
""")


@pytest.mark.slow
def test_mesh_elastic_snapshot_roundtrip(tmp_path):
    """A pool snapshotted under an 8-device mesh restores onto 1 and 4
    devices and finishes the stream bit-identically: snapshots are
    per-station slices, so device topology never reaches disk."""
    common = """
import hashlib, numpy as np, jax
from repro.configs.fast_seismic import smoke_config, \\
    stream_bounded_smoke_config
from repro.core.synth import SynthConfig, make_dataset
from repro.stream import StreamingDetector

cfg, scfg = smoke_config(), stream_bounded_smoke_config()
ds = make_dataset(SynthConfig(duration_s=600.0, n_stations=8,
                              n_sources=2, events_per_source=5,
                              event_snr=3.0, seed=11))
wf = ds.waveforms
starts = list(range(0, wf.shape[1], 6000))
half = len(starts) // 2

def digest(det):
    h = hashlib.sha256()
    dets, events, stats = det.finalize()
    for a in dets:
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest(), [int(st.stats.pairs) for st in det.stations]
"""
    save = run_forced_devices(common + f"""
det = StreamingDetector(cfg, scfg, n_stations=8)
for s in starts[:half]:
    det.push(wf[:, s:s + 6000])
assert det.mesh is not None and det.mesh.devices.size == 8
det.snapshot({str(tmp_path)!r})
for s in starts[half:]:
    det.push(wf[:, s:s + 6000])
print("DIGEST", *digest(det))
""", devices=8)
    ref = save.splitlines()[-1]
    for devices, width in ((1, None), (4, 4)):
        out = run_forced_devices(common + f"""
det, step = StreamingDetector.restore({str(tmp_path)!r}, cfg, scfg)
assert (det.mesh.devices.size if det.mesh else None) == {width!r}
for s in starts[half:]:
    det.push(wf[:, s:s + 6000])
print("DIGEST", *digest(det))
""", devices=devices)
        assert out.splitlines()[-1] == ref, (devices, out, ref)


@pytest.mark.slow
def test_bench_sharded_grid_schema(tmp_path, monkeypatch):
    """``make bench-sharded`` contract: the quick grid runs its forced-
    device children, every point carries exact (non-histogram) step
    percentiles and passes pair parity, and the flagship 8st × 8dev
    ratio lands in the ratios block."""
    import sys
    root = str(pathlib.Path(__file__).parent.parent)
    if root not in sys.path:
        sys.path.insert(0, root)
    monkeypatch.setenv("BENCH_OUT_DIR", str(tmp_path))
    from benchmarks import bench_e2e
    out = bench_e2e.main(["--sharded", "--quick"])
    assert out["schema"] == "bench-e2e/v4"
    sp = out["sharded_pool"]
    assert sp["host_cores"] >= 1
    assert {(p["devices"], p["stations"]) for p in sp["points"]} \
        == {(2, 4), (8, 8)}
    for p in sp["points"]:
        assert p["pair_parity"]
        assert p["sharded"]["mesh_devices"] == min(p["devices"],
                                                   p["stations"])
        assert p["baseline"]["mesh_devices"] == 1
        for v in ("sharded", "baseline"):
            assert p[v]["device_step_ms_p50"] > 0
            assert p[v]["device_step_ms_p95"] >= p[v]["device_step_ms_p50"]
    assert out["ratios"]["sharded_pool_speedup_8st_8dev"] \
        == sp["speedup_8st_8dev"] > 0
    # parallel scaling needs physical cores: with ≥8 the flagship point
    # must beat the single-device vmap baseline; time-sliced forced
    # devices on fewer cores can only measure the sharding overhead
    if sp["host_cores"] >= 8:
        assert sp["speedup_8st_8dev"] > 1.0
    written = json.loads((tmp_path / "BENCH_e2e.json").read_text())
    assert written["sharded_pool"]["speedup_8st_8dev"] \
        == sp["speedup_8st_8dev"]
