"""Cross-pod int8 gradient compression: correctness within quantization
tolerance + int8 collectives actually on the wire (subprocess, 8 devices
as a (2, 2, 2) pod×data×model mesh)."""
import functools

import pytest

from conftest import run_forced_devices

run_py = functools.partial(run_forced_devices, timeout=1500)


@pytest.mark.slow
def test_compressed_grads_close_and_int8_on_wire():
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import ModelConfig, init_params, lm_loss
from repro.train.compression import pod_compressed_value_and_grad

CFG = ModelConfig(name="c", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  d_ff=128, vocab_size=512, attn_q_block=32,
                  attn_kv_block=32, loss_seq_chunk=32,
                  param_dtype="float32", compute_dtype="float32",
                  remat="none")
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(0, 512, (8, 64)), jnp.int32)
batch = {"tokens": toks, "labels": toks,
         "loss_mask": jnp.ones((8, 64), jnp.float32)}
params = init_params(jax.random.PRNGKey(0), CFG)

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

def loss_fn(p, b):
    return lm_loss(p, b, CFG)[0]

with mesh:
    batch_s = jax.device_put(batch, NamedSharding(mesh, P(("pod", "data"))))
    # exact reference
    loss_ref, grads_ref = jax.jit(jax.value_and_grad(loss_fn))(params,
                                                               batch_s)
    f = pod_compressed_value_and_grad(loss_fn, mesh)
    jf = jax.jit(f)
    loss_c, grads_c = jf(params, batch_s)
    hlo = jf.lower(params, batch_s).compile().as_text()

assert abs(float(loss_ref) - float(loss_c)) < 1e-4
rels = []
for a, b in zip(jax.tree.leaves(grads_ref), jax.tree.leaves(grads_c)):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    denom = np.abs(a).max() + 1e-12
    rels.append(np.abs(a - b).max() / denom)
print("max rel err", max(rels))
assert max(rels) < 0.02, max(rels)   # int8 quantization tolerance
assert "s8[" in hlo and "all-gather" in hlo, "int8 collective missing"
print("COMPRESSION_OK")
""")
    assert "COMPRESSION_OK" in out
