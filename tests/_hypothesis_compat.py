"""Optional-hypothesis shim for network-less environments.

Property-based tests import ``given``/``settings``/``st`` from here instead
of hard-importing :mod:`hypothesis`. When hypothesis is installed the real
objects are re-exported; when it is missing, ``@given(...)`` turns the test
into a skip and the deterministic tests in the same module still collect
and run.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: absorbs any attribute
        access / call so module-level strategy expressions still evaluate."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco
