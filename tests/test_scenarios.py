"""Fault-injection scenario suite (ISSUE 4).

Every pathology the scenario generator can inject — telemetry gaps,
station dropouts, duplicated data blocks, repeating glitch trains,
clock-drifted copies — runs through the quality-hardened streaming path
(``stream_dirty_smoke_config``) and is held to two standards against the
clean-stream golden (the same trace without the pathology, streamed
through the same configuration):

  * spurious pairs beyond the clean set stay within a pinned budget
    (zero for sample-exact pathologies), and
  * recall on the clean portion — pairs whose fingerprints touch no
    injected sample — is unchanged (bit-exact with frozen statistics).

The scenario substrate is shared with ``bench_stream --scenario``
(``benchmarks.bench_stream.bench_scenario``); the glitch-train acceptance
(≥ 10× spurious reduction vs the unguarded path, recall unchanged) is
pinned here at the exact benchmark configuration.
"""
import json
import pathlib
import sys
from dataclasses import replace as dataclasses_replace

import numpy as np
import pytest

from repro.configs.fast_seismic import (smoke_config,
                                        stream_dirty_smoke_config,
                                        stream_smoke_config)
from repro.core.synth import (ScenarioConfig, SynthConfig,
                              make_scenario_dataset)
from repro.stream import StreamingDetector

ROOT = str(pathlib.Path(__file__).parent.parent)
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)             # the benchmarks package

# the same frozen statistics the scenario benchmark uses, so the pins
# here hold at the exact benchmark configuration
from benchmarks.common import frozen_smoke_stats as _frozen  # noqa: E402


def _raw_pairs(st):
    tri = (np.concatenate(st.triplets, axis=0) if st.triplets
           else np.zeros((0, 3), np.int64))
    return set(zip(tri[:, 0].tolist(), tri[:, 1].tolist()))


def _run(cfg, scfg, wf, med_mad, n_stations=1, n_chunks=10):
    """Stream a (S, T) or (T,) trace → per-station raw pair sets + det."""
    det = StreamingDetector(cfg, scfg, n_stations=n_stations,
                            med_mad=med_mad)
    wf = np.atleast_2d(np.asarray(wf, np.float32))
    for chunk in np.array_split(wf, n_chunks, axis=1):
        det.push(chunk if n_stations > 1 else chunk[0])
    det.flush()
    return [_raw_pairs(st) for st in det.stations], det


def _clean_ids(cfg, scen, station):
    fcfg = cfg.fingerprint
    return set(scen.clean_fp_ids(station, fcfg.window_samples,
                                 fcfg.lag_samples).tolist())


def _restrict(pairs, ids):
    return {p for p in pairs if p[0] in ids and p[1] in ids}


def _base_synth(**over):
    kw = dict(duration_s=600.0, n_stations=1, n_sources=2,
              events_per_source=5, event_snr=3.0, seed=3)
    kw.update(over)
    return SynthConfig(**kw)


# ---------------------------------------------------------------------------
# gaps
# ---------------------------------------------------------------------------


def test_gap_scenario_no_spurious_and_exact_clean_recall():
    """Telemetry gaps: fingerprints touching missing data never pair, and
    pairs among untouched fingerprints are bit-identical to the clean
    golden (spurious budget: zero)."""
    cfg, scfg = smoke_config(), stream_dirty_smoke_config()
    scen = make_scenario_dataset(ScenarioConfig(
        base=_base_synth(), n_gaps=4, gap_dur_s=(2.0, 8.0), seed=7))
    med_mad = _frozen(cfg, scen.clean.waveforms[0])
    (clean,), _ = _run(cfg, scfg, scen.clean.waveforms[0], med_mad)
    (dirty,), det = _run(cfg, scfg, scen.waveforms[0], med_mad)
    q = det.quality_summary()
    assert q["missing_samples"] == int(scen.missing.sum())
    assert q["suppressed_fingerprints"] > 0
    ok = _clean_ids(cfg, scen, 0)
    lag, w = cfg.fingerprint.lag_samples, cfg.fingerprint.window_samples
    n_fp = cfg.fingerprint.n_fingerprints(scen.waveforms.shape[1])
    bad = set(range(n_fp)) - ok
    # no pair touches a gap-masked fingerprint…
    assert not any(a in bad or b in bad for a, b in dirty)
    # …and the clean portion is exactly the clean golden (zero spurious,
    # recall unchanged)
    assert dirty == _restrict(clean, ok)


def test_station_dropout_pooled_isolation():
    """A dropout on one station of a pooled detector masks only that
    station: the healthy station's pair set stays bit-identical, the
    dropped span emits nothing, and network finalize still runs."""
    cfg, scfg = smoke_config(), stream_dirty_smoke_config()
    scen = make_scenario_dataset(ScenarioConfig(
        base=_base_synth(n_stations=2),
        dropout_stations=(1,), dropout_dur_s=90.0, seed=5))
    med_mad = _frozen(cfg, scen.clean.waveforms[0])
    clean_sets, det_c = _run(cfg, scfg, scen.clean.waveforms, med_mad,
                             n_stations=2)
    dirty_sets, det_d = _run(cfg, scfg, scen.waveforms, med_mad,
                             n_stations=2)
    assert det_d.pooled                  # the vmapped pool path ran
    assert dirty_sets[0] == clean_sets[0]
    ok1 = _clean_ids(cfg, scen, 1)
    n_fp = cfg.fingerprint.n_fingerprints(scen.waveforms.shape[1])
    bad1 = set(range(n_fp)) - ok1
    assert not any(a in bad1 or b in bad1 for a, b in dirty_sets[1])
    assert dirty_sets[1] == _restrict(clean_sets[1], ok1)
    d, _, stats = det_d.finalize()
    assert stats["quality"]["suppressed_fingerprints"] > 0


# ---------------------------------------------------------------------------
# duplicated data blocks
# ---------------------------------------------------------------------------


def test_duplicate_block_guard_budget():
    """Telemetry-duplicated blocks: the unguarded path emits spurious
    copy-vs-original pairs; the sample-exact duplicate guard suppresses
    the copies before insert, leaving at most a small boundary budget,
    with the clean portion exact."""
    cfg = smoke_config()
    scen = make_scenario_dataset(ScenarioConfig(
        base=_base_synth(), n_dup_blocks=2, dup_block_dur_s=20.0,
        dup_spacing_s=60.0, seed=2))
    med_mad = _frozen(cfg, scen.clean.waveforms[0])
    (clean,), _ = _run(cfg, stream_dirty_smoke_config(),
                       scen.clean.waveforms[0], med_mad)
    (unguarded,), _ = _run(cfg, stream_smoke_config(), scen.waveforms[0],
                           med_mad)
    (guarded,), det = _run(cfg, stream_dirty_smoke_config(),
                           scen.waveforms[0], med_mad)
    assert len(unguarded - clean) > len(guarded - clean)
    assert len(guarded - clean) <= 6     # boundary-window budget
    assert det.quality_summary()["duplicate_fingerprints"] > 0
    ok = _clean_ids(cfg, scen, 0)
    assert _restrict(guarded, ok) == _restrict(clean, ok)


def test_wild_offset_chunk_rejected():
    """A corrupted / unit-mismatched timestamp (offset jump beyond
    ``max_gap_samples``) is rejected and counted instead of gap-filling
    an unbounded sentinel span."""
    cfg = smoke_config()
    scfg = stream_dirty_smoke_config()
    scfg = dataclasses_replace(scfg, max_gap_samples=50_000)
    ds = make_scenario_dataset(ScenarioConfig(
        base=_base_synth(duration_s=300.0)))
    wf = ds.clean.waveforms[0]
    med_mad = _frozen(cfg, wf)
    det = StreamingDetector(cfg, scfg, n_stations=1, med_mad=med_mad)
    det.push(wf[:6000])
    det.push(wf[6000:12000], offset=8_640_000_000)   # ms-vs-samples bug
    det.push(wf[6000:12000], offset=6000)            # the real chunk
    q = det.quality_summary()
    assert q["rejected_chunks"] == 1
    assert q["rejected_samples"] == 6000
    assert det.stations[0].ring.pending_samples < 50_000
    # the stream continues unharmed: identical to never seeing the bogus
    # chunk at all
    det2 = StreamingDetector(cfg, scfg, n_stations=1, med_mad=med_mad)
    det2.push(wf[:6000])
    det2.push(wf[6000:12000], offset=6000)
    np.testing.assert_array_equal(det.stations[0].ring.buf,
                                  det2.stations[0].ring.buf)


def test_duplicate_chunk_redelivery_is_noop():
    """Re-delivered chunks (double-send telemetry) change nothing: the
    detector's output is bit-identical to single delivery and the drops
    are counted."""
    cfg, scfg = smoke_config(), stream_dirty_smoke_config()
    ds = make_scenario_dataset(ScenarioConfig(base=_base_synth()))
    wf = ds.clean.waveforms[0]
    med_mad = _frozen(cfg, wf)
    chunks = np.array_split(wf, 10)
    offs = np.cumsum([0] + [c.size for c in chunks])[:-1]
    det1 = StreamingDetector(cfg, scfg, n_stations=1, med_mad=med_mad)
    det2 = StreamingDetector(cfg, scfg, n_stations=1, med_mad=med_mad)
    for off, c in zip(offs, chunks):
        det1.push(c, int(off))
        det2.push(c, int(off))
        det2.push(c, int(off))          # every chunk delivered twice
    det1.flush()
    det2.flush()
    assert _raw_pairs(det1.stations[0]) == _raw_pairs(det2.stations[0])
    q = det2.quality_summary()
    assert q["duplicate_samples"] + q["late_dropped_samples"] \
        == int(wf.size)


# ---------------------------------------------------------------------------
# repeating glitch trains (the benchmark acceptance)
# ---------------------------------------------------------------------------


def test_glitch_train_scenario_10x_reduction():
    """Acceptance criterion: on the pinned gap + duplicate + glitch-train
    benchmark scenario, the guards cut spurious pairs ≥ 10× vs the
    unguarded path while clean-portion recall is unchanged."""
    from benchmarks.bench_stream import bench_scenario
    cfg = smoke_config()
    scen = make_scenario_dataset(bench_scenario(600.0))
    med_mad = _frozen(cfg, scen.clean.waveforms[0])
    (clean,), _ = _run(cfg, stream_dirty_smoke_config(),
                       scen.clean.waveforms[0], med_mad)
    (unguarded,), _ = _run(cfg, stream_smoke_config(), scen.waveforms[0],
                           med_mad)
    (guarded,), det = _run(cfg, stream_dirty_smoke_config(),
                           scen.waveforms[0], med_mad)
    spurious_u = len(unguarded - clean)
    spurious_g = len(guarded - clean)
    assert spurious_u >= 10              # the pathology really fires
    assert spurious_u / max(spurious_g, 1) >= 10.0, (spurious_u, spurious_g)
    ok = _clean_ids(cfg, scen, 0)
    ref = _restrict(clean, ok)
    assert len(ref) > 0
    assert _restrict(guarded, ok) == ref  # recall unchanged, no extras
    assert det.quality_summary()["duplicate_fingerprints"] > 0


def test_additive_glitch_limiter_10x_reduction():
    """Acceptance criterion (ISSUE 5): glitches riding on the live noise
    floor are not sample-exact, so the duplicate guard cannot see them
    and the saturation quarantine alone managed only ~2× — the
    in-dispatch §6.5 occurrence limiter lifts the additive glitch-train
    suppression to ≥ 10×, with the clean portion bit-exact. Pinned at
    the exact benchmark configuration (``bench_stream
    --scenario`` ``additive`` point)."""
    from benchmarks.bench_stream import additive_bench_scenario
    cfg = smoke_config()
    scen = make_scenario_dataset(additive_bench_scenario(600.0))
    med_mad = _frozen(cfg, scen.clean.waveforms[0])
    (clean,), _ = _run(cfg, stream_dirty_smoke_config(),
                       scen.clean.waveforms[0], med_mad)
    (unguarded,), _ = _run(cfg, stream_smoke_config(), scen.waveforms[0],
                           med_mad)
    (guarded,), det = _run(cfg, stream_dirty_smoke_config(),
                           scen.waveforms[0], med_mad)
    spurious_u = len(unguarded - clean)
    spurious_g = len(guarded - clean)
    assert spurious_u >= 10              # the pathology really fires
    assert spurious_u / max(spurious_g, 1) >= 10.0, (spurious_u, spurious_g)
    q = det.quality_summary()
    assert q["limited_pairs"] > 0        # the limiter did the cutting…
    assert q["saturated_lookups"] > 0    # …on top of the quarantine
    assert q["duplicate_fingerprints"] == 0  # invisible to the dup guard
    ok = _clean_ids(cfg, scen, 0)
    assert _restrict(guarded, ok) == _restrict(clean, ok)


def test_additive_glitch_limiter_off_is_weak():
    """Contrast pin for the ~2× → ≥10× claim: with the limiter disabled
    (every other guard unchanged) the additive train is only partially
    suppressed — the in-dispatch limiter is what closes the gap."""
    from benchmarks.bench_stream import additive_bench_scenario
    cfg = smoke_config()
    scen = make_scenario_dataset(additive_bench_scenario(600.0))
    med_mad = _frozen(cfg, scen.clean.waveforms[0])
    no_limiter = dataclasses_replace(stream_dirty_smoke_config(),
                                     occ_limit=0)
    (clean,), _ = _run(cfg, no_limiter, scen.clean.waveforms[0], med_mad)
    (unguarded,), _ = _run(cfg, stream_smoke_config(), scen.waveforms[0],
                           med_mad)
    (guarded,), det = _run(cfg, no_limiter, scen.waveforms[0], med_mad)
    spurious_u = len(unguarded - clean)
    spurious_g = len(guarded - clean)
    assert spurious_u > 0
    assert spurious_g < spurious_u       # strictly reduced…
    assert spurious_u / max(spurious_g, 1) >= 1.5   # …but nowhere near 10×
    assert spurious_u / max(spurious_g, 1) < 10.0
    assert det.quality_summary()["saturated_lookups"] > 0
    ok = _clean_ids(cfg, scen, 0)
    assert _restrict(guarded, ok) == _restrict(clean, ok)


# ---------------------------------------------------------------------------
# clock drift
# ---------------------------------------------------------------------------


def test_clock_drift_network_detection_survives():
    """A station with a few-hundred-ppm clock drift still associates into
    network detections (drift over the trace stays within the alignment
    tolerances)."""
    cfg, scfg = smoke_config(), stream_dirty_smoke_config()
    scen = make_scenario_dataset(ScenarioConfig(
        base=_base_synth(n_stations=3, seed=11),
        clock_drift_stations=(2,), clock_drift_ppm=200.0, seed=4))
    _, det = _run(cfg, scfg, scen.waveforms, None, n_stations=3)
    detections, _, stats = det.finalize()
    assert stats["detections"] >= 1


# ---------------------------------------------------------------------------
# snapshot/restore of the quality state
# ---------------------------------------------------------------------------


def test_dirty_stream_snapshot_roundtrip(tmp_path):
    """Kill/restore mid-dirty-stream reproduces the uninterrupted run
    exactly — including the new quality state (sample-validity ring,
    duplicate-hash history, reconciliation + guard counters)."""
    cfg, scfg = smoke_config(), stream_dirty_smoke_config()
    scen = make_scenario_dataset(ScenarioConfig(
        base=_base_synth(), n_gaps=3, n_dup_blocks=1,
        dup_block_dur_s=20.0, dup_spacing_s=60.0,
        glitch_stations=(0,), glitch_trains=1, glitch_train_dur_s=100.0,
        seed=6))
    wf = scen.waveforms[0]
    med_mad = _frozen(cfg, scen.clean.waveforms[0])
    chunks = np.array_split(wf, 12)

    run = StreamingDetector(cfg, scfg, n_stations=1, med_mad=med_mad)
    for c in chunks[:6]:
        run.push(c)
    run.snapshot(str(tmp_path), step=6)
    restored, step = StreamingDetector.restore(str(tmp_path), cfg, scfg)
    assert step == 6
    for c in chunks[6:]:
        run.push(c)
        restored.push(c)
    uninterrupted = StreamingDetector(cfg, scfg, n_stations=1,
                                      med_mad=med_mad)
    for c in chunks:
        uninterrupted.push(c)
    e0, p0, f0 = uninterrupted.stations[0].finalize()
    e1, p1, f1 = run.stations[0].finalize()
    e2, p2, f2 = restored.stations[0].finalize()
    np.testing.assert_array_equal(np.asarray(p0.idx1), np.asarray(p2.idx1))
    np.testing.assert_array_equal(np.asarray(p0.valid),
                                  np.asarray(p2.valid))
    assert f0 == f1 == f2                # incl. the quality counters
    assert f0["quality"]["duplicate_fingerprints"] > 0
    assert f0["quality"]["missing_samples"] > 0


# ---------------------------------------------------------------------------
# benchmark schema guard
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_bench_scenario_schema(tmp_path, monkeypatch):
    """``bench_stream --scenario-only`` emits a schema-stable scenario
    point meeting the acceptance numbers."""
    monkeypatch.setenv("BENCH_OUT_DIR", str(tmp_path))
    from benchmarks import bench_stream
    out = bench_stream.main(["--scenario-only"])
    point = out["scenario"]
    assert point["schema"] == "bench-stream-scenario/v2"
    assert set(point) >= {"spurious_unguarded", "spurious_guarded",
                          "spurious_reduction", "clean_portion_recall",
                          "guarded_chunks_per_s", "quality", "metrics",
                          "additive"}
    # the embedded telemetry snapshot (ISSUE 6) is the shared schema
    m = point["metrics"]
    assert m["schema"] == "stream-metrics/v1"
    assert m["drops"]["pairs_emitted"] > 0
    assert m["quality"] == point["quality"]
    assert point["spurious_reduction"] >= 10.0
    assert point["clean_portion_recall"] == 1.0
    # the ISSUE-5 additive-train acceptance rides in the same point
    add = point["additive"]
    assert add["spurious_reduction"] >= 10.0
    assert add["clean_portion_recall"] == 1.0
    assert add["limited_pairs"] > 0


@pytest.mark.slow
def test_bench_located_scenario_schema(tmp_path, monkeypatch):
    """``bench_stream --assoc-only`` (``make bench-assoc``) emits a
    schema-stable located-association point meeting the ISSUE-9
    acceptance: the moveout gate cuts ≥3-station false associations vs
    the pairwise baseline without losing true groups, and the kept
    groups locate within 2 coarse grid cells."""
    monkeypatch.setenv("BENCH_OUT_DIR", str(tmp_path))
    from benchmarks import bench_stream
    out = bench_stream.main(["--assoc-only"])
    point = out["located_scenario"]
    assert point["schema"] == "bench-stream-located/v1"
    assert set(point) >= {"golden_groups", "false_assoc_pairwise",
                          "false_assoc_gated", "false_assoc_reduction",
                          "true_kept_pairwise", "true_kept_gated",
                          "moveout_rejected", "median_origin_err_cells",
                          "coarse_cell_km"}
    # the A/B: measurable false-association cut, true groups preserved
    assert point["false_assoc_pairwise"] > 0
    assert point["false_assoc_gated"] < point["false_assoc_pairwise"]
    assert point["true_kept_gated"] == point["true_kept_pairwise"]
    assert point["moveout_rejected"] > 0
    # location acceptance: median origin error within 2 coarse cells
    assert point["median_origin_err_cells"] <= 2.0
    # --assoc-only only touches its own key of an existing artifact
    written = json.loads((tmp_path / "BENCH_stream.json").read_text())
    assert written["located_scenario"] == point
