"""Data pipeline + LSH dedup stage (paper technique as data infra)."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.data.dedup import DedupConfig, find_duplicates, \
    shingle_fingerprints
from repro.data.pipeline import DataConfig, IteratorState, TokenPipeline


def test_dedup_catches_injected_duplicates(rng):
    n, s = 24, 128
    docs = rng.integers(1, 1000, (n, s)).astype(np.int32)
    docs[20] = docs[3]           # exact dup
    docs[21] = docs[5].copy()
    docs[21, ::37] = 7           # near dup
    keep, stats = find_duplicates(docs)
    assert not keep[20] and keep[3]
    assert not keep[21] and keep[5]
    assert stats["dropped"] >= 2


def test_dedup_keeps_distinct(rng):
    docs = rng.integers(1, 10_000, (16, 128)).astype(np.int32)
    keep, _ = find_duplicates(docs)
    assert keep.sum() >= 15  # random docs should essentially all survive


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_shingle_fingerprints_deterministic_and_shift_sensitive(seed):
    rng = np.random.default_rng(seed)
    doc = rng.integers(1, 500, (1, 64)).astype(np.int32)
    cfg = DedupConfig()
    f1 = np.asarray(shingle_fingerprints(jnp.asarray(doc), cfg))
    f2 = np.asarray(shingle_fingerprints(jnp.asarray(doc), cfg))
    np.testing.assert_array_equal(f1, f2)
    # rolling by one token keeps most shingles → high overlap
    rolled = np.roll(doc, 1, axis=1)
    f3 = np.asarray(shingle_fingerprints(jnp.asarray(rolled), cfg))
    inter = (f1 & f3).sum()
    union = (f1 | f3).sum()
    assert inter / max(union, 1) > 0.7


def test_pipeline_batches_shapes():
    cfg = DataConfig(vocab_size=512, seq_len=64, global_batch=4,
                     dedup=True, dedup_buffer=16)
    pipe = TokenPipeline(cfg)
    b = next(pipe.batches())
    assert b["tokens"].shape == (4, 64)
    assert b["labels"].shape == (4, 64)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert pipe.dedup_stats["seen"] > 0


def test_pipeline_state_resume_reproduces_batches():
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=4,
                     dedup=False, dedup_buffer=8)
    p1 = TokenPipeline(cfg)
    it1 = p1.batches()
    for _ in range(3):
        next(it1)
    saved = p1.state.to_dict()
    want = next(it1)

    p2 = TokenPipeline(cfg, state=IteratorState.from_dict(saved))
    got = next(p2.batches())
    np.testing.assert_array_equal(want["tokens"], got["tokens"])
