# Convenience entry points. PYTHONPATH covers src (the package) and the
# repo root (the benchmarks package).
PY := PYTHONPATH=src:. python

.PHONY: test test-all bench bench-smoke bench-e2e bench-serve bench-emit \
	bench-assoc bench-sharded

test:            ## tier-1 suite (what the driver verifies)
	$(PY) -m pytest -x -q -m "not slow"

test-all:        ## tier-1 + slow parity sweeps
	$(PY) -m pytest -q

bench:           ## full benchmark suite (BENCH_*.json + csv lines)
	$(PY) -m benchmarks.run

bench-e2e:       ## streaming hot-path benchmark only (BENCH_e2e.json)
	$(PY) -m benchmarks.run --e2e

bench-serve:     ## concurrent serving-tier benchmark (BENCH_serve.json)
	$(PY) -m benchmarks.run --serve

bench-emit:      ## emission-compaction A/B only (BENCH_e2e.json emission key)
	$(PY) -m benchmarks.bench_e2e --emit

bench-assoc:     ## moveout-gate A/B only (BENCH_stream.json located_scenario key)
	$(PY) -m benchmarks.bench_stream --assoc-only

bench-sharded:   ## sharded-pool device grid only (BENCH_e2e.json sharded_pool key)
	$(PY) -m benchmarks.bench_e2e --sharded

bench-smoke:     ## tier-1-safe perf smoke: quick e2e + dirty-stream + serve
	$(PY) -m benchmarks.run --e2e --quick --scenario --serve
