"""Paper Table 1: occurrence-filter threshold sweep per station.

Reports % fingerprints filtered, search runtime, and the false-positive
rate against injected ground-truth events (station 0 carries repeating
noise; others are clean — mirroring LTZ vs MQZ/KHZ/THZ/OXZ).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (bench_dataset, bench_fp_config,
                               bench_lsh_config, csv_line, timed)
from repro.core import fingerprint as F
from repro.core import lsh as L


def main():
    ds = bench_dataset(duration_s=600.0, with_noise=True)
    fcfg = bench_fp_config()
    rows = []
    for station in (0, 1):
        x = jnp.asarray(ds.waveforms[station])
        bits, _ = F.fingerprints_from_waveform(x, fcfg)
        n = bits.shape[0]
        lag_s = fcfg.lag_samples / fcfg.fs
        # ground-truth fingerprint indices around event arrivals
        truth_idx = set()
        for ev in range(len(ds.event_times)):
            at = ds.arrival_time(ev, station)
            for d in range(-2, 8):
                truth_idx.add(int(at / lag_s) + d)
        for thresh in (0.5, 0.05, 0.01):
            lcfg = bench_lsh_config(fcfg, occurrence_frac=0.0)
            mp = L.hash_mappings(fcfg.fp_dim, lcfg)
            sigs = L.signatures(bits, mp, lcfg)

            def search():
                pairs = L.candidate_pairs(sigs, lcfg)
                return L.occurrence_filter(pairs, n, thresh)

            t, (pairs, excluded) = timed(search)
            exc = np.asarray(excluded)
            filtered_pct = 100.0 * exc.sum() / n
            fp_filtered = sum(1 for i in truth_idx if 0 <= i < n and
                              exc[i])
            fp_rate = fp_filtered / max(len(truth_idx), 1)
            rows.append((station, thresh, filtered_pct, fp_rate, t))
            csv_line(f"occur.st{station}.thresh{thresh}", t * 1e6,
                     f"filtered={filtered_pct:.1f}% fp_rate={fp_rate:.3f} "
                     f"pairs={int(pairs.count())}")
    return rows


if __name__ == "__main__":
    main()
