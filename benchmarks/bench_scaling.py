"""Paper Figure 14: parallel scaling of hash generation + search.

On this 1-core container thread-scaling cannot be measured directly; the
paper's observation is that both stages are embarrassingly parallel across
fingerprint ranges. We verify the *structure*: N independent shards cost
~N× one shard (no cross-shard dependency), so per-shard wall time is flat —
the quantity that scales linearly with workers on a real machine. The
distributed execution of exactly this structure over mesh shards is
exercised in tests/test_distributed.py and the dry-run.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (bench_lsh_config, csv_line,
                               station_fingerprints, timed)
from repro.core import lsh as L


def main():
    ds, fcfg, bits, packed = station_fingerprints(station=1)
    n = (bits.shape[0] // 8) * 8
    bits = bits[:n]
    lcfg = bench_lsh_config(fcfg)
    mp = L.hash_mappings(fcfg.fp_dim, lcfg)
    rows = []
    for shards in (1, 2, 4, 8):
        size = n // shards

        def hash_all():
            return [L.signatures(bits[i * size:(i + 1) * size], mp, lcfg)
                    for i in range(shards)]

        t, sigs = timed(hash_all, repeats=2)
        rows.append((shards, t))
        total_overhead = t / rows[0][1]
        csv_line(f"scaling.hashgen.shards{shards}", t * 1e6,
                 f"total_work_ratio={total_overhead:.2f} "
                 f"(1.0 = perfectly parallelizable)")
    return rows


if __name__ == "__main__":
    main()
