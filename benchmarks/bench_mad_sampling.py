"""Paper Table 6 / §8.3: MAD sampling-rate speed/accuracy trade-off."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_dataset, bench_fp_config, csv_line, timed
from repro.core import fingerprint as F


def main():
    ds = bench_dataset(duration_s=600.0)
    fcfg = bench_fp_config()
    x = jnp.asarray(ds.waveforms[1])
    spec = F.spectrogram(x, fcfg)
    imgs = F.spectral_images(spec, fcfg)
    coeffs = F.wavelet_coeffs(imgs, fcfg)
    key = jax.random.PRNGKey(0)

    t_full, (med_f, mad_f) = timed(
        lambda: F.mad_stats(coeffs, 1.0, key), repeats=3)
    z_full = F.mad_normalize(coeffs, med_f, mad_f)
    bits_full = np.asarray(F.topk_binarize(z_full, fcfg))

    rows = []
    for rate in (0.5, 0.1, 0.01):
        t, (med, mad) = timed(lambda: F.mad_stats(coeffs, rate, key),
                              repeats=3)
        z = F.mad_normalize(coeffs, med, mad)
        bits = np.asarray(F.topk_binarize(z, fcfg))
        acc = (bits == bits_full).mean()
        rows.append((rate, t, acc))
        csv_line(f"mad_sampling.rate{rate}", t * 1e6,
                 f"speedup={t_full/max(t,1e-9):.1f}x accuracy={acc:.4f}")
    csv_line("mad_sampling.rate1.0", t_full * 1e6,
             "speedup=1.0x accuracy=1.0")
    return rows


if __name__ == "__main__":
    main()
