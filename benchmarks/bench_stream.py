"""Streaming vs batch search: incremental cost per chunk at equal N.

Measures (a) steady-state ``StreamingIndex`` insert+query latency per
block, (b) end-to-end detector chunk throughput, and (c) offline
``lsh.search`` wall time over the same N fingerprints — the quantity the
streaming path amortizes: arrival of one new chunk costs O(chunk) against
the index instead of an O(N) re-sort of history.

``--memory`` additionally measures the bounded-mode claim: peak host
memory (tracemalloc) and peak buffered candidate-triplet rows of the
sliding-window + rolling-occurrence-filter path over a 1× and a 3× longer
synthetic stream. Flat peaks across the 3× run are the measured evidence
that host pair state is bounded by the window, not the stream length.

Emits csv lines plus a ``BENCH_stream.json`` trajectory point.
"""
from __future__ import annotations

import argparse
import json
import os
import tracemalloc

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (bench_lsh_config, csv_line,
                               station_fingerprints, stream_smoke_configs,
                               stream_smoke_dataset, timed)
from repro.core import fingerprint as F
from repro.core import lsh as L
from repro.core.detect import DetectConfig
from repro.core.synth import SynthConfig, make_dataset
from repro.stream import StreamingDetector, StreamConfig
from repro.stream import index as SI
from repro.stream.engine import ingest_chunks


def memory_point(base_duration_s: float = 600.0) -> dict:
    """Peak host memory of the rolling-filter path at 1× vs 3× stream.

    The detect/stream configs are built once (``stream_smoke_configs``);
    only the synthetic trace differs between the 1× and 3× runs.
    """
    cfg, scfg = stream_smoke_configs(bounded=True)
    out = {}
    for mult in (1, 3):
        ds = stream_smoke_dataset(duration_s=base_duration_s * mult,
                                  events_per_source=4 * mult)
        wf = ds.waveforms[0]
        det = StreamingDetector(cfg, scfg, n_stations=1)
        chunks = [wf[s: s + 6000] for s in range(0, wf.size, 6000)]
        for c in chunks[:4]:          # compile + freeze stats untraced
            det.push(c)
        det.stations[0].flush()       # pre-compile the masked-tail step too
        tracemalloc.start()
        for c in chunks[4:]:
            det.push(c)
        det.stations[0].flush()
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        st = det.stations[0]
        out[f"x{mult}"] = {
            "samples": int(wf.size),
            "fingerprints": int(st.ring.next_fp),
            "pairs_seen": int(st.filter.pairs_seen),
            "windows_closed": int(st.filter.windows_closed),
            "peak_traced_mb": round(peak / 2**20, 3),
            "peak_buffered_triplets": int(st.peak_tri_rows),
            "final_buffered_triplets": int(st.host_state_rows()),
        }
        csv_line(f"stream.memory_x{mult}", peak / 2**20,
                 f"unit=MB triplets={st.peak_tri_rows} "
                 f"windows={st.filter.windows_closed}")
    out["peak_mb_ratio_x3_over_x1"] = round(
        out["x3"]["peak_traced_mb"] / max(out["x1"]["peak_traced_mb"],
                                          1e-9), 3)
    out["peak_triplets_ratio_x3_over_x1"] = round(
        out["x3"]["peak_buffered_triplets"]
        / max(out["x1"]["peak_buffered_triplets"], 1), 3)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--memory", action="store_true",
                    help="also record rolling-filter peak host memory "
                         "(1x vs 3x stream) into BENCH_stream.json")
    ap.add_argument("--memory-duration-s", type=float, default=600.0)
    args = ap.parse_args(argv)
    ds, fcfg, bits, packed = station_fingerprints(station=1)
    n = bits.shape[0]
    lcfg = bench_lsh_config(fcfg)
    mp = L.hash_mappings(fcfg.fp_dim, lcfg)
    sigs = L.signatures(bits, mp, lcfg)

    # --- offline: full sort-based search at N (what a re-run would pay)
    t_search, _ = timed(lambda: L.candidate_pairs(sigs, lcfg).valid.sum())
    csv_line("stream.batch_search_at_N", t_search * 1e6, f"N={n}")

    # --- streaming index: steady-state insert+query per block
    block = 64
    state = SI.init_index(lcfg, SI.StreamIndexConfig(n_buckets=2048,
                                                     bucket_cap=8))
    ids0 = jnp.arange(block, dtype=jnp.int32)
    # preload the index to ~N resident entries, then time one more block
    for i in range(0, (n // block) * block, block):
        state = SI.insert(state, sigs[i:i + block], ids0 + i, lcfg)
    sb = sigs[:block]
    holder = {"state": state, "next": n}

    def insert_query():
        # rolling steady state (insert donates its input buffers)
        ids = ids0 + holder["next"]
        holder["next"] += block
        holder["state"] = SI.insert(holder["state"], sb, ids, lcfg)
        return SI.query(holder["state"], sb, ids, lcfg).valid.sum()

    t_iq, _ = timed(insert_query)
    csv_line("stream.insert_query_block", t_iq * 1e6,
             f"block={block} resident≈{n} "
             f"speedup_vs_resort={t_search / max(t_iq, 1e-12):.1f}x")

    # --- end-to-end detector chunk throughput (incl. fingerprinting)
    cfg = DetectConfig(fingerprint=fcfg, lsh=lcfg)
    det = StreamingDetector(
        cfg, StreamConfig(block_fingerprints=block,
                          index=SI.StreamIndexConfig(n_buckets=2048,
                                                     bucket_cap=8),
                          stats_warmup_blocks=2),
        n_stations=1)
    # shared ingest loop (same code path as serve_detect / bench_e2e)
    res = ingest_chunks(det, ds.waveforms[1], n_chunks=16, warmup_chunks=4)
    wall, n_done = res["wall_s"], res["timed_chunks"]
    ing = det.stations[0].stats.summary()
    csv_line("stream.detector_chunk", wall / n_done * 1e6,
             f"chunks_per_s={n_done / max(wall, 1e-9):.1f} "
             f"samples_per_s={res['samples'] / max(wall, 1e-9):.0f}")

    point = {
        "n_fingerprints": int(n),
        "batch_search_us": round(t_search * 1e6, 1),
        "insert_query_block_us": round(t_iq * 1e6, 1),
        "block": block,
        "amortized_speedup": round(t_search / max(t_iq, 1e-12), 2),
        "detector_chunks_per_s": round(n_done / max(wall, 1e-9), 2),
        "detector_samples_per_s": round(
            res["samples"] / max(wall, 1e-9), 1),
        "ingest": ing,
    }
    if args.memory:
        point["rolling_memory"] = memory_point(args.memory_duration_s)
    out = os.environ.get("BENCH_OUT_DIR", ".")
    with open(os.path.join(out, "BENCH_stream.json"), "w") as f:
        json.dump(point, f, indent=2)
    print(f"# wrote {os.path.join(out, 'BENCH_stream.json')}")
    return point


if __name__ == "__main__":
    main()
